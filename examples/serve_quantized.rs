//! Serving demo: the continuous-batching decode engine with a quantised
//! model, comparing FP32 vs W6A6/W4A4 BFP throughput, latency, batch
//! occupancy / decode amortisation (every engine step dequantises each
//! packed weight once for the whole batch), and — via the packed-weight
//! serving path — *measured* resident weight memory (the deployment story
//! the paper's ASIC argument targets: block formats shrink the bytes a
//! decoder must keep hot by ~5×).
//!
//!     cargo run --release --example serve_quantized

use bbq::coordinator::experiment::{default_steps, get_or_train};
use bbq::coordinator::{run_batched, Request, ServerConfig};
use bbq::data::vocab::Vocab;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::presets;

fn main() {
    let vocab = Vocab::build();
    let params = get_or_train("micro", default_steps("micro"), false);
    let prompts = [
        "the cat chased the",
        "alice took the key . the key belongs to",
        "the movie was wonderful and",
        "bob was in the",
    ];
    let reqs: Vec<Request> = (0..24)
        .map(|i| Request {
            id: i as u64,
            prompt: vocab.encode(prompts[i % prompts.len()]),
            max_new_tokens: 12,
            temperature: 0.0,
        })
        .collect();
    let cfg = ServerConfig::default();
    for (name, plan) in [
        ("fp32", QuantPlan::fp32()),
        ("bfp6 (W6A6)", QuantPlan::uniform(presets::bfp_w(6))),
        ("bfp4 (W4A4)", QuantPlan::uniform(presets::bfp_w(4))),
    ] {
        let model = Model::new(params.clone(), plan);
        let wm = model.weight_memory();
        println!(
            "[{name}] weight cache: {} B dense-f32 → {} B resident ({:.2}x)",
            wm.dense_f32_bytes,
            wm.resident_bytes,
            wm.ratio()
        );
        let (resps, metrics) = run_batched(&model, reqs.clone(), &cfg);
        println!("[{name}] {}", metrics.summary());
        if name == "fp32" {
            for r in resps.iter().take(2) {
                let prompt = prompts[r.id as usize % 4];
                println!("  sample: {:?} → {}", prompt, vocab.decode(&r.tokens));
            }
        }
    }
}
