//! Serving demo: the continuous-batching decode engine with a quantised
//! model, comparing FP32 vs W6A6/W4A4 BFP throughput, latency, batch
//! occupancy / decode amortisation (every engine step dequantises each
//! packed weight once for the whole batch), and — via the packed-weight
//! serving path — *measured* resident weight memory (the deployment story
//! the paper's ASIC argument targets: block formats shrink the bytes a
//! decoder must keep hot by ~5×). Ends with the live `Engine` API:
//! submission through an `EngineHandle`, token streaming over
//! `TokenEvent`s, and mid-decode cancellation.
//!
//!     cargo run --release --example serve_quantized

use bbq::coordinator::experiment::{default_steps, get_or_train};
use bbq::coordinator::{run_batched, Engine, GenerationParams, Request, ServerConfig, TokenEvent};
use bbq::data::vocab::Vocab;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::presets;
use std::sync::Arc;

fn main() {
    let vocab = Vocab::build();
    let params = get_or_train("micro", default_steps("micro"), false);
    let prompts = [
        "the cat chased the",
        "alice took the key . the key belongs to",
        "the movie was wonderful and",
        "bob was in the",
    ];
    let reqs: Vec<Request> = (0..24)
        .map(|i| Request::greedy(i as u64, vocab.encode(prompts[i % prompts.len()]), 12))
        .collect();
    let cfg = ServerConfig::default();
    for (name, plan) in [
        ("fp32", QuantPlan::fp32()),
        ("bfp6 (W6A6)", QuantPlan::uniform(presets::bfp_w(6))),
        ("bfp4 (W4A4)", QuantPlan::uniform(presets::bfp_w(4))),
    ] {
        let model = Model::new(params.clone(), plan);
        let wm = model.weight_memory();
        println!(
            "[{name}] weight cache: {} B dense-f32 → {} B resident ({:.2}x)",
            wm.dense_f32_bytes,
            wm.resident_bytes,
            wm.ratio()
        );
        let (resps, metrics) = run_batched(&model, reqs.clone(), &cfg);
        println!("[{name}] {}", metrics.summary());
        if name == "fp32" {
            for r in resps.iter().take(2) {
                let prompt = prompts[r.id as usize % 4];
                println!("  sample: {:?} → {}", prompt, vocab.decode(&r.tokens));
            }
        }
    }

    // --- the live Engine API -------------------------------------------
    // A long-lived scheduler accepting work after start: one request
    // streams its tokens as the engine steps, another is cancelled
    // mid-decode (its slot is recycled on the next step).
    let model = Arc::new(Model::new(params, QuantPlan::uniform(presets::bfp_w(6))));
    let engine = Engine::start(model, ServerConfig::default());
    let sampled = Request {
        id: 100,
        prompt: vocab.encode("the cat chased the"),
        params: GenerationParams {
            max_new_tokens: 10,
            temperature: 0.8,
            top_k: 16,
            seed: Some(7),
            ..GenerationParams::default()
        },
    };
    let streaming = engine.submit(sampled).expect("engine open");
    let bye = Request::greedy(101, vocab.encode("bob was in the"), 64);
    let doomed = engine.submit(bye).expect("engine open");
    doomed.cancel();
    let mut streamed = Vec::new();
    while let Some(ev) = streaming.recv() {
        match ev {
            TokenEvent::Token(t) => streamed.push(t),
            TokenEvent::Finished { reason, .. } => {
                println!("[engine] streamed → {:?} ({reason:?})", vocab.decode(&streamed));
                break;
            }
            _ => {}
        }
    }
    let cancelled = doomed.wait();
    println!(
        "[engine] cancelled request {} after {} tokens ({:?})",
        cancelled.id,
        cancelled.tokens.len(),
        cancelled.finish
    );
    println!("[engine] {}", engine.shutdown().summary());
}
