//! END-TO-END DRIVER (DESIGN.md §validation): proves all three layers
//! compose on a real small workload.
//!
//! 1. L2/L1 → L3: load the AOT-compiled JAX train-step artifact (which
//!    inlines the Pallas-lowered quantisation graph) and train a small
//!    transformer on the synthetic corpus for a few hundred steps via
//!    PJRT, logging the loss curve — python never runs here.
//! 2. PTQ the trained weights with every Table 3 format using the Rust
//!    quantisers and print the paper-shaped perplexity/density table.
//! 3. Cross-check: the PJRT fp32 forward and the Rust-native forward
//!    agree on held-out logits.
//!
//! Requires `make artifacts` first.
//!
//!     cargo run --release --example e2e_train_quantize

use bbq::data::corpus::{test_stream, train_stream};
use bbq::data::lm_eval::perplexity;
use bbq::data::vocab::Vocab;
use bbq::model::config::ModelConfig;
use bbq::model::plan::QuantPlan;
use bbq::model::{Model, Params, PosEncoding};
use bbq::quant::config::presets;
use bbq::runtime::{LmFwdExec, Runtime, TrainStepExec};
use bbq::util::table::{fnum, Table};

fn main() {
    if !bbq::runtime::PJRT_AVAILABLE {
        eprintln!("this example needs the PJRT runtime — rebuild with `--features xla`");
        std::process::exit(1);
    }
    let artifacts = bbq::util::artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut rt = Runtime::open(&artifacts).expect("open runtime");
    let steps = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300usize);

    // the golden-config model is what the artifact was lowered for
    let cfg = ModelConfig {
        name: "golden".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        d_ff: 64,
        vocab_size: 64,
        max_seq: 32,
        pos: PosEncoding::Learned,
        ln_eps: 1e-5,
    };
    let mut params = Params::init(&cfg, 123);
    let train_exec = TrainStepExec::load(&mut rt, "train_step_golden").expect("train artifact");
    let seq = train_exec.seq;

    let vocab = Vocab::build();
    let fold = |t: usize| t % cfg.vocab_size;
    let train: Vec<usize> = train_stream(&vocab, steps * seq + seq + 1)
        .into_iter()
        .map(fold)
        .collect();
    let test: Vec<usize> = test_stream(&vocab, 24 * seq).into_iter().map(fold).collect();

    println!("== phase 1: PJRT training ({steps} steps, seq {seq}) ==");
    let t0 = std::time::Instant::now();
    let mut curve = Vec::new();
    for step in 0..steps {
        let off = step * seq;
        let loss = train_exec
            .step(&train[off..off + seq], &train[off + 1..off + seq + 1], 0.5, &mut params)
            .expect("train step");
        curve.push(loss);
        if step % 50 == 0 || step + 1 == steps {
            println!("  step {step:>4}: loss {loss:.4}");
        }
    }
    let t_train = t0.elapsed();
    let first10: f64 = curve[..10].iter().sum::<f64>() / 10.0;
    let last10: f64 = curve[curve.len() - 10..].iter().sum::<f64>() / 10.0;
    println!(
        "  loss {first10:.3} → {last10:.3} in {:.1}s ({:.1} steps/s)",
        t_train.as_secs_f64(),
        steps as f64 / t_train.as_secs_f64()
    );
    assert!(last10 < first10 - 0.3, "training did not converge");

    println!("\n== phase 2: PJRT fwd vs rust-native fwd cross-check ==");
    let fwd = LmFwdExec::load(&mut rt, "lm_fwd_golden_fp32", cfg.vocab_size).expect("fwd artifact");
    let toks: Vec<usize> = test[..fwd.seq].to_vec();
    let pjrt_logits = fwd.run(&toks, &params).expect("pjrt fwd");
    let native = Model::new(params.clone(), QuantPlan::fp32()).forward(&toks, None);
    let mut max_err = 0.0f32;
    for (a, b) in pjrt_logits.data.iter().zip(&native.data) {
        max_err = max_err.max((a - b).abs());
    }
    println!("  max |pjrt - native| = {max_err:.2e}");
    assert!(max_err < 1e-3);

    println!("\n== phase 3: PTQ sweep of the PJRT-trained weights ==");
    let mut table = Table::new("e2e PTQ results", &["format", "ppl", "Δppl", "mem", "bits/el"]);
    let fp32_ppl = perplexity(
        &Model::new(params.clone(), QuantPlan::fp32()),
        &test,
        seq,
        16,
    )
    .perplexity;
    table.row(vec![
        "fp32".into(),
        fnum(fp32_ppl, 3),
        "-".into(),
        "1.0x".into(),
        "32".into(),
    ]);
    for (name, fmt) in presets::table3_formats() {
        let m = Model::new(params.clone(), QuantPlan::uniform(fmt));
        let ppl = perplexity(&m, &test, seq, 16).perplexity;
        table.row(vec![
            name.to_string(),
            fnum(ppl, 3),
            format!("{:+.3}", ppl - fp32_ppl),
            format!("{:.1}x", fmt.memory_density()),
            format!("{:.1}", fmt.bits_per_element()),
        ]);
        }
    println!("{}", table.render());
    let _ = bbq::util::write_file(
        &bbq::util::results_dir().join("e2e_train_quantize.md"),
        &table.render(),
    );
    println!("e2e OK — all three layers compose.");
}
