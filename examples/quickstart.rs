//! Quickstart: quantise tensors with every format the paper studies,
//! inspect the error/range trade-offs, and print the hardware densities.
//!
//!     cargo run --release --example quickstart

use bbq::density::arith::calibrate;
use bbq::quant::config::{presets, QFormat};
use bbq::quant::fake_quant;
use bbq::quant::qtensor::{decode, encode};
use bbq::util::check::llmish_values;
use bbq::util::rng::Pcg32;
use bbq::util::stats::sqnr_db;
use bbq::Tensor;

fn main() {
    let mut rng = Pcg32::new(42);
    // LLM-ish data: gaussian with occasional outliers — the regime the
    // paper calls "numerical scaling offsets"
    let x = Tensor::new(&[16, 64], llmish_values(&mut rng, 1024, 1.0, 0.01));
    let cost = calibrate();

    println!("{:<18} {:>9} {:>8} {:>8} {:>9}", "format", "sqnr dB", "bits/el", "mem", "arith");
    let mut formats = vec![("FP32", QFormat::Fp32)];
    formats.extend(presets::table3_formats());
    for (name, fmt) in formats {
        let q = fake_quant(&x, fmt);
        let sqnr = sqnr_db(&x.data, &q.data);
        println!(
            "{:<18} {:>9.1} {:>8.2} {:>7.2}x {:>8.2}x",
            name,
            sqnr,
            fmt.bits_per_element(),
            fmt.memory_density(),
            cost.arithmetic_density(fmt),
        );
    }

    // bit-packed storage round-trip (the density numbers are measured,
    // not just computed)
    let fmt = presets::bfp_w(6);
    let packed = encode(&x, fmt);
    let unpacked = decode(&packed);
    assert_eq!(fake_quant(&x, fmt).data, unpacked.data);
    println!(
        "\npacked W6A6 BFP: {} values in {} bytes = {:.2} bits/element (formula {:.2})",
        packed.numel(),
        packed.packed_bytes(),
        packed.bits_per_element(),
        fmt.bits_per_element()
    );

    // the paper's core mechanism, in one picture: one outlier ruins a
    // whole per-tensor fixed-point grid but only its own 16-wide block
    // under BFP
    let mut data = vec![0.02f32; 64];
    data[5] = 50.0;
    let t = Tensor::new(&[1, 64], data);
    let fx = fake_quant(&t, presets::fixed8());
    let bf = fake_quant(&t, presets::bfp_w(6));
    println!(
        "\noutlier demo — value at [40] (true 0.02): fixed8 → {:.4}, BFP6 → {:.4}",
        fx.data[40], bf.data[40]
    );
}
