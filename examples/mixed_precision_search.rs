//! Mixed-precision search demo (paper §4.4 / Figure 3): TPE over
//! per-tensor BFP bit widths on a LAMBADA-style task, recovering 4-bit
//! accuracy without losing memory density.
//!
//!     cargo run --release --example mixed_precision_search [trials]

use bbq::coordinator::experiment::{default_steps, get_or_train};
use bbq::data::tasks::{evaluate, generate, Task};
use bbq::data::vocab::Vocab;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::presets;
use bbq::search::objective::Objective;
use bbq::search::runner::{run_search, SearchConfig};
use bbq::search::space::SearchSpace;

fn main() {
    let trials = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30usize);
    let vocab = Vocab::build();
    let params = get_or_train("micro", default_steps("micro"), false);
    let cfg = params.cfg.clone();
    let task = Task::Lambada;
    let exs = generate(task, &vocab, 555, 40);

    let acc_of = |plan: QuantPlan| {
        evaluate(&Model::new(params.clone(), plan), task, &exs, 2).accuracy
    };
    let fp32 = acc_of(QuantPlan::fp32());
    let uni4 = acc_of(QuantPlan::uniform(presets::bfp_w(4)));
    println!("fp32 acc {:.1}% | uniform 4-bit {:.1}%", fp32 * 100.0, uni4 * 100.0);

    let space = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
    println!(
        "searching {} per-tensor dims × {} formats, {trials} TPE trials…",
        space.dims.len(),
        space.choices.len()
    );
    let sc = SearchConfig {
        trials,
        threads: 2,
        seed: 7,
        mem_threshold: presets::bfp_w(4).memory_density() * 0.95,
        objective: Objective::software(0.02),
        ..Default::default()
    };
    let res = run_search(&params, space, task, &exs, fp32, &sc);
    let best = res.best.as_ref().expect("no trials");
    println!(
        "best mixed config: acc {:.1}% at {:.2}x memory (uniform 4-bit is {:.2}x)",
        best.accuracy * 100.0,
        best.mem_density,
        presets::bfp_w(4).memory_density()
    );
    println!("\nper-layer mean bit width over accepted configs (Figure 3):");
    for (l, bits) in res.layer_bit_profile(cfg.n_layers).iter().enumerate() {
        let bar = "#".repeat((bits * 4.0) as usize);
        println!("  layer {l}: {bits:.2} bits {bar}");
    }
}
