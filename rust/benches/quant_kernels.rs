//! Quantisation + GEMM micro-benchmarks (custom harness — criterion is
//! unavailable offline; see DESIGN.md §3). One bench group per paper
//! artifact whose *cost* we claim: the quantisers behind Table 3, the
//! quantised GEMM hot path, the end-to-end forward, and the serving loop.
//!
//!     cargo bench

use bbq::model::config::ModelConfig;
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::presets;
use bbq::quant::fake_quant;
use bbq::quant::qmatmul::{bfp_matmul_blocked, qmatmul, qmatmul_packed, qmatmul_pret};
use bbq::quant::qtensor::encode;
use bbq::quant::{fake_quant_buffer, GemmQuant};
use bbq::tensor::matmul::{matmul, matmul_bt};
use bbq::tensor::Tensor;
use bbq::util::bench::{black_box, Bench};
use bbq::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::new(7);
    println!("== quantiser throughput (1M elements, [1,16] blocks) ==");
    let n = 1 << 20;
    let src: Vec<f32> = (0..n).map(|_| rng.normal_with(0.0, 1.0)).collect();
    for (name, fmt) in [
        ("fixed8", presets::fixed8()),
        ("minifloat8", presets::minifloat8()),
        ("dmf8", presets::dmf8()),
        ("bfp6", presets::bfp_w(6)),
        ("bfp4", presets::bfp_w(4)),
        ("bm8", presets::bm8()),
        ("bl8", presets::bl8()),
    ] {
        let mut buf = src.clone();
        let r = Bench::new(&format!("quantize/{name}"))
            .items(n as f64)
            .budget_ms(300.0)
            .run(|| {
                buf.copy_from_slice(&src);
                fake_quant_buffer(black_box(&mut buf), 1024, fmt);
            });
        println!("{}", r.line());
    }

    println!("\n== GEMM paths (256x256x256) ==");
    let a = Tensor::randn(&[256, 256], 1.0, &mut rng);
    let b = Tensor::randn(&[256, 256], 0.3, &mut rng);
    let bt = b.t();
    let macs = 256f64 * 256.0 * 256.0;
    let r = Bench::new("matmul/f32").items(macs).budget_ms(400.0).run(|| {
        black_box(matmul(black_box(&a), black_box(&b)));
    });
    println!("{}", r.line());
    let r = Bench::new("matmul/f32_bt").items(macs).budget_ms(400.0).run(|| {
        black_box(matmul_bt(black_box(&a), black_box(&bt)));
    });
    println!("{}", r.line());
    let r = Bench::new("qmatmul/bfp6_fakequant").items(macs).budget_ms(400.0).run(|| {
        black_box(qmatmul(
            black_box(&a),
            black_box(&b),
            GemmQuant::uniform(presets::bfp_w(6)),
        ));
    });
    println!("{}", r.line());
    let r = Bench::new("qmatmul/bfp6_eq4_intdomain").items(macs).budget_ms(600.0).run(|| {
        black_box(bfp_matmul_blocked(black_box(&a), black_box(&bt), 8, 5, 16));
    });
    println!("{}", r.line());

    println!("\n== packed vs fake-quant decode GEMM ([1,k]×[n,k], per-token decode shape) ==");
    // the serving trade: the dense cache holds dequantised f32 weights,
    // the packed cache holds the bit-packed payload (~4.9× smaller for
    // BFP6) and dequantises block-wise inside the GEMM
    for (k, n) in [(512usize, 512usize), (1024, 1024)] {
        let a1 = Tensor::randn(&[1, k], 1.0, &mut rng);
        let wt = Tensor::randn(&[n, k], 0.3, &mut rng);
        let fmt = presets::bfp_w(6);
        let wt_dense = fake_quant(&wt, fmt);
        let wt_packed = encode(&wt, fmt);
        println!(
            "  k={k} n={n}: dense cache {} B, packed cache {} B ({:.2}x)",
            n * k * 4,
            wt_packed.packed_bytes(),
            (n * k * 4) as f64 / wt_packed.packed_bytes() as f64
        );
        let macs = (k * n) as f64;
        let r = Bench::new(&format!("qmatmul_pret/bfp6_dense_{k}x{n}"))
            .items(macs)
            .budget_ms(400.0)
            .run(|| {
                black_box(qmatmul_pret(black_box(&a1), black_box(&wt_dense), fmt));
            });
        println!("{}", r.line());
        let r = Bench::new(&format!("qmatmul_packed/bfp6_{k}x{n}"))
            .items(macs)
            .budget_ms(400.0)
            .run(|| {
                black_box(qmatmul_packed(black_box(&a1), black_box(&wt_packed), fmt));
            });
        println!("{}", r.line());
    }

    println!("\n== model forward (tiny, seq 64) — Table 3's unit of work ==");
    let cfg = ModelConfig::preset("tiny");
    let params = Params::init(&cfg, 3);
    let toks: Vec<usize> = (0..64).map(|i| (i * 37) % cfg.vocab_size).collect();
    for (name, plan) in [
        ("fp32", QuantPlan::fp32()),
        ("bfp6", QuantPlan::uniform(presets::bfp_w(6))),
        ("bfp4", QuantPlan::uniform(presets::bfp_w(4))),
        ("llm_int8", QuantPlan::llm_int8(8)),
    ] {
        let model = Model::new(params.clone(), plan);
        let r = Bench::new(&format!("forward/tiny/{name}"))
            .items(64.0)
            .budget_ms(1200.0)
            .iters(3, 200)
            .run(|| {
                black_box(model.forward(black_box(&toks), None));
            });
        println!("{}", r.line());
    }

    println!("\n== serving (micro, batch 8, greedy, 8 new tokens) ==");
    let cfgm = ModelConfig::preset("micro");
    let paramsm = Params::init(&cfgm, 3);
    let model = Model::new(paramsm, QuantPlan::uniform(presets::bfp_w(6)));
    let reqs: Vec<bbq::coordinator::Request> = (0..8)
        .map(|i| bbq::coordinator::Request {
            id: i,
            prompt: vec![3, 10, 42],
            max_new_tokens: 8,
            temperature: 0.0,
        })
        .collect();
    let r = Bench::new("serve/batch8")
        .items(64.0)
        .budget_ms(2000.0)
        .iters(3, 50)
        .run(|| {
            black_box(bbq::coordinator::run_batched(
                &model,
                reqs.clone(),
                &bbq::coordinator::ServerConfig::default(),
            ));
        });
    println!("{}", r.line());
}
