//! Quantisation + GEMM micro-benchmarks (custom harness — criterion is
//! unavailable offline; see DESIGN.md §3). One bench group per paper
//! artifact whose *cost* we claim: the quantisers behind Table 3, the
//! quantised GEMM hot path, the end-to-end forward, the serving loop, and
//! the continuous-batching decode engine.
//!
//!     cargo bench                      # full budgets
//!     cargo bench -- --quick           # CI mode: ~20× smaller time budgets
//!     cargo bench -- --quick --check   # CI gate: perf regressions exit 1
//!
//! Either way the decode-engine section writes `BENCH_decode.json`
//! (single-stream vs batch-8 tokens/sec under BFP6, the live-Engine-API
//! path vs the run_batched wrapper, plus resident weight bytes), the
//! prefill section writes `BENCH_prefill.json` (chunked vs
//! token-at-a-time prefill tokens/sec), and the full-context section
//! writes `BENCH_forward.json` (fused packed prefill GEMM vs the
//! pre-refactor transient dense decode, plus forward tok/s), and the
//! paged-KV section writes `BENCH_kv.json` (paged vs dense-equivalent
//! decode, quantised-KV capacity multiplier, warm-vs-cold prefix-cached
//! prefill), and the plan-pipeline section writes `BENCH_plan.json`
//! (search → artifact → serve bit-identity, distinct bit-width count,
//! BFP4-plus-outlier-overlay perplexity vs plain BFP4, packed density),
//! and the speculative section writes `BENCH_spec.json` (self-drafting
//! BFP4-draft / BFP6-target decode vs plain decode tok/s, acceptance
//! rate, accepted tokens per target step)
//! next to the manifest — CI uploads all six as bench artifacts. The SIMD section measures the runtime-dispatched
//! microkernels against the forced-scalar reference at the three call
//! shapes (m == 1 decode GEMM, m ≥ 4 prefill panel GEMM, raw block
//! decode) and threads the ratios into BENCH_decode.json and
//! BENCH_forward.json. Under `--check` the acceptance bars (batch-8 ≥ 2×
//! single-stream decode; chunk-8 ≥ 2× chunk-1 prefill; EngineHandle
//! submission within 10% of run_batched; fused prefill GEMM ≥ 1.0× of
//! transient dense decode; SIMD ≥ 1.0× scalar at every shape when a SIMD
//! backend is active; paged-f32 decode ≥ 0.90× dense-equivalent;
//! quantised-KV capacity ≥ 2×; prefix-cached prefill ≥ 2× cold; searched
//! plan mixes ≥ 3 bit-widths and reloads bit-identically; BFP4 + outlier
//! overlay beats plain BFP4 perplexity at ≥ 4× density; the speculative
//! greedy stream is bit-identical to target-only decode and accepts ≥ 1.0
//! draft tokens per target step) are hard failures instead of
//! scrolled-past warnings.

use bbq::coordinator::experiment::get_or_train;
use bbq::coordinator::{
    run_batched, run_batched_with_draft, Engine, Metrics, Request, ServerConfig,
};
use bbq::kernels::{self, Backend};
use bbq::model::config::ModelConfig;
use bbq::model::kv_cache::BatchedDecodeSession;
use bbq::model::params::Params;
use bbq::model::plan::QuantPlan;
use bbq::model::{KvConfig, Model, SessionConfig};
use bbq::quant::config::presets;
use bbq::quant::fake_quant;
use bbq::quant::qmatmul::{
    bfp_matmul_blocked, matmul_packed_bt, qmatmul, qmatmul_packed, qmatmul_pret,
};
use bbq::quant::qtensor::{decode, encode};
use bbq::quant::{fake_quant_buffer, GemmQuant};
use bbq::tensor::matmul::{matmul, matmul_bt};
use bbq::tensor::Tensor;
use bbq::util::bench::{black_box, Bench};
use bbq::util::json::Json;
use bbq::util::rng::Pcg32;

fn main() {
    // `cargo bench` also forwards a bare `--bench` flag; ignore it
    let quick =
        std::env::args().any(|a| a == "--quick") || std::env::var("BBQ_BENCH_QUICK").is_ok();
    let check = std::env::args().any(|a| a == "--check");
    let budget_div = if quick { 20.0 } else { 1.0 };
    let ms = |full: f64| (full / budget_div).max(10.0);
    if quick {
        println!("(quick mode: budgets cut ~20x for CI)");
    }
    if check {
        println!("(check mode: regression gates are hard failures)");
    }
    // regression-gate failures collected across sections; fatal at exit
    // under --check so CI fails instead of scrolling past a warning
    let mut gates: Vec<String> = Vec::new();
    let mut rng = Pcg32::new(7);
    println!("== quantiser throughput (1M elements, [1,16] blocks) ==");
    let n = 1 << 20;
    let src: Vec<f32> = (0..n).map(|_| rng.normal_with(0.0, 1.0)).collect();
    for (name, fmt) in [
        ("fixed8", presets::fixed8()),
        ("minifloat8", presets::minifloat8()),
        ("dmf8", presets::dmf8()),
        ("bfp6", presets::bfp_w(6)),
        ("bfp4", presets::bfp_w(4)),
        ("bm8", presets::bm8()),
        ("bl8", presets::bl8()),
    ] {
        let mut buf = src.clone();
        let r = Bench::new(&format!("quantize/{name}"))
            .items(n as f64)
            .budget_ms(ms(300.0))
            .run(|| {
                buf.copy_from_slice(&src);
                fake_quant_buffer(black_box(&mut buf), 1024, fmt);
            });
        println!("{}", r.line());
    }

    println!("\n== GEMM paths (256x256x256) ==");
    let a = Tensor::randn(&[256, 256], 1.0, &mut rng);
    let b = Tensor::randn(&[256, 256], 0.3, &mut rng);
    let bt = b.t();
    let macs = 256f64 * 256.0 * 256.0;
    let r = Bench::new("matmul/f32").items(macs).budget_ms(ms(400.0)).run(|| {
        black_box(matmul(black_box(&a), black_box(&b)));
    });
    println!("{}", r.line());
    let r = Bench::new("matmul/f32_bt").items(macs).budget_ms(ms(400.0)).run(|| {
        black_box(matmul_bt(black_box(&a), black_box(&bt)));
    });
    println!("{}", r.line());
    let r = Bench::new("qmatmul/bfp6_fakequant").items(macs).budget_ms(ms(400.0)).run(|| {
        black_box(qmatmul(
            black_box(&a),
            black_box(&b),
            GemmQuant::uniform(presets::bfp_w(6)),
        ));
    });
    println!("{}", r.line());
    let r = Bench::new("qmatmul/bfp6_eq4_intdomain").items(macs).budget_ms(ms(600.0)).run(|| {
        black_box(bfp_matmul_blocked(black_box(&a), black_box(&bt), 8, 5, 16));
    });
    println!("{}", r.line());

    println!("\n== packed vs fake-quant decode GEMM ([1,k]×[n,k], per-token decode shape) ==");
    // the serving trade: the dense cache holds dequantised f32 weights,
    // the packed cache holds the bit-packed payload (~4.9× smaller for
    // BFP6) and dequantises block-wise inside the GEMM
    for (k, n) in [(512usize, 512usize), (1024, 1024)] {
        let a1 = Tensor::randn(&[1, k], 1.0, &mut rng);
        let wt = Tensor::randn(&[n, k], 0.3, &mut rng);
        let fmt = presets::bfp_w(6);
        let wt_dense = fake_quant(&wt, fmt);
        let wt_packed = encode(&wt, fmt);
        println!(
            "  k={k} n={n}: dense cache {} B, packed cache {} B ({:.2}x)",
            n * k * 4,
            wt_packed.packed_bytes(),
            (n * k * 4) as f64 / wt_packed.packed_bytes() as f64
        );
        let macs = (k * n) as f64;
        let r = Bench::new(&format!("qmatmul_pret/bfp6_dense_{k}x{n}"))
            .items(macs)
            .budget_ms(ms(400.0))
            .run(|| {
                black_box(qmatmul_pret(black_box(&a1), black_box(&wt_dense), fmt));
            });
        println!("{}", r.line());
        let r = Bench::new(&format!("qmatmul_packed/bfp6_{k}x{n}"))
            .items(macs)
            .budget_ms(ms(400.0))
            .run(|| {
                black_box(qmatmul_packed(black_box(&a1), black_box(&wt_packed), fmt));
            });
        println!("{}", r.line());
    }

    println!("\n== model forward (tiny, seq 64) — Table 3's unit of work ==");
    let cfg = ModelConfig::preset("tiny");
    let params = Params::init(&cfg, 3);
    let toks: Vec<usize> = (0..64).map(|i| (i * 37) % cfg.vocab_size).collect();
    for (name, plan) in [
        ("fp32", QuantPlan::fp32()),
        ("bfp6", QuantPlan::uniform(presets::bfp_w(6))),
        ("bfp4", QuantPlan::uniform(presets::bfp_w(4))),
        ("llm_int8", QuantPlan::llm_int8(8)),
    ] {
        let model = Model::new(params.clone(), plan);
        let r = Bench::new(&format!("forward/tiny/{name}"))
            .items(64.0)
            .budget_ms(ms(1200.0))
            .iters(3, 200)
            .run(|| {
                black_box(model.forward(black_box(&toks), None));
            });
        println!("{}", r.line());
    }

    println!("\n== serving (micro, batch 8, greedy, 8 new tokens) ==");
    let cfgm = ModelConfig::preset("micro");
    let paramsm = Params::init(&cfgm, 3);
    let model = Model::new(paramsm, QuantPlan::uniform(presets::bfp_w(6)));
    let reqs: Vec<Request> = (0..8)
        .map(|i| Request::greedy(i, vec![3, 10, 42], 8))
        .collect();
    let r = Bench::new("serve/batch8")
        .items(64.0)
        .budget_ms(ms(2000.0))
        .iters(3, 50)
        .run(|| {
            black_box(run_batched(&model, reqs.clone(), &ServerConfig::default()));
        });
    println!("{}", r.line());

    let simd = bench_simd(quick, &mut gates);
    bench_decode_engine(quick, &mut gates, &simd);
    bench_prefill_engine(quick, &mut gates);
    bench_forward_unified(quick, &mut gates, &simd);
    bench_kv(quick, &mut gates);
    bench_plan(quick, &mut gates);
    bench_spec(quick, &mut gates);

    if !gates.is_empty() {
        println!("\nbench gates below their acceptance bars:");
        for g in &gates {
            println!("  FAIL: {g}");
        }
        if check {
            std::process::exit(1);
        }
        println!("  (run with --check to make these fatal)");
    }
}

/// Measured SIMD-vs-scalar ratios from [`bench_simd`], threaded into the
/// BENCH_decode.json / BENCH_forward.json writers so the snapshots carry
/// the microkernel story alongside the engine-level numbers.
struct SimdBench {
    isa: String,
    simd_decode_gemm_mac_per_s: f64,
    scalar_decode_gemm_mac_per_s: f64,
    simd_vs_scalar_decode: f64,
    simd_prefill_gemm_mac_per_s: f64,
    scalar_prefill_gemm_mac_per_s: f64,
    simd_vs_scalar_prefill: f64,
    simd_block_decode_elem_per_s: f64,
    scalar_block_decode_elem_per_s: f64,
    simd_vs_scalar_block_decode: f64,
}

/// SIMD microkernels vs the scalar reference, at the three dispatched
/// shapes: the m == 1 packed decode GEMM, the m ≥ 4 packed prefill panel
/// GEMM, and raw block decode (dequantise every weight row). Both sides
/// run in-process through [`kernels::with_isa`], so this measures exactly
/// the dispatch the engine uses. Under `--check` the active backend must
/// be ≥ 1.0× scalar on best-iteration times for every shape — SIMD that
/// loses to the reference is a regression, not a curiosity. (On a host
/// whose detected backend IS scalar the ratios are trivially 1.0× and the
/// gate is skipped.)
fn bench_simd(quick: bool, gates: &mut Vec<String>) -> SimdBench {
    let active = kernels::active();
    println!(
        "\n== SIMD microkernels vs scalar reference (isa {}) ==",
        active.name()
    );
    let fmt = presets::bfp_w(6);
    let mut rng = Pcg32::new(13);
    let budget = if quick { 30.0 } else { 400.0 };
    let mut gate = |label: &str, ratio: f64| {
        if active != Backend::Scalar && ratio < 1.0 {
            println!("  WARNING: {} SIMD kernel slower than the scalar reference", label);
            gates.push(format!(
                "simd: {label} {} {ratio:.2}x < 1.00x of scalar",
                active.name()
            ));
        }
    };
    // decode shape: [1, k] activations against a packed [n, k] weight
    let (dk, dn) = (1024usize, 1024usize);
    let a1 = Tensor::randn(&[1, dk], 1.0, &mut rng);
    let w1 = encode(&Tensor::randn(&[dn, dk], 0.3, &mut rng), fmt);
    let macs = (dk * dn) as f64;
    let r_simd = kernels::with_isa(active, || {
        Bench::new(&format!("simd_gemm/decode_{}_1x{dk}x{dn}", active.name()))
            .items(macs)
            .budget_ms(budget)
            .run(|| {
                black_box(qmatmul_packed(black_box(&a1), black_box(&w1), fmt));
            })
    });
    println!("{}", r_simd.line());
    let r_scalar = kernels::with_isa(Backend::Scalar, || {
        Bench::new(&format!("simd_gemm/decode_scalar_1x{dk}x{dn}"))
            .items(macs)
            .budget_ms(budget)
            .run(|| {
                black_box(qmatmul_packed(black_box(&a1), black_box(&w1), fmt));
            })
    });
    println!("{}", r_scalar.line());
    let decode_ratio = r_scalar.min_ns / r_simd.min_ns.max(1e-9);
    println!("  decode GEMM {} vs scalar: {decode_ratio:.2}x", active.name());
    gate("decode GEMM", decode_ratio);
    let (simd_decode, scalar_decode) = (
        r_simd.throughput().unwrap_or(0.0),
        r_scalar.throughput().unwrap_or(0.0),
    );
    // prefill shape: [64, k] panel GEMM against the packed weight
    let (pm, pk, pn) = (64usize, 512usize, 512usize);
    let ap = Tensor::randn(&[pm, pk], 1.0, &mut rng);
    let wp = encode(&Tensor::randn(&[pn, pk], 0.3, &mut rng), fmt);
    let pmacs = (pm * pk * pn) as f64;
    let r_simd = kernels::with_isa(active, || {
        Bench::new(&format!("simd_gemm/prefill_{}_{pm}x{pk}x{pn}", active.name()))
            .items(pmacs)
            .budget_ms(budget)
            .run(|| {
                black_box(matmul_packed_bt(black_box(&ap), black_box(&wp)));
            })
    });
    println!("{}", r_simd.line());
    let r_scalar = kernels::with_isa(Backend::Scalar, || {
        Bench::new(&format!("simd_gemm/prefill_scalar_{pm}x{pk}x{pn}"))
            .items(pmacs)
            .budget_ms(budget)
            .run(|| {
                black_box(matmul_packed_bt(black_box(&ap), black_box(&wp)));
            })
    });
    println!("{}", r_scalar.line());
    let prefill_ratio = r_scalar.min_ns / r_simd.min_ns.max(1e-9);
    println!(
        "  prefill panel GEMM {} vs scalar: {prefill_ratio:.2}x",
        active.name()
    );
    gate("prefill panel GEMM", prefill_ratio);
    let (simd_prefill, scalar_prefill) = (
        r_simd.throughput().unwrap_or(0.0),
        r_scalar.throughput().unwrap_or(0.0),
    );
    // raw block decode: dequantise every packed weight row (the expand
    // kernels with no GEMM arithmetic on top)
    let mut row = vec![0f32; pk];
    let elems = (pn * pk) as f64;
    let r_simd = kernels::with_isa(active, || {
        Bench::new(&format!("simd_decode/block_{}_{pn}x{pk}", active.name()))
            .items(elems)
            .budget_ms(budget)
            .run(|| {
                for j in 0..pn {
                    wp.decode_row_into(j, &mut row);
                }
                black_box(&row);
            })
    });
    println!("{}", r_simd.line());
    let r_scalar = kernels::with_isa(Backend::Scalar, || {
        Bench::new(&format!("simd_decode/block_scalar_{pn}x{pk}"))
            .items(elems)
            .budget_ms(budget)
            .run(|| {
                for j in 0..pn {
                    wp.decode_row_into(j, &mut row);
                }
                black_box(&row);
            })
    });
    println!("{}", r_scalar.line());
    let block_ratio = r_scalar.min_ns / r_simd.min_ns.max(1e-9);
    println!("  block decode {} vs scalar: {block_ratio:.2}x", active.name());
    gate("block decode", block_ratio);
    SimdBench {
        isa: active.name().to_string(),
        simd_decode_gemm_mac_per_s: simd_decode,
        scalar_decode_gemm_mac_per_s: scalar_decode,
        simd_vs_scalar_decode: decode_ratio,
        simd_prefill_gemm_mac_per_s: simd_prefill,
        scalar_prefill_gemm_mac_per_s: scalar_prefill,
        simd_vs_scalar_prefill: prefill_ratio,
        simd_block_decode_elem_per_s: r_simd.throughput().unwrap_or(0.0),
        scalar_block_decode_elem_per_s: r_scalar.throughput().unwrap_or(0.0),
        simd_vs_scalar_block_decode: block_ratio,
    }
}

/// Continuous-batching decode engine: single-stream vs batch-8 tokens/sec
/// under BFP6 (the fused packed GEMM decodes each weight once per layer per
/// step, so batch-8 amortises the dequant 8×). Writes BENCH_decode.json.
fn bench_decode_engine(quick: bool, gates: &mut Vec<String>, simd: &SimdBench) {
    println!("\n== continuous-batching decode engine (tiny, BFP6, greedy) ==");
    let fmt = presets::bfp_w(6);
    let cfg = ModelConfig::preset("tiny");
    let params = Params::init(&cfg, 3);
    let model = std::sync::Arc::new(Model::new(params, QuantPlan::uniform(fmt)));
    let wm = model.weight_memory();
    let new_toks = if quick { 8 } else { 16 };
    let reps = if quick { 2 } else { 3 };
    let mk_reqs = |n: usize| -> Vec<Request> {
        (0..n)
            .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], new_toks))
            .collect()
    };
    // best-of-N closed-loop runs; tokens/sec from the engine's own metrics
    let run_tps = |max_batch: usize, n_req: usize| -> (f64, Metrics) {
        let server_cfg = ServerConfig {
            max_batch,
            ..ServerConfig::default()
        };
        let mut best: Option<(f64, Metrics)> = None;
        for _ in 0..reps {
            let (_, m) = run_batched(&model, mk_reqs(n_req), &server_cfg);
            let tps = m.throughput_tps();
            let better = match &best {
                None => true,
                Some((b, _)) => tps > *b,
            };
            if better {
                best = Some((tps, m));
            }
        }
        best.unwrap()
    };
    let (tps1, m1) = run_tps(1, 1);
    let (tps8, m8) = run_tps(8, 8);
    let speedup = tps8 / tps1.max(1e-12);
    println!(
        "  single-stream: {tps1:.1} tok/s (occ {:.2}) | batch-8: {tps8:.1} tok/s (occ {:.2})",
        m1.batch_occupancy(),
        m8.batch_occupancy(),
    );
    println!(
        "  batch-8 speedup: {speedup:.2}x (decode amortisation {:.2}x); \
         resident weights {} B vs {} B dense-f32",
        m8.decode_amortisation(),
        wm.resident_bytes,
        wm.dense_f32_bytes,
    );
    if speedup < 2.0 {
        println!("  WARNING: batch-8 speedup below the 2x acceptance bar");
        gates.push(format!(
            "decode: batch-8 speedup {speedup:.2}x < 2.0x over single-stream"
        ));
    }
    // engine-path: the same 8 requests submitted live through an
    // EngineHandle (submission thread + streaming events + metrics
    // snapshots on top of the identical scheduler core). Must stay within
    // 10% of the run_batched wrapper — the API redesign is not allowed to
    // tax the hot path.
    let mut engine_tps = 0.0f64;
    for _ in 0..reps {
        let engine = Engine::start(
            model.clone(),
            ServerConfig {
                max_batch: 8,
                ..ServerConfig::default()
            },
        );
        let handles: Vec<_> = mk_reqs(8)
            .into_iter()
            .map(|r| engine.submit(r).expect("engine open"))
            .collect();
        for h in handles {
            h.wait();
        }
        let m = engine.shutdown();
        engine_tps = engine_tps.max(m.throughput_tps());
    }
    let engine_ratio = engine_tps / tps8.max(1e-12);
    println!(
        "  engine-path: {engine_tps:.1} tok/s via EngineHandle \
         ({engine_ratio:.2}x of run_batched)"
    );
    if engine_ratio < 0.9 {
        println!("  WARNING: engine-path throughput >10% below run_batched");
        gates.push(format!(
            "engine: EngineHandle path {engine_ratio:.2}x < 0.90x of run_batched"
        ));
    }
    // fused expand-into-GEMM vs the staged decode-then-dot path at the
    // m == 1 decode shape: same packed weights, same reduce tree — the
    // only difference is whether every block round-trips through an f32
    // staging slab before the multiply
    let (dk, dn) = (1024usize, 1024usize);
    let mut drng = Pcg32::new(11);
    let x: Vec<f32> = (0..dk).map(|_| drng.normal_with(0.0, 1.0)).collect();
    let qw = encode(&Tensor::randn(&[dn, dk], 0.3, &mut drng), fmt);
    assert!(qw.fused_dot_supported(), "BFP n=16 rows must take the fused path");
    let dbudget = if quick { 30.0 } else { 300.0 };
    let dmacs = (dk * dn) as f64;
    let r_fused = Bench::new(&format!("decode_dot/fused_1x{dk}x{dn}"))
        .items(dmacs)
        .budget_ms(dbudget)
        .run(|| {
            let mut acc = 0.0f32;
            for j in 0..dn {
                acc += qw.dot_row(j, black_box(&x));
            }
            black_box(acc);
        });
    println!("{}", r_fused.line());
    let mut slab = vec![0f32; dk];
    let r_staged = Bench::new(&format!("decode_dot/staged_1x{dk}x{dn}"))
        .items(dmacs)
        .budget_ms(dbudget)
        .run(|| {
            let mut acc = 0.0f32;
            for j in 0..dn {
                qw.decode_row_into(j, &mut slab);
                acc += kernels::dot(&slab, black_box(&x));
            }
            black_box(acc);
        });
    println!("{}", r_staged.line());
    let fused_vs_staged = r_staged.min_ns / r_fused.min_ns.max(1e-9);
    println!("  fused m=1 dot vs staged decode-then-dot: {fused_vs_staged:.2}x");
    let j = Json::obj(vec![
        ("bench", Json::Str("decode_engine".into())),
        ("model", Json::Str(cfg.name.clone())),
        ("format", Json::Str(fmt.name())),
        ("new_tokens_per_request", Json::Num(new_toks as f64)),
        ("single_stream_tps", Json::Num(tps1)),
        ("batch8_tps", Json::Num(tps8)),
        ("batch8_speedup", Json::Num(speedup)),
        // occupancy IS the decode-amortisation factor (one fused dequant
        // pass per engine step serves `occupancy` token-steps)
        ("batch8_occupancy", Json::Num(m8.batch_occupancy())),
        // live Engine API vs the run_batched wrapper (same scheduler core)
        ("engine_api_tps", Json::Num(engine_tps)),
        ("engine_vs_run_batched", Json::Num(engine_ratio)),
        ("resident_weight_bytes", Json::Num(wm.resident_bytes as f64)),
        ("dense_f32_weight_bytes", Json::Num(wm.dense_f32_bytes as f64)),
        // SIMD-vs-scalar microkernel section (see bench_simd): the m == 1
        // packed decode GEMM under the active ISA vs the forced scalar
        // reference, best-iteration times
        ("isa", Json::Str(simd.isa.clone())),
        ("simd_decode_gemm_mac_per_s", Json::Num(simd.simd_decode_gemm_mac_per_s)),
        ("scalar_decode_gemm_mac_per_s", Json::Num(simd.scalar_decode_gemm_mac_per_s)),
        ("simd_vs_scalar_decode", Json::Num(simd.simd_vs_scalar_decode)),
        // fused expand-into-GEMM vs the staged decode-then-dot reference
        // at the m == 1 decode shape (see above)
        ("fused_dot_mac_per_s", Json::Num(r_fused.throughput().unwrap_or(0.0))),
        ("staged_dot_mac_per_s", Json::Num(r_staged.throughput().unwrap_or(0.0))),
        ("fused_vs_staged_decode_dot", Json::Num(fused_vs_staged)),
        ("quick", Json::Bool(quick)),
    ]);
    let path = "BENCH_decode.json";
    std::fs::write(path, j.to_string() + "\n").expect("write BENCH_decode.json");
    println!("  wrote {path}");
}

/// Chunked prefill: prompt tokens/sec at prefill_chunk 8 vs 1 (token at a
/// time) through the batched engine under BFP6. Chunk 8 shares each fused
/// weight-dequant pass across 8 prompt rows per slot — and attention over
/// the chunk runs slot-parallel on the worker pool — so prompt absorption
/// should run well over 2× faster. Writes BENCH_prefill.json.
fn bench_prefill_engine(quick: bool, gates: &mut Vec<String>) {
    println!("\n== chunked prefill through the batched engine (tiny, BFP6) ==");
    let fmt = presets::bfp_w(6);
    let cfg = ModelConfig::preset("tiny");
    let params = Params::init(&cfg, 3);
    let model = Model::new(params, QuantPlan::uniform(fmt));
    let prompt_len = if quick { 24 } else { 48 };
    let n_req = 4usize;
    let reps = if quick { 2 } else { 3 };
    let mk_reqs = || -> Vec<Request> {
        (0..n_req)
            .map(|i| {
                // max_new_tokens 1: a prefill-dominated workload
                let prompt = (0..prompt_len).map(|t| (3 + i + t * 7) % 512).collect();
                Request::greedy(i as u64, prompt, 1)
            })
            .collect()
    };
    // prefill tokens/sec = prompt rows absorbed per wall-clock second,
    // best of N closed-loop runs
    let run_prefill_tps = |chunk: usize| -> (f64, Metrics) {
        let server_cfg = ServerConfig {
            max_batch: n_req,
            prefill_chunk: chunk,
            ..ServerConfig::default()
        };
        let mut best: Option<(f64, Metrics)> = None;
        for _ in 0..reps {
            let (_, m) = run_batched(&model, mk_reqs(), &server_cfg);
            let secs = m.wall.as_secs_f64().max(1e-12);
            let tps = m.prefill_rows as f64 / secs;
            let better = match &best {
                None => true,
                Some((b, _)) => tps > *b,
            };
            if better {
                best = Some((tps, m));
            }
        }
        best.unwrap()
    };
    let (tps1, m1) = run_prefill_tps(1);
    let (tps8, m8) = run_prefill_tps(8);
    let speedup = tps8 / tps1.max(1e-12);
    println!(
        "  chunk 1: {tps1:.1} prompt tok/s (amort {:.2}x) | chunk 8: {tps8:.1} prompt tok/s \
         (amort {:.2}x)",
        m1.prefill_amortisation(),
        m8.prefill_amortisation(),
    );
    println!(
        "  chunk-8 speedup: {speedup:.2}x over token-at-a-time \
         ({prompt_len} prompt rows/request, {n_req} requests)"
    );
    if speedup < 2.0 {
        println!("  WARNING: chunked-prefill speedup below the 2x acceptance bar");
        gates.push(format!(
            "prefill: chunk-8 speedup {speedup:.2}x < 2.0x over token-at-a-time"
        ));
    }
    let j = Json::obj(vec![
        ("bench", Json::Str("prefill_engine".into())),
        ("model", Json::Str(cfg.name.clone())),
        ("format", Json::Str(fmt.name())),
        ("prompt_tokens_per_request", Json::Num(prompt_len as f64)),
        ("requests", Json::Num(n_req as f64)),
        ("chunk1_prefill_tps", Json::Num(tps1)),
        ("chunk8_prefill_tps", Json::Num(tps8)),
        ("chunk8_speedup", Json::Num(speedup)),
        // prompt rows sharing each fused weight-dequant pass at chunk 8
        ("chunk8_prefill_amortisation", Json::Num(m8.prefill_amortisation())),
        ("chunk1_prefill_amortisation", Json::Num(m1.prefill_amortisation())),
        ("quick", Json::Bool(quick)),
    ]);
    let path = "BENCH_prefill.json";
    std::fs::write(path, j.to_string() + "\n").expect("write BENCH_prefill.json");
    println!("  wrote {path}");
}

/// Full-context forward through the unified dispatch: the fused packed
/// prefill GEMM (weights decoded panel-wise inside the kernel) vs the
/// pre-refactor transient dense decode (decode the whole packed weight,
/// then the dense broadcast GEMM), at the m ≥ 4 shape the exp/* tables
/// pay per layer — plus the end-to-end packed forward tok/s. Writes
/// BENCH_forward.json; under `--check` the fused kernel must be at least
/// 1.0× of the dense-decode reference (the refactor must not tax the
/// experiment path).
fn bench_forward_unified(quick: bool, gates: &mut Vec<String>, simd: &SimdBench) {
    println!("\n== full-context forward: fused packed GEMM vs transient dense decode ==");
    let fmt = presets::bfp_w(6);
    let mut rng = Pcg32::new(11);
    let budget = if quick { 30.0 } else { 400.0 };
    // kernel level, prefill shape: [64, k] activations against [n, k]
    let (m, k, n) = (64usize, 512usize, 512usize);
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let wt = Tensor::randn(&[n, k], 0.3, &mut rng);
    let packed = encode(&wt, fmt);
    let macs = (m * k * n) as f64;
    let r_fused = Bench::new(&format!("prefill_gemm/packed_fused_{m}x{k}x{n}"))
        .items(macs)
        .budget_ms(budget)
        .run(|| {
            black_box(matmul_packed_bt(black_box(&a), black_box(&packed)));
        });
    println!("{}", r_fused.line());
    // the pre-refactor path, reconstructed so the gate outlives the code
    let r_dense = Bench::new(&format!("prefill_gemm/dense_decode_{m}x{k}x{n}"))
        .items(macs)
        .budget_ms(budget)
        .run(|| {
            let dw = decode(black_box(&packed));
            black_box(matmul_bt(black_box(&a), &dw));
        });
    println!("{}", r_dense.line());
    // best-iteration times: the most noise-robust basis for a
    // faster-or-equal claim on shared CI runners (the 1.0× bar has no
    // slack by design — the fused kernel must never tax the experiment
    // path — so the comparison must not eat scheduling jitter)
    let ratio = r_dense.min_ns / r_fused.min_ns.max(1e-9);
    println!("  fused vs transient-dense-decode: {ratio:.2}x");
    if ratio < 1.0 {
        println!("  WARNING: fused prefill GEMM slower than transient dense decode");
        gates.push(format!(
            "forward: fused packed GEMM {ratio:.2}x < 1.00x of transient dense decode"
        ));
    }
    // end-to-end: the experiment unit of work on the unified path
    let cfg = ModelConfig::preset("tiny");
    let model = Model::new(Params::init(&cfg, 3), QuantPlan::uniform(fmt));
    let toks: Vec<usize> = (0..64).map(|i| (i * 37) % cfg.vocab_size).collect();
    let r_fwd = Bench::new("forward/tiny/packed_fused")
        .items(64.0)
        .budget_ms(if quick { 60.0 } else { 1200.0 })
        .iters(3, 200)
        .run(|| {
            black_box(model.forward(black_box(&toks), None));
        });
    println!("{}", r_fwd.line());
    let j = Json::obj(vec![
        ("bench", Json::Str("forward_unified".into())),
        ("format", Json::Str(fmt.name())),
        ("gemm_m", Json::Num(m as f64)),
        ("gemm_k", Json::Num(k as f64)),
        ("gemm_n", Json::Num(n as f64)),
        ("fused_gemm_mac_per_s", Json::Num(r_fused.throughput().unwrap_or(0.0))),
        ("dense_decode_gemm_mac_per_s", Json::Num(r_dense.throughput().unwrap_or(0.0))),
        ("fused_vs_dense_decode", Json::Num(ratio)),
        ("model", Json::Str(cfg.name.clone())),
        ("seq", Json::Num(64.0)),
        ("forward_tps_packed", Json::Num(r_fwd.throughput().unwrap_or(0.0))),
        // SIMD-vs-scalar microkernel section (see bench_simd): the m ≥ 4
        // prefill panel GEMM and raw block decode under the active ISA vs
        // the forced scalar reference, best-iteration times
        ("isa", Json::Str(simd.isa.clone())),
        ("simd_prefill_gemm_mac_per_s", Json::Num(simd.simd_prefill_gemm_mac_per_s)),
        ("scalar_prefill_gemm_mac_per_s", Json::Num(simd.scalar_prefill_gemm_mac_per_s)),
        ("simd_vs_scalar_prefill", Json::Num(simd.simd_vs_scalar_prefill)),
        ("simd_block_decode_elem_per_s", Json::Num(simd.simd_block_decode_elem_per_s)),
        ("scalar_block_decode_elem_per_s", Json::Num(simd.scalar_block_decode_elem_per_s)),
        ("simd_vs_scalar_block_decode", Json::Num(simd.simd_vs_scalar_block_decode)),
        ("quick", Json::Bool(quick)),
    ]);
    let path = "BENCH_forward.json";
    std::fs::write(path, j.to_string() + "\n").expect("write BENCH_forward.json");
    println!("  wrote {path}");
}

/// Paged KV cache: (1) decode throughput of 16-row f32 pages vs a
/// dense-equivalent configuration (one page spanning the whole context
/// with the prefix cache off — the store's single-page zero-copy fast
/// path, i.e. the contiguous pre-paging layout); (2) resident KV bytes
/// with BFP6 pages vs dense f32 rows (sealed pages bit-pack, so capacity
/// grows ~5×); (3) prompt absorption cold vs through the prefix cache
/// (warm admissions attach the sealed pages and only recompute the final
/// prompt row). Writes BENCH_kv.json; under `--check` the paged decode
/// must hold ≥ 0.90× of dense-equivalent, quantised-KV capacity ≥ 2×,
/// and the prefix-cached prefill ≥ 2× over cold.
fn bench_kv(quick: bool, gates: &mut Vec<String>) {
    println!("\n== paged KV cache (tiny, BFP6 weights) ==");
    let wfmt = presets::bfp_w(6);
    let kvfmt = presets::bfp_w(6);
    let cfg = ModelConfig::preset("tiny");
    let model = Model::new(Params::init(&cfg, 3), QuantPlan::uniform(wfmt));
    let reps = if quick { 2 } else { 3 };
    let new_toks = if quick { 8 } else { 16 };
    let mk_reqs = || -> Vec<Request> {
        (0..8)
            .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], new_toks))
            .collect()
    };
    let run_tps = |kv: KvConfig| -> f64 {
        let server_cfg = ServerConfig {
            max_batch: 8,
            kv,
            ..ServerConfig::default()
        };
        let mut best = 0.0f64;
        for _ in 0..reps {
            let (_, m) = run_batched(&model, mk_reqs(), &server_cfg);
            best = best.max(m.throughput_tps());
        }
        best
    };
    let dense_tps = run_tps(KvConfig {
        page_size: cfg.max_seq,
        prefix_cache_pages: 0,
        ..KvConfig::default()
    });
    let paged_tps = run_tps(KvConfig::default());
    let paged_vs_dense = paged_tps / dense_tps.max(1e-12);
    println!(
        "  decode: paged 16-row pages {paged_tps:.1} tok/s vs dense-equivalent \
         {dense_tps:.1} tok/s ({paged_vs_dense:.2}x)"
    );
    if paged_vs_dense < 0.90 {
        println!("  WARNING: paged decode below 0.90x of the dense-equivalent layout");
        gates.push(format!(
            "kv: paged decode {paged_vs_dense:.2}x < 0.90x of dense-equivalent"
        ));
    }
    // capacity: 64 decoded rows, BFP6 pages vs f32 pages (both measured
    // through the store's own accounting)
    let rows = 64usize;
    let mut qsess = BatchedDecodeSession::new(&model, &SessionConfig::new(1).kv_format(kvfmt));
    let mut fsess = BatchedDecodeSession::new(&model, &SessionConfig::new(1));
    for t in 0..rows {
        let tok = (3 + t * 7) % cfg.vocab_size;
        qsess.step(&[(0, tok)]);
        fsess.step(&[(0, tok)]);
    }
    let q_bytes = qsess.kv_bytes();
    let f_bytes = fsess.kv_bytes();
    let capacity = f_bytes as f64 / q_bytes.max(1) as f64;
    println!(
        "  capacity: {rows} rows in {} KV = {q_bytes} B vs f32 {f_bytes} B \
         ({capacity:.2}x more context per byte)",
        kvfmt.name()
    );
    if capacity < 2.0 {
        println!("  WARNING: quantised-KV capacity multiplier below the 2x bar");
        gates.push(format!(
            "kv: {} capacity {capacity:.2}x < 2.0x over dense f32",
            kvfmt.name()
        ));
    }
    // prefix cache: absorb a long prompt cold, then admit the same prompt
    // warm (attach sealed pages, recompute only the uncovered tail)
    let prompt_len = if quick { 64 } else { 96 };
    let prompt: Vec<usize> = (0..prompt_len)
        .map(|t| (3 + t * 7) % cfg.vocab_size)
        .collect();
    fn feed(sess: &mut BatchedDecodeSession<'_>, slot: usize, prompt: &[usize], from: usize) {
        let mut fed = from;
        while fed < prompt.len() {
            let end = (fed + 8).min(prompt.len());
            sess.step_chunked(&[(slot, &prompt[fed..end])], None);
            fed = end;
        }
    }
    let mut sess = BatchedDecodeSession::new(&model, &SessionConfig::new(2));
    let mut cold_ms = f64::INFINITY;
    for _ in 0..reps {
        // cold never calls attach_prefix, so the warm cache can't help it
        sess.reset_slot(0);
        let t0 = std::time::Instant::now();
        feed(&mut sess, 0, &prompt, 0);
        cold_ms = cold_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mut warm_ms = f64::INFINITY;
    for _ in 0..reps {
        sess.reset_slot(1);
        let t0 = std::time::Instant::now();
        let attached = sess.attach_prefix(1, &prompt);
        feed(&mut sess, 1, &prompt, attached);
        warm_ms = warm_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    let prefix_speedup = cold_ms / warm_ms.max(1e-9);
    let hit_rows = sess.kv_stats().prefix_hit_rows;
    println!(
        "  prefill ({prompt_len} rows): cold {cold_ms:.2} ms vs prefix-cached \
         {warm_ms:.2} ms ({prefix_speedup:.2}x, {hit_rows} rows reused)"
    );
    if prefix_speedup < 2.0 {
        println!("  WARNING: prefix-cached prefill below the 2x acceptance bar");
        gates.push(format!(
            "kv: prefix-cached prefill {prefix_speedup:.2}x < 2.0x over cold"
        ));
    }
    let j = Json::obj(vec![
        ("bench", Json::Str("kv_cache".into())),
        ("model", Json::Str(cfg.name.clone())),
        ("format", Json::Str(kvfmt.name())),
        ("paged_tps", Json::Num(paged_tps)),
        ("dense_tps", Json::Num(dense_tps)),
        ("paged_vs_dense", Json::Num(paged_vs_dense)),
        ("gate_paged_vs_dense_min", Json::Num(0.90)),
        ("kv_bytes_quantised", Json::Num(q_bytes as f64)),
        ("kv_bytes_dense_f32", Json::Num(f_bytes as f64)),
        ("capacity_multiplier", Json::Num(capacity)),
        ("gate_capacity_multiplier_min", Json::Num(2.0)),
        ("prefill_cold_ms", Json::Num(cold_ms)),
        ("prefill_warm_ms", Json::Num(warm_ms)),
        ("prefix_speedup", Json::Num(prefix_speedup)),
        ("gate_prefix_speedup_min", Json::Num(2.0)),
        ("prefix_hit_rows", Json::Num(hit_rows as f64)),
        ("quick", Json::Bool(quick)),
    ]);
    let path = "BENCH_kv.json";
    std::fs::write(path, j.to_string() + "\n").expect("write BENCH_kv.json");
    println!("  wrote {path}");
}

/// Mixed-precision plan pipeline: (1) a CI-sized TPE search emits a plan
/// artifact; reloading it must reproduce the in-memory plan's forward
/// bit-for-bit and mix ≥ 3 distinct weight bit-widths; (2) on a trained
/// nano model, uniform BFP4 plus a 0.5% dense-and-sparse f32 outlier
/// overlay must beat plain BFP4 perplexity while the packed weights stay
/// ≥ 4× denser than f32 (overlay side tables counted). Writes
/// BENCH_plan.json; under `--check` all three bars are hard failures.
fn bench_plan(quick: bool, gates: &mut Vec<String>) {
    use bbq::coordinator::experiment::get_or_train;
    use bbq::data::corpus::test_stream;
    use bbq::data::lm_eval::perplexity;
    use bbq::data::tasks::{evaluate, generate, Task};
    use bbq::data::vocab::Vocab;
    use bbq::model::plan_file;
    use bbq::search::objective::Objective;
    use bbq::search::runner::{run_search, SearchConfig};
    use bbq::search::space::SearchSpace;

    println!("\n== mixed-precision plan pipeline (nano) ==");
    let cfg = ModelConfig::preset("nano");
    let params = Params::init(&cfg, 3);
    let vocab = Vocab::build();
    let task = Task::Lambada;
    let exs = generate(task, &vocab, 555, if quick { 8 } else { 16 });
    let fp32_acc = evaluate(&Model::new(params.clone(), QuantPlan::fp32()), task, &exs, 2).accuracy;
    let space = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
    let sc = SearchConfig {
        trials: if quick { 4 } else { 10 },
        seq: 32,
        threads: 2,
        seed: 7,
        objective: Objective::software(0.02),
        ..Default::default()
    };
    let res = run_search(&params, space, task, &exs, fp32_acc, &sc);
    let frac = 0.005f32;
    let plan = res
        .best_plan()
        .expect("search produced a best trial")
        .with_outliers(frac);
    let mut widths: Vec<u32> = plan.per_site.values().map(|q| q.weight.word_bits()).collect();
    widths.sort_unstable();
    widths.dedup();
    println!(
        "  search: {} trials, {} sites, weight bit-widths {widths:?}",
        res.history.len(),
        plan.per_site.len()
    );
    if widths.len() < 3 {
        println!("  WARNING: searched plan mixes fewer than 3 distinct bit-widths");
        gates.push(format!(
            "plan: {} distinct weight bit-widths < 3 ({widths:?})",
            widths.len()
        ));
    }
    // artifact round-trip must not perturb serving: save, reload against
    // the model config, compare forwards bit-for-bit
    let path = std::env::temp_dir().join("bbq_bench_plan.bbqp");
    plan_file::save(&plan, &cfg, &path, &["emitted by cargo bench".to_string()])
        .expect("save plan artifact");
    let from_file = Model::from_plan_file(params.clone(), &path).expect("reload plan artifact");
    let n_sites = plan.per_site.len();
    let in_memory = Model::new(params, plan);
    let toks = [3usize, 100, 7, 250, 9];
    let bit_identical = from_file.forward(&toks, None).data == in_memory.forward(&toks, None).data;
    println!("  artifact: reloaded plan forward bit-identical = {bit_identical}");
    if !bit_identical {
        gates.push("plan: file-loaded plan forward diverged from in-memory plan".to_string());
    }
    std::fs::remove_file(&path).ok();

    // overlay quality on a *trained* model: exact top-|w| side table +
    // finer residual blocks must beat plain BFP4 perplexity
    let trained = get_or_train("nano", 600, true);
    let seq = 48;
    let chunks = if quick { 4 } else { 8 };
    let stream = test_stream(&vocab, seq * chunks + seq);
    let plain = Model::new(trained.clone(), QuantPlan::uniform(presets::bfp_w(4)));
    let overlay = Model::new(trained, QuantPlan::uniform(presets::bfp_w(4)).with_outliers(frac));
    let ppl_plain = perplexity(&plain, &stream, seq, chunks).perplexity;
    let ppl_overlay = perplexity(&overlay, &stream, seq, chunks).perplexity;
    println!(
        "  ppl (trained nano, {} tokens): bfp4 {ppl_plain:.3} vs bfp4 + {frac} overlay \
         {ppl_overlay:.3}",
        seq * chunks
    );
    if ppl_overlay >= ppl_plain || ppl_overlay.is_nan() {
        println!("  WARNING: outlier overlay did not improve BFP4 perplexity");
        gates.push(format!(
            "plan: bfp4+overlay ppl {ppl_overlay:.3} not below plain bfp4 {ppl_plain:.3}"
        ));
    }
    let wm = overlay.weight_memory();
    let density = wm.ratio();
    let (_, outlier_bytes) = overlay.weight_memory_by_format();
    println!(
        "  density: {density:.2}x vs f32 ({} of {} resident bytes are outlier side tables)",
        outlier_bytes, wm.resident_bytes
    );
    if density < 4.0 {
        println!("  WARNING: overlayed BFP4 density below the 4x acceptance bar");
        gates.push(format!("plan: bfp4+overlay density {density:.2}x < 4.0x vs f32"));
    }
    let j = Json::obj(vec![
        ("bench", Json::Str("plan".into())),
        ("model", Json::Str(cfg.name.clone())),
        ("trials", Json::Num(res.history.len() as f64)),
        ("sites", Json::Num(n_sites as f64)),
        ("distinct_weight_bitwidths", Json::Num(widths.len() as f64)),
        ("gate_distinct_bitwidths_min", Json::Num(3.0)),
        ("plan_forward_bit_identical", Json::Bool(bit_identical)),
        ("outlier_fraction", Json::Num(frac as f64)),
        ("ppl_bfp4", Json::Num(ppl_plain)),
        ("ppl_bfp4_overlay", Json::Num(ppl_overlay)),
        ("density_vs_f32", Json::Num(density)),
        ("gate_density_min", Json::Num(4.0)),
        ("outlier_bytes", Json::Num(outlier_bytes as f64)),
        ("resident_weight_bytes", Json::Num(wm.resident_bytes as f64)),
        ("dense_f32_weight_bytes", Json::Num(wm.dense_f32_bytes as f64)),
        ("quick", Json::Bool(quick)),
    ]);
    let path = "BENCH_plan.json";
    std::fs::write(path, j.to_string() + "\n").expect("write BENCH_plan.json");
    println!("  wrote {path}");
}

/// Self-drafting speculative decoding: the same trained nano weights
/// serve twice — a BFP4 draft proposes `spec_k` tokens per round from its
/// own paged KV, the BFP6 target verifies all proposals plus one bonus
/// row in a single chunked step. Trained weights matter here: the
/// draft/target agreement rate (and so the whole win) is a property of a
/// real model, not of noise. Writes BENCH_spec.json. Under `--check` two
/// bars are hard failures: the speculative greedy stream must be
/// bit-identical to target-only decode, and the target must accept at
/// least 1.0 draft tokens per verify step on average (below that the
/// chunked verify is pure overhead).
fn bench_spec(quick: bool, gates: &mut Vec<String>) {
    println!("\n== self-drafting speculative decode (nano, BFP6 target / BFP4 draft) ==");
    let target_fmt = presets::bfp_w(6);
    let draft_fmt = presets::bfp_w(4);
    let params = get_or_train("nano", 600, true);
    let target = Model::new(params.clone(), QuantPlan::uniform(target_fmt));
    let draft = Model::new(params, QuantPlan::uniform(draft_fmt));
    let new_toks = if quick { 12 } else { 24 };
    let reps = if quick { 2 } else { 3 };
    let n_req = 4usize;
    let mk_reqs = || -> Vec<Request> {
        (0..n_req)
            .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], new_toks))
            .collect()
    };
    let server_cfg = ServerConfig {
        max_batch: n_req,
        ..ServerConfig::default()
    };
    // plain target-only decode: the reference stream and the baseline
    let mut plain_tps = 0.0f64;
    let mut plain_resps = Vec::new();
    for _ in 0..reps {
        let (resps, m) = run_batched(&target, mk_reqs(), &server_cfg);
        plain_tps = plain_tps.max(m.throughput_tps());
        plain_resps = resps;
    }
    // speculative: draft proposes, target verifies in one chunked step
    let mut spec_tps = 0.0f64;
    let mut spec_resps = Vec::new();
    let mut spec_metrics: Option<Metrics> = None;
    for _ in 0..reps {
        let (resps, m) = run_batched_with_draft(&target, &draft, mk_reqs(), &server_cfg);
        if spec_metrics.is_none() || m.throughput_tps() > spec_tps {
            spec_tps = m.throughput_tps();
            spec_metrics = Some(m);
        }
        spec_resps = resps;
    }
    let m = spec_metrics.expect("at least one speculative rep ran");
    let identical = plain_resps.len() == spec_resps.len()
        && plain_resps
            .iter()
            .zip(&spec_resps)
            .all(|(a, b)| a.tokens == b.tokens && a.finish == b.finish);
    let accepted_per_step = if m.spec_rounds > 0 {
        m.spec_accepted as f64 / m.spec_rounds as f64
    } else {
        0.0
    };
    let ratio = spec_tps / plain_tps.max(1e-12);
    println!("  plain {plain_tps:.1} tok/s | speculative {spec_tps:.1} tok/s ({ratio:.2}x)");
    println!(
        "  rounds {} (fallback {}) | proposed {} accepted {} rejected {} | \
         acceptance {:.2} | accepted/step {accepted_per_step:.2} | tokens/target-step {:.2}",
        m.spec_rounds,
        m.spec_fallback_steps,
        m.spec_proposed,
        m.spec_accepted,
        m.spec_rejected,
        m.spec_acceptance_rate(),
        m.spec_tokens_per_target_step(),
    );
    if !identical {
        println!("  WARNING: speculative stream diverged from target-only greedy decode");
        gates.push("spec: speculative greedy stream not bit-identical to target-only decode".into());
    }
    if accepted_per_step < 1.0 {
        println!("  WARNING: accepted tokens per target step below the 1.0 acceptance bar");
        gates.push(format!(
            "spec: accepted tokens per target step {accepted_per_step:.2} < 1.0"
        ));
    }
    let j = Json::obj(vec![
        ("bench", Json::Str("spec".into())),
        ("model", Json::Str("nano".into())),
        ("target_format", Json::Str(target_fmt.name())),
        ("draft_format", Json::Str(draft_fmt.name())),
        ("spec_k", Json::Num(server_cfg.spec_k as f64)),
        ("new_tokens_per_request", Json::Num(new_toks as f64)),
        ("requests", Json::Num(n_req as f64)),
        ("plain_tps", Json::Num(plain_tps)),
        ("spec_tps", Json::Num(spec_tps)),
        ("spec_vs_plain", Json::Num(ratio)),
        ("spec_rounds", Json::Num(m.spec_rounds as f64)),
        ("spec_fallback_steps", Json::Num(m.spec_fallback_steps as f64)),
        ("spec_proposed", Json::Num(m.spec_proposed as f64)),
        ("spec_accepted", Json::Num(m.spec_accepted as f64)),
        ("spec_rejected", Json::Num(m.spec_rejected as f64)),
        ("acceptance_rate", Json::Num(m.spec_acceptance_rate())),
        ("accepted_per_target_step", Json::Num(accepted_per_step)),
        ("tokens_per_target_step", Json::Num(m.spec_tokens_per_target_step())),
        ("bit_identical", Json::Bool(identical)),
        (
            "draft_resident_weight_bytes",
            Json::Num(m.draft_weight_memory.resident_bytes as f64),
        ),
        ("quick", Json::Bool(quick)),
    ]);
    let path = "BENCH_spec.json";
    std::fs::write(path, j.to_string() + "\n").expect("write BENCH_spec.json");
    println!("  wrote {path}");
}
