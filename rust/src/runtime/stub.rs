//! Dependency-free stand-in for the PJRT runtime (compiled when the `xla`
//! feature is off — the default in this offline environment).
//!
//! Mirrors the public surface of `client.rs`/`exec.rs` exactly: the
//! artifact manifest parses (so `bbq artifacts` and density accounting
//! work), but anything that would need a compiled executable returns
//! [`RuntimeError::Disabled`]. Callers that guard on artifact files being
//! present (the integration tests, `examples/e2e_train_quantize.rs`) skip
//! cleanly; callers that insist get an actionable error message.

use crate::model::params::Params;
use crate::tensor::Tensor;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum RuntimeError {
    MissingArtifact(String),
    Manifest(String),
    Io(std::io::Error),
    /// Built without the `xla` feature: no PJRT client is available.
    Disabled(String),
    Shape(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::MissingArtifact(a) => {
                write!(f, "artifact '{a}' not found in manifest")
            }
            RuntimeError::Manifest(m) => write!(f, "manifest error: {m}"),
            RuntimeError::Io(e) => write!(f, "io error: {e}"),
            RuntimeError::Disabled(what) => write!(
                f,
                "{what} requires the PJRT runtime — rebuild with `--features xla` \
                 (needs the local `xla` bindings)"
            ),
            RuntimeError::Shape(s) => write!(f, "shape mismatch: {s}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

fn disabled(what: &str) -> RuntimeError {
    RuntimeError::Disabled(what.to_string())
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub fmt: String,
    pub seq: usize,
    pub n_params: usize,
}

/// Artifact registry without a PJRT client behind it.
pub struct Runtime {
    pub artifacts_dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
}

impl Runtime {
    /// Open the artifacts directory (reads manifest.json; an absent
    /// directory yields an empty registry, matching the real client).
    pub fn open(artifacts_dir: &Path) -> Result<Runtime, RuntimeError> {
        let manifest_path = artifacts_dir.join("manifest.json");
        let mut manifest = HashMap::new();
        if manifest_path.exists() {
            let text = std::fs::read_to_string(&manifest_path)?;
            let j = Json::parse(&text).map_err(RuntimeError::Manifest)?;
            let arts = j
                .get("artifacts")
                .ok_or_else(|| RuntimeError::Manifest("no 'artifacts' key".into()))?;
            if let Json::Obj(m) = arts {
                for (name, meta) in m {
                    let file = meta
                        .get("file")
                        .and_then(|f| f.as_str())
                        .unwrap_or_default()
                        .to_string();
                    manifest.insert(
                        name.clone(),
                        ArtifactMeta {
                            name: name.clone(),
                            file: artifacts_dir.join(file),
                            kind: meta
                                .get("kind")
                                .and_then(|k| k.as_str())
                                .unwrap_or("")
                                .to_string(),
                            fmt: meta
                                .get("fmt")
                                .and_then(|k| k.as_str())
                                .unwrap_or("fp32")
                                .to_string(),
                            seq: meta.get("seq").and_then(|k| k.as_f64()).unwrap_or(0.0)
                                as usize,
                            n_params: meta
                                .get("n_params")
                                .and_then(|k| k.as_f64())
                                .unwrap_or(0.0) as usize,
                        },
                    );
                }
            }
        }
        Ok(Runtime {
            artifacts_dir: artifacts_dir.to_path_buf(),
            manifest,
        })
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }
}

/// Forward-pass executable: tokens → logits.
pub struct LmFwdExec {
    pub seq: usize,
    pub vocab: usize,
}

impl LmFwdExec {
    pub fn load(rt: &mut Runtime, name: &str, _vocab: usize) -> Result<LmFwdExec, RuntimeError> {
        rt.meta(name)
            .ok_or_else(|| RuntimeError::MissingArtifact(name.into()))?;
        Err(disabled("lm_fwd execution"))
    }

    pub fn run(&self, _tokens: &[usize], _params: &Params) -> Result<Tensor, RuntimeError> {
        Err(disabled("lm_fwd execution"))
    }
}

/// Train-step executable: (tokens, targets, lr, params) → (loss, params').
pub struct TrainStepExec {
    pub seq: usize,
}

impl TrainStepExec {
    pub fn load(rt: &mut Runtime, name: &str) -> Result<TrainStepExec, RuntimeError> {
        rt.meta(name)
            .ok_or_else(|| RuntimeError::MissingArtifact(name.into()))?;
        Err(disabled("train_step execution"))
    }

    pub fn step(
        &self,
        _tokens: &[usize],
        _targets: &[usize],
        _lr: f32,
        _params: &mut Params,
    ) -> Result<f64, RuntimeError> {
        Err(disabled("train_step execution"))
    }
}

/// Pallas quantised-GEMM executable: (x, w) → y.
pub struct QmatmulExec {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl QmatmulExec {
    pub fn load(
        rt: &mut Runtime,
        name: &str,
        _m: usize,
        _k: usize,
        _n: usize,
    ) -> Result<Self, RuntimeError> {
        rt.meta(name)
            .ok_or_else(|| RuntimeError::MissingArtifact(name.into()))?;
        Err(disabled("qmatmul execution"))
    }

    pub fn run(&self, _x: &Tensor, _w: &Tensor) -> Result<Tensor, RuntimeError> {
        Err(disabled("qmatmul execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_is_ok_but_empty() {
        let rt = Runtime::open(Path::new("/nonexistent/artifacts")).unwrap();
        assert!(rt.artifact_names().is_empty());
    }

    #[test]
    fn missing_artifact_reported_before_disabled() {
        let mut rt = Runtime::open(Path::new("/nonexistent/artifacts")).unwrap();
        match TrainStepExec::load(&mut rt, "nope") {
            Err(RuntimeError::MissingArtifact(a)) => assert_eq!(a, "nope"),
            other => panic!("expected MissingArtifact, got {other:?}"),
        }
    }

    #[test]
    fn manifest_parses_and_load_reports_disabled() {
        let dir = std::env::temp_dir().join("bbq_stub_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": {"train_step_golden": {"file": "t.hlo.txt", "kind": "train_step", "fmt": "fp32", "seq": 32, "n_params": 10}}}"#,
        )
        .unwrap();
        let mut rt = Runtime::open(&dir).unwrap();
        assert_eq!(rt.artifact_names(), vec!["train_step_golden".to_string()]);
        let meta = rt.meta("train_step_golden").unwrap();
        assert_eq!(meta.kind, "train_step");
        assert_eq!(meta.seq, 32);
        match TrainStepExec::load(&mut rt, "train_step_golden") {
            Err(RuntimeError::Disabled(_)) => {}
            other => panic!("expected Disabled, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
