//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the CPU PJRT client (the `xla` crate). This is the bridge between the
//! Rust coordinator and the JAX/Pallas compute graphs — python never runs
//! at request time.

pub mod client;
pub mod exec;

pub use client::{Runtime, RuntimeError};
pub use exec::{LmFwdExec, QmatmulExec, TrainStepExec};
