//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the CPU PJRT client (the `xla` crate). This is the bridge between the
//! Rust coordinator and the JAX/Pallas compute graphs — python never runs
//! at request time.
//!
//! The real client needs the `xla` bindings, which are not available in
//! every build environment, so it sits behind the `xla` cargo feature.
//! Default builds get the API-compatible stub in [`stub`]: manifests still
//! parse (so `bbq artifacts` works), but compiling/executing an artifact
//! returns a clear [`RuntimeError`]. PJRT-backed tests and examples probe
//! for artifact files first and skip when they are absent, so the stub
//! keeps `cargo test` green everywhere.

/// True when this build carries the real PJRT client. Callers that need
/// execution (integration tests, examples) should skip gracefully when
/// false instead of tripping over [`stub`]'s `Disabled` errors.
pub const PJRT_AVAILABLE: bool = cfg!(feature = "xla");

/// Persistent worker pool shared by the GEMM kernels, the fused packed
/// prefill/decode lanes, and the batched engine's slot-parallel attention.
/// Feature-independent: it backs the CPU hot paths whether or not the
/// PJRT client is compiled in.
pub mod pool;

#[cfg(feature = "xla")]
pub mod client;
#[cfg(feature = "xla")]
pub mod exec;

#[cfg(feature = "xla")]
pub use client::{Runtime, RuntimeError};
#[cfg(feature = "xla")]
pub use exec::{LmFwdExec, QmatmulExec, TrainStepExec};

#[cfg(not(feature = "xla"))]
pub mod stub;

#[cfg(not(feature = "xla"))]
pub use stub::{LmFwdExec, QmatmulExec, Runtime, RuntimeError, TrainStepExec};
