//! Typed executable wrappers over the PJRT runtime.
//!
//! Each wrapper owns the compiled executable plus the signature metadata
//! (sequence length, parameter count) and converts between `Tensor`/token
//! slices and XLA literals. Parameters ride as a flat literal list in the
//! canonical order shared with `python/compile/model.py::param_names` and
//! `rust Params::flat_views`.

use super::client::{
    scalar_literal, tensor_to_literal, tokens_to_literal, Runtime, RuntimeError,
};
use crate::model::params::Params;
use crate::tensor::Tensor;

fn params_to_literals(params: &Params) -> Result<Vec<xla::Literal>, RuntimeError> {
    let mut lits = Vec::new();
    let d = params.cfg.d_model;
    for (name, buf) in params.flat_views() {
        // shapes: embeddings/weights are 2-D, the rest 1-D
        let lit = if name == "tok_emb" {
            super::client::vec_to_literal(buf, &[params.cfg.vocab_size, d])?
        } else if name == "pos_emb" {
            super::client::vec_to_literal(buf, &[params.cfg.max_seq, d])?
        } else if name.ends_with(".w1") {
            super::client::vec_to_literal(buf, &[d, params.cfg.d_ff])?
        } else if name.ends_with(".w2") {
            super::client::vec_to_literal(buf, &[params.cfg.d_ff, d])?
        } else if name.ends_with(".wq")
            || name.ends_with(".wk")
            || name.ends_with(".wv")
            || name.ends_with(".wo")
        {
            super::client::vec_to_literal(buf, &[d, d])?
        } else {
            super::client::vec_to_literal(buf, &[buf.len()])?
        };
        lits.push(lit);
    }
    Ok(lits)
}

fn literals_into_params(lits: Vec<xla::Literal>, params: &mut Params) -> Result<(), RuntimeError> {
    let views = params.flat_views_mut();
    if lits.len() != views.len() {
        return Err(RuntimeError::Shape(format!(
            "expected {} param outputs, got {}",
            views.len(),
            lits.len()
        )));
    }
    for ((name, buf), lit) in views.into_iter().zip(lits) {
        let v = lit.to_vec::<f32>()?;
        if v.len() != buf.len() {
            return Err(RuntimeError::Shape(format!(
                "param '{name}': {} vs {}",
                v.len(),
                buf.len()
            )));
        }
        buf.copy_from_slice(&v);
    }
    Ok(())
}

/// Forward-pass executable: tokens → logits.
pub struct LmFwdExec {
    exe: xla::PjRtLoadedExecutable,
    pub seq: usize,
    pub vocab: usize,
}

impl LmFwdExec {
    pub fn load(rt: &mut Runtime, name: &str, vocab: usize) -> Result<LmFwdExec, RuntimeError> {
        let seq = rt
            .meta(name)
            .ok_or_else(|| RuntimeError::MissingArtifact(name.into()))?
            .seq;
        let exe = rt.compile(name)?;
        Ok(LmFwdExec { exe, seq, vocab })
    }

    /// Run: tokens (len == seq) + params → logits [seq, vocab].
    pub fn run(&self, tokens: &[usize], params: &Params) -> Result<Tensor, RuntimeError> {
        if tokens.len() != self.seq {
            return Err(RuntimeError::Shape(format!(
                "tokens len {} != artifact seq {}",
                tokens.len(),
                self.seq
            )));
        }
        let mut args = vec![tokens_to_literal(tokens)?];
        args.extend(params_to_literals(params)?);
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?;
        let data = logits.to_vec::<f32>()?;
        Ok(Tensor::new(&[self.seq, self.vocab], data))
    }
}

/// Train-step executable: (tokens, targets, lr, params) → (loss, params').
pub struct TrainStepExec {
    exe: xla::PjRtLoadedExecutable,
    pub seq: usize,
}

impl TrainStepExec {
    pub fn load(rt: &mut Runtime, name: &str) -> Result<TrainStepExec, RuntimeError> {
        let seq = rt
            .meta(name)
            .ok_or_else(|| RuntimeError::MissingArtifact(name.into()))?
            .seq;
        let exe = rt.compile(name)?;
        Ok(TrainStepExec { exe, seq })
    }

    /// One step; updates `params` in place, returns the loss.
    pub fn step(
        &self,
        tokens: &[usize],
        targets: &[usize],
        lr: f32,
        params: &mut Params,
    ) -> Result<f64, RuntimeError> {
        if tokens.len() != self.seq || targets.len() != self.seq {
            return Err(RuntimeError::Shape(format!(
                "tokens/targets len {}/{} != artifact seq {}",
                tokens.len(),
                targets.len(),
                self.seq
            )));
        }
        let mut args = vec![
            tokens_to_literal(tokens)?,
            tokens_to_literal(targets)?,
            scalar_literal(lr),
        ];
        args.extend(params_to_literals(params)?);
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        if outs.is_empty() {
            return Err(RuntimeError::Shape("empty train_step output".into()));
        }
        let loss = outs.remove(0).to_vec::<f32>()?[0] as f64;
        literals_into_params(outs, params)?;
        Ok(loss)
    }
}

/// Pallas quantised-GEMM executable: (x, w) → y.
pub struct QmatmulExec {
    exe: xla::PjRtLoadedExecutable,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl QmatmulExec {
    pub fn load(
        rt: &mut Runtime,
        name: &str,
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<Self, RuntimeError> {
        let exe = rt.compile(name)?;
        Ok(QmatmulExec { exe, m, k, n })
    }

    pub fn run(&self, x: &Tensor, w: &Tensor) -> Result<Tensor, RuntimeError> {
        if x.shape != vec![self.m, self.k] || w.shape != vec![self.k, self.n] {
            return Err(RuntimeError::Shape(format!(
                "qmatmul expects [{},{}]x[{},{}], got {:?}x{:?}",
                self.m, self.k, self.k, self.n, x.shape, w.shape
            )));
        }
        let args = [tensor_to_literal(x)?, tensor_to_literal(w)?];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let y = result.to_tuple1()?;
        Ok(Tensor::new(&[self.m, self.n], y.to_vec::<f32>()?))
    }
}
