//! PJRT client wrapper + artifact registry.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns them (see /opt/xla-example/README.md).

use crate::tensor::Tensor;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("artifact '{0}' not found in manifest")]
    MissingArtifact(String),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("shape mismatch: {0}")]
    Shape(String),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(format!("{e}"))
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub fmt: String,
    pub seq: usize,
    pub n_params: usize,
}

/// PJRT CPU client + artifact registry. Compiled executables are owned by
/// the typed wrappers in [`super::exec`]; compilation happens once per
/// wrapper construction (the PJRT executable type is not cloneable).
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
}

impl Runtime {
    /// Open the artifacts directory (reads manifest.json).
    pub fn open(artifacts_dir: &Path) -> Result<Runtime, RuntimeError> {
        let client = xla::PjRtClient::cpu()?;
        let manifest_path = artifacts_dir.join("manifest.json");
        let mut manifest = HashMap::new();
        if manifest_path.exists() {
            let text = std::fs::read_to_string(&manifest_path)?;
            let j = Json::parse(&text).map_err(RuntimeError::Manifest)?;
            let arts = j
                .get("artifacts")
                .ok_or_else(|| RuntimeError::Manifest("no 'artifacts' key".into()))?;
            if let Json::Obj(m) = arts {
                for (name, meta) in m {
                    let file = meta
                        .get("file")
                        .and_then(|f| f.as_str())
                        .unwrap_or_default()
                        .to_string();
                    manifest.insert(
                        name.clone(),
                        ArtifactMeta {
                            name: name.clone(),
                            file: artifacts_dir.join(file),
                            kind: meta
                                .get("kind")
                                .and_then(|k| k.as_str())
                                .unwrap_or("")
                                .to_string(),
                            fmt: meta
                                .get("fmt")
                                .and_then(|k| k.as_str())
                                .unwrap_or("fp32")
                                .to_string(),
                            seq: meta.get("seq").and_then(|k| k.as_f64()).unwrap_or(0.0)
                                as usize,
                            n_params: meta
                                .get("n_params")
                                .and_then(|k| k.as_f64())
                                .unwrap_or(0.0) as usize,
                        },
                    );
                }
            }
        }
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            manifest,
        })
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }

    /// Load + compile an artifact by manifest name.
    pub fn compile(&mut self, name: &str) -> Result<xla::PjRtLoadedExecutable, RuntimeError> {
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| RuntimeError::MissingArtifact(name.to_string()))?;
        if !meta.file.exists() {
            return Err(RuntimeError::MissingArtifact(format!(
                "{name} (file {} missing — run `make artifacts`)",
                meta.file.display()
            )));
        }
        let file = meta.file.clone();
        self.compile_file(&file)
    }

    /// Compile a bare .hlo.txt file (no manifest entry).
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable, RuntimeError> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| RuntimeError::Manifest("bad path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

// ---- literal conversion helpers ----

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal, RuntimeError> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

pub fn vec_to_literal(v: &[f32], shape: &[usize]) -> Result<xla::Literal, RuntimeError> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(v).reshape(&dims)?)
}

pub fn tokens_to_literal(tokens: &[usize]) -> Result<xla::Literal, RuntimeError> {
    let v: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    let dims = [tokens.len() as i64];
    Ok(xla::Literal::vec1(&v).reshape(&dims)?)
}

pub fn scalar_literal(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn literal_to_vec(l: &xla::Literal) -> Result<Vec<f32>, RuntimeError> {
    Ok(l.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        crate::util::artifacts_dir()
    }

    fn have_artifacts() -> bool {
        artifacts().join("manifest.json").exists()
    }

    #[test]
    fn open_missing_dir_is_ok_but_empty() {
        let rt = Runtime::open(Path::new("/nonexistent/artifacts")).unwrap();
        assert!(rt.artifact_names().is_empty());
    }

    #[test]
    fn missing_artifact_error() {
        let mut rt = Runtime::open(Path::new("/nonexistent/artifacts")).unwrap();
        match rt.compile("nope") {
            Err(RuntimeError::MissingArtifact(_)) => {}
            Err(other) => panic!("expected MissingArtifact, got {other}"),
            Ok(_) => panic!("expected MissingArtifact, got Ok"),
        }
    }

    #[test]
    fn manifest_parses_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let rt = Runtime::open(&artifacts()).unwrap();
        assert!(rt.artifact_names().iter().any(|n| n.starts_with("lm_fwd")));
        let meta = rt.meta("train_step_golden").unwrap();
        assert_eq!(meta.kind, "train_step");
        assert!(meta.n_params > 0);
    }
}
