//! Shared scoped-thread worker pool — the crate's one threading primitive.
//!
//! Every parallel hot path (the dense GEMM row partition, the packed GEMM's
//! column panels, and the batched engine's slot-parallel attention) funnels
//! through [`run_mut`]: a scoped-thread pool whose workers pull items off a
//! mutex-guarded iterator, so heterogeneous items (e.g. attention over
//! slots at very different sequence positions) load-balance dynamically
//! instead of being pinned to a static partition. Scoped threads mean no
//! `'static` bounds — items may borrow the caller's stack — and the pool
//! tears down before `run_mut` returns, so there is no global state and no
//! shutdown protocol.
//!
//! Grown out of the row-partition helper that used to live privately in
//! `tensor::matmul`; generalised here so the batched decode engine's
//! attention (④⑤) can share it.

use std::ops::Range;
use std::sync::Mutex;

/// Thread budget: `BBQ_THREADS` env override, else the machine's available
/// parallelism. Always ≥ 1.
pub fn available_threads() -> usize {
    std::env::var("BBQ_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .max(1)
}

/// Run `f` once per item across up to `threads` scoped worker threads.
///
/// Workers pull items dynamically from a shared queue, so uneven items
/// (long vs short attention contexts, ragged GEMM panels) keep every core
/// busy. With `threads <= 1` or a single item the loop runs inline on the
/// caller's thread — same `f`, same order-independent semantics, zero
/// spawn cost. `f` must be safe to call concurrently on *different* items;
/// each item is visited exactly once.
pub fn run_mut<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let nt = threads.min(n).max(1);
    if nt == 1 {
        for item in items.iter_mut() {
            f(item);
        }
        return;
    }
    // IterMut yields &mut T with the slice's lifetime, not the lock
    // guard's, so a worker holds the lock only long enough to grab its
    // next item.
    let queue = Mutex::new(items.iter_mut());
    let fref = &f;
    let qref = &queue;
    std::thread::scope(|scope| {
        for _ in 0..nt {
            scope.spawn(move || loop {
                let next = qref.lock().unwrap().next();
                match next {
                    Some(item) => fref(item),
                    None => break,
                }
            });
        }
    });
}

/// Partition the rows of a row-major `[m, n]` buffer across the pool: each
/// closure call gets a row range and the matching `&mut` chunk of `out`
/// (addressed relative to the range start). Row partitioning leaves each
/// row's accumulation order untouched, which is what lets the GEMM callers
/// keep their bit-identity guarantees while threading.
pub fn par_rows<F>(out: &mut [f32], m: usize, threads: usize, f: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    assert!(m > 0, "par_rows over zero rows");
    let n = out.len() / m;
    let nt = threads.min(m).max(1);
    let rows_per = m.div_ceil(nt);
    let mut items: Vec<(Range<usize>, &mut [f32])> = Vec::with_capacity(nt);
    let mut rest = out;
    let mut start = 0usize;
    while start < m {
        let end = (start + rows_per).min(m);
        let (chunk, tail) = rest.split_at_mut((end - start) * n);
        rest = tail;
        items.push((start..end, chunk));
        start = end;
    }
    run_mut(&mut items, nt, |item| f(item.0.clone(), &mut *item.1));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_mut_visits_every_item_once() {
        let mut items: Vec<usize> = vec![0; 37];
        let calls = AtomicUsize::new(0);
        run_mut(&mut items, 4, |x| {
            *x += 1;
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert!(items.iter().all(|&x| x == 1));
        assert_eq!(calls.load(Ordering::Relaxed), 37);
    }

    #[test]
    fn run_mut_single_thread_and_empty() {
        let mut items: Vec<usize> = vec![5; 3];
        run_mut(&mut items, 1, |x| *x *= 2);
        assert_eq!(items, vec![10, 10, 10]);
        let mut none: Vec<usize> = Vec::new();
        run_mut(&mut none, 8, |_| panic!("no items to visit"));
    }

    #[test]
    fn par_rows_covers_all_rows_disjointly() {
        let (m, n) = (13usize, 7usize);
        let mut out = vec![0.0f32; m * n];
        par_rows(&mut out, m, 4, |rows, chunk| {
            let row0 = rows.start;
            for i in rows {
                for j in 0..n {
                    chunk[(i - row0) * n + j] = (i * n + j) as f32;
                }
            }
        });
        for (idx, &v) in out.iter().enumerate() {
            assert_eq!(v, idx as f32);
        }
    }

    #[test]
    fn threads_env_floor() {
        assert!(available_threads() >= 1);
    }
}
