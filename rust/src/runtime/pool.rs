//! Persistent worker pool — the crate's one threading primitive.
//!
//! Every parallel hot path (the dense GEMM row partition, the packed GEMM's
//! column panels, the fused prefill kernel's column blocks, and the batched
//! engine's slot-parallel attention) funnels through [`run_mut`]: workers
//! pull items off a mutex-guarded iterator, so heterogeneous items (e.g.
//! attention over slots at very different sequence positions) load-balance
//! dynamically instead of being pinned to a static partition.
//!
//! Unlike the scoped-thread pool this module used to be, the workers are
//! **long-lived**: a [`WorkerPool`] is started lazily on first use
//! ([`global`]), sized by `BBQ_THREADS` (or the machine's available
//! parallelism), and its workers park between jobs instead of being
//! re-spawned per GEMM per layer — the recurring spawn/join cost the
//! roadmap flagged is paid exactly once per process ([`spawn_count`] lets
//! tests assert that steady-state decode loops spawn nothing). The
//! scoped-job guarantee is kept: [`WorkerPool::scoped`] does not return
//! until every worker has finished the job, so jobs may borrow the
//! caller's stack exactly like `std::thread::scope` allowed.
//!
//! Threading never changes results anywhere in the crate: every item is
//! computed by the same code whether it runs on a worker or inline, and
//! the GEMM callers partition work so each output element accumulates in a
//! fixed order.

use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// Per-thread override of the thread budget (test hook; see
    /// [`with_threads`]).
    static THREADS_OVERRIDE: std::cell::Cell<Option<usize>> =
        const { std::cell::Cell::new(None) };
    /// True while this thread is executing a pool job (worker or
    /// participating caller). Nested parallel calls run inline instead of
    /// deadlocking on the dispatch lock.
    static IN_POOL_JOB: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Thread budget: the calling thread's [`with_threads`] override if set,
/// else the `BBQ_THREADS` env override, else the machine's available
/// parallelism. Always ≥ 1.
pub fn available_threads() -> usize {
    if let Some(n) = THREADS_OVERRIDE.with(|c| c.get()) {
        return n;
    }
    configured_threads()
}

/// The process-wide thread budget (env/machine only — ignores the
/// per-thread test override, because the global pool is sized once).
fn configured_threads() -> usize {
    std::env::var("BBQ_THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .max(1)
}

/// Run `f` with [`available_threads`] pinned to `threads` on this thread
/// (restored on exit, panics included). A test hook: lets one process
/// compare thread counts — e.g. assert a forward pass is bit-identical
/// under 1 and 4 threads — without racing on the process environment.
/// Only affects dispatch decisions made on the calling thread.
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREADS_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = THREADS_OVERRIDE.with(|c| c.replace(Some(threads.max(1))));
    let _restore = Restore(prev);
    f()
}

/// Worker threads spawned by every [`WorkerPool`] so far (process-wide,
/// monotonic). Steady-state serving must not move this: the acceptance
/// tests snapshot it after pool start and assert whole forward/decode
/// loops leave it unchanged.
pub fn spawn_count() -> usize {
    SPAWN_COUNT.load(Ordering::SeqCst)
}

static SPAWN_COUNT: AtomicUsize = AtomicUsize::new(0);

/// Type-erased scoped job: a borrow of the caller's closure. Sound because
/// [`WorkerPool::scoped`] blocks until every worker finished the job, so
/// the pointee outlives every use.
struct JobPtr(*const (dyn Fn() + Sync));
unsafe impl Send for JobPtr {}

struct PoolState {
    job: Option<JobPtr>,
    /// Bumped per job so each worker runs each job exactly once.
    epoch: u64,
    /// Workers that have not yet picked up the current job.
    to_start: usize,
    /// Workers currently executing the current job.
    running: usize,
    /// A worker's job execution panicked (the worker itself survives).
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The dispatching caller parks here until every worker is done.
    done: Condvar,
}

impl PoolShared {
    /// Lock the state, recovering from poisoning (a panicking job must not
    /// brick the pool — the panic is re-raised on the caller instead).
    fn lock(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A persistent pool of parked worker threads with a scoped-job API.
///
/// `WorkerPool::new(t)` spawns `t - 1` workers; the thread calling
/// [`WorkerPool::scoped`] is always a participant, so a pool sized 1 has
/// no workers at all and every job runs inline. Jobs are dispatched one
/// at a time (a caller that finds the workers busy runs its job inline
/// rather than waiting), each job subscribes up to its requested thread
/// count of workers (the rest stay parked), and `scoped` returns only
/// after the last participant finishes
/// — which is what makes it safe for jobs to borrow stack data. A panic
/// inside a job is caught on the workers (they park again and stay
/// reusable) and re-raised on the calling thread.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Serialises job dispatch: one scoped job owns the workers at a time.
    dispatch: Mutex<()>,
    workers: usize,
    spawned: AtomicUsize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl WorkerPool {
    /// Build a pool sized for `threads` total participants (the caller
    /// counts as one, so this spawns `threads - 1` workers).
    pub fn new(threads: usize) -> WorkerPool {
        let workers = threads.max(1) - 1;
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                to_start: 0,
                running: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = shared.clone();
            SPAWN_COUNT.fetch_add(1, Ordering::SeqCst);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("bbq-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker"),
            );
        }
        WorkerPool {
            shared,
            dispatch: Mutex::new(()),
            workers,
            spawned: AtomicUsize::new(workers),
            handles: Mutex::new(handles),
        }
    }

    /// Parked worker threads owned by this pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Worker threads this pool has spawned over its lifetime (equals
    /// [`Self::workers`] — workers are reused, never re-spawned; the
    /// counter exists so tests can assert exactly that).
    pub fn spawned(&self) -> usize {
        self.spawned.load(Ordering::SeqCst)
    }

    /// Run `f` once on up to `threads - 1` pool workers *and* the calling
    /// thread, returning once all participants have finished. `f` is
    /// typically a queue-drain loop, so extra participants beyond the
    /// number of work items simply find the queue empty and return. `f`
    /// may borrow the caller's stack; it must be safe to run concurrently
    /// with itself. Workers beyond the cap skip the job and stay parked,
    /// so a `threads` below the pool size genuinely bounds concurrency.
    ///
    /// Runs inline (no workers involved) when `threads <= 1`, when the
    /// pool has no workers, when called from inside another pool job, or
    /// when another caller currently owns the workers — concurrent and
    /// nested parallel sections degrade to sequential execution instead
    /// of deadlocking or stalling behind a foreign job.
    pub fn scoped<F: Fn() + Sync>(&self, threads: usize, f: F) {
        let helpers = threads.saturating_sub(1).min(self.workers);
        if helpers == 0 || IN_POOL_JOB.with(|c| c.get()) {
            f();
            return;
        }
        // Jobs are dispatched one at a time; rather than queueing behind
        // another caller's whole job (unbounded added latency for, say,
        // an engine step racing an experiment forward), a contended
        // caller just runs its work inline on its own thread.
        let _serial = match self.dispatch.try_lock() {
            Ok(guard) => guard,
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => {
                f();
                return;
            }
        };
        {
            let fr: &(dyn Fn() + Sync) = &f;
            // SAFETY: erase the borrow's lifetime. Sound because the
            // rendezvous below blocks until every subscribed worker has
            // finished with the job, so the pointee strictly outlives
            // every use.
            let job: &'static (dyn Fn() + Sync + 'static) = unsafe { std::mem::transmute(fr) };
            let mut st = self.shared.lock();
            st.job = Some(JobPtr(job as *const _));
            st.epoch = st.epoch.wrapping_add(1);
            st.to_start = helpers;
            st.running = 0;
            st.panicked = false;
            self.shared.work.notify_all();
        }
        // The caller is a participant too.
        IN_POOL_JOB.with(|c| c.set(true));
        let mine = catch_unwind(AssertUnwindSafe(&f));
        IN_POOL_JOB.with(|c| c.set(false));
        // Rendezvous: every subscribed worker has started and finished.
        let worker_panicked = {
            let mut st = self.shared.lock();
            while st.to_start > 0 || st.running > 0 {
                st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
            st.panicked
        };
        match mine {
            Err(payload) => resume_unwind(payload),
            Ok(()) if worker_panicked => panic!("worker panicked during pool job"),
            Ok(()) => {}
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.lock().unwrap_or_else(|e| e.into_inner()).drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = shared.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(job) = &st.job {
                        let ptr = job.0;
                        seen = st.epoch;
                        // subscribe only while the job wants more hands —
                        // a capped job leaves the rest of the pool parked
                        if st.to_start > 0 {
                            st.to_start -= 1;
                            st.running += 1;
                            break ptr;
                        }
                    }
                }
                st = shared.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        IN_POOL_JOB.with(|c| c.set(true));
        // The job borrow is valid: the dispatcher cannot return from
        // `scoped` until this worker decrements `running` below.
        let res = catch_unwind(AssertUnwindSafe(|| unsafe { (&*task)() }));
        IN_POOL_JOB.with(|c| c.set(false));
        let mut st = shared.lock();
        if res.is_err() {
            st.panicked = true;
        }
        st.running -= 1;
        if st.to_start == 0 && st.running == 0 {
            shared.done.notify_all();
        }
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool, started lazily on first use and sized by
/// `BBQ_THREADS` (else available parallelism). Lives for the whole
/// process; workers park between jobs and are never re-spawned.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| WorkerPool::new(configured_threads()))
}

/// Run `f` once per item across up to `threads` participants of the
/// global pool (the calling thread included; workers beyond the cap stay
/// parked).
///
/// Participants pull items dynamically from a shared queue, so uneven
/// items (long vs short attention contexts, ragged GEMM panels) keep every
/// core busy. With `threads <= 1` or a single item the loop runs inline on
/// the caller's thread — same `f`, same order-independent semantics, no
/// pool involved. `f` must be safe to call concurrently on *different*
/// items; each item is visited exactly once regardless of thread count.
pub fn run_mut<T, F>(items: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let nt = threads.min(n).max(1);
    if nt == 1 {
        for item in items.iter_mut() {
            f(item);
        }
        return;
    }
    // IterMut yields &mut T with the slice's lifetime, not the lock
    // guard's, so a participant holds the lock only long enough to grab
    // its next item.
    let queue = Mutex::new(items.iter_mut());
    global().scoped(nt, || loop {
        let next = queue.lock().unwrap().next();
        match next {
            Some(item) => f(item),
            None => break,
        }
    });
}

/// Partition the rows of a row-major `[m, n]` buffer across the pool: each
/// closure call gets a row range and the matching `&mut` chunk of `out`
/// (addressed relative to the range start). Row partitioning leaves each
/// row's accumulation order untouched, which is what lets the GEMM callers
/// keep their bit-identity guarantees while threading.
pub fn par_rows<F>(out: &mut [f32], m: usize, threads: usize, f: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    assert!(m > 0, "par_rows over zero rows");
    let n = out.len() / m;
    let nt = threads.min(m).max(1);
    let rows_per = m.div_ceil(nt);
    let mut items: Vec<(Range<usize>, &mut [f32])> = Vec::with_capacity(nt);
    let mut rest = out;
    let mut start = 0usize;
    while start < m {
        let end = (start + rows_per).min(m);
        let (chunk, tail) = rest.split_at_mut((end - start) * n);
        rest = tail;
        items.push((start..end, chunk));
        start = end;
    }
    run_mut(&mut items, nt, |item| f(item.0.clone(), &mut *item.1));
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_mut_visits_every_item_once() {
        let mut items: Vec<usize> = vec![0; 37];
        let calls = AtomicUsize::new(0);
        run_mut(&mut items, 4, |x| {
            *x += 1;
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert!(items.iter().all(|&x| x == 1));
        assert_eq!(calls.load(Ordering::Relaxed), 37);
    }

    #[test]
    fn run_mut_single_thread_and_empty() {
        let mut items: Vec<usize> = vec![5; 3];
        run_mut(&mut items, 1, |x| *x *= 2);
        assert_eq!(items, vec![10, 10, 10]);
        let mut none: Vec<usize> = Vec::new();
        run_mut(&mut none, 8, |_| panic!("no items to visit"));
    }

    #[test]
    fn par_rows_covers_all_rows_disjointly() {
        let (m, n) = (13usize, 7usize);
        let mut out = vec![0.0f32; m * n];
        par_rows(&mut out, m, 4, |rows, chunk| {
            let row0 = rows.start;
            for i in rows {
                for j in 0..n {
                    chunk[(i - row0) * n + j] = (i * n + j) as f32;
                }
            }
        });
        for (idx, &v) in out.iter().enumerate() {
            assert_eq!(v, idx as f32);
        }
    }

    #[test]
    fn threads_env_floor_and_override() {
        assert!(available_threads() >= 1);
        let inside = with_threads(3, available_threads);
        assert_eq!(inside, 3);
        // restored afterwards (either the env/machine value, not the pin)
        assert_eq!(available_threads(), configured_threads());
        // nested overrides restore the outer pin
        with_threads(2, || {
            assert_eq!(available_threads(), 2);
            with_threads(5, || assert_eq!(available_threads(), 5));
            assert_eq!(available_threads(), 2);
        });
    }

    #[test]
    fn workers_are_reused_across_jobs() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.spawned(), 2);
        let hits = AtomicUsize::new(0);
        for _ in 0..16 {
            pool.scoped(3, || {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        // every participant (2 workers + caller) ran each of the 16 jobs,
        // and not a single extra thread was spawned to do it
        assert_eq!(hits.load(Ordering::SeqCst), 16 * 3);
        assert_eq!(pool.spawned(), 2);
        // a capped job leaves the extra worker parked: exactly one worker
        // joins the caller
        let capped = AtomicUsize::new(0);
        pool.scoped(2, || {
            capped.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(capped.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = WorkerPool::new(3);
        let spawned = pool.spawned();
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped(3, || panic!("job panic"));
        }));
        assert!(boom.is_err(), "job panic must propagate to the caller");
        // the pool is still serviceable afterwards, with the same workers
        let hits = AtomicUsize::new(0);
        pool.scoped(3, || {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        assert_eq!(pool.spawned(), spawned);
    }

    #[test]
    fn run_mut_panic_propagates_and_pool_recovers() {
        let mut items: Vec<usize> = (0..8).collect();
        let boom = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_mut(&mut items, 4, |x| {
                if *x == 3 {
                    panic!("item 3");
                }
            });
        }));
        assert!(boom.is_err());
        // the global pool keeps working after the panicked job
        let mut again: Vec<usize> = vec![0; 9];
        run_mut(&mut again, 4, |x| *x += 1);
        assert!(again.iter().all(|&x| x == 1));
    }

    #[test]
    fn nested_run_mut_degrades_to_inline() {
        // a pool job that itself calls run_mut must not deadlock on the
        // dispatch lock — the inner call runs inline on its participant
        let mut outer: Vec<Vec<usize>> = (0..6).map(|_| vec![0; 5]).collect();
        run_mut(&mut outer, 4, |inner| {
            run_mut(inner, 4, |x| *x += 1);
        });
        assert!(outer.iter().flatten().all(|&x| x == 1));
    }
}
