//! Variance profiler (paper Figure 1 / 4 / 5): run calibration samples
//! through a model and report per-tensor, per-layer variances for the
//! activations entering the eight GEMMs, plus weight variances.

use crate::data::corpus::test_stream;
use crate::data::vocab::Vocab;
use crate::model::plan::QuantPlan;
use crate::model::transformer::{ActStats, Model};
use crate::model::Params;
use crate::util::table::Table;

/// Activation tensors plotted in Figure 1 (unbounded-range GEMM operands).
pub const ACT_TENSORS: [&str; 8] = ["X1", "Q", "K", "V", "A", "B_c", "X2", "H"];
pub const WEIGHT_TENSORS: [&str; 6] = ["Wq", "Wk", "Wv", "Wo", "W1", "W2"];

#[derive(Debug)]
pub struct VarianceProfile {
    pub n_layers: usize,
    pub act: Vec<(String, Vec<f64>)>,
    pub weight: Vec<(String, Vec<f64>)>,
}

/// Feed `n_samples` held-out sequences of length `seq` (the paper uses 128
/// WikiText2 samples) and collect variances.
pub fn profile_variance(params: &Params, n_samples: usize, seq: usize) -> VarianceProfile {
    let vocab = Vocab::build();
    let stream = test_stream(&vocab, n_samples * seq + seq);
    let model = Model::new(params.clone(), QuantPlan::fp32());
    let mut stats = ActStats::default();
    for chunk in stream.chunks(seq).take(n_samples) {
        if chunk.len() < 2 {
            break;
        }
        model.forward(chunk, Some(&mut stats));
    }
    let n_layers = params.cfg.n_layers;
    let act = ACT_TENSORS
        .iter()
        .map(|name| (name.to_string(), stats.series(name, n_layers)))
        .collect();
    let wstats = model.weight_stats();
    let weight = WEIGHT_TENSORS
        .iter()
        .map(|name| (name.to_string(), wstats.series(name, n_layers)))
        .collect();
    VarianceProfile {
        n_layers,
        act,
        weight,
    }
}

impl VarianceProfile {
    pub fn to_table(&self, title: &str) -> Table {
        let mut header = vec!["tensor".to_string()];
        for l in 0..self.n_layers {
            header.push(format!("L{l}"));
        }
        let mut t = Table::new(title, &header.iter().map(String::as_str).collect::<Vec<_>>());
        for (name, series) in self.act.iter().chain(&self.weight) {
            let mut row = vec![name.clone()];
            row.extend(series.iter().map(|v| format!("{v:.4}")));
            t.row(row);
        }
        t
    }

    /// Paper observation 1: activation variance grows with depth.
    pub fn activation_depth_trend(&self, name: &str) -> f64 {
        let series = self
            .act
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.clone())
            .unwrap_or_default();
        trend_slope(&series)
    }

    /// Paper observation 3: weight variance ≪ activation variance.
    pub fn weight_act_ratio(&self) -> f64 {
        let mean = |vs: &Vec<(String, Vec<f64>)>| {
            let all: Vec<f64> = vs
                .iter()
                .flat_map(|(_, s)| s.iter().copied())
                .filter(|v| v.is_finite())
                .collect();
            all.iter().sum::<f64>() / all.len().max(1) as f64
        };
        mean(&self.weight) / mean(&self.act).max(1e-12)
    }
}

/// Least-squares slope of a series vs its index.
pub fn trend_slope(ys: &[f64]) -> f64 {
    let n = ys.len() as f64;
    if ys.len() < 2 {
        return 0.0;
    }
    let xm = (n - 1.0) / 2.0;
    let ym = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &y) in ys.iter().enumerate() {
        num += (i as f64 - xm) * (y - ym);
        den += (i as f64 - xm) * (i as f64 - xm);
    }
    num / den.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn profile_shapes() {
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 3);
        let prof = profile_variance(&p, 2, 24);
        assert_eq!(prof.act.len(), 8);
        assert_eq!(prof.weight.len(), 6);
        assert!(prof.act[0].1.iter().all(|v| v.is_finite()));
        let t = prof.to_table("fig1");
        assert!(t.render().contains("X1"));
    }

    #[test]
    fn trend_slope_signs() {
        assert!(trend_slope(&[1.0, 2.0, 3.0]) > 0.9);
        assert!(trend_slope(&[3.0, 2.0, 1.0]) < -0.9);
    }

    #[test]
    fn weight_variance_much_smaller_for_init_model() {
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 3);
        let prof = profile_variance(&p, 2, 24);
        assert!(prof.weight_act_ratio() < 0.5, "{}", prof.weight_act_ratio());
    }
}
