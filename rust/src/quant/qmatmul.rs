//! Quantised GEMM.
//!
//! Three execution paths that must agree (tested):
//!
//! 1. **Fake-quant path** (`qmatmul`): round both operands to the format's
//!    representable set, then run the optimized f32 GEMM. This is the
//!    paper's evaluation semantics.
//! 2. **Packed-weight path** (`qmatmul_packed`): the serving hot path —
//!    the weight lives as a bit-packed [`QTensor`] (blocks along the
//!    contraction dim, MSFP-style) and is dequantised block-row by
//!    block-row *inside* the GEMM, so resident weight memory is the packed
//!    payload (~5× smaller for BFP6) instead of dequantised f32. Bit-exact
//!    with path 1 because the streamed panels run through the very same
//!    [`crate::kernels`] GEMM primitives (`gemm_bt_rows`/`dot`), whatever
//!    SIMD backend is active.
//! 3. **Block-domain path** (`bfp_matmul_blocked`): the ASIC datapath of
//!    Eq. 4 — integer mantissa multiply-accumulate within each block pair
//!    plus a single shared-exponent add, no per-element shifting. Exact
//!    agreement with path 1 (up to f32 summation order) justifies the
//!    arithmetic-density numbers of Table 6.

use super::block::block_ranges;
use super::config::{GemmQuant, QFormat};
use super::qtensor::QTensor;
use crate::kernels::{dot, gemm_bt_rows, gemm_rows};
use crate::tensor::matmul::{available_threads, matmul, matmul_bt, PAR_THRESHOLD};
use crate::tensor::Tensor;

/// `act [m,k] @ weight [k,n]` with both operands fake-quantised.
/// Blocks run along the contraction dim: rows of `act`, columns of `weight`
/// (i.e. rows of `weight`ᵀ) — the paper's "slice along matrix row".
pub fn qmatmul(act: &Tensor, weight: &Tensor, q: GemmQuant) -> Tensor {
    let qa = super::fake_quant(act, q.act);
    // quantise weight along its k dimension: transpose, quantise rows, use B^T GEMM
    match q.weight {
        QFormat::Fp32 => matmul(&qa, weight),
        _ => {
            let wt = weight.t();
            let qwt = super::fake_quant(&wt, q.weight);
            matmul_bt(&qa, &qwt)
        }
    }
}

/// Same as [`qmatmul`] but the weight is already transposed ([n, k]) and
/// possibly pre-quantised — the layout the model's weight cache uses so the
/// per-token hot path never re-transposes or re-quantises weights.
pub fn qmatmul_pret(act: &Tensor, weight_t_quantised: &Tensor, act_fmt: QFormat) -> Tensor {
    let qa = super::fake_quant(act, act_fmt);
    matmul_bt(&qa, weight_t_quantised)
}

/// `act [m,k] @ packed weight [n,k]ᵀ` — the packed-weight serving path.
/// The activation is fake-quantised as usual; the weight is dequantised
/// block-row by block-row from its packed payload inside the GEMM.
/// Bit-identical to `qmatmul_pret(act, &decode(weight), act_fmt)` (tested).
pub fn qmatmul_packed(act: &Tensor, weight: &QTensor, act_fmt: QFormat) -> Tensor {
    let qa = super::fake_quant(act, act_fmt);
    matmul_packed_bt(&qa, weight)
}

/// `a [m,k] @ dequant(qw) [n,k]ᵀ` with block dequantisation fused into the
/// GEMM; `a` is used as-is (the caller quantises it). This is the crate's
/// one packed-GEMM dispatch point — serving *and* the full-context
/// experiment path route here — with two regimes:
///
/// * **decode (m < 4)** — the memory-bound per-token path: delegates to
///   [`matmul_packed_bt_rowwise`], whose 4-row dequant panels stream
///   through the same `gemm_bt_rows` kernel the dense path uses, so only
///   one small scratch panel is ever resident.
/// * **prefill (m ≥ 4)** — compute-bound: delegates to the internal
///   `matmul_packed_bt_bcast`, which streams column panels of the packed
///   weight through the broadcast kernel — each weight row decoded exactly
///   once per call, into a bounded panel scratch, never into a transient
///   dense weight matrix.
///
/// Both regimes are bit-identical to `matmul_bt(a, &decode(qw))` because
/// every output element accumulates the identical value sequence.
pub fn matmul_packed_bt(a: &Tensor, qw: &QTensor) -> Tensor {
    let (m, _) = a.dims2();
    if m >= 4 {
        return matmul_packed_bt_bcast(a, qw);
    }
    matmul_packed_bt_rowwise(a, qw)
}

/// Column width of the fused prefill kernel's decode panel: big enough to
/// amortise the per-panel transpose, small enough that the scratch
/// (`2 · JBLK · k` floats per thread) stays cache-resident.
const BCAST_JBLK: usize = 64;

/// `a [m,k] @ dequant(qw) [n,k]ᵀ` for the compute-bound prefill regime
/// (m ≥ 4) with block dequantisation fused into the GEMM. Replaces the
/// transient dense decode the experiment path used to pay per call: the
/// packed weight is decoded one `[≤64, k]` column panel at a time (each
/// weight row exactly once per call), transposed into a panel-local
/// `[k, ≤64]` buffer, and streamed through the same i-k-j broadcast kernel
/// the dense path uses — so every output element accumulates the identical
/// value sequence and the result is bit-identical to
/// `matmul_bt(a, &decode(qw))` (tested), while peak scratch drops from one
/// dense weight matrix to a few panel buffers. Threads over column panels
/// on the shared worker pool above the `PAR_THRESHOLD` MAC count;
/// per-element accumulation order is independent of the column partition,
/// so the thread count never changes the bits.
///
/// pub(crate): callers route through [`matmul_packed_bt`], the one public
/// dispatch point — the regime split is policy, not API.
pub(crate) fn matmul_packed_bt_bcast(a: &Tensor, qw: &QTensor) -> Tensor {
    let (m, k) = a.dims2();
    assert_eq!(qw.shape.len(), 2, "packed weight must be 2-D, got {:?}", qw.shape);
    let (n, k2) = (qw.shape[0], qw.shape[1]);
    assert_eq!(k, k2, "matmul_packed_bt_bcast inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let threads = available_threads();
    if m * n * k >= PAR_THRESHOLD && threads > 1 && n > BCAST_JBLK {
        // parallel over disjoint column ranges; each task decodes its own
        // rows (still exactly once overall) into a private [m, chunk]
        // buffer that is stitched back afterwards
        let nt = threads.min(n.div_ceil(BCAST_JBLK));
        let per = n.div_ceil(nt);
        let mut chunks: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + per).min(n);
            chunks.push((j0, j1, vec![0.0f32; m * (j1 - j0)]));
            j0 = j1;
        }
        crate::runtime::pool::run_mut(&mut chunks, nt, |c| {
            packed_bcast_columns(&a.data, m, k, qw, c.0, c.1, &mut c.2)
        });
        for (j0, j1, buf) in &chunks {
            let w = j1 - j0;
            for i in 0..m {
                out[i * n + j0..i * n + j1].copy_from_slice(&buf[i * w..(i + 1) * w]);
            }
        }
    } else {
        packed_bcast_columns(&a.data, m, k, qw, 0, n, &mut out);
    }
    Tensor::new(&[m, n], out)
}

/// Fill `out` (row-major `[m, j1-j0]`) with output columns `[j0, j1)` of
/// the fused prefill GEMM: decode a `[≤JBLK, k]` row panel of the packed
/// weight, transpose it to `[k, ≤JBLK]`, run the broadcast kernel over all
/// m activation rows, and copy the panel's `[m, w]` result into place.
fn packed_bcast_columns(
    a: &[f32],
    m: usize,
    k: usize,
    qw: &QTensor,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let w_total = j1 - j0;
    debug_assert_eq!(out.len(), m * w_total);
    let wmax = BCAST_JBLK.min(w_total.max(1));
    let mut panel = vec![0.0f32; wmax * k];
    let mut panel_t = vec![0.0f32; k * wmax];
    let mut tmp = vec![0.0f32; m * wmax];
    let mut j = j0;
    while j < j1 {
        let je = (j + BCAST_JBLK).min(j1);
        let w = je - j;
        for r in 0..w {
            qw.decode_row_into(j + r, &mut panel[r * k..(r + 1) * k]);
        }
        for r in 0..w {
            for kk in 0..k {
                panel_t[kk * w + r] = panel[r * k + kk];
            }
        }
        let t = &mut tmp[..m * w];
        t.fill(0.0);
        gemm_rows(a, &panel_t[..k * w], t, 0..m, k, w);
        for i in 0..m {
            out[i * w_total + (j - j0)..i * w_total + (je - j0)]
                .copy_from_slice(&t[i * w..(i + 1) * w]);
        }
        j = je;
    }
}

/// `out[i][j - j0] = dot(a_i, dequant(qw row j))` for `j ∈ [j0, j1)`,
/// dequantising one 4-row panel at a time into a reusable scratch buffer.
/// Every output element is one `kernels::dot` against a decoded weight row,
/// so any column partition produces identical bits — callers may chunk
/// `[j0, j1)` freely (panel grouping only batches the dequantisation).
fn packed_bt_panel(
    a: &[f32],
    m: usize,
    k: usize,
    qw: &QTensor,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    let w = j1 - j0;
    debug_assert_eq!(out.len(), m * w);
    if m == 1 && qw.fused_dot_supported() {
        // the memory-bound single-token shape: stream decoded field slabs
        // straight into the lane accumulator (QTensor::dot_row), skipping
        // the staged row buffer entirely. Bit-identical to decode + dot,
        // so partition invariance (and the threaded lane above) still hold.
        for j in j0..j1 {
            out[j - j0] = qw.dot_row(j, &a[..k]);
        }
        return;
    }
    let mut panel = vec![0.0f32; 4 * k];
    let mut tmp = vec![0.0f32; m * 4];
    let mut j = j0;
    while j + 4 <= j1 {
        for r in 0..4 {
            qw.decode_row_into(j + r, &mut panel[r * k..(r + 1) * k]);
        }
        gemm_bt_rows(a, &panel, &mut tmp, 0..m, k, 4);
        for i in 0..m {
            let o = i * w + (j - j0);
            out[o..o + 4].copy_from_slice(&tmp[i * 4..(i + 1) * 4]);
        }
        j += 4;
    }
    while j < j1 {
        qw.decode_row_into(j, &mut panel[..k]);
        for i in 0..m {
            out[i * w + (j - j0)] = dot(&a[i * k..(i + 1) * k], &panel[..k]);
        }
        j += 1;
    }
}

/// `a [m,k] @ dequant(qw) [n,k]ᵀ` for the *batched decode* engine: the
/// fused 4-row dequant panels of [`matmul_packed_bt`]'s decode regime, but
/// for any m. Each weight panel is decoded exactly once per call and then
/// streamed against every activation row, so weights are decoded once per
/// layer per step no matter how many sequences share the step — the
/// amortisation continuous batching exists to buy. Unlike the m ≥ 4 prefill
/// regime (fused column panels through the broadcast kernel, a different
/// f32 summation order), every output row here accumulates in exactly the
/// order the m == 1 path uses, so row i of the batch is bit-identical to a
/// single-sequence decode of that row (tested).
pub fn matmul_packed_bt_rowwise(a: &Tensor, qw: &QTensor) -> Tensor {
    let (m, k) = a.dims2();
    assert_eq!(qw.shape.len(), 2, "packed weight must be 2-D, got {:?}", qw.shape);
    let (n, k2) = (qw.shape[0], qw.shape[1]);
    assert_eq!(k, k2, "matmul_packed_bt_rowwise inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let threads = available_threads();
    if m * n * k >= PAR_THRESHOLD && threads > 1 && n >= 8 {
        // column-partitioned like the m == 1 lane; dot-per-output semantics
        // make the bits independent of where the chunks split. Each thread
        // fills a private [m, chunk] buffer that is stitched back afterwards
        // — a row-major chunk of the output is not contiguous for m > 1.
        let nt = threads.min(n.div_ceil(4));
        let per = n.div_ceil(nt);
        let mut chunks: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        let mut j0 = 0usize;
        while j0 < n {
            let j1 = (j0 + per).min(n);
            chunks.push((j0, j1, vec![0.0f32; m * (j1 - j0)]));
            j0 = j1;
        }
        crate::runtime::pool::run_mut(&mut chunks, nt, |c| {
            packed_bt_panel(&a.data, m, k, qw, c.0, c.1, &mut c.2)
        });
        for (j0, j1, buf) in &chunks {
            let w = j1 - j0;
            for i in 0..m {
                out[i * n + j0..i * n + j1].copy_from_slice(&buf[i * w..(i + 1) * w]);
            }
        }
    } else {
        packed_bt_panel(&a.data, m, k, qw, 0, n, &mut out);
    }
    Tensor::new(&[m, n], out)
}

/// Integer-domain BFP GEMM (Eq. 4): `act [m,k] @ weight_t [n,k]`.
/// Both operands are BFP-encoded per block of `n_blk` along k; each block
/// pair contributes `2^(ea+eb) * Σ ma·mb` with a single exponent add.
pub fn bfp_matmul_blocked(
    act: &Tensor,
    weight_t: &Tensor,
    e_bits: u32,
    m_bits: u32,
    n_blk: usize,
) -> Tensor {
    let (m, k) = act.dims2();
    let (n, k2) = weight_t.dims2();
    assert_eq!(k, k2);
    // encode rows once
    let enc_rows = |t: &Tensor| -> Vec<(Vec<i32>, Vec<i32>)> {
        // per row: (block exponents, mantissas)
        (0..t.shape[0])
            .map(|i| {
                let row = t.row(i);
                let mut es = Vec::new();
                let mut ms = Vec::with_capacity(k);
                for (s, e) in block_ranges(k, n_blk) {
                    let (be, bm) = super::bfp::bfp_encode_block(&row[s..e], e_bits, m_bits);
                    es.push(be);
                    ms.extend(bm);
                }
                (es, ms)
            })
            .collect()
    };
    let a_enc = enc_rows(act);
    let w_enc = enc_rows(weight_t);
    let mut out = vec![0.0f32; m * n];
    let blocks: Vec<(usize, usize)> = block_ranges(k, n_blk).collect();
    for i in 0..m {
        let (ae, am) = &a_enc[i];
        for j in 0..n {
            let (we, wm) = &w_enc[j];
            let mut acc = 0.0f64;
            for (bi, &(s, e)) in blocks.iter().enumerate() {
                // integer MAC within the block — the cheap ASIC inner loop
                let mut isum: i64 = 0;
                for t in s..e {
                    isum += am[t] as i64 * wm[t] as i64;
                }
                // one shared-exponent scale per block pair
                let shift = (ae[bi] + we[bi]) - 2 * (m_bits as i32 - 1);
                acc += isum as f64 * exp2i_f64(shift);
            }
            out[i * n + j] = acc as f32;
        }
    }
    Tensor::new(&[m, n], out)
}

#[inline]
fn exp2i_f64(k: i32) -> f64 {
    (2.0f64).powi(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;
    use crate::util::check::{check, close_slice, llmish_values};

    #[test]
    fn fp32_qmatmul_is_plain_matmul() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let a = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let b = Tensor::randn(&[8, 5], 1.0, &mut rng);
        let q = qmatmul(&a, &b, GemmQuant::fp32());
        let p = matmul(&a, &b);
        close_slice(&q.data, &p.data, 1e-6, "fp32").unwrap();
    }

    #[test]
    fn block_domain_matches_fake_quant_path() {
        check("bfp eq4 == fake-quant", 20, |rng| {
            let (m, k, n) = (2 + rng.below(4), 32, 2 + rng.below(4));
            let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
            let w = Tensor::new(&[n, k], llmish_values(rng, n * k, 0.3, 0.0));
            let fmt = presets::bfp_w(6);
            let (e, mb, nb) = match fmt {
                QFormat::Bfp { e, m, n } => (e, m, n as usize),
                _ => unreachable!(),
            };
            let fake = {
                let qa = crate::quant::fake_quant(&a, fmt);
                let qw = crate::quant::fake_quant(&w, fmt);
                matmul_bt(&qa, &qw)
            };
            let blocked = bfp_matmul_blocked(&a, &w, e, mb, nb);
            close_slice(&fake.data, &blocked.data, 1e-5, "eq4")
        });
    }

    #[test]
    fn pret_matches_direct() {
        check("pret == direct", 20, |rng| {
            let (m, k, n) = (3, 16, 4);
            let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
            let w = Tensor::new(&[k, n], llmish_values(rng, k * n, 0.3, 0.0));
            let fmt = presets::bfp_w(6);
            let direct = qmatmul(&a, &w, GemmQuant::uniform(fmt));
            let wt_q = crate::quant::fake_quant(&w.t(), fmt);
            let pret = qmatmul_pret(&a, &wt_q, fmt);
            close_slice(&direct.data, &pret.data, 1e-6, "pret")
        });
    }

    #[test]
    fn packed_matches_pret_bitwise() {
        // the serving guarantee: decoding from packed payloads inside the
        // GEMM changes nothing, bit for bit, for any preset format
        let mut formats = presets::table3_formats();
        formats.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
        for (name, fmt) in formats {
            check(&format!("packed == pret {name}"), 12, |rng| {
                let m = 1 + rng.below(6); // covers decode (m<4) + prefill (m>=4)
                let k = 5 + rng.below(60); // includes ragged tail blocks
                let n = 1 + rng.below(10); // includes tail columns (n % 4 != 0)
                let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
                let w = Tensor::new(&[n, k], llmish_values(rng, n * k, 0.3, 0.02));
                let wt_q = crate::quant::fake_quant(&w, fmt);
                let packed = crate::quant::qtensor::encode(&w, fmt);
                let want = qmatmul_pret(&a, &wt_q, fmt);
                let got = qmatmul_packed(&a, &packed, fmt);
                close_slice(&want.data, &got.data, 0.0, name)
            });
        }
    }

    #[test]
    fn packed_threaded_decode_path_bitwise() {
        // m == 1 with n·k above PAR_THRESHOLD takes the column-threaded
        // lane; it must still be bit-identical to the dense kernel
        let mut rng = crate::util::rng::Pcg32::new(21);
        let (k, n) = (2048, 1024); // n·k == PAR_THRESHOLD
        let fmt = presets::bfp_w(6);
        let a = Tensor::new(&[1, k], llmish_values(&mut rng, k, 1.0, 0.02));
        let w = Tensor::new(&[n, k], llmish_values(&mut rng, n * k, 0.3, 0.0));
        let wt_q = crate::quant::fake_quant(&w, fmt);
        let packed = crate::quant::qtensor::encode(&w, fmt);
        let want = qmatmul_pret(&a, &wt_q, fmt);
        let got = qmatmul_packed(&a, &packed, fmt);
        assert_eq!(want.data, got.data);
    }

    #[test]
    fn packed_rowwise_is_bitwise_per_row() {
        // every row of the batched fused GEMM must match the m == 1 fused
        // GEMM bit for bit, for every preset format
        let mut formats = presets::table3_formats();
        formats.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
        for (name, fmt) in formats {
            check(&format!("packed rowwise {name}"), 10, |rng| {
                let m = 1 + rng.below(8);
                let k = 5 + rng.below(60);
                let n = 1 + rng.below(12);
                let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
                let w = Tensor::new(&[n, k], llmish_values(rng, n * k, 0.3, 0.02));
                let packed = crate::quant::qtensor::encode(&w, fmt);
                let batched = matmul_packed_bt_rowwise(&a, &packed);
                for i in 0..m {
                    let ai = Tensor::new(&[1, k], a.row(i).to_vec());
                    let single = matmul_packed_bt(&ai, &packed);
                    close_slice(batched.row(i), single.row(0), 0.0, &format!("{name} row {i}"))?;
                }
                Ok(())
            });
        }
    }

    #[test]
    fn packed_rowwise_threaded_lane_bitwise() {
        // force the column-partitioned multi-row lane (m·n·k ≥ PAR_THRESHOLD)
        let mut rng = crate::util::rng::Pcg32::new(33);
        let (m, k, n) = (8usize, 1024usize, 260usize); // ragged tail columns
        let fmt = presets::bfp_w(6);
        let a = Tensor::new(&[m, k], llmish_values(&mut rng, m * k, 1.0, 0.02));
        let w = Tensor::new(&[n, k], llmish_values(&mut rng, n * k, 0.3, 0.0));
        let packed = crate::quant::qtensor::encode(&w, fmt);
        let batched = matmul_packed_bt_rowwise(&a, &packed);
        for i in 0..m {
            let ai = Tensor::new(&[1, k], a.row(i).to_vec());
            let single = matmul_packed_bt(&ai, &packed);
            assert_eq!(batched.row(i), single.row(0), "row {i}");
        }
    }

    #[test]
    fn packed_bcast_matches_transient_dense_decode_bitwise() {
        // the pre-refactor m ≥ 4 path decoded the whole packed weight into
        // a transient dense matrix and called matmul_bt; the fused panel
        // kernel must reproduce those bits exactly for every preset format
        // (ragged k blocks and non-JBLK-aligned column tails included)
        let mut formats = presets::table3_formats();
        formats.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
        for (name, fmt) in formats {
            check(&format!("bcast == dense decode {name}"), 10, |rng| {
                let m = 4 + rng.below(6);
                let k = 5 + rng.below(60);
                let n = 1 + rng.below(90);
                let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
                let w = Tensor::new(&[n, k], llmish_values(rng, n * k, 0.3, 0.02));
                let packed = crate::quant::qtensor::encode(&w, fmt);
                let want = matmul_bt(&a, &crate::quant::qtensor::decode(&packed));
                let got = matmul_packed_bt_bcast(&a, &packed);
                close_slice(&want.data, &got.data, 0.0, name)
            });
        }
    }

    #[test]
    fn packed_bcast_threaded_lane_bitwise() {
        // force the column-parallel lane (m·n·k ≥ PAR_THRESHOLD with a
        // ragged tail vs the 64-wide panel) — still the dense-decode bits
        let mut rng = crate::util::rng::Pcg32::new(44);
        let (m, k, n) = (8usize, 1024usize, 300usize);
        let fmt = presets::bfp_w(6);
        let a = Tensor::new(&[m, k], llmish_values(&mut rng, m * k, 1.0, 0.02));
        let w = Tensor::new(&[n, k], llmish_values(&mut rng, n * k, 0.3, 0.0));
        let packed = crate::quant::qtensor::encode(&w, fmt);
        let want = matmul_bt(&a, &crate::quant::qtensor::decode(&packed));
        let got = matmul_packed_bt_bcast(&a, &packed);
        assert_eq!(want.data, got.data);
    }

    #[test]
    fn quantised_gemm_error_shrinks_with_bits() {
        let mut rng = crate::util::rng::Pcg32::new(9);
        let a = Tensor::new(&[8, 64], llmish_values(&mut rng, 512, 1.0, 0.02));
        let w = Tensor::new(&[64, 8], llmish_values(&mut rng, 512, 0.3, 0.0));
        let exact = matmul(&a, &w);
        let err = |bits| {
            let q = qmatmul(&a, &w, GemmQuant::uniform(presets::bfp_w(bits)));
            crate::util::stats::mse(&exact.data, &q.data)
        };
        let (e4, e6, e8) = (err(4), err(6), err(8));
        assert!(e8 < e6 && e6 < e4, "{e4} {e6} {e8}");
    }
}
