//! Quantised GEMM.
//!
//! Two execution paths that must agree (tested):
//!
//! 1. **Fake-quant path** (`qmatmul`): round both operands to the format's
//!    representable set, then run the optimized f32 GEMM. This is the
//!    paper's evaluation semantics and our model hot path.
//! 2. **Block-domain path** (`bfp_matmul_blocked`): the ASIC datapath of
//!    Eq. 4 — integer mantissa multiply-accumulate within each block pair
//!    plus a single shared-exponent add, no per-element shifting. Exact
//!    agreement with path 1 (up to f32 summation order) justifies the
//!    arithmetic-density numbers of Table 6.

use super::block::block_ranges;
use super::config::{GemmQuant, QFormat};
use crate::tensor::matmul::{matmul, matmul_bt};
use crate::tensor::Tensor;

/// `act [m,k] @ weight [k,n]` with both operands fake-quantised.
/// Blocks run along the contraction dim: rows of `act`, columns of `weight`
/// (i.e. rows of `weight`ᵀ) — the paper's "slice along matrix row".
pub fn qmatmul(act: &Tensor, weight: &Tensor, q: GemmQuant) -> Tensor {
    let qa = super::fake_quant(act, q.act);
    // quantise weight along its k dimension: transpose, quantise rows, use B^T GEMM
    match q.weight {
        QFormat::Fp32 => matmul(&qa, weight),
        _ => {
            let wt = weight.t();
            let qwt = super::fake_quant(&wt, q.weight);
            matmul_bt(&qa, &qwt)
        }
    }
}

/// Same as [`qmatmul`] but the weight is already transposed ([n, k]) and
/// possibly pre-quantised — the layout the model's weight cache uses so the
/// per-token hot path never re-transposes or re-quantises weights.
pub fn qmatmul_pret(act: &Tensor, weight_t_quantised: &Tensor, act_fmt: QFormat) -> Tensor {
    let qa = super::fake_quant(act, act_fmt);
    matmul_bt(&qa, weight_t_quantised)
}

/// Activation-side in-place variant to avoid the clone in the hot loop.
pub fn qmatmul_pret_inplace(act: &mut Tensor, weight_t_quantised: &Tensor, act_fmt: QFormat) -> Tensor {
    super::fake_quant_in_place(act, act_fmt);
    matmul_bt(act, weight_t_quantised)
}

/// Integer-domain BFP GEMM (Eq. 4): `act [m,k] @ weight_t [n,k]`.
/// Both operands are BFP-encoded per block of `n_blk` along k; each block
/// pair contributes `2^(ea+eb) * Σ ma·mb` with a single exponent add.
pub fn bfp_matmul_blocked(
    act: &Tensor,
    weight_t: &Tensor,
    e_bits: u32,
    m_bits: u32,
    n_blk: usize,
) -> Tensor {
    let (m, k) = act.dims2();
    let (n, k2) = weight_t.dims2();
    assert_eq!(k, k2);
    // encode rows once
    let enc_rows = |t: &Tensor| -> Vec<(Vec<i32>, Vec<i32>)> {
        // per row: (block exponents, mantissas)
        (0..t.shape[0])
            .map(|i| {
                let row = t.row(i);
                let mut es = Vec::new();
                let mut ms = Vec::with_capacity(k);
                for (s, e) in block_ranges(k, n_blk) {
                    let (be, bm) = super::bfp::bfp_encode_block(&row[s..e], e_bits, m_bits);
                    es.push(be);
                    ms.extend(bm);
                }
                (es, ms)
            })
            .collect()
    };
    let a_enc = enc_rows(act);
    let w_enc = enc_rows(weight_t);
    let mut out = vec![0.0f32; m * n];
    let blocks: Vec<(usize, usize)> = block_ranges(k, n_blk).collect();
    for i in 0..m {
        let (ae, am) = &a_enc[i];
        for j in 0..n {
            let (we, wm) = &w_enc[j];
            let mut acc = 0.0f64;
            for (bi, &(s, e)) in blocks.iter().enumerate() {
                // integer MAC within the block — the cheap ASIC inner loop
                let mut isum: i64 = 0;
                for t in s..e {
                    isum += am[t] as i64 * wm[t] as i64;
                }
                // one shared-exponent scale per block pair
                let shift = (ae[bi] + we[bi]) - 2 * (m_bits as i32 - 1);
                acc += isum as f64 * exp2i_f64(shift);
            }
            out[i * n + j] = acc as f32;
        }
    }
    Tensor::new(&[m, n], out)
}

#[inline]
fn exp2i_f64(k: i32) -> f64 {
    (2.0f64).powi(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;
    use crate::util::check::{check, close_slice, llmish_values};

    #[test]
    fn fp32_qmatmul_is_plain_matmul() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let a = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let b = Tensor::randn(&[8, 5], 1.0, &mut rng);
        let q = qmatmul(&a, &b, GemmQuant::fp32());
        let p = matmul(&a, &b);
        close_slice(&q.data, &p.data, 1e-6, "fp32").unwrap();
    }

    #[test]
    fn block_domain_matches_fake_quant_path() {
        check("bfp eq4 == fake-quant", 20, |rng| {
            let (m, k, n) = (2 + rng.below(4), 32, 2 + rng.below(4));
            let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
            let w = Tensor::new(&[n, k], llmish_values(rng, n * k, 0.3, 0.0));
            let fmt = presets::bfp_w(6);
            let (e, mb, nb) = match fmt {
                QFormat::Bfp { e, m, n } => (e, m, n as usize),
                _ => unreachable!(),
            };
            let fake = {
                let qa = crate::quant::fake_quant(&a, fmt);
                let qw = crate::quant::fake_quant(&w, fmt);
                matmul_bt(&qa, &qw)
            };
            let blocked = bfp_matmul_blocked(&a, &w, e, mb, nb);
            close_slice(&fake.data, &blocked.data, 1e-5, "eq4")
        });
    }

    #[test]
    fn pret_matches_direct() {
        check("pret == direct", 20, |rng| {
            let (m, k, n) = (3, 16, 4);
            let a = Tensor::new(&[m, k], llmish_values(rng, m * k, 1.0, 0.05));
            let w = Tensor::new(&[k, n], llmish_values(rng, k * n, 0.3, 0.0));
            let fmt = presets::bfp_w(6);
            let direct = qmatmul(&a, &w, GemmQuant::uniform(fmt));
            let wt_q = crate::quant::fake_quant(&w.t(), fmt);
            let pret = qmatmul_pret(&a, &wt_q, fmt);
            close_slice(&direct.data, &pret.data, 1e-6, "pret")
        });
    }

    #[test]
    fn quantised_gemm_error_shrinks_with_bits() {
        let mut rng = crate::util::rng::Pcg32::new(9);
        let a = Tensor::new(&[8, 64], llmish_values(&mut rng, 512, 1.0, 0.02));
        let w = Tensor::new(&[64, 8], llmish_values(&mut rng, 512, 0.3, 0.0));
        let exact = matmul(&a, &w);
        let err = |bits| {
            let q = qmatmul(&a, &w, GemmQuant::uniform(presets::bfp_w(bits)));
            crate::util::stats::mse(&exact.data, &q.data)
        };
        let (e4, e6, e8) = (err(4), err(6), err(8));
        assert!(e8 < e6 && e6 < e4, "{e4} {e6} {e8}");
    }
}
