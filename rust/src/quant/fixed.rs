//! Plain fixed-point quantisation (the paper's failing baseline, Table 3).
//!
//! Per-tensor symmetric absmax scaling: `scale = absmax / (2^(W-1) - 1)`,
//! `q = clamp(round(x / scale))`. W8A8 corresponds to M=7 (+ sign) in the
//! paper's Table 2. This is *linear* quantisation — a single scaling factor
//! for the whole tensor — and is exactly what scaling offsets break.

/// Quantise a buffer in place with a given word length W (including sign).
/// Returns the scale used (for inspection / packed storage).
pub fn fixed_fake_quant(data: &mut [f32], w_bits: u32) -> f32 {
    assert!(w_bits >= 2 && w_bits <= 24);
    let qmax = ((1i64 << (w_bits - 1)) - 1) as f32;
    let absmax = crate::quant::block::block_absmax(data);
    if absmax == 0.0 {
        return 0.0;
    }
    let scale = absmax / qmax;
    let inv = 1.0 / scale;
    for x in data.iter_mut() {
        if x.is_nan() {
            *x = 0.0;
            continue;
        }
        let q = (*x * inv).round_ties_even().clamp(-qmax, qmax);
        *x = q * scale;
    }
    scale
}

/// Integer codes + scale (for packed storage / integer-domain kernels).
pub fn fixed_encode(data: &[f32], w_bits: u32) -> (Vec<i32>, f32) {
    let qmax = ((1i64 << (w_bits - 1)) - 1) as f32;
    let absmax = crate::quant::block::block_absmax(data);
    if absmax == 0.0 {
        return (vec![0; data.len()], 0.0);
    }
    let scale = absmax / qmax;
    let inv = 1.0 / scale;
    let codes = data
        .iter()
        .map(|&x| {
            if x.is_nan() {
                0
            } else {
                (x * inv).round_ties_even().clamp(-qmax, qmax) as i32
            }
        })
        .collect();
    (codes, scale)
}

pub fn fixed_decode(codes: &[i32], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, llmish_values};

    #[test]
    fn preserves_absmax() {
        let mut xs = vec![0.5, -2.0, 1.0];
        fixed_fake_quant(&mut xs, 8);
        assert_eq!(xs[1], -2.0); // absmax maps exactly
    }

    #[test]
    fn zero_tensor() {
        let mut xs = vec![0.0; 4];
        assert_eq!(fixed_fake_quant(&mut xs, 8), 0.0);
        assert!(xs.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn encode_decode_roundtrip() {
        check("fixed enc/dec == fake", 100, |rng| {
            let xs = llmish_values(rng, 64, 1.0, 0.05);
            let mut fake = xs.clone();
            fixed_fake_quant(&mut fake, 8);
            let (codes, scale) = fixed_encode(&xs, 8);
            let dec = fixed_decode(&codes, scale);
            crate::util::check::close_slice(&fake, &dec, 1e-6, "fixed")
        });
    }

    #[test]
    fn outliers_crush_inliers() {
        // the paper's core failure mode: one outlier destroys resolution
        let mut xs = vec![0.01, -0.02, 0.015, 100.0];
        fixed_fake_quant(&mut xs, 8);
        // inliers collapse to 0 because step = 100/127 ≈ 0.79
        assert_eq!(xs[0], 0.0);
        assert_eq!(xs[1], 0.0);
        assert_eq!(xs[3], 100.0);
    }

    #[test]
    fn idempotent() {
        check("fixed idempotent-ish", 50, |rng| {
            let xs = llmish_values(rng, 32, 1.0, 0.0);
            let mut q1 = xs.clone();
            fixed_fake_quant(&mut q1, 8);
            let mut q2 = q1.clone();
            fixed_fake_quant(&mut q2, 8);
            crate::util::check::close_slice(&q1, &q2, 1e-5, "idem")
        });
    }
}

#[cfg(test)]
mod fixedrow_tests {
    use crate::quant::config::QFormat;
    use crate::quant::fake_quant;
    use crate::util::check::{check, close_slice, llmish_values};
    use crate::Tensor;

    #[test]
    fn per_row_scales_are_independent() {
        // an outlier in row 0 must not affect row 1 (unlike per-tensor Fixed)
        let mut data = vec![0.01f32; 16];
        data[0] = 100.0;
        let mut t = Tensor::new(&[2, 8], data);
        t.row_mut(1).copy_from_slice(&[0.01; 8]);
        let q_row = fake_quant(&t, QFormat::FixedRow { w: 8 });
        let q_tensor = fake_quant(&t, QFormat::Fixed { w: 8 });
        assert!(q_row.row(1)[3] > 0.0, "row 1 survived under per-row scales");
        assert_eq!(q_tensor.row(1)[3], 0.0, "row 1 crushed under per-tensor");
    }

    #[test]
    fn fixedrow_idempotent_and_packs() {
        check("fixedrow idempotent+pack", 40, |rng| {
            let t = Tensor::new(&[3, 16], llmish_values(rng, 48, 1.0, 0.05));
            let fmt = QFormat::FixedRow { w: 8 };
            let q1 = fake_quant(&t, fmt);
            let q2 = fake_quant(&q1, fmt);
            close_slice(&q1.data, &q2.data, 1e-6, "idem")?;
            let dec = crate::quant::qtensor::decode(&crate::quant::qtensor::encode(&t, fmt));
            close_slice(&q1.data, &dec.data, 1e-6, "pack")
        });
    }

    #[test]
    fn parse_roundtrip_fixedrow() {
        let f = QFormat::FixedRow { w: 4 };
        assert_eq!(QFormat::parse(&f.name()), Some(f));
    }
}
