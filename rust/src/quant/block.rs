//! Block partitioning along the contraction dimension.
//!
//! The paper's block shape is `[1, N]` — a slice along a matrix row (the
//! token/channel vector), i.e. contiguous runs of N values in the last
//! dimension. Blocks never straddle rows; a short tail block is allowed.

/// Iterate (start, end) block ranges over one row of length `cols`.
#[inline]
pub fn block_ranges(cols: usize, block: usize) -> impl Iterator<Item = (usize, usize)> {
    let block = block.max(1);
    (0..cols.div_ceil(block)).map(move |b| (b * block, ((b + 1) * block).min(cols)))
}

/// Number of blocks per row.
#[inline]
pub fn blocks_per_row(cols: usize, block: usize) -> usize {
    cols.div_ceil(block.max(1))
}

/// Max |x| over a slice (0.0 for empty / all-NaN; NaN are skipped).
#[inline]
pub fn block_absmax(xs: &[f32]) -> f32 {
    let mut m = 0.0f32;
    for &x in xs {
        let a = x.abs();
        if a.is_finite() && a > m {
            m = a;
        } else if a.is_infinite() {
            return f32::MAX;
        }
    }
    m
}

/// Apply `f(block_slice)` to every [1, N] block of a row-major [rows, cols]
/// buffer, mutating in place.
pub fn for_each_block_mut(
    data: &mut [f32],
    cols: usize,
    block: usize,
    mut f: impl FnMut(&mut [f32]),
) {
    assert_eq!(data.len() % cols.max(1), 0);
    for row in data.chunks_mut(cols) {
        for (s, e) in block_ranges(cols, block) {
            f(&mut row[s..e]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_row() {
        let rs: Vec<_> = block_ranges(10, 4).collect();
        assert_eq!(rs, vec![(0, 4), (4, 8), (8, 10)]);
        assert_eq!(blocks_per_row(10, 4), 3);
        assert_eq!(blocks_per_row(16, 16), 1);
    }

    #[test]
    fn absmax_skips_nan() {
        assert_eq!(block_absmax(&[1.0, -3.0, f32::NAN, 2.0]), 3.0);
        assert_eq!(block_absmax(&[]), 0.0);
        assert_eq!(block_absmax(&[f32::INFINITY]), f32::MAX);
    }

    #[test]
    fn for_each_visits_all() {
        let mut data = vec![1.0f32; 12]; // 2 rows x 6 cols
        let mut count = 0;
        for_each_block_mut(&mut data, 6, 4, |b| {
            count += 1;
            for x in b.iter_mut() {
                *x = 2.0;
            }
        });
        assert_eq!(count, 4); // 2 blocks per row (4 + 2)
        assert!(data.iter().all(|&x| x == 2.0));
    }
}
