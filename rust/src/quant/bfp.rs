//! Block Floating-Point (BFP) — the paper's winning format (Table 3-5).
//!
//! MSFP convention (Darvish Rouhani et al. 2020): each block of N values
//! shares an E-bit exponent set by the block max; elements carry sign +
//! M-bit mantissa. `scale = 2^(emax - M + 1)`, `m = clamp(round(|x|/scale),
//! 0, 2^M - 1)`, value `= ±m·scale`. Bits/element = 1 + M + E/N.

use super::block::{block_absmax, for_each_block_mut};
use super::minifloat::{exp2i, ilogb};

/// Shared-exponent field for a block, clamped to the biased E-bit range.
/// Returns the *unbiased* effective exponent.
#[inline]
pub fn shared_exponent(absmax: f32, e_bits: u32) -> i32 {
    let bias = (1i32 << (e_bits - 1)) - 1;
    let emax_field = (1i32 << e_bits) - 1;
    if absmax == 0.0 {
        return -bias; // e_field = 0
    }
    let e_unb = ilogb(absmax);
    (e_unb + bias).clamp(0, emax_field) - bias
}

/// Quantise one block in place. Returns the shared exponent used.
#[inline]
pub fn bfp_quant_block(block: &mut [f32], e_bits: u32, m_bits: u32) -> i32 {
    let absmax = block_absmax(block);
    let e = shared_exponent(absmax, e_bits);
    if absmax == 0.0 {
        for x in block.iter_mut() {
            *x = 0.0;
        }
        return e;
    }
    let scale = exp2i(e - m_bits as i32 + 1);
    let inv = 1.0 / scale;
    let mmax = ((1u64 << m_bits) - 1) as f32;
    for x in block.iter_mut() {
        if x.is_nan() {
            *x = 0.0;
            continue;
        }
        let sign = if *x < 0.0 { -1.0 } else { 1.0 };
        let m = (x.abs() * inv).round_ties_even().min(mmax);
        *x = sign * m * scale;
    }
    e
}

/// Fake-quantise a row-major [rows, cols] buffer with [1, N] blocks.
pub fn bfp_fake_quant(data: &mut [f32], cols: usize, block: usize, e_bits: u32, m_bits: u32) {
    // Hot path (EXPERIMENTS.md §Perf): when rows are block-aligned, take a
    // branch-light lane — `f32::max` ignores NaN so the absmax reduction
    // vectorises, and NaN handling collapses into one select per element.
    if cols % block == 0 && block >= 4 {
        let mmax = ((1u64 << m_bits) - 1) as f32;
        for blk in data.chunks_mut(block) {
            let mut mx = 0.0f32;
            for &x in blk.iter() {
                mx = mx.max(x.abs()); // max(a, NaN) == a
            }
            if mx == 0.0 {
                for x in blk.iter_mut() {
                    *x = 0.0;
                }
                continue;
            }
            if !mx.is_finite() {
                mx = f32::MAX;
            }
            let e = shared_exponent(mx, e_bits);
            let scale = exp2i(e - m_bits as i32 + 1);
            let inv = 1.0 / scale;
            for x in blk.iter_mut() {
                let ax = x.abs() * inv;
                // NaN → 0 (matches the slow path and the python oracle)
                let m = if ax.is_nan() {
                    0.0
                } else {
                    ax.round_ties_even().min(mmax)
                };
                *x = if *x < 0.0 { -m * scale } else { m * scale };
            }
        }
        return;
    }
    for_each_block_mut(data, cols, block, |b| {
        bfp_quant_block(b, e_bits, m_bits);
    });
}

/// Integer-domain encoding of one block: (shared exponent, signed mantissas).
/// `value = m * 2^(e - M + 1)`. This is the ASIC datapath representation
/// used by [`crate::quant::qmatmul::bfp_matmul_blocked`] (paper Eq. 4).
pub fn bfp_encode_block(block: &[f32], e_bits: u32, m_bits: u32) -> (i32, Vec<i32>) {
    let absmax = block_absmax(block);
    let e = shared_exponent(absmax, e_bits);
    let mmax = ((1u64 << m_bits) - 1) as f32;
    if absmax == 0.0 {
        return (e, vec![0; block.len()]);
    }
    let inv = 1.0 / exp2i(e - m_bits as i32 + 1);
    let ms = block
        .iter()
        .map(|&x| {
            if x.is_nan() {
                return 0;
            }
            let m = (x.abs() * inv).round_ties_even().min(mmax) as i32;
            if x < 0.0 {
                -m
            } else {
                m
            }
        })
        .collect();
    (e, ms)
}

pub fn bfp_decode_block(e: i32, ms: &[i32], m_bits: u32) -> Vec<f32> {
    let scale = exp2i(e - m_bits as i32 + 1);
    ms.iter().map(|&m| m as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, close_slice, llmish_values};

    #[test]
    fn block_max_nearly_preserved() {
        // max element error bounded by scale/2
        let mut b = vec![1.9, 0.1, -0.5, 0.0];
        bfp_quant_block(&mut b, 8, 5);
        // emax = 0, scale = 2^-4 = 0.0625
        assert!((b[0] - 1.9).abs() <= 0.0625 / 2.0 + 1e-7, "{b:?}");
        assert_eq!(b[3], 0.0);
    }

    #[test]
    fn error_bound_half_step() {
        check("bfp err <= scale/2 in range", 200, |rng| {
            let xs = llmish_values(rng, 16, 1.0, 0.1);
            let mut q = xs.clone();
            let e = bfp_quant_block(&mut q, 8, 5);
            let scale = exp2i(e - 5 + 1);
            let mmax = 31.0f32; // 2^5 - 1
            for (i, (&x, &y)) in xs.iter().zip(&q).enumerate() {
                // elements within the top half-step of the mantissa ceiling
                // saturate to (2^M-1)*scale: error there can reach one step
                let bound = if x.abs() > (mmax - 0.5) * scale {
                    scale
                } else {
                    scale / 2.0
                };
                let err = (x - y).abs();
                if err > bound + 1e-6 {
                    return Err(format!("i={i} x={x} q={y} err={err} scale={scale}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn encode_decode_matches_fake_quant() {
        check("bfp enc/dec == fake", 200, |rng| {
            let n = 1 + rng.below(32);
            let xs = llmish_values(rng, n, 2.0, 0.1);
            let mut fake = xs.clone();
            bfp_quant_block(&mut fake, 8, 3);
            let (e, ms) = bfp_encode_block(&xs, 8, 3);
            let dec = bfp_decode_block(e, &ms, 3);
            close_slice(&fake, &dec, 0.0, "bfp")
        });
    }

    #[test]
    fn idempotent() {
        check("bfp idempotent", 200, |rng| {
            let xs = llmish_values(rng, 16, 1.0, 0.05);
            let mut q1 = xs.clone();
            bfp_quant_block(&mut q1, 8, 5);
            let mut q2 = q1.clone();
            bfp_quant_block(&mut q2, 8, 5);
            close_slice(&q1, &q2, 0.0, "idem")
        });
    }

    #[test]
    fn outlier_crushes_block_but_not_neighbours() {
        // scaling offsets are *local* under BFP: an outlier only affects its
        // own block of 16 — the paper's whole point.
        let mut data: Vec<f32> = vec![0.01; 32];
        data[0] = 100.0;
        bfp_fake_quant(&mut data, 32, 16, 8, 3);
        // block 0: scale = 2^(6-3+1)=16 → 0.01 → 0
        assert_eq!(data[1], 0.0);
        // block 1: small values survive
        assert!(data[20] > 0.0, "{}", data[20]);
    }

    #[test]
    fn mantissa_width_improves_error() {
        let mut rng = crate::util::rng::Pcg32::new(7);
        let xs = llmish_values(&mut rng, 1024, 1.0, 0.02);
        let err = |m_bits| {
            let mut q = xs.clone();
            bfp_fake_quant(&mut q, 1024, 16, 8, m_bits);
            crate::util::stats::mse(&xs, &q)
        };
        let (e3, e5, e7) = (err(3), err(5), err(7));
        assert!(e7 < e5 && e5 < e3, "{e3} {e5} {e7}");
    }

    #[test]
    fn shared_exponent_clamps() {
        // E=4 → bias 7, field range [0,15] → effective [-7, 8]
        assert_eq!(shared_exponent(exp2i(20), 4), 8);
        assert_eq!(shared_exponent(exp2i(-20), 4), -7);
        assert_eq!(shared_exponent(0.0, 4), -7);
    }
}
