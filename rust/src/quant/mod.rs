//! Quantisation arithmetic (paper §3.1, Appendix C).
//!
//! Six formats, one entry point: [`fake_quant`] rounds every element of a
//! tensor to its representable set (keeping f32 storage — the evaluation
//! semantics used throughout the paper), and [`qtensor`] provides the
//! actually-packed representation used for memory-density accounting and
//! the integer-domain BFP dot product (Eq. 4) in [`qmatmul`].

pub mod bfp;
pub mod bl;
pub mod block;
pub mod bm;
pub mod config;
pub mod fixed;
pub mod minifloat;
pub mod outlier;
pub mod qmatmul;
pub mod qtensor;

pub use config::{GemmQuant, QFormat};

use crate::tensor::Tensor;

/// Fake-quantise a flat buffer laid out as [rows, cols].
pub fn fake_quant_buffer(data: &mut [f32], cols: usize, fmt: QFormat) {
    match fmt {
        QFormat::Fp32 => {}
        QFormat::Fixed { w } => {
            fixed::fixed_fake_quant(data, w);
        }
        QFormat::FixedRow { w } => {
            for row in data.chunks_mut(cols.max(1)) {
                fixed::fixed_fake_quant(row, w);
            }
        }
        QFormat::MiniFloat { e, m } => {
            let bias = (1i32 << (e - 1)) - 1;
            for x in data.iter_mut() {
                *x = minifloat::round_minifloat(*x, e, m, bias);
            }
        }
        QFormat::Dmf { e, m } => {
            let bias = (1i32 << (e - 1)) - 1;
            for x in data.iter_mut() {
                *x = minifloat::round_dmf(*x, e, m, bias);
            }
        }
        QFormat::Bfp { e, m, n } => bfp::bfp_fake_quant(data, cols, n as usize, e, m),
        QFormat::Bm { e, m, b, n } => bm::bm_fake_quant(data, cols, n as usize, e, m, b),
        QFormat::Bl { e, b, n } => bl::bl_fake_quant(data, cols, n as usize, e, b),
    }
}

/// Fake-quantise a tensor (blocks run along the last dimension).
pub fn fake_quant(t: &Tensor, fmt: QFormat) -> Tensor {
    let mut out = t.clone();
    fake_quant_in_place(&mut out, fmt);
    out
}

pub fn fake_quant_in_place(t: &mut Tensor, fmt: QFormat) {
    let cols = *t.shape.last().unwrap_or(&1);
    fake_quant_buffer(&mut t.data, cols, fmt);
}

/// Quantise an activation for a GEMM site: pass-through for fp32, else
/// [`fake_quant`]. The closure every forward path used to inline.
pub fn quant_act(t: &Tensor, fmt: QFormat) -> Tensor {
    if fmt == QFormat::Fp32 {
        t.clone()
    } else {
        fake_quant(t, fmt)
    }
}

/// Row-independent fake-quant: each row of a [rows, cols] tensor is
/// quantised as if it were its own [1, cols] tensor. Identical to
/// [`fake_quant`] for every format whose scales never cross a row (all the
/// block formats, per-row fixed point, and the element-wise minifloats);
/// for per-tensor `Fixed` it re-derives the absmax scale per row. This is
/// what makes a batched decode step bit-identical to the sequential one:
/// each sequence's activation row quantises exactly as it would alone.
pub fn fake_quant_rows(t: &Tensor, fmt: QFormat) -> Tensor {
    let mut out = t.clone();
    fake_quant_rows_in_place(&mut out, fmt);
    out
}

pub fn fake_quant_rows_in_place(t: &mut Tensor, fmt: QFormat) {
    let cols = (*t.shape.last().unwrap_or(&1)).max(1);
    for row in t.data.chunks_mut(cols) {
        fake_quant_buffer(row, cols, fmt);
    }
}

/// Row-independent counterpart of [`quant_act`] for batched decode.
pub fn quant_act_rows(t: &Tensor, fmt: QFormat) -> Tensor {
    if fmt == QFormat::Fp32 {
        t.clone()
    } else {
        fake_quant_rows(t, fmt)
    }
}

#[cfg(test)]
mod tests {
    use super::config::presets;
    use super::*;
    use crate::util::check::{check, llmish_values};
    use crate::util::stats::sqnr_db;

    #[test]
    fn fp32_is_identity() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let t = Tensor::randn(&[4, 8], 1.0, &mut rng);
        assert_eq!(fake_quant(&t, QFormat::Fp32), t);
    }

    #[test]
    fn all_formats_idempotent() {
        for (name, fmt) in presets::table3_formats() {
            check(&format!("idempotent {name}"), 40, |rng| {
                let xs = llmish_values(rng, 64, 1.0, 0.05);
                let t = Tensor::new(&[2, 32], xs);
                let q1 = fake_quant(&t, fmt);
                let q2 = fake_quant(&q1, fmt);
                // Fixed re-derives the scale from the quantised absmax, which
                // is preserved exactly, so this holds for every format.
                crate::util::check::close_slice(&q1.data, &q2.data, 1e-6, name)
            });
        }
    }

    #[test]
    fn sqnr_ordering_on_llmish_data() {
        // On outlier-heavy data, block formats beat per-tensor fixed point —
        // the paper's central claim, at the signal level.
        let mut rng = crate::util::rng::Pcg32::new(42);
        let xs = llmish_values(&mut rng, 8192, 1.0, 0.01);
        let t = Tensor::new(&[8, 1024], xs);
        let sq = |fmt| sqnr_db(&t.data, &fake_quant(&t, fmt).data);
        let fixed = sq(presets::fixed8());
        let bfp8 = sq(presets::bfp_w(8));
        let bfp6 = sq(presets::bfp_w(6));
        let mini = sq(presets::minifloat8());
        assert!(bfp8 > fixed + 3.0, "bfp8={bfp8} fixed={fixed}");
        assert!(bfp6 > fixed, "bfp6={bfp6} fixed={fixed}");
        assert!(mini > fixed, "mini={mini} fixed={fixed}");
    }

    #[test]
    fn row_wise_quant_matches_per_row_tensors() {
        // fake_quant_rows on [m, cols] must equal fake_quant applied to each
        // row separately — including per-tensor Fixed, where the joint scale
        // would differ
        let mut formats = presets::table3_formats();
        formats.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
        for (name, fmt) in formats {
            check(&format!("rowwise {name}"), 15, |rng| {
                let cols = 3 + rng.below(40);
                let rows = 1 + rng.below(6);
                let t = Tensor::new(&[rows, cols], llmish_values(rng, rows * cols, 1.0, 0.05));
                let batched = fake_quant_rows(&t, fmt);
                for i in 0..rows {
                    let ti = Tensor::new(&[1, cols], t.data[i * cols..(i + 1) * cols].to_vec());
                    let single = fake_quant(&ti, fmt);
                    crate::util::check::close_slice(
                        &batched.data[i * cols..(i + 1) * cols],
                        &single.data,
                        0.0,
                        &format!("{name} row {i}"),
                    )?;
                }
                Ok(())
            });
        }
    }

    #[test]
    fn quantisation_error_zero_mean_ish() {
        // RNE keeps the error roughly unbiased
        let mut rng = crate::util::rng::Pcg32::new(3);
        let xs = llmish_values(&mut rng, 16384, 1.0, 0.0);
        let t = Tensor::new(&[16, 1024], xs);
        let q = fake_quant(&t, presets::bfp_w(6));
        let err_mean: f64 = t
            .data
            .iter()
            .zip(&q.data)
            .map(|(&a, &b)| (a - b) as f64)
            .sum::<f64>()
            / t.numel() as f64;
        assert!(err_mean.abs() < 1e-3, "bias {err_mean}");
    }
}
