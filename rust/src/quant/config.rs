//! Quantisation configuration: formats, presets (paper Table 2), and the
//! per-GEMM plans used for uniform and mixed-precision quantisation.

use crate::util::json::Json;

/// A single-tensor quantisation spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QFormat {
    /// No quantisation (float32 pass-through).
    Fp32,
    /// Plain fixed-point, `w` total bits incl. sign (per-tensor absmax scale).
    Fixed { w: u32 },
    /// Per-row (per-token) fixed-point — ZeroQuant's dynamic activation
    /// quantisation (one absmax scale per row of the operand).
    FixedRow { w: u32 },
    /// MiniFloat(E, M), IEEE-style bias.
    MiniFloat { e: u32, m: u32 },
    /// Denormalised MiniFloat(E, M).
    Dmf { e: u32, m: u32 },
    /// Block Floating-Point: shared E-bit exponent over blocks of N.
    Bfp { e: u32, m: u32, n: u32 },
    /// Block MiniFloat: MiniFloat(E, M) with shared B-bit bias over N.
    Bm { e: u32, m: u32, b: u32, n: u32 },
    /// Block Logarithm: ±2^k with shared B-bit bias over N.
    Bl { e: u32, b: u32, n: u32 },
}

impl QFormat {
    /// Average storage bits per element, amortising shared fields over the
    /// block (paper §3.2; reproduces Table 3's memory-density column).
    pub fn bits_per_element(&self) -> f64 {
        match *self {
            QFormat::Fp32 => 32.0,
            QFormat::Fixed { w } | QFormat::FixedRow { w } => w as f64,
            QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => 1.0 + e as f64 + m as f64,
            QFormat::Bfp { e, m, n } => 1.0 + m as f64 + e as f64 / n as f64,
            QFormat::Bm { e, m, b, n } => 1.0 + e as f64 + m as f64 + b as f64 / n as f64,
            QFormat::Bl { e, b, n } => 1.0 + e as f64 + b as f64 / n as f64,
        }
    }

    /// Memory density relative to float32 (Table 3 column "Mem").
    pub fn memory_density(&self) -> f64 {
        32.0 / self.bits_per_element()
    }

    /// Nominal "word length" used in WxAy naming (sign+mantissa+exponent of
    /// the per-element payload).
    pub fn word_bits(&self) -> u32 {
        match *self {
            QFormat::Fp32 => 32,
            QFormat::Fixed { w } | QFormat::FixedRow { w } => w,
            QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => 1 + e + m,
            QFormat::Bfp { m, .. } => 1 + m,
            QFormat::Bm { e, m, .. } => 1 + e + m,
            QFormat::Bl { e, .. } => 1 + e,
        }
    }

    pub fn block_size(&self) -> u32 {
        match *self {
            QFormat::Bfp { n, .. } | QFormat::Bm { n, .. } | QFormat::Bl { n, .. } => n,
            _ => 1,
        }
    }

    pub fn name(&self) -> String {
        match *self {
            QFormat::Fp32 => "fp32".into(),
            QFormat::Fixed { w } => format!("fixed{w}"),
            QFormat::FixedRow { w } => format!("fixedrow{w}"),
            QFormat::MiniFloat { e, m } => format!("minifloat_e{e}m{m}"),
            QFormat::Dmf { e, m } => format!("dmf_e{e}m{m}"),
            QFormat::Bfp { e, m, n } => format!("bfp_e{e}m{m}n{n}"),
            QFormat::Bm { e, m, b, n } => format!("bm_e{e}m{m}b{b}n{n}"),
            QFormat::Bl { e, b, n } => format!("bl_e{e}b{b}n{n}"),
        }
    }

    /// Parse the `name()` form back (used by CLI / manifests).
    pub fn parse(s: &str) -> Option<QFormat> {
        fn field(s: &str, k: char) -> Option<u32> {
            let idx = s.find(k)?;
            let rest = &s[idx + 1..];
            let end = rest
                .find(|c: char| !c.is_ascii_digit())
                .unwrap_or(rest.len());
            rest[..end].parse().ok()
        }
        if s == "fp32" {
            return Some(QFormat::Fp32);
        }
        if let Some(w) = s.strip_prefix("fixedrow") {
            return Some(QFormat::FixedRow { w: w.parse().ok()? });
        }
        if let Some(w) = s.strip_prefix("fixed") {
            return Some(QFormat::Fixed { w: w.parse().ok()? });
        }
        if let Some(r) = s.strip_prefix("minifloat_") {
            return Some(QFormat::MiniFloat {
                e: field(r, 'e')?,
                m: field(r, 'm')?,
            });
        }
        if let Some(r) = s.strip_prefix("dmf_") {
            return Some(QFormat::Dmf {
                e: field(r, 'e')?,
                m: field(r, 'm')?,
            });
        }
        if let Some(r) = s.strip_prefix("bfp_") {
            return Some(QFormat::Bfp {
                e: field(r, 'e')?,
                m: field(r, 'm')?,
                n: field(r, 'n')?,
            });
        }
        if let Some(r) = s.strip_prefix("bm_") {
            return Some(QFormat::Bm {
                e: field(r, 'e')?,
                m: field(r, 'm')?,
                b: field(r, 'b')?,
                n: field(r, 'n')?,
            });
        }
        if let Some(r) = s.strip_prefix("bl_") {
            return Some(QFormat::Bl {
                e: field(r, 'e')?,
                b: field(r, 'b')?,
                n: field(r, 'n')?,
            });
        }
        None
    }

    pub fn to_json(&self) -> Json {
        Json::Str(self.name())
    }
}

/// Weight + activation format pair for one GEMM (the paper's WxAy).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GemmQuant {
    pub weight: QFormat,
    pub act: QFormat,
}

impl GemmQuant {
    pub fn fp32() -> Self {
        GemmQuant {
            weight: QFormat::Fp32,
            act: QFormat::Fp32,
        }
    }

    pub fn uniform(f: QFormat) -> Self {
        GemmQuant { weight: f, act: f }
    }
}

/// Paper Table 2 presets. `bfp_w(bits)` gives BFP with E=8, M=bits-1, N=16.
pub mod presets {
    use super::QFormat;

    pub const BLOCK: u32 = 16;

    pub fn fixed8() -> QFormat {
        QFormat::Fixed { w: 8 }
    }

    pub fn minifloat8() -> QFormat {
        QFormat::MiniFloat { e: 4, m: 3 }
    }

    pub fn dmf8() -> QFormat {
        QFormat::Dmf { e: 4, m: 3 }
    }

    /// BFP WxAx: E=8, M=x-1, block [1,16].
    pub fn bfp_w(bits: u32) -> QFormat {
        assert!(bits >= 2);
        QFormat::Bfp {
            e: 8,
            m: bits - 1,
            n: BLOCK,
        }
    }

    pub fn bm8() -> QFormat {
        QFormat::Bm {
            e: 4,
            m: 3,
            b: 8,
            n: BLOCK,
        }
    }

    pub fn bl8() -> QFormat {
        QFormat::Bl {
            e: 7,
            b: 8,
            n: BLOCK,
        }
    }

    /// ZeroQuant (Yao et al. 2022): W4 group-wise weights (per output
    /// channel) + dynamic per-token A8 — both expressed as per-row
    /// fixed-point on the operand layouts our GEMMs use. 8/8 GEMMs.
    pub fn zeroquant_w() -> QFormat {
        QFormat::FixedRow { w: 4 }
    }

    pub fn zeroquant_a() -> QFormat {
        QFormat::FixedRow { w: 8 }
    }

    /// The Table 3 PTQ sweep, in paper order (name, format).
    pub fn table3_formats() -> Vec<(&'static str, QFormat)> {
        vec![
            ("Fixed-point W8A8", fixed8()),
            ("MiniFloat W8A8", minifloat8()),
            ("DMF W8A8", dmf8()),
            ("BFP W8A8", bfp_w(8)),
            ("BFP W6A6", bfp_w(6)),
            ("BFP W4A4", bfp_w(4)),
            ("BM W8A8", bm8()),
            ("BL W8A8", bl8()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::presets::*;
    use super::*;

    #[test]
    fn memory_densities_match_table3() {
        // paper Table 3 Mem column
        assert!((fixed8().memory_density() - 4.0).abs() < 1e-9);
        assert!((minifloat8().memory_density() - 4.0).abs() < 1e-9);
        assert!((dmf8().memory_density() - 4.0).abs() < 1e-9);
        assert!((bfp_w(6).memory_density() - 4.92).abs() < 0.01); // "4.9×"
        assert!((bfp_w(4).memory_density() - 7.11).abs() < 0.01); // "7.1×"
        assert!((bm8().memory_density() - 3.76).abs() < 0.01); // "3.8×"
        assert!((bl8().memory_density() - 3.76).abs() < 0.01); // "3.8×"
        assert!((QFormat::Fp32.memory_density() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn word_bits_naming() {
        assert_eq!(bfp_w(6).word_bits(), 6);
        assert_eq!(bfp_w(4).word_bits(), 4);
        assert_eq!(minifloat8().word_bits(), 8);
        assert_eq!(bl8().word_bits(), 8);
    }

    #[test]
    fn parse_roundtrip() {
        for (_, f) in table3_formats() {
            assert_eq!(QFormat::parse(&f.name()), Some(f), "{}", f.name());
        }
        assert_eq!(QFormat::parse("fp32"), Some(QFormat::Fp32));
        assert_eq!(QFormat::parse("nonsense"), None);
    }
}
