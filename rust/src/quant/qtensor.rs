//! Packed quantised tensors — the bits actually stored/moved on an ASIC.
//!
//! `QTensor` bit-packs codes into a byte buffer so the memory-density
//! numbers in Table 3 are *measured* (packed bytes vs f32 bytes), not just
//! computed from the formula. Decode reproduces the fake-quant values
//! exactly; this is asserted by tests and used by the weight cache.

use super::block::{block_absmax, block_ranges};
use super::config::QFormat;
use super::minifloat::{exp2i, ilogb, round_dmf, round_minifloat};
use crate::tensor::Tensor;

/// Bit-level writer.
struct BitWriter {
    buf: Vec<u8>,
    bitpos: usize,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter {
            buf: Vec::new(),
            bitpos: 0,
        }
    }

    fn push(&mut self, value: u32, bits: u32) {
        debug_assert!(bits <= 32);
        for i in 0..bits {
            let bit = (value >> i) & 1;
            let byte = self.bitpos / 8;
            if byte >= self.buf.len() {
                self.buf.push(0);
            }
            self.buf[byte] |= (bit as u8) << (self.bitpos % 8);
            self.bitpos += 1;
        }
    }
}

/// Bit-level reader.
struct BitReader<'a> {
    buf: &'a [u8],
    bitpos: usize,
}

impl<'a> BitReader<'a> {
    fn read(&mut self, bits: u32) -> u32 {
        let mut v = 0u32;
        for i in 0..bits {
            let byte = self.bitpos / 8;
            let bit = (self.buf[byte] >> (self.bitpos % 8)) & 1;
            v |= (bit as u32) << i;
            self.bitpos += 1;
        }
        v
    }
}

/// A packed quantised tensor.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub shape: Vec<usize>,
    pub fmt: QFormat,
    pub payload: Vec<u8>,
    /// Per-tensor f32 scale (Fixed only).
    pub scale: f32,
}

impl QTensor {
    /// Packed size in bytes (payload only — the Table 3 accounting unit).
    pub fn packed_bytes(&self) -> usize {
        self.payload.len()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Measured bits per element.
    pub fn bits_per_element(&self) -> f64 {
        self.packed_bytes() as f64 * 8.0 / self.numel() as f64
    }
}

/// Encode (quantise + pack). Blocks run along the last dim.
pub fn encode(t: &Tensor, fmt: QFormat) -> QTensor {
    let cols = *t.shape.last().unwrap_or(&1);
    let mut w = BitWriter::new();
    let mut scale = 0.0f32;
    match fmt {
        QFormat::Fp32 => {
            for &x in &t.data {
                w.push(x.to_bits(), 32);
            }
        }
        QFormat::Fixed { w: wb } => {
            let (codes, s) = super::fixed::fixed_encode(&t.data, wb);
            scale = s;
            for c in codes {
                w.push((c as u32) & ((1u32 << wb) - 1), wb);
            }
        }
        QFormat::FixedRow { w: wb } => {
            // per-row scale stored inline as 32 bits (amortised over the row)
            for row in t.data.chunks(cols.max(1)) {
                let (codes, s) = super::fixed::fixed_encode(row, wb);
                w.push(s.to_bits(), 32);
                for c in codes {
                    w.push((c as u32) & ((1u32 << wb) - 1), wb);
                }
            }
        }
        QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => {
            let bias = (1i32 << (e - 1)) - 1;
            let dmf = matches!(fmt, QFormat::Dmf { .. });
            for &x in &t.data {
                let q = if dmf {
                    round_dmf(x, e, m, bias)
                } else {
                    round_minifloat(x, e, m, bias)
                };
                let (s, ef, mf) = float_fields(q, e, m, bias, dmf);
                w.push(s, 1);
                w.push(ef, e);
                w.push(mf, m);
            }
        }
        QFormat::Bfp { e, m, n } => {
            for row in t.data.chunks(cols) {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let (sh_e, ms) = super::bfp::bfp_encode_block(&row[s0..e0], e, m);
                    let bias = (1i32 << (e - 1)) - 1;
                    w.push((sh_e + bias) as u32, e);
                    for mm in ms {
                        w.push((mm < 0) as u32, 1);
                        w.push(mm.unsigned_abs(), m);
                    }
                }
            }
        }
        QFormat::Bm { e, m, b, n } => {
            for row in t.data.chunks(cols) {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let blk = &row[s0..e0];
                    let bias = super::bm::shared_bias(block_absmax(blk), e, b);
                    w.push((bias + (1i32 << (b - 1))) as u32, b);
                    for &x in blk {
                        let q = round_minifloat(x, e, m, bias);
                        let (s, ef, mf) = float_fields(q, e, m, bias, false);
                        w.push(s, 1);
                        w.push(ef, e);
                        w.push(mf, m);
                    }
                }
            }
        }
        QFormat::Bl { e, b, n } => {
            for row in t.data.chunks(cols) {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let blk = &row[s0..e0];
                    let bias = super::bm::shared_bias(block_absmax(blk), e, b);
                    w.push((bias + (1i32 << (b - 1))) as u32, b);
                    for &x in blk {
                        let q = super::bl::bl_round(x, e, bias);
                        let (s, ef) = if q == 0.0 {
                            (0, 0)
                        } else {
                            ((q < 0.0) as u32, (ilogb(q.abs()) + bias) as u32)
                        };
                        w.push(s, 1);
                        w.push(ef, e);
                    }
                }
            }
        }
    }
    QTensor {
        shape: t.shape.clone(),
        fmt,
        payload: w.buf,
        scale,
    }
}

/// Field extraction for an already-rounded minifloat/DMF value.
fn float_fields(q: f32, e_bits: u32, m_bits: u32, bias: i32, dmf: bool) -> (u32, u32, u32) {
    if q == 0.0 {
        return (0, 0, 0);
    }
    let s = (q < 0.0) as u32;
    let aq = q.abs();
    let emax_field = (1i32 << e_bits) - 1;
    if dmf {
        // pick the smallest covering exponent (matches round_dmf's choice)
        let m_full = ((1u64 << m_bits) - 1) as f32;
        let mut ef = (ilogb(aq) + bias + 1).clamp(0, emax_field);
        while ef > 0 && aq <= m_full * exp2i(ef - 1 - bias - m_bits as i32) {
            ef -= 1;
        }
        let m = (aq / exp2i(ef - bias - m_bits as i32)).round() as u32;
        (s, ef as u32, m)
    } else {
        let e_unb = ilogb(aq);
        let ef = (e_unb + bias).clamp(0, emax_field);
        let m = if ef == 0 {
            (aq / exp2i(1 - bias - m_bits as i32)).round() as u32
        } else {
            ((aq / exp2i(ef - bias) - 1.0) * exp2i(m_bits as i32)).round() as u32
        };
        (s, ef as u32, m)
    }
}

/// Decode back to f32 (must equal the fake-quant values exactly).
pub fn decode(q: &QTensor) -> Tensor {
    let cols = *q.shape.last().unwrap_or(&1);
    let numel = q.numel();
    let mut r = BitReader {
        buf: &q.payload,
        bitpos: 0,
    };
    let mut out = Vec::with_capacity(numel);
    match q.fmt {
        QFormat::Fp32 => {
            for _ in 0..numel {
                out.push(f32::from_bits(r.read(32)));
            }
        }
        QFormat::Fixed { w } => {
            for _ in 0..numel {
                let raw = r.read(w);
                // sign-extend
                let shift = 32 - w;
                let c = ((raw << shift) as i32) >> shift;
                out.push(c as f32 * q.scale);
            }
        }
        QFormat::FixedRow { w } => {
            let rows = numel / cols.max(1);
            for _ in 0..rows {
                let s = f32::from_bits(r.read(32));
                for _ in 0..cols {
                    let raw = r.read(w);
                    let shift = 32 - w;
                    let c = ((raw << shift) as i32) >> shift;
                    out.push(c as f32 * s);
                }
            }
        }
        QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => {
            let bias = (1i32 << (e - 1)) - 1;
            let dmf = matches!(q.fmt, QFormat::Dmf { .. });
            for _ in 0..numel {
                let s = r.read(1);
                let ef = r.read(e) as i32;
                let mf = r.read(m);
                out.push(decode_float(s, ef, mf, m, bias, dmf));
            }
        }
        QFormat::Bfp { e, m, n } => {
            let rows = numel / cols.max(1);
            let bias = (1i32 << (e - 1)) - 1;
            for _ in 0..rows {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let sh_e = r.read(e) as i32 - bias;
                    let scale = exp2i(sh_e - m as i32 + 1);
                    for _ in s0..e0 {
                        let s = r.read(1);
                        let mm = r.read(m);
                        let v = mm as f32 * scale;
                        out.push(if s == 1 { -v } else { v });
                    }
                }
            }
        }
        QFormat::Bm { e, m, b, n } => {
            let rows = numel / cols.max(1);
            for _ in 0..rows {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let bias = r.read(b) as i32 - (1i32 << (b - 1));
                    for _ in s0..e0 {
                        let s = r.read(1);
                        let ef = r.read(e) as i32;
                        let mf = r.read(m);
                        out.push(decode_float(s, ef, mf, m, bias, false));
                    }
                }
            }
        }
        QFormat::Bl { e, b, n } => {
            let rows = numel / cols.max(1);
            for _ in 0..rows {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let bias = r.read(b) as i32 - (1i32 << (b - 1));
                    for _ in s0..e0 {
                        let s = r.read(1);
                        let ef = r.read(e) as i32;
                        let v = if ef == 0 { 0.0 } else { exp2i(ef - bias) };
                        out.push(if s == 1 { -v } else { v });
                    }
                }
            }
        }
    }
    Tensor::new(&q.shape, out)
}

fn decode_float(s: u32, ef: i32, mf: u32, m_bits: u32, bias: i32, dmf: bool) -> f32 {
    let frac = mf as f32 * exp2i(-(m_bits as i32));
    let v = if dmf {
        exp2i(ef - bias) * frac
    } else if ef == 0 {
        exp2i(1 - bias) * frac
    } else {
        exp2i(ef - bias) * (1.0 + frac)
    };
    if s == 1 {
        -v
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;
    use crate::quant::fake_quant;
    use crate::util::check::{check, close_slice, llmish_values};

    #[test]
    fn pack_roundtrips_all_formats() {
        for (name, fmt) in presets::table3_formats() {
            check(&format!("pack/unpack {name}"), 30, |rng| {
                let cols = 16 * (1 + rng.below(3));
                let rows = 1 + rng.below(4);
                let xs = llmish_values(rng, rows * cols, 1.0, 0.05);
                let t = Tensor::new(&[rows, cols], xs);
                let fake = fake_quant(&t, fmt);
                let packed = encode(&t, fmt);
                let dec = decode(&packed);
                close_slice(&fake.data, &dec.data, 0.0, name)
            });
        }
    }

    #[test]
    fn ragged_tail_block_roundtrips() {
        check("pack ragged", 30, |rng| {
            let t = Tensor::new(&[3, 21], llmish_values(rng, 63, 1.0, 0.05));
            for fmt in [presets::bfp_w(6), presets::bm8(), presets::bl8()] {
                let fake = fake_quant(&t, fmt);
                let dec = decode(&encode(&t, fmt));
                close_slice(&fake.data, &dec.data, 0.0, &fmt.name())?;
            }
            Ok(())
        });
    }

    #[test]
    fn measured_density_matches_formula() {
        let mut rng = crate::util::rng::Pcg32::new(2);
        // use a block-aligned shape so amortisation matches the formula
        let t = Tensor::randn(&[8, 256], 1.0, &mut rng);
        for (name, fmt) in presets::table3_formats() {
            let q = encode(&t, fmt);
            let measured = q.bits_per_element();
            let formula = fmt.bits_per_element();
            assert!(
                (measured - formula).abs() < 0.05 + 8.0 / t.numel() as f64,
                "{name}: measured {measured} vs formula {formula}"
            );
        }
    }

    #[test]
    fn fp32_pack_exact() {
        let mut rng = crate::util::rng::Pcg32::new(3);
        let t = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let dec = decode(&encode(&t, QFormat::Fp32));
        assert_eq!(t.data, dec.data);
    }
}
