//! Packed quantised tensors — the bits actually stored/moved on an ASIC.
//!
//! `QTensor` bit-packs codes into a byte buffer so the memory-density
//! numbers in Table 3 are *measured* (packed bytes vs f32 bytes), not just
//! computed from the formula. Decode reproduces the fake-quant values
//! exactly; this is asserted by tests and used by the weight cache.

use super::block::{block_absmax, block_ranges, blocks_per_row};
use super::config::QFormat;
use super::minifloat::{exp2i, ilogb, round_dmf, round_minifloat};
use crate::kernels;
use crate::tensor::Tensor;

/// Bit-level writer. Like [`BitReader::read`], `push` places a whole field
/// through a 64-bit little-endian window in one shot instead of looping bit
/// by bit — encode sits on every `set_plan` in the mixed-precision search
/// loop, so it gets the same treatment as the decode hot path.
struct BitWriter {
    buf: Vec<u8>,
    bitpos: usize,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter {
            buf: Vec::new(),
            bitpos: 0,
        }
    }

    fn push(&mut self, value: u32, bits: u32) {
        debug_assert!(bits <= 32);
        if bits == 0 {
            return;
        }
        // mask out any bits above the field width (the bit-serial loop only
        // ever consumed the low `bits` bits)
        let field = if bits == 32 {
            value as u64
        } else {
            value as u64 & ((1u64 << bits) - 1)
        };
        let byte = self.bitpos / 8;
        let off = (self.bitpos % 8) as u32;
        self.bitpos += bits as usize;
        self.buf.resize(self.bitpos.div_ceil(8), 0);
        // off ≤ 7 and bits ≤ 32, so the field spans at most 5 bytes — an
        // 8-byte window always covers it; bits past the write cursor are
        // still zero, so OR-ing the shifted field is exact
        let end = (byte + 8).min(self.buf.len());
        let mut tmp = [0u8; 8];
        tmp[..end - byte].copy_from_slice(&self.buf[byte..end]);
        let window = u64::from_le_bytes(tmp) | (field << off);
        self.buf[byte..end].copy_from_slice(&window.to_le_bytes()[..end - byte]);
    }
}

/// Bit-level reader. Fields are LSB-first within each byte (matching
/// [`BitWriter::push`]); `read` pulls a whole field from a 64-bit window
/// in one shot, which keeps the packed GEMM's dequant loop from being
/// bit-serial on the decode hot path.
struct BitReader<'a> {
    buf: &'a [u8],
    bitpos: usize,
}

impl<'a> BitReader<'a> {
    fn read(&mut self, bits: u32) -> u32 {
        debug_assert!(bits <= 32);
        let byte = self.bitpos / 8;
        let off = (self.bitpos % 8) as u32;
        // off ≤ 7 and bits ≤ 32, so the field spans at most 5 bytes — an
        // 8-byte little-endian window always covers it
        let mut tmp = [0u8; 8];
        let end = (byte + 8).min(self.buf.len());
        tmp[..end - byte].copy_from_slice(&self.buf[byte..end]);
        let window = u64::from_le_bytes(tmp);
        self.bitpos += bits as usize;
        ((window >> off) & ((1u64 << bits) - 1)) as u32
    }
}

/// A packed quantised tensor.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub shape: Vec<usize>,
    pub fmt: QFormat,
    pub payload: Vec<u8>,
    /// Per-tensor f32 scale (Fixed only).
    pub scale: f32,
}

impl QTensor {
    /// Packed size in bytes (payload only — the Table 3 accounting unit).
    pub fn packed_bytes(&self) -> usize {
        self.payload.len()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Measured bits per element.
    pub fn bits_per_element(&self) -> f64 {
        self.packed_bytes() as f64 * 8.0 / self.numel() as f64
    }

    /// Columns of the packed layout (the last dim; blocks run along it).
    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    /// Rows of the packed layout (all leading dims collapsed).
    pub fn rows(&self) -> usize {
        self.numel() / self.cols().max(1)
    }

    /// Exact packed bits per row. Every format packs rows independently at
    /// a fixed width (shared fields included), which is what makes O(1)
    /// row seeks — and therefore the fused packed GEMM — possible.
    pub fn row_bits(&self) -> usize {
        let cols = self.cols();
        match self.fmt {
            QFormat::Fp32 => 32 * cols,
            QFormat::Fixed { w } => w as usize * cols,
            QFormat::FixedRow { w } => 32 + w as usize * cols,
            QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => {
                (1 + e + m) as usize * cols
            }
            QFormat::Bfp { e, m, n } => {
                blocks_per_row(cols, n as usize) * e as usize + cols * (1 + m as usize)
            }
            QFormat::Bm { e, m, b, n } => {
                blocks_per_row(cols, n as usize) * b as usize
                    + cols * (1 + e as usize + m as usize)
            }
            QFormat::Bl { e, b, n } => {
                blocks_per_row(cols, n as usize) * b as usize + cols * (1 + e as usize)
            }
        }
    }

    /// Decode one row into `out` (`out.len() == cols`), one block at a
    /// time from the packed payload — the primitive under
    /// [`crate::quant::qmatmul::qmatmul_packed`]. Bit-identical to the
    /// corresponding slice of [`decode`].
    pub fn decode_row_into(&self, row: usize, out: &mut [f32]) {
        debug_assert!(row < self.rows());
        debug_assert_eq!(out.len(), self.cols());
        let mut r = BitReader {
            buf: &self.payload,
            bitpos: row * self.row_bits(),
        };
        decode_row(&mut r, self.fmt, self.scale, out);
    }

    /// Whether [`QTensor::dot_row`] supports this tensor. The fused dot
    /// needs every field chunk to start on a lane-aligned column index:
    /// Fixed/FixedRow stream in 64-wide slabs from column 0, and Bfp block
    /// starts are multiples of the block size, so those qualify whenever
    /// the block size is a multiple of the lane count. The branchy
    /// minifloat-family decodes stay on the staged path.
    pub fn fused_dot_supported(&self) -> bool {
        match self.fmt {
            QFormat::Fixed { .. } | QFormat::FixedRow { .. } => true,
            QFormat::Bfp { n, .. } => n as usize % kernels::LANES == 0,
            _ => false,
        }
    }

    /// Fused expand-into-dot for the m == 1 decode shape: `dot(x, row)`
    /// computed straight from the packed payload, streaming each ≤64-field
    /// expanded slab into the shared lane accumulator instead of staging
    /// the whole decoded row. Bit-identical to
    /// `kernels::dot(x, decoded_row)` by construction — same dispatched
    /// expand kernels, same lane order, same reduction tree, same serial
    /// tail (see [`FusedDot`]).
    ///
    /// # Panics
    ///
    /// Debug-asserts [`QTensor::fused_dot_supported`]; callers gate on it.
    pub fn dot_row(&self, row: usize, x: &[f32]) -> f32 {
        debug_assert!(self.fused_dot_supported());
        debug_assert!(row < self.rows());
        debug_assert_eq!(x.len(), self.cols());
        let mut r = BitReader {
            buf: &self.payload,
            bitpos: row * self.row_bits(),
        };
        let mut acc = FusedDot::new(x);
        match self.fmt {
            QFormat::Fixed { w } => {
                let scale = self.scale;
                fused_fields(&mut r, w, 0, x.len(), &mut acc, |f, o| {
                    kernels::expand_fixed(f, w, scale, o)
                });
            }
            QFormat::FixedRow { w } => {
                let s = f32::from_bits(r.read(32));
                fused_fields(&mut r, w, 0, x.len(), &mut acc, |f, o| {
                    kernels::expand_fixed(f, w, s, o)
                });
            }
            QFormat::Bfp { e, m, n } => {
                let bias = (1i32 << (e - 1)) - 1;
                for (s0, e0) in block_ranges(x.len(), n as usize) {
                    let sh_e = r.read(e) as i32 - bias;
                    let blk_scale = exp2i(sh_e - m as i32 + 1);
                    fused_fields(&mut r, 1 + m, s0, e0, &mut acc, |f, o| {
                        kernels::expand_bfp(f, blk_scale, o)
                    });
                }
            }
            _ => unreachable!("gated by fused_dot_supported"),
        }
        acc.finish()
    }
}

/// Streaming lane accumulator reproducing [`crate::kernels::dot`]'s exact
/// reduction order over a row that is decoded chunk by chunk: every chunk
/// start is lane-aligned, so its lane-eligible prefix goes through the
/// dispatched `dot_acc` (the same per-lane term sequence `dot` produces),
/// and the final `cols % 8` elements are buffered and folded serially
/// after the [`crate::kernels::reduce8`] tree — exactly `dot`'s tail.
struct FusedDot<'a> {
    x: &'a [f32],
    lane: [f32; kernels::LANES],
    /// Decoded values at column indices ≥ `lanes_end` (at most 7).
    tail: [f32; kernels::LANES - 1],
    tail_len: usize,
    /// `cols / 8 * 8` — the boundary between lane and serial accumulation.
    lanes_end: usize,
}

impl<'a> FusedDot<'a> {
    fn new(x: &'a [f32]) -> Self {
        FusedDot {
            x,
            lane: [0.0; kernels::LANES],
            tail: [0.0; kernels::LANES - 1],
            tail_len: 0,
            lanes_end: x.len() / kernels::LANES * kernels::LANES,
        }
    }

    /// Consume decoded values for columns `[i0, i0 + vals.len())`; `i0`
    /// must be a multiple of the lane count (the caller's chunking
    /// guarantees it), which makes the lane-eligible prefix length a
    /// multiple of the lane count too.
    fn consume(&mut self, i0: usize, vals: &[f32]) {
        debug_assert_eq!(i0 % kernels::LANES, 0);
        let ne = self.lanes_end.saturating_sub(i0).min(vals.len());
        debug_assert_eq!(ne % kernels::LANES, 0);
        kernels::dot_acc(&self.x[i0..i0 + ne], &vals[..ne], &mut self.lane);
        for &v in &vals[ne..] {
            self.tail[self.tail_len] = v;
            self.tail_len += 1;
        }
    }

    fn finish(&self) -> f32 {
        let mut s = kernels::reduce8(&self.lane);
        for t in 0..self.tail_len {
            s += self.x[self.lanes_end + t] * self.tail[t];
        }
        s
    }
}

/// Like [`expand_fields`], but hands each expanded slab to the fused dot
/// accumulator for columns `[start, end)` instead of a dense row buffer.
fn fused_fields(
    r: &mut BitReader,
    bits: u32,
    start: usize,
    end: usize,
    acc: &mut FusedDot,
    mut expand: impl FnMut(&[u32], &mut [f32]),
) {
    let mut fields = [0u32; FIELD_CHUNK];
    let mut vals = [0.0f32; FIELD_CHUNK];
    let mut i = start;
    while i < end {
        let len = (end - i).min(FIELD_CHUNK);
        for f in fields[..len].iter_mut() {
            *f = r.read(bits);
        }
        expand(&fields[..len], &mut vals[..len]);
        acc.consume(i, &vals[..len]);
        i += len;
    }
}

/// Encode (quantise + pack). Blocks run along the last dim.
pub fn encode(t: &Tensor, fmt: QFormat) -> QTensor {
    let cols = *t.shape.last().unwrap_or(&1);
    let mut w = BitWriter::new();
    let mut scale = 0.0f32;
    match fmt {
        QFormat::Fp32 => {
            for &x in &t.data {
                w.push(x.to_bits(), 32);
            }
        }
        QFormat::Fixed { w: wb } => {
            let (codes, s) = super::fixed::fixed_encode(&t.data, wb);
            scale = s;
            for c in codes {
                w.push((c as u32) & ((1u32 << wb) - 1), wb);
            }
        }
        QFormat::FixedRow { w: wb } => {
            // per-row scale stored inline as 32 bits (amortised over the row)
            for row in t.data.chunks(cols.max(1)) {
                let (codes, s) = super::fixed::fixed_encode(row, wb);
                w.push(s.to_bits(), 32);
                for c in codes {
                    w.push((c as u32) & ((1u32 << wb) - 1), wb);
                }
            }
        }
        QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => {
            let bias = (1i32 << (e - 1)) - 1;
            let dmf = matches!(fmt, QFormat::Dmf { .. });
            for &x in &t.data {
                let q = if dmf {
                    round_dmf(x, e, m, bias)
                } else {
                    round_minifloat(x, e, m, bias)
                };
                let (s, ef, mf) = float_fields(q, e, m, bias, dmf);
                w.push(s, 1);
                w.push(ef, e);
                w.push(mf, m);
            }
        }
        QFormat::Bfp { e, m, n } => {
            for row in t.data.chunks(cols) {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let (sh_e, ms) = super::bfp::bfp_encode_block(&row[s0..e0], e, m);
                    let bias = (1i32 << (e - 1)) - 1;
                    w.push((sh_e + bias) as u32, e);
                    for mm in ms {
                        w.push((mm < 0) as u32, 1);
                        w.push(mm.unsigned_abs(), m);
                    }
                }
            }
        }
        QFormat::Bm { e, m, b, n } => {
            for row in t.data.chunks(cols) {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let blk = &row[s0..e0];
                    let bias = super::bm::shared_bias(block_absmax(blk), e, b);
                    w.push((bias + (1i32 << (b - 1))) as u32, b);
                    for &x in blk {
                        let q = round_minifloat(x, e, m, bias);
                        let (s, ef, mf) = float_fields(q, e, m, bias, false);
                        w.push(s, 1);
                        w.push(ef, e);
                        w.push(mf, m);
                    }
                }
            }
        }
        QFormat::Bl { e, b, n } => {
            for row in t.data.chunks(cols) {
                for (s0, e0) in block_ranges(cols, n as usize) {
                    let blk = &row[s0..e0];
                    let bias = super::bm::shared_bias(block_absmax(blk), e, b);
                    w.push((bias + (1i32 << (b - 1))) as u32, b);
                    for &x in blk {
                        let q = super::bl::bl_round(x, e, bias);
                        let (s, ef) = if q == 0.0 {
                            (0, 0)
                        } else {
                            ((q < 0.0) as u32, (ilogb(q.abs()) + bias) as u32)
                        };
                        w.push(s, 1);
                        w.push(ef, e);
                    }
                }
            }
        }
    }
    QTensor {
        shape: t.shape.clone(),
        fmt,
        payload: w.buf,
        scale,
    }
}

/// Field extraction for an already-rounded minifloat/DMF value.
fn float_fields(q: f32, e_bits: u32, m_bits: u32, bias: i32, dmf: bool) -> (u32, u32, u32) {
    if q == 0.0 {
        return (0, 0, 0);
    }
    let s = (q < 0.0) as u32;
    let aq = q.abs();
    let emax_field = (1i32 << e_bits) - 1;
    if dmf {
        // pick the smallest covering exponent (matches round_dmf's choice)
        let m_full = ((1u64 << m_bits) - 1) as f32;
        let mut ef = (ilogb(aq) + bias + 1).clamp(0, emax_field);
        while ef > 0 && aq <= m_full * exp2i(ef - 1 - bias - m_bits as i32) {
            ef -= 1;
        }
        let m = (aq / exp2i(ef - bias - m_bits as i32)).round() as u32;
        (s, ef as u32, m)
    } else {
        let e_unb = ilogb(aq);
        let ef = (e_unb + bias).clamp(0, emax_field);
        let m = if ef == 0 {
            (aq / exp2i(1 - bias - m_bits as i32)).round() as u32
        } else {
            ((aq / exp2i(ef - bias) - 1.0) * exp2i(m_bits as i32)).round() as u32
        };
        (s, ef as u32, m)
    }
}

/// Decode back to f32 (must equal the fake-quant values exactly).
pub fn decode(q: &QTensor) -> Tensor {
    let cols = q.cols();
    let rows = q.rows();
    let mut out = vec![0.0f32; q.numel()];
    for row in 0..rows {
        q.decode_row_into(row, &mut out[row * cols..(row + 1) * cols]);
    }
    Tensor::new(&q.shape, out)
}

/// Staging chunk for SIMD field expansion: the bit-reader is inherently
/// serial, so `expand_fields` pulls raw fields into a stack slab and hands
/// each slab to a vectorised `kernels::expand_*` in one call.
const FIELD_CHUNK: usize = 64;

/// Read `out.len()` fields of `bits` bits each and expand them slab-wise.
fn expand_fields(
    r: &mut BitReader,
    bits: u32,
    out: &mut [f32],
    mut expand: impl FnMut(&[u32], &mut [f32]),
) {
    let mut fields = [0u32; FIELD_CHUNK];
    let mut i = 0;
    while i < out.len() {
        let len = (out.len() - i).min(FIELD_CHUNK);
        for f in fields[..len].iter_mut() {
            *f = r.read(bits);
        }
        expand(&fields[..len], &mut out[i..i + len]);
        i += len;
    }
}

/// Decode one packed row; `r` must be positioned at the row start. Shared
/// by [`decode`] and [`QTensor::decode_row_into`] so the streamed and
/// whole-tensor paths cannot diverge. The Fixed/FixedRow/Bfp arms expand
/// their packed fields through the dispatched [`crate::kernels`] expand
/// primitives (bit-identical across backends); the branchy minifloat-family
/// decode stays scalar.
fn decode_row(r: &mut BitReader, fmt: QFormat, scale: f32, out: &mut [f32]) {
    let cols = out.len();
    match fmt {
        QFormat::Fp32 => {
            for x in out.iter_mut() {
                *x = f32::from_bits(r.read(32));
            }
        }
        QFormat::Fixed { w } => {
            expand_fields(r, w, out, |f, o| kernels::expand_fixed(f, w, scale, o));
        }
        QFormat::FixedRow { w } => {
            let s = f32::from_bits(r.read(32));
            expand_fields(r, w, out, |f, o| kernels::expand_fixed(f, w, s, o));
        }
        QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => {
            let bias = (1i32 << (e - 1)) - 1;
            let dmf = matches!(fmt, QFormat::Dmf { .. });
            for x in out.iter_mut() {
                let s = r.read(1);
                let ef = r.read(e) as i32;
                let mf = r.read(m);
                *x = decode_float(s, ef, mf, m, bias, dmf);
            }
        }
        QFormat::Bfp { e, m, n } => {
            let bias = (1i32 << (e - 1)) - 1;
            for (s0, e0) in block_ranges(cols, n as usize) {
                // decode the block's shared exponent once ...
                let sh_e = r.read(e) as i32 - bias;
                let blk_scale = exp2i(sh_e - m as i32 + 1);
                // ... then vector-expand its mantissas: one combined
                // (1 + m)-bit read per element (sign is pushed first, so it
                // lands in the LSB) and a dispatched expand over the block
                expand_fields(r, 1 + m, &mut out[s0..e0], |f, o| {
                    kernels::expand_bfp(f, blk_scale, o)
                });
            }
        }
        QFormat::Bm { e, m, b, n } => {
            for (s0, e0) in block_ranges(cols, n as usize) {
                let bias = r.read(b) as i32 - (1i32 << (b - 1));
                for x in out[s0..e0].iter_mut() {
                    let s = r.read(1);
                    let ef = r.read(e) as i32;
                    let mf = r.read(m);
                    *x = decode_float(s, ef, mf, m, bias, false);
                }
            }
        }
        QFormat::Bl { e, b, n } => {
            for (s0, e0) in block_ranges(cols, n as usize) {
                let bias = r.read(b) as i32 - (1i32 << (b - 1));
                for x in out[s0..e0].iter_mut() {
                    let s = r.read(1);
                    let ef = r.read(e) as i32;
                    let v = if ef == 0 { 0.0 } else { exp2i(ef - bias) };
                    *x = if s == 1 { -v } else { v };
                }
            }
        }
    }
}

fn decode_float(s: u32, ef: i32, mf: u32, m_bits: u32, bias: i32, dmf: bool) -> f32 {
    let frac = mf as f32 * exp2i(-(m_bits as i32));
    let v = if dmf {
        exp2i(ef - bias) * frac
    } else if ef == 0 {
        exp2i(1 - bias) * frac
    } else {
        exp2i(ef - bias) * (1.0 + frac)
    };
    if s == 1 {
        -v
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;
    use crate::quant::fake_quant;
    use crate::util::check::{check, close_slice, llmish_values};

    #[test]
    fn pack_roundtrips_all_formats() {
        for (name, fmt) in presets::table3_formats() {
            check(&format!("pack/unpack {name}"), 30, |rng| {
                let cols = 16 * (1 + rng.below(3));
                let rows = 1 + rng.below(4);
                let xs = llmish_values(rng, rows * cols, 1.0, 0.05);
                let t = Tensor::new(&[rows, cols], xs);
                let fake = fake_quant(&t, fmt);
                let packed = encode(&t, fmt);
                let dec = decode(&packed);
                close_slice(&fake.data, &dec.data, 0.0, name)
            });
        }
    }

    #[test]
    fn ragged_tail_block_roundtrips() {
        check("pack ragged", 30, |rng| {
            let t = Tensor::new(&[3, 21], llmish_values(rng, 63, 1.0, 0.05));
            for fmt in [presets::bfp_w(6), presets::bm8(), presets::bl8()] {
                let fake = fake_quant(&t, fmt);
                let dec = decode(&encode(&t, fmt));
                close_slice(&fake.data, &dec.data, 0.0, &fmt.name())?;
            }
            Ok(())
        });
    }

    #[test]
    fn measured_density_matches_formula() {
        let mut rng = crate::util::rng::Pcg32::new(2);
        // use a block-aligned shape so amortisation matches the formula
        let t = Tensor::randn(&[8, 256], 1.0, &mut rng);
        for (name, fmt) in presets::table3_formats() {
            let q = encode(&t, fmt);
            let measured = q.bits_per_element();
            let formula = fmt.bits_per_element();
            assert!(
                (measured - formula).abs() < 0.05 + 8.0 / t.numel() as f64,
                "{name}: measured {measured} vs formula {formula}"
            );
        }
    }

    #[test]
    fn row_seek_matches_full_decode() {
        // decode_row_into must land on exact bit offsets for every format,
        // including ragged tail blocks — seek rows out of order on purpose.
        let mut formats = presets::table3_formats();
        formats.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
        formats.push(("Fp32", QFormat::Fp32));
        for (name, fmt) in formats {
            check(&format!("row seek {name}"), 20, |rng| {
                let cols = 5 + rng.below(40);
                let rows = 1 + rng.below(5);
                let t = Tensor::new(&[rows, cols], llmish_values(rng, rows * cols, 1.0, 0.05));
                let q = encode(&t, fmt);
                let bits = q.row_bits() * rows;
                if q.payload.len() != bits.div_ceil(8) {
                    return Err(format!(
                        "{name}: payload {} bytes vs computed {} bits",
                        q.payload.len(),
                        bits
                    ));
                }
                let full = decode(&q);
                let mut buf = vec![0.0f32; cols];
                for row in (0..rows).rev() {
                    q.decode_row_into(row, &mut buf);
                    close_slice(&buf, full.row(row), 0.0, name)?;
                }
                Ok(())
            });
        }
    }

    #[test]
    fn fused_dot_row_matches_staged_bits() {
        // dot_row must equal decode_row_into + kernels::dot bit for bit,
        // including ragged tail blocks and cols % 8 serial tails
        let mut formats = presets::table3_formats();
        formats.push(("FixedRow W8", QFormat::FixedRow { w: 8 }));
        for (name, fmt) in formats {
            check(&format!("fused dot {name}"), 20, |rng| {
                let cols = 5 + rng.below(80);
                let rows = 1 + rng.below(4);
                let t = Tensor::new(&[rows, cols], llmish_values(rng, rows * cols, 1.0, 0.05));
                let q = encode(&t, fmt);
                if !q.fused_dot_supported() {
                    return Ok(());
                }
                let x = llmish_values(rng, cols, 1.0, 0.02);
                let mut buf = vec![0.0f32; cols];
                for row in 0..rows {
                    q.decode_row_into(row, &mut buf);
                    let want = crate::kernels::dot(&x, &buf);
                    let got = q.dot_row(row, &x);
                    if want.to_bits() != got.to_bits() {
                        return Err(format!("{name} row {row}: {want} vs {got}"));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn fixedrow_pack_roundtrip_exact() {
        check("pack/unpack fixedrow", 30, |rng| {
            let t = Tensor::new(&[4, 24], llmish_values(rng, 96, 1.0, 0.05));
            let fmt = QFormat::FixedRow { w: 8 };
            let fake = fake_quant(&t, fmt);
            let dec = decode(&encode(&t, fmt));
            close_slice(&fake.data, &dec.data, 0.0, "fixedrow")
        });
    }

    #[test]
    fn fp32_pack_exact() {
        let mut rng = crate::util::rng::Pcg32::new(3);
        let t = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let dec = decode(&encode(&t, QFormat::Fp32));
        assert_eq!(t.data, dec.data);
    }
}
