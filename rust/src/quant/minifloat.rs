//! Scalar MiniFloat / Denormalised-MiniFloat rounding (paper §3.1, Appx. C).
//!
//! `round_minifloat(x, E, M, bias)` rounds to the nearest representable
//! IEEE-style minifloat with subnormals and a *saturating* top exponent
//! (no ±inf — `e = 2^E - 1` is an ordinary binade, Eq. 2 of the paper).
//! `round_dmf(x, E, M, bias)` is the denormalised variant with no implicit
//! leading bit (Eq. 3). Both use round-to-nearest-even, clamp NaN→0 and
//! ±inf→±max, and are the shared element primitive for BM (shared-bias
//! blocks reuse `round_minifloat` with the block bias).

/// floor(log2(x)) for finite positive x, exact via bit manipulation.
#[inline]
pub fn ilogb(x: f32) -> i32 {
    debug_assert!(x > 0.0 && x.is_finite());
    let bits = x.to_bits();
    let e = ((bits >> 23) & 0xff) as i32;
    if e == 0 {
        // f32 subnormal: normalise mantissa
        // value = m * 2^-149, highest set bit of m gives the exponent
        let m = bits & 0x7f_ffff;
        (31 - m.leading_zeros() as i32) - 149
    } else {
        e - 127
    }
}

/// Largest finite MiniFloat(E, M, bias) value: 2^(2^E-1-bias) * (2 - 2^-M).
#[inline]
pub fn minifloat_max(e_bits: u32, m_bits: u32, bias: i32) -> f32 {
    let emax = (1i64 << e_bits) - 1;
    exp2i((emax as i32) - bias) * (2.0 - exp2i(-(m_bits as i32)))
}

/// Largest finite DMF(E, M, bias) value: 2^(2^E-1-bias) * (2^M-1)/2^M.
#[inline]
pub fn dmf_max(e_bits: u32, m_bits: u32, bias: i32) -> f32 {
    let emax = (1i64 << e_bits) - 1;
    exp2i((emax as i32) - bias) * (((1u64 << m_bits) - 1) as f32) * exp2i(-(m_bits as i32))
}

/// 2^k as f32, exact for the huge k range we need (including subnormal results).
#[inline]
pub fn exp2i(k: i32) -> f32 {
    if k >= -126 && k <= 127 {
        f32::from_bits(((k + 127) as u32) << 23)
    } else if k < -126 && k >= -149 {
        f32::from_bits(1u32 << (k + 149) as u32)
    } else if k < -149 {
        0.0
    } else {
        f32::INFINITY
    }
}

/// Round to nearest MiniFloat(E, M, bias); saturating, RNE.
pub fn round_minifloat(x: f32, e_bits: u32, m_bits: u32, bias: i32) -> f32 {
    if x.is_nan() {
        return 0.0;
    }
    if x == 0.0 {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0f32 } else { 1.0 };
    let ax = x.abs();
    let max_val = minifloat_max(e_bits, m_bits, bias);
    if ax >= max_val {
        return sign * max_val;
    }
    let emax_field = ((1i64 << e_bits) - 1) as i32;
    let e_unb = ilogb(ax);
    // exponent field the value lands in (0 = subnormal binade)
    let e_field = (e_unb + bias).clamp(0, emax_field);
    // effective exponent of the binade: subnormals share 2^(1-bias)
    let e_eff = if e_field == 0 { 1 - bias } else { e_field - bias };
    // quantisation step in this binade
    let step = exp2i(e_eff - m_bits as i32);
    let q = (ax / step).round_ties_even() * step;
    // carry into the next binade is fine: lands exactly on a power of two,
    // and ax < max_val guarantees q <= max_val.
    sign * q.min(max_val)
}

/// Round to nearest DMF(E, M, bias): x = ±2^(e-bias) * m/2^M, no implicit bit.
pub fn round_dmf(x: f32, e_bits: u32, m_bits: u32, bias: i32) -> f32 {
    if x.is_nan() {
        return 0.0;
    }
    if x == 0.0 {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0f32 } else { 1.0 };
    let ax = x.abs();
    let max_val = dmf_max(e_bits, m_bits, bias);
    if ax >= max_val {
        return sign * max_val;
    }
    let emax_field = ((1i64 << e_bits) - 1) as i32;
    // smallest exponent e such that (2^M - 1) * 2^(e - bias - M) >= ax,
    // i.e. e >= log2(ax / (2^M - 1)) + bias + M. Derive from ilogb and fix up.
    let m_full = ((1u64 << m_bits) - 1) as f32;
    let mut e_field = (ilogb(ax) + bias + 1).clamp(0, emax_field);
    // fix-up: ensure coverage (at most a couple of steps)
    while e_field > 0 && ax <= m_full * exp2i(e_field - 1 - bias - m_bits as i32) {
        e_field -= 1;
    }
    while e_field < emax_field && ax > m_full * exp2i(e_field - bias - m_bits as i32) {
        e_field += 1;
    }
    let step = exp2i(e_field - bias - m_bits as i32);
    let cand1 = (ax / step).round_ties_even() * step;
    // the next-finer grid's maximum ((2^M-1)·step/2) lies between this
    // grid's points and can be nearer (e.g. E4M3: 7.2 → 7, not 8)
    if e_field > 0 {
        let cand2 = m_full * step * 0.5;
        if (cand2 - ax).abs() < (cand1 - ax).abs() {
            return sign * cand2;
        }
    }
    sign * cand1
}

/// Enumerate all non-negative representable MiniFloat values (test oracle).
pub fn enumerate_minifloat(e_bits: u32, m_bits: u32, bias: i32) -> Vec<f32> {
    let mut vals = vec![0.0f32];
    let emax = ((1i64 << e_bits) - 1) as i32;
    for e in 0..=emax {
        for m in 0..(1i64 << m_bits) {
            let frac = m as f32 * exp2i(-(m_bits as i32));
            let v = if e == 0 {
                exp2i(1 - bias) * frac
            } else {
                exp2i(e - bias) * (1.0 + frac)
            };
            vals.push(v);
        }
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    vals.dedup();
    vals
}

/// Enumerate all non-negative representable DMF values (test oracle).
pub fn enumerate_dmf(e_bits: u32, m_bits: u32, bias: i32) -> Vec<f32> {
    let mut vals = Vec::new();
    let emax = ((1i64 << e_bits) - 1) as i32;
    for e in 0..=emax {
        for m in 0..(1i64 << m_bits) {
            vals.push(exp2i(e - bias) * m as f32 * exp2i(-(m_bits as i32)));
        }
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    vals.dedup();
    vals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::check;

    fn nearest_in(vals: &[f32], ax: f32) -> f32 {
        // nearest with ties-to-even on the value grid: emulate by taking the
        // two neighbours and preferring the one the RNE mantissa picks;
        // for testing we accept either on exact ties.
        let mut best = vals[0];
        let mut bd = f32::INFINITY;
        for &v in vals {
            let d = (v - ax).abs();
            if d < bd {
                bd = d;
                best = v;
            }
        }
        best
    }

    #[test]
    fn ilogb_matches_log2() {
        for &x in &[1.0f32, 1.5, 2.0, 0.75, 3.9999, 1e-20, 1e20, 1.1754944e-38] {
            assert_eq!(ilogb(x), x.log2().floor() as i32, "x={x}");
        }
        // exact powers of two
        for k in -40..40 {
            assert_eq!(ilogb(exp2i(k)), k);
        }
    }

    #[test]
    fn e4m3_known_values() {
        // E=4, M=3, bias=7: classic MiniFloat. max = 2^8 * (2 - 1/8) = 480
        let max = minifloat_max(4, 3, 7);
        assert_eq!(max, 480.0);
        assert_eq!(round_minifloat(1000.0, 4, 3, 7), 480.0);
        assert_eq!(round_minifloat(-1000.0, 4, 3, 7), -480.0);
        assert_eq!(round_minifloat(1.0, 4, 3, 7), 1.0);
        assert_eq!(round_minifloat(1.0625, 4, 3, 7), 1.0); // RNE tie: m=0.5 → even (0)
        assert_eq!(round_minifloat(1.19, 4, 3, 7), 1.25); // 9.52 steps → 10
        assert_eq!(round_minifloat(1.15, 4, 3, 7), 1.125); // 9.2 steps → 9
        // subnormal region: step = 2^(1-7-3) = 2^-9
        assert_eq!(round_minifloat(exp2i(-9), 4, 3, 7), exp2i(-9));
        assert_eq!(round_minifloat(exp2i(-11), 4, 3, 7), 0.0); // below half-step → 0? 2^-11 = step/4 < step/2
    }

    #[test]
    fn matches_enumeration_minifloat() {
        let vals = enumerate_minifloat(4, 3, 7);
        check("minifloat nearest", 400, |rng| {
            let x = rng.normal_with(0.0, 50.0);
            let got = round_minifloat(x, 4, 3, 7).abs();
            let want = nearest_in(&vals, x.abs());
            // allow exact ties to go either way
            let d_got = (got - x.abs()).abs();
            let d_want = (want - x.abs()).abs();
            if (d_got - d_want).abs() <= f32::EPSILON * x.abs().max(1.0) {
                Ok(())
            } else {
                Err(format!("x={x} got={got} want={want}"))
            }
        });
    }

    #[test]
    fn matches_enumeration_dmf() {
        let vals = enumerate_dmf(4, 3, 7);
        check("dmf nearest", 400, |rng| {
            let x = rng.normal_with(0.0, 5.0);
            let got = round_dmf(x, 4, 3, 7).abs();
            let want = nearest_in(&vals, x.abs());
            let d_got = (got - x.abs()).abs();
            let d_want = (want - x.abs()).abs();
            if (d_got - d_want).abs() <= f32::EPSILON * x.abs().max(1.0) {
                Ok(())
            } else {
                Err(format!("x={x} got={got} want={want}"))
            }
        });
    }

    #[test]
    fn idempotent() {
        check("minifloat idempotent", 300, |rng| {
            let x = rng.normal_with(0.0, 10.0);
            let q = round_minifloat(x, 4, 3, 7);
            let qq = round_minifloat(q, 4, 3, 7);
            if q == qq {
                Ok(())
            } else {
                Err(format!("x={x} q={q} qq={qq}"))
            }
        });
    }

    #[test]
    fn dmf_range_narrower_than_minifloat() {
        // paper: DMF trades range for small-value precision
        assert!(dmf_max(4, 3, 7) < minifloat_max(4, 3, 7));
        // DMF represents 2^(0-7)*1/8 = 2^-10 exactly; MiniFloat's smallest
        // subnormal is 2^(1-7)*1/8 = 2^-9.
        assert_eq!(round_dmf(exp2i(-10), 4, 3, 7), exp2i(-10));
    }

    #[test]
    fn handles_nan_inf() {
        assert_eq!(round_minifloat(f32::NAN, 4, 3, 7), 0.0);
        assert_eq!(round_minifloat(f32::INFINITY, 4, 3, 7), 480.0);
        assert_eq!(round_dmf(f32::NEG_INFINITY, 4, 3, 7), -dmf_max(4, 3, 7));
    }

    #[test]
    fn monotone() {
        check("minifloat monotone", 200, |rng| {
            let a = rng.normal_with(0.0, 20.0);
            let b = a + rng.f32() * 5.0;
            let (qa, qb) = (round_minifloat(a, 4, 3, 7), round_minifloat(b, 4, 3, 7));
            if qa <= qb {
                Ok(())
            } else {
                Err(format!("a={a} b={b} qa={qa} qb={qb}"))
            }
        });
    }
}
