//! Dense-and-sparse outlier decomposition (SqueezeLLM, arXiv:2306.07629).
//!
//! Block formats spend their shared exponent on the largest magnitude in
//! each block, so a handful of outlier weights ruin the resolution of
//! every value packed next to them. The fix: at pack time, pull the
//! top-p (< 1%) largest-|w| weights out of the tensor *before* it is
//! block-quantised — the packed payload stores them as exact zeros — and
//! keep the originals in a CSR-style f32 side table. At GEMM time the
//! table contributes `act @ outliersᵀ` as a sparse f32 correction added
//! after the (packed or dense fake-quant) base GEMM. Outliers become
//! exact, and the blocks they vacated gain a smaller shared exponent, so
//! the remaining weights quantise finer too.
//!
//! [`OutlierTable::apply`] is deliberately scalar and serial: one fixed
//! multiply-add order per output element, independent per activation row,
//! touching no SIMD dispatch — so the correction is bit-identical across
//! ISA backends (`BBQ_ISA`), thread counts, and batch sizes by
//! construction, preserving the engine's exactness contract.

use crate::tensor::Tensor;

/// Sparse f32 outlier weights of one prepared `[out, in]` weight, in CSR
/// layout over the output rows. Extracted by [`extract`]; applied as a
/// post-GEMM correction by [`OutlierTable::apply`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OutlierTable {
    /// Output rows of the `[out, in]` weight this table was extracted from.
    pub n_rows: usize,
    /// Input (contraction) columns of that weight.
    pub n_cols: usize,
    /// CSR row pointers, length `n_rows + 1`.
    pub row_ptr: Vec<u32>,
    /// Column index of each stored outlier, grouped by row, ascending.
    pub col_idx: Vec<u32>,
    /// Exact f32 value of each stored outlier.
    pub values: Vec<f32>,
}

impl OutlierTable {
    /// Stored outliers.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of the source tensor's elements held in the table.
    pub fn frac(&self) -> f64 {
        let numel = self.n_rows * self.n_cols;
        if numel == 0 {
            0.0
        } else {
            self.nnz() as f64 / numel as f64
        }
    }

    /// Resident bytes of the side table (row pointers + column indices +
    /// f32 values) — counted into the weight-memory metrics so the
    /// density story stays honest.
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.values.len() * 4
    }

    /// Add the sparse correction `act @ selfᵀ` into `out` (shapes:
    /// `act [m, n_cols]`, `out [m, n_rows]`).
    ///
    /// Plain f32 multiply-adds in CSR order, one accumulator per output
    /// element, rows independent — bit-identical whatever ISA backend,
    /// thread count, or batch size produced the base GEMM.
    pub fn apply(&self, act: &Tensor, out: &mut Tensor) {
        if self.values.is_empty() {
            return;
        }
        let (m, k) = act.dims2();
        let (mo, n) = out.dims2();
        assert_eq!(m, mo, "outlier apply: row mismatch");
        assert_eq!(k, self.n_cols, "outlier apply: contraction mismatch");
        assert_eq!(n, self.n_rows, "outlier apply: output mismatch");
        for i in 0..m {
            let a = act.row(i);
            let o = out.row_mut(i);
            for r in 0..self.n_rows {
                let (s, e) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
                if s == e {
                    continue;
                }
                let mut acc = 0.0f32;
                for t in s..e {
                    acc += a[self.col_idx[t] as usize] * self.values[t];
                }
                o[r] += acc;
            }
        }
    }
}

/// Pull the `ceil(frac · numel)` largest-|w| elements out of the `[rows,
/// cols]` tensor `w`: zero them in place (so the subsequent block
/// quantisation sees exact zeros and a smaller per-block range) and
/// return them in a CSR table. Selection is deterministic — magnitude
/// descending, linear index ascending on ties — so two extractions from
/// the same tensor are identical.
pub fn extract(w: &mut Tensor, frac: f32) -> OutlierTable {
    let (rows, cols) = w.dims2();
    let numel = rows * cols;
    let k = ((numel as f64) * (frac.max(0.0) as f64)).ceil() as usize;
    let k = k.min(numel);
    let mut table = OutlierTable {
        n_rows: rows,
        n_cols: cols,
        row_ptr: vec![0u32; rows + 1],
        col_idx: Vec::with_capacity(k),
        values: Vec::with_capacity(k),
    };
    if k == 0 {
        return table;
    }
    let mut order: Vec<u32> = (0..numel as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        let (xa, xb) = (w.data[a as usize].abs(), w.data[b as usize].abs());
        xb.partial_cmp(&xa)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut sel = order[..k].to_vec();
    sel.sort_unstable();
    for &lin in &sel {
        let (r, c) = (lin as usize / cols, lin as usize % cols);
        table.row_ptr[r + 1] += 1;
        table.col_idx.push(c as u32);
        table.values.push(w.data[lin as usize]);
        w.data[lin as usize] = 0.0;
    }
    for r in 0..rows {
        table.row_ptr[r + 1] += table.row_ptr[r];
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul::matmul_bt;
    use crate::util::check::llmish_values;
    use crate::util::rng::Pcg32;

    fn llmish(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        Tensor::new(&[rows, cols], llmish_values(&mut rng, rows * cols, 1.0, 0.05))
    }

    #[test]
    fn extract_takes_exactly_the_largest() {
        let mut w = llmish(8, 32, 1);
        let orig = w.clone();
        let t = extract(&mut w, 0.05);
        let k = (8.0 * 32.0 * 0.05f64).ceil() as usize;
        assert_eq!(t.nnz(), k);
        assert_eq!(t.row_ptr.len(), 9);
        assert_eq!(*t.row_ptr.last().unwrap() as usize, k);
        // every extracted value matches the original and was zeroed
        let mut removed_min = f32::INFINITY;
        for r in 0..8 {
            for i in t.row_ptr[r] as usize..t.row_ptr[r + 1] as usize {
                let c = t.col_idx[i] as usize;
                assert_eq!(t.values[i], orig.row(r)[c]);
                assert_eq!(w.row(r)[c], 0.0);
                removed_min = removed_min.min(t.values[i].abs());
            }
        }
        // nothing left behind is larger than the smallest extracted value
        let remaining_max = w.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        assert!(remaining_max <= removed_min);
        // base + table reconstructs the original exactly
        let mut recon = w.clone();
        for r in 0..8 {
            for i in t.row_ptr[r] as usize..t.row_ptr[r + 1] as usize {
                recon.row_mut(r)[t.col_idx[i] as usize] = t.values[i];
            }
        }
        assert_eq!(recon.data, orig.data);
    }

    #[test]
    fn extract_is_deterministic() {
        let mut a = llmish(6, 48, 3);
        let mut b = a.clone();
        assert_eq!(extract(&mut a, 0.009), extract(&mut b, 0.009));
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn zero_fraction_is_empty_and_apply_is_identity() {
        let mut w = llmish(4, 16, 5);
        let orig = w.clone();
        let t = extract(&mut w, 0.0);
        assert_eq!(t.nnz(), 0);
        assert_eq!(w.data, orig.data);
        let act = llmish(3, 16, 6);
        let mut out = llmish(3, 4, 7);
        let before = out.clone();
        t.apply(&act, &mut out);
        assert_eq!(out.data, before.data);
    }

    #[test]
    fn apply_matches_dense_outlier_matmul() {
        let mut w = llmish(8, 32, 11);
        let orig = w.clone();
        let t = extract(&mut w, 0.02);
        // the outlier-only dense matrix is the original minus the residual
        let mut sparse = Tensor::zeros(&[8, 32]);
        for i in 0..orig.numel() {
            sparse.data[i] = orig.data[i] - w.data[i];
        }
        let act = llmish(5, 32, 12);
        let mut out = Tensor::zeros(&[5, 8]);
        t.apply(&act, &mut out);
        let dense = matmul_bt(&act, &sparse);
        for (a, b) in out.data.iter().zip(&dense.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn apply_is_batch_invariant() {
        // row i of a batched apply must equal a single-row apply bit for bit
        let mut w = llmish(8, 32, 21);
        let t = extract(&mut w, 0.02);
        let act = llmish(4, 32, 22);
        let mut batched = Tensor::zeros(&[4, 8]);
        t.apply(&act, &mut batched);
        for i in 0..4 {
            let one = Tensor::new(&[1, 32], act.row(i).to_vec());
            let mut out = Tensor::zeros(&[1, 8]);
            t.apply(&one, &mut out);
            assert_eq!(out.data, batched.row(i), "row {i}");
        }
    }

    #[test]
    fn bytes_accounting() {
        let mut w = llmish(8, 32, 31);
        let t = extract(&mut w, 0.02);
        assert_eq!(t.bytes(), (8 + 1) * 4 + t.nnz() * 8);
        assert!(t.frac() > 0.0 && t.frac() < 0.03);
    }
}
