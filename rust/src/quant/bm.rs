//! Block MiniFloat (BM, Fox et al. 2021): a block of N MiniFloat(E, M)
//! elements sharing a B-bit exponent *bias*, chosen so the block max lands
//! in the top binade. High range + high precision near the block peak, at
//! the cost of larger mid-range error — which is why it needs QAT and does
//! poorly under PTQ in the paper's Table 3.

use super::block::{block_absmax, for_each_block_mut};
use super::minifloat::{ilogb, round_minifloat};

/// Shared bias for a block: put `emax` in the top exponent field, clamped to
/// the signed B-bit range.
#[inline]
pub fn shared_bias(absmax: f32, e_bits: u32, b_bits: u32) -> i32 {
    let emax_field = (1i32 << e_bits) - 1;
    let lo = -(1i32 << (b_bits - 1));
    let hi = (1i32 << (b_bits - 1)) - 1;
    if absmax == 0.0 {
        return hi; // push everything to the tiniest range; block is all zero anyway
    }
    (emax_field - ilogb(absmax)).clamp(lo, hi)
}

/// Quantise one block in place; returns the shared bias.
pub fn bm_quant_block(block: &mut [f32], e_bits: u32, m_bits: u32, b_bits: u32) -> i32 {
    let absmax = block_absmax(block);
    let bias = shared_bias(absmax, e_bits, b_bits);
    for x in block.iter_mut() {
        *x = round_minifloat(*x, e_bits, m_bits, bias);
    }
    bias
}

/// Fake-quantise a [rows, cols] buffer with [1, N] blocks.
pub fn bm_fake_quant(
    data: &mut [f32],
    cols: usize,
    block: usize,
    e_bits: u32,
    m_bits: u32,
    b_bits: u32,
) {
    for_each_block_mut(data, cols, block, |b| {
        bm_quant_block(b, e_bits, m_bits, b_bits);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::minifloat::{exp2i, minifloat_max};
    use crate::util::check::{check, close_slice, llmish_values};

    #[test]
    fn block_max_in_top_binade() {
        let mut b = vec![6.0f32, 0.5, -0.25];
        let bias = bm_quant_block(&mut b, 4, 3, 8);
        // absmax 6.0 → ilogb 2 → bias = 15 - 2 = 13; max representable
        assert_eq!(bias, 13);
        let max = minifloat_max(4, 3, bias);
        assert!(max >= 6.0 && max < 16.0, "max={max}");
        assert!((b[0] - 6.0).abs() < 0.51, "{b:?}");
    }

    #[test]
    fn tiny_blocks_keep_precision() {
        // the whole point of a shared bias: a block of small values is
        // represented with full minifloat precision around its own scale.
        let mut b = vec![1e-4f32, -2e-4, 3e-4];
        bm_quant_block(&mut b, 4, 3, 8);
        assert!((b[2] - 3e-4).abs() / 3e-4 < 0.07, "{b:?}");
    }

    #[test]
    fn bias_clamps_to_b_bits() {
        assert_eq!(shared_bias(exp2i(30), 4, 4), -8); // wants 15-30=-15, clamps to -8
        assert_eq!(shared_bias(exp2i(-30), 4, 4), 7); // wants 45, clamps to 7
    }

    #[test]
    fn idempotent() {
        check("bm idempotent", 200, |rng| {
            let xs = llmish_values(rng, 16, 1.0, 0.05);
            let mut q1 = xs.clone();
            bm_quant_block(&mut q1, 4, 3, 8);
            let mut q2 = q1.clone();
            bm_quant_block(&mut q2, 4, 3, 8);
            close_slice(&q1, &q2, 0.0, "idem")
        });
    }

    #[test]
    fn relative_error_bounded_in_block_range(){
        check("bm rel err", 200, |rng| {
            let xs = llmish_values(rng, 16, 1.0, 0.0);
            let mut q = xs.clone();
            bm_quant_block(&mut q, 4, 3, 8);
            let absmax = crate::quant::block::block_absmax(&xs);
            for (&x, &y) in xs.iter().zip(&q) {
                // normal-range elements: relative error <= 2^-(M+1)
                if x.abs() > absmax / 128.0 && x != 0.0 {
                    let rel = ((x - y) / x).abs();
                    if rel > 1.0 / 16.0 + 1e-6 {
                        return Err(format!("x={x} q={y} rel={rel}"));
                    }
                }
            }
            Ok(())
        });
    }
}
