//! Block Logarithm (BL): power-of-two values with a shared B-bit exponent
//! bias per block (Miyashita et al. 2016; baseline in Fox et al. 2021).
//! Element = sign + E-bit exponent; mantissa is implicitly 1. The exponent
//! field value 0 is reserved for exact zero. Amenable to large dynamic
//! range, terrible mid-range precision under PTQ (paper Table 3).

use super::block::{block_absmax, for_each_block_mut};
use super::bm::shared_bias;
use super::minifloat::{exp2i, ilogb};

/// Quantise one value to ±2^(e - bias) with e in [1, 2^E - 1]; 0 → 0.
/// Nearest-in-linear-domain: threshold at 1.5·2^k.
#[inline]
pub fn bl_round(x: f32, e_bits: u32, bias: i32) -> f32 {
    if x == 0.0 || x.is_nan() {
        return 0.0;
    }
    let sign = if x < 0.0 { -1.0f32 } else { 1.0 };
    let ax = if x.is_infinite() { f32::MAX } else { x.abs() };
    let emax_field = (1i32 << e_bits) - 1;
    let mut k = ilogb(ax);
    // linear-domain nearest power of two: [1.5*2^k, 2^(k+1)) rounds up
    if ax >= 1.5 * exp2i(k) {
        k += 1;
    }
    let e_field = k + bias;
    if e_field < 1 {
        // below the smallest representable binade: flush to zero if nearer
        // to zero than to 2^(1-bias) (linear midpoint), else clamp up.
        let smallest = exp2i(1 - bias);
        if ax < smallest * 0.5 {
            return 0.0;
        }
        return sign * smallest;
    }
    if e_field > emax_field {
        return sign * exp2i(emax_field - bias);
    }
    sign * exp2i(e_field - bias)
}

/// Quantise one block in place; returns the shared bias.
pub fn bl_quant_block(block: &mut [f32], e_bits: u32, b_bits: u32) -> i32 {
    let absmax = block_absmax(block);
    let bias = shared_bias(absmax, e_bits, b_bits);
    for x in block.iter_mut() {
        *x = bl_round(*x, e_bits, bias);
    }
    bias
}

/// Fake-quantise a [rows, cols] buffer with [1, N] blocks.
pub fn bl_fake_quant(data: &mut [f32], cols: usize, block: usize, e_bits: u32, b_bits: u32) {
    for_each_block_mut(data, cols, block, |b| {
        bl_quant_block(b, e_bits, b_bits);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, close_slice, llmish_values};

    #[test]
    fn rounds_to_powers_of_two() {
        // bias=64 centres the representable range: 2^(e-64), e ∈ [1, 127]
        assert_eq!(bl_round(1.0, 7, 64), 1.0);
        assert_eq!(bl_round(1.4, 7, 64), 1.0);
        assert_eq!(bl_round(1.6, 7, 64), 2.0);
        assert_eq!(bl_round(-3.0, 7, 64), -4.0); // 3.0 ≥ 1.5·2 → rounds up
        assert_eq!(bl_round(2.9, 7, 64), 2.0);
    }

    #[test]
    fn zero_reserved() {
        assert_eq!(bl_round(0.0, 7, 64), 0.0);
        // far below smallest binade flushes to zero
        assert_eq!(bl_round(1e-30, 7, 64), 0.0);
    }

    #[test]
    fn block_outputs_are_pow2_multiples() {
        check("bl outputs pow2", 100, |rng| {
            let xs = llmish_values(rng, 16, 1.0, 0.1);
            let mut q = xs.clone();
            bl_quant_block(&mut q, 7, 8);
            for &v in &q {
                if v != 0.0 {
                    let l = v.abs().log2();
                    if (l - l.round()).abs() > 1e-5 {
                        return Err(format!("{v} not a power of two"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn relative_error_le_third() {
        // nearest power of two in linear domain → rel error ≤ 1/3
        check("bl rel err <= 1/3", 200, |rng| {
            let x = rng.normal_with(0.0, 4.0);
            if x == 0.0 {
                return Ok(());
            }
            let q = bl_round(x, 7, 64);
            let rel = ((x - q) / x).abs();
            if rel <= 1.0 / 3.0 + 1e-5 {
                Ok(())
            } else {
                Err(format!("x={x} q={q} rel={rel}"))
            }
        });
    }

    #[test]
    fn idempotent() {
        check("bl idempotent", 100, |rng| {
            let xs = llmish_values(rng, 16, 1.0, 0.05);
            let mut q1 = xs.clone();
            bl_quant_block(&mut q1, 7, 8);
            let mut q2 = q1.clone();
            bl_quant_block(&mut q2, 7, 8);
            close_slice(&q1, &q2, 0.0, "idem")
        });
    }
}
