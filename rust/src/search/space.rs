//! Mixed-precision search space: one categorical dimension per quantisable
//! tensor (each GEMM's weight and activation operand, per layer —
//! Appendix B.4's "per-tensor basis").

use crate::model::config::ModelConfig;
use crate::model::plan::{QuantPlan, GEMM_NAMES};
use crate::quant::config::{presets, GemmQuant, QFormat};

#[derive(Clone, Debug)]
pub struct Dim {
    pub layer: usize,
    pub gemm: u8,
    /// true = weight operand, false = activation operand
    pub is_weight: bool,
    pub name: String,
}

#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub dims: Vec<Dim>,
    /// the candidate formats each dimension may take
    pub choices: Vec<QFormat>,
}

impl SearchSpace {
    /// Per-tensor BFP bit-width search (the paper's §4.4 setting): every
    /// operand chooses a BFP word length from `bit_choices`.
    pub fn bfp_bits(cfg: &ModelConfig, bit_choices: &[u32]) -> SearchSpace {
        let choices: Vec<QFormat> = bit_choices.iter().map(|&b| presets::bfp_w(b)).collect();
        let mut dims = Vec::new();
        for layer in 0..cfg.n_layers {
            for g in 1..=8u8 {
                for is_weight in [true, false] {
                    dims.push(Dim {
                        layer,
                        gemm: g,
                        is_weight,
                        name: format!(
                            "L{layer}.{}.{}",
                            GEMM_NAMES[(g - 1) as usize],
                            if is_weight { "w" } else { "a" }
                        ),
                    });
                }
            }
        }
        SearchSpace { dims, choices }
    }

    pub fn cards(&self) -> Vec<usize> {
        vec![self.choices.len(); self.dims.len()]
    }

    /// Materialise a TPE assignment into a QuantPlan.
    pub fn plan_of(&self, assignment: &[usize]) -> QuantPlan {
        assert_eq!(assignment.len(), self.dims.len());
        let mut plan = QuantPlan::uniform(self.choices[0]);
        // group per site: find weight + act choices
        for (d, &choice) in self.dims.iter().zip(assignment) {
            let site = (d.layer, d.gemm);
            let mut q = plan
                .per_site
                .get(&site)
                .copied()
                .unwrap_or(GemmQuant::uniform(self.choices[0]));
            if d.is_weight {
                q.weight = self.choices[choice];
            } else {
                q.act = self.choices[choice];
            }
            plan.per_site.insert(site, q);
        }
        plan
    }

    /// Average word bits of an assignment (the "4.3-bit model" accounting).
    pub fn mean_bits(&self, assignment: &[usize]) -> f64 {
        assignment
            .iter()
            .map(|&c| self.choices[c].word_bits() as f64)
            .sum::<f64>()
            / assignment.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_size() {
        let cfg = ModelConfig::preset("nano");
        let sp = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
        assert_eq!(sp.dims.len(), 2 * 8 * 2); // layers × gemms × operands
        assert!(sp.cards().iter().all(|&c| c == 5));
    }

    #[test]
    fn plan_materialisation() {
        let cfg = ModelConfig::preset("nano");
        let sp = SearchSpace::bfp_bits(&cfg, &[4, 8]);
        let assignment: Vec<usize> = (0..sp.dims.len()).map(|i| i % 2).collect();
        let plan = sp.plan_of(&assignment);
        // first dim is layer0 gemm1 weight → choice 0 (4 bit)
        assert_eq!(plan.site(0, 1).weight.word_bits(), 4);
        assert_eq!(plan.site(0, 1).act.word_bits(), 8);
        assert!((sp.mean_bits(&assignment) - 6.0).abs() < 1e-9);
    }
}
