//! Mixed-precision search runner (§4.4): TPE over per-tensor BFP bit
//! widths, objective `acc + α·mem`, with the bit-width-distribution
//! statistics of Figures 3/8/9 (which layers keep high precision across
//! repeated searches) and the accuracy/memory threshold filter.

use super::objective::{plan_memory_density, Objective};
use super::space::SearchSpace;
use super::tpe::{Tpe, TpeConfig};
use crate::data::tasks::{evaluate, Example, Task};
use crate::model::params::Params;
use crate::model::plan::QuantPlan;
use crate::model::Model;

#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub trials: usize,
    pub seq: usize,
    pub seed: u64,
    pub threads: usize,
    /// accept configs within this many accuracy points of FP32
    pub acc_threshold: f64,
    /// and with at least this memory density
    pub mem_threshold: f64,
    pub objective: Objective,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            trials: 60,
            seq: 64,
            seed: 7,
            threads: 4,
            acc_threshold: 0.02,
            mem_threshold: 7.1,
            objective: Objective::software(0.05),
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrialRecord {
    pub assignment: Vec<usize>,
    pub accuracy: f64,
    pub mem_density: f64,
    pub objective: f64,
}

#[derive(Debug)]
pub struct SearchResult {
    pub history: Vec<TrialRecord>,
    pub best: Option<TrialRecord>,
    /// trials passing the accuracy + memory thresholds
    pub accepted: Vec<TrialRecord>,
    pub space: SearchSpace,
}

impl SearchResult {
    /// Mean assigned bit width per dimension across accepted configs —
    /// the Figure 3/8/9 histogram. Falls back to the best-half of history
    /// when the thresholds accept nothing.
    pub fn bitwidth_profile(&self) -> Vec<(String, f64)> {
        let pool: Vec<&TrialRecord> = if !self.accepted.is_empty() {
            self.accepted.iter().collect()
        } else {
            let mut sorted: Vec<&TrialRecord> = self.history.iter().collect();
            sorted.sort_by(|a, b| b.objective.partial_cmp(&a.objective).unwrap());
            sorted.into_iter().take(self.history.len() / 2 + 1).collect()
        };
        self.space
            .dims
            .iter()
            .enumerate()
            .map(|(d, dim)| {
                let mean = pool
                    .iter()
                    .map(|t| self.space.choices[t.assignment[d]].word_bits() as f64)
                    .sum::<f64>()
                    / pool.len() as f64;
                (dim.name.clone(), mean)
            })
            .collect()
    }

    /// The best trial's assignment materialised as a deployable
    /// [`QuantPlan`] (per-site formats populated for every GEMM site).
    /// `None` when the search produced no trials. Pair with
    /// [`crate::model::plan_file::save`] to emit a plan artifact.
    pub fn best_plan(&self) -> Option<QuantPlan> {
        self.best.as_ref().map(|t| self.space.plan_of(&t.assignment))
    }

    /// Aggregate the profile per layer (mean over the layer's dims).
    pub fn layer_bit_profile(&self, n_layers: usize) -> Vec<f64> {
        let profile = self.bitwidth_profile();
        let mut sums = vec![0.0; n_layers];
        let mut counts = vec![0usize; n_layers];
        for (dim, (_, bits)) in self.space.dims.iter().zip(&profile) {
            sums[dim.layer] += bits;
            counts[dim.layer] += 1;
        }
        sums.iter()
            .zip(&counts)
            .map(|(s, &c)| if c > 0 { s / c as f64 } else { f64::NAN })
            .collect()
    }
}

/// Run a TPE mixed-precision search against a zero-shot task.
pub fn run_search(
    params: &Params,
    space: SearchSpace,
    task: Task,
    examples: &[Example],
    fp32_acc: f64,
    cfg: &SearchConfig,
) -> SearchResult {
    let model_cfg = params.cfg.clone();
    let cost = crate::density::arith::calibrate();
    let mut tpe = Tpe::new(space.cards(), cfg.seed, TpeConfig::default());
    let mut history = Vec::with_capacity(cfg.trials);
    let mut model = Model::new(params.clone(), QuantPlan::fp32());
    for _ in 0..cfg.trials {
        let assignment = tpe.suggest();
        let plan = space.plan_of(&assignment);
        model.set_plan(plan.clone());
        let acc = evaluate(&model, task, examples, cfg.threads).accuracy;
        let mem = plan_memory_density(&model_cfg, &plan, cfg.seq);
        let obj = cfg
            .objective
            .value(acc, &model_cfg, &plan, cfg.seq, &cost);
        tpe.observe(assignment.clone(), obj);
        history.push(TrialRecord {
            assignment,
            accuracy: acc,
            mem_density: mem,
            objective: obj,
        });
    }
    let accepted: Vec<TrialRecord> = history
        .iter()
        .filter(|t| {
            t.accuracy >= fp32_acc - cfg.acc_threshold && t.mem_density >= cfg.mem_threshold
        })
        .cloned()
        .collect();
    let best = history
        .iter()
        .max_by(|a, b| a.objective.partial_cmp(&b.objective).unwrap())
        .cloned();
    SearchResult {
        history,
        best,
        accepted,
        space,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::generate;
    use crate::data::vocab::Vocab;
    use crate::model::config::ModelConfig;

    #[test]
    fn search_smoke_improves_over_first_trials() {
        let v = Vocab::build();
        let cfgm = ModelConfig::preset("nano");
        let params = Params::init(&cfgm, 3);
        let exs = generate(Task::Sst2, &v, 77, 24);
        let space = SearchSpace::bfp_bits(&cfgm, &[4, 6, 8]);
        let sc = SearchConfig {
            trials: 18,
            threads: 4,
            ..Default::default()
        };
        let res = run_search(&params, space, Task::Sst2, &exs, 0.5, &sc);
        assert_eq!(res.history.len(), 18);
        let best = res.best.as_ref().unwrap().objective;
        let first = res.history[0].objective;
        assert!(best >= first);
        let profile = res.bitwidth_profile();
        assert_eq!(profile.len(), 2 * 8 * 2);
        assert!(profile.iter().all(|(_, b)| (3.0..=8.5).contains(b)));
        let lp = res.layer_bit_profile(cfgm.n_layers);
        assert_eq!(lp.len(), 2);
    }
}
