//! Search objectives.
//!
//! Software objective (§3.3):   `O_f = acc + α·mem`
//! Hardware-aware (Appendix H): `O_f = acc + α₁·mem + α₂·tps + α₃·tpl`
//!
//! α is auto-calibrated: run with α=1 until convergence, then set
//! `α = acc_c / mem_c` so the two terms are balanced at the converged
//! point (paper §3.3).

use crate::density::arith::CostModel;
use crate::density::flops::layer_gemms;
use crate::density::memory::model_memory_density;
use crate::model::config::ModelConfig;
use crate::model::plan::QuantPlan;
use crate::quant::config::QFormat;

/// Memory density of a plan over the model's GEMM operand inventory.
pub fn plan_memory_density(cfg: &ModelConfig, plan: &QuantPlan, seq: usize) -> f64 {
    let mut tensors: Vec<(usize, QFormat)> = Vec::new();
    for li in 0..cfg.n_layers {
        for g in layer_gemms(cfg, seq) {
            let q = plan.site(li, g.index as u8);
            tensors.push((g.act_numel_per_tok * seq, q.act));
            let wn = if g.weight_numel > 0 {
                g.weight_numel
            } else {
                g.act_numel_per_tok * seq
            };
            tensors.push((wn, q.weight));
        }
    }
    model_memory_density(&tensors)
}

/// Simple throughput model: tokens/s ∝ 1 / Σ (MACs · area·time-weight).
/// We take per-MAC latency-area product proportional to the LUT area of
/// the chosen format's MAC (a unit-pipelined array: more LUTs per MAC =
/// fewer MACs per mm² per cycle). TPS is normalised to the FP32 model.
pub fn plan_tps(cfg: &ModelConfig, plan: &QuantPlan, seq: usize, cost: &CostModel) -> f64 {
    let mut weighted = 0.0f64;
    let mut fp32_weighted = 0.0f64;
    let fp32_area = cost.area(QFormat::Fp32);
    for li in 0..cfg.n_layers {
        for g in layer_gemms(cfg, seq) {
            let q = plan.site(li, g.index as u8);
            // MAC area dominated by the wider of the two operand formats
            let area = cost.area(q.act).max(cost.area(q.weight));
            weighted += g.macs_per_tok as f64 * area;
            fp32_weighted += g.macs_per_tok as f64 * fp32_area;
        }
    }
    fp32_weighted / weighted.max(1e-9)
}

/// TPS per LUT (area efficiency): tps / total plan area, normalised.
pub fn plan_tpl(cfg: &ModelConfig, plan: &QuantPlan, seq: usize, cost: &CostModel) -> f64 {
    let tps = plan_tps(cfg, plan, seq, cost);
    let mut area = 0.0;
    let mut fp32_area = 0.0;
    for li in 0..cfg.n_layers {
        for g in layer_gemms(cfg, seq) {
            let q = plan.site(li, g.index as u8);
            area += cost.area(q.act).max(cost.area(q.weight));
            fp32_area += cost.area(QFormat::Fp32);
        }
    }
    tps * fp32_area / area.max(1e-9)
}

#[derive(Clone, Copy, Debug)]
pub struct Objective {
    pub alpha_mem: f64,
    /// hardware-aware extension (0 = software-only)
    pub alpha_tps: f64,
    pub alpha_tpl: f64,
}

impl Objective {
    pub fn software(alpha: f64) -> Objective {
        Objective {
            alpha_mem: alpha,
            alpha_tps: 0.0,
            alpha_tpl: 0.0,
        }
    }

    pub fn hardware_aware(a1: f64, a2: f64, a3: f64) -> Objective {
        Objective {
            alpha_mem: a1,
            alpha_tps: a2,
            alpha_tpl: a3,
        }
    }

    pub fn value(
        &self,
        acc: f64,
        cfg: &ModelConfig,
        plan: &QuantPlan,
        seq: usize,
        cost: &CostModel,
    ) -> f64 {
        let mut v = acc + self.alpha_mem * plan_memory_density(cfg, plan, seq);
        if self.alpha_tps != 0.0 {
            v += self.alpha_tps * plan_tps(cfg, plan, seq, cost);
        }
        if self.alpha_tpl != 0.0 {
            v += self.alpha_tpl * plan_tpl(cfg, plan, seq, cost);
        }
        v
    }

    /// The paper's α calibration: α = acc_c / mem_c at the converged point.
    pub fn calibrate_alpha(acc_c: f64, mem_c: f64) -> f64 {
        acc_c / mem_c.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;

    #[test]
    fn uniform_plan_density_matches_format() {
        let cfg = ModelConfig::preset("nano");
        let plan = QuantPlan::uniform(presets::bfp_w(4));
        let d = plan_memory_density(&cfg, &plan, 64);
        assert!((d - presets::bfp_w(4).memory_density()).abs() < 1e-9);
    }

    #[test]
    fn lower_bits_higher_tps() {
        let cfg = ModelConfig::preset("nano");
        let cost = crate::density::arith::calibrate();
        let t4 = plan_tps(&cfg, &QuantPlan::uniform(presets::bfp_w(4)), 64, &cost);
        let t8 = plan_tps(&cfg, &QuantPlan::uniform(presets::bfp_w(8)), 64, &cost);
        assert!(t4 > t8, "{t4} vs {t8}");
        assert!(t8 > 1.0); // both beat fp32
    }

    #[test]
    fn objective_combines_terms() {
        let cfg = ModelConfig::preset("nano");
        let cost = crate::density::arith::calibrate();
        let plan = QuantPlan::uniform(presets::bfp_w(6));
        let sw = Objective::software(0.1).value(0.7, &cfg, &plan, 64, &cost);
        let hw = Objective::hardware_aware(0.1, 0.01, 0.01).value(0.7, &cfg, &plan, 64, &cost);
        assert!(hw > sw);
        assert!((Objective::calibrate_alpha(0.8, 4.0) - 0.2).abs() < 1e-12);
    }
}
