//! Tree-structured Parzen Estimator for categorical search spaces
//! (Bergstra et al. 2011) — the Optuna substitute behind the paper's
//! mixed-precision search (§3.3).
//!
//! Maximisation form: trials are split at the γ-quantile of the objective;
//! per dimension, smoothed categorical densities l(x) (good) and g(x)
//! (bad) are built, candidates are drawn from l and scored by l/g.

use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct Trial {
    /// choice index per dimension
    pub x: Vec<usize>,
    pub value: f64,
}

#[derive(Clone, Debug)]
pub struct TpeConfig {
    /// number of purely random startup trials
    pub n_startup: usize,
    /// top fraction considered "good"
    pub gamma: f64,
    /// candidates drawn per dimension
    pub n_candidates: usize,
    /// additive smoothing for the categorical densities
    pub prior_weight: f64,
}

impl Default for TpeConfig {
    fn default() -> Self {
        TpeConfig {
            n_startup: 12,
            gamma: 0.25,
            n_candidates: 24,
            prior_weight: 1.0,
        }
    }
}

pub struct Tpe {
    pub cfg: TpeConfig,
    /// number of choices per dimension
    pub cards: Vec<usize>,
    pub trials: Vec<Trial>,
    rng: Pcg32,
}

impl Tpe {
    pub fn new(cards: Vec<usize>, seed: u64, cfg: TpeConfig) -> Tpe {
        Tpe {
            cfg,
            cards,
            trials: Vec::new(),
            rng: Pcg32::new(seed),
        }
    }

    /// Propose the next configuration.
    pub fn suggest(&mut self) -> Vec<usize> {
        if self.trials.len() < self.cfg.n_startup {
            return self
                .cards
                .iter()
                .map(|&c| self.rng.below(c))
                .collect();
        }
        // split trials by objective (maximise)
        let mut sorted: Vec<&Trial> = self.trials.iter().collect();
        sorted.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
        let n_good = ((sorted.len() as f64 * self.cfg.gamma).ceil() as usize)
            .clamp(1, sorted.len() - 1);
        let good = &sorted[..n_good];
        let bad = &sorted[n_good..];
        let mut out = Vec::with_capacity(self.cards.len());
        for (d, &card) in self.cards.iter().enumerate() {
            let dens = |set: &[&Trial]| -> Vec<f64> {
                let mut c = vec![self.cfg.prior_weight; card];
                for t in set {
                    c[t.x[d]] += 1.0;
                }
                let total: f64 = c.iter().sum();
                c.into_iter().map(|x| x / total).collect()
            };
            let l = dens(good);
            let g = dens(bad);
            // draw candidates from l, keep the best l/g ratio
            let mut best = 0usize;
            let mut best_score = f64::NEG_INFINITY;
            for _ in 0..self.cfg.n_candidates {
                let cand = self.rng.weighted(&l);
                let score = (l[cand] / g[cand].max(1e-12)).ln();
                if score > best_score {
                    best_score = score;
                    best = cand;
                }
            }
            out.push(best);
        }
        out
    }

    pub fn observe(&mut self, x: Vec<usize>, value: f64) {
        assert_eq!(x.len(), self.cards.len());
        self.trials.push(Trial { x, value });
    }

    pub fn best(&self) -> Option<&Trial> {
        self.trials
            .iter()
            .max_by(|a, b| a.value.partial_cmp(&b.value).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A separable test objective with a known optimum.
    fn objective(x: &[usize]) -> f64 {
        // optimum at [2, 0, 3]; unimodal per dimension
        let opt = [2usize, 0, 3];
        -x.iter()
            .zip(opt)
            .map(|(&a, o)| ((a as f64) - o as f64).abs())
            .sum::<f64>()
    }

    #[test]
    fn finds_optimum_much_faster_than_random() {
        let cards = vec![5, 5, 5];
        let budget = 60;
        let mut tpe = Tpe::new(cards.clone(), 1, TpeConfig::default());
        for _ in 0..budget {
            let x = tpe.suggest();
            let v = objective(&x);
            tpe.observe(x, v);
        }
        let best_tpe = tpe.best().unwrap().value;
        assert!(best_tpe >= -1.0, "tpe best {best_tpe}");
        // count how often the last 20 proposals are near-optimal — TPE
        // should concentrate
        let near: usize = tpe.trials[40..]
            .iter()
            .filter(|t| t.value >= -2.0)
            .count();
        assert!(near >= 10, "only {near}/20 late trials near optimum");
    }

    #[test]
    fn startup_is_random_and_in_range() {
        let mut tpe = Tpe::new(vec![3, 7], 5, TpeConfig::default());
        for _ in 0..12 {
            let x = tpe.suggest();
            assert!(x[0] < 3 && x[1] < 7);
            tpe.observe(x, 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut t = Tpe::new(vec![4, 4], seed, TpeConfig::default());
            let mut hist = Vec::new();
            for _ in 0..20 {
                let x = t.suggest();
                let v = objective(&[x[0], 0, x[1]]);
                hist.push(x.clone());
                t.observe(x, v);
            }
            hist
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
