//! Mixed-precision quantisation search (paper §3.3 / §4.4): a TPE engine
//! (Optuna substitute), the per-tensor search space, the `acc + α·mem`
//! objective with its hardware-aware extension (Appendix H), and the
//! search runner producing Figure 3/8/9 bit-width profiles.

pub mod objective;
pub mod runner;
pub mod space;
pub mod tpe;

pub use objective::Objective;
pub use runner::{run_search, SearchConfig, SearchResult};
pub use space::SearchSpace;
pub use tpe::{Tpe, TpeConfig};
