//! Manual backpropagation through the quantised decoder.
//!
//! Quantised ops use the Straight-Through Estimator (Bengio et al. 2013),
//! exactly as the paper's TAQ setup: forward applies `fake_quant`, backward
//! passes gradients through unchanged. The train-path forward caches
//! intermediates and is verified (tests) to produce the same logits as the
//! inference path; gradients are verified by finite differences.
//!
//! Training supports learned-position models (the OPT family — Table 8
//! fine-tunes OPT); RoPE models are inference-only here.

use crate::model::config::PosEncoding;
use crate::model::params::{LayerParams, Params};
use crate::model::plan::QuantPlan;
use crate::quant::config::QFormat;
use crate::quant::fake_quant;
use crate::tensor::matmul::{matmul, matmul_bt};
use crate::tensor::Tensor;
#[allow(unused_imports)]
use LayerParams as _LayerParamsUsed;

fn fq(t: &Tensor, f: QFormat) -> Tensor {
    if f == QFormat::Fp32 {
        t.clone()
    } else {
        fake_quant(t, f)
    }
}

/// Gradients, same shapes as `Params`.
pub struct Grads {
    pub tok_emb: Tensor,
    pub pos_emb: Tensor,
    pub layers: Vec<LayerGrads>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

pub struct LayerGrads {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub bq: Vec<f32>,
    pub bk: Vec<f32>,
    pub bv: Vec<f32>,
    pub bo: Vec<f32>,
    pub w1: Tensor,
    pub w2: Tensor,
    pub b1: Vec<f32>,
    pub b2: Vec<f32>,
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
}

impl Grads {
    pub fn zeros(p: &Params) -> Grads {
        Grads {
            tok_emb: Tensor::zeros(&p.tok_emb.shape),
            pos_emb: Tensor::zeros(&p.pos_emb.shape),
            layers: p
                .layers
                .iter()
                .map(|l| LayerGrads {
                    wq: Tensor::zeros(&l.wq.shape),
                    wk: Tensor::zeros(&l.wk.shape),
                    wv: Tensor::zeros(&l.wv.shape),
                    wo: Tensor::zeros(&l.wo.shape),
                    bq: vec![0.0; l.bq.len()],
                    bk: vec![0.0; l.bk.len()],
                    bv: vec![0.0; l.bv.len()],
                    bo: vec![0.0; l.bo.len()],
                    w1: Tensor::zeros(&l.w1.shape),
                    w2: Tensor::zeros(&l.w2.shape),
                    b1: vec![0.0; l.b1.len()],
                    b2: vec![0.0; l.b2.len()],
                    ln1_g: vec![0.0; l.ln1_g.len()],
                    ln1_b: vec![0.0; l.ln1_b.len()],
                    ln2_g: vec![0.0; l.ln2_g.len()],
                    ln2_b: vec![0.0; l.ln2_b.len()],
                })
                .collect(),
            lnf_g: vec![0.0; p.lnf_g.len()],
            lnf_b: vec![0.0; p.lnf_b.len()],
        }
    }

    /// Flat mutable views in the same order as Params::flat_views.
    pub fn flat_views_mut(&mut self) -> Vec<&mut [f32]> {
        let mut out: Vec<&mut [f32]> = Vec::new();
        out.push(&mut self.tok_emb.data[..]);
        out.push(&mut self.pos_emb.data[..]);
        for l in self.layers.iter_mut() {
            out.push(&mut l.ln1_g[..]);
            out.push(&mut l.ln1_b[..]);
            out.push(&mut l.wq.data[..]);
            out.push(&mut l.bq[..]);
            out.push(&mut l.wk.data[..]);
            out.push(&mut l.bk[..]);
            out.push(&mut l.wv.data[..]);
            out.push(&mut l.bv[..]);
            out.push(&mut l.wo.data[..]);
            out.push(&mut l.bo[..]);
            out.push(&mut l.ln2_g[..]);
            out.push(&mut l.ln2_b[..]);
            out.push(&mut l.w1.data[..]);
            out.push(&mut l.b1[..]);
            out.push(&mut l.w2.data[..]);
            out.push(&mut l.b2[..]);
        }
        out.push(&mut self.lnf_g[..]);
        out.push(&mut self.lnf_b[..]);
        out
    }

    pub fn global_norm(&mut self) -> f64 {
        let mut s = 0.0f64;
        for v in self.flat_views_mut() {
            for &x in v.iter() {
                s += (x as f64) * (x as f64);
            }
        }
        s.sqrt()
    }

    pub fn scale(&mut self, f: f32) {
        for v in self.flat_views_mut() {
            for x in v.iter_mut() {
                *x *= f;
            }
        }
    }
}

// ---- layer caches ----

struct LnCache {
    xhat: Tensor,   // normalised pre-gain
    inv_std: Vec<f32>,
}

struct HeadCache {
    a: Tensor,      // post-softmax attention [s, s]
    qh_q: Tensor,   // quantised+scaled Q head [s, hd]
    kh_q: Tensor,   // quantised K head [s, hd]
    vh_q: Tensor,   // quantised V head [s, hd]
    a_q: Tensor,    // quantised attention probs
}

struct LayerCache {
    x_in: Tensor,
    ln1: LnCache,
    xn1_q: [Tensor; 3],
    heads: Vec<HeadCache>,
    ctx_q: Tensor,
    ln2: LnCache,
    xn2_q: Tensor,
    hpre: Tensor,
    hact_q: Tensor,
}

pub struct FwdCache {
    tokens: Vec<usize>,
    layers: Vec<LayerCache>,
    lnf: LnCache,
    xnf: Tensor,
    pub logits: Tensor,
}

fn layer_norm_fwd(x: &Tensor, g: &[f32], b: &[f32], eps: f32) -> (Tensor, LnCache) {
    let c = *x.shape.last().unwrap();
    let rows = x.data.len() / c;
    let mut xhat = x.clone();
    let mut inv_std = Vec::with_capacity(rows);
    let mut out = x.clone();
    for r in 0..rows {
        let chunk = &x.data[r * c..(r + 1) * c];
        let mean: f32 = chunk.iter().sum::<f32>() / c as f32;
        let var: f32 = chunk.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / c as f32;
        let inv = 1.0 / (var + eps).sqrt();
        inv_std.push(inv);
        for j in 0..c {
            let xh = (chunk[j] - mean) * inv;
            xhat.data[r * c + j] = xh;
            out.data[r * c + j] = xh * g[j] + b[j];
        }
    }
    (out, LnCache { xhat, inv_std })
}

fn layer_norm_bwd(
    dy: &Tensor,
    cache: &LnCache,
    g: &[f32],
    dg: &mut [f32],
    db: &mut [f32],
) -> Tensor {
    let c = g.len();
    let rows = dy.data.len() / c;
    let mut dx = dy.clone();
    for r in 0..rows {
        let dyr = &dy.data[r * c..(r + 1) * c];
        let xh = &cache.xhat.data[r * c..(r + 1) * c];
        let inv = cache.inv_std[r];
        let mut sum_gdy = 0.0f32;
        let mut sum_gdy_xh = 0.0f32;
        for j in 0..c {
            let gdy = g[j] * dyr[j];
            sum_gdy += gdy;
            sum_gdy_xh += gdy * xh[j];
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        let cinv = 1.0 / c as f32;
        for j in 0..c {
            let gdy = g[j] * dyr[j];
            dx.data[r * c + j] = inv * (gdy - cinv * sum_gdy - xh[j] * cinv * sum_gdy_xh);
        }
    }
    dx
}

#[inline]
fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654;
    let x3 = x * x * x;
    let t = (C * (x + 0.044715 * x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

fn slice_head(t: &Tensor, hi: usize, hd: usize) -> Tensor {
    let (s, _) = t.dims2();
    let mut out = Tensor::zeros(&[s, hd]);
    for i in 0..s {
        out.row_mut(i)
            .copy_from_slice(&t.row(i)[hi * hd..(hi + 1) * hd]);
    }
    out
}

fn unslice_head_add(dst: &mut Tensor, src: &Tensor, hi: usize, hd: usize) {
    let (s, _) = dst.dims2();
    for i in 0..s {
        let d = &mut dst.row_mut(i)[hi * hd..(hi + 1) * hd];
        for (a, &b) in d.iter_mut().zip(src.row(i)) {
            *a += b;
        }
    }
}

fn col_sums(t: &Tensor, out: &mut [f32]) {
    let c = *t.shape.last().unwrap();
    for row in t.data.chunks(c) {
        for (o, &x) in out.iter_mut().zip(row) {
            *o += x;
        }
    }
}

/// Training forward: caches everything backward needs.
pub fn forward_train(p: &Params, plan: &QuantPlan, tokens: &[usize]) -> FwdCache {
    let cfg = &p.cfg;
    assert_eq!(
        cfg.pos,
        PosEncoding::Learned,
        "trainer supports learned-position models"
    );
    let (s, d) = (tokens.len(), cfg.d_model);
    let h = cfg.n_heads;
    let hd = cfg.head_dim();
    let mut x = Tensor::zeros(&[s, d]);
    for (i, &t) in tokens.iter().enumerate() {
        let e = p.tok_emb.row(t);
        let pe = p.pos_emb.row(i);
        for j in 0..d {
            x.row_mut(i)[j] = e[j] + pe[j];
        }
    }
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let l = &p.layers[li];
        let (xn1, ln1) = layer_norm_fwd(&x, &l.ln1_g, &l.ln1_b, cfg.ln_eps);
        let q1 = plan.site(li, 1);
        let q2 = plan.site(li, 2);
        let q3 = plan.site(li, 3);
        let xn1_q = [fq(&xn1, q1.act), fq(&xn1, q2.act), fq(&xn1, q3.act)];
        let q = matmul(&xn1_q[0], &fq(&l.wq.t(), q1.weight).t()).add_bias(&l.bq);
        let k = matmul(&xn1_q[1], &fq(&l.wk.t(), q2.weight).t()).add_bias(&l.bk);
        let v = matmul(&xn1_q[2], &fq(&l.wv.t(), q3.weight).t()).add_bias(&l.bv);
        let scale = 1.0 / (hd as f32).sqrt();
        let q45 = (plan.site(li, 4), plan.site(li, 5));
        let mut ctx = Tensor::zeros(&[s, d]);
        let mut heads = Vec::with_capacity(h);
        for hi in 0..h {
            let (qh, kh, vh) = (
                slice_head(&q, hi, hd),
                slice_head(&k, hi, hd),
                slice_head(&v, hi, hd),
            );
            let mut qh_q = fq(&qh, q45.0.act);
            for r in qh_q.data.iter_mut() {
                *r *= scale;
            }
            let kh_q = fq(&kh, q45.0.weight);
            let mut scores = matmul_bt(&qh_q, &kh_q);
            for i in 0..s {
                for j in (i + 1)..s {
                    scores.row_mut(i)[j] = f32::NEG_INFINITY;
                }
            }
            scores.softmax_rows();
            let a = scores;
            let a_q = fq(&a, q45.1.act);
            // blocks along the key (contraction) dim: quantise Vᵀ rows
            let vh_q = fq(&vh.t(), q45.1.weight).t();
            let ctx_h = matmul(&a_q, &vh_q);
            unslice_head_add(&mut ctx, &ctx_h, hi, hd);
            heads.push(HeadCache {
                a,
                qh_q,
                kh_q,
                vh_q,
                a_q,
            });
        }
        let q6 = plan.site(li, 6);
        let ctx_q = fq(&ctx, q6.act);
        let att_out = matmul(&ctx_q, &fq(&l.wo.t(), q6.weight).t()).add_bias(&l.bo);
        let x_mid = x.add(&att_out);
        let (xn2, ln2) = layer_norm_fwd(&x_mid, &l.ln2_g, &l.ln2_b, cfg.ln_eps);
        let q7 = plan.site(li, 7);
        let q8 = plan.site(li, 8);
        let xn2_q = fq(&xn2, q7.act);
        let hpre = matmul(&xn2_q, &fq(&l.w1.t(), q7.weight).t()).add_bias(&l.b1);
        let hact = hpre.gelu();
        let hact_q = fq(&hact, q8.act);
        let mlp_out = matmul(&hact_q, &fq(&l.w2.t(), q8.weight).t()).add_bias(&l.b2);
        let x_out = x_mid.add(&mlp_out);
        layers.push(LayerCache {
            x_in: x,
            ln1,
            xn1_q,
            heads,
            ctx_q,
            ln2,
            xn2_q,
            hpre,
            hact_q,
        });
        x = x_out;
    }
    let (xnf, lnf) = layer_norm_fwd(&x, &p.lnf_g, &p.lnf_b, cfg.ln_eps);
    let logits = matmul_bt(&xnf, &p.tok_emb);
    // stash final x in a dummy layer? keep via lnf cache: xhat suffices + x
    FwdCache {
        tokens: tokens.to_vec(),
        layers,
        lnf,
        xnf,
        logits,
    }
}

/// Mean cross-entropy loss and full backward pass (uniform position weights).
pub fn backward(p: &Params, plan: &QuantPlan, cache: &FwdCache, targets: &[usize]) -> (f64, Grads) {
    backward_weighted(p, plan, cache, targets, None)
}

/// Weighted-CE backward: `weights[i]` scales position i's loss (e.g. answer-
/// only fine-tuning puts all mass on the label token). Loss is the weighted
/// mean; `None` = uniform.
pub fn backward_weighted(
    p: &Params,
    plan: &QuantPlan,
    cache: &FwdCache,
    targets: &[usize],
    weights: Option<&[f32]>,
) -> (f64, Grads) {
    let cfg = &p.cfg;
    let (s, _d) = cache.logits.dims2();
    assert_eq!(targets.len(), s);
    let mut g = Grads::zeros(p);
    // dlogits = (softmax - onehot)/s ; loss = mean CE
    let mut dlogits = cache.logits.clone();
    let mut loss = 0.0f64;
    {
        let v = cfg.vocab_size;
        let wsum: f64 = match weights {
            Some(w) => {
                assert_eq!(w.len(), s);
                w.iter().map(|&x| x as f64).sum::<f64>().max(1e-12)
            }
            None => s as f64,
        };
        for i in 0..s {
            let wi = weights.map(|w| w[i]).unwrap_or(1.0);
            let row = dlogits.row_mut(i);
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0f64;
            for x in row.iter_mut() {
                *x = (*x - m).exp();
                sum += *x as f64;
            }
            let t = targets[i];
            assert!(t < v);
            loss += wi as f64 * (sum.ln() + m as f64 - cache.logits.row(i)[t] as f64);
            let inv = (1.0 / sum) as f32 * wi / wsum as f32;
            for x in row.iter_mut() {
                *x *= inv;
            }
            row[t] -= wi / wsum as f32;
        }
        loss /= wsum;
    }
    // logits = xnf @ E^T: dxnf = dlogits @ E ; dE += dlogits^T @ xnf
    let dxnf = matmul(&dlogits, &p.tok_emb);
    g.tok_emb.add_assign(&matmul(&dlogits.t(), &cache.xnf));
    // final LN
    let mut dx = layer_norm_bwd(&dxnf, &cache.lnf, &p.lnf_g, &mut g.lnf_g, &mut g.lnf_b);

    let h = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    for li in (0..cfg.n_layers).rev() {
        let l = &p.layers[li];
        let lc = &cache.layers[li];
        let lg = &mut g.layers[li];
        // ---- MLP backward ----
        let q7 = plan.site(li, 7);
        let q8 = plan.site(li, 8);
        // x_out = x_mid + hact_q @ w2 + b2
        let dmlp = &dx; // gradient into mlp_out equals dx
        col_sums(dmlp, &mut lg.b2);
        // w2 quantised as fq(w2^T)^T; STE: dw2 = hact_q^T @ dmlp
        lg.w2.add_assign(&matmul(&lc.hact_q.t(), dmlp));
        let dhact = matmul(dmlp, &fq(&l.w2.t(), q8.weight)); // dmlp @ w2q^T
        // gelu backward (STE through hact quantisation)
        let mut dhpre = dhact;
        for (gd, &xp) in dhpre.data.iter_mut().zip(&lc.hpre.data) {
            *gd *= gelu_grad(xp);
        }
        col_sums(&dhpre, &mut lg.b1);
        lg.w1.add_assign(&matmul(&lc.xn2_q.t(), &dhpre));
        let dxn2 = matmul(&dhpre, &fq(&l.w1.t(), q7.weight));
        let dx_mid_ln = layer_norm_bwd(&dxn2, &lc.ln2, &l.ln2_g, &mut lg.ln2_g, &mut lg.ln2_b);
        let mut dx_mid = dx.clone(); // residual
        dx_mid.add_assign(&dx_mid_ln);

        // ---- attention backward ----
        let q6 = plan.site(li, 6);
        // att_out = ctx_q @ wo + bo, x_mid = x_in + att_out
        col_sums(&dx_mid, &mut lg.bo);
        lg.wo.add_assign(&matmul(&lc.ctx_q.t(), &dx_mid));
        let dctx = matmul(&dx_mid, &fq(&l.wo.t(), q6.weight));
        // per-head
        let q45 = (plan.site(li, 4), plan.site(li, 5));
        let (sdim, d) = lc.x_in.dims2();
        let mut dq = Tensor::zeros(&[sdim, d]);
        let mut dk = Tensor::zeros(&[sdim, d]);
        let mut dv = Tensor::zeros(&[sdim, d]);
        let _ = q45;
        for hi in 0..h {
            let hc = &lc.heads[hi];
            let dctx_h = slice_head(&dctx, hi, hd);
            // ctx_h = a_q @ vh_q
            let da = matmul_bt(&dctx_h, &hc.vh_q); // dctx_h @ vh_qᵀ
            let dvh = matmul(&hc.a_q.t(), &dctx_h);
            // softmax backward
            let mut ds = da;
            for i in 0..sdim {
                let arow = hc.a.row(i);
                let dsrow = ds.row_mut(i);
                let dot: f32 = arow.iter().zip(dsrow.iter()).map(|(&a, &d)| a * d).sum();
                for j in 0..sdim {
                    dsrow[j] = arow[j] * (dsrow[j] - dot);
                }
            }
            // scores = qh_q(scaled) @ kh_q^T
            let dqh_scaled = matmul(&ds, &hc.kh_q);
            let dkh = matmul(&ds.t(), &hc.qh_q); // note qh_q already includes scale
            let mut dqh = dqh_scaled;
            for x in dqh.data.iter_mut() {
                *x *= scale;
            }
            unslice_head_add(&mut dq, &dqh, hi, hd);
            unslice_head_add(&mut dk, &dkh, hi, hd);
            unslice_head_add(&mut dv, &dvh, hi, hd);
        }
        // projections: q = xn1_q0 @ wq + bq etc.
        col_sums(&dq, &mut lg.bq);
        col_sums(&dk, &mut lg.bk);
        col_sums(&dv, &mut lg.bv);
        lg.wq.add_assign(&matmul(&lc.xn1_q[0].t(), &dq));
        lg.wk.add_assign(&matmul(&lc.xn1_q[1].t(), &dk));
        lg.wv.add_assign(&matmul(&lc.xn1_q[2].t(), &dv));
        let q1 = plan.site(li, 1);
        let q2 = plan.site(li, 2);
        let q3 = plan.site(li, 3);
        let mut dxn1 = matmul(&dq, &fq(&l.wq.t(), q1.weight));
        dxn1.add_assign(&matmul(&dk, &fq(&l.wk.t(), q2.weight)));
        dxn1.add_assign(&matmul(&dv, &fq(&l.wv.t(), q3.weight)));
        let dx_ln1 = layer_norm_bwd(&dxn1, &lc.ln1, &l.ln1_g, &mut lg.ln1_g, &mut lg.ln1_b);
        dx = dx_mid;
        dx.add_assign(&dx_ln1);
    }
    // embeddings
    for (i, &t) in cache.tokens.iter().enumerate() {
        let dr = dx.row(i);
        let er = g.tok_emb.row_mut(t);
        for (a, &b) in er.iter_mut().zip(dr) {
            *a += b;
        }
        let pr = g.pos_emb.row_mut(i);
        for (a, &b) in pr.iter_mut().zip(dr) {
            *a += b;
        }
    }
    (loss, g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::plan::QuantPlan;
    use crate::model::Model;
    use crate::quant::config::presets;

    fn setup(plan: &QuantPlan) -> (Params, Vec<usize>, Vec<usize>) {
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 17);
        let _ = plan;
        (p, vec![3, 7, 42, 9, 100, 5], vec![7, 42, 9, 100, 5, 11])
    }

    #[test]
    fn train_forward_matches_inference_fp32() {
        let plan = QuantPlan::fp32();
        let (p, toks, _) = setup(&plan);
        let cache = forward_train(&p, &plan, &toks);
        let m = Model::new(p, plan);
        let inf = m.forward(&toks, None);
        for (a, b) in cache.logits.data.iter().zip(&inf.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn train_forward_matches_inference_quantised() {
        let plan = QuantPlan::uniform(presets::bfp_w(6));
        let (p, toks, _) = setup(&plan);
        let cache = forward_train(&p, &plan, &toks);
        let m = Model::new(p, plan);
        let inf = m.forward(&toks, None);
        for (a, b) in cache.logits.data.iter().zip(&inf.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    /// Finite-difference check on a sample of parameters.
    fn grad_check(plan: QuantPlan, tol: f64) {
        let (mut p, toks, tgts) = setup(&plan);
        let cache = forward_train(&p, &plan, &toks);
        let (_, grads) = backward(&p, &plan, &cache, &tgts);
        let eps = 2e-3f32;
        // sample a few parameter coordinates from distinct buffers
        let samples: Vec<(usize, usize)> = vec![
            (2, 5),   // layer0.wq some element (flat index order)
            (14, 3),  // layer0.w1
            (0, 77),  // tok_emb
            (33, 2),  // lnf_g is near the end; resolved below
        ];
        let loss_at = |p: &Params| -> f64 {
            let c = forward_train(p, &plan, &toks);
            let mut dl = c.logits.clone();
            let s = tgts.len();
            let mut loss = 0.0f64;
            for i in 0..s {
                let row = dl.row_mut(i);
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let sum: f64 = row.iter().map(|&x| ((x - m) as f64).exp()).sum();
                loss += sum.ln() + m as f64 - row[tgts[i]] as f64;
            }
            loss / s as f64
        };
        let mut grads = grads;
        let gviews = grads.flat_views_mut();
        let n_bufs = gviews.len();
        drop(gviews);
        for (bi, ei) in samples {
            let bi = bi % n_bufs;
            // read analytic grad
            let ga = {
                let mut gv = grads.flat_views_mut();
                let buf = &mut gv[bi];
                if buf.is_empty() {
                    continue;
                }
                buf[ei % buf.len()] as f64
            };
            // numeric grad
            let (orig, idx) = {
                let mut pv = p.flat_views_mut();
                let buf = &mut pv[bi].1;
                let idx = ei % buf.len();
                let orig = buf[idx];
                buf[idx] = orig + eps;
                (orig, idx)
            };
            let lp = loss_at(&p);
            {
                let mut pv = p.flat_views_mut();
                pv[bi].1[idx] = orig - eps;
            }
            let lm = loss_at(&p);
            {
                let mut pv = p.flat_views_mut();
                pv[bi].1[idx] = orig;
            }
            let gn = (lp - lm) / (2.0 * eps as f64);
            let denom = ga.abs().max(gn.abs()).max(1e-4);
            assert!(
                (ga - gn).abs() / denom < tol,
                "buf {bi} idx {idx}: analytic {ga} vs numeric {gn}"
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences_fp32() {
        grad_check(QuantPlan::fp32(), 0.08);
    }

    #[test]
    fn ste_gradients_align_with_fp32_gradients() {
        // Finite differences cannot see through the quantiser's staircase,
        // so we instead check the STE property directly: at 8-bit BFP the
        // STE gradient field should be strongly aligned with the FP32
        // gradient field (the quantiser is near-identity).
        let (p, toks, tgts) = setup(&QuantPlan::fp32());
        let plan32 = QuantPlan::fp32();
        let plan8 = QuantPlan::uniform(presets::bfp_w(8));
        let c32 = forward_train(&p, &plan32, &toks);
        let (_, mut g32) = backward(&p, &plan32, &c32, &tgts);
        let c8 = forward_train(&p, &plan8, &toks);
        let (_, mut g8) = backward(&p, &plan8, &c8, &tgts);
        let a = &g32.layers[0].wq.data;
        let b = &g8.layers[0].wq.data;
        let dot: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
        let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        let nb: f64 = b.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        let cos = dot / (na * nb).max(1e-12);
        assert!(cos > 0.95, "cosine {cos}");
        let _ = (g32.global_norm(), g8.global_norm());
    }

    #[test]
    fn loss_decreases_with_sgd_steps() {
        let plan = QuantPlan::fp32();
        let (mut p, toks, tgts) = setup(&plan);
        let mut losses = Vec::new();
        for _ in 0..8 {
            let cache = forward_train(&p, &plan, &toks);
            let (loss, mut grads) = backward(&p, &plan, &cache, &tgts);
            losses.push(loss);
            let lr = 0.25f32;
            let gv: Vec<Vec<f32>> = {
                let mut gvm = grads.flat_views_mut();
                gvm.iter_mut().map(|b| b.to_vec()).collect()
            };
            for (pb, gb) in p.flat_views_mut().into_iter().zip(gv) {
                for (w, g) in pb.1.iter_mut().zip(gb) {
                    *w -= lr * g;
                }
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] - 0.05),
            "losses {losses:?}"
        );
    }
}
