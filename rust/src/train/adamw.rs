//! AdamW optimizer over the flat parameter views.

use super::backward::Grads;
use crate::model::params::Params;

#[derive(Clone, Debug)]
pub struct AdamWConfig {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f64,
}

impl Default for AdamWConfig {
    fn default() -> Self {
        AdamWConfig {
            lr: 3e-3,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.01,
            grad_clip: 1.0,
        }
    }
}

pub struct AdamW {
    pub cfg: AdamWConfig,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
    /// buffer names, to skip weight decay on norms/biases
    decay_mask: Vec<bool>,
}

impl AdamW {
    pub fn new(params: &Params, cfg: AdamWConfig) -> AdamW {
        let views = params.flat_views();
        let m = views.iter().map(|(_, v)| vec![0.0; v.len()]).collect();
        let v = views.iter().map(|(_, v)| vec![0.0; v.len()]).collect();
        let decay_mask = views
            .iter()
            .map(|(name, _)| {
                // decay weights only (matrices), not LN gains/biases
                name.contains(".w") || name.ends_with("emb")
            })
            .collect();
        AdamW {
            cfg,
            m,
            v,
            t: 0,
            decay_mask,
        }
    }

    pub fn step(&mut self, params: &mut Params, grads: &mut Grads) {
        self.t += 1;
        // global-norm clip
        if self.cfg.grad_clip > 0.0 {
            let gn = grads.global_norm();
            if gn > self.cfg.grad_clip {
                grads.scale((self.cfg.grad_clip / gn) as f32);
            }
        }
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bias1 = 1.0 - b1.powi(self.t as i32);
        let bias2 = 1.0 - b2.powi(self.t as i32);
        let lr = self.cfg.lr;
        let gviews = grads.flat_views_mut();
        let pviews = params.flat_views_mut();
        for (bi, ((_, pbuf), gbuf)) in pviews.into_iter().zip(gviews).enumerate() {
            let m = &mut self.m[bi];
            let v = &mut self.v[bi];
            let decay = if self.decay_mask[bi] {
                self.cfg.weight_decay
            } else {
                0.0
            };
            for i in 0..pbuf.len() {
                let g = gbuf[i];
                m[i] = b1 * m[i] + (1.0 - b1) * g;
                v[i] = b2 * v[i] + (1.0 - b2) * g * g;
                let mhat = m[i] / bias1;
                let vhat = v[i] / bias2;
                pbuf[i] -= lr * (mhat / (vhat.sqrt() + self.cfg.eps) + decay * pbuf[i]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::plan::QuantPlan;
    use crate::train::backward::{backward, forward_train};

    #[test]
    fn adamw_reduces_loss() {
        let cfg = ModelConfig::preset("nano");
        let mut p = Params::init(&cfg, 23);
        let plan = QuantPlan::fp32();
        let toks = vec![4usize, 8, 15, 16, 23, 42, 4, 8];
        let tgts = vec![8usize, 15, 16, 23, 42, 4, 8, 15];
        let mut opt = AdamW::new(&p, AdamWConfig::default());
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..12 {
            let cache = forward_train(&p, &plan, &toks);
            let (loss, mut grads) = backward(&p, &plan, &cache, &tgts);
            if step == 0 {
                first = loss;
            }
            last = loss;
            opt.step(&mut p, &mut grads);
        }
        assert!(last < first - 0.5, "first {first} last {last}");
    }

    #[test]
    fn grad_clip_bounds_update() {
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 23);
        let mut grads = crate::train::backward::Grads::zeros(&p);
        // enormous gradient in one buffer
        grads.tok_emb.data[0] = 1e9;
        let gn_before = grads.global_norm();
        assert!(gn_before > 1e8);
        let mut p2 = p.clone();
        let mut opt = AdamW::new(&p2, AdamWConfig::default());
        opt.step(&mut p2, &mut grads);
        assert!(grads.global_norm() <= 1.0 + 1e-3);
    }
}
