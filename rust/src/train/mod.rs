//! Training substrate: manual backprop with STE (backward.rs), AdamW
//! (adamw.rs), and the two fine-tuning recipes compared in the paper's
//! §4.3 / Table 8:
//!
//! * **PTQ on fine-tuned FP32** — fine-tune in FP32, quantise afterwards;
//! * **TAQ on downstream** — quantise first, fine-tune the quantised model
//!   through the STE.

pub mod adamw;
pub mod backward;

pub use adamw::{AdamW, AdamWConfig};
pub use backward::{backward, backward_weighted, forward_train, Grads};

use crate::data::tasks::{finetune_sequences, Example};
use crate::model::params::Params;
use crate::model::plan::QuantPlan;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub seq_len: usize,
    pub lr: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            seq_len: 64,
            lr: 3e-3,
            seed: 7,
            log_every: 50,
        }
    }
}

/// Language-model training on a token stream. Returns the loss curve.
pub fn train_lm(
    params: &mut Params,
    plan: &QuantPlan,
    stream: &[usize],
    cfg: &TrainConfig,
    mut on_log: impl FnMut(usize, f64),
) -> Vec<f64> {
    let mut opt = AdamW::new(
        params,
        AdamWConfig {
            lr: cfg.lr,
            ..Default::default()
        },
    );
    let mut rng = Pcg32::new(cfg.seed);
    let mut losses = Vec::with_capacity(cfg.steps);
    let span = cfg.seq_len + 1;
    assert!(stream.len() > span, "stream too short");
    for step in 0..cfg.steps {
        // cosine decay to 10% of the base LR (stabilises the longer runs)
        let prog = step as f32 / cfg.steps.max(1) as f32;
        opt.cfg.lr = cfg.lr * (0.1 + 0.9 * 0.5 * (1.0 + (std::f32::consts::PI * prog).cos()));
        let start = rng.below(stream.len() - span);
        let chunk = &stream[start..start + span];
        let cache = forward_train(params, plan, &chunk[..cfg.seq_len]);
        let (loss, mut grads) = backward(params, plan, &cache, &chunk[1..]);
        opt.step(params, &mut grads);
        losses.push(loss);
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            on_log(step, loss);
        }
    }
    losses
}

/// Fine-tune on task examples (prompt+answer sequences) for `epochs`
/// passes. Loss is computed over the whole sequence (LM-style), which is
/// what makes label words more likely (paper fine-tunes OPT the same way
/// modulo a classification head).
pub fn finetune_task(
    params: &mut Params,
    plan: &QuantPlan,
    examples: &[Example],
    epochs: usize,
    lr: f32,
    seed: u64,
) -> Vec<f64> {
    let seqs = finetune_sequences(examples);
    let answer_lens: Vec<usize> = examples
        .iter()
        .map(|e| e.choices[e.label].len())
        .collect();
    let mut rng = Pcg32::new(seed);
    let mut epoch_losses = Vec::new();
    let mut order: Vec<usize> = (0..seqs.len()).collect();
    for _ in 0..epochs {
        // warm-restart the optimizer each epoch: with few examples the
        // accumulated second moments otherwise shrink the effective step
        // and fine-tuning stalls on a plateau (empirically verified)
        let mut opt = AdamW::new(
            params,
            AdamWConfig {
                lr,
                weight_decay: 0.0,
                ..Default::default()
            },
        );
        rng.shuffle(&mut order);
        let mut total = 0.0;
        for &i in &order {
            let s = &seqs[i];
            if s.len() < 2 {
                continue;
            }
            let cache = forward_train(params, plan, &s[..s.len() - 1]);
            // emphasise the answer token(s): the classification signal —
            // prompts are high-entropy templates we don't need to model
            let n = s.len() - 1;
            let mut w = vec![0.1f32; n];
            let answer_len = answer_lens[i].min(n);
            for x in w[n - answer_len..].iter_mut() {
                *x = 1.0;
            }
            let (loss, mut grads) =
                backward_weighted(params, plan, &cache, &s[1..], Some(&w));
            opt.step(params, &mut grads);
            total += loss;
        }
        epoch_losses.push(total / seqs.len() as f64);
    }
    epoch_losses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::train_stream;
    use crate::data::tasks::{evaluate, generate, Task};
    use crate::data::vocab::Vocab;
    use crate::model::config::ModelConfig;
    use crate::model::Model;

    #[test]
    fn lm_training_reduces_loss() {
        let v = Vocab::build();
        let stream = train_stream(&v, 4000);
        let cfg = ModelConfig::preset("nano");
        let mut p = Params::init(&cfg, 3);
        let losses = train_lm(
            &mut p,
            &QuantPlan::fp32(),
            &stream,
            &TrainConfig {
                steps: 60,
                seq_len: 32,
                lr: 3e-3,
                seed: 1,
                log_every: 0,
            },
            |_, _| {},
        );
        let head: f64 = losses[..10].iter().sum::<f64>() / 10.0;
        let tail: f64 = losses[losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(tail < head - 0.8, "head {head} tail {tail}");
    }

    #[test]
    fn finetune_improves_task_accuracy() {
        // a tiny randomly-initialised model can still learn the SST2
        // template mapping from a few hundred examples
        let v = Vocab::build();
        let cfg = ModelConfig::preset("nano");
        let mut p = Params::init(&cfg, 5);
        let train = generate(Task::Sst2, &v, 100, 240);
        let test = generate(Task::Sst2, &v, 200, 60);
        let before = {
            let m = Model::new(p.clone(), QuantPlan::fp32());
            evaluate(&m, Task::Sst2, &test, 2).accuracy
        };
        finetune_task(&mut p, &QuantPlan::fp32(), &train, 6, 4e-3, 9);
        let after = {
            let m = Model::new(p, QuantPlan::fp32());
            evaluate(&m, Task::Sst2, &test, 2).accuracy
        };
        assert!(after > 0.85, "before {before} after {after}");
    }
}
