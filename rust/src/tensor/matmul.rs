//! Blocked GEMM — the f32 hot path under every quantised GEMM.
//!
//! `matmul(a, b)` computes `a @ b` for 2-D tensors with an i-k-j loop order
//! (unit-stride inner loop over B's rows), 4-wide k unrolling and cache
//! blocking. Multi-threaded for large problems via the shared persistent
//! worker pool in [`crate::runtime::pool`] (no rayon in this environment).

use super::Tensor;
pub(crate) use crate::runtime::pool::available_threads;
use crate::runtime::pool::par_rows;

/// Threshold (in MACs) above which we spawn threads.
pub(crate) const PAR_THRESHOLD: usize = 1 << 21;

/// C = A @ B, A: [m,k], B: [k,n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let threads = available_threads();
    if m * n * k >= PAR_THRESHOLD && threads > 1 && m >= 2 {
        par_rows(&mut out, m, threads, |rows, out_chunk| {
            gemm_rows(&a.data, &b.data, out_chunk, rows, k, n);
        });
    } else {
        gemm_rows(&a.data, &b.data, &mut out, 0..m, k, n);
    }
    Tensor::new(&[m, n], out)
}

/// C = A @ B^T, A: [m,k], B: [n,k] (used for QK^T and weight-transposed GEMMs).
///
/// For multi-row A this transposes B once (O(nk)) and reuses the fast
/// broadcast kernel — ~3× faster than dot-product accumulation, which is
/// loop-carried-dependency bound (§Perf log in EXPERIMENTS.md). Single-row
/// A (incremental decode) keeps the dot path: the transpose would not be
/// amortised.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (_, k2) = b.dims2();
    assert_eq!(k, k2, "matmul_bt inner dims: {k} vs {k2}");
    if m >= 4 {
        return matmul(a, &b.t());
    }
    matmul_bt_rowwise(a, b)
}

/// C = A @ B^T like [`matmul_bt`], but every output row accumulates in
/// exactly the order the m == 1 path uses (the 1×4 panel kernel of
/// `gemm_bt_rows`), for *any* m. The batched decode engine uses this so a
/// batch-of-N decode step is bit-identical, row for row, to N sequential
/// single-row steps — the broadcast kernel `matmul_bt` switches to at
/// m ≥ 4 sums in a different order and would break that guarantee.
pub fn matmul_bt_rowwise(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (n, k2) = b.dims2();
    assert_eq!(k, k2, "matmul_bt_rowwise inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let threads = available_threads();
    if m * n * k >= PAR_THRESHOLD && threads > 1 && m >= 2 {
        // row partitioning leaves each row's summation order untouched
        par_rows(&mut out, m, threads, |rows, out_chunk| {
            gemm_bt_rows(&a.data, &b.data, out_chunk, rows, k, n);
        });
    } else {
        gemm_bt_rows(&a.data, &b.data, &mut out, 0..m, k, n);
    }
    Tensor::new(&[m, n], out)
}

/// Row-major inner GEMM over a row range. `out` addresses rows relative to
/// `rows.start`, and must be zeroed by the caller (the kernel accumulates).
/// pub(crate): the fused packed prefill GEMM in `quant::qmatmul` and the
/// shared attention body in `model::attention` stream panels through this
/// exact kernel so their summation order — and therefore their bits —
/// match the dense broadcast path.
pub(crate) fn gemm_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: std::ops::Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        // k unrolled by 4: accumulate b rows scaled by a[i][k..k+4]
        let mut kk = 0;
        while kk + 4 <= k {
            let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b[(kk + 3) * n..(kk + 4) * n];
            for j in 0..n {
                orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        while kk < k {
            let av = arow[kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
            kk += 1;
        }
    }
}

/// out[i][j] = dot(a_row_i, b_row_j); both rows contiguous.
/// 1×4 panel micro-kernel: four B rows share each A load, which roughly
/// triples throughput over a scalar dot loop (§Perf, EXPERIMENTS.md).
/// pub(crate): the fused packed-weight GEMM in `quant::qmatmul` streams
/// dequantised row panels through this exact kernel so its summation
/// order — and therefore its bits — match the dense path.
pub(crate) fn gemm_bt_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: std::ops::Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (idx, &av) in arow.iter().enumerate() {
                s0 += av * b0[idx];
                s1 += av * b1[idx];
                s2 += av * b2[idx];
                s3 += av * b3[idx];
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            orow[j + 2] = s2;
            orow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            orow[j] = dot(arow, &b[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

/// 4-accumulator dot product (auto-vectorises well).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += x[i] * y[i];
        acc[1] += x[i + 1] * y[i + 1];
        acc[2] += x[i + 2] * y[i + 2];
        acc[3] += x[i + 3] * y[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Naive reference for testing the optimized paths.
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (_, n) = b.dims2();
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f64;
            for kk in 0..k {
                s += a.data[i * k + kk] as f64 * b.data[kk * n + j] as f64;
            }
            out[i * n + j] = s as f32;
        }
    }
    Tensor::new(&[m, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, close_slice};
    use crate::util::rng::Pcg32;

    #[test]
    fn small_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]);
        assert_eq!(matmul(&a, &b).data, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn matches_naive_random() {
        check("matmul==naive", 25, |rng| {
            let m = 1 + rng.below(17);
            let k = 1 + rng.below(33);
            let n = 1 + rng.below(17);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let fast = matmul(&a, &b);
            let slow = matmul_naive(&a, &b);
            close_slice(&fast.data, &slow.data, 1e-4, "matmul")
        });
    }

    #[test]
    fn bt_matches_transpose() {
        check("matmul_bt==matmul(t)", 25, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(33);
            let n = 1 + rng.below(9);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[n, k], 1.0, rng);
            let direct = matmul_bt(&a, &b);
            let via_t = matmul(&a, &b.t());
            close_slice(&direct.data, &via_t.data, 1e-4, "matmul_bt")
        });
    }

    #[test]
    fn parallel_path_matches() {
        // force the parallel path with a big-enough problem
        let mut rng = Pcg32::new(4);
        let a = Tensor::randn(&[96, 256], 1.0, &mut rng);
        let b = Tensor::randn(&[256, 128], 1.0, &mut rng);
        let fast = matmul(&a, &b);
        let slow = matmul_naive(&a, &b);
        close_slice(&fast.data, &slow.data, 1e-3, "parallel").unwrap();
    }

    #[test]
    fn rowwise_bt_is_bitwise_per_row() {
        // each row of the batched result must equal the m == 1 result bit
        // for bit — the guarantee the batched decode engine builds on
        check("rowwise == per-row m1", 20, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(65);
            let n = 1 + rng.below(17);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[n, k], 1.0, rng);
            let batched = matmul_bt_rowwise(&a, &b);
            for i in 0..m {
                let ai = Tensor::new(&[1, k], a.row(i).to_vec());
                let single = matmul_bt(&ai, &b);
                if batched.row(i) != single.row(0) {
                    return Err(format!("row {i} diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1., 2., 3., 4., 5.], &[1., 1., 1., 1., 1.]), 15.0);
    }
}
