//! Blocked GEMM — the f32 hot path under every quantised GEMM.
//!
//! The inner loops live in [`crate::kernels`], which dispatches at runtime
//! to the best SIMD backend (AVX2/NEON, scalar reference) — all backends
//! bit-identical, so everything asserted about these entry points holds on
//! every ISA. This module owns the shape policy: which kernel a given
//! (m, k, n) routes to, and when the persistent worker pool
//! ([`crate::runtime::pool`]) splits rows across threads.
//!
//! Public entry points and their shape regimes:
//! - [`matmul`] — general `A @ B`, column-panel friendly (prefill).
//! - [`matmul_bt`] — `A @ Bᵀ`, switching regime on m (prefill vs decode).
//! - [`matmul_bt_rowwise`] — `A @ Bᵀ` with per-row order pinned to the
//!   m == 1 decode path (row-wise batched decode).

use super::Tensor;
use crate::kernels::{gemm_bt_rows, gemm_rows};
pub(crate) use crate::runtime::pool::available_threads;
use crate::runtime::pool::par_rows;

/// Threshold (in MACs) above which we spawn threads.
pub(crate) const PAR_THRESHOLD: usize = 1 << 21;

/// C = A @ B, A: [m,k], B: [k,n].
///
/// Shape regime: the column-panel prefill kernel — row-major broadcast
/// accumulation over B rows, threaded across A rows for large problems.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let threads = available_threads();
    if m * n * k >= PAR_THRESHOLD && threads > 1 && m >= 2 {
        par_rows(&mut out, m, threads, |rows, out_chunk| {
            gemm_rows(&a.data, &b.data, out_chunk, rows, k, n);
        });
    } else {
        gemm_rows(&a.data, &b.data, &mut out, 0..m, k, n);
    }
    Tensor::new(&[m, n], out)
}

/// C = A @ B^T, A: [m,k], B: [n,k] (used for QK^T and weight-transposed GEMMs).
///
/// Shape regime split: m ≥ 4 (column-panel prefill) transposes B once
/// (O(nk)) and reuses the broadcast kernel, which amortises memory traffic
/// across rows; m < 4 (decode, typically m == 1) keeps the dot-product
/// path where the transpose would not be amortised.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (_, k2) = b.dims2();
    assert_eq!(k, k2, "matmul_bt inner dims: {k} vs {k2}");
    if m >= 4 {
        return matmul(a, &b.t());
    }
    matmul_bt_rowwise(a, b)
}

/// C = A @ B^T like [`matmul_bt`], but every output row accumulates in
/// exactly the order the m == 1 path uses (one [`crate::kernels::dot`] per
/// output element), for *any* m.
///
/// Shape regime: row-wise batched decode. The batched decode engine uses
/// this so a batch-of-N decode step is bit-identical, row for row, to N
/// sequential single-row steps — the broadcast kernel `matmul_bt` switches
/// to at m ≥ 4 sums in a different order and would break that guarantee.
pub fn matmul_bt_rowwise(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (n, k2) = b.dims2();
    assert_eq!(k, k2, "matmul_bt_rowwise inner dims: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let threads = available_threads();
    if m * n * k >= PAR_THRESHOLD && threads > 1 && m >= 2 {
        // row partitioning leaves each row's summation order untouched
        par_rows(&mut out, m, threads, |rows, out_chunk| {
            gemm_bt_rows(&a.data, &b.data, out_chunk, rows, k, n);
        });
    } else {
        gemm_bt_rows(&a.data, &b.data, &mut out, 0..m, k, n);
    }
    Tensor::new(&[m, n], out)
}

/// Naive reference for testing the optimized paths.
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (_, n) = b.dims2();
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f64;
            for kk in 0..k {
                s += a.data[i * k + kk] as f64 * b.data[kk * n + j] as f64;
            }
            out[i * n + j] = s as f32;
        }
    }
    Tensor::new(&[m, n], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, close_slice};
    use crate::util::rng::Pcg32;

    #[test]
    fn small_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]);
        assert_eq!(matmul(&a, &b).data, vec![3., 3., 7., 7.]);
    }

    #[test]
    fn matches_naive_random() {
        check("matmul==naive", 25, |rng| {
            let m = 1 + rng.below(17);
            let k = 1 + rng.below(33);
            let n = 1 + rng.below(17);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let fast = matmul(&a, &b);
            let slow = matmul_naive(&a, &b);
            close_slice(&fast.data, &slow.data, 1e-4, "matmul")
        });
    }

    #[test]
    fn bt_matches_transpose() {
        check("matmul_bt==matmul(t)", 25, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(33);
            let n = 1 + rng.below(9);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[n, k], 1.0, rng);
            let direct = matmul_bt(&a, &b);
            let via_t = matmul(&a, &b.t());
            close_slice(&direct.data, &via_t.data, 1e-4, "matmul_bt")
        });
    }

    #[test]
    fn parallel_path_matches() {
        // force the parallel path with a big-enough problem
        let mut rng = Pcg32::new(4);
        let a = Tensor::randn(&[96, 256], 1.0, &mut rng);
        let b = Tensor::randn(&[256, 128], 1.0, &mut rng);
        let fast = matmul(&a, &b);
        let slow = matmul_naive(&a, &b);
        close_slice(&fast.data, &slow.data, 1e-3, "parallel").unwrap();
    }

    #[test]
    fn rowwise_bt_is_bitwise_per_row() {
        // each row of the batched result must equal the m == 1 result bit
        // for bit — the guarantee the batched decode engine builds on
        check("rowwise == per-row m1", 20, |rng| {
            let m = 1 + rng.below(9);
            let k = 1 + rng.below(65);
            let n = 1 + rng.below(17);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[n, k], 1.0, rng);
            let batched = matmul_bt_rowwise(&a, &b);
            for i in 0..m {
                let ai = Tensor::new(&[1, k], a.row(i).to_vec());
                let single = matmul_bt(&ai, &b);
                if batched.row(i) != single.row(0) {
                    return Err(format!("row {i} diverged"));
                }
            }
            Ok(())
        });
    }
}
