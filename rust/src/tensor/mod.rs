//! Dense f32 tensor substrate.
//!
//! Deliberately minimal: row-major, owned storage, the op set the OPT-style
//! decoder and the quantisers need. Heavy lifting (GEMM) lives in
//! [`matmul`]; everything here is correctness-first.

pub mod matmul;

use crate::util::rng::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data len {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    /// N(0, sigma) init.
    pub fn randn(shape: &[usize], sigma: f32, rng: &mut Pcg32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| rng.normal_with(0.0, sigma)).collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "expected 2-D tensor, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let (_, c) = self.dims2();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.shape[self.rank() - 1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = vec![0.0f32; r * c];
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..r).step_by(B) {
            for j0 in (0..c).step_by(B) {
                for i in i0..(i0 + B).min(r) {
                    for j in j0..(j0 + B).min(c) {
                        out[j * r + i] = self.data[i * c + j];
                    }
                }
            }
        }
        Tensor::new(&[c, r], out)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a + b)
            .collect();
        Tensor {
            shape: self.shape.clone(),
            data,
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// Broadcast-add a vector over the last dimension (bias add).
    pub fn add_bias(&self, bias: &[f32]) -> Tensor {
        let c = *self.shape.last().unwrap();
        assert_eq!(bias.len(), c);
        let mut out = self.clone();
        for chunk in out.data.chunks_mut(c) {
            for (x, &b) in chunk.iter_mut().zip(bias) {
                *x += b;
            }
        }
        out
    }

    /// Row-wise softmax over the last dim, in place.
    pub fn softmax_rows(&mut self) {
        let c = *self.shape.last().unwrap();
        for chunk in self.data.chunks_mut(c) {
            let m = chunk.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0f32;
            for x in chunk.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            let inv = 1.0 / sum.max(1e-30);
            for x in chunk.iter_mut() {
                *x *= inv;
            }
        }
    }

    /// LayerNorm over last dim with gain/bias.
    pub fn layer_norm(&self, gain: &[f32], bias: &[f32], eps: f32) -> Tensor {
        let c = *self.shape.last().unwrap();
        assert_eq!(gain.len(), c);
        assert_eq!(bias.len(), c);
        let mut out = self.clone();
        for chunk in out.data.chunks_mut(c) {
            let mean: f32 = chunk.iter().sum::<f32>() / c as f32;
            let var: f32 = chunk.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / c as f32;
            let inv = 1.0 / (var + eps).sqrt();
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (*x - mean) * inv * gain[j] + bias[j];
            }
        }
        out
    }

    /// GELU (tanh approximation, matches jax.nn.gelu default).
    pub fn gelu(&self) -> Tensor {
        self.map(gelu_scalar)
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }

    /// Max |x|.
    pub fn abs_max(&self) -> f32 {
        crate::util::stats::abs_max(&self.data)
    }
}

#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.t().t();
        assert_eq!(t, tt);
        assert_eq!(t.t().row(0), &[1., 4.]);
    }

    #[test]
    fn softmax_rows_normalises() {
        let mut t = Tensor::new(&[2, 3], vec![0., 1., 2., -1., 0., 1.]);
        t.softmax_rows();
        for i in 0..2 {
            let s: f32 = t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(t.row(0)[2] > t.row(0)[0]);
    }

    #[test]
    fn layernorm_standardises() {
        let t = Tensor::new(&[1, 4], vec![1., 2., 3., 4.]);
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let n = t.layer_norm(&g, &b, 1e-5);
        let mean: f32 = n.data.iter().sum::<f32>() / 4.0;
        let var: f32 = n.data.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_known_points() {
        assert!(gelu_scalar(0.0).abs() < 1e-7);
        assert!((gelu_scalar(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_scalar(-10.0).abs() < 1e-4);
    }

    #[test]
    fn bias_add_broadcasts() {
        let t = Tensor::zeros(&[2, 3]).add_bias(&[1., 2., 3.]);
        assert_eq!(t.row(1), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Tensor::new(&[2, 2], vec![1.0]);
    }
}
