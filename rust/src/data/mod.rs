//! Data substrate: lexicon/tokenizer, synthetic corpus (WikiText-2
//! substitute), LM perplexity evaluation, and the eight downstream tasks.

pub mod corpus;
pub mod lm_eval;
pub mod tasks;
pub mod vocab;

pub use corpus::{test_stream, train_stream};
pub use lm_eval::{completion_logprob, perplexity, perplexity_par, PplResult};
pub use tasks::{evaluate, generate, Task, TaskResult};
pub use vocab::Vocab;
