//! Synthetic WikiText-style corpus generator (DESIGN.md §3 substitution).
//!
//! Sentences are drawn from a small template grammar over the shared
//! lexicon, mixing:
//!  * SVO facts ("the fox chased the ball .")
//!  * attribute sentences with *sentiment-consistent* adjective pairs
//!  * coreference patterns ("alice took the key . the key belongs to alice .")
//!  * adjective→polarity rules ("... is wonderful so it is good .")
//!  * a Zipf-distributed noise tail
//!
//! The grammar gives a trained LM real structure to exploit (perplexity
//! well below uniform) while the noise keeps entropy non-trivial — the
//! regime where quantisation error is visible in perplexity.

use super::vocab::Vocab;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub seed: u64,
    /// fraction of pure-noise sentences
    pub noise_rate: f64,
    pub zipf_s: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            seed: 1234,
            noise_rate: 0.12,
            zipf_s: 1.1,
        }
    }
}

pub struct CorpusGen<'v> {
    pub vocab: &'v Vocab,
    cfg: CorpusConfig,
    rng: Pcg32,
    zipf_cdf: Vec<f64>,
}

impl<'v> CorpusGen<'v> {
    pub fn new(vocab: &'v Vocab, cfg: CorpusConfig) -> Self {
        let rng = Pcg32::new(cfg.seed);
        // precompute Zipf CDF over the whole vocab (skipping specials)
        let n = vocab.words.len() - 3;
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(cfg.zipf_s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        CorpusGen {
            vocab,
            cfg,
            rng,
            zipf_cdf: cdf,
        }
    }

    fn zipf_tok(&mut self) -> usize {
        let u = self.rng.f64();
        let idx = self
            .zipf_cdf
            .partition_point(|&c| c < u)
            .min(self.zipf_cdf.len() - 1);
        3 + idx
    }

    fn pick(&mut self, cat: &[usize]) -> usize {
        cat[self.rng.below(cat.len())]
    }

    /// Emit one sentence as token ids (ends with ".").
    pub fn sentence(&mut self) -> Vec<usize> {
        let v = self.vocab;
        let id = |w: &str| v.id(w);
        if self.rng.f64() < self.cfg.noise_rate {
            let len = 4 + self.rng.below(8);
            let mut s: Vec<usize> = (0..len).map(|_| self.zipf_tok()).collect();
            s.push(id("."));
            return s;
        }
        let nouns = v.nouns.clone();
        let verbs = v.verbs.clone();
        let names = v.names.clone();
        match self.rng.below(6) {
            0 => {
                // SVO
                let (n1, ve, n2) = (self.pick(&nouns), self.pick(&verbs), self.pick(&nouns));
                vec![id("the"), n1, ve, id("the"), n2, id(".")]
            }
            1 => {
                // sentiment-consistent attributes
                let pos = self.rng.f64() < 0.5;
                let cat = if pos { &v.adj_pos } else { &v.adj_neg };
                let (a1, a2) = (cat[self.rng.below(cat.len())], cat[self.rng.below(cat.len())]);
                let n = self.pick(&nouns);
                vec![id("the"), n, id("was"), a1, id("and"), a2, id(".")]
            }
            2 => {
                // coreference / last-word predictability (LAMBADA pattern)
                let (name, n) = (self.pick(&names), self.pick(&nouns));
                vec![
                    name,
                    id("took"),
                    id("the"),
                    n,
                    id("."),
                    id("the"),
                    n,
                    id("belongs"),
                    id("to"),
                    name,
                    id("."),
                ]
            }
            3 => {
                // adjective → polarity rule (zero-shot sentiment signal)
                let pos = self.rng.f64() < 0.5;
                let cat = if pos { &v.adj_pos } else { &v.adj_neg };
                let a = cat[self.rng.below(cat.len())];
                let n = self.pick(&nouns);
                let label = if pos { id("good") } else { id("bad") };
                vec![
                    id("the"),
                    n,
                    id("is"),
                    a,
                    id("so"),
                    id("it"),
                    id("is"),
                    label,
                    id("."),
                ]
            }
            4 => {
                // name + place
                let (name, p) = (self.pick(&names), self.pick(&v.places.clone()));
                vec![name, id("was"), id("in"), id("the"), p, id(".")]
            }
            _ => {
                // adverbial attribute
                let pos = self.rng.f64() < 0.5;
                let cat = if pos { &v.adj_pos } else { &v.adj_neg };
                let a = cat[self.rng.below(cat.len())];
                let name = self.pick(&names);
                vec![name, id("is"), id("very"), a, id(".")]
            }
        }
    }

    /// Generate a token stream of at least `min_tokens`.
    pub fn stream(&mut self, min_tokens: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(min_tokens + 16);
        while out.len() < min_tokens {
            out.extend(self.sentence());
        }
        out
    }
}

/// Standard splits used by the experiments (disjoint seeds).
pub fn train_stream(vocab: &Vocab, tokens: usize) -> Vec<usize> {
    CorpusGen::new(
        vocab,
        CorpusConfig {
            seed: 1001,
            ..Default::default()
        },
    )
    .stream(tokens)
}

pub fn test_stream(vocab: &Vocab, tokens: usize) -> Vec<usize> {
    CorpusGen::new(
        vocab,
        CorpusConfig {
            seed: 9009,
            ..Default::default()
        },
    )
    .stream(tokens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::{Vocab, UNK};

    #[test]
    fn stream_reaches_length_and_in_vocab() {
        let v = Vocab::build();
        let s = train_stream(&v, 5000);
        assert!(s.len() >= 5000);
        assert!(s.iter().all(|&t| t < v.words.len() && t != UNK));
    }

    #[test]
    fn deterministic_given_seed() {
        let v = Vocab::build();
        let a = train_stream(&v, 1000);
        let b = train_stream(&v, 1000);
        assert_eq!(a, b);
    }

    #[test]
    fn train_test_differ() {
        let v = Vocab::build();
        assert_ne!(train_stream(&v, 500), test_stream(&v, 500));
    }

    #[test]
    fn has_structure_lower_entropy_than_uniform() {
        // unigram entropy of the corpus must be far below log2(512)
        let v = Vocab::build();
        let s = train_stream(&v, 20000);
        let mut counts = vec![0f64; v.words.len()];
        for &t in &s {
            counts[t] += 1.0;
        }
        let total: f64 = counts.iter().sum();
        let h: f64 = counts
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| {
                let p = c / total;
                -p * p.log2()
            })
            .sum();
        assert!(h < 7.0, "unigram entropy {h}");
        assert!(h > 3.0, "degenerate corpus, entropy {h}");
    }

    #[test]
    fn coreference_pattern_present() {
        let v = Vocab::build();
        let s = train_stream(&v, 20000);
        let text = v.decode(&s);
        assert!(text.contains("belongs to"));
    }
}
