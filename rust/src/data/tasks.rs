//! Downstream task suite — synthetic stand-ins for the paper's eight
//! evaluation tasks (ARC-easy, COPA, LAMBADA, PIQA, SST2, QNLI, MRPC,
//! COLA), sharing the corpus lexicon so zero-shot prompting has signal
//! exactly where the pre-training distribution supports it:
//!
//! * sst2/piqa/copa/lambada/arc exploit corpus patterns → FP32 zero-shot
//!   is well above chance (paper Table 5 tasks);
//! * qnli/mrpc/cola need sentence-pair or acceptability reasoning that the
//!   corpus never shows → zero-shot ≈ random, recovered by fine-tuning
//!   (paper §4.3 / Table 8 tasks).
//!
//! Every task is expressed as prompt + candidate completions; zero-shot
//! evaluation scores each completion's log-probability (lm-eval-harness
//! protocol) and picks the argmax.

use super::lm_eval::completion_logprob;
use super::vocab::Vocab;
use crate::model::Model;
use crate::util::rng::Pcg32;
use crate::util::stats::mcc;

#[derive(Clone, Debug)]
pub struct Example {
    pub prompt: Vec<usize>,
    pub choices: Vec<Vec<usize>>,
    pub label: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    ArcEasy,
    Copa,
    Lambada,
    Piqa,
    Sst2,
    Qnli,
    Mrpc,
    Cola,
}

impl Task {
    pub fn all() -> Vec<Task> {
        vec![
            Task::ArcEasy,
            Task::Copa,
            Task::Lambada,
            Task::Piqa,
            Task::Sst2,
            Task::Qnli,
            Task::Mrpc,
            Task::Cola,
        ]
    }

    /// The five "zero-shot works" tasks of Table 5.
    pub fn zero_shot_suite() -> Vec<Task> {
        vec![Task::ArcEasy, Task::Copa, Task::Lambada, Task::Piqa, Task::Sst2]
    }

    /// The four fine-tuning tasks of Table 8.
    pub fn finetune_suite() -> Vec<Task> {
        vec![Task::Sst2, Task::Qnli, Task::Mrpc, Task::Cola]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::ArcEasy => "arc_easy",
            Task::Copa => "copa",
            Task::Lambada => "lambada",
            Task::Piqa => "piqa",
            Task::Sst2 => "sst2",
            Task::Qnli => "qnli",
            Task::Mrpc => "mrpc",
            Task::Cola => "cola",
        }
    }

    pub fn parse(s: &str) -> Option<Task> {
        Task::all().into_iter().find(|t| t.name() == s)
    }

    /// COLA is scored with Matthews correlation, the rest with accuracy.
    pub fn uses_mcc(&self) -> bool {
        matches!(self, Task::Cola)
    }
}

/// Generate `n` examples for a task.
pub fn generate(task: Task, vocab: &Vocab, seed: u64, n: usize) -> Vec<Example> {
    let mut rng = Pcg32::new(seed ^ (task as u64).wrapping_mul(0x9E37_79B9));
    (0..n).map(|_| gen_one(task, vocab, &mut rng)).collect()
}

fn pick(rng: &mut Pcg32, cat: &[usize]) -> usize {
    cat[rng.below(cat.len())]
}

fn pick_other(rng: &mut Pcg32, cat: &[usize], not: usize) -> usize {
    loop {
        let c = pick(rng, cat);
        if c != not {
            return c;
        }
    }
}

fn gen_one(task: Task, v: &Vocab, rng: &mut Pcg32) -> Example {
    let id = |w: &str| v.id(w);
    match task {
        Task::Sst2 => {
            // corpus rule: "the N is ADJ so it is good/bad"
            let pos = rng.f64() < 0.5;
            let adj = pick(rng, if pos { &v.adj_pos } else { &v.adj_neg });
            let n = pick(rng, &v.nouns);
            Example {
                prompt: vec![id("the"), n, id("is"), adj, id("so"), id("it"), id("is")],
                choices: vec![vec![id("good")], vec![id("bad")]],
                label: if pos { 0 } else { 1 },
            }
        }
        Task::Lambada => {
            // last-word prediction over the coreference pattern
            let name = pick(rng, &v.names);
            let n = pick(rng, &v.nouns);
            let mut choices = vec![vec![name]];
            let mut used = vec![name];
            for _ in 0..3 {
                let d = loop {
                    let c = pick(rng, &v.names);
                    if !used.contains(&c) {
                        break c;
                    }
                };
                used.push(d);
                choices.push(vec![d]);
            }
            // shuffle choices, track label
            let mut order: Vec<usize> = (0..4).collect();
            rng.shuffle(&mut order);
            let label = order.iter().position(|&i| i == 0).unwrap();
            let choices = order.into_iter().map(|i| choices[i].clone()).collect();
            Example {
                prompt: vec![
                    name,
                    id("took"),
                    id("the"),
                    n,
                    id("."),
                    id("the"),
                    n,
                    id("belongs"),
                    id("to"),
                ],
                choices,
                label,
            }
        }
        Task::ArcEasy => {
            // category selection: names go with places, not objects
            let name = pick(rng, &v.names);
            let place = pick(rng, &v.places);
            let mut choices = vec![vec![place]];
            for _ in 0..3 {
                choices.push(vec![pick(rng, &v.nouns)]);
            }
            let mut order: Vec<usize> = (0..4).collect();
            rng.shuffle(&mut order);
            let label = order.iter().position(|&i| i == 0).unwrap();
            let choices = order.into_iter().map(|i| choices[i].clone()).collect();
            Example {
                prompt: vec![name, id("was"), id("in"), id("the")],
                choices,
                label,
            }
        }
        Task::Piqa => {
            // plausible continuation: sentiment-consistent adjective
            let pos = rng.f64() < 0.5;
            let (same, other) = if pos {
                (&v.adj_pos, &v.adj_neg)
            } else {
                (&v.adj_neg, &v.adj_pos)
            };
            let a1 = pick(rng, same);
            let good = pick_other(rng, same, a1);
            let bad = pick(rng, other);
            let n = pick(rng, &v.nouns);
            let flip = rng.f64() < 0.5;
            let choices = if flip {
                vec![vec![bad, id(".")], vec![good, id(".")]]
            } else {
                vec![vec![good, id(".")], vec![bad, id(".")]]
            };
            Example {
                prompt: vec![id("the"), n, id("was"), a1, id("and")],
                choices,
                label: if flip { 1 } else { 0 },
            }
        }
        Task::Copa => {
            // binary coreference: whose object is it?
            let name = pick(rng, &v.names);
            let distract = pick_other(rng, &v.names, name);
            let n = pick(rng, &v.nouns);
            let flip = rng.f64() < 0.5;
            let choices = if flip {
                vec![vec![distract], vec![name]]
            } else {
                vec![vec![name], vec![distract]]
            };
            Example {
                prompt: vec![
                    name,
                    id("took"),
                    id("the"),
                    n,
                    id("."),
                    id("the"),
                    n,
                    id("belongs"),
                    id("to"),
                ],
                choices,
                label: if flip { 1 } else { 0 },
            }
        }
        Task::Qnli => {
            // does the answer sentence address the question's noun?
            let n1 = pick(rng, &v.nouns);
            let matched = rng.f64() < 0.5;
            let n2 = if matched {
                n1
            } else {
                pick_other(rng, &v.nouns, n1)
            };
            let adj = pick(rng, &v.adj_pos);
            Example {
                prompt: vec![
                    id("question"),
                    id("the"),
                    n1,
                    id("is"),
                    id("good"),
                    id("?"),
                    id("answer"),
                    id("the"),
                    n2,
                    id("is"),
                    adj,
                    id("."),
                ],
                choices: vec![vec![id("yes")], vec![id("no")]],
                label: if matched { 0 } else { 1 },
            }
        }
        Task::Mrpc => {
            // paraphrase detection over SVO triples
            let (s, ve, o) = (pick(rng, &v.nouns), pick(rng, &v.verbs), pick(rng, &v.nouns));
            let paraphrase = rng.f64() < 0.5;
            let (s2, v2, o2) = if paraphrase {
                (s, ve, o)
            } else {
                match rng.below(3) {
                    0 => (pick_other(rng, &v.nouns, s), ve, o),
                    1 => (s, pick_other(rng, &v.verbs, ve), o),
                    _ => (s, ve, pick_other(rng, &v.nouns, o)),
                }
            };
            Example {
                prompt: vec![
                    id("premise"),
                    id("the"),
                    s,
                    ve,
                    id("the"),
                    o,
                    id("."),
                    id("paraphrase"),
                    id("the"),
                    s2,
                    v2,
                    id("the"),
                    o2,
                    id("."),
                ],
                choices: vec![vec![id("yes")], vec![id("no")]],
                label: if paraphrase { 0 } else { 1 },
            }
        }
        Task::Cola => {
            // linguistic acceptability: grammatical vs scrambled SVO
            let (s, ve, o) = (pick(rng, &v.nouns), pick(rng, &v.verbs), pick(rng, &v.nouns));
            let ok = rng.f64() < 0.5;
            let sent = if ok {
                vec![id("the"), s, ve, id("the"), o, id(".")]
            } else {
                // scramble: verb first or determiner displaced
                match rng.below(2) {
                    0 => vec![ve, id("the"), id("the"), s, o, id(".")],
                    _ => vec![id("the"), ve, s, o, id("the"), id(".")],
                }
            };
            let mut prompt = sent;
            prompt.push(id("?"));
            Example {
                prompt,
                choices: vec![vec![id("yes")], vec![id("no")]],
                label: if ok { 0 } else { 1 },
            }
        }
    }
}

/// Zero-shot evaluation result.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub task: Task,
    pub n: usize,
    pub accuracy: f64,
    /// MCC for COLA, accuracy otherwise (the paper's Table 8 convention)
    pub metric: f64,
}

/// Score one example: argmax over length-normalised completion log-probs.
pub fn predict(model: &Model, ex: &Example) -> usize {
    let mut best = 0usize;
    let mut best_lp = f64::NEG_INFINITY;
    for (ci, choice) in ex.choices.iter().enumerate() {
        let lp = completion_logprob(model, &ex.prompt, choice) / choice.len() as f64;
        if lp > best_lp {
            best_lp = lp;
            best = ci;
        }
    }
    best
}

/// Evaluate a task zero-shot, optionally across threads.
pub fn evaluate(model: &Model, task: Task, examples: &[Example], threads: usize) -> TaskResult {
    let nthreads = threads.max(1).min(examples.len().max(1));
    let preds: Vec<usize> = if nthreads <= 1 {
        examples.iter().map(|e| predict(model, e)).collect()
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..nthreads)
                .map(|ti| {
                    let exs = examples;
                    scope.spawn(move || {
                        exs.iter()
                            .enumerate()
                            .filter(|(i, _)| i % nthreads == ti)
                            .map(|(i, e)| (i, predict(model, e)))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            let mut all: Vec<(usize, usize)> =
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            all.sort_by_key(|(i, _)| *i);
            all.into_iter().map(|(_, p)| p).collect()
        })
    };
    let correct = preds
        .iter()
        .zip(examples)
        .filter(|(p, e)| **p == e.label)
        .count();
    let accuracy = correct as f64 / examples.len().max(1) as f64;
    let metric = if task.uses_mcc() {
        let pb: Vec<bool> = preds.iter().map(|&p| p == 0).collect();
        let lb: Vec<bool> = examples.iter().map(|e| e.label == 0).collect();
        mcc(&pb, &lb)
    } else {
        accuracy
    };
    TaskResult {
        task,
        n: examples.len(),
        accuracy,
        metric,
    }
}

/// Fine-tuning sequences: prompt + correct completion as an LM sample.
pub fn finetune_sequences(examples: &[Example]) -> Vec<Vec<usize>> {
    examples
        .iter()
        .map(|e| {
            let mut s = e.prompt.clone();
            s.extend(&e.choices[e.label]);
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;

    #[test]
    fn generators_produce_valid_examples() {
        let v = Vocab::build();
        for task in Task::all() {
            let exs = generate(task, &v, 7, 20);
            assert_eq!(exs.len(), 20);
            for e in &exs {
                assert!(e.label < e.choices.len(), "{task:?}");
                assert!(!e.prompt.is_empty());
                assert!(e.choices.iter().all(|c| !c.is_empty()));
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let v = Vocab::build();
        for task in [Task::Sst2, Task::Qnli, Task::Mrpc, Task::Cola] {
            let exs = generate(task, &v, 11, 200);
            let zeros = exs.iter().filter(|e| e.label == 0).count();
            assert!(zeros > 60 && zeros < 140, "{task:?}: {zeros}/200");
        }
    }

    #[test]
    fn random_model_near_chance() {
        let v = Vocab::build();
        let cfg = ModelConfig::preset("nano");
        let m = crate::model::Model::new(Params::init(&cfg, 3), QuantPlan::fp32());
        let exs = generate(Task::Sst2, &v, 5, 40);
        let r = evaluate(&m, Task::Sst2, &exs, 2);
        assert!(r.accuracy > 0.2 && r.accuracy < 0.8, "{}", r.accuracy);
    }

    #[test]
    fn deterministic_generation() {
        let v = Vocab::build();
        let a = generate(Task::Lambada, &v, 9, 10);
        let b = generate(Task::Lambada, &v, 9, 10);
        assert_eq!(a[3].prompt, b[3].prompt);
        assert_eq!(a[3].label, b[3].label);
    }

    #[test]
    fn finetune_sequences_end_with_answer() {
        let v = Vocab::build();
        let exs = generate(Task::Sst2, &v, 2, 5);
        let seqs = finetune_sequences(&exs);
        for (s, e) in seqs.iter().zip(&exs) {
            assert_eq!(s[s.len() - 1], e.choices[e.label][0]);
        }
    }

    #[test]
    fn task_parse_roundtrip() {
        for t in Task::all() {
            assert_eq!(Task::parse(t.name()), Some(t));
        }
    }
}
