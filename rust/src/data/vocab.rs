//! Synthetic lexicon + word-level tokenizer.
//!
//! A WikiText-2 substitute must give the LM *learnable* structure with a
//! non-trivial long-tail distribution (DESIGN.md §3). We build an
//! English-like lexicon with part-of-speech and sentiment categories so
//! that (a) the corpus generator can emit grammatical, predictable
//! sentences, and (b) downstream tasks can be templated from the same
//! vocabulary (zero-shot prompting then has signal exactly where the
//! corpus distribution supports it, mirroring the paper's task split).

use std::collections::HashMap;

pub const VOCAB_SIZE: usize = 512;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub words: Vec<String>,
    pub index: HashMap<String, usize>,
    pub nouns: Vec<usize>,
    pub verbs: Vec<usize>,
    pub adj_pos: Vec<usize>,
    pub adj_neg: Vec<usize>,
    pub names: Vec<usize>,
    pub places: Vec<usize>,
}

pub const PAD: usize = 0;
pub const UNK: usize = 1;
pub const BOS: usize = 2;

impl Vocab {
    /// The fixed lexicon (deterministic; shared with the python side via
    /// artifacts/vocab.json).
    pub fn build() -> Vocab {
        let mut words: Vec<String> = vec!["<pad>".into(), "<unk>".into(), "<bos>".into()];
        let push_all = |items: &[&str], words: &mut Vec<String>| -> Vec<usize> {
            items
                .iter()
                .map(|w| {
                    words.push(w.to_string());
                    words.len() - 1
                })
                .collect()
        };
        // structural words (ids stay stable as long as order is unchanged)
        let _structural = push_all(
            &[
                "the", "a", "is", "was", "and", "or", "not", "very", "quite", "it", "this",
                "that", "then", "because", "but", "of", "in", "on", "to", "by", ".", ",", "?",
                "review", "sentiment", "question", "answer", "premise", "paraphrase",
                "positive", "negative", "yes", "no", "good", "bad", "true", "false",
                "belongs", "said", "story", "ending", "because:", "so",
            ],
            &mut words,
        );
        let nouns = push_all(
            &[
                "cat", "dog", "bird", "fish", "horse", "mouse", "fox", "wolf", "bear", "lion",
                "book", "ball", "cup", "door", "key", "lamp", "table", "chair", "stone", "tree",
                "river", "house", "garden", "road", "bridge", "boat", "train", "car", "plane",
                "clock", "letter", "song", "movie", "game", "meal", "coat", "hat", "shoe",
                "box", "coin", "map", "tool", "rope", "wheel", "window", "flower", "cloud",
                "storm", "market", "farm",
            ],
            &mut words,
        );
        let verbs = push_all(
            &[
                "chased", "found", "took", "dropped", "carried", "watched", "opened", "closed",
                "moved", "broke", "fixed", "made", "sold", "bought", "gave", "kept", "lost",
                "painted", "cleaned", "built", "pushed", "pulled", "threw", "caught", "hid",
                "showed", "followed", "helped", "liked", "loved",
            ],
            &mut words,
        );
        let adj_pos = push_all(
            &[
                "great", "wonderful", "excellent", "delightful", "brilliant", "charming",
                "lovely", "superb", "amazing", "pleasant", "bright", "fresh", "clever",
                "graceful", "splendid",
            ],
            &mut words,
        );
        let adj_neg = push_all(
            &[
                "terrible", "awful", "dreadful", "boring", "ugly", "broken", "dull", "nasty",
                "horrid", "gloomy", "dirty", "rotten", "weak", "bitter", "dismal",
            ],
            &mut words,
        );
        let names = push_all(
            &[
                "alice", "bob", "carol", "david", "emma", "frank", "grace", "henry", "iris",
                "jack", "karen", "liam", "mary", "noah", "olivia", "peter", "quinn", "rose",
                "sam", "tina",
            ],
            &mut words,
        );
        let places = push_all(
            &[
                "town", "city", "village", "forest", "mountain", "valley", "island", "harbor",
                "castle", "field",
            ],
            &mut words,
        );
        // filler tokens up to VOCAB_SIZE (rare tail mass)
        let mut i = 0;
        while words.len() < VOCAB_SIZE {
            words.push(format!("w{i}"));
            i += 1;
        }
        assert_eq!(words.len(), VOCAB_SIZE);
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i))
            .collect();
        Vocab {
            words,
            index,
            nouns,
            verbs,
            adj_pos,
            adj_neg,
            names,
            places,
        }
    }

    pub fn id(&self, w: &str) -> usize {
        *self.index.get(w).unwrap_or(&UNK)
    }

    pub fn encode(&self, text: &str) -> Vec<usize> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }

    pub fn decode(&self, ids: &[usize]) -> String {
        ids.iter()
            .map(|&i| self.words.get(i).map(String::as_str).unwrap_or("<?>"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_is_full_and_unique() {
        let v = Vocab::build();
        assert_eq!(v.words.len(), VOCAB_SIZE);
        assert_eq!(v.index.len(), VOCAB_SIZE, "duplicate words");
    }

    #[test]
    fn encode_decode() {
        let v = Vocab::build();
        let ids = v.encode("the cat chased the ball .");
        assert_eq!(v.decode(&ids), "the cat chased the ball .");
        assert!(!ids.contains(&UNK));
    }

    #[test]
    fn unknown_maps_to_unk() {
        let v = Vocab::build();
        assert_eq!(v.encode("qwertyuiop"), vec![UNK]);
    }

    #[test]
    fn categories_nonempty_and_in_range() {
        let v = Vocab::build();
        for cat in [&v.nouns, &v.verbs, &v.adj_pos, &v.adj_neg, &v.names, &v.places] {
            assert!(!cat.is_empty());
            assert!(cat.iter().all(|&i| i < VOCAB_SIZE));
        }
    }

    #[test]
    fn deterministic() {
        let a = Vocab::build();
        let b = Vocab::build();
        assert_eq!(a.words, b.words);
    }
}
