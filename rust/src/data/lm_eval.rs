//! Language-model evaluation: perplexity over a held-out token stream,
//! following the paper's protocol (Appendix B.1: chop the test set into
//! fixed-length sequences, feed each to the LM, normalise cross-entropy by
//! sequence length).

use crate::model::kv_cache::DecodeSession;
use crate::model::paged::SessionConfig;
use crate::model::transformer::{cross_entropy, Model};
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct PplResult {
    pub nats_per_tok: f64,
    pub perplexity: f64,
    pub tokens: usize,
    pub chunks: usize,
}

/// Perplexity of `model` on `stream`, in chunks of `seq_len` tokens
/// (the paper uses 2000-token chunks of WikiText2; we scale down).
pub fn perplexity(model: &Model, stream: &[usize], seq_len: usize, max_chunks: usize) -> PplResult {
    assert!(seq_len >= 2);
    let mut total_nats = 0.0f64;
    let mut total_toks = 0usize;
    let mut chunks = 0usize;
    for chunk in stream.chunks(seq_len) {
        if chunk.len() < 2 || chunks >= max_chunks {
            break;
        }
        let inputs = &chunk[..chunk.len() - 1];
        let targets = &chunk[1..];
        let logits = model.forward(inputs, None);
        total_nats += cross_entropy(&logits, targets) * targets.len() as f64;
        total_toks += targets.len();
        chunks += 1;
    }
    let nats = if total_toks > 0 {
        total_nats / total_toks as f64
    } else {
        f64::NAN
    };
    PplResult {
        nats_per_tok: nats,
        perplexity: nats.exp(),
        tokens: total_toks,
        chunks,
    }
}

/// Decode-path perplexity: feeds each chunk token-by-token through a
/// [`DecodeSession`] built from `cfg`, so the session's KV storage format
/// applies to every cached key/value row. With the default f32 KV this
/// reproduces [`perplexity`] (the decode path matches the parallel
/// forward); with a block KV format (`cfg.kv.format` = BFP/BM/BL) it
/// measures the accuracy cost of quantising the KV cache itself — the
/// quantised-KV lane of the paper's Table 3 sweep.
pub fn perplexity_decode(
    model: &Model,
    cfg: &SessionConfig,
    stream: &[usize],
    seq_len: usize,
    max_chunks: usize,
) -> PplResult {
    assert!(seq_len >= 2);
    let vocab = model.cfg().vocab_size;
    let mut total_nats = 0.0f64;
    let mut total_toks = 0usize;
    let mut chunks = 0usize;
    for chunk in stream.chunks(seq_len) {
        if chunk.len() < 2 || chunks >= max_chunks {
            break;
        }
        let inputs = &chunk[..chunk.len() - 1];
        let targets = &chunk[1..];
        let mut session = DecodeSession::new(model, cfg);
        let mut data = Vec::with_capacity(inputs.len() * vocab);
        for &t in inputs {
            data.extend_from_slice(&session.step(t));
        }
        let logits = Tensor::new(&[inputs.len(), vocab], data);
        total_nats += cross_entropy(&logits, targets) * targets.len() as f64;
        total_toks += targets.len();
        chunks += 1;
    }
    let nats = if total_toks > 0 {
        total_nats / total_toks as f64
    } else {
        f64::NAN
    };
    PplResult {
        nats_per_tok: nats,
        perplexity: nats.exp(),
        tokens: total_toks,
        chunks,
    }
}

/// Parallel variant: evaluates chunks on worker threads (model forward is
/// immutable, so this is embarrassingly parallel).
pub fn perplexity_par(
    model: &Model,
    stream: &[usize],
    seq_len: usize,
    max_chunks: usize,
    threads: usize,
) -> PplResult {
    let chunks: Vec<&[usize]> = stream
        .chunks(seq_len)
        .filter(|c| c.len() >= 2)
        .take(max_chunks)
        .collect();
    if chunks.is_empty() {
        return PplResult {
            nats_per_tok: f64::NAN,
            perplexity: f64::NAN,
            tokens: 0,
            chunks: 0,
        };
    }
    let nthreads = threads.max(1).min(chunks.len());
    let results: Vec<(f64, usize)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for ti in 0..nthreads {
            let my_chunks: Vec<&[usize]> = chunks
                .iter()
                .enumerate()
                .filter(|(i, _)| i % nthreads == ti)
                .map(|(_, c)| *c)
                .collect();
            handles.push(scope.spawn(move || {
                let mut nats = 0.0;
                let mut toks = 0;
                for c in my_chunks {
                    let logits = model.forward(&c[..c.len() - 1], None);
                    nats += cross_entropy(&logits, &c[1..]) * (c.len() - 1) as f64;
                    toks += c.len() - 1;
                }
                (nats, toks)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let total_nats: f64 = results.iter().map(|(n, _)| n).sum();
    let total_toks: usize = results.iter().map(|(_, t)| t).sum();
    let nats = total_nats / total_toks as f64;
    PplResult {
        nats_per_tok: nats,
        perplexity: nats.exp(),
        tokens: total_toks,
        chunks: chunks.len(),
    }
}

/// Log-probability of `completion` tokens given `prompt` tokens — the
/// zero-shot prompting primitive (lm-eval-harness style continuation
/// scoring).
pub fn completion_logprob(model: &Model, prompt: &[usize], completion: &[usize]) -> f64 {
    assert!(!completion.is_empty());
    let mut full = prompt.to_vec();
    full.extend_from_slice(completion);
    let logits = model.forward(&full[..full.len() - 1], None);
    let mut lp = 0.0f64;
    for (ci, &tok) in completion.iter().enumerate() {
        let row_idx = prompt.len() + ci - 1;
        let row = logits.row(row_idx);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse =
            m as f64 + row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln();
        lp += row[tok] as f64 - lse;
    }
    lp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::test_stream;
    use crate::data::vocab::Vocab;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::model::Model;

    fn model() -> Model {
        let cfg = ModelConfig::preset("nano");
        Model::new(Params::init(&cfg, 5), QuantPlan::fp32())
    }

    #[test]
    fn random_model_near_uniform_ppl() {
        let v = Vocab::build();
        let m = model();
        let s = test_stream(&v, 400);
        let r = perplexity(&m, &s, 64, 4);
        assert!(r.perplexity > 200.0 && r.perplexity < 900.0, "{}", r.perplexity);
    }

    #[test]
    fn par_matches_serial() {
        let v = Vocab::build();
        let m = model();
        let s = test_stream(&v, 500);
        let a = perplexity(&m, &s, 64, 8);
        let b = perplexity_par(&m, &s, 64, 8, 4);
        assert!((a.nats_per_tok - b.nats_per_tok).abs() < 1e-9);
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn decode_path_matches_forward_perplexity() {
        let v = Vocab::build();
        let m = model();
        let s = test_stream(&v, 300);
        let a = perplexity(&m, &s, 48, 3);
        let b = perplexity_decode(&m, &SessionConfig::new(1), &s, 48, 3);
        assert!(
            (a.nats_per_tok - b.nats_per_tok).abs() < 1e-3,
            "forward {} vs decode {}",
            a.nats_per_tok,
            b.nats_per_tok
        );
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.chunks, b.chunks);
    }

    #[test]
    fn quantised_kv_ppl_within_documented_delta_of_f32_kv() {
        // the quantised-KV accuracy lane: storing the KV cache in a block
        // format must stay within a small, documented relative perplexity
        // delta of the f32 KV baseline — 5% for BFP8, 20% for BFP6
        use crate::quant::config::presets;
        let v = Vocab::build();
        let m = model();
        let s = test_stream(&v, 300);
        let base = perplexity_decode(&m, &SessionConfig::new(1), &s, 48, 3);
        for (fmt, budget) in [(presets::bfp_w(8), 0.05), (presets::bfp_w(6), 0.20)] {
            let q = perplexity_decode(&m, &SessionConfig::new(1).kv_format(fmt), &s, 48, 3);
            let rel = (q.perplexity - base.perplexity).abs() / base.perplexity;
            assert!(
                rel < budget,
                "{}: ppl {} vs f32-KV {} (rel {rel:.4} > {budget})",
                fmt.name(),
                q.perplexity,
                base.perplexity
            );
        }
    }

    #[test]
    fn completion_logprob_is_negative_and_finite() {
        let m = model();
        let lp = completion_logprob(&m, &[3, 4, 5], &[6, 7]);
        assert!(lp.is_finite() && lp < 0.0);
    }

    #[test]
    fn empty_stream_is_nan() {
        let m = model();
        let r = perplexity(&m, &[], 64, 4);
        assert!(r.nats_per_tok.is_nan());
        assert_eq!(r.tokens, 0);
    }
}
