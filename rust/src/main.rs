//! `bbq` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   exp `<id>`       run a paper experiment (table1/3/4/5/6/8, fig1/3/4/5/7/10, all)
//!   train            train a model on the synthetic corpus (rust-native)
//!   train-pjrt       train via the AOT jax train-step artifact (PJRT)
//!   eval-ppl         perplexity of a model under a format
//!   eval-tasks       zero-shot downstream accuracy
//!   quantize         quantise a demo tensor, show formats + densities
//!   density          print memory/arithmetic density for every preset format
//!   profile-variance Figure-1-style variance profile
//!   search           mixed-precision TPE search
//!   serve            batched-inference demo with latency/throughput metrics
//!                    (`--stream` drives the live Engine API and prints
//!                    request 0's tokens as they arrive; `--temperature`,
//!                    `--top-k`, `--stop-token`, `--seed`, `--queue-depth`
//!                    set the per-request GenerationParams / engine queue)
//!   artifacts        list AOT artifacts visible to the runtime
//!
//! Common options: `--model <preset>` `--format <name>` `--seq N` `--threads N`

#![allow(clippy::needless_range_loop, clippy::collapsible_if)]

use bbq::coordinator::experiment::{default_steps, get_or_train};
use bbq::coordinator::{run_batched, Engine, GenerationParams, Request, ServerConfig, TokenEvent};
use bbq::data::corpus::test_stream;
use bbq::data::lm_eval::perplexity_par;
use bbq::data::tasks::{evaluate, generate, Task};
use bbq::data::vocab::Vocab;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::{presets, QFormat};
use bbq::util::cli::Args;

fn plan_from_args(args: &Args, n_layers: usize) -> QuantPlan {
    let fmt_name = args.get_or("format", "fp32");
    match fmt_name.as_str() {
        "llm_int8" => QuantPlan::llm_int8(8),
        "llm_int4" => QuantPlan::llm_int8(4),
        name => {
            let fmt = QFormat::parse(name)
                .unwrap_or_else(|| panic!("unknown format '{name}' (try bfp_e8m5n16)"));
            if args.has_flag("six-of-eight") {
                QuantPlan::six_of_eight(fmt, n_layers)
            } else {
                QuantPlan::uniform(fmt)
            }
        }
    }
}

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_str() {
        "exp" => {
            let id = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("table3");
            if !bbq::exp::run(id, &args) {
                eprintln!(
                    "unknown experiment '{id}'. available: {:?}",
                    bbq::exp::EXPERIMENTS
                );
                std::process::exit(2);
            }
        }
        "train" => {
            let preset = args.get_or("model", "tiny");
            let steps = args.usize_or("steps", default_steps(&preset));
            let p = get_or_train(&preset, steps, args.has_flag("quiet"));
            println!("trained/loaded {preset}: {} params", p.param_count());
        }
        "train-pjrt" => cmd_train_pjrt(&args),
        "eval-ppl" => {
            let preset = args.get_or("model", "tiny");
            let seq = args.usize_or("seq", 64);
            let chunks = args.usize_or("chunks", 8);
            let threads = args.usize_or("threads", 8);
            let params = get_or_train(&preset, default_steps(&preset), true);
            let plan = plan_from_args(&args, params.cfg.n_layers);
            let model = Model::new(params, plan);
            let vocab = Vocab::build();
            let test = test_stream(&vocab, seq * chunks + seq);
            let r = perplexity_par(&model, &test, seq, chunks, threads);
            println!(
                "model={preset} format={} ppl={:.3} ({} tokens, {} chunks)",
                args.get_or("format", "fp32"),
                r.perplexity,
                r.tokens,
                r.chunks
            );
        }
        "eval-tasks" => {
            let preset = args.get_or("model", "tiny");
            let n = args.usize_or("examples", 60);
            let threads = args.usize_or("threads", 8);
            let params = get_or_train(&preset, default_steps(&preset), true);
            let plan = plan_from_args(&args, params.cfg.n_layers);
            let model = Model::new(params, plan);
            let vocab = Vocab::build();
            let mut mean = 0.0;
            let tasks = Task::zero_shot_suite();
            for &task in &tasks {
                let exs = generate(task, &vocab, 1000, n);
                let r = evaluate(&model, task, &exs, threads);
                println!("{:>10}: acc {:.1}%", task.name(), r.accuracy * 100.0);
                mean += r.accuracy;
            }
            println!("{:>10}: {:.1}%", "mean", mean / tasks.len() as f64 * 100.0);
        }
        "quantize" => cmd_quantize(&args),
        "density" => {
            let cost = bbq::density::arith::calibrate();
            println!("{:<18} {:>8} {:>8} {:>10}", "format", "bits/el", "mem", "arith");
            let mut fmts = vec![QFormat::Fp32];
            fmts.extend(presets::table3_formats().into_iter().map(|(_, f)| f));
            fmts.push(presets::bfp_w(5));
            for f in fmts {
                println!(
                    "{:<18} {:>8.2} {:>7.2}x {:>9.2}x",
                    f.name(),
                    f.bits_per_element(),
                    f.memory_density(),
                    cost.arithmetic_density(f)
                );
            }
        }
        "profile-variance" => {
            let preset = args.get_or("model", "tiny");
            let params = get_or_train(&preset, default_steps(&preset), true);
            let prof = bbq::profile::profile_variance(
                &params,
                args.usize_or("samples", 16),
                args.usize_or("seq", 64),
            );
            println!(
                "{}",
                prof.to_table(&format!("variance profile: {preset}")).render()
            );
        }
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "artifacts" => {
            let rt = bbq::runtime::Runtime::open(&bbq::util::artifacts_dir())
                .expect("open artifacts dir");
            for name in rt.artifact_names() {
                let m = rt.meta(&name).unwrap();
                println!("{name}: kind={} fmt={} seq={}", m.kind, m.fmt, m.seq);
            }
        }
        "" | "help" | "--help" => {
            println!("{HELP}");
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{HELP}");
            std::process::exit(2);
        }
    }
}

const HELP: &str = "bbq — block-based quantisation lab (EMNLP 2023 reproduction)
usage: bbq <exp|train|train-pjrt|eval-ppl|eval-tasks|quantize|density|profile-variance|search|serve|artifacts> [--opts]
see rust/src/main.rs header for the option list";

fn cmd_quantize(args: &Args) {
    use bbq::quant::fake_quant;
    use bbq::util::rng::Pcg32;
    let fmt_name = args.get_or("format", "bfp_e8m5n16");
    let fmt = QFormat::parse(&fmt_name).expect("unknown format");
    let mut rng = Pcg32::new(args.u64_or("seed", 1));
    let t = bbq::Tensor::new(
        &[2, 16],
        bbq::util::check::llmish_values(&mut rng, 32, 1.0, 0.05),
    );
    let q = fake_quant(&t, fmt);
    println!(
        "format: {} ({:.2} bits/element, {:.2}x memory density)",
        fmt.name(),
        fmt.bits_per_element(),
        fmt.memory_density()
    );
    for r in 0..2 {
        println!("in : {:?}", &t.row(r)[..8]);
        println!("out: {:?}", &q.row(r)[..8]);
    }
    println!("sqnr: {:.1} dB", bbq::util::stats::sqnr_db(&t.data, &q.data));
}

fn cmd_search(args: &Args) {
    use bbq::search::objective::Objective;
    use bbq::search::runner::{run_search, SearchConfig};
    use bbq::search::space::SearchSpace;
    let preset = args.get_or("model", "micro");
    let params = get_or_train(&preset, default_steps(&preset), true);
    let cfg = params.cfg.clone();
    let vocab = Vocab::build();
    let task = Task::parse(&args.get_or("task", "lambada")).expect("unknown task");
    let exs = generate(task, &vocab, 555, args.usize_or("examples", 40));
    let threads = args.usize_or("threads", 8);
    let fp32_acc = evaluate(
        &Model::new(params.clone(), QuantPlan::fp32()),
        task,
        &exs,
        threads,
    )
    .accuracy;
    let space = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
    let sc = SearchConfig {
        trials: args.usize_or("trials", 40),
        threads,
        seed: args.u64_or("seed", 7),
        objective: Objective::software(args.f64_or("alpha", 0.02)),
        ..Default::default()
    };
    let res = run_search(&params, space, task, &exs, fp32_acc, &sc);
    let b = res.best.as_ref().expect("no trials");
    println!(
        "fp32 acc {:.3}; best searched: acc {:.3} mem {:.2}x obj {:.3} ({} trials)",
        fp32_acc,
        b.accuracy,
        b.mem_density,
        b.objective,
        res.history.len()
    );
    for (name, bits) in res.bitwidth_profile().iter().take(16) {
        println!("  {name:<20} {bits:.2} bits");
    }
}

fn cmd_serve(args: &Args) {
    use std::io::Write;
    let preset = args.get_or("model", "tiny");
    let params = get_or_train(&preset, default_steps(&preset), true);
    let plan = plan_from_args(args, params.cfg.n_layers);
    let model = Model::new(params, plan);
    let vocab = Vocab::build();
    let n_req = args.usize_or("requests", 32);
    let stop_token: Option<usize> = args.get("stop-token").and_then(|s| s.parse().ok());
    let gen = GenerationParams {
        max_new_tokens: args.usize_or("new-tokens", 16),
        temperature: args.f64_or("temperature", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        stop_tokens: stop_token.into_iter().collect(),
        seed: args.get("seed").and_then(|s| s.parse().ok()),
    };
    let reqs: Vec<Request> = (0..n_req)
        .map(|i| Request {
            id: i as u64,
            prompt: vocab.encode("the cat chased the"),
            params: gen.clone(),
        })
        .collect();
    let cfg = ServerConfig {
        max_batch: args.usize_or("max-batch", 8),
        prefill_chunk: args.usize_or("prefill-chunk", 8),
        queue_depth: args.usize_or("queue-depth", 64),
    };
    if args.has_flag("stream") {
        // live-engine demo: submit through an EngineHandle and stream
        // request 0's tokens as the scheduler produces them
        let engine = Engine::start(std::sync::Arc::new(model), cfg);
        let handles: Vec<_> = reqs
            .into_iter()
            .map(|r| engine.submit(r).expect("engine accepts while open"))
            .collect();
        let mut handles = handles.into_iter();
        if let Some(first) = handles.next() {
            print!("request 0:");
            while let Some(ev) = first.recv() {
                match ev {
                    TokenEvent::Token(t) => {
                        print!(" {}", vocab.decode(&[t]));
                        let _ = std::io::stdout().flush();
                    }
                    TokenEvent::Finished { reason, .. } => {
                        println!("  [{reason:?}]");
                        break;
                    }
                    _ => {}
                }
            }
        }
        for h in handles {
            h.wait();
        }
        let metrics = engine.shutdown();
        println!("{}", metrics.summary());
    } else {
        let (resps, metrics) = run_batched(&model, reqs, &cfg);
        println!("{}", metrics.summary());
        if let Some(r) = resps.first() {
            println!("sample completion: {}", vocab.decode(&r.tokens));
        }
    }
}

fn cmd_train_pjrt(args: &Args) {
    use bbq::runtime::{Runtime, TrainStepExec};
    let artifact = args.get_or("artifact", "train_step_golden");
    let steps = args.usize_or("steps", 50);
    let lr = args.f64_or("lr", 0.5) as f32;
    let mut rt = Runtime::open(&bbq::util::artifacts_dir()).expect("open artifacts");
    let meta = rt.meta(&artifact).expect("artifact not in manifest").clone();
    let exec = TrainStepExec::load(&mut rt, &artifact).expect("compile artifact");
    // golden-config params; tokens from the synthetic corpus mod vocab
    let cfg = bbq::model::config::ModelConfig {
        name: "golden".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        d_ff: 64,
        vocab_size: 64,
        max_seq: 32,
        pos: bbq::model::PosEncoding::Learned,
        ln_eps: 1e-5,
    };
    let mut params = bbq::model::Params::init(&cfg, 7);
    let vocab = Vocab::build();
    let stream: Vec<usize> = test_stream(&vocab, steps * meta.seq + meta.seq)
        .into_iter()
        .map(|t| t % cfg.vocab_size)
        .collect();
    println!("training via PJRT artifact '{artifact}' (seq {})", meta.seq);
    for step in 0..steps {
        let off = step * meta.seq;
        let toks = &stream[off..off + meta.seq];
        let tgts = &stream[off + 1..off + meta.seq + 1];
        let loss = exec.step(toks, tgts, lr, &mut params).expect("train step");
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}: loss {loss:.4}");
        }
    }
}
