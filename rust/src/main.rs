//! `bbq` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   exp `<id>`       run a paper experiment (table1/3/4/5/6/8, fig1/3/4/5/7/10, all)
//!   train            train a model on the synthetic corpus (rust-native)
//!   train-pjrt       train via the AOT jax train-step artifact (PJRT)
//!   eval-ppl         perplexity of a model under a format
//!   eval-tasks       zero-shot downstream accuracy
//!   quantize         quantise a demo tensor, show formats + densities
//!   density          print memory/arithmetic density for every preset format
//!   profile-variance Figure-1-style variance profile
//!   search           mixed-precision TPE search
//!   search-plan      TPE search that emits a deployable plan artifact
//!                    (`--out plan.bbqp`; `--bits`, `--outliers`,
//!                    `--quick` for CI-sized runs) — load it back with
//!                    `--plan PATH` on serve/serve-bench/eval-ppl/eval-tasks
//!   serve            batched-inference demo with latency/throughput metrics
//!                    (`--stream` drives the live Engine API and prints
//!                    request 0's tokens as they arrive; `--temperature`,
//!                    `--top-k`, `--stop-token`, `--seed`, `--queue-depth`
//!                    set the per-request GenerationParams / engine queue;
//!                    `--kv-format <name>`/`--kv-page N` pick the paged
//!                    KV cache's storage format and page size;
//!                    `--draft-plan PATH` or `--draft-format <name>` turn
//!                    on self-drafting speculative decoding — the same
//!                    weights under a second, cheaper plan propose up to
//!                    `--spec-k N` tokens per round and the target model
//!                    verifies them in one chunked step, bit-identical to
//!                    target-only greedy decode;
//!                    `--listen ADDR` starts the HTTP/SSE front door
//!                    instead, printing live p50/p99 latency and queue-wait
//!                    snapshots until SIGTERM/SIGINT drains it)
//!   serve-bench      open-loop Poisson traffic against the HTTP front
//!                    door; writes BENCH_serve.json (`--quick` shrinks the
//!                    trace for CI, `--check` makes the SLO bars fatal,
//!                    `--trace-out`/`--trace-in` record/replay a trace;
//!                    `--kv-format`/`--kv-page` as for serve; TTFT p99 is
//!                    also gated per priority class:
//!                    `--slo-interactive-ttft-p99-ms`,
//!                    `--slo-batch-ttft-p99-ms`)
//!   bench-report     render BENCH_*.json files as markdown tables (CI
//!                    appends the output to $GITHUB_STEP_SUMMARY)
//!   bench-snapshot   fail if committed BENCH_*.json snapshots drifted
//!                    out of schema-sync with freshly produced ones
//!   artifacts        list AOT artifacts visible to the runtime
//!   isa              print detected/active/supported kernel ISA backends
//!                    (BBQ_ISA=scalar|avx2|neon overrides detection)
//!
//! Common options: `--model <preset>` `--format <name>` `--seq N` `--threads N`
//! `--plan PATH` (deploy a plan artifact) `--outliers F` (dense-and-sparse
//! overlay fraction on the uniform-format path)

#![allow(clippy::needless_range_loop, clippy::collapsible_if)]

use bbq::coordinator::experiment::{default_steps, get_or_train};
use bbq::coordinator::{
    run_batched, run_batched_with_draft, Engine, GenerationParams, Request, ServerConfig, TokenEvent,
};
use bbq::data::corpus::test_stream;
use bbq::data::lm_eval::perplexity_par;
use bbq::data::tasks::{evaluate, generate, Task};
use bbq::data::vocab::Vocab;
use bbq::model::plan::QuantPlan;
use bbq::model::Model;
use bbq::quant::config::{presets, QFormat};
use bbq::util::cli::Args;

/// `--kv-format <name> --kv-page N` → the serving stack's [`KvConfig`]
/// (defaults: f32 pages of 16 rows). Block formats (bfp/bm/bl) quantise
/// sealed KV pages; per-tensor formats are rejected by `validate`.
fn kv_config_from_args(args: &Args) -> bbq::model::KvConfig {
    let mut kv = bbq::model::KvConfig::default();
    if let Some(name) = args.get("kv-format") {
        kv.format = QFormat::parse(name).unwrap_or_else(|| panic!("unknown KV format '{name}'"));
    }
    kv.page_size = args.usize_or("kv-page", kv.page_size);
    kv
}

/// `--plan PATH` loads a deployable plan artifact (validated against the
/// model's shape + fingerprint); otherwise `--format <name>` picks a
/// uniform plan ("llm_int8"/"llm_int4" select the LLM.int8() baseline and
/// `--six-of-eight` quantises six of the eight GEMMs). `--outliers F`
/// adds a dense-and-sparse overlay (the top-F fraction of |w| kept
/// exactly in an f32 side table) on the fake-quant path.
fn plan_from_args(args: &Args, cfg: &bbq::model::ModelConfig) -> QuantPlan {
    if let Some(path) = args.get("plan") {
        return bbq::model::plan_file::load(std::path::Path::new(path), cfg)
            .unwrap_or_else(|e| panic!("load plan '{path}': {e}"));
    }
    let fmt_name = args.get_or("format", "fp32");
    match fmt_name.as_str() {
        "llm_int8" => QuantPlan::llm_int8(8),
        "llm_int4" => QuantPlan::llm_int8(4),
        name => {
            let fmt = QFormat::parse(name)
                .unwrap_or_else(|| panic!("unknown format '{name}' (try bfp_e8m5n16)"));
            let plan = if args.has_flag("six-of-eight") {
                QuantPlan::six_of_eight(fmt, cfg.n_layers)
            } else {
                QuantPlan::uniform(fmt)
            };
            plan.with_outliers(args.f64_or("outliers", 0.0) as f32)
        }
    }
}

/// `--draft-plan PATH` / `--draft-format <name>` select the quantisation
/// plan for the self-drafting speculative draft — the *same* trained
/// weights under a second, cheaper plan (typically BFP4). `None` when
/// neither flag is given: serving then runs target-only.
fn draft_plan_from_args(args: &Args, cfg: &bbq::model::ModelConfig) -> Option<QuantPlan> {
    if let Some(path) = args.get("draft-plan") {
        return Some(
            bbq::model::plan_file::load(std::path::Path::new(path), cfg)
                .unwrap_or_else(|e| panic!("load draft plan '{path}': {e}")),
        );
    }
    let name = args.get("draft-format")?;
    let fmt = QFormat::parse(name)
        .unwrap_or_else(|| panic!("unknown draft format '{name}' (try bfp_e8m3n16)"));
    Some(QuantPlan::uniform(fmt))
}

/// What the quantisation column of a report line should say: the plan
/// artifact path when one was loaded, the format name otherwise.
fn quant_label(args: &Args) -> String {
    match args.get("plan") {
        Some(path) => format!("plan:{path}"),
        None => args.get_or("format", "fp32"),
    }
}

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_str() {
        "exp" => {
            let id = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("table3");
            if !bbq::exp::run(id, &args) {
                eprintln!(
                    "unknown experiment '{id}'. available: {:?}",
                    bbq::exp::EXPERIMENTS
                );
                std::process::exit(2);
            }
        }
        "train" => {
            let preset = args.get_or("model", "tiny");
            let steps = args.usize_or("steps", default_steps(&preset));
            let p = get_or_train(&preset, steps, args.has_flag("quiet"));
            println!("trained/loaded {preset}: {} params", p.param_count());
        }
        "train-pjrt" => cmd_train_pjrt(&args),
        "eval-ppl" => {
            let preset = args.get_or("model", "tiny");
            let seq = args.usize_or("seq", 64);
            let chunks = args.usize_or("chunks", 8);
            let threads = args.usize_or("threads", 8);
            let steps = args.usize_or("steps", default_steps(&preset));
            let params = get_or_train(&preset, steps, true);
            let plan = plan_from_args(&args, &params.cfg);
            let model = Model::new(params, plan);
            let vocab = Vocab::build();
            let test = test_stream(&vocab, seq * chunks + seq);
            let r = perplexity_par(&model, &test, seq, chunks, threads);
            println!(
                "model={preset} format={} ppl={:.3} ({} tokens, {} chunks)",
                quant_label(&args),
                r.perplexity,
                r.tokens,
                r.chunks
            );
        }
        "eval-tasks" => {
            let preset = args.get_or("model", "tiny");
            let n = args.usize_or("examples", 60);
            let threads = args.usize_or("threads", 8);
            let params = get_or_train(&preset, default_steps(&preset), true);
            let plan = plan_from_args(&args, &params.cfg);
            let model = Model::new(params, plan);
            let vocab = Vocab::build();
            let mut mean = 0.0;
            let tasks = Task::zero_shot_suite();
            for &task in &tasks {
                let exs = generate(task, &vocab, 1000, n);
                let r = evaluate(&model, task, &exs, threads);
                println!("{:>10}: acc {:.1}%", task.name(), r.accuracy * 100.0);
                mean += r.accuracy;
            }
            println!("{:>10}: {:.1}%", "mean", mean / tasks.len() as f64 * 100.0);
        }
        "quantize" => cmd_quantize(&args),
        "density" => {
            let cost = bbq::density::arith::calibrate();
            println!("{:<18} {:>8} {:>8} {:>10}", "format", "bits/el", "mem", "arith");
            let mut fmts = vec![QFormat::Fp32];
            fmts.extend(presets::table3_formats().into_iter().map(|(_, f)| f));
            fmts.push(presets::bfp_w(5));
            for f in fmts {
                println!(
                    "{:<18} {:>8.2} {:>7.2}x {:>9.2}x",
                    f.name(),
                    f.bits_per_element(),
                    f.memory_density(),
                    cost.arithmetic_density(f)
                );
            }
        }
        "profile-variance" => {
            let preset = args.get_or("model", "tiny");
            let params = get_or_train(&preset, default_steps(&preset), true);
            let prof = bbq::profile::profile_variance(
                &params,
                args.usize_or("samples", 16),
                args.usize_or("seq", 64),
            );
            println!(
                "{}",
                prof.to_table(&format!("variance profile: {preset}")).render()
            );
        }
        "search" => cmd_search(&args),
        "search-plan" => cmd_search_plan(&args),
        "serve" => cmd_serve(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "bench-report" => cmd_bench_report(&args),
        "bench-snapshot" => cmd_bench_snapshot(&args),
        "artifacts" => {
            let rt = bbq::runtime::Runtime::open(&bbq::util::artifacts_dir())
                .expect("open artifacts dir");
            for name in rt.artifact_names() {
                let m = rt.meta(&name).unwrap();
                println!("{name}: kind={} fmt={} seq={}", m.kind, m.fmt, m.seq);
            }
        }
        "isa" => {
            use bbq::kernels;
            let forced = std::env::var("BBQ_ISA")
                .ok()
                .filter(|v| !v.trim().is_empty())
                .map(|v| format!(" (forced by BBQ_ISA={})", v.trim()))
                .unwrap_or_default();
            let supported = kernels::supported_backends();
            let names: Vec<&str> = supported.iter().map(|b| b.name()).collect();
            println!("detected:  {}", kernels::detected().name());
            println!("active:    {}{forced}", kernels::active().name());
            println!("supported: {}", names.join(" "));
        }
        "" | "help" | "--help" => {
            println!("{HELP}");
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{HELP}");
            std::process::exit(2);
        }
    }
}

const HELP: &str = "bbq — block-based quantisation lab (EMNLP 2023 reproduction)
usage: bbq <exp|train|train-pjrt|eval-ppl|eval-tasks|quantize|density|profile-variance|search|search-plan|serve|serve-bench|bench-report|bench-snapshot|artifacts|isa> [--opts]
see rust/src/main.rs header for the option list";

fn cmd_quantize(args: &Args) {
    use bbq::quant::fake_quant;
    use bbq::util::rng::Pcg32;
    let fmt_name = args.get_or("format", "bfp_e8m5n16");
    let fmt = QFormat::parse(&fmt_name).expect("unknown format");
    let mut rng = Pcg32::new(args.u64_or("seed", 1));
    let t = bbq::Tensor::new(
        &[2, 16],
        bbq::util::check::llmish_values(&mut rng, 32, 1.0, 0.05),
    );
    let q = fake_quant(&t, fmt);
    println!(
        "format: {} ({:.2} bits/element, {:.2}x memory density)",
        fmt.name(),
        fmt.bits_per_element(),
        fmt.memory_density()
    );
    for r in 0..2 {
        println!("in : {:?}", &t.row(r)[..8]);
        println!("out: {:?}", &q.row(r)[..8]);
    }
    println!("sqnr: {:.1} dB", bbq::util::stats::sqnr_db(&t.data, &q.data));
}

fn cmd_search(args: &Args) {
    use bbq::search::objective::Objective;
    use bbq::search::runner::{run_search, SearchConfig};
    use bbq::search::space::SearchSpace;
    let preset = args.get_or("model", "micro");
    let params = get_or_train(&preset, default_steps(&preset), true);
    let cfg = params.cfg.clone();
    let vocab = Vocab::build();
    let task = Task::parse(&args.get_or("task", "lambada")).expect("unknown task");
    let exs = generate(task, &vocab, 555, args.usize_or("examples", 40));
    let threads = args.usize_or("threads", 8);
    let fp32_acc = evaluate(
        &Model::new(params.clone(), QuantPlan::fp32()),
        task,
        &exs,
        threads,
    )
    .accuracy;
    let space = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
    let sc = SearchConfig {
        trials: args.usize_or("trials", 40),
        threads,
        seed: args.u64_or("seed", 7),
        objective: Objective::software(args.f64_or("alpha", 0.02)),
        ..Default::default()
    };
    let res = run_search(&params, space, task, &exs, fp32_acc, &sc);
    let b = res.best.as_ref().expect("no trials");
    println!(
        "fp32 acc {:.3}; best searched: acc {:.3} mem {:.2}x obj {:.3} ({} trials)",
        fp32_acc,
        b.accuracy,
        b.mem_density,
        b.objective,
        res.history.len()
    );
    for (name, bits) in res.bitwidth_profile().iter().take(16) {
        println!("  {name:<20} {bits:.2} bits");
    }
}

/// `bbq search-plan`: run the mixed-precision TPE search and emit the
/// best assignment as a deployable plan artifact (`--out`, default
/// plan.bbqp) that `serve --plan` / `eval-ppl --plan` load back.
/// `--quick` shrinks training + trials for CI; `--outliers F` bakes a
/// dense-and-sparse overlay fraction into the emitted plan; `--bits`
/// picks the BFP word-length choices the search mixes over.
fn cmd_search_plan(args: &Args) {
    use bbq::search::objective::Objective;
    use bbq::search::runner::{run_search, SearchConfig};
    use bbq::search::space::SearchSpace;
    let quick = args.has_flag("quick");
    let preset = args.get_or("model", "micro");
    let steps = args.usize_or("steps", if quick { 60 } else { default_steps(&preset) });
    let params = get_or_train(&preset, steps, true);
    let cfg = params.cfg.clone();
    let vocab = Vocab::build();
    let task = Task::parse(&args.get_or("task", "lambada")).expect("unknown task");
    let n_examples = args.usize_or("examples", if quick { 12 } else { 40 });
    let exs = generate(task, &vocab, 555, n_examples);
    let threads = args.usize_or("threads", 8);
    let fp32_acc = evaluate(
        &Model::new(params.clone(), QuantPlan::fp32()),
        task,
        &exs,
        threads,
    )
    .accuracy;
    let bits: Vec<u32> = args
        .get_or("bits", "3,4,5,6,8")
        .split(',')
        .map(|s| s.trim().parse().expect("--bits takes e.g. 3,4,6,8"))
        .collect();
    let space = SearchSpace::bfp_bits(&cfg, &bits);
    let sc = SearchConfig {
        trials: args.usize_or("trials", if quick { 8 } else { 40 }),
        threads,
        seed: args.u64_or("seed", 7),
        objective: Objective::software(args.f64_or("alpha", 0.02)),
        ..Default::default()
    };
    let res = run_search(&params, space, task, &exs, fp32_acc, &sc);
    let best = res.best.as_ref().expect("search produced no trials");
    let frac = args.f64_or("outliers", 0.005) as f32;
    let plan = res
        .best_plan()
        .expect("search produced no trials")
        .with_outliers(frac);
    let out = args.get_or("out", "plan.bbqp");
    let provenance = vec![
        format!(
            "emitted by `bbq search-plan` (model {preset}, task {}, {} trials, seed {})",
            task.name(),
            res.history.len(),
            sc.seed,
        ),
        format!(
            "best trial: acc {:.3} (fp32 {:.3}) mem {:.2}x obj {:.3}",
            best.accuracy, fp32_acc, best.mem_density, best.objective,
        ),
    ];
    bbq::model::plan_file::save(&plan, &cfg, std::path::Path::new(&out), &provenance)
        .unwrap_or_else(|e| panic!("save plan '{out}': {e}"));
    let mut widths: Vec<u32> = plan.per_site.values().map(|q| q.weight.word_bits()).collect();
    widths.sort_unstable();
    widths.dedup();
    println!(
        "wrote {out}: {} sites, weight bit-widths {widths:?}, outliers {frac}, \
         acc {:.3} (fp32 {:.3}), mem {:.2}x",
        plan.per_site.len(),
        best.accuracy,
        fp32_acc,
        best.mem_density,
    );
}

fn cmd_serve(args: &Args) {
    use std::io::Write;
    let preset = args.get_or("model", "tiny");
    let params = get_or_train(&preset, default_steps(&preset), true);
    let plan = plan_from_args(args, &params.cfg);
    // self-drafting: the draft shares the target's trained weights, only
    // the quantisation plan differs
    let draft = draft_plan_from_args(args, &params.cfg).map(|dp| Model::new(params.clone(), dp));
    let model = Model::new(params, plan);
    let vocab = Vocab::build();
    let n_req = args.usize_or("requests", 32);
    let stop_token: Option<usize> = args.get("stop-token").and_then(|s| s.parse().ok());
    let gen = GenerationParams {
        max_new_tokens: args.usize_or("new-tokens", 16),
        temperature: args.f64_or("temperature", 0.0) as f32,
        top_k: args.usize_or("top-k", 0),
        stop_tokens: stop_token.into_iter().collect(),
        seed: args.get("seed").and_then(|s| s.parse().ok()),
    };
    let reqs: Vec<Request> = (0..n_req)
        .map(|i| Request {
            id: i as u64,
            prompt: vocab.encode("the cat chased the"),
            params: gen.clone(),
        })
        .collect();
    let cfg = ServerConfig {
        max_batch: args.usize_or("max-batch", 8),
        prefill_chunk: args.usize_or("prefill-chunk", 8),
        queue_depth: args.usize_or("queue-depth", 64),
        kv: kv_config_from_args(args),
        spec_k: args.usize_or("spec-k", 4),
    };
    if let Some(listen) = args.get("listen") {
        let listen = listen.to_string();
        serve_listen(&listen, model, draft, &preset, cfg, args);
        return;
    }
    if args.has_flag("stream") {
        // live-engine demo: submit through an EngineHandle and stream
        // request 0's tokens as the scheduler produces them
        let model = std::sync::Arc::new(model);
        let engine = match draft {
            Some(d) => Engine::start_with_draft(model, std::sync::Arc::new(d), cfg),
            None => Engine::start(model, cfg),
        };
        let handles: Vec<_> = reqs
            .into_iter()
            .map(|r| engine.submit(r).expect("engine accepts while open"))
            .collect();
        let mut handles = handles.into_iter();
        if let Some(first) = handles.next() {
            print!("request 0:");
            while let Some(ev) = first.recv() {
                match ev {
                    TokenEvent::Token(t) => {
                        print!(" {}", vocab.decode(&[t]));
                        let _ = std::io::stdout().flush();
                    }
                    TokenEvent::Finished { reason, .. } => {
                        println!("  [{reason:?}]");
                        break;
                    }
                    _ => {}
                }
            }
        }
        for h in handles {
            h.wait();
        }
        let metrics = engine.shutdown();
        println!("{}", metrics.summary());
    } else {
        let (resps, metrics) = match &draft {
            Some(d) => run_batched_with_draft(&model, d, reqs, &cfg),
            None => run_batched(&model, reqs, &cfg),
        };
        println!("{}", metrics.summary());
        if let Some(r) = resps.first() {
            println!("sample completion: {}", vocab.decode(&r.tokens));
        }
    }
}

/// `bbq serve --listen ADDR`: stand up the network front door (engine →
/// router → HTTP server) on `addr` and run until SIGTERM/SIGINT, printing
/// live p50/p99 latency and queue-wait snapshots from the engine's
/// metrics between requests. On a signal the stack drains gracefully in
/// order: HTTP server (stop accepting), router (dispatch everything
/// accepted), engine (finish queued + in-flight requests).
fn serve_listen(
    addr: &str,
    model: Model,
    draft: Option<Model>,
    name: &str,
    cfg: ServerConfig,
    args: &Args,
) {
    use bbq::coordinator::{
        shutdown_signal, HttpConfig, HttpServer, ModelEntry, Router, RouterConfig,
    };
    use std::time::{Duration, Instant};
    let model = std::sync::Arc::new(model);
    let engine = match draft {
        Some(d) => Engine::start_with_draft(model.clone(), std::sync::Arc::new(d), cfg),
        None => Engine::start(model.clone(), cfg),
    };
    let entry = ModelEntry::for_model(name, engine.handle(), &model);
    let router = Router::new(vec![entry], RouterConfig::default());
    let server =
        HttpServer::bind(addr, router.handle(), HttpConfig::default()).expect("bind listen address");
    shutdown_signal::install();
    println!(
        "listening on http://{} (model {name}; isa {}; POST /v1/generate, GET /v1/metrics, \
         GET /healthz; SIGTERM/SIGINT drains)",
        server.local_addr(),
        bbq::kernels::active().name(),
    );
    let handle = engine.handle();
    let interval = Duration::from_millis(args.u64_or("metrics-interval-ms", 2000).max(100));
    let mut last_tick = Instant::now();
    let mut last_completed = usize::MAX; // force one initial line
    while !shutdown_signal::triggered() {
        std::thread::sleep(Duration::from_millis(100));
        if last_tick.elapsed() < interval {
            continue;
        }
        last_tick = Instant::now();
        let m = handle.metrics();
        if m.completed == last_completed {
            continue; // idle: don't scroll identical snapshots
        }
        last_completed = m.completed;
        println!(
            "[metrics] completed {} ({} cancelled) | {:.1} tok/s | latency p50/p99 \
             {:.1}/{:.1} ms | queue wait p50/p99 {:.1}/{:.1} ms | queue depth {} (peak {})",
            m.completed,
            m.cancelled,
            m.throughput_tps(),
            m.p(50.0),
            m.p(99.0),
            m.queue_wait.percentile(50.0),
            m.queue_wait.percentile(99.0),
            handle.queue_depth(),
            m.queue_peak,
        );
    }
    println!("shutdown signal received: draining (http server -> router -> engine)");
    server.shutdown();
    router.shutdown();
    let metrics = engine.shutdown();
    println!("{}", metrics.summary());
}

/// `bbq serve-bench`: open-loop Poisson traffic through the real HTTP
/// front door, end to end over localhost sockets. Writes BENCH_serve.json
/// next to the manifest. Under `--check` the SLO bars (zero dropped, zero
/// rejected, every request completed, TTFT p99 and inter-token-gap p99
/// under their bars) are hard failures.
fn cmd_serve_bench(args: &Args) {
    use bbq::coordinator::{serve_trace, HttpConfig, Priority, RouterConfig, Trace, TrafficConfig};
    use bbq::model::config::ModelConfig;
    use bbq::model::params::Params;
    use bbq::util::json::Json;

    let quick = args.has_flag("quick");
    let check = args.has_flag("check");
    let preset = args.get_or("model", "tiny");
    let mcfg = ModelConfig::preset(&preset);
    let (plan, fmt_name) = match args.get("plan") {
        Some(path) => {
            let plan = bbq::model::plan_file::load(std::path::Path::new(path), &mcfg)
                .unwrap_or_else(|e| panic!("load plan '{path}': {e}"));
            (plan, format!("plan:{path}"))
        }
        None => {
            let fmt_name = args.get_or("format", "bfp_e8m5n16");
            let fmt = QFormat::parse(&fmt_name)
                .unwrap_or_else(|| panic!("unknown format '{fmt_name}'"));
            let plan = QuantPlan::uniform(fmt).with_outliers(args.f64_or("outliers", 0.0) as f32);
            (plan, fmt.name())
        }
    };
    // untrained weights: the bench measures the serving stack, not the model
    let model = std::sync::Arc::new(Model::new(Params::init(&mcfg, 3), plan));
    let trace = match args.get("trace-in") {
        Some(path) => Trace::load(path).unwrap_or_else(|e| panic!("{e}")),
        None => Trace::poisson(&TrafficConfig {
            requests: args.usize_or("requests", if quick { 32 } else { 128 }),
            rate_rps: args.f64_or("rate", if quick { 16.0 } else { 24.0 }),
            prompt_len: (4, 16),
            new_tokens: (4, 12),
            vocab: mcfg.vocab_size,
            priority_mix: [0.5, 0.4, 0.1],
            seed: args.u64_or("seed", 0x5EED),
        }),
    };
    if let Some(path) = args.get("trace-out") {
        trace.save(path).expect("write trace file");
        println!("wrote trace ({} items) to {path}", trace.items.len());
    }
    let server_cfg = ServerConfig {
        max_batch: args.usize_or("max-batch", 8),
        prefill_chunk: args.usize_or("prefill-chunk", 8),
        // the zero-rejection SLO bar is structural: by default every
        // request in the trace can sit in the engine queue at once
        queue_depth: args.usize_or("queue-depth", trace.items.len().max(64)),
        kv: kv_config_from_args(args),
        spec_k: args.usize_or("spec-k", 4),
    };
    let queue_depth = server_cfg.queue_depth;
    let router_cfg = RouterConfig {
        class_depth: trace.items.len().max(256),
        ..RouterConfig::default()
    };
    println!(
        "serve-bench: {} requests, model {preset} / {fmt_name}{}{}",
        trace.items.len(),
        if quick { ", quick" } else { "" },
        if check { ", gated" } else { "" },
    );
    let (report, metrics) = serve_trace(model, server_cfg, router_cfg, HttpConfig::default(), &trace);

    let slo_ttft = args.f64_or("slo-ttft-p99-ms", 2500.0);
    let slo_gap = args.f64_or("slo-token-p99-ms", 500.0);
    // per-class TTFT bars: interactive is held to a tighter bar than the
    // aggregate, batch to a looser one — the aggregate alone would let a
    // scheduler starve interactive traffic behind batch and still pass
    let slo_class_ttft = [
        args.f64_or("slo-interactive-ttft-p99-ms", 2000.0),
        args.f64_or("slo-standard-ttft-p99-ms", slo_ttft),
        args.f64_or("slo-batch-ttft-p99-ms", 5000.0),
    ];
    let ttft_p99 = report.ttft_ms.percentile(99.0);
    let gap_p99 = report.token_gap_ms.percentile(99.0);
    let mut failures: Vec<String> = Vec::new();
    if report.dropped > 0 {
        failures.push(format!("{} dropped requests (bar: 0)", report.dropped));
    }
    if report.rejected > 0 {
        failures.push(format!("{} rejected requests (bar: 0)", report.rejected));
    }
    if report.completed != report.sent {
        failures.push(format!(
            "completed {}/{} (bar: every request)",
            report.completed, report.sent
        ));
    }
    if ttft_p99 > slo_ttft {
        failures.push(format!("TTFT p99 {ttft_p99:.1} ms > {slo_ttft:.0} ms bar"));
    }
    if gap_p99 > slo_gap {
        failures.push(format!("token gap p99 {gap_p99:.1} ms > {slo_gap:.0} ms bar"));
    }
    for p in Priority::ALL {
        let h = &report.class_ttft_ms[p.index()];
        if h.count() == 0 {
            continue; // the trace carried no traffic in this class
        }
        let p99 = h.percentile(99.0);
        if p99 > slo_class_ttft[p.index()] {
            failures.push(format!(
                "{} TTFT p99 {p99:.1} ms > {:.0} ms bar",
                p.as_str(),
                slo_class_ttft[p.index()],
            ));
        }
    }
    let pass = failures.is_empty();

    let mut doc = report.to_json();
    if let Json::Obj(map) = &mut doc {
        map.insert("bench".to_string(), Json::Str("serve".to_string()));
        map.insert("model".to_string(), Json::Str(preset.clone()));
        map.insert("format".to_string(), Json::Str(fmt_name.clone()));
        map.insert("quick".to_string(), Json::Bool(quick));
        map.insert("queue_depth".to_string(), Json::Num(queue_depth as f64));
        map.insert("queue_peak".to_string(), Json::Num(metrics.queue_peak as f64));
        map.insert(
            "engine_completed".to_string(),
            Json::Num(metrics.completed as f64),
        );
        map.insert(
            "engine_cancelled".to_string(),
            Json::Num(metrics.cancelled as f64),
        );
        map.insert(
            "slo".to_string(),
            Json::obj(vec![
                ("ttft_p99_ms_bar", Json::Num(slo_ttft)),
                ("token_gap_p99_ms_bar", Json::Num(slo_gap)),
                (
                    "class_ttft_p99_ms_bars",
                    Json::Obj(
                        Priority::ALL
                            .iter()
                            .map(|&p| {
                                (p.as_str().to_string(), Json::Num(slo_class_ttft[p.index()]))
                            })
                            .collect(),
                    ),
                ),
                ("pass", Json::Bool(pass)),
            ]),
        );
    }
    let out = args.get_or("out", "BENCH_serve.json");
    std::fs::write(&out, doc.to_string() + "\n").expect("write BENCH_serve.json");

    println!(
        "  offered {:.1} rps | achieved {:.1} rps, {:.1} tok/s | completed {}/{} \
         (rejected {}, dropped {})",
        report.offered_rps,
        report.achieved_rps,
        report.achieved_tps,
        report.completed,
        report.sent,
        report.rejected,
        report.dropped,
    );
    println!(
        "  TTFT p50/p99 {:.1}/{:.1} ms | token gap p50/p99 {:.1}/{:.1} ms | request p99 {:.1} ms \
         | queue peak {}",
        report.ttft_ms.percentile(50.0),
        ttft_p99,
        report.token_gap_ms.percentile(50.0),
        gap_p99,
        report.request_ms.percentile(99.0),
        metrics.queue_peak,
    );
    let class_line: Vec<String> = Priority::ALL
        .iter()
        .map(|&p| {
            let h = &report.class_ttft_ms[p.index()];
            if h.count() == 0 {
                format!("{} -", p.as_str())
            } else {
                format!("{} {:.1} ms (n={})", p.as_str(), h.percentile(99.0), h.count())
            }
        })
        .collect();
    println!("  TTFT p99 by class: {}", class_line.join(" | "));
    println!("  wrote {out}");
    if pass {
        println!("  all serve SLO bars met");
    } else {
        println!("serve SLO bars missed:");
        for f in &failures {
            println!("  FAIL: {f}");
        }
        if check {
            std::process::exit(1);
        }
        println!("  (run with --check to make these fatal)");
    }
}

/// `BENCH_*.json` files directly under `dir`, sorted by name.
fn bench_files(dir: &str) -> Vec<std::path::PathBuf> {
    let mut out: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                        .unwrap_or(false)
                })
                .collect()
        })
        .unwrap_or_default();
    out.sort();
    out
}

fn read_bench_json(path: &std::path::Path) -> bbq::util::json::Json {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    bbq::util::json::Json::parse(&text).unwrap_or_else(|e| panic!("parse {}: {e}", path.display()))
}

/// `bbq bench-report [files...]`: one markdown table per BENCH_*.json
/// (positional paths, or every BENCH_*.json under `--dir`, default `.`).
/// CI appends the output to `$GITHUB_STEP_SUMMARY`.
fn cmd_bench_report(args: &Args) {
    use bbq::util::report::markdown_table;
    let files: Vec<std::path::PathBuf> = if args.positional.is_empty() {
        bench_files(&args.get_or("dir", "."))
    } else {
        args.positional.iter().map(std::path::PathBuf::from).collect()
    };
    if files.is_empty() {
        println!("no BENCH_*.json files found");
        return;
    }
    for path in files {
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        print!("{}", markdown_table(&name, &read_bench_json(&path)));
    }
}

/// `bbq bench-snapshot --committed DIR --fresh DIR`: for every committed
/// `BENCH_*.json` snapshot, require a freshly produced file of the same
/// name whose *schema* (dotted key set) matches. Values are ignored — the
/// committed trajectory files hold nulls until refreshed from CI — and so
/// are the `pending_first_ci_run`/`note` bookkeeping keys the committed
/// copies carry. Exits 1 on any drift.
fn cmd_bench_snapshot(args: &Args) {
    use bbq::util::json::Json;
    use bbq::util::report::schema_diff;
    let committed_dir = args.get_or("committed", "..");
    let fresh_dir = args.get_or("fresh", ".");
    let committed = bench_files(&committed_dir);
    if committed.is_empty() {
        eprintln!("no committed BENCH_*.json snapshots under {committed_dir}");
        std::process::exit(1);
    }
    let strip_bookkeeping = |mut doc: Json| -> Json {
        if let Json::Obj(map) = &mut doc {
            map.remove("pending_first_ci_run");
            map.remove("note");
        }
        doc
    };
    let mut problems: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for cpath in committed {
        let name = cpath
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| cpath.display().to_string());
        let fpath = std::path::Path::new(&fresh_dir).join(&name);
        if !fpath.exists() {
            problems.push(format!(
                "{name}: committed snapshot has no freshly produced counterpart in {fresh_dir}"
            ));
            continue;
        }
        checked += 1;
        for d in schema_diff(
            &strip_bookkeeping(read_bench_json(&cpath)),
            &read_bench_json(&fpath),
        ) {
            problems.push(format!("{name}: {d}"));
        }
    }
    if problems.is_empty() {
        println!("bench snapshots: {checked} file(s) schema-synced with fresh output");
    } else {
        println!("bench snapshot drift (refresh the committed BENCH_*.json from CI artifacts):");
        for p in &problems {
            println!("  FAIL: {p}");
        }
        std::process::exit(1);
    }
}

fn cmd_train_pjrt(args: &Args) {
    use bbq::runtime::{Runtime, TrainStepExec};
    let artifact = args.get_or("artifact", "train_step_golden");
    let steps = args.usize_or("steps", 50);
    let lr = args.f64_or("lr", 0.5) as f32;
    let mut rt = Runtime::open(&bbq::util::artifacts_dir()).expect("open artifacts");
    let meta = rt.meta(&artifact).expect("artifact not in manifest").clone();
    let exec = TrainStepExec::load(&mut rt, &artifact).expect("compile artifact");
    // golden-config params; tokens from the synthetic corpus mod vocab
    let cfg = bbq::model::config::ModelConfig {
        name: "golden".into(),
        n_layers: 2,
        d_model: 32,
        n_heads: 2,
        d_ff: 64,
        vocab_size: 64,
        max_seq: 32,
        pos: bbq::model::PosEncoding::Learned,
        ln_eps: 1e-5,
    };
    let mut params = bbq::model::Params::init(&cfg, 7);
    let vocab = Vocab::build();
    let stream: Vec<usize> = test_stream(&vocab, steps * meta.seq + meta.seq)
        .into_iter()
        .map(|t| t % cfg.vocab_size)
        .collect();
    println!("training via PJRT artifact '{artifact}' (seq {})", meta.seq);
    for step in 0..steps {
        let off = step * meta.seq;
        let toks = &stream[off..off + meta.seq];
        let tgts = &stream[off + 1..off + meta.seq + 1];
        let loss = exec.step(toks, tgts, lr, &mut params).expect("train step");
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}: loss {loss:.4}");
        }
    }
}
