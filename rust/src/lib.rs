//! # bbq — Block-Based Quantisation for sub-8-bit LLM inference
//!
//! A Rust + JAX/Pallas reproduction of *"Revisiting Block-based
//! Quantisation: What is Important for Sub-8-bit LLM Inference?"*
//! (Zhang et al., EMNLP 2023).
//!
//! Layer map (see DESIGN.md):
//! - [`quant`] / [`density`]: the paper's numeric formats and hardware
//!   efficiency metrics (§3).
//! - [`model`] / [`data`] / [`train`]: the LLM substrate the formats are
//!   evaluated on (Algorithm 2, WikiText-style LM eval, downstream tasks,
//!   fine-tuning for Table 8).
//! - [`kernels`]: runtime-dispatched SIMD microkernels (AVX2/NEON with a
//!   scalar reference, all bit-identical) under every GEMM and block decode.
//! - [`baselines`]: LLM.int8(), SmoothQuant(-c), GPTQ re-implementations.
//! - [`search`]: the TPE mixed-precision search (§3.3, §4.4).
//! - [`runtime`] / [`coordinator`]: PJRT execution of AOT-compiled JAX
//!   artifacts and the serving stack — the live `Engine` (submission,
//!   token streaming, cancellation), its batch wrapper, and experiment
//!   orchestration.

// Style lints that fight the numeric-kernel idiom used throughout the
// crate (explicit index loops over several buffers at once, wide kernel
// signatures, inherent to_string on the no-dependency JSON type). CI runs
// clippy with `-D warnings`; correctness lints stay enabled.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_range_contains,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::excessive_precision,
    clippy::inherent_to_string,
    clippy::redundant_closure,
    clippy::vec_init_then_push,
    clippy::manual_memcpy,
    clippy::needless_bool
)]

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod density;
pub mod kernels;
pub mod model;
pub mod profile;
pub mod quant;
pub mod runtime;
pub mod search;
pub mod tensor;
pub mod train;
pub mod util;

pub use quant::config::{GemmQuant, QFormat};
pub use tensor::Tensor;
