//! Table 3: zero-shot PTQ perplexity on the WikiText2-substitute, across
//! the format sweep and the re-implemented baselines, with memory and
//! arithmetic densities.

use crate::baselines::{gptq, smoothquant};
use crate::coordinator::experiment::{default_steps, get_or_train, save_result};
use crate::data::corpus::{test_stream, train_stream};
use crate::data::lm_eval::perplexity_par;
use crate::data::vocab::Vocab;
use crate::density::arith::calibrate;
use crate::model::plan::QuantPlan;
use crate::model::Model;
use crate::quant::config::{presets, QFormat};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

pub fn run(args: &Args) {
    let sizes: Vec<String> = args
        .get_or("sizes", "micro,tiny,small,base")
        .split(',')
        .map(String::from)
        .collect();
    let seq = args.usize_or("seq", 64);
    let chunks = args.usize_or("chunks", 8);
    let threads = args.usize_or("threads", 8);
    let vocab = Vocab::build();
    let test = test_stream(&vocab, seq * chunks + seq);
    let cal: Vec<Vec<usize>> = train_stream(&vocab, 8 * 48)
        .chunks(48)
        .take(8)
        .map(|c| c.to_vec())
        .collect();
    let cost = calibrate();

    let mut header = vec!["Method".to_string(), "Config".to_string()];
    header.extend(sizes.iter().cloned());
    header.push("Mem↑".into());
    header.push("Arith↑".into());
    let mut table = Table::new(
        "Table 3 — PTQ perplexity (synthetic WikiText substitute)",
        &header.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    // evaluate one (method name, config, model builder, mem, arith) row
    let mut eval_row = |method: &str,
                        config: &str,
                        mem: String,
                        arith: String,
                        build: &dyn Fn(&crate::model::Params) -> Model| {
        let mut row = vec![method.to_string(), config.to_string()];
        for size in &sizes {
            let params = get_or_train(size, default_steps(size), true);
            let model = build(&params);
            let ppl = perplexity_par(&model, &test, seq, chunks, threads).perplexity;
            row.push(fnum(ppl, 2));
            eprintln!("[table3] {method} {size}: ppl {ppl:.2}");
        }
        row.push(mem);
        row.push(arith);
        table.row(row);
    };

    let ad = |f: QFormat| format!("{:.1}x", cost.arithmetic_density(f));
    let md = |f: QFormat| format!("{:.1}x", f.memory_density());

    eval_row("FP32", "-", "1x".into(), "1x".into(), &|p| {
        Model::new(p.clone(), QuantPlan::fp32())
    });
    eval_row("LLM.int8()", "W8A8", "2x".into(), format!("<{}", ad(presets::fixed8())), &|p| {
        Model::new(p.clone(), QuantPlan::llm_int8(8))
    });
    eval_row("ZeroQuant", "W4A8", "6.4x".into(), format!("<{}", ad(presets::fixed8())), &|p| {
        Model::new(
            p.clone(),
            QuantPlan::wa(presets::zeroquant_w(), presets::zeroquant_a()),
        )
    });
    eval_row("GPTQ", "W4", "<1.6x".into(), "-".into(), &|p| {
        gptq::build(p, &cal, 4, 0.01)
    });
    let sq_mem = format!("<{}", md(presets::fixed8()));
    let sq_arith = format!("<{}", ad(presets::fixed8()));
    eval_row("SmoothQuant", "W8A8", sq_mem, sq_arith, &|p| {
        smoothquant::build(p, &cal, 0.5).0
    });
    eval_row("SmoothQuant-c", "W8A8", md(presets::fixed8()), ad(presets::fixed8()), &|p| {
        smoothquant::build(p, &cal, 0.5).1
    });
    for (name, fmt) in presets::table3_formats() {
        let (method, config) = name.rsplit_once(' ').map(|(a, b)| (a, b)).unwrap_or((name, ""));
        eval_row(method, config, md(fmt), ad(fmt), &|p| {
            Model::new(p.clone(), QuantPlan::uniform(fmt))
        });
    }

    save_result(
        "table3",
        &table,
        Some(Json::obj(vec![
            ("seq", Json::Num(seq as f64)),
            ("chunks", Json::Num(chunks as f64)),
        ])),
    );
}
