//! Table 8: 4/5-bit LLMs via fine-tuning — *PTQ on fine-tuned FP32* vs
//! *TAQ on downstream* across the four tasks zero-shot prompting cannot
//! handle (SST2, QNLI, MRPC, COLA), tracked per epoch.

use crate::coordinator::experiment::{default_steps, get_or_train, save_result};
use crate::data::tasks::{evaluate, generate, Task};
use crate::data::vocab::Vocab;
use crate::model::plan::QuantPlan;
use crate::model::Model;
use crate::quant::config::presets;
use crate::train::finetune_task;
use crate::util::cli::Args;
use crate::util::table::Table;

pub fn run(args: &Args) {
    let sizes: Vec<String> = args
        .get_or("sizes", "micro,tiny")
        .split(',')
        .map(String::from)
        .collect();
    let epochs = args.usize_or("epochs", 3);
    let bits = args.usize_or("bits", 5) as u32;
    let n_train = args.usize_or("train-examples", 192);
    let n_test = args.usize_or("test-examples", 64);
    let lr = args.f64_or("lr", 4e-3) as f32;
    let threads = args.usize_or("threads", 8);
    let vocab = Vocab::build();
    let fmt = presets::bfp_w(bits);

    let mut header = vec![
        "Task".to_string(),
        "Style".to_string(),
        "Config".to_string(),
        "Size".to_string(),
        "zero-shot".to_string(),
    ];
    for e in 0..epochs {
        header.push(format!("epoch {e}"));
    }
    let mut t = Table::new(
        &format!("Table 8 — PTQ-on-finetuned vs TAQ (W{bits}A{bits} BFP)"),
        &header.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    for task in Task::finetune_suite() {
        let train_exs = generate(task, &vocab, 3000, n_train);
        let test_exs = generate(task, &vocab, 4000, n_test);
        for size in &sizes {
            let base = get_or_train(size, default_steps(size), true);
            let metric = |m: &Model| evaluate(m, task, &test_exs, threads).metric;
            let zs = metric(&Model::new(base.clone(), QuantPlan::fp32()));

            // --- FP32 reference fine-tuning ---
            let mut p_fp = base.clone();
            let mut fp_epochs = Vec::new();
            for e in 0..epochs {
                finetune_task(&mut p_fp, &QuantPlan::fp32(), &train_exs, 2, lr, 100 + e as u64);
                fp_epochs.push(metric(&Model::new(p_fp.clone(), QuantPlan::fp32())));
            }
            // --- PTQ on fine-tuned FP32: quantise the FP32 checkpoints ---
            let mut ptq_epochs = Vec::new();
            {
                let mut p = base.clone();
                for e in 0..epochs {
                    finetune_task(&mut p, &QuantPlan::fp32(), &train_exs, 2, lr, 100 + e as u64);
                    ptq_epochs
                        .push(metric(&Model::new(p.clone(), QuantPlan::uniform(fmt))));
                }
            }
            // --- TAQ: fine-tune the quantised model through the STE ---
            let mut taq_epochs = Vec::new();
            {
                let mut p = base.clone();
                let plan = QuantPlan::uniform(fmt);
                for e in 0..epochs {
                    finetune_task(&mut p, &plan, &train_exs, 2, lr, 200 + e as u64);
                    taq_epochs.push(metric(&Model::new(p.clone(), plan.clone())));
                }
            }
            eprintln!(
                "[table8] {} {size}: zs {zs:.3} fp32 {:?} ptq {:?} taq {:?}",
                task.name(),
                fp_epochs.last(),
                ptq_epochs.last(),
                taq_epochs.last()
            );
            let pct = |v: f64| format!("{:.1}%", v * 100.0);
            let mut mkrow = |style: &str, cfgname: String, vals: &[f64]| {
                let mut row = vec![
                    task.name().to_string(),
                    style.to_string(),
                    cfgname,
                    size.clone(),
                    pct(zs),
                ];
                row.extend(vals.iter().map(|&v| pct(v)));
                t.row(row);
            };
            mkrow("FP32", "W32A32".into(), &fp_epochs);
            mkrow("PTQ on downstream", format!("W{bits}A{bits}"), &ptq_epochs);
            mkrow("TAQ on downstream", format!("W{bits}A{bits}"), &taq_epochs);
        }
    }
    save_result("table8", &t, None);
}
