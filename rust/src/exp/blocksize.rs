//! Variation-aware block size (paper §4.4 + Appendix A).
//!
//! The paper observes that weight variance is small and stable while
//! activation variance is large and grows, and proposes *larger* weight
//! blocks with *smaller* activation blocks to gain memory density at
//! equal accuracy. This driver sweeps the weight and activation block
//! sizes independently for W4A4 BFP and reports perplexity + memory
//! density for each combination, plus the uniform diagonal.

use crate::coordinator::experiment::{default_steps, get_or_train, save_result};
use crate::data::corpus::test_stream;
use crate::data::lm_eval::perplexity_par;
use crate::data::vocab::Vocab;
use crate::model::plan::QuantPlan;
use crate::model::Model;
use crate::quant::config::QFormat;
use crate::search::objective::plan_memory_density;
use crate::util::cli::Args;
use crate::util::table::{fnum, Table};

fn bfp_n(m_bits: u32, n: u32) -> QFormat {
    QFormat::Bfp { e: 8, m: m_bits, n }
}

pub fn run(args: &Args) {
    let preset = args.get_or("model", "tiny");
    let bits = args.usize_or("bits", 4) as u32;
    let m = bits - 1;
    let seq = args.usize_or("seq", 64);
    let chunks = args.usize_or("chunks", 6);
    let threads = args.usize_or("threads", 8);
    let blocks: Vec<u32> = args
        .get_or("blocks", "4,16,64")
        .split(',')
        .filter_map(|s| s.parse().ok())
        .collect();
    let vocab = Vocab::build();
    let test = test_stream(&vocab, seq * chunks + seq);
    let params = get_or_train(&preset, default_steps(&preset), true);
    let cfg = params.cfg.clone();

    let mut t = Table::new(
        &format!("Variation-aware block size — W{bits}A{bits} BFP on {preset} (ppl / mem density)"),
        &["weight N \\ act N", "ppl", "mem", "note"],
    );
    let fp32 = {
        let model = Model::new(params.clone(), QuantPlan::fp32());
        perplexity_par(&model, &test, seq, chunks, threads).perplexity
    };
    t.row(vec!["fp32".into(), fnum(fp32, 3), "1.0x".into(), "reference".into()]);
    let mut best: Option<(u32, u32, f64, f64)> = None;
    for &wn in &blocks {
        for &an in &blocks {
            let plan = QuantPlan::wa(bfp_n(m, wn), bfp_n(m, an));
            let model = Model::new(params.clone(), plan.clone());
            let ppl = perplexity_par(&model, &test, seq, chunks, threads).perplexity;
            let mem = plan_memory_density(&cfg, &plan, seq);
            let note = if wn == an {
                "uniform"
            } else if wn > an {
                "variation-aware (paper's direction)"
            } else {
                ""
            };
            eprintln!("[blocksize] W n={wn} A n={an}: ppl {ppl:.3} mem {mem:.2}x");
            t.row(vec![
                format!("w{wn} / a{an}"),
                fnum(ppl, 3),
                format!("{mem:.2}x"),
                note.into(),
            ]);
            let better = match best {
                None => true,
                Some((_, _, bppl, bmem)) => {
                    // prefer configs dominating on both axes, else best ppl
                    ppl < bppl && mem >= bmem * 0.98
                }
            };
            if better {
                best = Some((wn, an, ppl, mem));
            }
        }
    }
    if let Some((wn, an, ppl, mem)) = best {
        println!(
            "best block config: weight N={wn}, act N={an} → ppl {ppl:.3} at {mem:.2}x \
             (paper predicts large-weight/small-activation blocks win)"
        );
    }
    save_result("blocksize", &t, None);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant;
    use crate::util::check::llmish_values;
    use crate::util::rng::Pcg32;
    use crate::Tensor;

    #[test]
    fn bigger_blocks_cheaper_but_noisier_on_outliers() {
        let mut rng = Pcg32::new(1);
        let x = Tensor::new(&[8, 256], llmish_values(&mut rng, 2048, 1.0, 0.02));
        let err = |n: u32| {
            let q = fake_quant(&x, bfp_n(3, n));
            crate::util::stats::mse(&x.data, &q.data)
        };
        // memory density rises with N…
        assert!(bfp_n(3, 64).memory_density() > bfp_n(3, 16).memory_density());
        // …while error rises too on outlier-bearing data
        assert!(err(64) >= err(16), "{} vs {}", err(64), err(16));
        assert!(err(16) >= err(4) * 0.99);
    }

    #[test]
    fn weights_tolerate_big_blocks_better_than_activations() {
        // weights ~ N(0, 0.02) without outliers: enlarging the block
        // barely hurts. activations with outliers: enlarging hurts a lot.
        let mut rng = Pcg32::new(2);
        let w = Tensor::randn(&[16, 256], 0.02, &mut rng);
        let a = Tensor::new(&[16, 256], llmish_values(&mut rng, 4096, 1.0, 0.03));
        let rel_growth = |t: &Tensor| {
            let e = |n: u32| {
                crate::util::stats::mse(&t.data, &fake_quant(t, bfp_n(3, n)).data)
            };
            e(64) / e(4).max(1e-18)
        };
        assert!(
            rel_growth(&a) > rel_growth(&w) * 1.2,
            "act growth {} vs weight growth {}",
            rel_growth(&a),
            rel_growth(&w)
        );
    }
}
