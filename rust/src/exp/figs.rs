//! Figure drivers: 1/4/5 (variance profiles), 3/8/9 (searched bit-width
//! distributions), 7 (uniform vs mixed 4-bit), 10 (hardware-aware search),
//! plus the conceptual Table 1 comparison matrix.

use crate::coordinator::experiment::{default_steps, get_or_train, save_result};
use crate::data::tasks::{evaluate, generate, Task};
use crate::data::vocab::Vocab;
use crate::density::arith::calibrate;
use crate::model::plan::QuantPlan;
use crate::model::Model;
use crate::profile::profile_variance;
use crate::quant::config::presets;
use crate::search::objective::{plan_memory_density, Objective};
use crate::search::runner::{run_search, SearchConfig};
use crate::search::space::SearchSpace;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::{ascii_plot, Table};

/// Figures 1 (largest model), 4 (RoPE) and 5 (size trend).
pub fn fig1(args: &Args, rope: bool) {
    let preset = args.get_or("model", if rope { "rope-tiny" } else { "base" });
    let samples = args.usize_or("samples", 24);
    let seq = args.usize_or("seq", 64);
    let params = if rope {
        super::table4::rope_params_pub(&preset, true)
    } else {
        get_or_train(&preset, default_steps(&preset), true)
    };
    let prof = profile_variance(&params, samples, seq);
    let id = if rope { "fig4" } else { "fig1" };
    let t = prof.to_table(&format!(
        "Figure {} — per-tensor variance vs layer ({preset})",
        if rope { "4" } else { "1" }
    ));
    save_result(id, &t, None);
    let series: Vec<(String, Vec<f64>)> = prof
        .act
        .iter()
        .map(|(n, s)| (n.clone(), s.clone()))
        .collect();
    let plot = ascii_plot("activation variance vs layer", &series, 14);
    println!("{plot}");
    println!(
        "K-depth-trend slope: {:+.4}  (paper: variance grows with depth)",
        prof.activation_depth_trend("K")
    );
    println!(
        "weight/activation variance ratio: {:.4}  (paper: weights ≪ activations)",
        prof.weight_act_ratio()
    );
}

/// Figure 5: the variance-depth slope across model sizes.
pub fn fig5(args: &Args) {
    let sizes: Vec<String> = args
        .get_or("sizes", "tiny,small,base")
        .split(',')
        .map(String::from)
        .collect();
    let samples = args.usize_or("samples", 16);
    let mut t = Table::new(
        "Figure 5 — activation variance growth with depth, by model size",
        &["Model", "K slope", "Q slope", "X2 slope", "mean act var", "mean weight var"],
    );
    for size in &sizes {
        let params = get_or_train(size, default_steps(size), true);
        let prof = profile_variance(&params, samples, 64);
        let mean_act: f64 = prof
            .act
            .iter()
            .flat_map(|(_, s)| s.iter().copied())
            .sum::<f64>()
            / (prof.act.len() * prof.n_layers) as f64;
        let mean_w: f64 = prof
            .weight
            .iter()
            .flat_map(|(_, s)| s.iter().copied())
            .sum::<f64>()
            / (prof.weight.len() * prof.n_layers) as f64;
        t.row(vec![
            size.clone(),
            format!("{:+.5}", prof.activation_depth_trend("K")),
            format!("{:+.5}", prof.activation_depth_trend("Q")),
            format!("{:+.5}", prof.activation_depth_trend("X2")),
            format!("{:.4}", mean_act),
            format!("{:.5}", mean_w),
        ]);
    }
    save_result("fig5", &t, None);
}

/// Figures 3/8/9: repeated mixed-precision searches → bit-width profile.
pub fn fig3(args: &Args) {
    let preset = args.get_or("model", "tiny");
    let n_seeds = args.usize_or("seeds", 3);
    let trials = args.usize_or("trials", 40);
    let examples = args.usize_or("examples", 40);
    let threads = args.usize_or("threads", 8);
    let vocab = Vocab::build();
    let params = get_or_train(&preset, default_steps(&preset), true);
    let cfg = params.cfg.clone();
    let task = Task::Lambada;
    let exs = generate(task, &vocab, 555, examples);
    let fp32_acc = evaluate(
        &Model::new(params.clone(), QuantPlan::fp32()),
        task,
        &exs,
        threads,
    )
    .accuracy;
    let uniform4 = evaluate(
        &Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(4))),
        task,
        &exs,
        threads,
    )
    .accuracy;

    let mut layer_profiles: Vec<Vec<f64>> = Vec::new();
    let mut best_acc = 0.0f64;
    let mut best_mem = 0.0f64;
    for seed in 0..n_seeds {
        let space = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
        let sc = SearchConfig {
            trials,
            seed: 1000 + seed as u64,
            threads,
            acc_threshold: 0.05,
            mem_threshold: presets::bfp_w(4).memory_density() * 0.95,
            objective: Objective::software(0.02),
            ..Default::default()
        };
        let res = run_search(&params, space, task, &exs, fp32_acc, &sc);
        if let Some(b) = &res.best {
            eprintln!(
                "[fig3 seed {seed}] best acc {:.3} mem {:.2}x obj {:.3}",
                b.accuracy, b.mem_density, b.objective
            );
            if b.accuracy > best_acc {
                best_acc = b.accuracy;
                best_mem = b.mem_density;
            }
        }
        layer_profiles.push(res.layer_bit_profile(cfg.n_layers));
    }
    let header: Vec<String> = {
        let mut h = vec!["seed".to_string()];
        h.extend((0..cfg.n_layers).map(|l| format!("L{l}")));
        h
    };
    let mut t = Table::new(
        "Figure 3/8/9 — searched mean bit width per layer (higher = less tolerant)",
        &header.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for (si, prof) in layer_profiles.iter().enumerate() {
        let mut row = vec![format!("{si}")];
        row.extend(prof.iter().map(|b| format!("{b:.2}")));
        t.row(row);
    }
    save_result("fig3", &t, Some(Json::obj(vec![
        ("fp32_acc", Json::Num(fp32_acc)),
        ("uniform4_acc", Json::Num(uniform4)),
        ("best_searched_acc", Json::Num(best_acc)),
        ("best_searched_mem", Json::Num(best_mem)),
    ])));
    println!(
        "LAMBADA-like: fp32 {:.1}% | uniform 4-bit {:.1}% | searched mixed {:.1}% at {:.2}x mem",
        fp32_acc * 100.0,
        uniform4 * 100.0,
        best_acc * 100.0,
        best_mem
    );
}

/// Figure 7: FP32 vs uniform-4bit vs searched mixed-4bit across sizes.
pub fn fig7(args: &Args) {
    let sizes: Vec<String> = args
        .get_or("sizes", "micro,tiny")
        .split(',')
        .map(String::from)
        .collect();
    let examples = args.usize_or("examples", 40);
    let trials = args.usize_or("trials", 30);
    let threads = args.usize_or("threads", 8);
    let vocab = Vocab::build();
    let mut t = Table::new(
        "Figure 7 — FP32 vs uniform 4-bit vs mixed-precision 4-bit",
        &["Task", "Model", "FP32", "uniform 4-bit", "mixed 4-bit", "mixed mem"],
    );
    for task in [Task::Lambada, Task::ArcEasy] {
        for size in &sizes {
            let params = get_or_train(size, default_steps(size), true);
            let cfg = params.cfg.clone();
            let exs = generate(task, &vocab, 555, examples);
            let acc = |plan: QuantPlan| {
                evaluate(&Model::new(params.clone(), plan), task, &exs, threads).accuracy
            };
            let fp32 = acc(QuantPlan::fp32());
            let uni4 = acc(QuantPlan::uniform(presets::bfp_w(4)));
            let space = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
            let sc = SearchConfig {
                trials,
                threads,
                seed: 31,
                mem_threshold: presets::bfp_w(4).memory_density() * 0.95,
                objective: Objective::software(0.02),
                ..Default::default()
            };
            let res = run_search(&params, space, task, &exs, fp32, &sc);
            let (macc, mmem) = res
                .accepted
                .iter()
                .map(|r| (r.accuracy, r.mem_density))
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .or_else(|| res.best.as_ref().map(|b| (b.accuracy, b.mem_density)))
                .unwrap_or((0.0, 0.0));
            eprintln!(
                "[fig7] {} {size}: fp32 {fp32:.3} uni4 {uni4:.3} mixed {macc:.3}@{mmem:.2}x",
                task.name()
            );
            t.row(vec![
                task.name().to_string(),
                size.clone(),
                format!("{:.1}%", fp32 * 100.0),
                format!("{:.1}%", uni4 * 100.0),
                format!("{:.1}%", macc * 100.0),
                format!("{mmem:.2}x"),
            ]);
        }
    }
    save_result("fig7", &t, None);
}

/// Figure 10: hardware-aware vs software-only search traces.
pub fn fig10(args: &Args) {
    let preset = args.get_or("model", "micro");
    let trials = args.usize_or("trials", 40);
    let examples = args.usize_or("examples", 32);
    let threads = args.usize_or("threads", 8);
    let vocab = Vocab::build();
    let params = get_or_train(&preset, default_steps(&preset), true);
    let cfg = params.cfg.clone();
    let cost = calibrate();
    let task = Task::Sst2;
    let exs = generate(task, &vocab, 777, examples);
    let fp32_model = Model::new(params.clone(), QuantPlan::fp32());
    let fp32_acc = evaluate(&fp32_model, task, &exs, threads).accuracy;

    let mut traces: Vec<(String, Vec<f64>)> = Vec::new();
    let mut rows: Vec<(String, f64, f64, f64, f64)> = Vec::new();
    for (name, obj) in [
        ("software (acc+α·mem)", Objective::software(0.02)),
        (
            "hardware-aware (acc+α₁·mem+α₂·tps+α₃·tpl)",
            Objective::hardware_aware(0.02, 0.02, 0.02),
        ),
    ] {
        let space = SearchSpace::bfp_bits(&cfg, &[3, 4, 5, 6, 8]);
        let sc = SearchConfig {
            trials,
            threads,
            seed: 77,
            objective: obj,
            ..Default::default()
        };
        let res = run_search(&params, space, task, &exs, fp32_acc, &sc);
        // best-so-far hardware-efficiency trace: tps of the incumbent
        let mut best_obj = f64::NEG_INFINITY;
        let mut trace = Vec::new();
        let mut best_tps = 0.0;
        let mut best_tpl = 0.0;
        let mut best_acc = 0.0;
        let mut best_mem = 0.0;
        for tr in &res.history {
            if tr.objective > best_obj {
                best_obj = tr.objective;
                let plan = res.space.plan_of(&tr.assignment);
                best_tps = crate::search::objective::plan_tps(&cfg, &plan, 64, &cost);
                best_tpl = crate::search::objective::plan_tpl(&cfg, &plan, 64, &cost);
                best_acc = tr.accuracy;
                best_mem = tr.mem_density;
            }
            trace.push(best_tps);
        }
        rows.push((name.to_string(), best_acc, best_mem, best_tps, best_tpl));
        traces.push((name.to_string(), trace));
    }
    let mut t = Table::new(
        "Figure 10 — hardware-aware vs software-only search",
        &["Objective", "best acc", "best mem", "best TPS (rel)", "best TPS/LUT (rel)"],
    );
    for (name, acc, mem, tps, tpl) in &rows {
        t.row(vec![
            name.clone(),
            format!("{:.1}%", acc * 100.0),
            format!("{mem:.2}x"),
            format!("{tps:.1}x"),
            format!("{tpl:.1}x"),
        ]);
    }
    save_result("fig10", &t, None);
    println!("{}", ascii_plot("best-so-far TPS vs trial", &traces, 12));
}

/// Table 1 — the conceptual comparison matrix.
pub fn table1(_args: &Args) {
    let mut t = Table::new(
        "Table 1 — LLM quantisation method comparison",
        &["Method", "(QW,QAct)", "Bitwidth", "PTQ or TAQ", "# Quantised GEMMs"],
    );
    let rows = [
        ["ZeroQuant", "(yes,yes)", "W4A8", "TAQ", "8/8"],
        ["LLM.int8()", "(yes,yes)", "W8A8*", "PTQ", "6/8"],
        ["GPTQ", "(yes,no)", "W4", "PTQ + DC", "6/8"],
        ["SmoothQuant", "(yes,yes)", "W8A8", "PTQ + DC", "6/8"],
        ["OURS (BFP)", "(yes,yes)", "W6A6/W4A4", "PTQ/TAQ", "8/8"],
    ];
    for r in rows {
        t.row(r.iter().map(|s| s.to_string()).collect());
    }
    save_result("table1", &t, None);
    // verify the 6/8 vs 8/8 accounting against our plan machinery
    let cfg = crate::model::config::ModelConfig::preset("nano");
    let p68 = QuantPlan::six_of_eight(presets::fixed8(), cfg.n_layers);
    let p88 = QuantPlan::uniform(presets::bfp_w(6));
    println!(
        "plan accounting check: six_of_eight={:?} uniform={:?}",
        p68.quantised_gemms(cfg.n_layers),
        p88.quantised_gemms(cfg.n_layers)
    );
    // memory density of a uniform 4-bit plan at seq 64 (sanity print)
    println!(
        "uniform 4-bit plan model memory density: {:.2}x",
        plan_memory_density(&cfg, &QuantPlan::uniform(presets::bfp_w(4)), 64)
    );
}
