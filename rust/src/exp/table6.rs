//! Table 6: arithmetic density via the calibrated LUT-area model
//! (Vivado substitute — DESIGN.md §3). Anchor rows are fitted; the BFP
//! rows are *held-out predictions*, reported against the paper's values.

use crate::coordinator::experiment::save_result;
use crate::density::arith::{calibrate, paper_anchor_rows, paper_validation_rows};
use crate::quant::config::QFormat;
use crate::util::cli::Args;
use crate::util::table::Table;

pub fn run(_args: &Args) {
    let model = calibrate();
    let mut t = Table::new(
        "Table 6 — MAC area (LUT-equivalent) and arithmetic density",
        &[
            "Method", "Config", "Block", "Area (model)", "Area (paper)", "Arith density (model)",
            "Arith density (paper)", "Row kind",
        ],
    );
    let fp32_area = model.area(QFormat::Fp32);
    let mut add = |fmt: QFormat, paper_area: f64, kind: &str| {
        let area = model.area(fmt);
        t.row(vec![
            fmt.name(),
            format!("W{0}A{0}", fmt.word_bits()),
            format!("{}", fmt.block_size()),
            format!("{:.1}", area),
            format!("{:.1}", paper_area),
            format!("{:.1}x", fp32_area / area),
            format!("{:.1}x", 835.0 / paper_area),
            kind.to_string(),
        ]);
    };
    for (fmt, paper) in paper_anchor_rows() {
        add(fmt, paper, "calibration anchor");
    }
    for (fmt, paper) in paper_validation_rows() {
        add(fmt, paper, "held-out prediction");
    }
    save_result("table6", &t, None);
    println!(
        "model coefficients: c_mult={:.3} c_acc={:.3} c_shift={:.3} c_exp={:.3}",
        model.c_mult, model.c_acc, model.c_shift, model.c_exp
    );
}
