//! Table 4: W6A6 BFP on the RoPE (LLaMA-stand-in) family — FP32 vs
//! LLM.int8() vs BFP6, showing format generality across architectures.

use crate::coordinator::experiment::{default_steps, save_result};
use crate::data::corpus::{test_stream, train_stream};
use crate::data::lm_eval::perplexity_par;
use crate::data::vocab::Vocab;
use crate::model::config::ModelConfig;
use crate::model::params::Params;
use crate::model::plan::QuantPlan;
use crate::model::Model;
use crate::quant::config::presets;
use crate::util::cli::Args;
use crate::util::table::{fnum, Table};

/// RoPE models are inference-only in the Rust trainer, so the "trained"
/// RoPE zoo is produced by short training of a learned-pos twin and
/// transplanting the transformer weights (position information then comes
/// from RoPE at inference). Chat-style variants ("vicuna"/"alpaca" rows)
/// are the same backbone fine-tuned briefly on task-formatted text.
pub fn rope_params_pub(preset: &str, quiet: bool) -> Params {
    let twin = match preset {
        "rope-tiny" => "tiny",
        "rope-small" => "small",
        other => other,
    };
    let base = crate::coordinator::experiment::get_or_train(twin, default_steps(twin), quiet);
    let cfg = ModelConfig::preset(preset);
    let mut p = Params::init(&cfg, 42);
    p.tok_emb = base.tok_emb.clone();
    p.layers = base.layers.clone();
    p.lnf_g = base.lnf_g.clone();
    p.lnf_b = base.lnf_b.clone();
    p
}

pub fn run(args: &Args) {
    let seq = args.usize_or("seq", 64);
    let chunks = args.usize_or("chunks", 6);
    let threads = args.usize_or("threads", 8);
    let vocab = Vocab::build();
    let test = test_stream(&vocab, seq * chunks + seq);
    let _ = train_stream(&vocab, 8); // touch the generator for determinism parity

    let mut table = Table::new(
        "Table 4 — RoPE (LLaMA-family stand-in) perplexity under W6A6 BFP",
        &["Model", "FP32", "LLM.int8()", "W6A6 BFP"],
    );
    for preset in ["rope-tiny", "rope-small"] {
        let params = rope_params_pub(preset, true);
        let ppl = |plan: QuantPlan| {
            let m = Model::new(params.clone(), plan);
            perplexity_par(&m, &test, seq, chunks, threads).perplexity
        };
        let fp32 = ppl(QuantPlan::fp32());
        let int8 = ppl(QuantPlan::llm_int8(8));
        let bfp6 = ppl(QuantPlan::uniform(presets::bfp_w(6)));
        eprintln!("[table4] {preset}: fp32 {fp32:.2} int8 {int8:.2} bfp6 {bfp6:.2}");
        table.row(vec![
            preset.to_string(),
            fnum(fp32, 2),
            format!("{} ({:+.2})", fnum(int8, 2), int8 - fp32),
            format!("{} ({:+.2})", fnum(bfp6, 2), bfp6 - fp32),
        ]);
    }
    save_result("table4", &table, None);
}
