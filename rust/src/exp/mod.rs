//! Experiment drivers — one per paper table/figure (DESIGN.md §6 index).
//! Run with `bbq exp <id>`; each prints the paper-shaped table and writes
//! `results/<id>.{md,csv,json}`.

pub mod ablation;
pub mod blocksize;
pub mod figs;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table8;

use crate::util::cli::Args;

pub const EXPERIMENTS: [&str; 14] = [
    "table1", "table3", "table4", "table5", "table6", "table8",
    "fig1", "fig3", "fig4", "fig5", "fig7", "fig10", "ablation", "blocksize",
];

pub fn run(id: &str, args: &Args) -> bool {
    match id {
        "table1" => figs::table1(args),
        "table3" => table3::run(args),
        "table4" => table4::run(args),
        "table5" | "table7" | "fig6" => table5::run(args),
        "table6" => table6::run(args),
        "table8" => table8::run(args),
        "fig1" => figs::fig1(args, false),
        "fig4" => figs::fig1(args, true),
        "fig5" => figs::fig5(args),
        "fig3" | "fig8" | "fig9" => figs::fig3(args),
        "fig7" => figs::fig7(args),
        "fig10" => figs::fig10(args),
        "ablation" => ablation::run(args),
        "blocksize" => blocksize::run(args),
        "all" => {
            for e in EXPERIMENTS {
                eprintln!("=== running {e} ===");
                run(e, args);
            }
        }
        _ => return false,
    }
    true
}
