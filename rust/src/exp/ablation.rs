//! Outlier-stress ablation.
//!
//! Our trained-from-scratch micro-models do not develop the extreme
//! activation outliers of OPT-6.7B (they emerge with scale), so plain
//! per-tensor fixed-point does not collapse on them the way Table 3
//! shows. This ablation *induces* the paper's phenomenon with an exact
//! function-preserving transform — the inverse of SmoothQuant's scale
//! migration: a few channels of each LayerNorm gain/bias are multiplied
//! by `s` and the corresponding weight rows divided by `s`. FP32
//! behaviour is bit-for-bit unchanged (up to rounding), but the
//! activations entering GEMMs ①②③⑦ now carry genuine outlier channels of
//! magnitude ~s× typical — exactly the "numerical scaling offsets" regime.
//!
//! Expected shape (matches paper Table 3): FP32 unchanged; per-tensor
//! fixed-point collapses; MiniFloat survives; BFP stays nearly lossless
//! because each outlier only poisons its own [1,16] block.

use crate::coordinator::experiment::{default_steps, get_or_train, save_result};
use crate::data::corpus::test_stream;
use crate::data::lm_eval::perplexity_par;
use crate::data::vocab::Vocab;
use crate::model::params::Params;
use crate::model::plan::QuantPlan;
use crate::model::Model;
use crate::quant::config::presets;
use crate::util::cli::Args;
use crate::util::rng::Pcg32;
use crate::util::table::{fnum, Table};

/// Inverse-SmoothQuant transform: amplify `n_chan` channels per LN by `s`.
pub fn inject_outlier_channels(params: &Params, n_chan: usize, s: f32, seed: u64) -> Params {
    let mut p = params.clone();
    let d = p.cfg.d_model;
    let mut rng = Pcg32::new(seed);
    for l in p.layers.iter_mut() {
        for _ in 0..n_chan {
            // attention input channel
            let j = rng.below(d);
            l.ln1_g[j] *= s;
            l.ln1_b[j] *= s;
            for w in [&mut l.wq, &mut l.wk, &mut l.wv] {
                for c in 0..d {
                    w.data[j * d + c] /= s;
                }
            }
            // MLP input channel
            let j2 = rng.below(d);
            let f = p.cfg.d_ff;
            l.ln2_g[j2] *= s;
            l.ln2_b[j2] *= s;
            for c in 0..f {
                l.w1.data[j2 * f + c] /= s;
            }
        }
    }
    p
}

pub fn run(args: &Args) {
    let preset = args.get_or("model", "tiny");
    let seq = args.usize_or("seq", 64);
    let chunks = args.usize_or("chunks", 8);
    let threads = args.usize_or("threads", 8);
    let scale = args.f64_or("scale", 80.0) as f32;
    let n_chan = args.usize_or("channels", 8);
    let vocab = Vocab::build();
    let test = test_stream(&vocab, seq * chunks + seq);
    let base = get_or_train(&preset, default_steps(&preset), true);
    let stressed = inject_outlier_channels(&base, n_chan, scale, 99);

    let ppl = |p: &Params, plan: QuantPlan| {
        perplexity_par(&Model::new(p.clone(), plan), &test, seq, chunks, threads).perplexity
    };
    let mut t = Table::new(
        &format!(
            "Outlier-stress ablation ({preset}, {n_chan} channels x{scale} per LN) — the scaling-offsets mechanism"
        ),
        &["Method", "clean ppl", "outlier-stressed ppl"],
    );
    let rows: Vec<(&str, QuantPlan)> = vec![
        ("FP32", QuantPlan::fp32()),
        ("Fixed-point W8A8", QuantPlan::uniform(presets::fixed8())),
        ("MiniFloat W8A8", QuantPlan::uniform(presets::minifloat8())),
        ("LLM.int8()", QuantPlan::llm_int8(8)),
        ("BFP W8A8", QuantPlan::uniform(presets::bfp_w(8))),
        ("BFP W6A6", QuantPlan::uniform(presets::bfp_w(6))),
        ("BFP W4A4", QuantPlan::uniform(presets::bfp_w(4))),
    ];
    for (name, plan) in rows {
        let clean = ppl(&base, plan.clone());
        let stress = ppl(&stressed, plan.clone());
        eprintln!("[ablation] {name}: clean {clean:.2} stressed {stress:.2}");
        t.row(vec![name.to_string(), fnum(clean, 2), fnum(stress, 2)]);
    }
    save_result("ablation_outliers", &t, None);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn transform_preserves_fp32_function() {
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 5);
        let q = inject_outlier_channels(&p, 3, 16.0, 1);
        let toks = [1usize, 9, 42, 7];
        let a = Model::new(p, QuantPlan::fp32()).forward(&toks, None);
        let b = Model::new(q, QuantPlan::fp32()).forward(&toks, None);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn transform_creates_outliers_that_break_fixed_point() {
        // under induced scaling offsets, a per-element exponent format
        // (MiniFloat) must beat the single per-tensor scale by a wide
        // margin — the paper's core signature. (BFP's behaviour at this
        // tiny d_model depends on how many blocks catch an outlier, so the
        // block-format comparison lives in the driver, not this unit test.)
        // brief training gives the residual stream real structure (a
        // random-init model's logits are too degenerate to discriminate)
        let cfg = ModelConfig::preset("nano");
        let mut p = Params::init(&cfg, 5);
        let vocab = crate::data::vocab::Vocab::build();
        let stream = crate::data::corpus::train_stream(&vocab, 3000);
        crate::train::train_lm(
            &mut p,
            &QuantPlan::fp32(),
            &stream,
            &crate::train::TrainConfig {
                steps: 40,
                seq_len: 32,
                lr: 3e-3,
                seed: 1,
                log_every: 0,
            },
            |_, _| {},
        );
        let q = inject_outlier_channels(&p, 4, 64.0, 1);
        let toks: Vec<usize> = (0..24).map(|i| (i * 19) % 512).collect();
        let fp = Model::new(q.clone(), QuantPlan::fp32()).forward(&toks, None);
        let fx = Model::new(q.clone(), QuantPlan::uniform(presets::fixed8())).forward(&toks, None);
        let mf = Model::new(q, QuantPlan::uniform(presets::minifloat8()))
            .forward(&toks, None);
        let err_fx = crate::util::stats::mse(&fp.data, &fx.data);
        let err_mf = crate::util::stats::mse(&fp.data, &mf.data);
        assert!(
            err_fx > err_mf * 2.0,
            "fixed-point err {err_fx} vs minifloat err {err_mf}"
        );
    }
}
