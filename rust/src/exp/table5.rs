//! Table 5 / Table 7 / Figure 6: zero-shot downstream accuracy across the
//! model ladder for FP32, LLM.int8()/int4(), SmoothQuant-c, MiniFloat and
//! the BFP family; the Figure 6 rendition plots mean accuracy vs scale.

use crate::baselines::smoothquant;
use crate::coordinator::experiment::{default_steps, get_or_train, save_result};
use crate::data::corpus::train_stream;
use crate::data::tasks::{evaluate, generate, Task};
use crate::data::vocab::Vocab;
use crate::model::plan::QuantPlan;
use crate::model::Model;
use crate::quant::config::presets;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::{ascii_plot, Table};

pub fn methods() -> Vec<&'static str> {
    vec![
        "fp32",
        "llm_int8",
        "llm_int4",
        "smoothquant_c",
        "minifloat8",
        "bfp4",
        "bfp5",
        "bfp6",
        "bfp8",
    ]
}

pub fn build_model(method: &str, params: &crate::model::Params, cal: &[Vec<usize>]) -> Model {
    match method {
        "fp32" => Model::new(params.clone(), QuantPlan::fp32()),
        "llm_int8" => Model::new(params.clone(), QuantPlan::llm_int8(8)),
        "llm_int4" => Model::new(params.clone(), QuantPlan::llm_int8(4)),
        "smoothquant_c" => smoothquant::build(params, cal, 0.5).1,
        "minifloat8" => Model::new(params.clone(), QuantPlan::uniform(presets::minifloat8())),
        "bfp4" => Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(4))),
        "bfp5" => Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(5))),
        "bfp6" => Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(6))),
        "bfp8" => Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(8))),
        other => panic!("unknown method {other}"),
    }
}

pub fn run(args: &Args) {
    let sizes: Vec<String> = args
        .get_or("sizes", "micro,tiny,small,base")
        .split(',')
        .map(String::from)
        .collect();
    let n_examples = args.usize_or("examples", 60);
    let threads = args.usize_or("threads", 8);
    let vocab = Vocab::build();
    let tasks = Task::zero_shot_suite();
    let cal: Vec<Vec<usize>> = train_stream(&vocab, 8 * 48)
        .chunks(48)
        .take(8)
        .map(|c| c.to_vec())
        .collect();

    // full per-task table (Table 7) + mean table (Table 5)
    let mut header7 = vec!["Method".to_string(), "Model".to_string()];
    header7.extend(tasks.iter().map(|t| t.name().to_string()));
    header7.push("Mean".into());
    let mut t7 = Table::new(
        "Table 7 — per-task zero-shot accuracy",
        &header7.iter().map(String::as_str).collect::<Vec<_>>(),
    );
    let mut header5 = vec!["Method".to_string()];
    header5.extend(sizes.iter().cloned());
    let mut t5 = Table::new(
        "Table 5 — mean zero-shot accuracy (%)",
        &header5.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut fig6_series: Vec<(String, Vec<f64>)> = Vec::new();
    let mut results_json = Vec::new();
    for method in methods() {
        let mut means = Vec::new();
        for size in &sizes {
            let params = get_or_train(size, default_steps(size), true);
            let model = build_model(method, &params, &cal);
            let mut accs = Vec::new();
            for &task in &tasks {
                let exs = generate(task, &vocab, 1000, n_examples);
                let r = evaluate(&model, task, &exs, threads);
                accs.push(r.accuracy);
            }
            let mean = accs.iter().sum::<f64>() / accs.len() as f64;
            eprintln!("[table5] {method} {size}: mean {:.3}", mean);
            let mut row = vec![method.to_string(), size.clone()];
            row.extend(accs.iter().map(|a| format!("{:.1}%", a * 100.0)));
            row.push(format!("{:.1}%", mean * 100.0));
            t7.row(row);
            means.push(mean);
            results_json.push(Json::obj(vec![
                ("method", Json::Str(method.to_string())),
                ("size", Json::Str(size.clone())),
                ("mean_acc", Json::Num(mean)),
            ]));
        }
        let mut row5 = vec![method.to_string()];
        row5.extend(means.iter().map(|m| format!("{:.1}%", m * 100.0)));
        t5.row(row5);
        fig6_series.push((method.to_string(), means));
    }
    save_result("table7", &t7, None);
    save_result("table5", &t5, Some(Json::Arr(results_json)));
    let plot = ascii_plot(
        "Figure 6 — mean zero-shot accuracy vs model scale",
        &fig6_series,
        16,
    );
    let _ = crate::util::write_file(&crate::util::results_dir().join("fig6.md"), &plot);
    println!("{plot}");
}
