//! Serving metrics: latency distribution, token throughput, and the
//! served model's resident weight memory.

use crate::model::WeightMemory;
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub completed: usize,
    pub generated_tokens: usize,
    pub latencies_ms: Vec<f64>,
    pub wall: Duration,
    /// Dense-f32 vs actually-resident bytes of the served model's weight
    /// cache (packed payloads under block formats).
    pub weight_memory: WeightMemory,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&mut self, latency: Duration, tokens: usize) {
        self.completed += 1;
        self.generated_tokens += tokens;
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
    }

    pub fn p(&self, pct: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((pct / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// generated tokens per wall-clock second
    pub fn throughput_tps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / secs
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "completed={} tokens={} wall={:.2}s tput={:.1} tok/s p50={:.1}ms p99={:.1}ms",
            self.completed,
            self.generated_tokens,
            self.wall.as_secs_f64(),
            self.throughput_tps(),
            self.p(50.0),
            self.p(99.0),
        );
        if self.weight_memory.dense_f32_bytes > 0 {
            s.push_str(&format!(
                " weights={}B resident={}B ({:.2}x)",
                self.weight_memory.dense_f32_bytes,
                self.weight_memory.resident_bytes,
                self.weight_memory.ratio(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_millis(i), 1);
        }
        m.wall = Duration::from_secs(1);
        assert!((m.p(50.0) - 50.0).abs() <= 1.0);
        assert!((m.p(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(m.throughput_tps(), 100.0);
        assert!(m.summary().contains("tok/s"));
    }
}
