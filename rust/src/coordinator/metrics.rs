//! Serving metrics: latency distribution, token throughput, and the
//! served model's resident weight memory.

use crate::model::WeightMemory;
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub completed: usize,
    pub generated_tokens: usize,
    pub latencies_ms: Vec<f64>,
    pub wall: Duration,
    /// Dense-f32 vs actually-resident bytes of the served model's weight
    /// cache (packed payloads under block formats).
    pub weight_memory: WeightMemory,
    /// Fused engine steps executed by the continuous-batching scheduler;
    /// each one decodes every packed weight exactly once.
    pub engine_steps: usize,
    /// Slot contributions across all engine steps (Σ active slots per
    /// step). A slot counts once per step whether it fed one decode row or
    /// a whole prefill chunk, so occupancy stays bounded by the pool size.
    pub slot_steps: usize,
    /// Prompt rows fed through chunked prefill (Σ chunk lengths). Together
    /// with [`Self::decode_rows`] this is every row the engine processed.
    pub prefill_rows: usize,
    /// Engine steps that carried at least one prefill row — each one paid
    /// exactly one weight-dequant pass for all its prompt rows.
    pub prefill_steps: usize,
    /// Decode rows fed (one per decoding slot per step; the final sampled
    /// token of a sequence is never fed back).
    pub decode_rows: usize,
    /// Requests cancelled before finishing — via
    /// [`crate::coordinator::RequestHandle::cancel`] or a dropped event
    /// listener. Cancelled requests are not counted in [`Self::completed`]
    /// and do not contribute to the latency distribution.
    pub cancelled: usize,
    /// Admission-queue depth when this snapshot was published (a gauge;
    /// the live value is `EngineHandle::queue_depth`).
    pub queue_depth: usize,
    /// Highest admission-queue depth observed — how hard backpressure was
    /// leaned on.
    pub queue_peak: usize,
    /// Per-request time spent in the admission queue before a slot
    /// admitted it, in milliseconds (one entry per admitted request).
    pub queue_wait_ms: Vec<f64>,
    /// Resident KV-cache bytes across all slots when this snapshot was
    /// published (drops back to 0 once every sequence finishes).
    pub kv_bytes: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&mut self, latency: Duration, tokens: usize) {
        self.completed += 1;
        self.generated_tokens += tokens;
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
    }

    pub fn p(&self, pct: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((pct / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Mean active slots per engine step — continuous-batching occupancy.
    pub fn batch_occupancy(&self) -> f64 {
        if self.engine_steps == 0 {
            0.0
        } else {
            self.slot_steps as f64 / self.engine_steps as f64
        }
    }

    /// Decode-side amortisation: sequences sharing each fused weight-dequant
    /// pass (== [`Self::batch_occupancy`], one slot contribution per step).
    /// A sequential decoder pays one dequant pass per sequence per step; the
    /// engine pays one per step for all of them. The *row*-level prefill
    /// amortisation (chunk rows sharing a pass) is reported separately by
    /// [`Self::prefill_amortisation`].
    pub fn decode_amortisation(&self) -> f64 {
        self.batch_occupancy()
    }

    /// Prefill amortisation: prompt rows fed per prefill-carrying engine
    /// step, i.e. how many prompt tokens shared each fused weight-dequant
    /// pass. Token-at-a-time prefill caps this at the slot-pool size;
    /// chunked prefill multiplies it by the chunk length.
    pub fn prefill_amortisation(&self) -> f64 {
        if self.prefill_steps == 0 {
            0.0
        } else {
            self.prefill_rows as f64 / self.prefill_steps as f64
        }
    }

    /// Mean time-in-queue across admitted requests, milliseconds.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        if self.queue_wait_ms.is_empty() {
            0.0
        } else {
            self.queue_wait_ms.iter().sum::<f64>() / self.queue_wait_ms.len() as f64
        }
    }

    /// generated tokens per wall-clock second
    pub fn throughput_tps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / secs
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "completed={} tokens={} wall={:.2}s tput={:.1} tok/s p50={:.1}ms p99={:.1}ms",
            self.completed,
            self.generated_tokens,
            self.wall.as_secs_f64(),
            self.throughput_tps(),
            self.p(50.0),
            self.p(99.0),
        );
        if self.engine_steps > 0 {
            s.push_str(&format!(
                " steps={} occ={:.2} decode_amort={:.2}x",
                self.engine_steps,
                self.batch_occupancy(),
                self.decode_amortisation(),
            ));
        }
        if self.prefill_steps > 0 {
            s.push_str(&format!(
                " prefill_rows={} prefill_steps={} prefill_amort={:.2}x",
                self.prefill_rows,
                self.prefill_steps,
                self.prefill_amortisation(),
            ));
        }
        if self.queue_peak > 0 || self.cancelled > 0 {
            s.push_str(&format!(
                " queued={} qpeak={} qwait_mean={:.1}ms cancelled={}",
                self.queue_depth,
                self.queue_peak,
                self.mean_queue_wait_ms(),
                self.cancelled,
            ));
        }
        if self.kv_bytes > 0 {
            s.push_str(&format!(" kv={}B", self.kv_bytes));
        }
        if self.weight_memory.dense_f32_bytes > 0 {
            s.push_str(&format!(
                " weights={}B resident={}B ({:.2}x)",
                self.weight_memory.dense_f32_bytes,
                self.weight_memory.resident_bytes,
                self.weight_memory.ratio(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_millis(i), 1);
        }
        m.wall = Duration::from_secs(1);
        assert!((m.p(50.0) - 50.0).abs() <= 1.0);
        assert!((m.p(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(m.throughput_tps(), 100.0);
        assert!(m.summary().contains("tok/s"));
    }

    #[test]
    fn occupancy_and_amortisation() {
        let mut m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        m.engine_steps = 10;
        m.slot_steps = 25;
        assert!((m.batch_occupancy() - 2.5).abs() < 1e-12);
        assert_eq!(m.decode_amortisation(), m.batch_occupancy());
        assert!(m.summary().contains("decode_amort=2.50x"));
    }

    #[test]
    fn queue_and_cancellation_counters() {
        let mut m = Metrics::new();
        assert_eq!(m.mean_queue_wait_ms(), 0.0);
        assert!(!m.summary().contains("qpeak"));
        m.queue_depth = 2;
        m.queue_peak = 7;
        m.cancelled = 3;
        m.queue_wait_ms = vec![1.0, 3.0];
        m.kv_bytes = 128;
        assert!((m.mean_queue_wait_ms() - 2.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("queued=2"));
        assert!(s.contains("qpeak=7"));
        assert!(s.contains("qwait_mean=2.0ms"));
        assert!(s.contains("cancelled=3"));
        assert!(s.contains("kv=128B"));
    }

    #[test]
    fn prefill_amortisation_view() {
        let mut m = Metrics::new();
        assert_eq!(m.prefill_amortisation(), 0.0);
        assert!(!m.summary().contains("prefill_amort"));
        m.engine_steps = 6;
        m.slot_steps = 6;
        m.prefill_steps = 2;
        m.prefill_rows = 16;
        m.decode_rows = 4;
        assert!((m.prefill_amortisation() - 8.0).abs() < 1e-12);
        assert!(m.summary().contains("prefill_rows=16"));
        assert!(m.summary().contains("prefill_amort=8.00x"));
    }
}
