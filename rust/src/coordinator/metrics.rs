//! Serving metrics: latency distribution, token throughput, and the
//! served model's resident weight memory.
//!
//! Per-request distributions (request latency, admission-queue wait) are
//! held in fixed-size log-bucket histograms ([`LogHistogram`]), not
//! per-request vectors: a daemon serving millions of requests accumulates
//! O(1) state per request, and the whole `Metrics` struct stays cheap to
//! clone — which is what lets the engine publish a complete live snapshot
//! (distributions included) every step.

use crate::model::WeightMemory;
use std::time::Duration;

/// Fixed-size log-bucketed histogram over millisecond samples.
///
/// Buckets are quarter-octaves (each spans a factor of 2^(1/4) ≈ 1.19×)
/// starting at [`LogHistogram::MIN_MS`]; with [`LogHistogram::BUCKETS`]
/// buckets the range covers ~1 µs to ~70 minutes, and samples outside it
/// clamp into the edge buckets. Memory is constant no matter how many
/// samples are recorded — the daemon-scale replacement for the
/// per-request vectors `Metrics` used to keep. Percentiles come back as
/// the containing bucket's upper edge (≤ 19% high, clamped to the exact
/// observed min/max, which are tracked separately); count, sum, min and
/// max are exact.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; Self::BUCKETS],
    total: u64,
    sum_ms: f64,
    min_ms: f64,
    max_ms: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: [0; Self::BUCKETS],
            total: 0,
            sum_ms: 0.0,
            min_ms: f64::INFINITY,
            max_ms: f64::NEG_INFINITY,
        }
    }
}

impl LogHistogram {
    /// Number of buckets (fixed — the whole point).
    pub const BUCKETS: usize = 128;
    /// Lower edge of bucket 0, in milliseconds.
    pub const MIN_MS: f64 = 1e-3;
    /// Buckets per factor-of-2 span.
    pub const PER_OCTAVE: f64 = 4.0;

    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Bucket index for a sample (clamped into range).
    pub fn bucket(ms: f64) -> usize {
        if ms.is_nan() || ms <= Self::MIN_MS {
            // non-positive, sub-minimum and NaN samples land in bucket 0
            return 0;
        }
        let b = ((ms / Self::MIN_MS).log2() * Self::PER_OCTAVE).floor() as isize;
        b.clamp(0, Self::BUCKETS as isize - 1) as usize
    }

    /// Lower edge of bucket `i` in milliseconds (`bucket_floor(i + 1)` is
    /// its upper edge).
    pub fn bucket_floor(i: usize) -> f64 {
        Self::MIN_MS * (2.0f64).powf(i as f64 / Self::PER_OCTAVE)
    }

    /// Record one sample, in milliseconds. A NaN sample is recorded as 0
    /// (the bucket it lands in anyway), so min/mean/max/percentile stay
    /// well-defined whatever a caller feeds in.
    pub fn record(&mut self, ms: f64) {
        let ms = if ms.is_nan() { 0.0 } else { ms };
        self.counts[Self::bucket(ms)] += 1;
        self.total += 1;
        self.sum_ms += ms;
        self.min_ms = self.min_ms.min(ms);
        self.max_ms = self.max_ms.max(ms);
    }

    /// Samples recorded.
    pub fn count(&self) -> usize {
        self.total as usize
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact mean of all samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ms / self.total as f64
        }
    }

    /// Exact smallest sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min_ms
        }
    }

    /// Exact largest sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max_ms
        }
    }

    /// The `pct`-th percentile (0–100): the upper edge of the bucket
    /// holding the sample of that rank, clamped to the exact observed
    /// min/max — so the error is bounded by the ~19% bucket width.
    pub fn percentile(&self, pct: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((pct / 100.0) * self.total as f64).ceil().clamp(1.0, self.total as f64) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_floor(i + 1).clamp(self.min_ms, self.max_ms);
            }
        }
        self.max_ms
    }
}

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub completed: usize,
    pub generated_tokens: usize,
    /// Submission-to-finish request latency distribution, milliseconds.
    pub latency: LogHistogram,
    pub wall: Duration,
    /// Dense-f32 vs actually-resident bytes of the served model's weight
    /// cache (packed payloads under block formats).
    pub weight_memory: WeightMemory,
    /// Fused engine steps executed by the continuous-batching scheduler;
    /// each one decodes every packed weight exactly once.
    pub engine_steps: usize,
    /// Slot contributions across all engine steps (Σ active slots per
    /// step). A slot counts once per step whether it fed one decode row or
    /// a whole prefill chunk, so occupancy stays bounded by the pool size.
    pub slot_steps: usize,
    /// Prompt rows fed through chunked prefill (Σ chunk lengths). Together
    /// with [`Self::decode_rows`] this is every row the engine processed.
    pub prefill_rows: usize,
    /// Engine steps that carried at least one prefill row — each one paid
    /// exactly one weight-dequant pass for all its prompt rows.
    pub prefill_steps: usize,
    /// Decode rows fed (one per decoding slot per step; the final sampled
    /// token of a sequence is never fed back).
    pub decode_rows: usize,
    /// Requests cancelled before finishing — via
    /// [`crate::coordinator::RequestHandle::cancel`] or a dropped event
    /// listener. Cancelled requests are not counted in [`Self::completed`]
    /// and do not contribute to the latency distribution.
    pub cancelled: usize,
    /// Admission-queue depth when this snapshot was published (a gauge;
    /// the live value is `EngineHandle::queue_depth`).
    pub queue_depth: usize,
    /// Highest admission-queue depth observed — how hard backpressure was
    /// leaned on.
    pub queue_peak: usize,
    /// Time admitted requests spent in the admission queue before a slot
    /// took them, milliseconds (one sample per admitted request).
    pub queue_wait: LogHistogram,
    /// Resident KV-cache bytes (all formats, shared pages counted once)
    /// when this snapshot was published. Drops back to the prefix cache's
    /// pinned footprint ([`Self::kv_cached_bytes`]) once every sequence
    /// finishes — 0 with the cache empty or disabled.
    pub kv_bytes: usize,
    /// Portion of [`Self::kv_bytes`] held as raw-f32 page rows.
    pub kv_bytes_f32: usize,
    /// Portion of [`Self::kv_bytes`] held bit-packed in sealed
    /// block-format pages (counted at packed size).
    pub kv_bytes_packed: usize,
    /// Bytes pinned by the prefix cache (reachable from cached pages);
    /// the slice of [`Self::kv_bytes`] that outlives the slots using it.
    pub kv_cached_bytes: usize,
    /// Live KV pages.
    pub kv_pages: usize,
    /// KV pages mapped into two or more slot tables (prefix sharing).
    pub kv_pages_shared: usize,
    /// Prefix-cache lookups at admission (one per multi-token prompt).
    pub prefix_lookups: usize,
    /// Lookups that attached at least one cached page.
    pub prefix_hits: usize,
    /// Prompt rows never re-fed thanks to attached prefixes.
    pub prefix_hit_rows: usize,
    /// Active [`crate::kernels`] ISA backend ("scalar", "avx2", "neon") the
    /// engine's GEMMs dispatch to — set once at engine construction, empty
    /// until then.
    pub isa: String,
    /// Resident weight bytes by storage format name ("f32",
    /// "bfp_e8m3n16", …), outlier side tables excluded — the per-format
    /// breakdown of a mixed-precision plan's footprint. Sorted by name;
    /// set once at engine construction.
    pub weight_bytes_by_format: Vec<(String, usize)>,
    /// Bytes held by dense-and-sparse outlier side tables (CSR f32
    /// overlays on packed weights). Together with
    /// [`Self::weight_bytes_by_format`] this sums to
    /// `weight_memory.resident_bytes`.
    pub outlier_bytes: usize,
    /// Speculative verify rounds executed (one chunked multi-row target
    /// step each). 0 on an engine started without a draft model.
    pub spec_rounds: u64,
    /// Draft tokens proposed across all speculative rounds.
    pub spec_proposed: u64,
    /// Proposals the target's greedy verify accepted.
    pub spec_accepted: u64,
    /// Proposals rejected (the round emitted the target's correction).
    pub spec_rejected: u64,
    /// Budget/context-starved rounds that fell back to a plain
    /// single-row target step (no proposals).
    pub spec_fallback_steps: u64,
    /// Resident KV bytes of the draft model's own paged store
    /// (speculation overhead — kept out of [`Self::kv_bytes`], which is
    /// serving state).
    pub draft_kv_bytes: usize,
    /// Resident weight bytes of the draft model (zero without one).
    pub draft_weight_memory: WeightMemory,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&mut self, latency: Duration, tokens: usize) {
        self.completed += 1;
        self.generated_tokens += tokens;
        self.latency.record(latency.as_secs_f64() * 1e3);
    }

    /// Latency percentile in milliseconds (log-bucket resolution, ≤ ~19%
    /// high; exact at the observed min/max).
    pub fn p(&self, pct: f64) -> f64 {
        self.latency.percentile(pct)
    }

    /// Mean active slots per engine step — continuous-batching occupancy.
    pub fn batch_occupancy(&self) -> f64 {
        if self.engine_steps == 0 {
            0.0
        } else {
            self.slot_steps as f64 / self.engine_steps as f64
        }
    }

    /// Decode-side amortisation: sequences sharing each fused weight-dequant
    /// pass (== [`Self::batch_occupancy`], one slot contribution per step).
    /// A sequential decoder pays one dequant pass per sequence per step; the
    /// engine pays one per step for all of them. The *row*-level prefill
    /// amortisation (chunk rows sharing a pass) is reported separately by
    /// [`Self::prefill_amortisation`].
    pub fn decode_amortisation(&self) -> f64 {
        self.batch_occupancy()
    }

    /// Prefill amortisation: prompt rows fed per prefill-carrying engine
    /// step, i.e. how many prompt tokens shared each fused weight-dequant
    /// pass. Token-at-a-time prefill caps this at the slot-pool size;
    /// chunked prefill multiplies it by the chunk length.
    pub fn prefill_amortisation(&self) -> f64 {
        if self.prefill_steps == 0 {
            0.0
        } else {
            self.prefill_rows as f64 / self.prefill_steps as f64
        }
    }

    /// Mean time-in-queue across admitted requests, milliseconds.
    pub fn mean_queue_wait_ms(&self) -> f64 {
        self.queue_wait.mean()
    }

    /// Fraction of prefix-cache lookups that attached cached pages
    /// (0 when the cache is disabled or no multi-token prompt arrived).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Fraction of speculative proposals the target accepted (0 before
    /// any round, or on an engine without a draft).
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_proposed as f64
        }
    }

    /// Tokens emitted per speculative verify step — `(accepted + rounds)
    /// / rounds`, the multi-token-per-target-step win (plain fallback
    /// steps excluded; 0 without any round).
    pub fn spec_tokens_per_target_step(&self) -> f64 {
        if self.spec_rounds == 0 {
            0.0
        } else {
            (self.spec_accepted + self.spec_rounds) as f64 / self.spec_rounds as f64
        }
    }

    /// generated tokens per wall-clock second
    pub fn throughput_tps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / secs
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "completed={} tokens={} wall={:.2}s tput={:.1} tok/s p50={:.1}ms p99={:.1}ms",
            self.completed,
            self.generated_tokens,
            self.wall.as_secs_f64(),
            self.throughput_tps(),
            self.p(50.0),
            self.p(99.0),
        );
        if self.engine_steps > 0 {
            s.push_str(&format!(
                " steps={} occ={:.2} decode_amort={:.2}x",
                self.engine_steps,
                self.batch_occupancy(),
                self.decode_amortisation(),
            ));
        }
        if self.prefill_steps > 0 {
            s.push_str(&format!(
                " prefill_rows={} prefill_steps={} prefill_amort={:.2}x",
                self.prefill_rows,
                self.prefill_steps,
                self.prefill_amortisation(),
            ));
        }
        if self.queue_peak > 0 || self.cancelled > 0 {
            s.push_str(&format!(
                " queued={} qpeak={} qwait_mean={:.1}ms cancelled={}",
                self.queue_depth,
                self.queue_peak,
                self.mean_queue_wait_ms(),
                self.cancelled,
            ));
        }
        if self.kv_bytes > 0 {
            s.push_str(&format!(" kv={}B", self.kv_bytes));
            if self.kv_bytes_packed > 0 {
                s.push_str(&format!(" kv_packed={}B", self.kv_bytes_packed));
            }
            if self.kv_pages_shared > 0 {
                s.push_str(&format!(" kv_shared_pages={}", self.kv_pages_shared));
            }
        }
        if self.prefix_lookups > 0 {
            s.push_str(&format!(
                " prefix_hit_rate={:.2} prefix_rows={}",
                self.prefix_hit_rate(),
                self.prefix_hit_rows,
            ));
        }
        if self.spec_rounds > 0 || self.spec_fallback_steps > 0 {
            s.push_str(&format!(
                " spec_rounds={} spec_accept_rate={:.2} spec_tok_per_step={:.2}",
                self.spec_rounds,
                self.spec_acceptance_rate(),
                self.spec_tokens_per_target_step(),
            ));
            if self.draft_kv_bytes > 0 {
                s.push_str(&format!(" draft_kv={}B", self.draft_kv_bytes));
            }
            if self.draft_weight_memory.resident_bytes > 0 {
                s.push_str(&format!(
                    " draft_resident={}B",
                    self.draft_weight_memory.resident_bytes
                ));
            }
        }
        if self.weight_memory.dense_f32_bytes > 0 {
            s.push_str(&format!(
                " weights={}B resident={}B ({:.2}x)",
                self.weight_memory.dense_f32_bytes,
                self.weight_memory.resident_bytes,
                self.weight_memory.ratio(),
            ));
        }
        if self.weight_bytes_by_format.len() > 1 {
            let parts: Vec<String> = self
                .weight_bytes_by_format
                .iter()
                .map(|(name, bytes)| format!("{name}:{bytes}B"))
                .collect();
            s.push_str(&format!(" weights_by_format=[{}]", parts.join(" ")));
        }
        if self.outlier_bytes > 0 {
            s.push_str(&format!(" outliers={}B", self.outlier_bytes));
        }
        if !self.isa.is_empty() {
            s.push_str(&format!(" isa={}", self.isa));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        // bucket 0 starts at MIN_MS; everything at or below lands there
        assert_eq!(LogHistogram::bucket(0.0), 0);
        assert_eq!(LogHistogram::bucket(-3.0), 0);
        assert_eq!(LogHistogram::bucket(LogHistogram::MIN_MS), 0);
        assert_eq!(LogHistogram::bucket(f64::NAN), 0);
        // each bucket spans exactly one quarter-octave: a sample nudged
        // just above floor(i) maps to i, just below floor(i+1) still to i
        for i in 0..LogHistogram::BUCKETS - 1 {
            let lo = LogHistogram::bucket_floor(i);
            let hi = LogHistogram::bucket_floor(i + 1);
            assert!(hi / lo > 1.18 && hi / lo < 1.20, "bucket {i} width");
            assert_eq!(LogHistogram::bucket(lo * 1.001), i, "floor of bucket {i}");
            assert_eq!(LogHistogram::bucket(hi * 0.999), i, "ceil of bucket {i}");
        }
        // beyond the last edge everything clamps into the final bucket
        let top = LogHistogram::bucket_floor(LogHistogram::BUCKETS);
        assert_eq!(LogHistogram::bucket(top * 1e6), LogHistogram::BUCKETS - 1);
        // the range really covers ~1µs .. minutes
        assert!(LogHistogram::bucket_floor(LogHistogram::BUCKETS) > 60_000.0);
    }

    #[test]
    fn histogram_stats_and_percentiles() {
        let mut h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 100.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        // percentiles are bucket upper edges: within one bucket width
        // (2^(1/4) ≈ 1.19×) of the exact answer
        let p50 = h.percentile(50.0);
        assert!(p50 >= 50.0 && p50 <= 50.0 * 1.19, "p50 {p50}");
        let p99 = h.percentile(99.0);
        assert!(p99 >= 99.0 && p99 <= 100.0, "p99 {p99}"); // clamped to max
        assert_eq!(h.percentile(100.0), 100.0);
        // a single sample reports itself exactly at every percentile
        let mut one = LogHistogram::new();
        one.record(7.3);
        assert_eq!(one.percentile(50.0), 7.3);
        assert_eq!(one.percentile(99.0), 7.3);
        // degenerate samples must not poison the stats: NaN records as 0,
        // negatives land in bucket 0 with exact min/max — and percentile
        // never panics on its min/max clamp
        let mut odd = LogHistogram::new();
        odd.record(f64::NAN);
        assert_eq!(odd.count(), 1);
        assert_eq!(odd.min(), 0.0);
        assert_eq!(odd.max(), 0.0);
        assert_eq!(odd.percentile(50.0), 0.0);
        odd.record(-5.0);
        assert_eq!(odd.min(), -5.0);
        assert_eq!(odd.max(), 0.0);
        assert!(odd.percentile(99.0) <= 0.0);
    }

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_millis(i), 1);
        }
        m.wall = Duration::from_secs(1);
        // log-bucket resolution: within ~19% above the exact percentile
        assert!(m.p(50.0) >= 50.0 && m.p(50.0) <= 60.0);
        assert!(m.p(99.0) >= 99.0 && m.p(99.0) <= 100.0);
        assert_eq!(m.throughput_tps(), 100.0);
        assert!(m.summary().contains("tok/s"));
        assert_eq!(m.latency.count(), 100);
    }

    #[test]
    fn occupancy_and_amortisation() {
        let mut m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        m.engine_steps = 10;
        m.slot_steps = 25;
        assert!((m.batch_occupancy() - 2.5).abs() < 1e-12);
        assert_eq!(m.decode_amortisation(), m.batch_occupancy());
        assert!(m.summary().contains("decode_amort=2.50x"));
    }

    #[test]
    fn queue_and_cancellation_counters() {
        let mut m = Metrics::new();
        assert_eq!(m.mean_queue_wait_ms(), 0.0);
        assert!(!m.summary().contains("qpeak"));
        m.queue_depth = 2;
        m.queue_peak = 7;
        m.cancelled = 3;
        m.queue_wait.record(1.0);
        m.queue_wait.record(3.0);
        m.kv_bytes = 128;
        assert!((m.mean_queue_wait_ms() - 2.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("queued=2"));
        assert!(s.contains("qpeak=7"));
        assert!(s.contains("qwait_mean=2.0ms"));
        assert!(s.contains("cancelled=3"));
        assert!(s.contains("kv=128B"));
        // paged-KV fields appear only once they are non-zero
        assert!(!s.contains("kv_packed"));
        assert!(!s.contains("prefix_hit_rate"));
        m.kv_bytes_packed = 32;
        m.kv_pages_shared = 2;
        m.prefix_lookups = 4;
        m.prefix_hits = 3;
        m.prefix_hit_rows = 21;
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("kv_packed=32B"));
        assert!(s.contains("kv_shared_pages=2"));
        assert!(s.contains("prefix_hit_rate=0.75"));
        assert!(s.contains("prefix_rows=21"));
    }

    #[test]
    fn weight_breakdown_reported_when_mixed() {
        let mut m = Metrics::new();
        assert!(!m.summary().contains("weights_by_format"));
        assert!(!m.summary().contains("outliers="));
        // a uniform (single-format) model stays quiet — the breakdown only
        // earns summary space when a plan actually mixes formats
        m.weight_bytes_by_format = vec![("bfp_e8m3n16".to_string(), 1000)];
        assert!(!m.summary().contains("weights_by_format"));
        m.weight_bytes_by_format = vec![
            ("bfp_e8m3n16".to_string(), 1000),
            ("bfp_e8m7n16".to_string(), 500),
            ("f32".to_string(), 256),
        ];
        m.outlier_bytes = 96;
        let s = m.summary();
        assert!(s.contains("weights_by_format=[bfp_e8m3n16:1000B bfp_e8m7n16:500B f32:256B]"));
        assert!(s.contains("outliers=96B"));
    }

    #[test]
    fn speculative_counters_and_summary() {
        let mut m = Metrics::new();
        assert_eq!(m.spec_acceptance_rate(), 0.0);
        assert_eq!(m.spec_tokens_per_target_step(), 0.0);
        assert!(!m.summary().contains("spec_rounds"));
        m.spec_rounds = 10;
        m.spec_proposed = 40;
        m.spec_accepted = 30;
        m.spec_rejected = 10;
        m.draft_kv_bytes = 64;
        assert!((m.spec_acceptance_rate() - 0.75).abs() < 1e-12);
        // 30 accepted + 10 correction/bonus tokens over 10 verify steps
        assert!((m.spec_tokens_per_target_step() - 4.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("spec_rounds=10"));
        assert!(s.contains("spec_accept_rate=0.75"));
        assert!(s.contains("spec_tok_per_step=4.00"));
        assert!(s.contains("draft_kv=64B"));
    }

    #[test]
    fn prefix_hit_rate_defaults_to_zero() {
        let m = Metrics::new();
        assert_eq!(m.prefix_hit_rate(), 0.0);
    }

    #[test]
    fn isa_reported_once_set() {
        let mut m = Metrics::new();
        assert!(!m.summary().contains("isa="));
        m.isa = crate::kernels::active().name().to_string();
        assert!(m.summary().contains(&format!("isa={}", m.isa)));
    }

    #[test]
    fn prefill_amortisation_view() {
        let mut m = Metrics::new();
        assert_eq!(m.prefill_amortisation(), 0.0);
        assert!(!m.summary().contains("prefill_amort"));
        m.engine_steps = 6;
        m.slot_steps = 6;
        m.prefill_steps = 2;
        m.prefill_rows = 16;
        m.decode_rows = 4;
        assert!((m.prefill_amortisation() - 8.0).abs() < 1e-12);
        assert!(m.summary().contains("prefill_rows=16"));
        assert!(m.summary().contains("prefill_amort=8.00x"));
    }
}
