//! Serving metrics: latency distribution, token throughput, and the
//! served model's resident weight memory.

use crate::model::WeightMemory;
use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub completed: usize,
    pub generated_tokens: usize,
    pub latencies_ms: Vec<f64>,
    pub wall: Duration,
    /// Dense-f32 vs actually-resident bytes of the served model's weight
    /// cache (packed payloads under block formats).
    pub weight_memory: WeightMemory,
    /// Fused engine steps executed by the continuous-batching scheduler;
    /// each one decodes every packed weight exactly once.
    pub engine_steps: usize,
    /// Token-steps processed across all slots (Σ active slots per engine
    /// step) — what a sequential decoder would have paid one weight-decode
    /// pass each for.
    pub slot_steps: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record(&mut self, latency: Duration, tokens: usize) {
        self.completed += 1;
        self.generated_tokens += tokens;
        self.latencies_ms.push(latency.as_secs_f64() * 1e3);
    }

    pub fn p(&self, pct: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((pct / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Mean active slots per engine step — continuous-batching occupancy.
    pub fn batch_occupancy(&self) -> f64 {
        if self.engine_steps == 0 {
            0.0
        } else {
            self.slot_steps as f64 / self.engine_steps as f64
        }
    }

    /// Packed-weight decode amortisation: token-steps served per weight
    /// decode pass. Sequential decode pays one pass per token-step; the
    /// batched engine pays one per engine step, so each fused GEMM's decode
    /// work is shared by this many sequences on average.
    pub fn decode_amortisation(&self) -> f64 {
        self.batch_occupancy()
    }

    /// generated tokens per wall-clock second
    pub fn throughput_tps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / secs
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "completed={} tokens={} wall={:.2}s tput={:.1} tok/s p50={:.1}ms p99={:.1}ms",
            self.completed,
            self.generated_tokens,
            self.wall.as_secs_f64(),
            self.throughput_tps(),
            self.p(50.0),
            self.p(99.0),
        );
        if self.engine_steps > 0 {
            s.push_str(&format!(
                " steps={} occ={:.2} decode_amort={:.2}x",
                self.engine_steps,
                self.batch_occupancy(),
                self.decode_amortisation(),
            ));
        }
        if self.weight_memory.dense_f32_bytes > 0 {
            s.push_str(&format!(
                " weights={}B resident={}B ({:.2}x)",
                self.weight_memory.dense_f32_bytes,
                self.weight_memory.resident_bytes,
                self.weight_memory.ratio(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(Duration::from_millis(i), 1);
        }
        m.wall = Duration::from_secs(1);
        assert!((m.p(50.0) - 50.0).abs() <= 1.0);
        assert!((m.p(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(m.throughput_tps(), 100.0);
        assert!(m.summary().contains("tok/s"));
    }

    #[test]
    fn occupancy_and_amortisation() {
        let mut m = Metrics::new();
        assert_eq!(m.batch_occupancy(), 0.0);
        m.engine_steps = 10;
        m.slot_steps = 25;
        assert!((m.batch_occupancy() - 2.5).abs() < 1e-12);
        assert_eq!(m.decode_amortisation(), m.batch_occupancy());
        assert!(m.summary().contains("decode_amort=2.50x"));
    }
}
