//! Continuous-batching inference coordinator — the L3 serving path.
//!
//! A single scheduler loop owns a [`BatchedDecodeSession`] slot pool of
//! `max_batch` slots. Queued requests are admitted into free slots; every
//! active slot contributes a row-block to each fused engine step — up to
//! `prefill_chunk` prompt rows while prefilling, one row while decoding —
//! and the packed weights are decoded **once per layer per step regardless
//! of how many rows the step carries**, so the dequant cost is amortised
//! across sequences *and* across prompt tokens. The logit mask covers all
//! but each slot's final prompt row (intermediate prompt logits are
//! discarded anyway, and the vocab-sized head GEMM dominates a prefill
//! step's cost). Slots are recycled the moment a sequence finishes, so
//! short requests drain out and queued ones join mid-flight without batch
//! barriers. Greedy decode is bit-identical to running each request alone
//! through [`DecodeSession`] — for any `prefill_chunk` — (tested here and
//! in tests/continuous_batching.rs).

use super::metrics::Metrics;
use crate::model::kv_cache::{sample_logits, BatchedDecodeSession, DecodeSession};
use crate::model::Model;
use crate::util::rng::Pcg32;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Seed for the engine's per-request sampling RNGs (`seed ^ request id`),
/// so temperature > 0 decodes are reproducible for a given schedule.
pub const ENGINE_SEED: u64 = 0xC0FFEE;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    pub temperature: f32,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    pub latency: Duration,
    pub prompt_len: usize,
}

pub struct ServerConfig {
    /// Slot-pool size: the maximum number of sequences decoded together in
    /// one fused engine step. (The worker-pool-era `workers`/`batch_timeout`
    /// knobs are gone: the scheduler loop admits work the moment a slot
    /// frees, and the fused GEMMs thread internally.)
    pub max_batch: usize,
    /// Maximum prompt rows a prefilling slot feeds into one engine step.
    /// 1 reproduces token-at-a-time prefill; larger chunks amortise the
    /// per-step weight dequant across that many prompt tokens per slot.
    /// Never changes results — chunked prefill is bit-identical to
    /// sequential prefill (tested) — only how fast prompts are absorbed.
    pub prefill_chunk: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            prefill_chunk: 8,
        }
    }
}

/// Process one request to completion (prefill + decode) on the calling
/// thread with its own [`DecodeSession`] — the sequential reference the
/// batched engine must match bit for bit under greedy decoding, and the
/// single-stream baseline the decode bench compares against.
pub fn serve_one(model: &Model, req: &Request, seed: u64) -> Response {
    let start = Instant::now();
    let mut session = DecodeSession::new(model);
    let mut rng = Pcg32::new(seed ^ req.id);
    let mut logits = Vec::new();
    for &t in &req.prompt {
        logits = session.step(t);
    }
    let mut out = Vec::with_capacity(req.max_new_tokens);
    let cap = model.cfg().max_seq;
    for _ in 0..req.max_new_tokens {
        if session.pos >= cap {
            break;
        }
        let next = sample_logits(&logits, req.temperature, &mut rng);
        out.push(next);
        logits = session.step(next);
    }
    Response {
        id: req.id,
        tokens: out,
        latency: start.elapsed(),
        prompt_len: req.prompt.len(),
    }
}

/// One in-flight sequence occupying an engine slot.
struct ActiveSeq {
    req: Request,
    start: Instant,
    rng: Pcg32,
    /// tokens already fed to the model
    fed: usize,
    out: Vec<usize>,
    /// sampled token to feed on the next decode step (prompt rows are fed
    /// directly from `req.prompt` as chunked row-blocks)
    next_input: usize,
}

impl ActiveSeq {
    fn into_response(self) -> Response {
        Response {
            id: self.req.id,
            tokens: self.out,
            latency: self.start.elapsed(),
            prompt_len: self.req.prompt.len(),
        }
    }
}

/// Admission result: most requests become active; degenerate ones (no
/// prompt and nothing to generate) complete immediately.
enum Admission {
    Active(ActiveSeq),
    Done(Response),
}

fn admit(req: Request, submitted: Instant) -> Admission {
    let mut seq = ActiveSeq {
        rng: Pcg32::new(ENGINE_SEED ^ req.id),
        start: submitted,
        fed: 0,
        out: Vec::new(),
        next_input: 0,
        req,
    };
    if seq.req.prompt.is_empty() {
        // mirror `serve_one`: with no prompt there are no logits yet, and
        // sampling from an empty logit vector yields token 0
        if seq.req.max_new_tokens == 0 {
            return Admission::Done(seq.into_response());
        }
        let next = sample_logits(&[], seq.req.temperature, &mut seq.rng);
        seq.out.push(next);
        seq.next_input = next;
        if seq.out.len() >= seq.req.max_new_tokens {
            return Admission::Done(seq.into_response());
        }
    } else {
        seq.next_input = seq.req.prompt[0];
    }
    Admission::Active(seq)
}

/// Serve all `requests` through the continuous-batching engine and return
/// responses (sorted by id) plus metrics. Latency is measured from
/// submission, so it includes time spent queued for a slot.
pub fn run_batched(
    model: &Model,
    requests: Vec<Request>,
    cfg: &ServerConfig,
) -> (Vec<Response>, Metrics) {
    let n_slots = cfg.max_batch.max(1);
    let cap = model.cfg().max_seq;
    let mut queue: VecDeque<Request> = requests.into_iter().collect();
    let mut session = BatchedDecodeSession::new(model, n_slots);
    let mut slots: Vec<Option<ActiveSeq>> = (0..n_slots).map(|_| None).collect();
    let mut responses: Vec<Response> = Vec::new();
    let mut metrics = Metrics::new();
    let t0 = Instant::now();
    loop {
        // admit queued requests into free slots (continuous batching)
        for slot in 0..n_slots {
            while slots[slot].is_none() && !queue.is_empty() {
                let req = queue.pop_front().unwrap();
                session.reset_slot(slot);
                match admit(req, t0) {
                    Admission::Active(seq) => slots[slot] = Some(seq),
                    Admission::Done(resp) => {
                        metrics.record(resp.latency, resp.tokens.len());
                        responses.push(resp);
                    }
                }
            }
        }
        // one fused step over every active slot: prefilling slots feed a
        // chunk of up to `prefill_chunk` prompt rows, decoding slots one
        // row; the logit mask keeps only each slot's final prompt row and
        // decode rows (intermediate prompt logits are discarded anyway)
        let chunk = cfg.prefill_chunk.max(1);
        let mut batch: Vec<(usize, &[usize])> = Vec::with_capacity(n_slots);
        let mut needs_logits: Vec<bool> = Vec::with_capacity(n_slots);
        let mut meta: Vec<(usize, usize)> = Vec::with_capacity(n_slots); // (slot, rows fed)
        let mut prefill_rows = 0usize;
        for (s, a) in slots.iter().enumerate() {
            if let Some(a) = a {
                let plen = a.req.prompt.len();
                if a.fed < plen {
                    let end = (a.fed + chunk).min(plen);
                    batch.push((s, &a.req.prompt[a.fed..end]));
                    needs_logits.extend((a.fed..end).map(|j| j + 1 == plen));
                    meta.push((s, end - a.fed));
                    prefill_rows += end - a.fed;
                } else {
                    batch.push((s, std::slice::from_ref(&a.next_input)));
                    needs_logits.push(true);
                    meta.push((s, 1));
                }
            }
        }
        if batch.is_empty() {
            break; // queue drained and nothing in flight
        }
        let logits = session.step_chunked(&batch, Some(&needs_logits));
        drop(batch); // release the borrow of the slots' prompts
        metrics.engine_steps += 1;
        metrics.slot_steps += meta.len();
        if prefill_rows > 0 {
            metrics.prefill_steps += 1;
            metrics.prefill_rows += prefill_rows;
        }
        let mut row0 = 0usize;
        for &(slot, rows) in &meta {
            let last = row0 + rows - 1; // the slot's final row this step
            row0 += rows;
            let seq = slots[slot].as_mut().unwrap();
            let was_prefill = seq.fed < seq.req.prompt.len();
            seq.fed += rows;
            if was_prefill {
                if seq.fed < seq.req.prompt.len() {
                    continue; // still prefilling: every row was masked
                }
            } else {
                metrics.decode_rows += 1;
            }
            // `last` is the final prompt row (prefill just completed) or
            // the decode row: its logits belong to the newest token
            let more = seq.out.len() < seq.req.max_new_tokens && session.pos(slot) < cap;
            let finished = if more {
                let next = sample_logits(&logits[last], seq.req.temperature, &mut seq.rng);
                seq.out.push(next);
                seq.next_input = next;
                // the final sampled token needs no further forward pass
                seq.out.len() >= seq.req.max_new_tokens
            } else {
                true
            };
            if finished {
                let resp = slots[slot].take().unwrap().into_response();
                metrics.record(resp.latency, resp.tokens.len());
                responses.push(resp);
            }
        }
    }
    metrics.wall = t0.elapsed();
    // report what the weight cache actually occupies while serving —
    // packed block formats shrink this ~5× vs dense f32 (Table 3's Mem
    // column, measured on live state)
    metrics.weight_memory = model.weight_memory();
    responses.sort_by_key(|r| r.id);
    (responses, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn model() -> Model {
        let cfg = ModelConfig::preset("nano");
        Model::new(Params::init(&cfg, 4), QuantPlan::uniform(presets::bfp_w(6)))
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt: vec![3 + i % 5, 10, 42],
                max_new_tokens: 4,
                temperature: 0.0,
            })
            .collect()
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let (resps, metrics) = run_batched(&m, reqs(12), &ServerConfig::default());
        assert_eq!(resps.len(), 12);
        assert!(resps.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(metrics.completed, 12);
        assert!(metrics.throughput_tps() > 0.0);
        // every request feeds 3 prompt rows (one chunk at the default
        // prefill_chunk of 8) and 3 decode rows (the 4th sampled token is
        // never fed back) — 6 rows each, 4 slot contributions each
        assert_eq!(metrics.prefill_rows, 12 * 3);
        assert_eq!(metrics.decode_rows, 12 * 3);
        assert_eq!(metrics.slot_steps, 12 * 4);
        assert!(metrics.engine_steps > 0);
        assert!(metrics.prefill_steps > 0);
        assert!(metrics.batch_occupancy() > 1.0);
        // the whole 3-token prompt shares each prefill dequant pass
        assert!(metrics.prefill_amortisation() >= 3.0);
    }

    #[test]
    fn greedy_decode_is_deterministic_across_batch_sizes() {
        // the slot-pool size must never change a generated token
        let m = model();
        let one = ServerConfig {
            max_batch: 1,
            ..ServerConfig::default()
        };
        let four = ServerConfig {
            max_batch: 4,
            ..ServerConfig::default()
        };
        let (a, _) = run_batched(&m, reqs(6), &one);
        let (b, _) = run_batched(&m, reqs(6), &four);
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.tokens, rb.tokens, "request {}", ra.id);
        }
    }

    #[test]
    fn greedy_decode_is_deterministic_across_prefill_chunks() {
        // the prefill chunk size must never change a generated token:
        // chunk 1 is token-at-a-time, larger chunks only batch the rows
        let m = model();
        let requests: Vec<Request> = (0..5)
            .map(|i| Request {
                id: i as u64,
                prompt: vec![3 + i % 5, 10, 42, 7, 1, 30, 9, 100, 2, 8][..4 + i].to_vec(),
                max_new_tokens: 3,
                temperature: 0.0,
            })
            .collect();
        let mut baseline: Option<Vec<Response>> = None;
        let mut prefill_steps = Vec::new();
        for chunk in [1usize, 3, 8] {
            let cfg = ServerConfig {
                max_batch: 3,
                prefill_chunk: chunk,
            };
            let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
            prefill_steps.push(metrics.prefill_steps);
            match &baseline {
                None => baseline = Some(resps),
                Some(want) => {
                    for (ra, rb) in want.iter().zip(&resps) {
                        assert_eq!(ra.tokens, rb.tokens, "chunk {chunk} request {}", ra.id);
                    }
                }
            }
        }
        // chunking must genuinely reduce dequant passes, not just ride on
        // cross-slot batching: bigger chunks → strictly fewer prefill steps
        assert!(
            prefill_steps[2] < prefill_steps[1] && prefill_steps[1] < prefill_steps[0],
            "prefill steps by chunk: {prefill_steps:?}"
        );
    }

    #[test]
    fn engine_matches_sequential_reference() {
        // continuous batching must not change a single generated token
        let m = model();
        let requests = reqs(9);
        let cfg = ServerConfig {
            max_batch: 4,
            ..ServerConfig::default()
        };
        let (got, metrics) = run_batched(&m, requests.clone(), &cfg);
        assert!(metrics.batch_occupancy() > 1.0);
        for (resp, req) in got.iter().zip(&requests) {
            let want = serve_one(&m, req, ENGINE_SEED);
            assert_eq!(resp.id, req.id);
            assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
        }
    }

    #[test]
    fn metrics_report_packed_weight_savings() {
        // acceptance: under BFP6 the batched server must report ≥ 4× lower
        // resident weight bytes than the dense-f32 equivalent
        let m = model();
        let (_, metrics) = run_batched(&m, reqs(2), &ServerConfig::default());
        let wm = metrics.weight_memory;
        assert!(wm.dense_f32_bytes > 0);
        assert!(
            wm.resident_bytes * 4 <= wm.dense_f32_bytes,
            "resident {} vs f32 {}",
            wm.resident_bytes,
            wm.dense_f32_bytes
        );
        assert!(metrics.summary().contains("resident"));
        // an fp32 model reports density 1×
        let cfg = ModelConfig::preset("nano");
        let m32 = Model::new(Params::init(&cfg, 4), QuantPlan::fp32());
        let (_, metrics32) = run_batched(&m32, reqs(2), &ServerConfig::default());
        assert_eq!(
            metrics32.weight_memory.dense_f32_bytes,
            metrics32.weight_memory.resident_bytes
        );
        assert_eq!(metrics32.weight_memory.ratio(), 1.0);
    }

    #[test]
    fn respects_context_cap() {
        let m = model();
        let long = Request {
            id: 0,
            prompt: vec![1; 250],
            max_new_tokens: 50,
            temperature: 0.0,
        };
        let r = serve_one(&m, &long, 1);
        assert!(r.prompt_len + r.tokens.len() <= m.cfg().max_seq);
        // the engine honours the cap the same way
        let (resps, _) = run_batched(&m, vec![long.clone()], &ServerConfig::default());
        assert_eq!(resps[0].tokens, r.tokens);
    }

    #[test]
    fn degenerate_requests_complete() {
        let m = model();
        let requests: Vec<Request> = [(0u64, vec![], 0usize), (1, vec![3, 4], 0), (2, vec![], 3)]
            .into_iter()
            .map(|(id, prompt, max_new_tokens)| Request {
                id,
                prompt,
                max_new_tokens,
                temperature: 0.0,
            })
            .collect();
        let (resps, metrics) = run_batched(&m, requests.clone(), &ServerConfig::default());
        assert_eq!(resps.len(), 3);
        assert_eq!(metrics.completed, 3);
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req, ENGINE_SEED);
            assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
        }
    }
}
