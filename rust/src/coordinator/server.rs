//! Batched inference coordinator — the L3 serving path.
//!
//! std-thread implementation (no tokio in this environment): a bounded
//! request queue feeds a dynamic batcher; the batcher groups requests up
//! to `max_batch` (or `batch_timeout`), fans the batch out to a worker
//! pool that decodes with per-request KV-cache sessions, and records
//! latency/throughput metrics.

use super::metrics::Metrics;
use crate::model::kv_cache::{sample_logits, DecodeSession};
use crate::model::Model;
use crate::util::rng::Pcg32;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
    pub temperature: f32,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<usize>,
    pub latency: Duration,
    pub prompt_len: usize,
}

pub struct ServerConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_millis(5),
            workers: std::thread::available_parallelism()
                .map(|n| n.get().min(8))
                .unwrap_or(4),
        }
    }
}

/// Process one request to completion (prefill + decode) on the calling
/// thread. Used by the worker pool and directly by benchmarks.
pub fn serve_one(model: &Model, req: &Request, seed: u64) -> Response {
    let start = Instant::now();
    let mut session = DecodeSession::new(model);
    let mut rng = Pcg32::new(seed ^ req.id);
    let mut logits = Vec::new();
    for &t in &req.prompt {
        logits = session.step(t);
    }
    let mut out = Vec::with_capacity(req.max_new_tokens);
    let cap = model.cfg().max_seq;
    for _ in 0..req.max_new_tokens {
        if session.pos >= cap {
            break;
        }
        let next = sample_logits(&logits, req.temperature, &mut rng);
        out.push(next);
        logits = session.step(next);
    }
    Response {
        id: req.id,
        tokens: out,
        latency: start.elapsed(),
        prompt_len: req.prompt.len(),
    }
}

/// Run a closed-loop benchmark: submit all `requests`, process with the
/// dynamic batcher + worker pool, return responses + metrics.
pub fn run_batched(model: &Model, requests: Vec<Request>, cfg: &ServerConfig) -> (Vec<Response>, Metrics) {
    let (tx, rx) = mpsc::channel::<Request>();
    for r in requests.iter().cloned() {
        tx.send(r).unwrap();
    }
    drop(tx);
    let rx = Arc::new(Mutex::new(rx));
    let n_total = requests.len();
    let done = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let responses = Arc::new(Mutex::new(Vec::with_capacity(n_total)));
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for wi in 0..cfg.workers {
            let rx = Arc::clone(&rx);
            let responses = Arc::clone(&responses);
            let metrics = Arc::clone(&metrics);
            let done = Arc::clone(&done);
            let stop = Arc::clone(&stop);
            scope.spawn(move || {
                loop {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    // dynamic batching: grab up to max_batch requests
                    let mut batch = Vec::new();
                    {
                        let guard = rx.lock().unwrap();
                        let deadline = Instant::now() + cfg.batch_timeout;
                        while batch.len() < cfg.max_batch {
                            match guard.try_recv() {
                                Ok(r) => batch.push(r),
                                Err(mpsc::TryRecvError::Empty) => {
                                    if batch.is_empty() && Instant::now() < deadline {
                                        std::thread::yield_now();
                                        continue;
                                    }
                                    break;
                                }
                                Err(mpsc::TryRecvError::Disconnected) => break,
                            }
                        }
                    }
                    if batch.is_empty() {
                        if done.load(Ordering::Relaxed) >= n_total {
                            break;
                        }
                        std::thread::yield_now();
                        continue;
                    }
                    for req in batch {
                        let resp = serve_one(model, &req, 0xC0FFEE + wi as u64);
                        let gen_toks = resp.tokens.len();
                        let lat = resp.latency;
                        responses.lock().unwrap().push(resp);
                        metrics.lock().unwrap().record(lat, gen_toks);
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let mut m = Arc::try_unwrap(metrics).unwrap().into_inner().unwrap();
    m.wall = wall;
    // report what the weight cache actually occupies while serving —
    // packed block formats shrink this ~5× vs dense f32 (Table 3's Mem
    // column, measured on live state)
    m.weight_memory = model.weight_memory();
    let mut out = Arc::try_unwrap(responses).unwrap().into_inner().unwrap();
    out.sort_by_key(|r| r.id);
    (out, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn model() -> Model {
        let cfg = ModelConfig::preset("nano");
        Model::new(Params::init(&cfg, 4), QuantPlan::uniform(presets::bfp_w(6)))
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt: vec![3 + i % 5, 10, 42],
                max_new_tokens: 4,
                temperature: 0.0,
            })
            .collect()
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let (resps, metrics) = run_batched(&m, reqs(12), &ServerConfig::default());
        assert_eq!(resps.len(), 12);
        assert!(resps.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(metrics.completed, 12);
        assert!(metrics.throughput_tps() > 0.0);
    }

    #[test]
    fn greedy_decode_is_deterministic_across_workers() {
        let m = model();
        let (a, _) = run_batched(&m, reqs(6), &ServerConfig { workers: 1, ..Default::default() });
        let (b, _) = run_batched(&m, reqs(6), &ServerConfig { workers: 4, ..Default::default() });
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.tokens, rb.tokens, "request {}", ra.id);
        }
    }

    #[test]
    fn metrics_report_packed_weight_savings() {
        // acceptance: under BFP6 the batched server must report ≥ 4× lower
        // resident weight bytes than the dense-f32 equivalent
        let m = model();
        let (_, metrics) = run_batched(&m, reqs(2), &ServerConfig::default());
        let wm = metrics.weight_memory;
        assert!(wm.dense_f32_bytes > 0);
        assert!(
            wm.resident_bytes * 4 <= wm.dense_f32_bytes,
            "resident {} vs f32 {}",
            wm.resident_bytes,
            wm.dense_f32_bytes
        );
        assert!(metrics.summary().contains("resident"));
        // an fp32 model reports density 1×
        let cfg = ModelConfig::preset("nano");
        let m32 = Model::new(Params::init(&cfg, 4), QuantPlan::fp32());
        let (_, metrics32) = run_batched(&m32, reqs(2), &ServerConfig::default());
        assert_eq!(
            metrics32.weight_memory.dense_f32_bytes,
            metrics32.weight_memory.resident_bytes
        );
        assert_eq!(metrics32.weight_memory.ratio(), 1.0);
    }

    #[test]
    fn respects_context_cap() {
        let m = model();
        let long = Request {
            id: 0,
            prompt: vec![1; 250],
            max_new_tokens: 50,
            temperature: 0.0,
        };
        let r = serve_one(&m, &long, 1);
        assert!(r.prompt_len + r.tokens.len() <= m.cfg().max_seq);
    }
}
