//! Serving front door: request/response types, per-request
//! [`GenerationParams`], the sequential reference path ([`serve_one`]),
//! and the offline batch wrapper ([`run_batched`]).
//!
//! The actual scheduler lives in [`super::engine`]: a long-lived loop over
//! a [`crate::model::kv_cache::BatchedDecodeSession`] slot pool that
//! admits queued requests into free slots, steps every active slot through
//! one fused packed GEMM per weight site per layer — up to
//! `prefill_chunk` prompt rows while prefilling, one row while decoding —
//! and recycles slots the moment a sequence finishes or is cancelled.
//! [`run_batched`] is a thin submit-all/collect wrapper over that same
//! core, so everything proved about the engine (batched greedy decode
//! bit-identical to [`serve_one`], chunked prefill bit-identical to
//! token-at-a-time, for any slot count and chunk size) holds for the batch
//! path by construction (tested here and in tests/continuous_batching.rs
//! and tests/engine_lifecycle.rs).

use super::engine::{channels, EngineCore, RequestHandle};
use super::metrics::Metrics;
use crate::model::kv_cache::{sample_top_k, DecodeSession};
use crate::model::paged::{KvConfig, SessionConfig};
use crate::model::Model;
use crate::util::rng::Pcg32;
use std::time::{Duration, Instant};

/// Default seed for per-request sampling RNGs (`ENGINE_SEED ^ request id`
/// when [`GenerationParams::seed`] is `None`), so temperature > 0 decodes
/// are reproducible and schedule-independent.
pub const ENGINE_SEED: u64 = 0xC0FFEE;

/// Per-request generation knobs, shared verbatim by [`serve_one`] and the
/// engine so the two paths stay bit-identical for any setting.
#[derive(Clone, Debug)]
pub struct GenerationParams {
    /// Maximum number of tokens to sample (the context cap and stop
    /// tokens may end generation earlier — see [`FinishReason`]).
    pub max_new_tokens: usize,
    /// `<= 0` is greedy argmax; otherwise softmax temperature sampling
    /// from the per-request RNG.
    pub temperature: f32,
    /// Restrict temperature sampling to the `top_k` highest logits;
    /// `0` disables the filter. Ignored under greedy decoding.
    pub top_k: usize,
    /// Generation stops (with [`FinishReason::StopToken`]) as soon as one
    /// of these tokens is sampled; the stop token is included in the
    /// output.
    pub stop_tokens: Vec<usize>,
    /// Explicit sampler seed for reproducible temperature sampling.
    /// `None` derives `ENGINE_SEED ^ id`, which already makes every
    /// request reproducible independent of batch schedule.
    pub seed: Option<u64>,
}

impl Default for GenerationParams {
    fn default() -> Self {
        GenerationParams {
            max_new_tokens: 16,
            temperature: 0.0,
            top_k: 0,
            stop_tokens: Vec::new(),
            seed: None,
        }
    }
}

impl GenerationParams {
    /// Greedy decoding for `max_new_tokens` tokens — the common test and
    /// benchmark configuration.
    pub fn greedy(max_new_tokens: usize) -> GenerationParams {
        GenerationParams {
            max_new_tokens,
            ..GenerationParams::default()
        }
    }

    /// The per-request sampler seed: explicit seed if set, else
    /// `ENGINE_SEED ^ id` (schedule-independent either way).
    pub(crate) fn sampler_seed(&self, id: u64) -> u64 {
        self.seed.unwrap_or(ENGINE_SEED ^ id)
    }
}

/// One generation request: a prompt plus its [`GenerationParams`].
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen id, echoed on the [`Response`] (and used to derive
    /// the default sampler seed).
    pub id: u64,
    /// Prompt token ids (may be empty).
    pub prompt: Vec<usize>,
    /// Generation parameters for this request.
    pub params: GenerationParams,
}

impl Request {
    /// Greedy request — the common shorthand.
    pub fn greedy(id: u64, prompt: Vec<usize>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            params: GenerationParams::greedy(max_new_tokens),
        }
    }
}

/// Why a sequence stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new_tokens` tokens were sampled.
    MaxTokens,
    /// A [`GenerationParams::stop_tokens`] entry was sampled (it is the
    /// last token of the output).
    StopToken,
    /// The model's context window filled before `max_new_tokens`.
    ContextFull,
    /// The request was cancelled ([`RequestHandle::cancel`], or its event
    /// listener was dropped); the response holds the tokens generated so
    /// far.
    Cancelled,
}

impl FinishReason {
    /// Stable wire name, used by the HTTP front door's JSON and SSE
    /// framing (`coordinator/http.rs`).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopToken => "stop_token",
            FinishReason::ContextFull => "context_full",
            FinishReason::Cancelled => "cancelled",
        }
    }

    /// Inverse of [`Self::as_str`] (used by HTTP clients and tests).
    pub fn parse(s: &str) -> Option<FinishReason> {
        match s {
            "max_tokens" => Some(FinishReason::MaxTokens),
            "stop_token" => Some(FinishReason::StopToken),
            "context_full" => Some(FinishReason::ContextFull),
            "cancelled" => Some(FinishReason::Cancelled),
            _ => None,
        }
    }
}

/// A finished (or cancelled) generation.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// Generated tokens (prompt not included).
    pub tokens: Vec<usize>,
    /// Submission-to-finish latency, time queued for a slot included.
    pub latency: Duration,
    /// Length of the request's prompt.
    pub prompt_len: usize,
    /// Why generation stopped.
    pub finish: FinishReason,
}

/// Engine configuration. Validated at construction via
/// [`ServerConfig::new`] / [`ServerConfig::validate`] (the scheduler
/// asserts it once at start instead of patching values deep in the loop).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Slot-pool size: the maximum number of sequences decoded together in
    /// one fused engine step.
    pub max_batch: usize,
    /// Maximum prompt rows a prefilling slot feeds into one engine step.
    /// 1 reproduces token-at-a-time prefill; larger chunks amortise the
    /// per-step weight dequant across that many prompt tokens per slot.
    /// Never changes results — chunked prefill is bit-identical to
    /// sequential prefill (tested) — only how fast prompts are absorbed.
    pub prefill_chunk: usize,
    /// Bound of the admission queue: once this many submitted requests are
    /// waiting for a slot, [`super::engine::EngineHandle::submit`] blocks
    /// and `try_submit` returns `QueueFull` — the engine's explicit
    /// backpressure signal.
    pub queue_depth: usize,
    /// KV-cache configuration for the engine's slot pool: page size,
    /// storage format (f32 or a block format), prefix-cache budget.
    /// Exposed on the CLI as `--kv-page` / `--kv-format`.
    pub kv: KvConfig,
    /// Maximum draft proposals per speculative round (`--spec-k`). Only
    /// consulted when the engine is started with a draft model
    /// ([`super::engine::Engine::start_with_draft`] /
    /// [`run_batched_with_draft`]); the plain engine ignores it.
    pub spec_k: usize,
}

impl ServerConfig {
    /// Build a validated config (panics on a zero field; see
    /// [`Self::validate`]). KV settings take the defaults (f32 pages of
    /// 16 rows); override via the public `kv` field.
    pub fn new(max_batch: usize, prefill_chunk: usize, queue_depth: usize) -> ServerConfig {
        let cfg = ServerConfig {
            max_batch,
            prefill_chunk,
            queue_depth,
            ..ServerConfig::default()
        };
        cfg.validate();
        cfg
    }

    /// Assert the invariants the scheduler relies on: at least one slot,
    /// at least one prompt row per prefill step, a non-zero queue bound,
    /// at least one speculative proposal per round, and a well-formed KV
    /// config (non-zero page size, pageable format).
    pub fn validate(&self) {
        assert!(self.max_batch >= 1, "ServerConfig: max_batch must be >= 1");
        assert!(self.prefill_chunk >= 1, "ServerConfig: prefill_chunk must be >= 1");
        assert!(self.queue_depth >= 1, "ServerConfig: queue_depth must be >= 1");
        assert!(self.spec_k >= 1, "ServerConfig: spec_k must be >= 1");
        self.kv.validate();
    }

    /// The [`SessionConfig`] the engine builds its slot pool from: one
    /// slot per `max_batch` entry, this config's KV settings.
    pub fn session_config(&self) -> SessionConfig {
        SessionConfig::new(self.max_batch).kv(self.kv)
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            prefill_chunk: 8,
            queue_depth: 64,
            kv: KvConfig::default(),
            spec_k: 4,
        }
    }
}

/// Process one request to completion (prefill + decode) on the calling
/// thread with its own [`DecodeSession`] — the sequential reference the
/// batched engine must match bit for bit (greedy *and* seeded sampling),
/// and the single-stream baseline the decode bench compares against.
pub fn serve_one(model: &Model, req: &Request) -> Response {
    let start = Instant::now();
    let p = &req.params;
    let mut session = DecodeSession::new(model, &SessionConfig::new(1));
    let mut rng = Pcg32::new(p.sampler_seed(req.id));
    let mut logits = Vec::new();
    for &t in &req.prompt {
        logits = session.step(t);
    }
    let mut out = Vec::with_capacity(p.max_new_tokens);
    let cap = session.max_context();
    let mut finish = FinishReason::MaxTokens;
    for _ in 0..p.max_new_tokens {
        if session.pos >= cap {
            finish = FinishReason::ContextFull;
            break;
        }
        let next = sample_top_k(&logits, p.temperature, p.top_k, &mut rng);
        out.push(next);
        if p.stop_tokens.contains(&next) {
            finish = FinishReason::StopToken;
            break;
        }
        // the final sampled token needs no further forward pass
        if out.len() < p.max_new_tokens {
            logits = session.step(next);
        }
    }
    Response {
        id: req.id,
        tokens: out,
        latency: start.elapsed(),
        prompt_len: req.prompt.len(),
        finish,
    }
}

/// Serve all `requests` through the continuous-batching engine and return
/// responses (sorted by id) plus metrics — a thin submit-all/collect
/// wrapper over the same `EngineCore` scheduler that powers
/// [`super::engine::Engine`], run on a scoped thread so it can borrow
/// `model` directly. Latency is measured from submission, so it includes
/// time spent queued for a slot.
///
/// Every request is enqueued before the scheduler starts (the admission
/// queue is widened to hold them all), which keeps offline-batch
/// scheduling — and therefore the step/occupancy metrics — deterministic.
pub fn run_batched(
    model: &Model,
    requests: Vec<Request>,
    cfg: &ServerConfig,
) -> (Vec<Response>, Metrics) {
    run_batched_inner(model, None, requests, cfg)
}

/// [`run_batched`] with self-drafting speculative decoding: greedy
/// requests decode through draft-propose / chunked-verify rounds
/// (`cfg.spec_k` proposals per round) and still emit exactly the tokens
/// target-only greedy decode would (tested in tests/speculative.rs);
/// temperature > 0 requests take the plain path untouched.
pub fn run_batched_with_draft(
    model: &Model,
    draft: &Model,
    requests: Vec<Request>,
    cfg: &ServerConfig,
) -> (Vec<Response>, Metrics) {
    run_batched_inner(model, Some(draft), requests, cfg)
}

fn run_batched_inner(
    model: &Model,
    draft: Option<&Model>,
    requests: Vec<Request>,
    cfg: &ServerConfig,
) -> (Vec<Response>, Metrics) {
    cfg.validate();
    let mut engine_cfg = cfg.clone();
    engine_cfg.queue_depth = cfg.queue_depth.max(requests.len()).max(1);
    let (handle, rx, shared) = channels(&engine_cfg);
    let pending: Vec<RequestHandle> = requests
        .into_iter()
        .map(|r| handle.submit(r).expect("pre-start submit fits queue"))
        .collect();
    let core_shared = shared.clone();
    let mut responses: Vec<Response> = std::thread::scope(|s| {
        s.spawn(move || EngineCore::new_with_draft(model, draft, engine_cfg, rx, core_shared).run());
        let out: Vec<Response> = pending.into_iter().map(|h| h.wait()).collect();
        // every RequestHandle is consumed and this drops the last sender,
        // so the scheduler drains, publishes final metrics, and exits
        drop(handle);
        out
    });
    let metrics = shared.metrics.lock().unwrap().clone();
    responses.sort_by_key(|r| r.id);
    (responses, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn model() -> Model {
        let cfg = ModelConfig::preset("nano");
        Model::new(Params::init(&cfg, 4), QuantPlan::uniform(presets::bfp_w(6)))
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request::greedy(i as u64, vec![3 + i % 5, 10, 42], 4))
            .collect()
    }

    #[test]
    fn serves_all_requests() {
        let m = model();
        let (resps, metrics) = run_batched(&m, reqs(12), &ServerConfig::default());
        assert_eq!(resps.len(), 12);
        assert!(resps.iter().all(|r| r.tokens.len() == 4));
        assert!(resps.iter().all(|r| r.finish == FinishReason::MaxTokens));
        assert_eq!(metrics.completed, 12);
        assert!(metrics.throughput_tps() > 0.0);
        // every request feeds 3 prompt rows (one chunk at the default
        // prefill_chunk of 8) and 3 decode rows (the 4th sampled token is
        // never fed back) — 6 rows each, 4 slot contributions each
        assert_eq!(metrics.prefill_rows, 12 * 3);
        assert_eq!(metrics.decode_rows, 12 * 3);
        assert_eq!(metrics.slot_steps, 12 * 4);
        assert!(metrics.engine_steps > 0);
        assert!(metrics.prefill_steps > 0);
        assert!(metrics.batch_occupancy() > 1.0);
        // the whole 3-token prompt shares each prefill dequant pass
        assert!(metrics.prefill_amortisation() >= 3.0);
        // queue accounting: all 12 were pre-queued, all were admitted
        assert_eq!(metrics.queue_wait.count(), 12);
        assert_eq!(metrics.queue_peak, 12);
        assert_eq!(metrics.queue_depth, 0);
        assert_eq!(metrics.cancelled, 0);
    }

    #[test]
    fn greedy_decode_is_deterministic_across_batch_sizes() {
        // the slot-pool size must never change a generated token
        let m = model();
        let one = ServerConfig {
            max_batch: 1,
            ..ServerConfig::default()
        };
        let four = ServerConfig {
            max_batch: 4,
            ..ServerConfig::default()
        };
        let (a, _) = run_batched(&m, reqs(6), &one);
        let (b, _) = run_batched(&m, reqs(6), &four);
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.tokens, rb.tokens, "request {}", ra.id);
        }
    }

    #[test]
    fn greedy_decode_is_deterministic_across_prefill_chunks() {
        // the prefill chunk size must never change a generated token:
        // chunk 1 is token-at-a-time, larger chunks only batch the rows
        let m = model();
        let requests: Vec<Request> = (0..5)
            .map(|i| {
                let prompt = vec![3 + i % 5, 10, 42, 7, 1, 30, 9, 100, 2, 8][..4 + i].to_vec();
                Request::greedy(i as u64, prompt, 3)
            })
            .collect();
        let mut baseline: Option<Vec<Response>> = None;
        let mut prefill_steps = Vec::new();
        for chunk in [1usize, 3, 8] {
            let cfg = ServerConfig {
                max_batch: 3,
                prefill_chunk: chunk,
                ..ServerConfig::default()
            };
            let (resps, metrics) = run_batched(&m, requests.clone(), &cfg);
            prefill_steps.push(metrics.prefill_steps);
            match &baseline {
                None => baseline = Some(resps),
                Some(want) => {
                    for (ra, rb) in want.iter().zip(&resps) {
                        assert_eq!(ra.tokens, rb.tokens, "chunk {chunk} request {}", ra.id);
                    }
                }
            }
        }
        // chunking must genuinely reduce dequant passes, not just ride on
        // cross-slot batching: bigger chunks → strictly fewer prefill steps
        assert!(
            prefill_steps[2] < prefill_steps[1] && prefill_steps[1] < prefill_steps[0],
            "prefill steps by chunk: {prefill_steps:?}"
        );
    }

    #[test]
    fn engine_matches_sequential_reference() {
        // continuous batching must not change a single generated token
        let m = model();
        let requests = reqs(9);
        let cfg = ServerConfig {
            max_batch: 4,
            ..ServerConfig::default()
        };
        let (got, metrics) = run_batched(&m, requests.clone(), &cfg);
        assert!(metrics.batch_occupancy() > 1.0);
        for (resp, req) in got.iter().zip(&requests) {
            let want = serve_one(&m, req);
            assert_eq!(resp.id, req.id);
            assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
            assert_eq!(resp.finish, want.finish, "request {}", req.id);
        }
    }

    #[test]
    fn seeded_sampling_matches_reference_through_engine() {
        // temperature sampling draws from a per-request RNG exactly once
        // per generated token, so batch schedule never changes the draw
        // sequence: sampled decodes match serve_one token for token
        let m = model();
        let requests: Vec<Request> = (0..6u64)
            .map(|i| Request {
                id: i,
                prompt: vec![3 + i as usize % 5, 10, 42],
                params: GenerationParams {
                    max_new_tokens: 5,
                    temperature: 0.9,
                    top_k: 8,
                    seed: if i % 2 == 0 { Some(1234 + i) } else { None },
                    ..GenerationParams::default()
                },
            })
            .collect();
        let cfg = ServerConfig {
            max_batch: 3,
            ..ServerConfig::default()
        };
        let (got, _) = run_batched(&m, requests.clone(), &cfg);
        for (resp, req) in got.iter().zip(&requests) {
            let want = serve_one(&m, req);
            assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
        }
    }

    #[test]
    fn metrics_report_packed_weight_savings() {
        // acceptance: under BFP6 the batched server must report ≥ 4× lower
        // resident weight bytes than the dense-f32 equivalent
        let m = model();
        let (_, metrics) = run_batched(&m, reqs(2), &ServerConfig::default());
        let wm = metrics.weight_memory;
        assert!(wm.dense_f32_bytes > 0);
        assert!(
            wm.resident_bytes * 4 <= wm.dense_f32_bytes,
            "resident {} vs f32 {}",
            wm.resident_bytes,
            wm.dense_f32_bytes
        );
        assert!(metrics.summary().contains("resident"));
        // an fp32 model reports density 1×
        let cfg = ModelConfig::preset("nano");
        let m32 = Model::new(Params::init(&cfg, 4), QuantPlan::fp32());
        let (_, metrics32) = run_batched(&m32, reqs(2), &ServerConfig::default());
        assert_eq!(
            metrics32.weight_memory.dense_f32_bytes,
            metrics32.weight_memory.resident_bytes
        );
        assert_eq!(metrics32.weight_memory.ratio(), 1.0);
    }

    #[test]
    fn respects_context_cap() {
        let m = model();
        let long = Request::greedy(0, vec![1; 250], 50);
        let r = serve_one(&m, &long);
        assert!(r.prompt_len + r.tokens.len() <= m.cfg().max_seq);
        assert_eq!(r.finish, FinishReason::ContextFull);
        // the engine honours the cap the same way
        let (resps, _) = run_batched(&m, vec![long.clone()], &ServerConfig::default());
        assert_eq!(resps[0].tokens, r.tokens);
        assert_eq!(resps[0].finish, FinishReason::ContextFull);
    }

    #[test]
    fn degenerate_requests_complete() {
        let m = model();
        let base = [(0u64, vec![], 0usize), (1, vec![3, 4], 0), (2, vec![], 3)];
        let mut requests: Vec<Request> = base
            .into_iter()
            .map(|(id, prompt, max_new_tokens)| Request::greedy(id, prompt, max_new_tokens))
            .collect();
        // empty prompt + temperature > 0: the first token is sampled from
        // empty logits — must fall back to token 0, never panic the
        // scheduler thread
        requests.push(Request {
            id: 3,
            prompt: vec![],
            params: GenerationParams {
                max_new_tokens: 3,
                temperature: 0.8,
                ..GenerationParams::default()
            },
        });
        let (resps, metrics) = run_batched(&m, requests.clone(), &ServerConfig::default());
        assert_eq!(resps.len(), 4);
        assert_eq!(metrics.completed, 4);
        for (resp, req) in resps.iter().zip(&requests) {
            let want = serve_one(&m, req);
            assert_eq!(resp.tokens, want.tokens, "request {}", req.id);
        }
        assert_eq!(resps[3].tokens[0], 0);
    }

    #[test]
    fn stop_tokens_match_reference() {
        // a stop token ends generation early on both paths, identically
        let m = model();
        let free = serve_one(&m, &Request::greedy(0, vec![3, 10, 42], 6));
        assert_eq!(free.tokens.len(), 6);
        let stop = free.tokens[2];
        let req = Request {
            id: 0,
            prompt: vec![3, 10, 42],
            params: GenerationParams {
                max_new_tokens: 6,
                stop_tokens: vec![stop],
                ..GenerationParams::default()
            },
        };
        let want = serve_one(&m, &req);
        assert_eq!(want.finish, FinishReason::StopToken);
        assert_eq!(want.tokens.last(), Some(&stop));
        assert!(want.tokens.len() <= 3);
        let (resps, _) = run_batched(&m, vec![req], &ServerConfig::default());
        assert_eq!(resps[0].tokens, want.tokens);
        assert_eq!(resps[0].finish, FinishReason::StopToken);
    }

    #[test]
    #[should_panic(expected = "max_batch must be >= 1")]
    fn zero_max_batch_is_rejected_at_construction() {
        ServerConfig::new(0, 8, 64);
    }

    #[test]
    #[should_panic(expected = "prefill_chunk must be >= 1")]
    fn zero_prefill_chunk_is_rejected() {
        let cfg = ServerConfig {
            prefill_chunk: 0,
            ..ServerConfig::default()
        };
        run_batched(&model(), Vec::new(), &cfg);
    }
}
