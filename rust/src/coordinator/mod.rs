//! L3 coordination: the live serving engine (engine.rs), the batch
//! front door and request/response types (server.rs), serving metrics,
//! and experiment orchestration (model zoo, result persistence).

pub mod engine;
pub mod experiment;
pub mod metrics;
pub mod server;

pub use engine::{Engine, EngineHandle, RequestHandle, SubmitError, TokenEvent};
pub use experiment::{default_steps, get_or_train, save_result};
pub use metrics::{LogHistogram, Metrics};
pub use server::{
    run_batched, serve_one, FinishReason, GenerationParams, Request, Response, ServerConfig,
    ENGINE_SEED,
};
