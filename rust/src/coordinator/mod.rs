//! L3 coordination: the live serving engine (engine.rs), the batch
//! front door and request/response types (server.rs), the network front
//! door (router.rs priority admission + http.rs HTTP/SSE server), the
//! open-loop SLO traffic harness (traffic.rs), serving metrics, and
//! experiment orchestration (model zoo, result persistence).

pub mod engine;
pub mod experiment;
pub mod http;
pub mod metrics;
pub mod router;
pub mod server;
pub mod traffic;

pub use engine::{Engine, EngineHandle, RequestHandle, SubmitError, TokenEvent};
pub use experiment::{default_steps, get_or_train, save_result};
pub use http::{hist_json, metrics_json, response_json, shutdown_signal, HttpConfig, HttpServer};
pub use metrics::{LogHistogram, Metrics};
pub use router::{
    FairPicker, ModelEntry, Priority, RouteError, Router, RouterConfig, RouterHandle, RouterStats,
    Ticket,
};
pub use server::{
    run_batched, run_batched_with_draft, serve_one, FinishReason, GenerationParams, Request,
    Response, ServerConfig, ENGINE_SEED,
};
pub use traffic::{
    http_exchange, run_trace, serve_trace, HttpOutcome, OpenLoopReport, SseRecord, Trace,
    TraceItem, TrafficConfig,
};
