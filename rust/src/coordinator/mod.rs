//! L3 coordination: batched inference serving (server.rs), metrics, and
//! experiment orchestration (model zoo, result persistence).

pub mod experiment;
pub mod metrics;
pub mod server;

pub use experiment::{default_steps, get_or_train, save_result};
pub use metrics::Metrics;
pub use server::{run_batched, serve_one, Request, Response, ServerConfig, ENGINE_SEED};
