//! Long-lived serving engine: live submission, per-request token
//! streaming, cancellation, and graceful shutdown over the
//! continuous-batching scheduler.
//!
//! [`Engine::start`] spawns a scheduler thread that owns a
//! [`BatchedDecodeSession`] slot pool and returns a cloneable
//! [`EngineHandle`]. Requests join and leave the pool mid-flight — the
//! serving shape that makes the paper's amortised block-dequant economics
//! pay off: every fused engine step dequantises each packed weight exactly
//! once for *all* rows it carries, so throughput grows with occupancy, and
//! occupancy only stays high if work can be admitted the moment a slot
//! frees.
//!
//! The lifecycle of one request:
//!
//! 1. [`EngineHandle::submit`] places it on the bounded admission queue
//!    (blocking when full; [`EngineHandle::try_submit`] returns
//!    [`SubmitError::QueueFull`] instead) and returns a [`RequestHandle`].
//! 2. The handle streams [`TokenEvent`]s: `Queued` at submission,
//!    `Started` when a slot admits the request, one `Token` per sampled
//!    token, and a terminal `Finished` carrying the [`FinishReason`] and
//!    the full [`Response`].
//! 3. [`RequestHandle::cancel`] (or dropping the handle mid-stream) frees
//!    the slot on the next engine step; the `Finished` event then carries
//!    [`FinishReason::Cancelled`] and the tokens generated so far.
//! 4. [`Engine::shutdown`] stops admissions, drains queued and in-flight
//!    work to completion, and returns the final [`Metrics`] snapshot.
//!
//! Scheduling never changes results: greedy *and* seeded sampling are
//! bit-identical to [`super::server::serve_one`] because each request owns
//! a [`Pcg32`] advanced exactly once per sampled token
//! (tests/engine_lifecycle.rs asserts this for every preset format).
//!
//! The scheduler body itself is the lifetime-generic `EngineCore`, which
//! [`super::server::run_batched`] also drives on a scoped thread borrowing
//! `&Model` — one scheduler, two front doors.

use super::metrics::Metrics;
use super::server::{FinishReason, Request, Response, ServerConfig};
use crate::model::kv_cache::{sample_top_k, BatchedDecodeSession};
use crate::model::{KvStats, Model, SpecStats, SpeculativeSession};
use crate::util::rng::Pcg32;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Per-request lifecycle events streamed over a [`RequestHandle`].
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// The request was accepted into the bounded admission queue.
    Queued,
    /// The request was admitted into an engine slot; prefill begins.
    Started,
    /// One sampled token, emitted the engine step it was produced.
    Token(usize),
    /// Terminal event: why generation stopped, plus the full response.
    /// Nothing is emitted for a request after this.
    Finished {
        /// Why the sequence stopped.
        reason: FinishReason,
        /// The completed (possibly partial, if cancelled) response.
        response: Response,
    },
}

/// Why a submission was rejected; the request is handed back unmodified.
#[derive(Debug)]
pub enum SubmitError {
    /// The bounded admission queue is at `queue_depth` — backpressure.
    /// Only returned by [`EngineHandle::try_submit`] (blocking `submit`
    /// waits for space instead).
    QueueFull(Request),
    /// The engine has shut down (or its scheduler exited).
    Closed(Request),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(r) => write!(f, "admission queue full (request {})", r.id),
            SubmitError::Closed(r) => write!(f, "engine closed (request {})", r.id),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A submitted request travelling to the scheduler.
pub(crate) struct Submission {
    req: Request,
    submitted: Instant,
    events: Sender<TokenEvent>,
    cancelled: Arc<AtomicBool>,
}

/// Scheduler mailbox messages.
pub(crate) enum Msg {
    Submit(Box<Submission>),
    /// Wake an idle scheduler so it notices a freshly set cancel flag.
    Wake,
    /// Stop admitting, drain queued + in-flight work, then exit.
    Shutdown,
}

/// Admission-queue accounting shared between submitters and the scheduler.
struct QueueState {
    len: usize,
    peak: usize,
    closed: bool,
}

/// State shared by the scheduler thread and every handle.
pub(crate) struct Shared {
    queue: Mutex<QueueState>,
    space: Condvar,
    queue_cap: usize,
    /// Latest metrics snapshot, refreshed by the scheduler every step and
    /// finally at exit.
    pub(crate) metrics: Mutex<Metrics>,
}

/// Build the handle/mailbox/shared-state triple for one scheduler. Used by
/// [`Engine::start`] (detached thread) and `run_batched` (scoped thread).
pub(crate) fn channels(cfg: &ServerConfig) -> (EngineHandle, Receiver<Msg>, Arc<Shared>) {
    let (tx, rx) = channel();
    let state = QueueState {
        len: 0,
        peak: 0,
        closed: false,
    };
    let shared = Arc::new(Shared {
        queue: Mutex::new(state),
        space: Condvar::new(),
        queue_cap: cfg.queue_depth,
        metrics: Mutex::new(Metrics::new()),
    });
    let handle = EngineHandle {
        tx,
        shared: shared.clone(),
    };
    (handle, rx, shared)
}

/// Cloneable submission/observation handle to a running engine. All clones
/// feed the same scheduler; the engine keeps serving until every clone
/// (and every outstanding [`RequestHandle`]) is dropped or
/// [`Engine::shutdown`] is called.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
}

impl EngineHandle {
    /// Submit a request, blocking while the admission queue is full
    /// (explicit backpressure). Returns the streaming [`RequestHandle`],
    /// or [`SubmitError::Closed`] once the engine is shutting down.
    pub fn submit(&self, req: Request) -> Result<RequestHandle, SubmitError> {
        self.enqueue(req, true)
    }

    /// Non-blocking [`Self::submit`]: a full queue returns
    /// [`SubmitError::QueueFull`] with the request handed back, letting
    /// callers shed or retry on their own policy.
    pub fn try_submit(&self, req: Request) -> Result<RequestHandle, SubmitError> {
        self.enqueue(req, false)
    }

    fn enqueue(&self, req: Request, block: bool) -> Result<RequestHandle, SubmitError> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                if q.closed {
                    return Err(SubmitError::Closed(req));
                }
                if q.len < self.shared.queue_cap {
                    break;
                }
                if !block {
                    return Err(SubmitError::QueueFull(req));
                }
                q = self.shared.space.wait(q).unwrap();
            }
            q.len += 1;
            q.peak = q.peak.max(q.len);
        }
        let (etx, erx) = channel();
        let cancelled = Arc::new(AtomicBool::new(false));
        let _ = etx.send(TokenEvent::Queued);
        let id = req.id;
        let sub = Submission {
            req,
            submitted: Instant::now(),
            events: etx,
            cancelled: cancelled.clone(),
        };
        match self.tx.send(Msg::Submit(Box::new(sub))) {
            Ok(()) => Ok(RequestHandle {
                id,
                events: erx,
                cancelled,
                wake: self.tx.clone(),
            }),
            Err(std::sync::mpsc::SendError(msg)) => {
                // the scheduler exited between the queue check and the
                // send: undo the count and report closed
                {
                    let mut q = self.shared.queue.lock().unwrap();
                    q.len -= 1;
                    q.closed = true;
                }
                self.shared.space.notify_all();
                let req = match msg {
                    Msg::Submit(sub) => sub.req,
                    _ => unreachable!("enqueue only sends Submit"),
                };
                Err(SubmitError::Closed(req))
            }
        }
    }

    /// Clone of the scheduler's latest [`Metrics`] snapshot, refreshed
    /// every engine step — counters, gauges (queue depth/peak, KV bytes…)
    /// *and* the latency/queue-wait distributions, which are fixed-size
    /// log-bucket histograms and therefore O(1) to publish live.
    pub fn metrics(&self) -> Metrics {
        self.shared.metrics.lock().unwrap().clone()
    }

    /// Requests currently waiting in the admission queue (live gauge, not
    /// a snapshot).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.lock().unwrap().len
    }

    /// True once the engine stops accepting submissions (shutdown
    /// requested or scheduler exited).
    pub fn is_closed(&self) -> bool {
        self.shared.queue.lock().unwrap().closed
    }
}

/// Streaming handle to one submitted request. Receive [`TokenEvent`]s as
/// the engine produces them, [`Self::cancel`] to stop early, or
/// [`Self::wait`] to block for the final [`Response`]. Dropping the handle
/// without cancelling also releases the request's slot: once the engine
/// notices nobody is listening it finishes the request as
/// [`FinishReason::Cancelled`].
pub struct RequestHandle {
    id: u64,
    events: Receiver<TokenEvent>,
    cancelled: Arc<AtomicBool>,
    wake: Sender<Msg>,
}

impl RequestHandle {
    /// The submitted request's id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Ask the engine to stop this request. The slot is freed on the next
    /// engine step; the terminal event then reports
    /// [`FinishReason::Cancelled`] with the tokens generated so far.
    /// Cancelling a request that already finished is a no-op.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
        let _ = self.wake.send(Msg::Wake);
    }

    /// Block for the next event; `None` once the stream is exhausted
    /// (after `Finished`, or if the engine died).
    pub fn recv(&self) -> Option<TokenEvent> {
        self.events.recv().ok()
    }

    /// Non-blocking [`Self::recv`].
    pub fn try_recv(&self) -> Option<TokenEvent> {
        self.events.try_recv().ok()
    }

    /// Block for the next event at most `timeout`. `Err(Timeout)` means no
    /// event arrived in time (the request is still live — deadline
    /// enforcement can now [`Self::cancel`] and keep draining);
    /// `Err(Disconnected)` means the stream is exhausted, exactly like
    /// [`Self::recv`] returning `None`.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<TokenEvent, RecvTimeoutError> {
        self.events.recv_timeout(timeout)
    }

    /// Drain events until the terminal `Finished` and return its
    /// [`Response`]. Panics if the engine exited without finishing the
    /// request (it never does on the drain paths — only if the scheduler
    /// thread itself panicked).
    pub fn wait(self) -> Response {
        loop {
            match self.events.recv() {
                Ok(TokenEvent::Finished { response, .. }) => return response,
                Ok(_) => {}
                Err(_) => panic!("engine dropped request {} without finishing it", self.id),
            }
        }
    }
}

/// A running engine: the scheduler thread plus its root handle.
///
/// ```text
/// let engine = Engine::start(model, ServerConfig::default());
/// let h = engine.submit(Request::greedy(0, prompt, 16))?;
/// while let Some(ev) = h.recv() { /* Queued/Started/Token/Finished */ }
/// let metrics = engine.shutdown();
/// ```
pub struct Engine {
    handle: EngineHandle,
    join: JoinHandle<()>,
}

impl Engine {
    /// Validate `cfg`, spawn the scheduler thread over `model`'s slot
    /// pool, and return the running engine. The model is shared by `Arc`
    /// so the engine owns its lifetime independent of the caller.
    pub fn start(model: Arc<Model>, cfg: ServerConfig) -> Engine {
        cfg.validate();
        let (handle, rx, shared) = channels(&cfg);
        let join = std::thread::Builder::new()
            .name("bbq-engine".into())
            .spawn(move || EngineCore::new(&model, cfg, rx, shared).run())
            .expect("spawn engine scheduler thread");
        Engine { handle, join }
    }

    /// [`Self::start`] with self-drafting speculative decoding: greedy
    /// requests decode through draft-propose / chunked-verify rounds
    /// (`cfg.spec_k` proposals per round), bit-identical to target-only
    /// greedy decode; temperature > 0 requests take the plain path
    /// untouched. `draft` is typically the same weights under a lower-bit
    /// plan (BFP4 drafting for a BFP6 target).
    pub fn start_with_draft(model: Arc<Model>, draft: Arc<Model>, cfg: ServerConfig) -> Engine {
        cfg.validate();
        let (handle, rx, shared) = channels(&cfg);
        let join = std::thread::Builder::new()
            .name("bbq-engine".into())
            .spawn(move || EngineCore::new_with_draft(&model, Some(&draft), cfg, rx, shared).run())
            .expect("spawn engine scheduler thread");
        Engine { handle, join }
    }

    /// A new [`EngineHandle`] feeding this engine (clone freely; hand to
    /// other threads).
    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Submit on the root handle — see [`EngineHandle::submit`].
    pub fn submit(&self, req: Request) -> Result<RequestHandle, SubmitError> {
        self.handle.submit(req)
    }

    /// Latest metrics snapshot — see [`EngineHandle::metrics`].
    pub fn metrics(&self) -> Metrics {
        self.handle.metrics()
    }

    /// Graceful shutdown: reject new submissions, drain queued and
    /// in-flight requests to completion (every outstanding
    /// [`RequestHandle`] still receives its `Finished` event), join the
    /// scheduler thread, and return the final metrics.
    pub fn shutdown(self) -> Metrics {
        let _ = self.handle.tx.send(Msg::Shutdown);
        self.join.join().expect("engine scheduler thread panicked");
        self.handle.metrics()
    }
}

/// One in-flight sequence occupying an engine slot.
struct Active {
    req: Request,
    /// submission time — latency includes time queued for a slot
    start: Instant,
    rng: Pcg32,
    /// tokens already fed to the model
    fed: usize,
    out: Vec<usize>,
    /// sampled token to feed on the next decode step (prompt rows are fed
    /// directly from `req.prompt` as chunked row-blocks)
    next_input: usize,
    events: Sender<TokenEvent>,
    cancelled: Arc<AtomicBool>,
}

impl Active {
    fn response(&self, reason: FinishReason) -> Response {
        Response {
            id: self.req.id,
            tokens: self.out.clone(),
            latency: self.start.elapsed(),
            prompt_len: self.req.prompt.len(),
            finish: reason,
        }
    }
}

/// Emit the admission events for a sequence entering (or immediately
/// leaving) a slot: `Started`, then one `Token` per token already sampled
/// at admission (only the empty-prompt path samples there).
fn announce(seq: &Active) {
    let _ = seq.events.send(TokenEvent::Started);
    for &t in &seq.out {
        let _ = seq.events.send(TokenEvent::Token(t));
    }
}

/// Admission result: most requests occupy a slot; degenerate ones (empty
/// prompt and at most one token to sample) finish immediately.
enum Admission {
    Run(Box<Active>),
    Done(Box<Active>, FinishReason),
}

fn admit_request(sub: Submission) -> Admission {
    let Submission {
        req,
        submitted,
        events,
        cancelled,
    } = sub;
    let mut seq = Active {
        rng: Pcg32::new(req.params.sampler_seed(req.id)),
        start: submitted,
        fed: 0,
        out: Vec::new(),
        next_input: 0,
        events,
        cancelled,
        req,
    };
    if seq.req.prompt.is_empty() {
        // mirror `serve_one`: with no prompt there are no logits yet, and
        // greedy sampling from an empty logit vector yields token 0
        if seq.req.params.max_new_tokens == 0 {
            return Admission::Done(Box::new(seq), FinishReason::MaxTokens);
        }
        let p = seq.req.params.clone();
        let next = sample_top_k(&[], p.temperature, p.top_k, &mut seq.rng);
        seq.out.push(next);
        seq.next_input = next;
        if p.stop_tokens.contains(&next) {
            return Admission::Done(Box::new(seq), FinishReason::StopToken);
        }
        if seq.out.len() >= p.max_new_tokens {
            return Admission::Done(Box::new(seq), FinishReason::MaxTokens);
        }
    } else {
        seq.next_input = seq.req.prompt[0];
    }
    Admission::Run(Box::new(seq))
}

/// The scheduler's execution backend: a plain batched session, or a
/// draft + target [`SpeculativeSession`] pair when the engine was started
/// with a draft model. Both expose the same slot-pool surface; only the
/// speculative variant supports [`Self::round`].
enum Exec<'m> {
    Plain(BatchedDecodeSession<'m>),
    Spec(SpeculativeSession<'m>),
}

impl<'m> Exec<'m> {
    fn max_context(&self) -> usize {
        match self {
            Exec::Plain(s) => s.max_context(),
            Exec::Spec(s) => s.max_context(),
        }
    }

    fn pos(&self, slot: usize) -> usize {
        match self {
            Exec::Plain(s) => s.pos(slot),
            Exec::Spec(s) => s.pos(slot),
        }
    }

    fn reset_slot(&mut self, slot: usize) {
        match self {
            Exec::Plain(s) => s.reset_slot(slot),
            Exec::Spec(s) => s.reset_slot(slot),
        }
    }

    fn attach_prefix(&mut self, slot: usize, prompt: &[usize]) -> usize {
        match self {
            Exec::Plain(s) => s.attach_prefix(slot, prompt),
            Exec::Spec(s) => s.attach_prefix(slot, prompt),
        }
    }

    fn kv_stats(&self) -> KvStats {
        match self {
            Exec::Plain(s) => s.kv_stats(),
            Exec::Spec(s) => s.kv_stats(),
        }
    }

    fn step_chunked(
        &mut self,
        batch: &[(usize, &[usize])],
        needs_logits: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        match self {
            Exec::Plain(s) => s.step_chunked(batch, needs_logits),
            Exec::Spec(s) => s.step_chunked(batch, needs_logits),
        }
    }

    fn round(&mut self, slot: usize, next: usize, budget: usize) -> Vec<usize> {
        match self {
            Exec::Spec(s) => s.round(slot, next, budget),
            Exec::Plain(_) => unreachable!("speculative round on a plain engine"),
        }
    }

    fn spec_stats(&self) -> Option<SpecStats> {
        match self {
            Exec::Plain(_) => None,
            Exec::Spec(s) => Some(s.spec_stats()),
        }
    }

    fn draft_kv_bytes(&self) -> usize {
        match self {
            Exec::Plain(_) => 0,
            Exec::Spec(s) => s.draft_kv_bytes(),
        }
    }
}

/// The scheduler loop body, generic over the model borrow so it runs both
/// detached over an `Arc<Model>` ([`Engine::start`]) and on a scoped
/// thread over `&Model` ([`super::server::run_batched`]).
pub(crate) struct EngineCore<'m> {
    cfg: ServerConfig,
    exec: Exec<'m>,
    slots: Vec<Option<Box<Active>>>,
    queue: VecDeque<Box<Submission>>,
    rx: Receiver<Msg>,
    shared: Arc<Shared>,
    metrics: Metrics,
    draining: bool,
    disconnected: bool,
}

impl<'m> EngineCore<'m> {
    pub(crate) fn new(
        model: &'m Model,
        cfg: ServerConfig,
        rx: Receiver<Msg>,
        shared: Arc<Shared>,
    ) -> EngineCore<'m> {
        EngineCore::new_with_draft(model, None, cfg, rx, shared)
    }

    pub(crate) fn new_with_draft(
        model: &'m Model,
        draft: Option<&'m Model>,
        cfg: ServerConfig,
        rx: Receiver<Msg>,
        shared: Arc<Shared>,
    ) -> EngineCore<'m> {
        cfg.validate();
        let n = cfg.max_batch;
        let mut metrics = Metrics::new();
        // the prepared weight cache is immutable for the engine's whole
        // lifetime — measure it once, not once per step
        metrics.weight_memory = model.weight_memory();
        let (by_format, outlier_bytes) = model.weight_memory_by_format();
        metrics.weight_bytes_by_format = by_format;
        metrics.outlier_bytes = outlier_bytes;
        metrics.isa = crate::kernels::active().name().to_string();
        let exec = match draft {
            None => Exec::Plain(BatchedDecodeSession::new(model, &cfg.session_config())),
            Some(d) => {
                metrics.draft_weight_memory = d.weight_memory();
                Exec::Spec(SpeculativeSession::new(model, d, &cfg.session_config(), cfg.spec_k))
            }
        };
        EngineCore {
            exec,
            slots: (0..n).map(|_| None).collect(),
            queue: VecDeque::new(),
            metrics,
            draining: false,
            disconnected: false,
            cfg,
            rx,
            shared,
        }
    }

    /// Run the scheduler until shutdown (drained) or every handle is gone.
    pub(crate) fn run(mut self) {
        let t0 = Instant::now();
        loop {
            self.drain_msgs();
            self.reap_cancelled();
            self.admit();
            let stepped = self.step();
            self.publish(t0);
            if stepped {
                continue;
            }
            // nothing in flight: exit if drained, else sleep on the
            // mailbox until new work (or a shutdown) arrives
            if self.idle_exit() {
                break;
            }
            if !self.queue.is_empty() {
                continue; // idle_exit drained a submission — go admit it
            }
            match self.rx.recv() {
                Ok(msg) => self.on_msg(msg),
                Err(_) => self.disconnected = true,
            }
        }
        self.close(t0);
    }

    /// With no active slots: true when the engine should exit — shutdown
    /// was requested or every sender is gone, and no submission can still
    /// be in the pipe. A submit that won the race against `closed` (its
    /// queue-counter increment landed before the flag) keeps the engine
    /// alive until its message arrives.
    fn idle_exit(&mut self) -> bool {
        if !self.draining && !self.disconnected {
            return false;
        }
        self.drain_msgs();
        if !self.queue.is_empty() {
            return false;
        }
        self.disconnected || self.shared.queue.lock().unwrap().len == 0
    }

    fn on_msg(&mut self, msg: Msg) {
        match msg {
            Msg::Submit(sub) => self.queue.push_back(sub),
            Msg::Wake => {}
            Msg::Shutdown => {
                self.draining = true;
                // stop accepting new work immediately; wake blocked
                // submitters so they observe `closed`
                self.shared.queue.lock().unwrap().closed = true;
                self.shared.space.notify_all();
            }
        }
    }

    fn drain_msgs(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok(msg) => self.on_msg(msg),
                Err(TryRecvError::Empty) => return,
                Err(TryRecvError::Disconnected) => {
                    self.disconnected = true;
                    return;
                }
            }
        }
    }

    /// One request left the admission queue: release its backpressure
    /// seat and wake a blocked submitter.
    fn leave_queue(&mut self) {
        self.shared.queue.lock().unwrap().len -= 1;
        self.shared.space.notify_all();
    }

    /// Finish a sequence: account it, then emit the terminal event.
    fn complete(&mut self, seq: Active, reason: FinishReason) {
        let response = seq.response(reason);
        if reason == FinishReason::Cancelled {
            self.metrics.cancelled += 1;
        } else {
            self.metrics.record(response.latency, response.tokens.len());
        }
        let _ = seq.events.send(TokenEvent::Finished { reason, response });
    }

    /// Finish a submission that never reached a slot (cancelled while
    /// queued).
    fn complete_unadmitted(&mut self, sub: Submission) {
        self.metrics.cancelled += 1;
        let response = Response {
            id: sub.req.id,
            tokens: Vec::new(),
            latency: sub.submitted.elapsed(),
            prompt_len: sub.req.prompt.len(),
            finish: FinishReason::Cancelled,
        };
        let reason = FinishReason::Cancelled;
        let _ = sub.events.send(TokenEvent::Finished { reason, response });
    }

    /// Drop cancelled requests: queued ones finish without ever running,
    /// active ones free their slot (and its KV rows) this step.
    fn reap_cancelled(&mut self) {
        for _ in 0..self.queue.len() {
            let sub = self.queue.pop_front().unwrap();
            if sub.cancelled.load(Ordering::SeqCst) {
                self.leave_queue();
                self.complete_unadmitted(*sub);
            } else {
                self.queue.push_back(sub);
            }
        }
        for slot in 0..self.slots.len() {
            let hit = match &self.slots[slot] {
                Some(a) => a.cancelled.load(Ordering::SeqCst),
                None => false,
            };
            if hit {
                let seq = self.slots[slot].take().unwrap();
                self.exec.reset_slot(slot);
                self.complete(*seq, FinishReason::Cancelled);
            }
        }
    }

    /// Admit queued requests into free slots (continuous batching).
    fn admit(&mut self) {
        for slot in 0..self.slots.len() {
            while self.slots[slot].is_none() {
                let Some(sub) = self.queue.pop_front() else {
                    return;
                };
                self.leave_queue();
                if sub.cancelled.load(Ordering::SeqCst) {
                    self.complete_unadmitted(*sub);
                    continue;
                }
                let wait_ms = sub.submitted.elapsed().as_secs_f64() * 1e3;
                self.metrics.queue_wait.record(wait_ms);
                match admit_request(*sub) {
                    Admission::Run(mut seq) => {
                        announce(&seq);
                        self.exec.reset_slot(slot);
                        // prefix-cache lookup: map cached prefill pages for
                        // the longest matching prompt prefix into the slot
                        // and skip feeding those rows (bit-identical reuse;
                        // at least the final prompt row always recomputes,
                        // so admission still ends on a fresh logit row)
                        seq.fed = self.exec.attach_prefix(slot, &seq.req.prompt);
                        self.slots[slot] = Some(seq);
                    }
                    Admission::Done(seq, reason) => {
                        announce(&seq);
                        self.complete(*seq, reason);
                    }
                }
            }
        }
    }

    /// One fused step over every active slot: prefilling slots feed a
    /// chunk of up to `prefill_chunk` prompt rows, decoding slots one row;
    /// the logit mask keeps only each slot's final prompt row and decode
    /// rows (intermediate prompt logits are discarded anyway). On a
    /// speculative engine, greedy decode-phase slots leave the fused batch
    /// and run draft-propose / chunked-verify rounds instead (one round
    /// per slot per step — the verify is itself a chunked multi-row
    /// target step). Returns false when nothing is in flight.
    fn step(&mut self) -> bool {
        let cap = self.exec.max_context();
        let chunk = self.cfg.prefill_chunk;
        let n_slots = self.slots.len();
        let speculative = matches!(self.exec, Exec::Spec(_));
        let mut batch: Vec<(usize, &[usize])> = Vec::with_capacity(n_slots);
        let mut needs_logits: Vec<bool> = Vec::with_capacity(n_slots);
        let mut meta: Vec<(usize, usize)> = Vec::with_capacity(n_slots); // (slot, rows fed)
        let mut spec_slots: Vec<usize> = Vec::new();
        let mut prefill_rows = 0usize;
        for (s, a) in self.slots.iter().enumerate() {
            if let Some(a) = a {
                let plen = a.req.prompt.len();
                if a.fed < plen {
                    let end = (a.fed + chunk).min(plen);
                    batch.push((s, &a.req.prompt[a.fed..end]));
                    needs_logits.extend((a.fed..end).map(|j| j + 1 == plen));
                    meta.push((s, end - a.fed));
                    prefill_rows += end - a.fed;
                } else if speculative && a.req.params.temperature <= 0.0 {
                    // greedy decode on the speculative engine: rounds run
                    // after the fused batch (acceptance is only defined
                    // for argmax decoding; sampled requests stay below)
                    spec_slots.push(s);
                } else {
                    batch.push((s, std::slice::from_ref(&a.next_input)));
                    needs_logits.push(true);
                    meta.push((s, 1));
                }
            }
        }
        if batch.is_empty() && spec_slots.is_empty() {
            return false;
        }
        if !batch.is_empty() {
            let logits = self.exec.step_chunked(&batch, Some(&needs_logits));
            drop(batch); // release the borrow of the slots' prompts
            self.metrics.engine_steps += 1;
            self.metrics.slot_steps += meta.len();
            if prefill_rows > 0 {
                self.metrics.prefill_steps += 1;
                self.metrics.prefill_rows += prefill_rows;
            }
            let mut row0 = 0usize;
            for &(slot, rows) in &meta {
                let last = row0 + rows - 1; // the slot's final row this step
                row0 += rows;
                let seq = self.slots[slot].as_mut().unwrap();
                let was_prefill = seq.fed < seq.req.prompt.len();
                seq.fed += rows;
                if was_prefill {
                    if seq.fed < seq.req.prompt.len() {
                        continue; // still prefilling: every row was masked
                    }
                } else {
                    self.metrics.decode_rows += 1;
                }
                // `last` is the final prompt row (prefill just completed) or
                // the decode row: its logits belong to the newest token
                let max_new = seq.req.params.max_new_tokens;
                let more = seq.out.len() < max_new && self.exec.pos(slot) < cap;
                let finished: Option<FinishReason> = if more {
                    let next = sample_top_k(
                        &logits[last],
                        seq.req.params.temperature,
                        seq.req.params.top_k,
                        &mut seq.rng,
                    );
                    seq.out.push(next);
                    let listener = seq.events.send(TokenEvent::Token(next));
                    if seq.req.params.stop_tokens.contains(&next) {
                        Some(FinishReason::StopToken)
                    } else if seq.out.len() >= max_new {
                        // the final sampled token needs no further forward pass
                        Some(FinishReason::MaxTokens)
                    } else if listener.is_err() {
                        // the RequestHandle was dropped without cancel():
                        // nobody can observe further tokens, so free the slot
                        // exactly like a cancellation
                        Some(FinishReason::Cancelled)
                    } else {
                        seq.next_input = next;
                        None
                    }
                } else if seq.out.len() < max_new {
                    Some(FinishReason::ContextFull)
                } else {
                    Some(FinishReason::MaxTokens)
                };
                if let Some(reason) = finished {
                    let seq = self.slots[slot].take().unwrap();
                    self.exec.reset_slot(slot); // release the KV rows now
                    self.complete(*seq, reason);
                }
            }
        }
        for &slot in &spec_slots {
            self.spec_step_slot(slot, cap);
        }
        true
    }

    /// One speculative round for a greedy decode-phase slot, consuming
    /// every emitted token exactly as the plain path consumes its one
    /// sample per step — same stop-token / max-tokens / dropped-listener
    /// checks in the same order, so the observable stream (and
    /// [`FinishReason`]) is bit-identical to the plain engine's.
    fn spec_step_slot(&mut self, slot: usize, cap: usize) {
        let (next, max_new, out_len) = {
            let seq = self.slots[slot].as_ref().expect("spec slot is active");
            (seq.next_input, seq.req.params.max_new_tokens, seq.out.len())
        };
        let finished: Option<FinishReason> = if out_len < max_new && self.exec.pos(slot) < cap {
            let emitted = self.exec.round(slot, next, max_new - out_len);
            self.metrics.engine_steps += 1;
            self.metrics.slot_steps += 1;
            // committed target decode rows == emitted tokens (the accepted
            // prefix plus the round's correction/bonus row)
            self.metrics.decode_rows += emitted.len();
            let seq = self.slots[slot].as_mut().expect("spec slot is active");
            let mut reason = None;
            for &tok in &emitted {
                seq.out.push(tok);
                let listener = seq.events.send(TokenEvent::Token(tok));
                if seq.req.params.stop_tokens.contains(&tok) {
                    reason = Some(FinishReason::StopToken);
                    break;
                }
                if seq.out.len() >= max_new {
                    reason = Some(FinishReason::MaxTokens);
                    break;
                }
                if listener.is_err() {
                    reason = Some(FinishReason::Cancelled);
                    break;
                }
                seq.next_input = tok;
            }
            reason
        } else if out_len < max_new {
            Some(FinishReason::ContextFull)
        } else {
            Some(FinishReason::MaxTokens)
        };
        if let Some(reason) = finished {
            let seq = self.slots[slot].take().unwrap();
            self.exec.reset_slot(slot); // release both stores' KV rows now
            self.complete(*seq, reason);
        }
    }

    /// Refresh the shared metrics snapshot so `EngineHandle::metrics`
    /// observes live state. The whole struct is published every step —
    /// since the per-request distributions became fixed-size log-bucket
    /// histograms this is O(1) per step, so mid-flight snapshots now carry
    /// live latency/queue-wait percentiles too (they used to be
    /// shutdown-only, when the distributions were per-request vectors).
    fn publish(&mut self, t0: Instant) {
        {
            let q = self.shared.queue.lock().unwrap();
            self.metrics.queue_depth = q.len;
            self.metrics.queue_peak = q.peak;
        }
        let kv = self.exec.kv_stats();
        self.metrics.kv_bytes = kv.bytes();
        self.metrics.kv_bytes_f32 = kv.bytes_f32;
        self.metrics.kv_bytes_packed = kv.bytes_packed;
        self.metrics.kv_cached_bytes = kv.cache_bytes;
        self.metrics.kv_pages = kv.pages;
        self.metrics.kv_pages_shared = kv.pages_shared;
        self.metrics.prefix_lookups = kv.prefix_lookups;
        self.metrics.prefix_hits = kv.prefix_hits;
        self.metrics.prefix_hit_rows = kv.prefix_hit_rows;
        if let Some(spec) = self.exec.spec_stats() {
            self.metrics.spec_rounds = spec.rounds;
            self.metrics.spec_proposed = spec.proposed;
            self.metrics.spec_accepted = spec.accepted;
            self.metrics.spec_rejected = spec.rejected;
            self.metrics.spec_fallback_steps = spec.fallback_steps;
            self.metrics.draft_kv_bytes = self.exec.draft_kv_bytes();
        }
        self.metrics.wall = t0.elapsed();
        *self.shared.metrics.lock().unwrap() = self.metrics.clone();
    }

    /// Publish the final metrics and reject any submitter still blocked.
    fn close(&mut self, t0: Instant) {
        self.publish(t0);
        self.shared.queue.lock().unwrap().closed = true;
        self.shared.space.notify_all();
    }
}
