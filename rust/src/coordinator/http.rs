//! Zero-dependency HTTP/1.1 front door over the serving stack.
//!
//! [`HttpServer`] listens on a [`std::net::TcpListener`] and fronts the
//! engines registered with a [`super::router::Router`] — the network
//! surface that turns the paper's arithmetic-density claim into a serving
//! claim (traffic over a wire, latency SLOs under load, see
//! `coordinator/traffic.rs`). The server is hand-rolled on the standard
//! library: blocking accept loop, one thread per connection, HTTP/1.1
//! keep-alive, chunk-free bodies framed by `Content-Length`.
//!
//! ## Endpoints
//!
//! - `POST /v1/generate` — JSON body → [`Request`] +
//!   [`super::router::Priority`] + optional deadline. With `"stream":
//!   true` the response is Server-Sent Events mirroring the engine's
//!   [`TokenEvent`] stream (`queued`, `started`, one `token` per sampled
//!   token, a terminal `done` carrying the full response JSON); otherwise
//!   a single JSON document once generation finishes.
//! - `GET /v1/metrics` — live [`super::metrics::Metrics`] snapshot per
//!   registered model (p50/p99 latency and queue-wait straight from the
//!   engine's [`LogHistogram`]s) plus per-class router counters.
//! - `GET /healthz` — liveness (reports `draining: true` once shutdown
//!   begins).
//!
//! ## Deadlines and cancellation
//!
//! A request's `deadline_ms` covers queueing *and* generation. If it
//! expires while the request waits for admission, the request is
//! abandoned (the engine reaps it as cancelled the moment it is
//! dispatched) and the client receives an empty response with finish
//! reason `"cancelled"`. If it expires mid-generation the connection
//! handler calls [`RequestHandle::cancel`] and keeps draining, so the
//! terminal event — and therefore the client's response — carries the
//! tokens generated so far with finish reason `"cancelled"`. A client
//! that stops reading its SSE stream is handled the same way: the write
//! fails (or times out), the handler cancels, and the slot frees on the
//! next engine step. Event channels are unbounded, so a slow reader only
//! ever stalls its own connection thread, never a co-resident slot.
//!
//! ## Validation
//!
//! The front door is the trust boundary: prompts are checked against the
//! served model's vocabulary size and context window (see
//! [`super::router::ModelEntry`]) before submission, because an
//! out-of-range token id would panic the scheduler thread it reaches.
//! Oversized bodies are refused with 413 before reading, malformed
//! request lines and bodies with 400, unknown routes with 404.
//!
//! ## Shutdown
//!
//! [`HttpServer::shutdown`] stops the accept loop and waits (bounded by
//! [`HttpConfig::drain_wait`]) for in-flight connections to finish. The
//! full graceful-drain order — used by `bbq serve` on SIGTERM via
//! [`shutdown_signal`] — is HTTP server first (stop taking traffic),
//! then [`super::router::Router::shutdown`] (dispatch everything already
//! accepted), then [`super::engine::Engine::shutdown`] (drain queued and
//! in-flight requests to completion), so every admitted request still
//! receives its terminal event.

use super::engine::{RequestHandle, SubmitError, TokenEvent};
use super::metrics::LogHistogram;
use super::router::{Priority, RouteError, RouterHandle, Ticket};
use super::server::{FinishReason, GenerationParams, Request, Response};
use crate::util::json::Json;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Longest accepted request/header line, bytes.
const MAX_LINE: usize = 8 * 1024;
/// Most headers accepted on one request.
const MAX_HEADERS: usize = 64;
/// First auto-assigned request id (client-supplied ids normally stay
/// below this, keeping the default sampler seeds disjoint).
const AUTO_ID_BASE: u64 = 1 << 32;

/// HTTP front-door limits and timeouts.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Largest accepted request body; anything bigger is refused with 413
    /// before reading.
    pub max_body_bytes: usize,
    /// Socket read timeout (also bounds how long an idle keep-alive
    /// connection is held open).
    pub read_timeout: Duration,
    /// Socket write timeout: a client that stops reading its (SSE)
    /// response for this long gets its request cancelled.
    pub write_timeout: Duration,
    /// How long [`HttpServer::shutdown`] waits for in-flight connections
    /// to finish before giving up on stragglers.
    pub drain_wait: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            max_body_bytes: 1 << 20,
            read_timeout: Duration::from_secs(60),
            write_timeout: Duration::from_secs(30),
            drain_wait: Duration::from_secs(10),
        }
    }
}

/// SIGTERM/SIGINT latch for graceful drain, with no libc dependency: the
/// handler only flips an [`AtomicBool`] (async-signal-safe), which the
/// serve loop polls between metric ticks. [`trigger`] flips the same
/// latch from code — tests and programmatic shutdown use it, and on
/// non-Unix targets (where [`install`] is a no-op) it is the only source.
///
/// [`trigger`]: shutdown_signal::trigger
/// [`install`]: shutdown_signal::install
pub mod shutdown_signal {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TRIGGERED: AtomicBool = AtomicBool::new(false);

    #[cfg(unix)]
    extern "C" fn latch(_signum: i32) {
        TRIGGERED.store(true, Ordering::SeqCst);
    }

    /// Install the latch for SIGTERM and SIGINT (no-op off Unix).
    #[cfg(unix)]
    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        let handler = latch as extern "C" fn(i32) as usize;
        unsafe {
            signal(SIGTERM, handler);
            signal(SIGINT, handler);
        }
    }

    /// Install the latch for SIGTERM and SIGINT (no-op off Unix).
    #[cfg(not(unix))]
    pub fn install() {}

    /// True once a shutdown signal (or [`trigger`]) has fired.
    pub fn triggered() -> bool {
        TRIGGERED.load(Ordering::SeqCst)
    }

    /// Flip the latch from code, exactly as a signal would.
    pub fn trigger() {
        TRIGGERED.store(true, Ordering::SeqCst);
    }
}

struct ServerShared {
    router: RouterHandle,
    cfg: HttpConfig,
    next_id: AtomicU64,
    open: Mutex<usize>,
    idle: Condvar,
    draining: AtomicBool,
}

impl ServerShared {
    fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }
}

/// Decrements the open-connection gauge when a connection thread exits —
/// held across the handler so panics unwind through it too.
struct ConnGuard(Arc<ServerShared>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        let mut open = self.0.open.lock().unwrap();
        *open -= 1;
        self.0.idle.notify_all();
    }
}

/// A running HTTP front door: accept loop plus one thread per live
/// connection, all submitting through a shared [`RouterHandle`].
pub struct HttpServer {
    shared: Arc<ServerShared>,
    addr: SocketAddr,
    accept: JoinHandle<()>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, port 0 for ephemeral) and
    /// start serving `router`'s engines.
    pub fn bind(addr: &str, router: RouterHandle, cfg: HttpConfig) -> io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(ServerShared {
            router,
            cfg,
            next_id: AtomicU64::new(AUTO_ID_BASE),
            open: Mutex::new(0),
            idle: Condvar::new(),
            draining: AtomicBool::new(false),
        });
        let accept_shared = shared.clone();
        let accept = std::thread::Builder::new()
            .name("bbq-http".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if accept_shared.draining() {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    *accept_shared.open.lock().unwrap() += 1;
                    let conn_shared = accept_shared.clone();
                    let spawned = std::thread::Builder::new()
                        .name("bbq-http-conn".into())
                        .spawn(move || {
                            let _guard = ConnGuard(conn_shared.clone());
                            let _ = serve_conn(stream, &conn_shared);
                        });
                    if spawned.is_err() {
                        let mut open = accept_shared.open.lock().unwrap();
                        *open -= 1;
                    }
                }
            })
            .expect("spawn http accept thread");
        Ok(HttpServer {
            shared,
            addr: local,
            accept,
        })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections and wait — bounded by
    /// [`HttpConfig::drain_wait`] — for in-flight ones to finish. Shut
    /// the router and engines down *after* this so already-admitted
    /// requests still stream their terminal events.
    pub fn shutdown(self) {
        self.shared.draining.store(true, Ordering::SeqCst);
        // the accept loop is blocked in accept(): poke it awake
        let _ = TcpStream::connect(self.addr);
        let _ = self.accept.join();
        let deadline = Instant::now() + self.shared.cfg.drain_wait;
        let mut open = self.shared.open.lock().unwrap();
        while *open > 0 {
            let now = Instant::now();
            if now >= deadline {
                break; // stragglers keep their sockets; we stop waiting
            }
            let (guard, _) = self.shared.idle.wait_timeout(open, deadline - now).unwrap();
            open = guard;
        }
    }
}

/// A parsed and validated `POST /v1/generate` body.
struct GenerateSpec {
    req: Request,
    priority: Priority,
    deadline: Option<Duration>,
    stream: bool,
}

/// Read one `\r\n`- (or `\n`-) terminated line, rejecting anything longer
/// than `cap`. `None` is clean EOF before any byte.
fn read_limited_line(r: &mut impl BufRead, cap: usize) -> io::Result<Option<String>> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                return Ok(Some(String::from_utf8_lossy(&buf).into_owned()));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    return Ok(Some(String::from_utf8_lossy(&buf).into_owned()));
                }
                if byte[0] != b'\r' {
                    buf.push(byte[0]);
                }
                if buf.len() > cap {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "request line too long",
                    ));
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// `"METHOD /path HTTP/1.x"` → `(method, path)` with any query string
/// stripped; `None` on anything else.
fn parse_request_line(line: &str) -> Option<(&str, &str)> {
    let mut parts = line.split_whitespace();
    let method = parts.next()?;
    let target = parts.next()?;
    let version = parts.next()?;
    if parts.next().is_some() || !version.starts_with("HTTP/1.") || !target.starts_with('/') {
        return None;
    }
    let path = target.split('?').next().unwrap_or(target);
    Some((method, path))
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

fn write_json(w: &mut TcpStream, status: u16, reason: &str, body: &str, keep: bool) -> io::Result<()> {
    let conn = if keep { "keep-alive" } else { "close" };
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
        body.len()
    )?;
    w.flush()
}

fn sse_event(w: &mut TcpStream, name: &str, data: &str) -> io::Result<()> {
    write!(w, "event: {name}\ndata: {data}\n\n")?;
    w.flush()
}

/// Serialise a [`Response`] to its wire JSON (`finish` uses
/// [`FinishReason::as_str`]).
pub fn response_json(r: &Response) -> Json {
    Json::obj(vec![
        ("id", Json::Num(r.id as f64)),
        ("tokens", Json::arr_usize(&r.tokens)),
        ("prompt_len", Json::Num(r.prompt_len as f64)),
        ("finish", Json::Str(r.finish.as_str().to_string())),
        ("latency_ms", Json::Num(r.latency.as_secs_f64() * 1e3)),
    ])
}

/// Serialise a [`LogHistogram`] summary (`count`/`mean`/`p50`/`p99`/
/// `max`, milliseconds) — the shape `/v1/metrics` and `BENCH_serve.json`
/// share.
pub fn hist_json(h: &LogHistogram) -> Json {
    Json::obj(vec![
        ("count", Json::Num(h.count() as f64)),
        ("mean", Json::Num(h.mean())),
        ("p50", Json::Num(h.percentile(50.0))),
        ("p99", Json::Num(h.percentile(99.0))),
        ("max", Json::Num(h.max())),
    ])
}

fn arr_u64(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// The `GET /v1/metrics` document: one entry per registered model with
/// the engine's live counters and latency/queue-wait percentiles, plus
/// the router's per-class admission counters and the process-wide
/// kernel ISA backend.
pub fn metrics_json(router: &RouterHandle) -> Json {
    let models: Vec<Json> = router
        .entries()
        .iter()
        .map(|e| {
            let m = e.handle.metrics();
            Json::obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("completed", Json::Num(m.completed as f64)),
                ("generated_tokens", Json::Num(m.generated_tokens as f64)),
                ("throughput_tps", Json::Num(m.throughput_tps())),
                ("cancelled", Json::Num(m.cancelled as f64)),
                ("queue_depth", Json::Num(e.handle.queue_depth() as f64)),
                ("queue_peak", Json::Num(m.queue_peak as f64)),
                ("latency_ms", hist_json(&m.latency)),
                ("queue_wait_ms", hist_json(&m.queue_wait)),
                ("kv_bytes", Json::Num(m.kv_bytes as f64)),
                ("kv_bytes_f32", Json::Num(m.kv_bytes_f32 as f64)),
                ("kv_bytes_packed", Json::Num(m.kv_bytes_packed as f64)),
                ("kv_cached_bytes", Json::Num(m.kv_cached_bytes as f64)),
                ("kv_pages", Json::Num(m.kv_pages as f64)),
                ("kv_pages_shared", Json::Num(m.kv_pages_shared as f64)),
                ("prefix_hit_rate", Json::Num(m.prefix_hit_rate())),
                ("prefix_hit_rows", Json::Num(m.prefix_hit_rows as f64)),
                ("weight_dense_f32_bytes", Json::Num(m.weight_memory.dense_f32_bytes as f64)),
                ("weight_resident_bytes", Json::Num(m.weight_memory.resident_bytes as f64)),
                (
                    "weights_by_format",
                    Json::Obj(
                        m.weight_bytes_by_format
                            .iter()
                            .map(|(name, bytes)| (name.clone(), Json::Num(*bytes as f64)))
                            .collect(),
                    ),
                ),
                ("outlier_bytes", Json::Num(m.outlier_bytes as f64)),
                ("spec_rounds", Json::Num(m.spec_rounds as f64)),
                ("spec_accept_rate", Json::Num(m.spec_acceptance_rate())),
                ("spec_tok_per_step", Json::Num(m.spec_tokens_per_target_step())),
                ("draft_kv_bytes", Json::Num(m.draft_kv_bytes as f64)),
                (
                    "draft_weight_resident_bytes",
                    Json::Num(m.draft_weight_memory.resident_bytes as f64),
                ),
                ("isa", Json::Str(m.isa.clone())),
            ])
        })
        .collect();
    let stats = router.stats();
    Json::obj(vec![
        (
            "isa",
            Json::Str(crate::kernels::active().name().to_string()),
        ),
        ("models", Json::Arr(models)),
        (
            "router",
            Json::obj(vec![
                ("queued", Json::arr_usize(&stats.queued)),
                ("submitted", arr_u64(&stats.submitted)),
                ("dispatched", arr_u64(&stats.dispatched)),
                ("rejected", arr_u64(&stats.rejected)),
            ]),
        ),
    ])
}

/// A JSON number that is a non-negative integer fitting `usize` (token
/// ids, counts). Rejects fractions, negatives, non-numbers.
fn num_usize(v: &Json) -> Option<usize> {
    let x = v.as_f64()?;
    if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= u32::MAX as f64 {
        Some(x as usize)
    } else {
        None
    }
}

/// A JSON number that is a non-negative integer exactly representable in
/// f64 (request ids, seeds).
fn num_u64(v: &Json) -> Option<u64> {
    let x = v.as_f64()?;
    if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= 9.007_199_254_740_992e15 {
        Some(x as u64)
    } else {
        None
    }
}

/// Validate a generate body against the served model's bounds and build
/// the [`Request`]. Every error string becomes a 400 response body.
fn parse_generate(
    j: &Json,
    vocab_size: usize,
    max_seq: usize,
    auto_id: u64,
) -> Result<GenerateSpec, String> {
    let id = match j.get("id") {
        None => auto_id,
        Some(v) => num_u64(v).ok_or("\"id\" must be a non-negative integer")?,
    };
    let prompt_json = j.get("prompt").ok_or("missing \"prompt\"")?;
    let arr = prompt_json
        .as_arr()
        .ok_or("\"prompt\" must be an array of token ids")?;
    if arr.len() > max_seq {
        return Err(format!(
            "prompt length {} exceeds context window {max_seq}",
            arr.len()
        ));
    }
    let mut prompt = Vec::with_capacity(arr.len());
    for v in arr {
        let t = num_usize(v).ok_or("prompt tokens must be non-negative integers")?;
        if t >= vocab_size {
            return Err(format!(
                "prompt token {t} out of range (vocabulary size {vocab_size})"
            ));
        }
        prompt.push(t);
    }
    let mut params = GenerationParams::default();
    if let Some(v) = j.get("max_new_tokens") {
        params.max_new_tokens =
            num_usize(v).ok_or("\"max_new_tokens\" must be a non-negative integer")?;
    }
    if let Some(v) = j.get("temperature") {
        let t = v.as_f64().ok_or("\"temperature\" must be a number")?;
        if !t.is_finite() {
            return Err("\"temperature\" must be finite".into());
        }
        params.temperature = t as f32;
    }
    if let Some(v) = j.get("top_k") {
        params.top_k = num_usize(v).ok_or("\"top_k\" must be a non-negative integer")?;
    }
    if let Some(v) = j.get("stop_tokens") {
        let stops = v.as_arr().ok_or("\"stop_tokens\" must be an array")?;
        params.stop_tokens = stops
            .iter()
            .map(num_usize)
            .collect::<Option<Vec<usize>>>()
            .ok_or("stop tokens must be non-negative integers")?;
    }
    if let Some(v) = j.get("seed") {
        params.seed = Some(num_u64(v).ok_or("\"seed\" must be a non-negative integer")?);
    }
    let priority = match j.get("priority") {
        None => Priority::Standard,
        Some(v) => {
            let s = v.as_str().ok_or("\"priority\" must be a string")?;
            Priority::parse(s).ok_or_else(|| format!("unknown priority \"{s}\""))?
        }
    };
    let deadline = match j.get("deadline_ms") {
        None => None,
        Some(v) => {
            let ms = v.as_f64().ok_or("\"deadline_ms\" must be a number")?;
            if !ms.is_finite() || ms <= 0.0 {
                return Err("\"deadline_ms\" must be positive".into());
            }
            Some(Duration::from_millis((ms as u64).max(1)))
        }
    };
    let stream = match j.get("stream") {
        None => false,
        Some(v) => v.as_bool().ok_or("\"stream\" must be a boolean")?,
    };
    Ok(GenerateSpec {
        req: Request { id, prompt, params },
        priority,
        deadline,
        stream,
    })
}

/// One connection's keep-alive loop. Any `Err` drops the connection (the
/// peer vanished or broke framing); clean EOF returns `Ok`.
fn serve_conn(stream: TcpStream, shared: &ServerShared) -> io::Result<()> {
    let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let line = match read_limited_line(&mut reader, MAX_LINE) {
            Ok(None) => return Ok(()),
            Ok(Some(l)) => l,
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                let _ = write_json(&mut writer, 400, "Bad Request", &err_json("line too long"), false);
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        if line.is_empty() {
            continue; // tolerate stray CRLFs between pipelined requests
        }
        let Some((method, path)) = parse_request_line(&line) else {
            write_json(
                &mut writer,
                400,
                "Bad Request",
                &err_json("malformed request line"),
                false,
            )?;
            return Ok(());
        };
        let method = method.to_string();
        let path = path.to_string();
        let mut content_length = 0usize;
        let mut close = shared.draining();
        let mut header_error: Option<&'static str> = None;
        let mut n_headers = 0usize;
        loop {
            let header = match read_limited_line(&mut reader, MAX_LINE) {
                Ok(None) => return Ok(()), // peer vanished mid-headers
                Ok(Some(h)) => h,
                Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                    let _ =
                        write_json(&mut writer, 400, "Bad Request", &err_json("header too long"), false);
                    return Ok(());
                }
                Err(e) => return Err(e),
            };
            if header.is_empty() {
                break;
            }
            n_headers += 1;
            if n_headers > MAX_HEADERS {
                header_error = Some("too many headers");
                continue;
            }
            let Some((name, value)) = header.split_once(':') else {
                header_error = Some("malformed header");
                continue;
            };
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim();
            if name == "content-length" {
                match value.parse::<usize>() {
                    Ok(n) => content_length = n,
                    Err(_) => header_error = Some("bad content-length"),
                }
            } else if name == "connection" && value.eq_ignore_ascii_case("close") {
                close = true;
            }
        }
        if let Some(msg) = header_error {
            write_json(&mut writer, 400, "Bad Request", &err_json(msg), false)?;
            return Ok(());
        }
        if content_length > shared.cfg.max_body_bytes {
            // refuse before reading; framing is now unknown, so close
            write_json(
                &mut writer,
                413,
                "Payload Too Large",
                &err_json("body exceeds limit"),
                false,
            )?;
            return Ok(());
        }
        let mut body = vec![0u8; content_length];
        if content_length > 0 {
            if let Err(e) = reader.read_exact(&mut body) {
                // truncated body: answer best-effort, then drop the conn
                let _ = write_json(&mut writer, 400, "Bad Request", &err_json("truncated body"), false);
                return Err(e);
            }
        }
        let keep = dispatch(&mut writer, shared, &method, &path, &body, !close)?;
        if !keep {
            return Ok(());
        }
    }
}

/// Route one parsed request; returns whether to keep the connection.
fn dispatch(
    w: &mut TcpStream,
    shared: &ServerShared,
    method: &str,
    path: &str,
    body: &[u8],
    keep: bool,
) -> io::Result<bool> {
    match (method, path) {
        ("GET", "/healthz") => {
            let doc = Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("draining", Json::Bool(shared.draining())),
            ]);
            write_json(w, 200, "OK", &doc.to_string(), keep)?;
            Ok(keep)
        }
        ("GET", "/v1/metrics") => {
            write_json(w, 200, "OK", &metrics_json(&shared.router).to_string(), keep)?;
            Ok(keep)
        }
        ("POST", "/v1/generate") => generate(w, shared, body, keep),
        (_, "/healthz") | (_, "/v1/metrics") | (_, "/v1/generate") => {
            write_json(
                w,
                405,
                "Method Not Allowed",
                &err_json("method not allowed"),
                keep,
            )?;
            Ok(keep)
        }
        _ => {
            write_json(w, 404, "Not Found", &err_json("unknown route"), keep)?;
            Ok(keep)
        }
    }
}

/// Handle `POST /v1/generate`: validate, submit through the router, then
/// stream SSE or block for the single JSON response.
fn generate(w: &mut TcpStream, shared: &ServerShared, body: &[u8], keep: bool) -> io::Result<bool> {
    let Ok(text) = std::str::from_utf8(body) else {
        write_json(w, 400, "Bad Request", &err_json("body is not UTF-8"), keep)?;
        return Ok(keep);
    };
    let parsed = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            write_json(w, 400, "Bad Request", &err_json(&format!("bad JSON: {e}")), keep)?;
            return Ok(keep);
        }
    };
    let model = parsed
        .get("model")
        .and_then(|m| m.as_str())
        .map(|s| s.to_string());
    let Some(entry) = shared.router.entry(model.as_deref()) else {
        write_json(w, 404, "Not Found", &err_json("unknown model"), keep)?;
        return Ok(keep);
    };
    let (vocab_size, max_seq) = (entry.vocab_size, entry.max_seq);
    let auto_id = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let spec = match parse_generate(&parsed, vocab_size, max_seq, auto_id) {
        Ok(s) => s,
        Err(msg) => {
            write_json(w, 400, "Bad Request", &err_json(&msg), keep)?;
            return Ok(keep);
        }
    };
    let id = spec.req.id;
    let prompt_len = spec.req.prompt.len();
    let deadline = spec.deadline.map(|d| Instant::now() + d);
    let submitted = Instant::now();
    let ticket = match shared.router.submit(model.as_deref(), spec.priority, spec.req) {
        Ok(t) => t,
        Err(RouteError::ClassFull(_)) => {
            write_json(
                w,
                429,
                "Too Many Requests",
                &err_json("priority class queue full"),
                keep,
            )?;
            return Ok(keep);
        }
        Err(RouteError::UnknownModel(_)) => {
            write_json(w, 404, "Not Found", &err_json("unknown model"), keep)?;
            return Ok(keep);
        }
        Err(RouteError::Closed(_)) => {
            write_json(w, 503, "Service Unavailable", &err_json("server draining"), keep)?;
            return Ok(keep);
        }
    };
    if spec.stream {
        stream_sse(w, ticket, id, prompt_len, deadline, submitted)?;
        Ok(false) // SSE responses always close the connection
    } else {
        respond_once(w, ticket, id, prompt_len, deadline, submitted, keep)
    }
}

/// The synthetic response for a request whose deadline expired before it
/// was ever dispatched to an engine.
fn queued_cancel_response(id: u64, prompt_len: usize, submitted: Instant) -> Response {
    Response {
        id,
        tokens: Vec::new(),
        latency: submitted.elapsed(),
        prompt_len,
        finish: FinishReason::Cancelled,
    }
}

fn engine_gone() -> io::Error {
    io::Error::other("engine dropped the request")
}

/// Pump a dispatched request's event stream to the terminal `Finished`,
/// enforcing `deadline` by cancelling and continuing to drain (the
/// terminal response then carries the partial output). `sink` observes
/// every event; a sink failure cancels the request and aborts.
fn drive(
    handle: RequestHandle,
    deadline: Option<Instant>,
    sink: &mut dyn FnMut(&TokenEvent) -> io::Result<()>,
) -> io::Result<Response> {
    let mut expired = false;
    loop {
        let ev = if expired {
            // already cancelled: the terminal event arrives promptly
            match handle.recv() {
                Some(ev) => ev,
                None => return Err(engine_gone()),
            }
        } else if let Some(d) = deadline {
            let now = Instant::now();
            if now >= d {
                handle.cancel();
                expired = true;
                continue;
            }
            match handle.recv_timeout(d - now) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    handle.cancel();
                    expired = true;
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => return Err(engine_gone()),
            }
        } else {
            match handle.recv() {
                Some(ev) => ev,
                None => return Err(engine_gone()),
            }
        };
        if let TokenEvent::Finished { response, .. } = &ev {
            let response = response.clone();
            sink(&ev)?;
            return Ok(response);
        }
        if sink(&ev).is_err() {
            // client stopped reading: free the slot, drop the stream
            handle.cancel();
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "client write failed"));
        }
    }
}

/// Non-streaming generate: block until the terminal event, answer with
/// one JSON document.
#[allow(clippy::too_many_arguments)]
fn respond_once(
    w: &mut TcpStream,
    ticket: Ticket,
    id: u64,
    prompt_len: usize,
    deadline: Option<Instant>,
    submitted: Instant,
    keep: bool,
) -> io::Result<bool> {
    let handle = match ticket.wait_until(deadline) {
        None => {
            let resp = queued_cancel_response(id, prompt_len, submitted);
            write_json(w, 200, "OK", &response_json(&resp).to_string(), keep)?;
            return Ok(keep);
        }
        Some(Ok(h)) => h,
        Some(Err(SubmitError::Closed(_))) | Some(Err(SubmitError::QueueFull(_))) => {
            write_json(w, 503, "Service Unavailable", &err_json("engine closed"), keep)?;
            return Ok(keep);
        }
    };
    let resp = drive(handle, deadline, &mut |_| Ok(()))?;
    write_json(w, 200, "OK", &response_json(&resp).to_string(), keep)?;
    Ok(keep)
}

/// Streaming generate: SSE events `queued`, `started`, `token`…, and a
/// terminal `done` carrying the full response JSON (or `error` if the
/// engine refused the dispatch).
fn stream_sse(
    w: &mut TcpStream,
    ticket: Ticket,
    id: u64,
    prompt_len: usize,
    deadline: Option<Instant>,
    submitted: Instant,
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
    )?;
    w.flush()?;
    let id_doc = Json::obj(vec![("id", Json::Num(id as f64))]).to_string();
    let handle = match ticket.wait_until(deadline) {
        None => {
            let resp = queued_cancel_response(id, prompt_len, submitted);
            return sse_event(w, "done", &response_json(&resp).to_string());
        }
        Some(Ok(h)) => h,
        Some(Err(e)) => return sse_event(w, "error", &err_json(&e.to_string())),
    };
    let resp = drive(handle, deadline, &mut |ev| match ev {
        TokenEvent::Queued => sse_event(w, "queued", &id_doc),
        TokenEvent::Started => sse_event(w, "started", &id_doc),
        TokenEvent::Token(t) => sse_event(
            w,
            "token",
            &Json::obj(vec![("token", Json::Num(*t as f64))]).to_string(),
        ),
        TokenEvent::Finished { .. } => Ok(()),
    })?;
    sse_event(w, "done", &response_json(&resp).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_line_grammar() {
        assert_eq!(
            parse_request_line("GET /healthz HTTP/1.1"),
            Some(("GET", "/healthz"))
        );
        assert_eq!(
            parse_request_line("POST /v1/generate?x=1 HTTP/1.0"),
            Some(("POST", "/v1/generate"))
        );
        assert_eq!(parse_request_line("GARBAGE"), None);
        assert_eq!(parse_request_line("GET /x HTTP/2"), None);
        assert_eq!(parse_request_line("GET noslash HTTP/1.1"), None);
        assert_eq!(parse_request_line("GET /x HTTP/1.1 extra"), None);
        assert_eq!(parse_request_line(""), None);
    }

    #[test]
    fn generate_body_validation() {
        let parse = |s: &str| parse_generate(&Json::parse(s).unwrap(), 512, 256, 7);
        // defaults
        let spec = parse(r#"{"prompt": [1, 2, 3]}"#).unwrap();
        assert_eq!(spec.req.id, 7);
        assert_eq!(spec.req.prompt, vec![1, 2, 3]);
        assert_eq!(spec.req.params.max_new_tokens, 16);
        assert_eq!(spec.req.params.temperature, 0.0);
        assert!(spec.req.params.seed.is_none());
        assert_eq!(spec.priority, Priority::Standard);
        assert!(spec.deadline.is_none());
        assert!(!spec.stream);
        // everything set
        let spec = parse(
            r#"{"id": 9, "prompt": [0, 511], "max_new_tokens": 4, "temperature": 0.9,
                "top_k": 8, "stop_tokens": [5], "seed": 42, "priority": "interactive",
                "deadline_ms": 250, "stream": true}"#,
        )
        .unwrap();
        assert_eq!(spec.req.id, 9);
        assert_eq!(spec.req.params.max_new_tokens, 4);
        assert_eq!(spec.req.params.top_k, 8);
        assert_eq!(spec.req.params.stop_tokens, vec![5]);
        assert_eq!(spec.req.params.seed, Some(42));
        assert_eq!(spec.priority, Priority::Interactive);
        assert_eq!(spec.deadline, Some(Duration::from_millis(250)));
        assert!(spec.stream);
        // the trust boundary: bounds and types are enforced here
        assert!(parse(r#"{}"#).is_err(), "prompt required");
        assert!(parse(r#"{"prompt": "hi"}"#).is_err(), "prompt must be array");
        assert!(parse(r#"{"prompt": [512]}"#).is_err(), "token >= vocab");
        assert!(parse(r#"{"prompt": [-1]}"#).is_err(), "negative token");
        assert!(parse(r#"{"prompt": [1.5]}"#).is_err(), "fractional token");
        assert!(parse(r#"{"prompt": [1], "priority": "bulk"}"#).is_err());
        assert!(parse(r#"{"prompt": [1], "deadline_ms": -5}"#).is_err());
        assert!(parse(r#"{"prompt": [1], "stream": 1}"#).is_err());
        assert!(parse(r#"{"prompt": [1], "seed": -2}"#).is_err());
        let long = format!("{{\"prompt\": [{}]}}", vec!["1"; 257].join(","));
        assert!(parse(&long).is_err(), "prompt longer than max_seq");
    }

    #[test]
    fn response_wire_format_roundtrips() {
        let resp = Response {
            id: 5,
            tokens: vec![1, 2, 3],
            latency: Duration::from_millis(12),
            prompt_len: 2,
            finish: FinishReason::MaxTokens,
        };
        let j = Json::parse(&response_json(&resp).to_string()).unwrap();
        assert_eq!(j.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("tokens").unwrap().usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(j.get("prompt_len").unwrap().as_f64(), Some(2.0));
        let finish = j.get("finish").unwrap().as_str().unwrap();
        assert_eq!(FinishReason::parse(finish), Some(FinishReason::MaxTokens));
        assert!(j.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn numeric_bounds() {
        assert_eq!(num_usize(&Json::Num(3.0)), Some(3));
        assert_eq!(num_usize(&Json::Num(-1.0)), None);
        assert_eq!(num_usize(&Json::Num(1.5)), None);
        assert_eq!(num_usize(&Json::Num(f64::NAN)), None);
        assert_eq!(num_usize(&Json::Str("3".into())), None);
        assert_eq!(num_u64(&Json::Num(2.0_f64.powi(53))), Some(1 << 53));
        assert_eq!(num_u64(&Json::Num(2.0_f64.powi(54))), None);
    }

    #[test]
    fn shutdown_signal_latch() {
        shutdown_signal::install(); // must not crash; handler is a no-op here
        shutdown_signal::trigger();
        assert!(shutdown_signal::triggered());
    }
}
