//! Experiment orchestration: shared model zoo (train once, reuse across
//! experiments), result persistence (markdown + CSV + JSON), and the
//! common "evaluate a set of methods over the model ladder" loop.

use crate::data::corpus::train_stream;
use crate::data::vocab::Vocab;
use crate::model::config::ModelConfig;
use crate::model::params::Params;
use crate::model::plan::QuantPlan;
use crate::train::{train_lm, TrainConfig};
use crate::util::json::Json;
use crate::util::table::Table;
use std::path::PathBuf;

/// Where trained checkpoints live (gitignored).
pub fn zoo_dir() -> PathBuf {
    PathBuf::from(std::env::var("BBQ_ZOO_DIR").unwrap_or_else(|_| "zoo".to_string()))
}

/// Train (or load a cached) model of `preset` on the synthetic corpus.
/// Training budgets scale with model size so bigger models are genuinely
/// better — preserving the paper's "bigger models, lower perplexity" axis.
pub fn get_or_train(preset: &str, steps: usize, quiet: bool) -> Params {
    let path = zoo_dir().join(format!("{preset}_s{steps}.bbqw"));
    if path.exists() {
        if let Ok(p) = Params::load(&path) {
            return p;
        }
    }
    let vocab = Vocab::build();
    let cfg = ModelConfig::preset(preset);
    let mut params = Params::init(&cfg, 42);
    let stream = train_stream(&vocab, 60_000);
    let tc = TrainConfig {
        steps,
        seq_len: 64,
        lr: 3e-3,
        seed: 42,
        log_every: if quiet { 0 } else { 50 },
    };
    train_lm(&mut params, &QuantPlan::fp32(), &stream, &tc, |step, loss| {
        if !quiet {
            eprintln!("[train {preset}] step {step}: loss {loss:.4}");
        }
    });
    let _ = params.save(&path);
    params
}

/// Default training budget per preset (bigger model, more steps).
pub fn default_steps(preset: &str) -> usize {
    match preset {
        "nano" => 600,
        "micro" => 1200,
        "tiny" => 2000,
        "small" => 2800,
        "base" => 3200,
        "rope-tiny" => 2000,
        "rope-small" => 2800,
        _ => 800,
    }
}

/// Persist an experiment's table: `results/<id>.md`, .csv, .json.
pub fn save_result(id: &str, table: &Table, extra: Option<Json>) {
    let dir = crate::util::results_dir();
    let _ = crate::util::write_file(&dir.join(format!("{id}.md")), &table.render());
    let _ = crate::util::write_file(&dir.join(format!("{id}.csv")), &table.to_csv());
    if let Some(j) = extra {
        let _ = crate::util::write_file(&dir.join(format!("{id}.json")), &j.to_string());
    }
    println!("{}", table.render());
    println!("[saved results/{id}.md .csv]");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_roundtrip() {
        std::env::set_var("BBQ_ZOO_DIR", std::env::temp_dir().join("bbq_zoo_test"));
        let p1 = get_or_train("nano", 5, true);
        let p2 = get_or_train("nano", 5, true); // cached
        assert_eq!(p1.tok_emb.data, p2.tok_emb.data);
        std::fs::remove_dir_all(zoo_dir()).ok();
        std::env::remove_var("BBQ_ZOO_DIR");
    }

    #[test]
    fn steps_scale_with_size() {
        assert!(default_steps("base") > default_steps("micro"));
    }
}
