//! Multi-engine request router with priority classes and weighted-fair
//! admission.
//!
//! The [`super::engine::EngineHandle`] admission queue is a blind FIFO: a
//! burst of bulk work admitted first starves an interactive request that
//! arrives a millisecond later. The router replaces direct submission with
//! three bounded per-class queues ([`Priority::Interactive`] /
//! [`Priority::Standard`] / [`Priority::Batch`]) drained by a single pump
//! thread in **weighted-fair order** (stride scheduling, see
//! [`FairPicker`]): whenever the engine's bounded queue has a free seat,
//! the backlogged class with the lowest virtual time takes it, so under
//! sustained contention the classes share engine admissions in the ratio
//! of their [`RouterConfig::weights`] while an idle class builds no
//! credit.
//!
//! The router is also the model registry for the network front door: each
//! [`ModelEntry`] names one engine plus the bounds the HTTP layer needs to
//! validate requests (vocabulary size, context window) before they can
//! reach — and panic — a scheduler thread.
//!
//! Flow control is explicit at both levels: a full class queue rejects at
//! submission ([`RouteError::ClassFull`] → HTTP 429), while a full engine
//! queue merely blocks the pump — the weighted-fair choice is made again
//! for every engine seat as it frees.

use super::engine::{EngineHandle, RequestHandle, SubmitError};
use super::server::Request;
use crate::model::Model;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Request priority class. Classes share engine admissions in the ratio
/// of their configured weights when backlogged; an empty class accrues no
/// credit (no burst after idling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    /// Latency-sensitive traffic (default weight 8).
    Interactive,
    /// Ordinary traffic, the default class (default weight 4).
    Standard,
    /// Throughput traffic that tolerates queueing (default weight 1).
    Batch,
}

impl Priority {
    /// Number of priority classes.
    pub const COUNT: usize = 3;
    /// All classes, index order.
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Standard, Priority::Batch];

    /// Dense index (0 = interactive, 1 = standard, 2 = batch).
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }

    /// Stable wire name (HTTP JSON, trace files).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Inverse of [`Self::as_str`].
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "standard" => Some(Priority::Standard),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }
}

/// One engine behind the router: its route name plus the request bounds
/// the HTTP layer validates against before submission (a prompt token ≥
/// `vocab_size` or a prompt longer than `max_seq` would panic the
/// scheduler thread it reaches — the front door must shed those with a
/// 400, never forward them).
#[derive(Clone)]
pub struct ModelEntry {
    /// Route name (the `"model"` field of a generate request).
    pub name: String,
    /// Submission handle to the engine serving this model.
    pub handle: EngineHandle,
    /// Exclusive upper bound for prompt token ids.
    pub vocab_size: usize,
    /// Context window: maximum prompt length admitted.
    pub max_seq: usize,
}

impl ModelEntry {
    /// Entry for `handle` serving `model`, bounds read off the model config.
    pub fn for_model(name: &str, handle: EngineHandle, model: &Model) -> ModelEntry {
        ModelEntry {
            name: name.to_string(),
            handle,
            vocab_size: model.cfg().vocab_size,
            max_seq: model.cfg().max_seq,
        }
    }
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Admission weight per class, [interactive, standard, batch]. Under
    /// sustained backlog the classes take engine-queue seats in this
    /// ratio. Zero weights are clamped to 1.
    pub weights: [u32; 3],
    /// Bound of each per-class queue; a class at this depth rejects new
    /// submissions with [`RouteError::ClassFull`] (→ HTTP 429).
    pub class_depth: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            weights: [8, 4, 1],
            class_depth: 256,
        }
    }
}

/// Why the router refused a submission; the request is handed back.
#[derive(Debug)]
pub enum RouteError {
    /// The priority class's bounded queue is full — shed or retry later.
    ClassFull(Request),
    /// No [`ModelEntry`] matches the requested model name.
    UnknownModel(Request),
    /// The router (or its engine) has shut down.
    Closed(Request),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::ClassFull(r) => write!(f, "priority class full (request {})", r.id),
            RouteError::UnknownModel(r) => write!(f, "unknown model (request {})", r.id),
            RouteError::Closed(r) => write!(f, "router closed (request {})", r.id),
        }
    }
}

impl std::error::Error for RouteError {}

/// Stride scheduler over the priority classes: each class carries a
/// virtual time (`pass`) advanced by `1/weight` per dispatch; the
/// backlogged class with the lowest pass goes next, so over any busy
/// window dispatches converge to the weight ratio. A class activating
/// from empty is clamped forward to the scheduler's current virtual time,
/// so idling earns no burst credit.
#[derive(Clone, Debug)]
pub struct FairPicker {
    stride: [f64; 3],
    pass: [f64; 3],
    global: f64,
}

impl FairPicker {
    /// Scheduler with the given per-class weights (zeros clamp to 1).
    pub fn new(weights: [u32; 3]) -> FairPicker {
        let mut stride = [0.0; 3];
        for (s, &w) in stride.iter_mut().zip(&weights) {
            *s = 1e6 / w.max(1) as f64;
        }
        FairPicker {
            stride,
            pass: [0.0; 3],
            global: 0.0,
        }
    }

    /// Class `i` went from empty to backlogged: forfeit credit accrued
    /// while idle.
    pub fn activate(&mut self, i: usize) {
        self.pass[i] = self.pass[i].max(self.global);
    }

    /// Choose the next class to dispatch among the currently backlogged
    /// ones and advance its virtual time. Ties break toward the more
    /// latency-sensitive (lower-index) class.
    pub fn pick(&mut self, backlogged: &[bool; 3]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..3 {
            if !backlogged[i] {
                continue;
            }
            best = match best {
                Some(b) if self.pass[b] <= self.pass[i] => Some(b),
                _ => Some(i),
            };
        }
        if let Some(i) = best {
            self.global = self.pass[i];
            self.pass[i] += self.stride[i];
        }
        best
    }
}

/// Per-class router counters (a snapshot; `queued` is live depth).
#[derive(Clone, Debug, Default)]
pub struct RouterStats {
    /// Requests currently waiting in each class queue.
    pub queued: [usize; 3],
    /// Requests accepted into each class queue since start.
    pub submitted: [u64; 3],
    /// Requests handed to an engine (weighted-fair order) per class.
    pub dispatched: [u64; 3],
    /// Requests shed at a full class queue per class.
    pub rejected: [u64; 3],
}

struct Pending {
    model: usize,
    req: Request,
    reply: Sender<Result<RequestHandle, SubmitError>>,
}

struct RouterState {
    classes: [VecDeque<Pending>; 3],
    picker: FairPicker,
    submitted: [u64; 3],
    dispatched: [u64; 3],
    rejected: [u64; 3],
    closed: bool,
}

struct RouterShared {
    entries: Vec<ModelEntry>,
    cfg: RouterConfig,
    state: Mutex<RouterState>,
    work: Condvar,
}

/// The admission result of one routed submission: resolves to the
/// engine's [`RequestHandle`] once the pump dispatches the request in
/// weighted-fair order (or to the engine's [`SubmitError`] if it closed
/// first). Dropping an unresolved ticket abandons the request: when the
/// pump eventually dispatches it, the unobserved handle is dropped and the
/// engine reaps it as a cancellation.
pub struct Ticket {
    rx: Receiver<Result<RequestHandle, SubmitError>>,
}

impl Ticket {
    /// Block until the request is dispatched to its engine.
    pub fn wait(self) -> Result<RequestHandle, SubmitError> {
        match self.rx.recv() {
            Ok(res) => res,
            // the pump exited with the request still queued (router
            // shutdown drains, so this only happens if the pump panicked)
            Err(_) => panic!("router pump dropped a pending request"),
        }
    }

    /// Like [`Self::wait`] but gives up at `deadline` (`None` = never).
    /// `None` result means the deadline passed first; the request stays
    /// queued and will be reaped as cancelled when dispatched unobserved.
    pub fn wait_until(self, deadline: Option<Instant>) -> Option<Result<RequestHandle, SubmitError>> {
        match deadline {
            None => Some(self.wait()),
            Some(d) => loop {
                let now = Instant::now();
                if now >= d {
                    return None;
                }
                match self.rx.recv_timeout(d - now) {
                    Ok(res) => return Some(res),
                    Err(RecvTimeoutError::Timeout) => return None,
                    Err(RecvTimeoutError::Disconnected) => {
                        panic!("router pump dropped a pending request")
                    }
                }
            },
        }
    }
}

/// Cloneable submission/observation handle to a running [`Router`].
#[derive(Clone)]
pub struct RouterHandle {
    shared: Arc<RouterShared>,
}

impl RouterHandle {
    /// The registered engines, route order (`None` model routes to the
    /// first entry).
    pub fn entries(&self) -> &[ModelEntry] {
        &self.shared.entries
    }

    /// Look up a route: `None` is the default (first) entry.
    pub fn entry(&self, model: Option<&str>) -> Option<&ModelEntry> {
        match model {
            None => self.shared.entries.first(),
            Some(name) => self.shared.entries.iter().find(|e| e.name == name),
        }
    }

    /// Queue `req` for `model` under `priority`. Returns a [`Ticket`]
    /// resolving to the engine's streaming handle once the pump dispatches
    /// the request in weighted-fair order.
    pub fn submit(
        &self,
        model: Option<&str>,
        priority: Priority,
        req: Request,
    ) -> Result<Ticket, RouteError> {
        let idx = match model {
            None => 0,
            Some(name) => match self.shared.entries.iter().position(|e| e.name == name) {
                Some(i) => i,
                None => return Err(RouteError::UnknownModel(req)),
            },
        };
        if self.shared.entries.is_empty() {
            return Err(RouteError::UnknownModel(req));
        }
        let (tx, rx) = channel();
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.closed {
                return Err(RouteError::Closed(req));
            }
            let c = priority.index();
            if st.classes[c].len() >= self.shared.cfg.class_depth {
                st.rejected[c] += 1;
                return Err(RouteError::ClassFull(req));
            }
            if st.classes[c].is_empty() {
                st.picker.activate(c);
            }
            st.submitted[c] += 1;
            st.classes[c].push_back(Pending {
                model: idx,
                req,
                reply: tx,
            });
        }
        self.shared.work.notify_all();
        Ok(Ticket { rx })
    }

    /// Current per-class counters.
    pub fn stats(&self) -> RouterStats {
        let st = self.shared.state.lock().unwrap();
        RouterStats {
            queued: [
                st.classes[0].len(),
                st.classes[1].len(),
                st.classes[2].len(),
            ],
            submitted: st.submitted,
            dispatched: st.dispatched,
            rejected: st.rejected,
        }
    }

    /// True once the router stops accepting submissions.
    pub fn is_closed(&self) -> bool {
        self.shared.state.lock().unwrap().closed
    }
}

/// A running router: the weighted-fair pump thread plus its root handle.
/// One pump serves all classes and all engines; it blocks on a full
/// engine queue (that backpressure is the point — the fair choice is
/// re-made per engine seat) and drains every already-accepted request on
/// [`Self::shutdown`].
pub struct Router {
    handle: RouterHandle,
    pump: JoinHandle<()>,
}

impl Router {
    /// Start a router over `entries` (route order; the first entry is the
    /// default model).
    pub fn new(entries: Vec<ModelEntry>, cfg: RouterConfig) -> Router {
        let shared = Arc::new(RouterShared {
            state: Mutex::new(RouterState {
                classes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                picker: FairPicker::new(cfg.weights),
                submitted: [0; 3],
                dispatched: [0; 3],
                rejected: [0; 3],
                closed: false,
            }),
            work: Condvar::new(),
            entries,
            cfg,
        });
        let pump_shared = shared.clone();
        let pump = std::thread::Builder::new()
            .name("bbq-router".into())
            .spawn(move || Router::pump(pump_shared))
            .expect("spawn router pump thread");
        Router {
            handle: RouterHandle { shared },
            pump,
        }
    }

    /// A new [`RouterHandle`] feeding this router.
    pub fn handle(&self) -> RouterHandle {
        self.handle.clone()
    }

    /// Submit on the root handle — see [`RouterHandle::submit`].
    pub fn submit(
        &self,
        model: Option<&str>,
        priority: Priority,
        req: Request,
    ) -> Result<Ticket, RouteError> {
        self.handle.submit(model, priority, req)
    }

    /// Stop accepting submissions, dispatch every already-queued request
    /// to its engine (weighted-fair to the end), and join the pump. The
    /// engines keep running — shut them down after the router so drained
    /// requests still complete.
    pub fn shutdown(self) {
        {
            let mut st = self.handle.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.handle.shared.work.notify_all();
        self.pump.join().expect("router pump thread panicked");
    }

    fn pump(shared: Arc<RouterShared>) {
        loop {
            let pending = {
                let mut st = shared.state.lock().unwrap();
                loop {
                    let backlogged = [
                        !st.classes[0].is_empty(),
                        !st.classes[1].is_empty(),
                        !st.classes[2].is_empty(),
                    ];
                    if let Some(c) = st.picker.pick(&backlogged) {
                        st.dispatched[c] += 1;
                        break st.classes[c].pop_front().unwrap();
                    }
                    if st.closed {
                        return; // every accepted request has been dispatched
                    }
                    st = shared.work.wait(st).unwrap();
                }
            };
            // lock released: the engine's bounded queue may block here —
            // that is the backpressure seat the fair schedule is filling
            let res = shared.entries[pending.model].handle.submit(pending.req);
            // a dropped ticket (deadline passed while queued, client gone)
            // leaves the handle unobserved; the engine reaps it as cancelled
            let _ = pending.reply.send(res);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::ServerConfig;
    use crate::coordinator::{serve_one, Engine, TokenEvent};
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn priority_wire_names_roundtrip() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.as_str()), Some(p));
        }
        assert_eq!(Priority::parse("bulk"), None);
        assert_eq!(Priority::Interactive.index(), 0);
        assert_eq!(Priority::Batch.index(), 2);
    }

    #[test]
    fn fair_picker_respects_weights_under_backlog() {
        // all classes permanently backlogged: dispatches converge to the
        // exact weight ratio over any window that is a multiple of the
        // weight sum
        let mut p = FairPicker::new([4, 2, 1]);
        let mut counts = [0usize; 3];
        for _ in 0..70 {
            counts[p.pick(&[true, true, true]).unwrap()] += 1;
        }
        assert_eq!(counts, [40, 20, 10], "dispatch ratio must be 4:2:1");
    }

    #[test]
    fn fair_picker_idle_class_earns_no_burst() {
        // batch idles while interactive is served, then activates: it must
        // rejoin at the current virtual time, not claim the whole backlog
        let mut p = FairPicker::new([1, 1, 1]);
        for _ in 0..50 {
            assert_eq!(p.pick(&[true, false, false]), Some(0));
        }
        p.activate(2);
        let mut batch_run = 0;
        for _ in 0..10 {
            match p.pick(&[true, false, true]).unwrap() {
                2 => batch_run += 1,
                _ => break,
            }
        }
        // equal weights: at most one catch-up dispatch, never a burst
        assert!(batch_run <= 1, "idle class burst of {batch_run}");
    }

    #[test]
    fn fair_picker_skips_empty_classes() {
        let mut p = FairPicker::new([8, 4, 1]);
        assert_eq!(p.pick(&[false, false, true]), Some(2));
        assert_eq!(p.pick(&[false, true, false]), Some(1));
        assert_eq!(p.pick(&[false, false, false]), None);
    }

    fn tiny_engine() -> (Engine, Arc<crate::model::Model>) {
        let cfg = ModelConfig::preset("tiny");
        let m = Arc::new(crate::model::Model::new(
            Params::init(&cfg, 42),
            QuantPlan::uniform(presets::bfp_w(6)),
        ));
        // one slot, one engine-queue seat: admission contention on demand
        let engine = Engine::start(m.clone(), ServerConfig::new(1, 8, 1));
        (engine, m)
    }

    #[test]
    fn routes_reject_and_drain_end_to_end() {
        let (engine, m) = tiny_engine();
        let entry = ModelEntry::for_model("default", engine.handle(), &m);
        assert_eq!(entry.vocab_size, 512);
        assert_eq!(entry.max_seq, 256);
        let router = Router::new(
            vec![entry],
            RouterConfig {
                class_depth: 1,
                ..RouterConfig::default()
            },
        );
        // unknown model is refused up front, request handed back
        match router.submit(Some("nope"), Priority::Standard, Request::greedy(9, vec![1], 1)) {
            Err(RouteError::UnknownModel(r)) => assert_eq!(r.id, 9),
            other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
        }
        // hog occupies the engine's single slot for ~200 slow steps
        let hog = router
            .submit(None, Priority::Interactive, Request::greedy(0, vec![3], 200))
            .expect("router open")
            .wait()
            .expect("engine open");
        loop {
            match hog.recv().expect("engine alive") {
                TokenEvent::Started => break,
                TokenEvent::Finished { .. } => panic!("hog finished prematurely"),
                _ => {}
            }
        }
        // r1 takes the engine's one queue seat, r2 blocks the pump on the
        // full engine queue, r3 fills the 1-deep standard class queue
        let r1 = Request::greedy(1, vec![5, 9], 3);
        let t1 = router.submit(None, Priority::Standard, r1.clone()).expect("router open");
        // wait until the pump has picked r1 up and is blocked in the
        // engine submit (the class queue shows empty again)
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        while router.handle().stats().queued[1] > 0 {
            assert!(std::time::Instant::now() < deadline, "pump never drained r1");
            std::thread::sleep(Duration::from_millis(2));
        }
        let r2 = Request::greedy(2, vec![7, 1], 3);
        let t2 = router.submit(None, Priority::Standard, r2.clone()).expect("router open");
        while router.handle().stats().queued[1] > 0 {
            assert!(std::time::Instant::now() < deadline, "pump never drained r2");
            std::thread::sleep(Duration::from_millis(2));
        }
        let r3 = Request::greedy(3, vec![8], 2);
        let t3 = router.submit(None, Priority::Standard, r3.clone()).expect("router open");
        // class queue is now at depth 1: the next standard submission sheds
        match router.submit(None, Priority::Standard, Request::greedy(4, vec![2], 2)) {
            Err(RouteError::ClassFull(r)) => assert_eq!(r.id, 4),
            other => panic!("expected ClassFull, got {:?}", other.map(|_| ())),
        }
        let stats = router.handle().stats();
        assert_eq!(stats.rejected[1], 1);
        assert_eq!(stats.submitted[1], 3);
        // free the slot: everything queued drains, outputs bit-match the
        // sequential reference
        hog.cancel();
        for (ticket, req) in [(t1, &r1), (t2, &r2), (t3, &r3)] {
            let got = ticket.wait().expect("engine open").wait();
            assert_eq!(got.tokens, serve_one(&m, req).tokens, "request {}", req.id);
        }
        let handle = router.handle();
        router.shutdown();
        assert!(handle.is_closed());
        match handle.submit(None, Priority::Batch, Request::greedy(99, vec![1], 1)) {
            Err(RouteError::Closed(r)) => assert_eq!(r.id, 99),
            other => panic!("expected Closed, got {:?}", other.map(|_| ())),
        }
        let metrics = engine.shutdown();
        assert_eq!(metrics.cancelled, 1);
        assert_eq!(metrics.completed, 3);
    }

    #[test]
    fn ticket_wait_until_times_out_and_request_reaps_as_cancelled() {
        let (engine, _m) = tiny_engine();
        let router = Router::new(
            vec![ModelEntry {
                name: "default".into(),
                handle: engine.handle(),
                vocab_size: 512,
                max_seq: 256,
            }],
            RouterConfig::default(),
        );
        // hog the single slot and queue seat so the next request waits
        let hog = router
            .submit(None, Priority::Interactive, Request::greedy(0, vec![3], 200))
            .expect("router open")
            .wait()
            .expect("engine open");
        let seat = router
            .submit(None, Priority::Standard, Request::greedy(1, vec![5], 2))
            .expect("router open");
        // this one cannot be dispatched while the pump is blocked: its
        // ticket deadline expires and the request is abandoned
        let late = router
            .submit(None, Priority::Standard, Request::greedy(2, vec![7], 2))
            .expect("router open");
        let res = late.wait_until(Some(std::time::Instant::now() + Duration::from_millis(50)));
        assert!(res.is_none(), "deadline must expire while the pump is blocked");
        hog.cancel();
        // the abandoned request is dispatched unobserved and reaped as a
        // cancellation; the seated request completes normally
        seat.wait().expect("engine open").wait();
        router.shutdown();
        let metrics = engine.shutdown();
        assert_eq!(metrics.completed, 1);
        assert_eq!(metrics.cancelled, 2, "hog + abandoned ticket");
    }
}
