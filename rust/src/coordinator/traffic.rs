//! Open-loop SLO traffic harness for the HTTP front door.
//!
//! Closed-loop load generators (fire the next request when the previous
//! one finishes) hide queueing collapse: when the server slows down the
//! generator slows down with it, and the measured latency stays
//! flattering. This harness is **open-loop**: arrivals follow a seeded
//! Poisson process ([`Trace::poisson`], exponential inter-arrival gaps
//! drawn from the crate's [`Pcg32`]) and are dispatched at their trace
//! timestamps no matter how the server is doing, so offered load and
//! achieved throughput can diverge — which is exactly the signal the
//! `serve-bench` SLO bars assert on.
//!
//! Traces are plain JSON ([`Trace::to_json`] / [`Trace::from_json`]), so
//! a run can be replayed byte-for-byte later (`bbq serve-bench
//! --trace-out` / `--trace-in`) — same arrival times, same prompts, same
//! priorities.
//!
//! [`run_trace`] drives a trace against a live server end to end over
//! real sockets: one dispatcher pacing arrivals, one client thread per
//! request streaming SSE and timestamping every event. The resulting
//! [`OpenLoopReport`] carries offered vs achieved rates plus TTFT,
//! inter-token gap, and whole-request latency distributions in the same
//! [`LogHistogram`]s the engine uses, and serialises into
//! `BENCH_serve.json` via [`OpenLoopReport::to_json`].

use super::engine::Engine;
use super::http::{hist_json, HttpConfig, HttpServer};
use super::metrics::{LogHistogram, Metrics};
use super::router::{ModelEntry, Priority, Router, RouterConfig};
use super::server::ServerConfig;
use crate::model::Model;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Parameters for synthesising a Poisson [`Trace`].
#[derive(Clone, Debug)]
pub struct TrafficConfig {
    /// Number of requests in the trace.
    pub requests: usize,
    /// Mean arrival rate, requests per second (the offered load).
    pub rate_rps: f64,
    /// Inclusive range of prompt lengths, sampled uniformly.
    pub prompt_len: (usize, usize),
    /// Inclusive range of `max_new_tokens`, sampled uniformly.
    pub new_tokens: (usize, usize),
    /// Exclusive upper bound for sampled prompt token ids (the served
    /// model's vocabulary size).
    pub vocab: usize,
    /// Unnormalised weights for the priority mix,
    /// `[interactive, standard, batch]`.
    pub priority_mix: [f64; 3],
    /// Seed for arrivals, lengths, prompts, and priorities alike.
    pub seed: u64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            requests: 64,
            rate_rps: 8.0,
            prompt_len: (4, 24),
            new_tokens: (4, 16),
            vocab: 512,
            priority_mix: [0.5, 0.4, 0.1],
            seed: 0x7EA_7EA,
        }
    }
}

/// One scheduled request of a [`Trace`].
#[derive(Clone, Debug, PartialEq)]
pub struct TraceItem {
    /// Dispatch time, milliseconds after the run starts.
    pub at_ms: f64,
    /// Request id (also fixes the default sampler seed, keeping replays
    /// bit-identical).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<usize>,
    /// Tokens to generate.
    pub max_new_tokens: usize,
    /// Priority class submitted with the request.
    pub priority: Priority,
}

impl TraceItem {
    /// The `POST /v1/generate` body for this item (streaming on, so the
    /// client can timestamp TTFT and inter-token gaps).
    pub fn request_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("prompt", Json::arr_usize(&self.prompt)),
            ("max_new_tokens", Json::Num(self.max_new_tokens as f64)),
            ("priority", Json::Str(self.priority.as_str().to_string())),
            ("stream", Json::Bool(true)),
        ])
    }
}

/// A replayable open-loop arrival schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// Scheduled requests, ascending `at_ms`.
    pub items: Vec<TraceItem>,
}

impl Trace {
    /// Synthesise a Poisson trace: inter-arrival gaps `-ln(1-u)/rate`,
    /// uniform prompt/output-length mix, weighted priority classes — all
    /// from one seeded [`Pcg32`], so the same config reproduces the same
    /// trace on any machine.
    pub fn poisson(cfg: &TrafficConfig) -> Trace {
        assert!(cfg.rate_rps > 0.0, "rate_rps must be positive");
        assert!(cfg.vocab > 0, "vocab must be positive");
        let (plo, phi) = cfg.prompt_len;
        let (nlo, nhi) = cfg.new_tokens;
        assert!(plo <= phi && nlo <= nhi, "length ranges must be lo <= hi");
        let mut rng = Pcg32::new(cfg.seed);
        let mut at_ms = 0.0f64;
        let mut items = Vec::with_capacity(cfg.requests);
        for i in 0..cfg.requests {
            let u = rng.f64();
            at_ms += -(1.0 - u).ln() / cfg.rate_rps * 1e3;
            let plen = plo + rng.below(phi - plo + 1);
            let prompt = (0..plen).map(|_| rng.below(cfg.vocab)).collect();
            let max_new_tokens = (nlo + rng.below(nhi - nlo + 1)).max(1);
            let priority = Priority::ALL[rng.weighted(&cfg.priority_mix)];
            items.push(TraceItem {
                at_ms,
                id: i as u64,
                prompt,
                max_new_tokens,
                priority,
            });
        }
        Trace { items }
    }

    /// Serialise for replay files.
    pub fn to_json(&self) -> Json {
        let items = self
            .items
            .iter()
            .map(|it| {
                Json::obj(vec![
                    ("at_ms", Json::Num(it.at_ms)),
                    ("id", Json::Num(it.id as f64)),
                    ("prompt", Json::arr_usize(&it.prompt)),
                    ("max_new_tokens", Json::Num(it.max_new_tokens as f64)),
                    ("priority", Json::Str(it.priority.as_str().to_string())),
                ])
            })
            .collect();
        Json::obj(vec![("items", Json::Arr(items))])
    }

    /// Inverse of [`Self::to_json`].
    pub fn from_json(j: &Json) -> Result<Trace, String> {
        let items = j
            .get("items")
            .and_then(|v| v.as_arr())
            .ok_or("trace: missing \"items\" array")?;
        let mut out = Vec::with_capacity(items.len());
        for (i, it) in items.iter().enumerate() {
            let field = |k: &str| it.get(k).ok_or(format!("trace item {i}: missing \"{k}\""));
            let at_ms = field("at_ms")?.as_f64().ok_or("at_ms must be a number")?;
            let id = field("id")?.as_f64().ok_or("id must be a number")? as u64;
            let prompt = field("prompt")?
                .usize_vec()
                .ok_or("prompt must be an array")?;
            let max_new_tokens =
                field("max_new_tokens")?.as_f64().ok_or("max_new_tokens must be a number")? as usize;
            let pname = field("priority")?.as_str().ok_or("priority must be a string")?;
            let priority =
                Priority::parse(pname).ok_or(format!("trace item {i}: unknown priority"))?;
            out.push(TraceItem {
                at_ms,
                id,
                prompt,
                max_new_tokens,
                priority,
            });
        }
        Ok(Trace { items: out })
    }

    /// Write the trace to `path` as JSON.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    /// Load a trace previously written by [`Self::save`].
    pub fn load(path: &str) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Trace::from_json(&Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?)
    }
}

/// One timestamped Server-Sent Event observed by the client.
#[derive(Clone, Debug)]
pub struct SseRecord {
    /// The `event:` name (`queued`, `started`, `token`, `done`, `error`).
    pub event: String,
    /// The parsed `data:` document.
    pub data: Json,
    /// Milliseconds after the request was written to the socket.
    pub at_ms: f64,
}

/// What one HTTP exchange produced.
#[derive(Clone, Debug)]
pub struct HttpOutcome {
    /// HTTP status code.
    pub status: u16,
    /// SSE events in arrival order (empty for non-SSE responses).
    pub events: Vec<SseRecord>,
    /// The response document: the JSON body for plain responses, the
    /// `done` (or `error`) event's data for SSE streams.
    pub body: Option<Json>,
}

impl HttpOutcome {
    /// The generated token ids carried by `token` events, arrival order.
    pub fn tokens(&self) -> Vec<usize> {
        self.events
            .iter()
            .filter(|r| r.event == "token")
            .filter_map(|r| r.data.get("token").and_then(|t| t.as_f64()))
            .map(|t| t as usize)
            .collect()
    }

    /// The `finish` field of the response document, if any.
    pub fn finish(&self) -> Option<&str> {
        self.body.as_ref()?.get("finish")?.as_str()
    }
}

/// Perform one HTTP exchange against a front door: write the request,
/// then read either a single JSON response or a full SSE stream,
/// timestamping every event. This is the client half the harness and the
/// end-to-end tests share.
pub fn http_exchange(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<HttpOutcome, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| e.to_string())?;
    let _ = stream.set_nodelay(true);
    let mut w = stream.try_clone().map_err(|e| e.to_string())?;
    let body = body.unwrap_or("");
    write!(
        w,
        "{method} {path} HTTP/1.1\r\nHost: bbq\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .map_err(|e| format!("write: {e}"))?;
    w.flush().map_err(|e| format!("flush: {e}"))?;
    let sent = Instant::now();
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    r.read_line(&mut line).map_err(|e| format!("status: {e}"))?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or(format!("bad status line {line:?}"))?;
    let mut content_length = 0usize;
    let mut sse = false;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).map_err(|e| format!("header: {e}"))?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim();
            if name == "content-length" {
                content_length = value.parse().unwrap_or(0);
            } else if name == "content-type" && value.starts_with("text/event-stream") {
                sse = true;
            }
        }
    }
    if !sse {
        let mut buf = vec![0u8; content_length];
        std::io::Read::read_exact(&mut r, &mut buf).map_err(|e| format!("body: {e}"))?;
        let text = String::from_utf8_lossy(&buf);
        let body = Json::parse(&text).ok();
        return Ok(HttpOutcome {
            status,
            events: Vec::new(),
            body,
        });
    }
    // SSE: accumulate `event:`/`data:` lines, finalise on each blank line
    let mut events: Vec<SseRecord> = Vec::new();
    let mut done: Option<Json> = None;
    let (mut name, mut data) = (String::new(), String::new());
    loop {
        let mut l = String::new();
        let n = r.read_line(&mut l).map_err(|e| format!("sse read: {e}"))?;
        if n == 0 {
            break; // server closed the stream
        }
        let l = l.trim_end();
        if let Some(v) = l.strip_prefix("event:") {
            name = v.trim().to_string();
        } else if let Some(v) = l.strip_prefix("data:") {
            data = v.trim().to_string();
        } else if l.is_empty() && !name.is_empty() {
            let parsed = Json::parse(&data).map_err(|e| format!("sse data: {e}"))?;
            if name == "done" || name == "error" {
                done = Some(parsed.clone());
            }
            events.push(SseRecord {
                event: std::mem::take(&mut name),
                data: parsed,
                at_ms: sent.elapsed().as_secs_f64() * 1e3,
            });
            data.clear();
        }
    }
    Ok(HttpOutcome {
        status,
        events,
        body: done,
    })
}

/// What an open-loop run measured, client side.
#[derive(Clone, Debug, Default)]
pub struct OpenLoopReport {
    /// Requests dispatched (the whole trace, regardless of outcome).
    pub sent: usize,
    /// Requests that finished normally over the wire.
    pub completed: usize,
    /// Requests the server shed with 429/503 (admission control working
    /// as designed).
    pub rejected: usize,
    /// Requests lost any other way — transport errors, cancelled
    /// mid-stream, malformed replies. The SLO gate requires zero.
    pub dropped: usize,
    /// Tokens received over the wire across completed requests.
    pub generated_tokens: usize,
    /// Offered load: the trace's arrival rate, requests per second.
    pub offered_rps: f64,
    /// Completed requests per wall-clock second.
    pub achieved_rps: f64,
    /// Tokens received per wall-clock second.
    pub achieved_tps: f64,
    /// Dispatch-to-first-token latency, ms (one sample per completed
    /// request).
    pub ttft_ms: LogHistogram,
    /// Gap between consecutive token events, ms.
    pub token_gap_ms: LogHistogram,
    /// Dispatch-to-done whole-request latency, ms.
    pub request_ms: LogHistogram,
    /// TTFT split by priority class, indexed by [`Priority::index`] —
    /// the per-class SLO bars gate interactive p99 separately from batch
    /// p99, because the aggregate hides exactly the inversion the
    /// weighted scheduler exists to prevent.
    pub class_ttft_ms: [LogHistogram; Priority::COUNT],
    /// Inter-token gap split by priority class, indexed by
    /// [`Priority::index`].
    pub class_token_gap_ms: [LogHistogram; Priority::COUNT],
    /// Wall-clock time of the whole run.
    pub wall: Duration,
}

impl OpenLoopReport {
    /// Fraction of sent requests the server shed.
    pub fn rejection_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.rejected as f64 / self.sent as f64
        }
    }

    /// The `BENCH_serve.json` payload (queue/SLO fields are appended by
    /// the CLI, which owns the server-side handles and the bars).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sent", Json::Num(self.sent as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("dropped", Json::Num(self.dropped as f64)),
            ("generated_tokens", Json::Num(self.generated_tokens as f64)),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("achieved_rps", Json::Num(self.achieved_rps)),
            ("achieved_tps", Json::Num(self.achieved_tps)),
            ("rejection_rate", Json::Num(self.rejection_rate())),
            ("ttft_ms", hist_json(&self.ttft_ms)),
            ("token_gap_ms", hist_json(&self.token_gap_ms)),
            ("request_ms", hist_json(&self.request_ms)),
            (
                "classes",
                Json::Obj(
                    Priority::ALL
                        .iter()
                        .map(|&p| {
                            let i = p.index();
                            (
                                p.as_str().to_string(),
                                Json::obj(vec![
                                    ("ttft_ms", hist_json(&self.class_ttft_ms[i])),
                                    ("token_gap_ms", hist_json(&self.class_token_gap_ms[i])),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            ("wall_s", Json::Num(self.wall.as_secs_f64())),
        ])
    }
}

#[derive(Default)]
struct Acc {
    completed: usize,
    rejected: usize,
    dropped: usize,
    generated_tokens: usize,
    ttft_ms: LogHistogram,
    token_gap_ms: LogHistogram,
    request_ms: LogHistogram,
    class_ttft_ms: [LogHistogram; Priority::COUNT],
    class_token_gap_ms: [LogHistogram; Priority::COUNT],
}

/// How long a client waits on a silent socket before counting the
/// request as dropped.
const CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

/// Drive `trace` against the front door at `addr`, open-loop: a
/// dispatcher sleeps to each item's `at_ms` and hands it to its own
/// client thread, which streams SSE and timestamps TTFT / inter-token
/// gaps / completion. Blocks until every client finishes.
pub fn run_trace(addr: SocketAddr, trace: &Trace) -> OpenLoopReport {
    let start = Instant::now();
    let acc = Arc::new(Mutex::new(Acc::default()));
    let mut workers = Vec::with_capacity(trace.items.len());
    for item in &trace.items {
        let due = Duration::from_secs_f64(item.at_ms / 1e3);
        let elapsed = start.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
        let item = item.clone();
        let acc = acc.clone();
        workers.push(std::thread::spawn(move || {
            let body = item.request_json().to_string();
            let outcome = http_exchange(addr, "POST", "/v1/generate", Some(&body), CLIENT_TIMEOUT);
            let mut a = acc.lock().unwrap();
            match outcome {
                Err(_) => a.dropped += 1,
                Ok(o) if o.status == 429 || o.status == 503 => a.rejected += 1,
                Ok(o) if o.status == 200 && o.finish().is_some() => {
                    if o.finish() == Some("cancelled") {
                        // the server gave up on it (deadline/drain): lost
                        a.dropped += 1;
                        return;
                    }
                    a.completed += 1;
                    let class = item.priority.index();
                    let tokens: Vec<&SseRecord> =
                        o.events.iter().filter(|r| r.event == "token").collect();
                    a.generated_tokens += tokens.len();
                    if let Some(first) = tokens.first() {
                        a.ttft_ms.record(first.at_ms);
                        a.class_ttft_ms[class].record(first.at_ms);
                    }
                    for pair in tokens.windows(2) {
                        a.token_gap_ms.record(pair[1].at_ms - pair[0].at_ms);
                        a.class_token_gap_ms[class].record(pair[1].at_ms - pair[0].at_ms);
                    }
                    if let Some(done) = o.events.iter().find(|r| r.event == "done") {
                        a.request_ms.record(done.at_ms);
                    }
                }
                Ok(_) => a.dropped += 1,
            }
        }));
    }
    for w in workers {
        let _ = w.join();
    }
    let wall = start.elapsed();
    let acc = Arc::try_unwrap(acc)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_else(|arc| {
            let a = arc.lock().unwrap();
            Acc {
                completed: a.completed,
                rejected: a.rejected,
                dropped: a.dropped,
                generated_tokens: a.generated_tokens,
                ttft_ms: a.ttft_ms.clone(),
                token_gap_ms: a.token_gap_ms.clone(),
                request_ms: a.request_ms.clone(),
                class_ttft_ms: a.class_ttft_ms.clone(),
                class_token_gap_ms: a.class_token_gap_ms.clone(),
            }
        });
    let span_s = trace.items.last().map(|it| it.at_ms / 1e3).unwrap_or(0.0);
    let offered_rps = if span_s > 0.0 {
        trace.items.len() as f64 / span_s
    } else {
        0.0
    };
    let wall_s = wall.as_secs_f64().max(1e-9);
    OpenLoopReport {
        sent: trace.items.len(),
        completed: acc.completed,
        rejected: acc.rejected,
        dropped: acc.dropped,
        generated_tokens: acc.generated_tokens,
        offered_rps,
        achieved_rps: acc.completed as f64 / wall_s,
        achieved_tps: acc.generated_tokens as f64 / wall_s,
        ttft_ms: acc.ttft_ms,
        token_gap_ms: acc.token_gap_ms,
        request_ms: acc.request_ms,
        class_ttft_ms: acc.class_ttft_ms,
        class_token_gap_ms: acc.class_token_gap_ms,
        wall,
    }
}

/// Stand up the full serving stack (engine → router → HTTP server) on an
/// ephemeral localhost port, drive `trace` through it open-loop, then
/// drain everything in graceful order. Returns the client-side report
/// and the engine's final [`Metrics`] — the shared core of `bbq
/// serve-bench` and the end-to-end tests.
pub fn serve_trace(
    model: Arc<Model>,
    server_cfg: ServerConfig,
    router_cfg: RouterConfig,
    http_cfg: HttpConfig,
    trace: &Trace,
) -> (OpenLoopReport, Metrics) {
    let engine = Engine::start(model.clone(), server_cfg);
    let entry = ModelEntry::for_model("default", engine.handle(), &model);
    let router = Router::new(vec![entry], router_cfg);
    let server =
        HttpServer::bind("127.0.0.1:0", router.handle(), http_cfg).expect("bind ephemeral port");
    let addr = server.local_addr();
    let report = run_trace(addr, trace);
    server.shutdown();
    router.shutdown();
    let metrics = engine.shutdown();
    (report, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn cfg() -> TrafficConfig {
        TrafficConfig {
            requests: 200,
            rate_rps: 50.0,
            ..TrafficConfig::default()
        }
    }

    #[test]
    fn poisson_trace_is_deterministic_and_in_bounds() {
        let a = Trace::poisson(&cfg());
        let b = Trace::poisson(&cfg());
        assert_eq!(a, b, "same seed must reproduce the same trace");
        let c = Trace::poisson(&TrafficConfig {
            seed: 1,
            ..cfg()
        });
        assert_ne!(a, c, "a different seed must change the trace");
        let tc = cfg();
        let mut last = 0.0;
        for it in &a.items {
            assert!(it.at_ms >= last, "arrivals must be non-decreasing");
            last = it.at_ms;
            assert!(it.prompt.len() >= tc.prompt_len.0 && it.prompt.len() <= tc.prompt_len.1);
            assert!(it.prompt.iter().all(|&t| t < tc.vocab));
            assert!(it.max_new_tokens >= tc.new_tokens.0 && it.max_new_tokens <= tc.new_tokens.1);
        }
        // mean inter-arrival ≈ 1/rate (20ms at 50 rps); generous bound
        let mean_gap = last / (a.items.len() - 1) as f64;
        assert!(
            (mean_gap - 20.0).abs() < 8.0,
            "mean gap {mean_gap}ms vs expected 20ms"
        );
        // the weighted mix must actually produce every class
        for p in Priority::ALL {
            assert!(
                a.items.iter().any(|it| it.priority == p),
                "no {} items",
                p.as_str()
            );
        }
    }

    #[test]
    fn trace_roundtrips_through_json() {
        let trace = Trace::poisson(&TrafficConfig {
            requests: 17,
            ..cfg()
        });
        let back = Trace::from_json(&Json::parse(&trace.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(trace, back);
        assert!(Trace::from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(Trace::from_json(
            &Json::parse(r#"{"items": [{"at_ms": 1}]}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn open_loop_run_completes_a_small_trace() {
        let mcfg = ModelConfig::preset("nano");
        let model = Arc::new(Model::new(
            Params::init(&mcfg, 42),
            QuantPlan::uniform(presets::bfp_w(6)),
        ));
        let trace = Trace::poisson(&TrafficConfig {
            requests: 6,
            rate_rps: 200.0,
            prompt_len: (2, 5),
            new_tokens: (2, 4),
            vocab: mcfg.vocab_size,
            ..TrafficConfig::default()
        });
        let (report, metrics) = serve_trace(
            model,
            ServerConfig::default(),
            RouterConfig::default(),
            HttpConfig::default(),
            &trace,
        );
        assert_eq!(report.sent, 6);
        assert_eq!(report.completed, 6, "dropped={} rejected={}", report.dropped, report.rejected);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.rejected, 0);
        assert!(report.generated_tokens >= 6 * 2);
        assert_eq!(report.ttft_ms.count(), 6);
        assert_eq!(report.request_ms.count(), 6);
        assert!(report.achieved_tps > 0.0);
        assert_eq!(metrics.completed, 6);
        assert_eq!(metrics.cancelled, 0);
        // the report serialises with the full BENCH_serve schema
        let doc = Json::parse(&report.to_json().to_string()).unwrap();
        for key in [
            "sent",
            "completed",
            "rejected",
            "dropped",
            "generated_tokens",
            "offered_rps",
            "achieved_rps",
            "achieved_tps",
            "rejection_rate",
            "ttft_ms",
            "token_gap_ms",
            "request_ms",
            "classes",
            "wall_s",
        ] {
            assert!(doc.get(key).is_some(), "missing {key}");
        }
        assert_eq!(doc.get("ttft_ms").unwrap().get("count").unwrap().as_f64(), Some(6.0));
        // per-class splits cover every completed request exactly once
        let classes = doc.get("classes").unwrap();
        let mut class_ttft = 0.0;
        for p in Priority::ALL {
            let h = classes.get(p.as_str()).expect("every class serialises");
            class_ttft += h.get("ttft_ms").unwrap().get("count").unwrap().as_f64().unwrap();
        }
        assert_eq!(class_ttft, 6.0, "class TTFT counts must sum to the aggregate");
    }
}
