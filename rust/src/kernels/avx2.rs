//! AVX2 backend: 8-wide f32 lanes, bit-identical to `scalar` by
//! construction — plain `vmulps` + `vaddps` (never FMA, whose fused
//! rounding changes bits), the scalar module's exact 8-lane reduction tree,
//! and sign application via sign-bit XOR (exactly f32 negation).
//!
//! # Safety
//!
//! Every function here is `#[target_feature(enable = "avx2")]` and must only
//! be called when the host supports AVX2; the `kernels` dispatch layer
//! guarantees this (a backend is only activated when `supported()` holds).

use core::arch::x86_64::*;
use std::ops::Range;

use super::scalar;

/// Reduces an 8-lane accumulator with the scalar reference tree:
/// `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn reduce8(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v); // [l0 l1 l2 l3]
    let hi = _mm256_extractf128_ps::<1>(v); // [l4 l5 l6 l7]
    let q = _mm_add_ps(lo, hi); // [q0 q1 q2 q3]
    let r = _mm_add_ps(q, _mm_movehl_ps(q, q)); // [q0+q2, q1+q3, ..]
    let s = _mm_add_ss(r, _mm_shuffle_ps::<0b01>(r, r));
    _mm_cvtss_f32(s)
}

/// # Safety
/// Caller must ensure the host supports AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    let mut acc = _mm256_setzero_ps();
    for c in 0..chunks {
        let i = c * 8;
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
    }
    let mut s = reduce8(acc);
    for i in chunks * 8..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// # Safety
/// Caller must ensure the host supports AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn dot_acc(x: &[f32], y: &[f32], lane: &mut [f32; 8]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len() % 8, 0);
    // resume the 8-lane accumulator from `lane`: per lane the update is
    // `lane[l] = (lane[l] + p0) + p1 + ...`, the same left-association the
    // scalar `lane[l] += x*y` loop produces
    let mut acc = _mm256_loadu_ps(lane.as_ptr());
    let mut i = 0;
    while i < x.len() {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let yv = _mm256_loadu_ps(y.as_ptr().add(i));
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, yv));
        i += 8;
    }
    _mm256_storeu_ps(lane.as_mut_ptr(), acc);
}

/// # Safety
/// Caller must ensure the host supports AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_bt_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    let chunks = k / 8;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        let mut j = 0;
        // 4-column panels share each A load; every column is still the
        // exact `dot` order, so panel grouping never changes bits.
        while j + 4 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let mut c0 = _mm256_setzero_ps();
            let mut c1 = _mm256_setzero_ps();
            let mut c2 = _mm256_setzero_ps();
            let mut c3 = _mm256_setzero_ps();
            for c in 0..chunks {
                let off = c * 8;
                let av = _mm256_loadu_ps(arow.as_ptr().add(off));
                c0 = _mm256_add_ps(c0, _mm256_mul_ps(av, _mm256_loadu_ps(b0.as_ptr().add(off))));
                c1 = _mm256_add_ps(c1, _mm256_mul_ps(av, _mm256_loadu_ps(b1.as_ptr().add(off))));
                c2 = _mm256_add_ps(c2, _mm256_mul_ps(av, _mm256_loadu_ps(b2.as_ptr().add(off))));
                c3 = _mm256_add_ps(c3, _mm256_mul_ps(av, _mm256_loadu_ps(b3.as_ptr().add(off))));
            }
            let mut s0 = reduce8(c0);
            let mut s1 = reduce8(c1);
            let mut s2 = reduce8(c2);
            let mut s3 = reduce8(c3);
            for t in chunks * 8..k {
                let av = arow[t];
                s0 += av * b0[t];
                s1 += av * b1[t];
                s2 += av * b2[t];
                s3 += av * b3[t];
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            orow[j + 2] = s2;
            orow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            orow[j] = dot(arow, &b[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

/// # Safety
/// Caller must ensure the host supports AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    let jv = n / 8 * 8;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let a0s = arow[kk];
            let a1s = arow[kk + 1];
            let a2s = arow[kk + 2];
            let a3s = arow[kk + 3];
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b[(kk + 3) * n..(kk + 4) * n];
            let a0 = _mm256_set1_ps(a0s);
            let a1 = _mm256_set1_ps(a1s);
            let a2 = _mm256_set1_ps(a2s);
            let a3 = _mm256_set1_ps(a3s);
            let mut j = 0;
            while j < jv {
                // same association as scalar: ((a0*b0 + a1*b1) + a2*b2) + a3*b3
                let mut s = _mm256_mul_ps(a0, _mm256_loadu_ps(b0.as_ptr().add(j)));
                s = _mm256_add_ps(s, _mm256_mul_ps(a1, _mm256_loadu_ps(b1.as_ptr().add(j))));
                s = _mm256_add_ps(s, _mm256_mul_ps(a2, _mm256_loadu_ps(b2.as_ptr().add(j))));
                s = _mm256_add_ps(s, _mm256_mul_ps(a3, _mm256_loadu_ps(b3.as_ptr().add(j))));
                let o = _mm256_add_ps(_mm256_loadu_ps(orow.as_ptr().add(j)), s);
                _mm256_storeu_ps(orow.as_mut_ptr().add(j), o);
                j += 8;
            }
            for j in jv..n {
                orow[j] += a0s * b0[j] + a1s * b1[j] + a2s * b2[j] + a3s * b3[j];
            }
            kk += 4;
        }
        while kk < k {
            let avs = arow[kk];
            let brow = &b[kk * n..(kk + 1) * n];
            let av = _mm256_set1_ps(avs);
            let mut j = 0;
            while j < jv {
                let o = _mm256_add_ps(
                    _mm256_loadu_ps(orow.as_ptr().add(j)),
                    _mm256_mul_ps(av, _mm256_loadu_ps(brow.as_ptr().add(j))),
                );
                _mm256_storeu_ps(orow.as_mut_ptr().add(j), o);
                j += 8;
            }
            for j in jv..n {
                orow[j] += avs * brow[j];
            }
            kk += 1;
        }
    }
}

/// # Safety
/// Caller must ensure the host supports AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn expand_bfp(fields: &[u32], blk_scale: f32, out: &mut [f32]) {
    debug_assert_eq!(fields.len(), out.len());
    let nv = fields.len() / 8 * 8;
    let scale = _mm256_set1_ps(blk_scale);
    let one = _mm256_set1_epi32(1);
    let mut i = 0;
    while i < nv {
        let f = _mm256_loadu_si256(fields.as_ptr().add(i) as *const __m256i);
        // mantissa < 2^31 always (a <= 32-bit field shifted right by one),
        // so the signed convert matches scalar `u32 as f32` exactly.
        let mm = _mm256_srli_epi32::<1>(f);
        let v = _mm256_mul_ps(_mm256_cvtepi32_ps(mm), scale);
        // negate by sign-bit XOR: identical to scalar `-v`, including -0.0
        let sgn = _mm256_slli_epi32::<31>(_mm256_and_si256(f, one));
        let r = _mm256_xor_ps(v, _mm256_castsi256_ps(sgn));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
        i += 8;
    }
    scalar::expand_bfp(&fields[nv..], blk_scale, &mut out[nv..]);
}

/// # Safety
/// Caller must ensure the host supports AVX2.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn expand_fixed(fields: &[u32], w: u32, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(fields.len(), out.len());
    let nv = fields.len() / 8 * 8;
    let sv = _mm256_set1_ps(scale);
    let shift = _mm_cvtsi32_si128(32 - w as i32);
    let mut i = 0;
    while i < nv {
        let f = _mm256_loadu_si256(fields.as_ptr().add(i) as *const __m256i);
        let c = _mm256_sra_epi32(_mm256_sll_epi32(f, shift), shift);
        let v = _mm256_mul_ps(_mm256_cvtepi32_ps(c), sv);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), v);
        i += 8;
    }
    scalar::expand_fixed(&fields[nv..], w, scale, &mut out[nv..]);
}
