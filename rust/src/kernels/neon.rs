//! NEON (aarch64) backend: 4-wide f32 lanes, using register *pairs* for the
//! shared 8-lane dot accumulation order so results are bit-identical to
//! `scalar`. Plain `fmul` + `fadd` throughout — never `vfmaq_f32`, whose
//! fused rounding changes bits.
//!
//! # Safety
//!
//! Every function is `#[target_feature(enable = "neon")]` and must only be
//! called when the host supports NEON; the `kernels` dispatch layer
//! guarantees this (a backend is only activated when `supported()` holds).

use core::arch::aarch64::*;
use std::ops::Range;

use super::scalar;

/// Reduces an 8-lane accumulator held as two quad registers
/// (`lo` = lanes 0..4, `hi` = lanes 4..8) with the scalar reference tree:
/// `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))`.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn reduce8(lo: float32x4_t, hi: float32x4_t) -> f32 {
    let q = vaddq_f32(lo, hi); // [q0 q1 q2 q3]
    let r = vadd_f32(vget_low_f32(q), vget_high_f32(q)); // [q0+q2, q1+q3]
    vget_lane_f32::<0>(r) + vget_lane_f32::<1>(r)
}

/// # Safety
/// Caller must ensure the host supports NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 8;
    let mut lo = vdupq_n_f32(0.0);
    let mut hi = vdupq_n_f32(0.0);
    for c in 0..chunks {
        let i = c * 8;
        lo = vaddq_f32(
            lo,
            vmulq_f32(vld1q_f32(x.as_ptr().add(i)), vld1q_f32(y.as_ptr().add(i))),
        );
        hi = vaddq_f32(
            hi,
            vmulq_f32(
                vld1q_f32(x.as_ptr().add(i + 4)),
                vld1q_f32(y.as_ptr().add(i + 4)),
            ),
        );
    }
    let mut s = reduce8(lo, hi);
    for i in chunks * 8..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// # Safety
/// Caller must ensure the host supports NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn dot_acc(x: &[f32], y: &[f32], lane: &mut [f32; 8]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len() % 8, 0);
    // resume the 8-lane accumulator (register pair) from `lane`; per lane
    // the update order matches the scalar `lane[l] += x*y` loop exactly
    let mut lo = vld1q_f32(lane.as_ptr());
    let mut hi = vld1q_f32(lane.as_ptr().add(4));
    let mut i = 0;
    while i < x.len() {
        lo = vaddq_f32(
            lo,
            vmulq_f32(vld1q_f32(x.as_ptr().add(i)), vld1q_f32(y.as_ptr().add(i))),
        );
        hi = vaddq_f32(
            hi,
            vmulq_f32(
                vld1q_f32(x.as_ptr().add(i + 4)),
                vld1q_f32(y.as_ptr().add(i + 4)),
            ),
        );
        i += 8;
    }
    vst1q_f32(lane.as_mut_ptr(), lo);
    vst1q_f32(lane.as_mut_ptr().add(4), hi);
}

/// # Safety
/// Caller must ensure the host supports NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_bt_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    let chunks = k / 8;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        let mut j = 0;
        // 2-column panels (4 quad accumulators) share each A load; every
        // column is still the exact `dot` order.
        while j + 2 <= n {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let mut c0l = vdupq_n_f32(0.0);
            let mut c0h = vdupq_n_f32(0.0);
            let mut c1l = vdupq_n_f32(0.0);
            let mut c1h = vdupq_n_f32(0.0);
            for c in 0..chunks {
                let off = c * 8;
                let al = vld1q_f32(arow.as_ptr().add(off));
                let ah = vld1q_f32(arow.as_ptr().add(off + 4));
                c0l = vaddq_f32(c0l, vmulq_f32(al, vld1q_f32(b0.as_ptr().add(off))));
                c0h = vaddq_f32(c0h, vmulq_f32(ah, vld1q_f32(b0.as_ptr().add(off + 4))));
                c1l = vaddq_f32(c1l, vmulq_f32(al, vld1q_f32(b1.as_ptr().add(off))));
                c1h = vaddq_f32(c1h, vmulq_f32(ah, vld1q_f32(b1.as_ptr().add(off + 4))));
            }
            let mut s0 = reduce8(c0l, c0h);
            let mut s1 = reduce8(c1l, c1h);
            for t in chunks * 8..k {
                let av = arow[t];
                s0 += av * b0[t];
                s1 += av * b1[t];
            }
            orow[j] = s0;
            orow[j + 1] = s1;
            j += 2;
        }
        while j < n {
            orow[j] = dot(arow, &b[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

/// # Safety
/// Caller must ensure the host supports NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn gemm_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    let jv = n / 4 * 4;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let a0s = arow[kk];
            let a1s = arow[kk + 1];
            let a2s = arow[kk + 2];
            let a3s = arow[kk + 3];
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b[(kk + 3) * n..(kk + 4) * n];
            let a0 = vdupq_n_f32(a0s);
            let a1 = vdupq_n_f32(a1s);
            let a2 = vdupq_n_f32(a2s);
            let a3 = vdupq_n_f32(a3s);
            let mut j = 0;
            while j < jv {
                // same association as scalar: ((a0*b0 + a1*b1) + a2*b2) + a3*b3
                let mut s = vmulq_f32(a0, vld1q_f32(b0.as_ptr().add(j)));
                s = vaddq_f32(s, vmulq_f32(a1, vld1q_f32(b1.as_ptr().add(j))));
                s = vaddq_f32(s, vmulq_f32(a2, vld1q_f32(b2.as_ptr().add(j))));
                s = vaddq_f32(s, vmulq_f32(a3, vld1q_f32(b3.as_ptr().add(j))));
                let o = vaddq_f32(vld1q_f32(orow.as_ptr().add(j)), s);
                vst1q_f32(orow.as_mut_ptr().add(j), o);
                j += 4;
            }
            for j in jv..n {
                orow[j] += a0s * b0[j] + a1s * b1[j] + a2s * b2[j] + a3s * b3[j];
            }
            kk += 4;
        }
        while kk < k {
            let avs = arow[kk];
            let brow = &b[kk * n..(kk + 1) * n];
            let av = vdupq_n_f32(avs);
            let mut j = 0;
            while j < jv {
                let o = vaddq_f32(
                    vld1q_f32(orow.as_ptr().add(j)),
                    vmulq_f32(av, vld1q_f32(brow.as_ptr().add(j))),
                );
                vst1q_f32(orow.as_mut_ptr().add(j), o);
                j += 4;
            }
            for j in jv..n {
                orow[j] += avs * brow[j];
            }
            kk += 1;
        }
    }
}

/// # Safety
/// Caller must ensure the host supports NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn expand_bfp(fields: &[u32], blk_scale: f32, out: &mut [f32]) {
    debug_assert_eq!(fields.len(), out.len());
    let nv = fields.len() / 4 * 4;
    let scale = vdupq_n_f32(blk_scale);
    let one = vdupq_n_u32(1);
    let mut i = 0;
    while i < nv {
        let f = vld1q_u32(fields.as_ptr().add(i));
        let mm = vshrq_n_u32::<1>(f);
        let v = vmulq_f32(vcvtq_f32_u32(mm), scale);
        // negate by sign-bit XOR: identical to scalar `-v`, including -0.0
        let sgn = vshlq_n_u32::<31>(vandq_u32(f, one));
        let r = vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(v), sgn));
        vst1q_f32(out.as_mut_ptr().add(i), r);
        i += 4;
    }
    scalar::expand_bfp(&fields[nv..], blk_scale, &mut out[nv..]);
}

/// # Safety
/// Caller must ensure the host supports NEON.
#[target_feature(enable = "neon")]
pub(crate) unsafe fn expand_fixed(fields: &[u32], w: u32, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(fields.len(), out.len());
    let nv = fields.len() / 4 * 4;
    let sv = vdupq_n_f32(scale);
    let sh = 32 - w as i32;
    // SSHL: positive shift = left, negative = truncating arithmetic right
    let lsh = vdupq_n_s32(sh);
    let rsh = vdupq_n_s32(-sh);
    let mut i = 0;
    while i < nv {
        let f = vreinterpretq_s32_u32(vld1q_u32(fields.as_ptr().add(i)));
        let c = vshlq_s32(vshlq_s32(f, lsh), rsh);
        let v = vmulq_f32(vcvtq_f32_s32(c), sv);
        vst1q_f32(out.as_mut_ptr().add(i), v);
        i += 4;
    }
    scalar::expand_fixed(&fields[nv..], w, scale, &mut out[nv..]);
}
