//! Portable reference implementations of the kernel primitives.
//!
//! This is the bit-identity oracle: the SIMD backends (`avx2`, `neon`) must
//! reproduce these results exactly, which is why `dot` is written in the
//! lane-structured form a vector register computes naturally (8 independent
//! accumulators, fixed reduction tree) rather than as a single serial chain.

use std::ops::Range;

/// Lane count of the shared dot-product accumulation order (one AVX2
/// register, or a NEON register pair).
pub(crate) const LANES: usize = 8;

/// The shared 8-lane reduction tree: `((l0+l4) + (l2+l6)) + ((l1+l5) +
/// (l3+l7))`. Scalar arithmetic — every backend reduces through this exact
/// association, which is why it lives here and is reused directly by the
/// streaming fused-dot path.
#[inline]
pub(crate) fn reduce8(lane: &[f32; LANES]) -> f32 {
    let q0 = lane[0] + lane[4];
    let q1 = lane[1] + lane[5];
    let q2 = lane[2] + lane[6];
    let q3 = lane[3] + lane[7];
    (q0 + q2) + (q1 + q3)
}

/// See `kernels::dot` for the contract this implementation defines.
#[inline]
pub(crate) fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / LANES;
    let mut lane = [0.0f32; LANES];
    for c in 0..chunks {
        let i = c * LANES;
        for l in 0..LANES {
            lane[l] += x[i + l] * y[i + l];
        }
    }
    let mut s = reduce8(&lane);
    for i in chunks * LANES..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// See `kernels::dot_acc`: the lane-accumulation phase of [`dot`] in
/// streaming form. Both slice lengths must be equal and a multiple of
/// [`LANES`]; `lane[l]` receives `x[i] * y[i]` for every `i ≡ l (mod 8)`,
/// in increasing-`i` order — exactly the per-lane term sequence of [`dot`],
/// so feeding consecutive lane-aligned chunks and finishing with
/// [`reduce8`] plus a serial tail reproduces `dot` bit for bit.
#[inline]
pub(crate) fn dot_acc(x: &[f32], y: &[f32], lane: &mut [f32; LANES]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len() % LANES, 0);
    for c in 0..x.len() / LANES {
        let i = c * LANES;
        for l in 0..LANES {
            lane[l] += x[i + l] * y[i + l];
        }
    }
}

/// See `kernels::gemm_bt_rows`: one [`dot`] per output element.
pub(crate) fn gemm_bt_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// See `kernels::gemm_rows`: i-k-j broadcast order, k unrolled by 4, each
/// output column updated elementwise (no cross-column reduction).
pub(crate) fn gemm_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    let row0 = rows.start;
    for i in rows {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[(i - row0) * n..(i - row0 + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let a0 = arow[kk];
            let a1 = arow[kk + 1];
            let a2 = arow[kk + 2];
            let a3 = arow[kk + 3];
            let b0 = &b[kk * n..(kk + 1) * n];
            let b1 = &b[(kk + 1) * n..(kk + 2) * n];
            let b2 = &b[(kk + 2) * n..(kk + 3) * n];
            let b3 = &b[(kk + 3) * n..(kk + 4) * n];
            for j in 0..n {
                orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        while kk < k {
            let av = arow[kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
            kk += 1;
        }
    }
}

/// See `kernels::expand_bfp`: field = `(mantissa << 1) | sign`.
#[inline]
pub(crate) fn expand_bfp(fields: &[u32], blk_scale: f32, out: &mut [f32]) {
    debug_assert_eq!(fields.len(), out.len());
    for (&f, x) in fields.iter().zip(out.iter_mut()) {
        let v = (f >> 1) as f32 * blk_scale;
        *x = if f & 1 == 1 { -v } else { v };
    }
}

/// See `kernels::expand_fixed`: raw `w`-bit two's-complement fields.
#[inline]
pub(crate) fn expand_fixed(fields: &[u32], w: u32, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(fields.len(), out.len());
    let shift = 32 - w;
    for (&f, x) in fields.iter().zip(out.iter_mut()) {
        let c = ((f << shift) as i32) >> shift;
        *x = c as f32 * scale;
    }
}
