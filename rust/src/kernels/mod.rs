//! Runtime-dispatched SIMD microkernels for the two hot primitives behind
//! every matmul in the crate: the GEMM inner loops (`gemm_bt_rows`,
//! `gemm_rows`, [`dot`]) and block dequantisation field expansion
//! (`expand_bfp`, `expand_fixed`, used by `QTensor::decode_row_into`).
//!
//! # Backends
//!
//! A [`Backend`] is selected once at startup by hardware feature detection —
//! AVX2 on x86_64, NEON on aarch64 — with the scalar implementation kept as
//! the always-available reference. The `BBQ_ISA` environment variable
//! (`scalar`, `avx2`, `neon`) overrides detection; an unknown or
//! unsupported-on-this-host value panics loudly rather than silently falling
//! back, so CI lanes cannot rot. Tests force a backend in-process with
//! [`with_isa`].
//!
//! # Bit-identity contract
//!
//! Every backend produces **bit-identical** f32 results, not merely close
//! ones. This is what makes the crate's per-format exactness suites valid
//! across ISAs, and it is achieved by construction:
//!
//! - No FMA anywhere: each term is one f32 multiply then one f32 add, in
//!   every backend, because fused rounding changes bits.
//! - [`dot`] (and therefore `gemm_bt_rows`, which computes one `dot` per
//!   output element) uses a fixed lane-structured accumulation order: 8
//!   independent lane accumulators over `k / 8` chunks, a fixed reduction
//!   tree `(l0+l4) + (l2+l6)` / `(l1+l5) + (l3+l7)`, then a serial tail for
//!   `k % 8`. The scalar reference implements exactly this order, so an
//!   8-wide AVX2 accumulator (or a NEON register pair) reproduces it lane
//!   for lane.
//! - `gemm_rows` is elementwise across the output row (no cross-lane
//!   reduction), so vectorising over columns is bit-exact by IEEE-754
//!   determinism of per-lane mul/add.
//! - The expand kernels negate via sign-bit XOR, which is exactly f32
//!   negation (including `-0.0`), and convert integers with round-to-nearest
//!   just like scalar `as f32`.
//!
//! Because all backends agree bitwise, the process-global test override in
//! [`with_isa`] is safe even while unrelated threads (e.g. the worker pool)
//! keep computing: they may observe the forced backend, but the numbers they
//! produce do not change.

pub(crate) mod scalar;

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

use std::ops::Range;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// A kernel ISA backend. All variants exist on every platform (so CLI
/// parsing and error messages are uniform); [`supported`] says whether the
/// current host can actually run one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable reference implementation; always available, and the
    /// bit-identity oracle the SIMD lanes are tested against.
    Scalar,
    /// 8-wide f32 via `std::arch::x86_64` AVX2 intrinsics.
    Avx2,
    /// 4-wide f32 (register pairs for the 8-lane dot) via
    /// `std::arch::aarch64` NEON intrinsics.
    Neon,
}

impl Backend {
    /// Lower-case name as accepted by `BBQ_ISA` and reported in metrics.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }

    /// Inverse of [`Backend::name`]; `None` for unknown strings.
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "scalar" => Some(Backend::Scalar),
            "avx2" => Some(Backend::Avx2),
            "neon" => Some(Backend::Neon),
            _ => None,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            Backend::Scalar => 0,
            Backend::Avx2 => 1,
            Backend::Neon => 2,
        }
    }

    fn from_u8(v: u8) -> Backend {
        match v {
            0 => Backend::Scalar,
            1 => Backend::Avx2,
            _ => Backend::Neon,
        }
    }
}

/// Whether this host can execute `b`. Scalar is always supported; the SIMD
/// backends require both the matching architecture and the runtime CPU
/// feature.
pub fn supported(b: Backend) -> bool {
    match b {
        Backend::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        _ => false,
    }
}

/// The best backend the hardware supports, ignoring `BBQ_ISA` and
/// [`with_isa`] overrides. Used by observability surfaces that want to
/// report "what the machine has" next to "what is active".
pub fn detected() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Backend::Neon;
        }
    }
    Backend::Scalar
}

/// Every backend the current host supports, scalar first. Bit-identity
/// tests iterate this so they exercise whatever SIMD lane exists without
/// failing on hardware that has none.
pub fn supported_backends() -> Vec<Backend> {
    [Backend::Scalar, Backend::Avx2, Backend::Neon]
        .into_iter()
        .filter(|&b| supported(b))
        .collect()
}

fn startup() -> Backend {
    match std::env::var("BBQ_ISA") {
        Ok(v) if !v.trim().is_empty() => {
            let v = v.trim();
            let b = Backend::parse(v).unwrap_or_else(|| {
                panic!("BBQ_ISA={v}: unknown ISA (expected scalar, avx2 or neon)")
            });
            assert!(
                supported(b),
                "BBQ_ISA={v}: ISA not supported on this host (detected {})",
                detected().name()
            );
            b
        }
        _ => detected(),
    }
}

static STARTUP: OnceLock<Backend> = OnceLock::new();

/// `u8::MAX` = no override; otherwise `Backend::as_u8` of the forced lane.
static FORCE: AtomicU8 = AtomicU8::new(u8::MAX);
/// Serialises [`with_isa`] sections so concurrent forcing tests cannot
/// interleave their overrides.
static FORCE_LOCK: Mutex<()> = Mutex::new(());

/// The backend all kernel calls currently dispatch to: the [`with_isa`]
/// override if one is active, else the startup selection (`BBQ_ISA` when
/// set, hardware detection otherwise).
pub fn active() -> Backend {
    match FORCE.load(Ordering::Relaxed) {
        u8::MAX => *STARTUP.get_or_init(startup),
        v => Backend::from_u8(v),
    }
}

/// Runs `f` with kernel dispatch forced to `b`, restoring the previous
/// selection afterwards (also on panic).
///
/// The override is process-global — worker-pool threads doing the actual
/// GEMM work must observe it too — and sections are serialised by an
/// internal mutex, so concurrent tests queue rather than trample each
/// other. Not reentrant: nesting `with_isa` inside `with_isa` deadlocks.
///
/// # Panics
///
/// Panics if `b` is not [`supported`] on this host.
pub fn with_isa<R>(b: Backend, f: impl FnOnce() -> R) -> R {
    assert!(
        supported(b),
        "with_isa({}): ISA not supported on this host (detected {})",
        b.name(),
        detected().name()
    );
    let _lock = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            FORCE.store(u8::MAX, Ordering::SeqCst);
        }
    }
    FORCE.store(b.as_u8(), Ordering::SeqCst);
    let _reset = Reset;
    f()
}

/// Lane-structured dot product — the crate's single dot-product reduction
/// order, shared bit-for-bit by every backend.
///
/// Semantics (the contract SIMD lanes must reproduce): 8 independent lane
/// accumulators walk `len / 8` chunks in order (`lane[l] += x[8c+l] *
/// y[8c+l]`, one multiply then one add per term, no FMA); lanes reduce
/// through the fixed tree `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))`; the
/// `len % 8` tail is added serially.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot(x, y) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot(x, y) },
        _ => scalar::dot(x, y),
    }
}

/// Lane count of the shared dot accumulation order (re-exported from the
/// scalar reference for callers that stream into [`dot_acc`]).
pub(crate) use scalar::LANES;

/// The shared 8-lane reduction tree of [`dot`], for callers that finish a
/// [`dot_acc`] accumulator themselves. Scalar arithmetic — identical on
/// every backend by construction.
#[inline]
pub(crate) fn reduce8(lane: &[f32; LANES]) -> f32 {
    scalar::reduce8(lane)
}

/// Streaming form of [`dot`]'s lane-accumulation phase: `lane[l] +=
/// x[i] * y[i]` for `i ≡ l (mod 8)`, in increasing-`i` order. Both slices
/// must have equal length, a multiple of 8. Feeding consecutive
/// lane-aligned chunks of a conceptual longer vector and then finishing
/// with [`reduce8`] plus a serial tail reproduces [`dot`] on that vector
/// bit for bit — this is what lets the fused packed-weight dot consume
/// decoded fields slab by slab without a full-row staging buffer.
#[inline]
pub(crate) fn dot_acc(x: &[f32], y: &[f32], lane: &mut [f32; LANES]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::dot_acc(x, y, lane) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::dot_acc(x, y, lane) },
        _ => scalar::dot_acc(x, y, lane),
    }
}

/// B-transposed GEMM over a row range: `out[i - rows.start][j] =`
/// [`dot`]`(a[i], b[j])` for `i in rows`, with `a: [?, k]` row-major,
/// `b: [n, k]` row-major (i.e. Bᵀ), `out: [rows.len(), n]`. Every output
/// element is one `dot`, so results are independent of how callers
/// partition rows or columns across threads or panels.
pub(crate) fn gemm_bt_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::gemm_bt_rows(a, b, out, rows, k, n) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::gemm_bt_rows(a, b, out, rows, k, n) },
        _ => scalar::gemm_bt_rows(a, b, out, rows, k, n),
    }
}

/// Row-major GEMM over a row range of A (`a: [?, k]`, `b: [k, n]`,
/// `out: [rows.len(), n]`, accumulating into `out`). The i–k–j broadcast
/// order is elementwise across each output row — per column `j` the update
/// order is `out[j] += ((a0*b0[j] + a1*b1[j]) + a2*b2[j]) + a3*b3[j]` for
/// each unrolled group of four k-steps, then `out[j] += a*b[j]` for the
/// remainder — so vector lanes across `j` are bit-exact by construction.
pub(crate) fn gemm_rows(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    rows: Range<usize>,
    k: usize,
    n: usize,
) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::gemm_rows(a, b, out, rows, k, n) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::gemm_rows(a, b, out, rows, k, n) },
        _ => scalar::gemm_rows(a, b, out, rows, k, n),
    }
}

/// Expands BFP-style fields into f32: each field packs `(mantissa << 1) |
/// sign` (sign in the LSB, matching the bit-stream layout), and the output
/// is `±(mantissa as f32 * blk_scale)` with the sign applied as a sign-bit
/// XOR. `blk_scale` is the block's decoded shared-exponent scale.
pub(crate) fn expand_bfp(fields: &[u32], blk_scale: f32, out: &mut [f32]) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::expand_bfp(fields, blk_scale, out) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::expand_bfp(fields, blk_scale, out) },
        _ => scalar::expand_bfp(fields, blk_scale, out),
    }
}

/// Expands raw `w`-bit two's-complement fields into f32: sign-extend to
/// i32, convert (round-to-nearest, same as `as f32`), multiply by `scale`.
pub(crate) fn expand_fixed(fields: &[u32], w: u32, scale: f32, out: &mut [f32]) {
    debug_assert!((1..=32).contains(&w));
    match active() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => unsafe { avx2::expand_fixed(fields, w, scale, out) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { neon::expand_fixed(fields, w, scale, out) },
        _ => scalar::expand_fixed(fields, w, scale, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    }

    #[test]
    fn backend_name_parse_roundtrip() {
        for b in [Backend::Scalar, Backend::Avx2, Backend::Neon] {
            assert_eq!(Backend::parse(b.name()), Some(b));
        }
        assert_eq!(Backend::parse("avx512"), None);
        assert_eq!(Backend::parse(""), None);
    }

    #[test]
    fn active_backend_is_supported() {
        assert!(supported(active()));
        assert!(supported(detected()));
        assert_eq!(supported_backends()[0], Backend::Scalar);
    }

    #[test]
    fn with_isa_forces_and_restores() {
        let ambient = active();
        with_isa(Backend::Scalar, || {
            assert_eq!(active(), Backend::Scalar);
        });
        assert_eq!(active(), ambient);
        // restore also happens on panic
        let r = std::panic::catch_unwind(|| {
            with_isa(Backend::Scalar, || panic!("boom"));
        });
        assert!(r.is_err());
        assert_eq!(active(), ambient);
    }

    #[test]
    fn dot_exact_on_integers() {
        // Integer-valued inputs are order-insensitive, so this pins the
        // value itself rather than the reduction order.
        let x: Vec<f32> = (1..=13).map(|i| i as f32).collect();
        let y = vec![1.0f32; 13];
        assert_eq!(dot(&x, &y), 91.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_follows_documented_lane_order() {
        // One full 8-chunk plus a 3-element tail, non-associative values:
        // recompute the documented order by hand and demand exact equality.
        let x: Vec<f32> = (0..11).map(|i| 0.1 + 0.37 * i as f32).collect();
        let y: Vec<f32> = (0..11).map(|i| 1.9 - 0.21 * i as f32).collect();
        let mut lane = [0.0f32; 8];
        for l in 0..8 {
            lane[l] += x[l] * y[l];
        }
        let (q0, q1, q2, q3) = (
            lane[0] + lane[4],
            lane[1] + lane[5],
            lane[2] + lane[6],
            lane[3] + lane[7],
        );
        let mut want = (q0 + q2) + (q1 + q3);
        for i in 8..11 {
            want += x[i] * y[i];
        }
        assert_eq!(scalar::dot(&x, &y), want);
        assert_eq!(dot(&x, &y), want);
    }

    #[test]
    fn dot_bitwise_identical_across_backends() {
        let mut rng = Pcg32::new(7);
        for len in [0, 1, 5, 7, 8, 9, 15, 16, 17, 31, 64, 67, 130] {
            let x = randv(&mut rng, len);
            let y = randv(&mut rng, len);
            let want = with_isa(Backend::Scalar, || dot(&x, &y));
            for b in supported_backends() {
                let got = with_isa(b, || dot(&x, &y));
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "dot len={len} backend={}",
                    b.name()
                );
            }
        }
    }

    #[test]
    fn dot_acc_streams_to_dot_bits() {
        // consuming lane-aligned chunks then reducing + serial tail must
        // equal one `dot` call over the concatenation, on every backend
        let mut rng = Pcg32::new(13);
        for len in [8, 16, 21, 37, 64, 70, 130] {
            let x = randv(&mut rng, len);
            let y = randv(&mut rng, len);
            let want = with_isa(Backend::Scalar, || dot(&x, &y));
            let ne = len / 8 * 8;
            for b in supported_backends() {
                let got = with_isa(b, || {
                    let mut lane = [0.0f32; LANES];
                    // split the lane-eligible region into two aligned chunks
                    let mid = ne / 2 / 8 * 8;
                    dot_acc(&x[..mid], &y[..mid], &mut lane);
                    dot_acc(&x[mid..ne], &y[mid..ne], &mut lane);
                    let mut s = reduce8(&lane);
                    for i in ne..len {
                        s += x[i] * y[i];
                    }
                    s
                });
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "dot_acc len={len} backend={}",
                    b.name()
                );
            }
        }
    }

    #[test]
    fn gemms_bitwise_identical_across_backends() {
        let mut rng = Pcg32::new(11);
        // ragged shapes: k and n straddle every lane width and panel size
        for (m, k, n) in [(1, 7, 5), (2, 17, 9), (3, 33, 13), (5, 64, 31), (4, 70, 66)] {
            let a = randv(&mut rng, m * k);
            let bt = randv(&mut rng, n * k); // [n, k] for gemm_bt_rows
            let bk = randv(&mut rng, k * n); // [k, n] for gemm_rows
            let mut want_bt = vec![0.0f32; m * n];
            let mut want_r = vec![0.0f32; m * n];
            with_isa(Backend::Scalar, || {
                gemm_bt_rows(&a, &bt, &mut want_bt, 0..m, k, n);
                gemm_rows(&a, &bk, &mut want_r, 0..m, k, n);
            });
            for b in supported_backends() {
                let mut got_bt = vec![0.0f32; m * n];
                let mut got_r = vec![0.0f32; m * n];
                with_isa(b, || {
                    gemm_bt_rows(&a, &bt, &mut got_bt, 0..m, k, n);
                    gemm_rows(&a, &bk, &mut got_r, 0..m, k, n);
                });
                for i in 0..m * n {
                    assert_eq!(
                        got_bt[i].to_bits(),
                        want_bt[i].to_bits(),
                        "gemm_bt_rows m={m} k={k} n={n} i={i} backend={}",
                        b.name()
                    );
                    assert_eq!(
                        got_r[i].to_bits(),
                        want_r[i].to_bits(),
                        "gemm_rows m={m} k={k} n={n} i={i} backend={}",
                        b.name()
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_bt_rows_is_partition_invariant() {
        // dot-per-output semantics: any row/column partition yields the
        // same bits, which is what lets threaded callers chunk freely.
        let mut rng = Pcg32::new(23);
        let (m, k, n) = (6, 19, 11);
        let a = randv(&mut rng, m * k);
        let bt = randv(&mut rng, n * k);
        let mut whole = vec![0.0f32; m * n];
        gemm_bt_rows(&a, &bt, &mut whole, 0..m, k, n);
        let mut split = vec![0.0f32; m * n];
        gemm_bt_rows(&a, &bt, &mut split[..2 * n], 0..2, k, n);
        gemm_bt_rows(&a, &bt, &mut split[2 * n..], 2..m, k, n);
        assert_eq!(
            whole.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            split.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn expand_kernels_bitwise_identical_across_backends() {
        let mut rng = Pcg32::new(31);
        for len in [1, 3, 8, 15, 16, 21, 64] {
            // bfp: (mantissa << 1) | sign fields with a 5-bit mantissa
            let bfp: Vec<u32> = (0..len).map(|_| rng.next_u32() & 0x3f).collect();
            // fixed: raw 6-bit two's-complement fields
            let fixed: Vec<u32> = (0..len).map(|_| rng.next_u32() & 0x3f).collect();
            let mut want_b = vec![0.0f32; len];
            let mut want_f = vec![0.0f32; len];
            with_isa(Backend::Scalar, || {
                expand_bfp(&bfp, 0.125, &mut want_b);
                expand_fixed(&fixed, 6, 0.25, &mut want_f);
            });
            for b in supported_backends() {
                let mut got_b = vec![0.0f32; len];
                let mut got_f = vec![0.0f32; len];
                with_isa(b, || {
                    expand_bfp(&bfp, 0.125, &mut got_b);
                    expand_fixed(&fixed, 6, 0.25, &mut got_f);
                });
                for i in 0..len {
                    assert_eq!(
                        got_b[i].to_bits(),
                        want_b[i].to_bits(),
                        "expand_bfp len={len} i={i} backend={} field={:#x}",
                        b.name(),
                        bfp[i]
                    );
                    assert_eq!(
                        got_f[i].to_bits(),
                        want_f[i].to_bits(),
                        "expand_fixed len={len} i={i} backend={} field={:#x}",
                        b.name(),
                        fixed[i]
                    );
                }
            }
        }
    }

    #[test]
    fn expand_bfp_keeps_negative_zero() {
        // field 0b1 = mantissa 0, sign set -> scalar produces -0.0; SIMD
        // sign-XOR must too (a naive "0 - v" style lane would give +0.0).
        for b in supported_backends() {
            let mut out = [0.0f32; 1];
            with_isa(b, || expand_bfp(&[0b1], 0.5, &mut out));
            assert_eq!(out[0].to_bits(), (-0.0f32).to_bits(), "{}", b.name());
        }
    }
}
