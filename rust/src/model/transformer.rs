//! OPT-style decoder forward pass with all eight GEMMs quantisable
//! (Algorithm 2 of the paper). Pre-LN residual blocks, multi-head causal
//! attention, GELU MLP, tied-embedding LM head (kept FP32, as the paper
//! quantises the per-layer GEMMs).

use super::attention;
use super::config::{ModelConfig, PosEncoding};
use super::params::{PackedLayerParams, PackedWeight, Params, WeightMemory};
use super::plan::{GemmMode, QuantPlan, WeightStore};
use super::rope::apply_rope;
use crate::quant::config::QFormat;
use crate::quant::qtensor::encode;
use crate::quant::{fake_quant, fake_quant_in_place, quant_act};
use crate::tensor::matmul::matmul_bt;
use crate::tensor::Tensor;
use crate::util::stats::Welford;

/// Activation/weight statistics collector (Figure 1/4/5).
#[derive(Clone, Debug, Default)]
pub struct ActStats {
    /// (tensor name, layer) → online variance
    pub acc: std::collections::BTreeMap<(String, usize), Welford>,
    /// (tensor name, layer) → per-channel |x| max (SmoothQuant calibration)
    pub chan_absmax: std::collections::BTreeMap<(String, usize), Vec<f32>>,
}

impl ActStats {
    pub fn record(&mut self, name: &str, layer: usize, data: &[f32]) {
        self.acc
            .entry((name.to_string(), layer))
            .or_default()
            .push_slice(data);
    }

    /// Per-layer variance series for one tensor name.
    pub fn series(&self, name: &str, n_layers: usize) -> Vec<f64> {
        (0..n_layers)
            .map(|l| {
                self.acc
                    .get(&(name.to_string(), l))
                    .map(|w| w.variance())
                    .unwrap_or(f64::NAN)
            })
            .collect()
    }

    /// Track per-channel absmax of a [rows, cols] tensor.
    pub fn record_channels(&mut self, name: &str, layer: usize, t: &Tensor) {
        let cols = *t.shape.last().unwrap();
        let e = self
            .chan_absmax
            .entry((name.to_string(), layer))
            .or_insert_with(|| vec![0.0; cols]);
        for row in t.data.chunks(cols) {
            for (m, &x) in e.iter_mut().zip(row) {
                let a = x.abs();
                if a > *m {
                    *m = a;
                }
            }
        }
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.acc.keys().map(|(n, _)| n.clone()).collect();
        v.sort();
        v.dedup();
        v
    }
}

pub struct Model {
    pub params: Params,
    pub plan: QuantPlan,
    prepared: Vec<PackedLayerParams>,
}

/// Prepare one weight for serving: transpose to [out, in] so blocks run
/// along the contraction dim, optionally pull the top-`outlier_frac`
/// largest-|w| weights into an exact f32 side table
/// ([`crate::quant::outlier`]), then either bit-pack the residual (the
/// serving default for quantised fake-quant plans — resident memory
/// becomes the packed payload) or keep a dequantised f32 copy of it. Both
/// storages quantise the *same* outlier-zeroed residual and attach the
/// same table, so they stay bit-identical (tested in
/// `tests/packed_serving.rs` / `tests/plan_artifacts.rs`). The LLM.int8()
/// mode does its own runtime decomposition on unmodified dense weights
/// and never extracts.
fn prep_weight(
    w: &Tensor,
    fmt: QFormat,
    mode: GemmMode,
    store: WeightStore,
    outlier_frac: f32,
) -> PackedWeight {
    let mut wt = w.t();
    if fmt == QFormat::Fp32 {
        return PackedWeight::new_dense(wt);
    }
    let overlay = if outlier_frac > 0.0 && matches!(mode, GemmMode::FakeQuant) {
        Some(crate::quant::outlier::extract(&mut wt, outlier_frac))
    } else {
        None
    };
    let pw = match (store, mode) {
        (WeightStore::PackedAuto, GemmMode::FakeQuant) => {
            PackedWeight::new_packed(encode(&wt, fmt))
        }
        _ => PackedWeight::new_dense(fake_quant(&wt, fmt)),
    };
    match overlay {
        Some(t) => pw.with_outliers(t),
        None => pw,
    }
}

impl Model {
    fn prepare(params: &Params, plan: &QuantPlan) -> Vec<PackedLayerParams> {
        let p = |w: &Tensor, li: usize, g: u8| -> PackedWeight {
            prep_weight(
                w,
                plan.site(li, g).weight,
                plan.mode,
                plan.store,
                plan.outliers,
            )
        };
        params
            .layers
            .iter()
            .enumerate()
            .map(|(li, l)| PackedLayerParams {
                wq_t: p(&l.wq, li, 1),
                wk_t: p(&l.wk, li, 2),
                wv_t: p(&l.wv, li, 3),
                wo_t: p(&l.wo, li, 6),
                w1_t: p(&l.w1, li, 7),
                w2_t: p(&l.w2, li, 8),
            })
            .collect()
    }

    pub fn new(params: Params, plan: QuantPlan) -> Model {
        let prepared = Self::prepare(&params, &plan);
        Model {
            params,
            plan,
            prepared,
        }
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.params.cfg
    }

    /// Prepared (transposed + weight-quantised, possibly packed) weight
    /// cache for one layer.
    pub fn prepared(&self, li: usize) -> &PackedLayerParams {
        &self.prepared[li]
    }

    /// Measured resident vs dense-f32 bytes of the prepared weight cache —
    /// the serving-side counterpart of Table 3's memory-density column,
    /// reported by the batched server's metrics.
    pub fn weight_memory(&self) -> WeightMemory {
        let mut m = WeightMemory::default();
        for pl in &self.prepared {
            for w in pl.weights() {
                m.dense_f32_bytes += w.numel() * 4;
                m.resident_bytes += w.resident_bytes();
            }
        }
        m
    }

    /// Build a model by loading and validating a plan-file artifact
    /// ([`super::plan_file`]) against `params.cfg` — the deployment path:
    /// `bbq search-plan` emits the file, `serve --plan` feeds it here.
    pub fn from_plan_file(
        params: Params,
        path: &std::path::Path,
    ) -> Result<Model, super::plan_file::PlanFileError> {
        let plan = super::plan_file::load(path, &params.cfg)?;
        Ok(Model::new(params, plan))
    }

    /// Per-storage-format resident-byte breakdown of the prepared weight
    /// cache, plus the total bytes held in outlier side tables — the
    /// observable memory story of a mixed plan (a single aggregate
    /// [`WeightMemory`] can't show that L0 is 8-bit while L5 is 4-bit).
    /// Keys are [`PackedWeight::store_format_name`] labels, sorted; the
    /// per-format bytes exclude the side tables, so
    /// `Σ per-format + outlier_bytes == weight_memory().resident_bytes`.
    pub fn weight_memory_by_format(&self) -> (Vec<(String, usize)>, usize) {
        let mut by: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
        let mut outlier_bytes = 0usize;
        for pl in &self.prepared {
            for w in pl.weights() {
                *by.entry(w.store_format_name()).or_insert(0) +=
                    w.resident_bytes() - w.outlier_bytes();
                outlier_bytes += w.outlier_bytes();
            }
        }
        (by.into_iter().collect(), outlier_bytes)
    }

    /// Re-plan without copying parameters (mixed-precision search loop).
    pub fn set_plan(&mut self, plan: QuantPlan) {
        self.prepared = Self::prepare(&self.params, &plan);
        self.plan = plan;
    }

    /// Full-sequence forward: tokens → logits [s, vocab].
    pub fn forward(&self, tokens: &[usize], stats: Option<&mut ActStats>) -> Tensor {
        self.forward_from(tokens, 0, stats)
    }

    /// Forward with an explicit start position (for KV-cache decode the
    /// position offsets matter; here used by the full-context path).
    pub fn forward_from(
        &self,
        tokens: &[usize],
        pos0: usize,
        mut stats: Option<&mut ActStats>,
    ) -> Tensor {
        let cfg = &self.params.cfg;
        let (s, d) = (tokens.len(), cfg.d_model);
        assert!(pos0 + s <= cfg.max_seq, "sequence too long");
        // embeddings
        let mut x = Tensor::zeros(&[s, d]);
        for (i, &t) in tokens.iter().enumerate() {
            assert!(t < cfg.vocab_size, "token {t} out of vocab");
            let e = self.params.tok_emb.row(t);
            let xr = x.row_mut(i);
            xr.copy_from_slice(e);
            if cfg.pos == PosEncoding::Learned {
                let p = self.params.pos_emb.row(pos0 + i);
                for (a, &b) in xr.iter_mut().zip(p) {
                    *a += b;
                }
            }
        }
        for li in 0..cfg.n_layers {
            x = self.layer_forward(li, &x, pos0, &mut stats);
        }
        // final LN + tied-embedding head (FP32)
        let xn = x.layer_norm(&self.params.lnf_g, &self.params.lnf_b, cfg.ln_eps);
        matmul_bt(&xn, &self.params.tok_emb)
    }

    fn layer_forward(
        &self,
        li: usize,
        x: &Tensor,
        pos0: usize,
        stats: &mut Option<&mut ActStats>,
    ) -> Tensor {
        let cfg = &self.params.cfg;
        let l = &self.params.layers[li];
        let pl = &self.prepared[li];
        let (s, d) = x.dims2();
        let h = cfg.n_heads;
        let hd = cfg.head_dim();
        let plan = &self.plan;

        // --- attention block ---
        let xn = x.layer_norm(&l.ln1_g, &l.ln1_b, cfg.ln_eps);
        if let Some(st) = stats.as_deref_mut() {
            st.record("X1", li, &xn.data);
            st.record_channels("X1", li, &xn);
        }
        // ①②③: projections with quantised act + weight
        let proj = |idx: u8, w_t: &PackedWeight| -> Tensor {
            match plan.mode {
                GemmMode::FakeQuant => w_t.matmul_bt(&quant_act(&xn, plan.site(li, idx).act)),
                GemmMode::LlmInt8 { threshold, bits } => {
                    crate::baselines::llm_int8::llm_int8_matmul(&xn, w_t.dense(), threshold, bits)
                }
            }
        };
        let q = proj(1, &pl.wq_t).add_bias(&l.bq);
        let k = proj(2, &pl.wk_t).add_bias(&l.bk);
        let v = proj(3, &pl.wv_t).add_bias(&l.bv);
        let (q, k) = if cfg.pos == PosEncoding::Rope {
            (apply_rope(&q, h, pos0), apply_rope(&k, h, pos0))
        } else {
            (q, k)
        };
        if let Some(st) = stats.as_deref_mut() {
            st.record("Q", li, &q.data);
            st.record("K", li, &k.data);
            st.record("V", li, &v.data);
        }
        let scale = 1.0 / (hd as f32).sqrt();
        let mut ctx = Tensor::zeros(&[s, d]);
        // per-head attention: ④ S = QKᵀ, ⑤ C = softmax(S)·V, both quantised,
        // gathered through strided head views into reused scratch buffers
        // (the shared body in `model::attention`) instead of three fresh
        // Tensors per head per layer. Heads fan out over the worker pool
        // when the layer carries enough work; the serial lane (also the
        // stats-recording lane) runs the identical per-head code, so the
        // thread count never changes the bits.
        let q45 = (plan.site(li, 4), plan.site(li, 5));
        let threads = crate::runtime::pool::available_threads();
        let attn_macs = 2 * s * s * d;
        if stats.is_some() || threads <= 1 || h < 2 || attn_macs < attention::ATTN_PAR_MACS {
            let mut scr = attention::AttnScratch::new();
            let mut a_rec: Vec<f32> = Vec::new();
            for hi in 0..h {
                let rec = if hi == 0 && stats.is_some() {
                    Some(&mut a_rec)
                } else {
                    None
                };
                attention::attn_head_full(
                    &mut scr,
                    &q,
                    &k,
                    &v,
                    s,
                    hi,
                    hd,
                    scale,
                    q45,
                    &mut ctx.data,
                    d,
                    hi * hd,
                    rec,
                );
                if hi == 0 {
                    if let Some(st) = stats.as_deref_mut() {
                        st.record("A", li, &a_rec);
                    }
                }
            }
        } else {
            // contiguous head ranges, one scratch + one [s, range·hd]
            // output per task, stitched into ctx afterwards — allocations
            // stay O(threads) per layer no matter how many heads
            struct HeadTask {
                h0: usize,
                h1: usize,
                out: Vec<f32>,
                scr: attention::AttnScratch,
            }
            let nt = threads.min(h);
            let per = h.div_ceil(nt);
            let mut tasks: Vec<HeadTask> = Vec::with_capacity(nt);
            let mut h0 = 0usize;
            while h0 < h {
                let h1 = (h0 + per).min(h);
                tasks.push(HeadTask {
                    h0,
                    h1,
                    out: vec![0.0f32; s * (h1 - h0) * hd],
                    scr: attention::AttnScratch::new(),
                });
                h0 = h1;
            }
            let (qr, kr, vr) = (&q, &k, &v);
            crate::runtime::pool::run_mut(&mut tasks, nt, |t| {
                let w = (t.h1 - t.h0) * hd;
                for hi in t.h0..t.h1 {
                    attention::attn_head_full(
                        &mut t.scr,
                        qr,
                        kr,
                        vr,
                        s,
                        hi,
                        hd,
                        scale,
                        q45,
                        &mut t.out,
                        w,
                        (hi - t.h0) * hd,
                        None,
                    );
                }
            });
            for t in &tasks {
                let w = (t.h1 - t.h0) * hd;
                for i in 0..s {
                    ctx.data[i * d + t.h0 * hd..i * d + t.h0 * hd + w]
                        .copy_from_slice(&t.out[i * w..(i + 1) * w]);
                }
            }
        }
        if let Some(st) = stats.as_deref_mut() {
            st.record("B_c", li, &ctx.data);
        }
        // ⑥ output projection
        let att_out = match plan.mode {
            GemmMode::FakeQuant => {
                fake_quant_in_place(&mut ctx, plan.site(li, 6).act);
                pl.wo_t.matmul_bt(&ctx)
            }
            GemmMode::LlmInt8 { threshold, bits } => {
                crate::baselines::llm_int8::llm_int8_matmul(&ctx, pl.wo_t.dense(), threshold, bits)
            }
        }
        .add_bias(&l.bo);
        let x = x.add(&att_out);

        // --- MLP block ---
        let xn2 = x.layer_norm(&l.ln2_g, &l.ln2_b, cfg.ln_eps);
        if let Some(st) = stats.as_deref_mut() {
            st.record("X2", li, &xn2.data);
            st.record_channels("X2", li, &xn2);
        }
        // ⑦ fc1
        let hpre = match plan.mode {
            GemmMode::FakeQuant => {
                pl.w1_t.matmul_bt(&quant_act(&xn2, plan.site(li, 7).act))
            }
            GemmMode::LlmInt8 { threshold, bits } => {
                crate::baselines::llm_int8::llm_int8_matmul(&xn2, pl.w1_t.dense(), threshold, bits)
            }
        }
        .add_bias(&l.b1);
        let mut hact = hpre.gelu();
        if let Some(st) = stats.as_deref_mut() {
            st.record("H", li, &hact.data);
        }
        // ⑧ fc2
        let mlp_out = match plan.mode {
            GemmMode::FakeQuant => {
                fake_quant_in_place(&mut hact, plan.site(li, 8).act);
                pl.w2_t.matmul_bt(&hact)
            }
            GemmMode::LlmInt8 { threshold, bits } => {
                crate::baselines::llm_int8::llm_int8_matmul(&hact, pl.w2_t.dense(), threshold, bits)
            }
        }
        .add_bias(&l.b2);
        x.add(&mlp_out)
    }

    /// Record weight variances (Figure 1 lower-right panel).
    pub fn weight_stats(&self) -> ActStats {
        let mut st = ActStats::default();
        for (li, l) in self.params.layers.iter().enumerate() {
            st.record("Wq", li, &l.wq.data);
            st.record("Wk", li, &l.wk.data);
            st.record("Wv", li, &l.wv.data);
            st.record("Wo", li, &l.wo.data);
            st.record("W1", li, &l.w1.data);
            st.record("W2", li, &l.w2.data);
        }
        st
    }

    /// Per-tensor (numel, format) inventory for memory-density accounting.
    /// `seq` sets activation sizes.
    pub fn quant_inventory(&self, seq: usize) -> Vec<(usize, QFormat)> {
        let cfg = &self.params.cfg;
        let mut out = Vec::new();
        for li in 0..cfg.n_layers {
            for g in crate::density::flops::layer_gemms(cfg, seq) {
                let q = self.plan.site(li, g.index as u8);
                out.push((g.act_numel_per_tok * seq, q.act));
                if g.weight_numel > 0 {
                    out.push((g.weight_numel, q.weight));
                } else {
                    // ④⑤ second operand is an activation (K / V)
                    out.push((g.act_numel_per_tok * seq, q.weight));
                }
            }
        }
        out
    }
}

/// Greedy cross-entropy loss of logits vs next-token targets (nats/token).
pub fn cross_entropy(logits: &Tensor, targets: &[usize]) -> f64 {
    let (s, v) = logits.dims2();
    assert_eq!(s, targets.len());
    let mut total = 0.0f64;
    for i in 0..s {
        let row = logits.row(i);
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse = m as f64 + row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln();
        debug_assert!(targets[i] < v);
        total += lse - row[targets[i]] as f64;
    }
    total / s as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::quant::config::presets;

    fn tiny_model(plan: QuantPlan) -> Model {
        let cfg = ModelConfig::preset("nano");
        Model::new(Params::init(&cfg, 42), plan)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(QuantPlan::fp32());
        let logits = m.forward(&[1, 2, 3, 4, 5], None);
        assert_eq!(logits.shape, vec![5, 512]);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causal_mask_prefix_invariance() {
        // logits at position i must not depend on tokens after i
        let m = tiny_model(QuantPlan::fp32());
        let full = m.forward(&[5, 6, 7, 8], None);
        let prefix = m.forward(&[5, 6], None);
        for j in 0..512 {
            assert!(
                (full.row(1)[j] - prefix.row(1)[j]).abs() < 1e-4,
                "position 1 logit {j} differs"
            );
        }
    }

    #[test]
    fn quantised_forward_close_to_fp32_at_8bit() {
        let m32 = tiny_model(QuantPlan::fp32());
        let m8 = tiny_model(QuantPlan::uniform(presets::bfp_w(8)));
        let toks = [3usize, 100, 7, 250, 9, 12];
        let a = m32.forward(&toks, None);
        let b = m8.forward(&toks, None);
        let rel = crate::util::stats::mse(&a.data, &b.data).sqrt()
            / (crate::util::stats::std_dev(&a.data) + 1e-9);
        assert!(rel < 0.1, "rel err {rel}");
    }

    #[test]
    fn packed_store_is_bit_identical_to_dense_store() {
        // the tentpole guarantee: serving from packed payloads changes
        // nothing — all paper tables measured on the dense path stay valid
        let cfg = ModelConfig::preset("nano");
        let params = Params::init(&cfg, 42);
        let toks = [3usize, 100, 7, 250, 9, 12];
        for fmt in [presets::bfp_w(6), presets::bfp_w(4), presets::bm8(), presets::bl8()] {
            let packed = Model::new(
                params.clone(),
                QuantPlan::uniform(fmt).with_store(WeightStore::PackedAuto),
            );
            let dense = Model::new(
                params.clone(),
                QuantPlan::uniform(fmt).with_store(WeightStore::DenseF32),
            );
            assert!(packed.prepared(0).wq_t.is_packed());
            assert!(!dense.prepared(0).wq_t.is_packed());
            let a = packed.forward(&toks, None);
            let b = dense.forward(&toks, None);
            assert_eq!(a.data, b.data, "{}", fmt.name());
        }
    }

    #[test]
    fn outlier_overlay_is_bit_identical_across_stores() {
        // the overlay extracts from the transposed weight BEFORE encoding,
        // so packed and dense stores share the identical residual + table
        let cfg = ModelConfig::preset("nano");
        let params = Params::init(&cfg, 42);
        let toks = [3usize, 100, 7, 250, 9, 12];
        let plan = QuantPlan::uniform(presets::bfp_w(4)).with_outliers(0.005);
        let packed = Model::new(params.clone(), plan.clone());
        let dense = Model::new(params.clone(), plan.clone().with_store(WeightStore::DenseF32));
        assert!(packed.prepared(0).wq_t.outliers().is_some());
        assert!(dense.prepared(0).wq_t.outliers().is_some());
        assert_eq!(
            packed.prepared(0).wq_t.outliers(),
            dense.prepared(0).wq_t.outliers()
        );
        let a = packed.forward(&toks, None);
        let b = dense.forward(&toks, None);
        assert_eq!(a.data, b.data);
        // zero fraction attaches nothing and changes nothing
        let plain = Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(4)));
        let zero = Model::new(
            params.clone(),
            QuantPlan::uniform(presets::bfp_w(4)).with_outliers(0.0),
        );
        assert!(zero.prepared(0).wq_t.outliers().is_none());
        assert_eq!(
            plain.forward(&toks, None).data,
            zero.forward(&toks, None).data
        );
    }

    #[test]
    fn weight_memory_by_format_partitions_resident_bytes() {
        let cfg = ModelConfig::preset("nano");
        let params = Params::init(&cfg, 42);
        let mut plan = QuantPlan::uniform(presets::bfp_w(4)).with_outliers(0.005);
        for l in 0..cfg.n_layers {
            plan.set(l, 7, crate::quant::config::GemmQuant::uniform(presets::bfp_w(8)));
            plan.set(l, 6, crate::quant::config::GemmQuant::fp32());
        }
        let m = Model::new(params, plan);
        let (by_format, outlier_bytes) = m.weight_memory_by_format();
        let names: Vec<&str> = by_format.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"f32"), "{names:?}");
        assert!(names.contains(&"bfp_e8m3n16"), "{names:?}");
        assert!(names.contains(&"bfp_e8m7n16"), "{names:?}");
        assert!(outlier_bytes > 0);
        let total: usize = by_format.iter().map(|(_, b)| b).sum();
        assert_eq!(total + outlier_bytes, m.weight_memory().resident_bytes);
    }

    #[test]
    fn packed_store_shrinks_resident_weights() {
        let m = tiny_model(QuantPlan::uniform(presets::bfp_w(6)));
        let wm = m.weight_memory();
        // BFP6 = 6.5 bits/element → ≥ 4× below f32 (Table 3's "4.9×")
        assert!(
            wm.resident_bytes * 4 <= wm.dense_f32_bytes,
            "resident {} vs f32 {}",
            wm.resident_bytes,
            wm.dense_f32_bytes
        );
        assert!(wm.ratio() > 4.0 && wm.ratio() < 6.0, "{}", wm.ratio());
        let m32 = tiny_model(QuantPlan::fp32());
        assert_eq!(m32.weight_memory().ratio(), 1.0);
    }

    #[test]
    fn stats_collects_all_tensors() {
        let m = tiny_model(QuantPlan::fp32());
        let mut st = ActStats::default();
        m.forward(&[1, 2, 3], Some(&mut st));
        for name in ["X1", "Q", "K", "V", "A", "B_c", "X2", "H"] {
            let series = st.series(name, 2);
            assert!(series.iter().all(|v| v.is_finite()), "{name}: {series:?}");
        }
    }

    #[test]
    fn cross_entropy_sane() {
        // uniform logits → ln(vocab)
        let logits = Tensor::zeros(&[3, 512]);
        let ce = cross_entropy(&logits, &[0, 1, 2]);
        assert!((ce - (512f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn inventory_counts_both_operands() {
        let m = tiny_model(QuantPlan::uniform(presets::bfp_w(6)));
        let inv = m.quant_inventory(16);
        // 8 GEMMs × 2 operands × 2 layers
        assert_eq!(inv.len(), 32);
    }
}
