//! OPT-style decoder substrate: configuration ladder, parameters,
//! quantisation plans, full-sequence forward (Algorithm 2's eight GEMMs),
//! RoPE variant, and KV-cache incremental decoding.

pub mod config;
pub mod kv_cache;
pub mod params;
pub mod plan;
pub mod rope;
pub mod transformer;

pub use config::{ModelConfig, PosEncoding};
pub use params::Params;
pub use plan::{QuantPlan, SiteId, GEMM_NAMES};
pub use transformer::{cross_entropy, ActStats, Model};
