//! OPT-style decoder substrate: configuration ladder, parameters,
//! quantisation plans, full-sequence forward (Algorithm 2's eight GEMMs),
//! RoPE variant, and KV-cache incremental decoding.

pub(crate) mod attention;
pub mod config;
pub mod kv_cache;
pub mod paged;
pub mod params;
pub mod plan;
pub mod rope;
pub mod transformer;

pub use config::{ModelConfig, PosEncoding};
pub use kv_cache::{sample_logits, BatchedDecodeSession, DecodeSession};
pub use paged::{KvConfig, KvStats, PagedKv, SessionConfig};
pub use params::{PackedLayerParams, PackedWeight, Params, WeightMemory};
pub use plan::{QuantPlan, SiteId, WeightStore, GEMM_NAMES};
pub use transformer::{cross_entropy, ActStats, Model};
