//! OPT-style decoder substrate: configuration ladder, parameters,
//! quantisation plans, full-sequence forward (Algorithm 2's eight GEMMs),
//! RoPE variant, and KV-cache incremental decoding.

pub(crate) mod attention;
pub mod config;
pub mod kv_cache;
pub mod paged;
pub mod params;
pub mod plan;
pub mod plan_file;
pub mod rope;
pub mod speculative;
pub mod transformer;

pub use config::{ModelConfig, PosEncoding};
pub use kv_cache::{sample_logits, BatchedDecodeSession, DecodeSession};
pub use paged::{KvConfig, KvStats, PagedKv, SessionConfig};
pub use params::{PackedLayerParams, PackedWeight, Params, WeightMemory};
pub use plan::{PlanError, QuantPlan, SiteId, WeightStore, GEMM_NAMES};
pub use plan_file::PlanFileError;
pub use speculative::{SpecStats, SpeculativeSession};
pub use transformer::{cross_entropy, ActStats, Model};
