//! Model configurations — the OPT-style scaling ladder standing in for
//! OPT-125M…6.7B (DESIGN.md §3), plus a RoPE family standing in for
//! LLaMA/Vicuna/Alpaca (Table 4, Figure 4).

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PosEncoding {
    /// Learned absolute position embeddings (OPT style).
    Learned,
    /// Rotary position embeddings (LLaMA style).
    Rope,
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq: usize,
    pub pos: PosEncoding,
    pub ln_eps: f32,
}

impl ModelConfig {
    /// The scaling ladder. Sizes chosen so the paper's trends (variance
    /// growth with depth, quantisation tolerance vs scale) are measurable
    /// on CPU: micro≈0.2M, tiny≈0.9M, small≈2.8M, base≈6.4M params.
    pub fn preset(name: &str) -> ModelConfig {
        let (n_layers, d_model, n_heads, d_ff) = match name {
            "nano" => (2, 48, 2, 192),
            "micro" => (2, 64, 2, 256),
            "tiny" => (4, 128, 4, 512),
            "small" => (6, 192, 6, 768),
            "base" => (8, 256, 8, 1024),
            "rope-tiny" => (4, 128, 4, 512),
            "rope-small" => (6, 192, 6, 768),
            other => panic!("unknown model preset '{other}'"),
        };
        let pos = if name.starts_with("rope") {
            PosEncoding::Rope
        } else {
            PosEncoding::Learned
        };
        ModelConfig {
            name: name.to_string(),
            n_layers,
            d_model,
            n_heads,
            d_ff,
            vocab_size: 512,
            max_seq: 256,
            pos,
            ln_eps: 1e-5,
        }
    }

    /// The OPT-family ladder used in Table 3/5 style sweeps.
    pub fn ladder() -> Vec<&'static str> {
        vec!["micro", "tiny", "small", "base"]
    }

    pub fn head_dim(&self) -> usize {
        assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff;
        let per_layer = 4 * d * d + 4 * d          // attn weights + biases
            + 2 * d * f + f + d                    // mlp weights + biases
            + 4 * d; // two LayerNorms
        let emb = self.vocab_size * d
            + if self.pos == PosEncoding::Learned {
                self.max_seq * d
            } else {
                0
            };
        emb + self.n_layers * per_layer + 2 * d // final LN
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("vocab_size", Json::Num(self.vocab_size as f64)),
            ("max_seq", Json::Num(self.max_seq as f64)),
            (
                "pos",
                Json::Str(
                    match self.pos {
                        PosEncoding::Learned => "learned",
                        PosEncoding::Rope => "rope",
                    }
                    .to_string(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            n_layers: j.get("n_layers")?.as_f64()? as usize,
            d_model: j.get("d_model")?.as_f64()? as usize,
            n_heads: j.get("n_heads")?.as_f64()? as usize,
            d_ff: j.get("d_ff")?.as_f64()? as usize,
            vocab_size: j.get("vocab_size")?.as_f64()? as usize,
            max_seq: j.get("max_seq")?.as_f64()? as usize,
            pos: match j.get("pos")?.as_str()? {
                "rope" => PosEncoding::Rope,
                _ => PosEncoding::Learned,
            },
            ln_eps: 1e-5,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_grows() {
        let counts: Vec<usize> = ModelConfig::ladder()
            .iter()
            .map(|n| ModelConfig::preset(n).param_count())
            .collect();
        for w in counts.windows(2) {
            assert!(w[1] > w[0], "{counts:?}");
        }
        assert!(counts[0] > 50_000);
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::preset("tiny");
        let j = c.to_json();
        let back = ModelConfig::from_json(&j).unwrap();
        assert_eq!(back.d_model, c.d_model);
        assert_eq!(back.pos, c.pos);
    }

    #[test]
    fn rope_preset() {
        assert_eq!(ModelConfig::preset("rope-tiny").pos, PosEncoding::Rope);
    }

    #[test]
    #[should_panic]
    fn unknown_preset_panics() {
        ModelConfig::preset("opt-6.7b");
    }
}
