//! Shared per-head attention body (GEMMs ④⑤ of Algorithm 2) — one
//! implementation behind every execution path.
//!
//! The full-context forward ([`super::transformer::Model::forward`]), the
//! sequential decoder ([`super::kv_cache::DecodeSession`]) and the batched
//! engine's slot-parallel rows ([`super::kv_cache::BatchedDecodeSession`])
//! all used to carry their own copy of the per-head loop, each building
//! three fresh `Tensor`s per head per layer. They now share the two
//! functions here, which gather head slices into a reusable
//! [`AttnScratch`] instead: after a scratch's first head, processing more
//! heads performs **zero further allocations** (asserted by
//! [`AttnScratch::grow_events`] in tests).
//!
//! Bit-identity is the design constraint, not an accident: every
//! operation replicates the exact sequence the old tensor-based code
//! performed — gather, `fake_quant_buffer` over the same buffer layout,
//! the same `matmul_bt` regime split (broadcast kernel via a transposed
//! copy at m ≥ 4, dot-product panels below), the same row softmax — so
//! logits are unchanged from the pre-refactor paths and independent of
//! which path (or thread) computes them.

use crate::quant::{fake_quant_buffer, GemmQuant};
use crate::kernels::{gemm_bt_rows, gemm_rows};
use crate::tensor::Tensor;

/// MAC threshold below which parallel attention stays on the caller's
/// thread — tiny steps would pay more in pool-dispatch overhead than the
/// parallelism returns. Lower than the pure-GEMM `PAR_THRESHOLD` (1 << 21)
/// because each attention "MAC" here also carries KV gathers and per-head
/// quantisation — several times the work of a GEMM lane — but still high
/// enough that single-token decode steps on short contexts run serially.
/// Crossing the threshold never changes results (the parallel lane runs
/// the identical per-head/per-row code).
pub(crate) const ATTN_PAR_MACS: usize = 1 << 17;

/// Reusable buffers for one attention worker: per-head query/key/value
/// gathers, the score matrix, the head's context output, and a transpose
/// scratch for the broadcast-kernel lane. Buffers grow to the largest
/// size requested and are then reused verbatim — [`Self::grow_events`]
/// counts capacity growths so tests can assert that processing additional
/// heads allocates nothing.
#[derive(Debug, Default)]
pub(crate) struct AttnScratch {
    /// `[rows, hd]` quantised (then scaled) query head.
    qh: Vec<f32>,
    /// `[t, hd]` quantised key head.
    kh: Vec<f32>,
    /// `[hd, t]` quantised value head, pre-transposed (Vᵀ rows run along
    /// the key dim, the layout GEMM ⑤ consumes).
    vt: Vec<f32>,
    /// `[rows, t]` attention scores / post-softmax weights.
    scores: Vec<f32>,
    /// `[rows, hd]` per-head context output.
    hctx: Vec<f32>,
    /// Transpose scratch for the m ≥ 4 broadcast lanes.
    tbuf: Vec<f32>,
    grow_events: usize,
}

/// Size `v` to exactly `len` elements, counting capacity growths. Kept
/// contents are *not* re-zeroed: every scratch buffer is fully written
/// before it is read (gathers overwrite, the dot-panel kernel assigns,
/// and the broadcast lane zero-fills its accumulator itself), so reuse
/// across heads costs no memset.
fn ensure(v: &mut Vec<f32>, len: usize, grows: &mut usize) {
    if v.capacity() < len {
        *grows += 1;
    }
    v.resize(len, 0.0);
}

impl AttnScratch {
    pub(crate) fn new() -> AttnScratch {
        AttnScratch::default()
    }

    /// Times any internal buffer had to grow its capacity. Stable across
    /// heads (and across layers of equal width): the zero-extra-allocation
    /// guarantee the refactor makes.
    pub(crate) fn grow_events(&self) -> usize {
        self.grow_events
    }
}

/// `C = A @ Bᵀ` on raw row-major buffers (`a: [m,k]`, `b: [n,k]`,
/// `out: [m,n]`), replicating [`crate::tensor::matmul::matmul_bt`]'s
/// regime split — and therefore its bits: at m ≥ 4, transpose `b` into
/// `tbuf` and run the i-k-j broadcast kernel; below, the 1×4 dot-product
/// panels. `out` is fully overwritten.
#[allow(clippy::too_many_arguments)]
fn gemm_bt_into(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    tbuf: &mut Vec<f32>,
    grows: &mut usize,
) {
    debug_assert!(a.len() >= m * k && b.len() >= n * k && out.len() >= m * n);
    if m >= 4 {
        ensure(tbuf, k * n, grows);
        for j in 0..n {
            for kk in 0..k {
                tbuf[kk * n + j] = b[j * k + kk];
            }
        }
        out[..m * n].fill(0.0);
        gemm_rows(a, tbuf, out, 0..m, k, n);
    } else {
        gemm_bt_rows(a, b, out, 0..m, k, n);
    }
}

/// Row softmax, exactly [`Tensor::softmax_rows`]'s per-row body.
fn softmax_row(row: &mut [f32]) {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum.max(1e-30);
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// One head of full-context causal attention (④⑤) over `q`/`k`/`v`
/// `[s, d]` projections: gather head `hi`, quantise per the site formats,
/// scale after quantisation (the ASIC applies it in the accumulator),
/// mask causally, softmax, and write the head's `[s, hd]` context into
/// `out` at column `out_col` with row stride `out_stride`. Bit-identical
/// to the tensor-based per-head body `Model::layer_forward` used to
/// inline. When `scores_out` is given, the post-softmax,
/// pre-quantisation attention weights are copied into it (the stats
/// collector's "A" tensor — the in-scratch copy is quantised in place
/// for GEMM ⑤ afterwards).
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_head_full(
    scr: &mut AttnScratch,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    s: usize,
    hi: usize,
    hd: usize,
    scale: f32,
    q45: (GemmQuant, GemmQuant),
    out: &mut [f32],
    out_stride: usize,
    out_col: usize,
    scores_out: Option<&mut Vec<f32>>,
) {
    // gather head slices: the same `[s, hd]` buffers slice_head built
    ensure(&mut scr.qh, s * hd, &mut scr.grow_events);
    ensure(&mut scr.kh, s * hd, &mut scr.grow_events);
    for i in 0..s {
        scr.qh[i * hd..(i + 1) * hd].copy_from_slice(&q.row(i)[hi * hd..(hi + 1) * hd]);
        scr.kh[i * hd..(i + 1) * hd].copy_from_slice(&k.row(i)[hi * hd..(hi + 1) * hd]);
    }
    // ④: blocks along head_dim on both operands
    fake_quant_buffer(&mut scr.qh, hd, q45.0.act);
    fake_quant_buffer(&mut scr.kh, hd, q45.0.weight);
    for x in scr.qh.iter_mut() {
        *x *= scale; // scale after quantisation: ASIC applies it in the accumulator
    }
    ensure(&mut scr.scores, s * s, &mut scr.grow_events);
    gemm_bt_into(
        &scr.qh,
        &scr.kh,
        &mut scr.scores,
        s,
        hd,
        s,
        &mut scr.tbuf,
        &mut scr.grow_events,
    );
    // causal mask (queries at row i attend keys ≤ i), then row softmax
    for i in 0..s {
        let row = &mut scr.scores[i * s..(i + 1) * s];
        for x in row.iter_mut().skip(i + 1) {
            *x = f32::NEG_INFINITY;
        }
    }
    for i in 0..s {
        softmax_row(&mut scr.scores[i * s..(i + 1) * s]);
    }
    if let Some(dst) = scores_out {
        dst.clear();
        dst.extend_from_slice(&scr.scores[..s * s]);
    }
    // ⑤: blocks along the key dim — quantise A rows and Vᵀ rows
    ensure(&mut scr.vt, hd * s, &mut scr.grow_events);
    for ti in 0..s {
        let vrow = &v.row(ti)[hi * hd..(hi + 1) * hd];
        for (c, &x) in vrow.iter().enumerate() {
            scr.vt[c * s + ti] = x;
        }
    }
    fake_quant_buffer(&mut scr.scores, s, q45.1.act);
    fake_quant_buffer(&mut scr.vt, s, q45.1.weight);
    ensure(&mut scr.hctx, s * hd, &mut scr.grow_events);
    gemm_bt_into(
        &scr.scores,
        &scr.vt,
        &mut scr.hctx,
        s,
        s,
        hd,
        &mut scr.tbuf,
        &mut scr.grow_events,
    );
    for i in 0..s {
        out[i * out_stride + out_col..i * out_stride + out_col + hd]
            .copy_from_slice(&scr.hctx[i * hd..(i + 1) * hd]);
    }
}

/// All heads of one KV-cached attention row (④⑤ for a single query at
/// position `t - 1` against `t` cached keys): the per-token body shared by
/// [`super::kv_cache::DecodeSession::step`] and the batched engine's
/// per-row attention tasks. `cache_k`/`cache_v` hold at least `t` rows of
/// `d` floats; the result fills `ctx_row` (`[d]`). Bit-identical to the
/// tensor-based loop both callers used to inline — the gathered `[t, hd]`
/// operands (and therefore any per-tensor quantisation scales) match the
/// old code exactly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_row_cached(
    scr: &mut AttnScratch,
    q_row: &[f32],
    cache_k: &[f32],
    cache_v: &[f32],
    t: usize,
    d: usize,
    h: usize,
    hd: usize,
    scale: f32,
    q45: (GemmQuant, GemmQuant),
    ctx_row: &mut [f32],
) {
    for hi in 0..h {
        ensure(&mut scr.qh, hd, &mut scr.grow_events);
        scr.qh.copy_from_slice(&q_row[hi * hd..(hi + 1) * hd]);
        ensure(&mut scr.kh, t * hd, &mut scr.grow_events);
        ensure(&mut scr.vt, hd * t, &mut scr.grow_events);
        for ti in 0..t {
            let krow = &cache_k[ti * d + hi * hd..ti * d + (hi + 1) * hd];
            scr.kh[ti * hd..(ti + 1) * hd].copy_from_slice(krow);
            let vrow = &cache_v[ti * d + hi * hd..ti * d + (hi + 1) * hd];
            for (c, &x) in vrow.iter().enumerate() {
                scr.vt[c * t + ti] = x;
            }
        }
        fake_quant_buffer(&mut scr.qh, hd, q45.0.act);
        fake_quant_buffer(&mut scr.kh, hd, q45.0.weight);
        for x in scr.qh.iter_mut() {
            *x *= scale;
        }
        ensure(&mut scr.scores, t, &mut scr.grow_events);
        // m == 1: the dot-product panel lane, like matmul_bt at m < 4
        gemm_bt_rows(&scr.qh, &scr.kh, &mut scr.scores, 0..1, hd, t);
        softmax_row(&mut scr.scores);
        fake_quant_buffer(&mut scr.scores, t, q45.1.act);
        fake_quant_buffer(&mut scr.vt, t, q45.1.weight);
        ensure(&mut scr.hctx, hd, &mut scr.grow_events);
        gemm_bt_rows(&scr.scores, &scr.vt, &mut scr.hctx, 0..1, t, hd);
        ctx_row[hi * hd..(hi + 1) * hd].copy_from_slice(&scr.hctx[..hd]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::{presets, QFormat};
    use crate::util::rng::Pcg32;

    fn q45(fmt: QFormat) -> (GemmQuant, GemmQuant) {
        (GemmQuant::uniform(fmt), GemmQuant::uniform(fmt))
    }

    #[test]
    fn full_heads_reuse_scratch_with_zero_extra_allocations() {
        // the satellite guarantee: after the first head warms the scratch,
        // every further head performs zero allocations
        let (s, h, hd) = (12usize, 8usize, 16usize);
        let d = h * hd;
        let mut rng = Pcg32::new(9);
        let q = Tensor::randn(&[s, d], 1.0, &mut rng);
        let k = Tensor::randn(&[s, d], 1.0, &mut rng);
        let v = Tensor::randn(&[s, d], 1.0, &mut rng);
        let mut out = vec![0.0f32; s * d];
        let mut scr = AttnScratch::new();
        let fmts = q45(presets::bfp_w(6));
        attn_head_full(&mut scr, &q, &k, &v, s, 0, hd, 0.25, fmts, &mut out, d, 0, None);
        let warm = scr.grow_events();
        assert!(warm > 0, "first head must size the buffers");
        for hi in 1..h {
            attn_head_full(
                &mut scr,
                &q,
                &k,
                &v,
                s,
                hi,
                hd,
                0.25,
                fmts,
                &mut out,
                d,
                hi * hd,
                None,
            );
        }
        assert_eq!(
            scr.grow_events(),
            warm,
            "heads beyond the first must not allocate"
        );
    }

    #[test]
    fn cached_rows_reuse_scratch_at_fixed_context() {
        let (t, h, hd) = (9usize, 4usize, 8usize);
        let d = h * hd;
        let mut rng = Pcg32::new(5);
        let q = Tensor::randn(&[1, d], 1.0, &mut rng);
        let ck = Tensor::randn(&[t, d], 1.0, &mut rng);
        let cv = Tensor::randn(&[t, d], 1.0, &mut rng);
        let mut ctx = vec![0.0f32; d];
        let mut scr = AttnScratch::new();
        let fmts = q45(presets::fixed8());
        attn_row_cached(&mut scr, &q.data, &ck.data, &cv.data, t, d, h, hd, 0.3, fmts, &mut ctx);
        let warm = scr.grow_events();
        for _ in 0..5 {
            attn_row_cached(
                &mut scr,
                &q.data,
                &ck.data,
                &cv.data,
                t,
                d,
                h,
                hd,
                0.3,
                fmts,
                &mut ctx,
            );
        }
        assert_eq!(scr.grow_events(), warm);
    }
}
