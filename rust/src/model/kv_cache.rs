//! Incremental decoding with a per-layer KV cache — the serving hot path
//! used by the coordinator. Numerically identical to the full-context
//! forward (tested), but O(s) per new token instead of O(s²).
//!
//! Two sessions share the same math, both configured through
//! [`SessionConfig`] (slots, KV page size, KV storage format, max
//! context):
//!
//! * [`DecodeSession`] — one sequence, one token per step, KV held as
//!   dense contiguous rows. The reference path: every weight is decoded
//!   from its packed payload once per step, and when a KV storage format
//!   is configured each K/V row is fake-quantised exactly as the paged
//!   store would — so the dense session doubles as the bit-exact oracle
//!   for quantised-KV paged attention.
//! * [`BatchedDecodeSession`] — N sequences over a slot pool, KV held in
//!   the paged store ([`crate::model::paged::PagedKv`]): fixed-size pages,
//!   slot → page-table indirection, copy-on-write prefix sharing, and
//!   optionally block-quantised sealed pages. Each slot contributes a
//!   *row-block* of one or more tokens per step (one for decode, up to
//!   `prefill_chunk` for chunked prefill), all rows flowing through a
//!   single fused packed GEMM per weight site per layer. Weights are
//!   decoded once per layer per step **regardless of how many rows the
//!   step carries**, which is the amortisation the continuous-batching
//!   coordinator exists to buy — for decode it is shared across
//!   sequences, for chunked prefill across prompt *tokens* too. Every row
//!   of a batched step is bit-identical to the sequential session
//!   (tested), because the row-wise kernels accumulate in exactly the
//!   m == 1 order, activation rows quantise independently
//!   ([`crate::quant::fake_quant_rows`]), attention is causal per slot
//!   over the chunk (row j of a chunk attends keys 0..=p0+j only), and
//!   the f32 page path gathers exactly the bytes the dense layout holds.
//!   Attention (④⑤) runs as one task per row on the shared persistent
//!   worker pool ([`crate::runtime::pool`]) once the step carries enough
//!   work, so it scales across cores — across slots *and* across a single
//!   slot's chunk rows — instead of serialising on the scheduler thread.
//!   Threading never changes the bits (every row is computed by exactly
//!   the same code either way).

use super::attention::{attn_row_cached, AttnScratch, ATTN_PAR_MACS};
use super::config::PosEncoding;
use super::paged::{KvStats, PagedKv, SessionConfig};
use super::rope::apply_rope;
use super::transformer::Model;
use crate::quant::{fake_quant_buffer, quant_act, quant_act_rows, GemmQuant, QFormat};
use crate::tensor::matmul::{matmul_bt, matmul_bt_rowwise};
use crate::tensor::Tensor;

/// Cached keys/values for one layer of the *dense* reference session:
/// rows are positions, [t, d_model].
#[derive(Clone, Debug, Default)]
struct LayerCache {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Resolve a config's context cap against the model: 0 means "model
/// max_seq", anything larger is clamped to it.
fn resolve_max_context(cfg: &SessionConfig, model: &Model) -> usize {
    let max_seq = model.cfg().max_seq;
    if cfg.max_context == 0 {
        max_seq
    } else {
        cfg.max_context.min(max_seq)
    }
}

pub struct DecodeSession<'m> {
    model: &'m Model,
    caches: Vec<LayerCache>,
    /// Attention scratch reused across steps, layers and heads — steady
    /// decode allocates nothing here once the buffers are warm.
    scratch: AttnScratch,
    /// KV storage format ([`SessionConfig::kv`]): rows are fake-quantised
    /// to this on append, matching the paged store's write path. The dense
    /// session ignores page size and prefix caching — it exists to be the
    /// geometry-free reference.
    kv_fmt: QFormat,
    max_context: usize,
    pub pos: usize,
}

impl<'m> DecodeSession<'m> {
    pub fn new(model: &'m Model, cfg: &SessionConfig) -> Self {
        cfg.validate();
        DecodeSession {
            caches: vec![LayerCache::default(); model.cfg().n_layers],
            scratch: AttnScratch::new(),
            kv_fmt: cfg.kv.format,
            max_context: resolve_max_context(cfg, model),
            model,
            pos: 0,
        }
    }

    /// Context cap in tokens (config cap clamped to the model's max_seq).
    pub fn max_context(&self) -> usize {
        self.max_context
    }

    /// Feed one token, return logits `[vocab]`.
    pub fn step(&mut self, token: usize) -> Vec<f32> {
        let m = self.model;
        let cfg = m.cfg();
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let hd = cfg.head_dim();
        let kv_fmt = self.kv_fmt;
        assert!(self.pos < self.max_context, "context overflow");
        // embedding
        let mut x = Tensor::new(&[1, d], m.params.tok_emb.row(token).to_vec());
        if cfg.pos == PosEncoding::Learned {
            let p = m.params.pos_emb.row(self.pos);
            for (a, &b) in x.data.iter_mut().zip(p) {
                *a += b;
            }
        }
        for li in 0..cfg.n_layers {
            let l = &m.params.layers[li];
            let pl = m.prepared(li);
            let plan = &m.plan;
            let xn = x.layer_norm(&l.ln1_g, &l.ln1_b, cfg.ln_eps);
            // ①②③ decode straight from the packed weight cache: for block
            // formats the [1, d] activation streams against bit-packed
            // rows, so the bytes touched per token are the packed payload
            let q = pl.wq_t.matmul_bt(&quant_act(&xn, plan.site(li, 1).act)).add_bias(&l.bq);
            let k = pl.wk_t.matmul_bt(&quant_act(&xn, plan.site(li, 2).act)).add_bias(&l.bk);
            let v = pl.wv_t.matmul_bt(&quant_act(&xn, plan.site(li, 3).act)).add_bias(&l.bv);
            let (q, k) = if cfg.pos == PosEncoding::Rope {
                (apply_rope(&q, h, self.pos), apply_rope(&k, h, self.pos))
            } else {
                (q, k)
            };
            // cache the K/V row, fake-quantised to the KV storage format
            // (post-RoPE, per row with cols = d — exactly what the paged
            // store's append does, so the two lanes agree bit for bit)
            let mut krow = k.data;
            let mut vrow = v.data;
            if kv_fmt != QFormat::Fp32 {
                fake_quant_buffer(&mut krow, d, kv_fmt);
                fake_quant_buffer(&mut vrow, d, kv_fmt);
            }
            let cache = &mut self.caches[li];
            cache.k.extend_from_slice(&krow);
            cache.v.extend_from_slice(&vrow);
            let t = self.pos + 1; // keys available
            let scale = 1.0 / (hd as f32).sqrt();
            let mut ctx = Tensor::zeros(&[1, d]);
            let q45 = (plan.site(li, 4), plan.site(li, 5));
            // ④⑤ via the shared per-row attention body (strided head
            // gathers into the reused scratch — bit-identical to the
            // tensor-per-head loop this used to inline)
            attn_row_cached(
                &mut self.scratch,
                &q.data,
                &cache.k,
                &cache.v,
                t,
                d,
                h,
                hd,
                scale,
                q45,
                ctx.row_mut(0),
            );
            let ctx_q = quant_act(&ctx, plan.site(li, 6).act);
            let att_out = pl.wo_t.matmul_bt(&ctx_q).add_bias(&l.bo);
            let x1 = x.add(&att_out);
            let xn2 = x1.layer_norm(&l.ln2_g, &l.ln2_b, cfg.ln_eps);
            let hpre = pl.w1_t.matmul_bt(&quant_act(&xn2, plan.site(li, 7).act)).add_bias(&l.b1);
            let hact = hpre.gelu();
            let h_q = quant_act(&hact, plan.site(li, 8).act);
            let mlp_out = pl.w2_t.matmul_bt(&h_q).add_bias(&l.b2);
            x = x1.add(&mlp_out);
        }
        self.pos += 1;
        let xn = x.layer_norm(&m.params.lnf_g, &m.params.lnf_b, cfg.ln_eps);
        matmul_bt(&xn, &m.params.tok_emb).data
    }
}

/// Per-slot gathered K/V context, reused across layers and steps.
#[derive(Clone, Default)]
struct KvView {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Continuous-batching decode state: a paged KV store shared by a slot
/// pool. The coordinator admits a sequence into a free slot (optionally
/// mapping cached prompt-prefix pages via [`Self::attach_prefix`]), steps
/// every active slot together through [`Self::step`], and recycles the
/// slot via [`Self::reset_slot`] — which releases its page references —
/// when the sequence finishes.
pub struct BatchedDecodeSession<'m> {
    model: &'m Model,
    /// The paged KV store: page tables, refcounts, prefix cache.
    kv: PagedKv,
    /// Per-batch-entry contiguous K/V gather buffers for the current
    /// layer, grown on demand and reused across layers and steps.
    views: Vec<KvView>,
    /// One attention scratch per step row, grown on demand and reused
    /// across layers and steps — steady-state decode re-warms nothing.
    scratches: Vec<AttnScratch>,
    /// Slots whose next chunked step leaves its rows uncommitted (the
    /// speculative verify handshake — see [`Self::defer_commit`]).
    deferred: Vec<bool>,
    max_context: usize,
}

impl<'m> BatchedDecodeSession<'m> {
    pub fn new(model: &'m Model, cfg: &SessionConfig) -> Self {
        cfg.validate();
        BatchedDecodeSession {
            kv: PagedKv::new(cfg.slots, model.cfg().n_layers, model.cfg().d_model, &cfg.kv),
            views: vec![KvView::default(); cfg.slots],
            scratches: Vec::new(),
            deferred: vec![false; cfg.slots],
            max_context: resolve_max_context(cfg, model),
            model,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.kv.n_slots()
    }

    /// Tokens consumed so far by one slot.
    pub fn pos(&self, slot: usize) -> usize {
        self.kv.pos(slot)
    }

    /// Context cap in tokens (config cap clamped to the model's max_seq).
    pub fn max_context(&self) -> usize {
        self.max_context
    }

    /// Release a slot's page references and rewind it so the next admitted
    /// sequence can reuse it — the release path for finished *and*
    /// cancelled sequences (the engine resets a cancelled slot the step it
    /// reaps it, so abandoned KV pages never linger). Pages survive only
    /// while shared with other slots or pinned by the prefix cache.
    pub fn reset_slot(&mut self, slot: usize) {
        self.kv.reset_slot(slot);
    }

    /// Map cached prefill pages for `prompt` into an empty slot; returns
    /// the number of prompt rows covered, which the caller skips feeding
    /// (the engine treats them as already-prefilled). Rows are reused bit
    /// for bit — the pages hold exactly the K/V the slot would recompute.
    pub fn attach_prefix(&mut self, slot: usize, prompt: &[usize]) -> usize {
        self.kv.attach_prefix(slot, prompt)
    }

    /// Resident KV bytes right now: shared pages counted once, quantised
    /// (sealed + bit-packed) pages at packed size — the serving-pressure
    /// gauge surfaced by the engine's metrics. Back to the prefix cache's
    /// pinned footprint once every slot is reset.
    pub fn kv_bytes(&self) -> usize {
        self.kv.kv_bytes()
    }

    /// Full paged-KV accounting (bytes by format, page/sharing counts,
    /// prefix-cache hit rates).
    pub fn kv_stats(&self) -> KvStats {
        self.kv.stats()
    }

    /// Roll a slot back to `new_pos` *committed* rows — the speculative
    /// draft's rejection path (its wrong proposals were committed as real
    /// decode steps). Sealed / shared pages are never mutated: whole tail
    /// pages are popped and refcount-released, a partial tail is trimmed in
    /// place only when private and unsealed, else copy-on-write forked.
    pub fn truncate(&mut self, slot: usize, new_pos: usize) {
        self.kv.truncate(slot, new_pos);
    }

    /// Arm the speculative verify handshake for `slot`: its next
    /// [`Self::step_chunked`] computes logits as usual but leaves the
    /// appended rows *uncommitted* — positions do not advance, no page can
    /// seal, nothing enters the prefix cache. The caller must follow up
    /// with [`Self::commit_partial`] before the slot is stepped again.
    pub fn defer_commit(&mut self, slot: usize) {
        self.deferred[slot] = true;
    }

    /// Resolve a deferred step: keep the first `keep` uncommitted rows
    /// (the accepted prefix), discard the rest, then commit — advancing
    /// the position by `keep` and sealing/caching exactly as if only those
    /// rows had ever been fed. Rejected rows can never have sealed a page
    /// (they were uncommitted), so the post-commit store is bit-identical
    /// to a never-speculated session's (tested in `tests/speculative.rs`).
    pub fn commit_partial(&mut self, slot: usize, keep: usize) {
        self.deferred[slot] = false;
        self.kv.rollback_prepared(slot, keep);
        self.kv.commit_append(slot, keep);
    }

    /// Feed one token per listed `(slot, token)` pair; returns each slot's
    /// logits in input order. Single-token convenience wrapper around
    /// [`Self::step_chunked`]; row `i` of the result is bit-identical to
    /// what a [`DecodeSession`] holding only that sequence would return
    /// (tested across every preset format).
    pub fn step(&mut self, batch: &[(usize, usize)]) -> Vec<Vec<f32>> {
        self.step_with_logit_mask(batch, None)
    }

    /// [`Self::step`] with an optional per-slot logit mask: slots with
    /// `needs_logits[i] == false` skip the final layer-norm + LM-head GEMM
    /// and get an empty vector back. Unmasked rows are bit-identical to
    /// [`Self::step`]'s output (the head GEMM is row-independent; tested).
    pub fn step_with_logit_mask(
        &mut self,
        batch: &[(usize, usize)],
        needs_logits: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        let toks: Vec<[usize; 1]> = batch.iter().map(|&(_, t)| [t]).collect();
        let chunks: Vec<(usize, &[usize])> = batch
            .iter()
            .zip(&toks)
            .map(|(&(slot, _), t)| (slot, &t[..]))
            .collect();
        self.step_chunked(&chunks, needs_logits)
    }

    /// One fused engine step over per-slot *row-blocks*: each `(slot,
    /// tokens)` entry feeds `tokens.len()` consecutive prompt/decode tokens
    /// into that slot, and all entries' rows concatenate into one
    /// `[Σm_i, d]` activation matrix, so every weight site is dequantised
    /// exactly once per step no matter how many rows — chunked prefill
    /// amortises the packed-weight decode across prompt tokens the same way
    /// batching amortises it across sequences.
    ///
    /// Returns one logits vector per *row*, in batch-then-token order.
    /// `needs_logits` (same row order, `Σm_i` long) masks rows out of the
    /// LM head — the scheduler keeps only each slot's final prompt row and
    /// decode rows; masked rows return an empty vector. `None` computes
    /// logits for every row.
    ///
    /// Bit-identity: row `(slot, j)` equals the logits a sequential
    /// [`DecodeSession`] produces when fed the same token at the same
    /// position (tested for every preset format). This holds because the
    /// row-wise GEMMs accumulate every output row in the m == 1 order,
    /// activation rows quantise independently, RoPE uses each row's own
    /// absolute position, and attention is causal per slot over the chunk:
    /// row j sees keys `0..=p0+j` only, and its attention operands (the
    /// gathered `[t_j, hd]` key/value heads) are exactly the tensors the
    /// sequential step would quantise — per-tensor formats included. The
    /// paged store preserves this: K/V rows are written (and under a KV
    /// format, fake-quantised) once at append, page gathers reproduce the
    /// dense layout value for value, and copy-on-write forks copy rows
    /// verbatim, so page geometry and prefix sharing never touch the bits.
    pub fn step_chunked(
        &mut self,
        batch: &[(usize, &[usize])],
        needs_logits: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        let m = self.model;
        let cfg = m.cfg();
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let hd = cfg.head_dim();
        let b = batch.len();
        assert!(b > 0, "empty batch step");
        for (i, &(slot, toks)) in batch.iter().enumerate() {
            assert!(slot < self.kv.n_slots(), "slot {slot} out of range");
            assert!(!toks.is_empty(), "empty row-block for slot {slot}");
            assert!(
                self.kv.pos(slot) + toks.len() <= self.max_context,
                "context overflow in slot {slot}"
            );
            // a duplicate would append interleaved KV rows and advance pos
            // twice, silently corrupting the slot — keep this loud in
            // release too (b is the slot-pool size, so the scan is tiny)
            assert!(
                batch[..i].iter().all(|&(s, _)| s != slot),
                "slot {slot} listed twice in one step"
            );
        }
        // page bookkeeping once per step: copy-on-write-fork any shared or
        // sealed tail page, extend page tables for the incoming rows, and
        // record the chunk's token ids (they key the prefix cache)
        for &(slot, toks) in batch {
            self.kv.prepare_append(slot, toks);
        }
        let r: usize = batch.iter().map(|&(_, toks)| toks.len()).sum();
        // per-row absolute positions (RoPE and learned embeddings both key
        // off these; within a chunk they advance token by token)
        let mut positions: Vec<usize> = Vec::with_capacity(r);
        for &(slot, toks) in batch {
            let p0 = self.kv.pos(slot);
            positions.extend(p0..p0 + toks.len());
        }
        // embeddings
        let mut x = Tensor::zeros(&[r, d]);
        let mut row = 0usize;
        for &(slot, toks) in batch {
            let p0 = self.kv.pos(slot);
            for (j, &tok) in toks.iter().enumerate() {
                let xr = x.row_mut(row);
                xr.copy_from_slice(m.params.tok_emb.row(tok));
                if cfg.pos == PosEncoding::Learned {
                    for (a, &p) in xr.iter_mut().zip(m.params.pos_emb.row(p0 + j)) {
                        *a += p;
                    }
                }
                row += 1;
            }
        }
        let threads = crate::runtime::pool::available_threads();
        // one scratch per row, kept across layers and steps
        if self.scratches.len() < r {
            self.scratches.resize_with(r, AttnScratch::new);
        }
        for li in 0..cfg.n_layers {
            let l = &m.params.layers[li];
            let pl = m.prepared(li);
            let plan = &m.plan;
            let xn = x.layer_norm(&l.ln1_g, &l.ln1_b, cfg.ln_eps);
            // ①②③: one fused [Σm_i, k]×[n, k] GEMM each; activation rows
            // are quantised independently so each row sees exactly the
            // values it would alone
            let q_in = quant_act_rows(&xn, plan.site(li, 1).act);
            let q = pl.wq_t.matmul_bt_rowwise(&q_in).add_bias(&l.bq);
            let k_in = quant_act_rows(&xn, plan.site(li, 2).act);
            let k = pl.wk_t.matmul_bt_rowwise(&k_in).add_bias(&l.bk);
            let v_in = quant_act_rows(&xn, plan.site(li, 3).act);
            let v = pl.wv_t.matmul_bt_rowwise(&v_in).add_bias(&l.bv);
            let (q, k) = if cfg.pos == PosEncoding::Rope {
                (rope_rows(&q, &positions, h), rope_rows(&k, &positions, h))
            } else {
                (q, k)
            };
            let scale = 1.0 / (hd as f32).sqrt();
            let q45 = (plan.site(li, 4), plan.site(li, 5));
            // ④⑤ per slot over its chunk rows. Append this step's K/V rows
            // into the slot's pages first (fake-quantised to the KV format
            // there); attention row j then reads keys 0..=p0+j only, so
            // causality holds within the chunk.
            let mut row0 = 0usize;
            for &(slot, toks) in batch {
                let mi = toks.len();
                self.kv.append_rows(
                    slot,
                    li,
                    &k.data[row0 * d..(row0 + mi) * d],
                    &v.data[row0 * d..(row0 + mi) * d],
                );
                row0 += mi;
            }
            // materialise each slot's context as one contiguous [t, d]
            // view: slots living in a single resident f32 page read it in
            // place (no copy — the dense layout, recovered); everyone else
            // gathers their pages (decoding packed ones losslessly) into
            // the slot's reusable view buffer
            for (bi, &(slot, toks)) in batch.iter().enumerate() {
                let upto = self.kv.pos(slot) + toks.len();
                if self.kv.slot_slices(slot, li, upto).is_none() {
                    let view = &mut self.views[bi];
                    self.kv.gather_into(slot, li, upto, &mut view.k, &mut view.v);
                }
            }
            // slot/row-parallel attention: one task per row (rows are
            // independent once the step's K/V rows are appended — row j
            // only reads keys 0..=p0+j, all present), each writing its own
            // [d] slice of ctx, dispatched on the shared worker pool when
            // the step carries enough work. Per-row tasks mean a single
            // long-prompt slot parallelises across its chunk rows, not
            // just across slots. The serial lane runs the identical task
            // code, so the bits never depend on the thread count.
            let mut ctx = Tensor::zeros(&[r, d]);
            let mut tasks: Vec<AttnTask> = Vec::with_capacity(r);
            let mut ctx_rest: &mut [f32] = ctx.data.as_mut_slice();
            let mut q_rest: &[f32] = &q.data;
            let mut scr_iter = self.scratches.iter_mut();
            for (bi, &(slot, toks)) in batch.iter().enumerate() {
                let p0 = self.kv.pos(slot);
                let upto = p0 + toks.len();
                let (ck, cv): (&[f32], &[f32]) = match self.kv.slot_slices(slot, li, upto) {
                    Some(s) => s,
                    None => (self.views[bi].k.as_slice(), self.views[bi].v.as_slice()),
                };
                for j in 0..toks.len() {
                    let (ctx_row, rest) = ctx_rest.split_at_mut(d);
                    ctx_rest = rest;
                    let (q_row, rest_q) = q_rest.split_at(d);
                    q_rest = rest_q;
                    tasks.push(AttnTask {
                        ctx: ctx_row,
                        q: q_row,
                        k: ck,
                        v: cv,
                        t: p0 + j + 1,
                        scr: scr_iter.next().expect("one scratch per row"),
                    });
                }
            }
            let macs: usize = tasks.iter().map(|task| task.t * d * 2).sum();
            if threads > 1 && tasks.len() > 1 && macs >= ATTN_PAR_MACS {
                crate::runtime::pool::run_mut(&mut tasks, threads, |task| {
                    attn_row(task, d, h, hd, scale, q45)
                });
            } else {
                for task in tasks.iter_mut() {
                    attn_row(task, d, h, hd, scale, q45);
                }
            }
            drop(tasks);
            // ⑥⑦⑧: fused batched GEMMs again
            let ctx_q = quant_act_rows(&ctx, plan.site(li, 6).act);
            let att_out = pl.wo_t.matmul_bt_rowwise(&ctx_q).add_bias(&l.bo);
            let x1 = x.add(&att_out);
            let xn2 = x1.layer_norm(&l.ln2_g, &l.ln2_b, cfg.ln_eps);
            let h_in = quant_act_rows(&xn2, plan.site(li, 7).act);
            let hpre = pl.w1_t.matmul_bt_rowwise(&h_in).add_bias(&l.b1);
            let hact = hpre.gelu();
            let h_q = quant_act_rows(&hact, plan.site(li, 8).act);
            let mlp_out = pl.w2_t.matmul_bt_rowwise(&h_q).add_bias(&l.b2);
            x = x1.add(&mlp_out);
        }
        // commit the appended rows: advance slot positions, seal pages
        // that filled (bit-packing them under a block KV format) and
        // register sealed pages in the prefix cache. Slots armed via
        // `defer_commit` skip this — the speculative caller commits the
        // accepted prefix itself through `commit_partial`.
        for &(slot, toks) in batch {
            if !self.deferred[slot] {
                self.kv.commit_append(slot, toks.len());
            }
        }
        // tied-embedding LM head, row-order-preserving like everything else
        match needs_logits {
            None => {
                let xn = x.layer_norm(&m.params.lnf_g, &m.params.lnf_b, cfg.ln_eps);
                let logits = matmul_bt_rowwise(&xn, &m.params.tok_emb);
                (0..r).map(|ri| logits.row(ri).to_vec()).collect()
            }
            Some(mask) => {
                assert_eq!(mask.len(), r, "logit mask length");
                // gather the rows that want logits and run ONE batched head
                // GEMM over them — bit-identical per row to the full path
                let wanted: Vec<usize> = (0..r).filter(|&ri| mask[ri]).collect();
                let mut out = vec![Vec::new(); r];
                if !wanted.is_empty() {
                    let mut xs = Tensor::zeros(&[wanted.len(), d]);
                    for (gi, &ri) in wanted.iter().enumerate() {
                        xs.row_mut(gi).copy_from_slice(x.row(ri));
                    }
                    let xn = xs.layer_norm(&m.params.lnf_g, &m.params.lnf_b, cfg.ln_eps);
                    let logits = matmul_bt_rowwise(&xn, &m.params.tok_emb);
                    for (gi, &ri) in wanted.iter().enumerate() {
                        out[ri] = logits.row(gi).to_vec();
                    }
                }
                out
            }
        }
    }
}

/// One row's attention work for one layer of a chunked step: the row's
/// `[d]` roped query, the slot's contiguous `[t, d]` K/V context (a direct
/// page slice on the single-page fast path, else the gathered view), how
/// many keys this row may see, the matching `&mut` slice of the ctx
/// output, and the task's own reusable scratch. Rows of the same slot
/// share the context by `&` reference — attention only reads it.
struct AttnTask<'a> {
    ctx: &'a mut [f32],
    q: &'a [f32],
    k: &'a [f32],
    v: &'a [f32],
    /// keys visible to this row: its absolute position + 1
    t: usize,
    /// the session-resident scratch assigned to this row
    scr: &'a mut AttnScratch,
}

/// ④⑤ for one chunk row — exactly the sequential session's per-token
/// attention body with `t` available keys (the shared
/// [`attn_row_cached`]), so the gathered `[t, hd]` operands (and
/// therefore any per-tensor quantisation scales) match the sequential
/// step bit for bit.
fn attn_row(
    task: &mut AttnTask,
    d: usize,
    h: usize,
    hd: usize,
    scale: f32,
    q45: (GemmQuant, GemmQuant),
) {
    attn_row_cached(
        &mut *task.scr,
        task.q,
        task.k,
        task.v,
        task.t,
        d,
        h,
        hd,
        scale,
        q45,
        &mut *task.ctx,
    );
}

/// Apply RoPE row by row with each row's own absolute position.
fn rope_rows(t: &Tensor, positions: &[usize], n_heads: usize) -> Tensor {
    let (r, d) = t.dims2();
    assert_eq!(r, positions.len());
    let mut out = t.clone();
    for (i, &pos) in positions.iter().enumerate() {
        let row = Tensor::new(&[1, d], t.row(i).to_vec());
        let rotated = apply_rope(&row, n_heads, pos);
        out.row_mut(i).copy_from_slice(&rotated.data);
    }
    out
}

/// Temperature sampling restricted to the `top_k` highest logits;
/// `top_k == 0` (or `top_k >= vocab`) disables the filter and greedy
/// decoding (`temperature <= 0`) ignores it entirely. Ties at the k-th
/// logit break by index, so the candidate set is deterministic. This is
/// the sampler both `serve_one` and the engine call — one RNG draw per
/// generated token — which is what keeps sampled decodes bit-identical
/// across batch schedules.
pub fn sample_top_k(
    logits: &[f32],
    temperature: f32,
    top_k: usize,
    rng: &mut crate::util::rng::Pcg32,
) -> usize {
    if temperature <= 0.0 || top_k == 0 || top_k >= logits.len() {
        return sample_logits(logits, temperature, rng);
    }
    // index-tie-broken descending order is a strict total order, so the
    // top-k *set* is unique: selecting it in O(vocab) and then sorting
    // just those k is bit-identical to sorting the whole vocabulary
    let cmp = |a: &usize, b: &usize| logits[*b].partial_cmp(&logits[*a]).unwrap().then(a.cmp(b));
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.select_nth_unstable_by(top_k - 1, cmp);
    idx.truncate(top_k);
    idx.sort_unstable_by(cmp);
    let m = idx.iter().fold(f32::NEG_INFINITY, |acc, &i| acc.max(logits[i]));
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| (((logits[i] - m) / temperature) as f64).exp())
        .collect();
    idx[rng.weighted(&weights)]
}

/// Greedy / temperature sampling helper.
pub fn sample_logits(logits: &[f32], temperature: f32, rng: &mut crate::util::rng::Pcg32) -> usize {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
    }
    if logits.is_empty() {
        // mirror the greedy fallback (empty-prompt first step): token 0.
        // Without this, weighted(&[]) would divide by zero — and on the
        // engine that panic would be on the shared scheduler thread.
        return 0;
    }
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - m) / temperature) as f64).exp())
        .collect();
    rng.weighted(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn model(preset: &str, plan: QuantPlan) -> Model {
        let cfg = ModelConfig::preset(preset);
        Model::new(Params::init(&cfg, 42), plan)
    }

    fn scfg(slots: usize) -> SessionConfig {
        SessionConfig::new(slots)
    }

    #[test]
    fn decode_matches_full_forward_fp32() {
        let m = model("nano", QuantPlan::fp32());
        let toks = [3usize, 9, 100, 42, 7];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m, &scfg(1));
        for (i, &t) in toks.iter().enumerate() {
            let logits = sess.step(t);
            for j in (0..512).step_by(37) {
                assert!(
                    (logits[j] - full.row(i)[j]).abs() < 2e-4,
                    "pos {i} logit {j}: {} vs {}",
                    logits[j],
                    full.row(i)[j]
                );
            }
        }
    }

    #[test]
    fn decode_matches_full_forward_quantised() {
        // GEMM ⑤ blocks run along the key dimension, so in the full-context
        // path a block's shared exponent can see *future* keys that the
        // incremental path has not produced yet. The two paths therefore
        // agree only up to quantisation noise at intermediate positions —
        // a property of block formats worth documenting, hence the looser
        // tolerance here (FP32 decode matches to 2e-4 above).
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let toks = [1usize, 2, 3, 4];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m, &scfg(1));
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t);
        }
        for j in (0..512).step_by(23) {
            assert!(
                (last[j] - full.row(3)[j]).abs() < 3e-2,
                "logit {j}: {} vs {}",
                last[j],
                full.row(3)[j]
            );
        }
    }

    #[test]
    fn rope_decode_matches_full() {
        let m = model("rope-tiny", QuantPlan::fp32());
        let toks = [5usize, 6, 7];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m, &scfg(1));
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t);
        }
        for j in (0..512).step_by(31) {
            assert!((last[j] - full.row(2)[j]).abs() < 2e-4);
        }
    }

    #[test]
    fn batched_step_bit_identical_to_sequential() {
        // the tentpole guarantee: a batch-of-N step returns, per row, the
        // exact bits the sequential session produces
        for plan in [
            QuantPlan::fp32(),
            QuantPlan::uniform(presets::bfp_w(6)),
            QuantPlan::uniform(presets::fixed8()),
        ] {
            let m = model("nano", plan);
            let streams: [&[usize]; 3] = [&[3, 9, 100, 42], &[7, 7, 7, 7], &[250, 1, 30, 8]];
            let mut batched = BatchedDecodeSession::new(&m, &scfg(3));
            let mut seq: Vec<DecodeSession> =
                (0..3).map(|_| DecodeSession::new(&m, &scfg(1))).collect();
            for step in 0..4 {
                let batch: Vec<(usize, usize)> =
                    (0..3).map(|s| (s, streams[s][step])).collect();
                let got = batched.step(&batch);
                for s in 0..3 {
                    let want = seq[s].step(streams[s][step]);
                    assert_eq!(got[s], want, "slot {s} step {step}");
                }
            }
        }
    }

    #[test]
    fn batched_rope_per_slot_positions() {
        // slots at different positions must each get their own rotation
        let m = model("rope-tiny", QuantPlan::fp32());
        let mut batched = BatchedDecodeSession::new(&m, &scfg(2));
        let mut s0 = DecodeSession::new(&m, &scfg(1));
        let mut s1 = DecodeSession::new(&m, &scfg(1));
        // advance slot 0 by two tokens first, so positions diverge
        batched.step(&[(0, 5)]);
        s0.step(5);
        batched.step(&[(0, 6)]);
        s0.step(6);
        let got = batched.step(&[(0, 7), (1, 9)]);
        let w0 = s0.step(7);
        let w1 = s1.step(9);
        assert_eq!(got[0], w0);
        assert_eq!(got[1], w1);
        assert_eq!(batched.pos(0), 3);
        assert_eq!(batched.pos(1), 1);
    }

    #[test]
    fn logit_mask_skips_rows_exactly() {
        // masked rows return empty logits; unmasked rows are bit-identical
        // to the unmasked step
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let mut a = BatchedDecodeSession::new(&m, &scfg(3));
        let mut b = BatchedDecodeSession::new(&m, &scfg(3));
        let batch = [(0usize, 3usize), (1, 9), (2, 100)];
        let full = a.step(&batch);
        let masked = b.step_with_logit_mask(&batch, Some(&[true, false, true]));
        assert_eq!(masked[0], full[0]);
        assert!(masked[1].is_empty());
        assert_eq!(masked[2], full[2]);
        // positions advance for masked rows too
        assert_eq!(b.pos(1), 1);
    }

    #[test]
    fn reset_slot_reuses_cleanly() {
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let mut batched = BatchedDecodeSession::new(&m, &scfg(2));
        batched.step(&[(0, 3), (1, 9)]);
        batched.step(&[(0, 4), (1, 10)]);
        // recycle slot 1 for a fresh sequence; slot 0 keeps its history
        batched.reset_slot(1);
        assert_eq!(batched.pos(1), 0);
        let mut fresh = DecodeSession::new(&m, &scfg(1));
        let mut old = DecodeSession::new(&m, &scfg(1));
        old.step(3);
        old.step(4);
        let got = batched.step(&[(0, 5), (1, 42)]);
        assert_eq!(got[0], old.step(5));
        assert_eq!(got[1], fresh.step(42));
    }

    #[test]
    fn chunked_prefill_bit_identical_to_token_at_a_time() {
        // the tentpole guarantee: feeding a prompt as [m_i, d] row-blocks
        // returns, per row, the exact bits of the one-token-per-step path
        for plan in [
            QuantPlan::fp32(),
            QuantPlan::uniform(presets::bfp_w(6)),
            QuantPlan::uniform(presets::fixed8()),
        ] {
            let m = model("nano", plan);
            let prompt = [3usize, 9, 100, 42, 7, 250, 1];
            let mut chunked = BatchedDecodeSession::new(&m, &scfg(1));
            let mut seq = DecodeSession::new(&m, &scfg(1));
            let mut fed = 0usize;
            for chunk in [3usize, 4] {
                let toks = &prompt[fed..fed + chunk];
                let got = chunked.step_chunked(&[(0, toks)], None);
                assert_eq!(got.len(), chunk);
                for (j, row_logits) in got.iter().enumerate() {
                    let want = seq.step(toks[j]);
                    assert_eq!(row_logits, &want, "row {} of chunk at {fed}", j);
                }
                fed += chunk;
            }
            assert_eq!(chunked.pos(0), prompt.len());
        }
    }

    #[test]
    fn chunked_rope_uses_per_row_positions() {
        let m = model("rope-tiny", QuantPlan::fp32());
        let mut chunked = BatchedDecodeSession::new(&m, &scfg(2));
        let mut s0 = DecodeSession::new(&m, &scfg(1));
        let mut s1 = DecodeSession::new(&m, &scfg(1));
        // stagger slot 0 so the two slots' row positions differ in-step
        chunked.step_chunked(&[(0, &[5, 6])], None);
        s0.step(5);
        s0.step(6);
        let got = chunked.step_chunked(&[(0, &[7, 8]), (1, &[9, 10, 11])], None);
        let want = [
            s0.step(7),
            s0.step(8),
            s1.step(9),
            s1.step(10),
            s1.step(11),
        ];
        for (ri, w) in want.iter().enumerate() {
            assert_eq!(&got[ri], w, "row {ri}");
        }
        assert_eq!(chunked.pos(0), 4);
        assert_eq!(chunked.pos(1), 3);
    }

    #[test]
    fn chunked_mixed_prefill_and_decode_rows() {
        // one slot decoding while another prefills a chunk, same fused step
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let mut batched = BatchedDecodeSession::new(&m, &scfg(2));
        let mut dec = DecodeSession::new(&m, &scfg(1));
        let mut pre = DecodeSession::new(&m, &scfg(1));
        batched.step_chunked(&[(0, &[3, 9, 100])], None);
        dec.step(3);
        dec.step(9);
        dec.step(100);
        // slot 0 feeds one decode row; slot 1 a 4-row prefill chunk
        let got = batched.step_chunked(&[(0, &[42]), (1, &[7, 7, 8, 1])], None);
        assert_eq!(got[0], dec.step(42));
        assert_eq!(got[1], pre.step(7));
        assert_eq!(got[2], pre.step(7));
        assert_eq!(got[3], pre.step(8));
        assert_eq!(got[4], pre.step(1));
    }

    #[test]
    fn chunked_logit_mask_is_per_row() {
        // masked rows return empty vectors; unmasked rows are bit-identical
        // to the unmasked step
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let mut a = BatchedDecodeSession::new(&m, &scfg(2));
        let mut b = BatchedDecodeSession::new(&m, &scfg(2));
        let batch: [(usize, &[usize]); 2] = [(0, &[3, 9, 100]), (1, &[42, 7])];
        let full = a.step_chunked(&batch, None);
        let mask = [false, false, true, false, true]; // final row per slot
        let masked = b.step_chunked(&batch, Some(&mask));
        assert_eq!(masked.len(), 5);
        for ri in 0..5 {
            if mask[ri] {
                assert_eq!(masked[ri], full[ri], "row {ri}");
            } else {
                assert!(masked[ri].is_empty(), "row {ri}");
            }
        }
        // positions advance by the whole chunk either way
        assert_eq!(b.pos(0), 3);
        assert_eq!(b.pos(1), 2);
    }

    #[test]
    #[should_panic(expected = "context overflow")]
    fn chunked_overflow_is_loud() {
        let m = model("nano", QuantPlan::fp32());
        let mut batched = BatchedDecodeSession::new(&m, &scfg(1));
        let long = vec![1usize; m.cfg().max_seq + 1];
        batched.step_chunked(&[(0, &long)], None);
    }

    #[test]
    #[should_panic(expected = "context overflow")]
    fn session_max_context_caps_below_model_max() {
        let m = model("nano", QuantPlan::fp32());
        let mut batched = BatchedDecodeSession::new(&m, &scfg(1).max_context(4));
        assert_eq!(batched.max_context(), 4);
        batched.step_chunked(&[(0, &[1, 2, 3, 4, 5])], None);
    }

    #[test]
    fn kv_bytes_tracks_rows_and_resets() {
        // unsealed f32 pages are counted at committed rows, so short
        // contexts account exactly like the old dense layout — and
        // releasing a slot refcount-frees its (unshared, uncached) pages
        let m = model("nano", QuantPlan::fp32());
        let d = m.cfg().d_model;
        let layers = m.cfg().n_layers;
        let mut batched = BatchedDecodeSession::new(&m, &scfg(2));
        assert_eq!(batched.kv_bytes(), 0);
        batched.step_chunked(&[(0, &[3, 9, 100]), (1, &[7])], None);
        // k + v rows of d floats, per layer, 4 bytes each; 3 + 1 tokens
        assert_eq!(batched.kv_bytes(), (3 + 1) * d * 2 * layers * 4);
        batched.reset_slot(0);
        assert_eq!(batched.kv_bytes(), d * 2 * layers * 4);
        batched.reset_slot(1);
        assert_eq!(batched.kv_bytes(), 0);
    }

    #[test]
    fn kv_bytes_counts_shared_pages_once_and_releases_refcounted() {
        let m = model("nano", QuantPlan::fp32());
        let mut s = BatchedDecodeSession::new(&m, &scfg(2).page_size(4));
        let prompt: Vec<usize> = (3..11).collect(); // 8 tokens = 2 full pages
        s.step_chunked(&[(0, &prompt)], None);
        let solo = s.kv_bytes();
        // second slot attaches the shared prefix: zero new bytes
        let attached = s.attach_prefix(1, &prompt);
        assert_eq!(attached, 7, "last prompt row is left to recompute");
        assert_eq!(s.kv_bytes(), solo);
        assert!(s.kv_stats().pages_shared > 0);
        // recomputing the final row copy-on-write-forks the shared tail
        let logits = s.step_chunked(&[(1, &[prompt[7]])], None);
        assert_eq!(logits.len(), 1);
        assert!(s.kv_bytes() > solo, "fork allocates a private tail page");
        // resets release refcounted pages down to the prefix-cache pins
        s.reset_slot(0);
        s.reset_slot(1);
        let st = s.kv_stats();
        assert_eq!(st.bytes(), st.cache_bytes, "only cache-pinned pages remain");
        assert!(st.prefix_hits >= 1);
    }

    #[test]
    fn top_k_sampling_restricts_support() {
        let mut rng = crate::util::rng::Pcg32::new(7);
        let logits = vec![0.0, 5.0, 4.0, -1.0, 3.0];
        // greedy ignores top_k
        assert_eq!(sample_top_k(&logits, 0.0, 2, &mut rng), 1);
        // top_k == 1 is argmax even at high temperature
        for _ in 0..50 {
            assert_eq!(sample_top_k(&logits, 2.0, 1, &mut rng), 1);
        }
        // top_k == 3 only ever yields the three largest logits {1, 2, 4}
        let mut seen = [0usize; 5];
        for _ in 0..300 {
            seen[sample_top_k(&logits, 1.5, 3, &mut rng)] += 1;
        }
        assert_eq!(seen[0], 0);
        assert_eq!(seen[3], 0);
        assert!(seen[1] > 0 && seen[2] > 0 && seen[4] > 0);
        // top_k == 0 and top_k >= vocab fall back to full-vocab sampling
        let full = sample_top_k(&logits, 0.0, 0, &mut rng);
        assert_eq!(full, sample_top_k(&logits, 0.0, 99, &mut rng));
        // empty logits (empty-prompt first step) yield token 0 at any
        // temperature — the engine's scheduler thread must never panic here
        assert_eq!(sample_logits(&[], 1.0, &mut rng), 0);
        assert_eq!(sample_top_k(&[], 0.7, 3, &mut rng), 0);
    }

    #[test]
    fn sampling_greedy_vs_temp() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let logits = vec![0.0, 5.0, 1.0];
        assert_eq!(sample_logits(&logits, 0.0, &mut rng), 1);
        let mut counts = [0; 3];
        for _ in 0..200 {
            counts[sample_logits(&logits, 1.0, &mut rng)] += 1;
        }
        assert!(counts[1] > 150);
    }
}
