//! Incremental decoding with a per-layer KV cache — the serving hot path
//! used by the coordinator. Numerically identical to the full-context
//! forward (tested), but O(s) per new token instead of O(s²).
//!
//! Two sessions share the same math:
//!
//! * [`DecodeSession`] — one sequence, one token per step. The reference
//!   path: every weight is decoded from its packed payload once per step.
//! * [`BatchedDecodeSession`] — N sequences over a slot pool, one token per
//!   *active slot* per step, all rows flowing through a single fused packed
//!   GEMM per weight site per layer. Weights are decoded once per layer per
//!   step **regardless of batch size**, which is the amortisation the
//!   continuous-batching coordinator exists to buy. Every row of a batched
//!   step is bit-identical to the sequential session (tested), because the
//!   row-wise kernels accumulate in exactly the m == 1 order and activation
//!   rows quantise independently ([`crate::quant::fake_quant_rows`]).

use super::config::PosEncoding;
use super::rope::apply_rope;
use super::transformer::Model;
use crate::quant::{quant_act, quant_act_rows};
use crate::tensor::matmul::{matmul_bt, matmul_bt_rowwise};
use crate::tensor::Tensor;

/// Cached keys/values for one layer: rows are positions, [t, d_model].
#[derive(Clone, Debug, Default)]
struct LayerCache {
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct DecodeSession<'m> {
    model: &'m Model,
    caches: Vec<LayerCache>,
    pub pos: usize,
}

impl<'m> DecodeSession<'m> {
    pub fn new(model: &'m Model) -> Self {
        DecodeSession {
            caches: vec![LayerCache::default(); model.cfg().n_layers],
            model,
            pos: 0,
        }
    }

    /// Feed one token, return logits [vocab].
    pub fn step(&mut self, token: usize) -> Vec<f32> {
        let m = self.model;
        let cfg = m.cfg();
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let hd = cfg.head_dim();
        assert!(self.pos < cfg.max_seq, "context overflow");
        // embedding
        let mut x = Tensor::new(&[1, d], m.params.tok_emb.row(token).to_vec());
        if cfg.pos == PosEncoding::Learned {
            let p = m.params.pos_emb.row(self.pos);
            for (a, &b) in x.data.iter_mut().zip(p) {
                *a += b;
            }
        }
        for li in 0..cfg.n_layers {
            let l = &m.params.layers[li];
            let pl = m.prepared(li);
            let plan = &m.plan;
            let xn = x.layer_norm(&l.ln1_g, &l.ln1_b, cfg.ln_eps);
            // ①②③ decode straight from the packed weight cache: for block
            // formats the [1, d] activation streams against bit-packed
            // rows, so the bytes touched per token are the packed payload
            let q = pl.wq_t.matmul_bt(&quant_act(&xn, plan.site(li, 1).act)).add_bias(&l.bq);
            let k = pl.wk_t.matmul_bt(&quant_act(&xn, plan.site(li, 2).act)).add_bias(&l.bk);
            let v = pl.wv_t.matmul_bt(&quant_act(&xn, plan.site(li, 3).act)).add_bias(&l.bv);
            let (q, k) = if cfg.pos == PosEncoding::Rope {
                (apply_rope(&q, h, self.pos), apply_rope(&k, h, self.pos))
            } else {
                (q, k)
            };
            let cache = &mut self.caches[li];
            cache.k.extend_from_slice(&k.data);
            cache.v.extend_from_slice(&v.data);
            let t = self.pos + 1; // keys available
            let scale = 1.0 / (hd as f32).sqrt();
            let mut ctx = Tensor::zeros(&[1, d]);
            let q45 = (plan.site(li, 4), plan.site(li, 5));
            for hi in 0..h {
                // gather head slices
                let qh = Tensor::new(&[1, hd], q.data[hi * hd..(hi + 1) * hd].to_vec());
                let mut kh = Tensor::zeros(&[t, hd]);
                let mut vh = Tensor::zeros(&[t, hd]);
                for ti in 0..t {
                    kh.row_mut(ti)
                        .copy_from_slice(&cache.k[ti * d + hi * hd..ti * d + (hi + 1) * hd]);
                    vh.row_mut(ti)
                        .copy_from_slice(&cache.v[ti * d + hi * hd..ti * d + (hi + 1) * hd]);
                }
                let mut qh_q = quant_act(&qh, q45.0.act);
                let kh_q = quant_act(&kh, q45.0.weight);
                for r in qh_q.data.iter_mut() {
                    *r *= scale;
                }
                let mut scores = matmul_bt(&qh_q, &kh_q); // [1, t]
                scores.softmax_rows();
                let a_q = quant_act(&scores, q45.1.act);
                let vht_q = quant_act(&vh.t(), q45.1.weight);
                let ctx_h = matmul_bt(&a_q, &vht_q); // [1, hd]
                ctx.row_mut(0)[hi * hd..(hi + 1) * hd].copy_from_slice(ctx_h.row(0));
            }
            let ctx_q = quant_act(&ctx, plan.site(li, 6).act);
            let att_out = pl.wo_t.matmul_bt(&ctx_q).add_bias(&l.bo);
            let x1 = x.add(&att_out);
            let xn2 = x1.layer_norm(&l.ln2_g, &l.ln2_b, cfg.ln_eps);
            let hpre = pl.w1_t.matmul_bt(&quant_act(&xn2, plan.site(li, 7).act)).add_bias(&l.b1);
            let hact = hpre.gelu();
            let h_q = quant_act(&hact, plan.site(li, 8).act);
            let mlp_out = pl.w2_t.matmul_bt(&h_q).add_bias(&l.b2);
            x = x1.add(&mlp_out);
        }
        self.pos += 1;
        let xn = x.layer_norm(&m.params.lnf_g, &m.params.lnf_b, cfg.ln_eps);
        matmul_bt(&xn, &m.params.tok_emb).data
    }
}

/// Continuous-batching decode state: per-slot KV caches over a shared slot
/// pool. The coordinator admits a sequence into a free slot, steps every
/// active slot together through [`Self::step`], and recycles the slot via
/// [`Self::reset_slot`] when the sequence finishes.
pub struct BatchedDecodeSession<'m> {
    model: &'m Model,
    /// caches[slot][layer]
    caches: Vec<Vec<LayerCache>>,
    /// tokens consumed so far, per slot
    pos: Vec<usize>,
}

impl<'m> BatchedDecodeSession<'m> {
    pub fn new(model: &'m Model, n_slots: usize) -> Self {
        assert!(n_slots > 0, "need at least one slot");
        BatchedDecodeSession {
            caches: vec![vec![LayerCache::default(); model.cfg().n_layers]; n_slots],
            pos: vec![0; n_slots],
            model,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.pos.len()
    }

    /// Tokens consumed so far by one slot.
    pub fn pos(&self, slot: usize) -> usize {
        self.pos[slot]
    }

    /// Clear a slot's KV cache and position so the next admitted sequence
    /// can reuse it.
    pub fn reset_slot(&mut self, slot: usize) {
        for c in self.caches[slot].iter_mut() {
            c.k.clear();
            c.v.clear();
        }
        self.pos[slot] = 0;
    }

    /// Feed one token per listed `(slot, token)` pair; returns each slot's
    /// logits in input order. All rows advance through ONE fused packed
    /// GEMM per weight site per layer — the weight payload is decoded once
    /// for the whole batch — while attention runs per slot against that
    /// slot's own KV cache and position. Row `i` of the result is
    /// bit-identical to what a [`DecodeSession`] holding only that sequence
    /// would return (tested across every preset format).
    pub fn step(&mut self, batch: &[(usize, usize)]) -> Vec<Vec<f32>> {
        self.step_with_logit_mask(batch, None)
    }

    /// [`Self::step`] with an optional per-row logit mask: rows with
    /// `needs_logits[i] == false` skip the final layer-norm + LM-head GEMM
    /// and get an empty vector back. The scheduler masks rows that are
    /// still prefilling — their logits are discarded anyway, and the
    /// vocab-sized head GEMM dominates a prefill step's cost. Unmasked rows
    /// are bit-identical to [`Self::step`]'s output (the head GEMM is
    /// row-independent; tested).
    pub fn step_with_logit_mask(
        &mut self,
        batch: &[(usize, usize)],
        needs_logits: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        let m = self.model;
        let cfg = m.cfg();
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let hd = cfg.head_dim();
        let b = batch.len();
        assert!(b > 0, "empty batch step");
        for (i, &(slot, _)) in batch.iter().enumerate() {
            assert!(slot < self.pos.len(), "slot {slot} out of range");
            assert!(self.pos[slot] < cfg.max_seq, "context overflow in slot {slot}");
            // a duplicate would append two KV rows and advance pos twice,
            // silently corrupting the slot — keep this loud in release too
            // (b is the slot-pool size, so the scan is tiny)
            assert!(
                batch[..i].iter().all(|&(s, _)| s != slot),
                "slot {slot} listed twice in one step"
            );
        }
        // embeddings, with each slot's own absolute position
        let mut x = Tensor::zeros(&[b, d]);
        for (bi, &(slot, tok)) in batch.iter().enumerate() {
            let xr = x.row_mut(bi);
            xr.copy_from_slice(m.params.tok_emb.row(tok));
            if cfg.pos == PosEncoding::Learned {
                for (a, &p) in xr.iter_mut().zip(m.params.pos_emb.row(self.pos[slot])) {
                    *a += p;
                }
            }
        }
        for li in 0..cfg.n_layers {
            let l = &m.params.layers[li];
            let pl = m.prepared(li);
            let plan = &m.plan;
            let xn = x.layer_norm(&l.ln1_g, &l.ln1_b, cfg.ln_eps);
            // ①②③: one fused [b, k]×[n, k] GEMM each; activation rows are
            // quantised independently so each sequence sees exactly the
            // values it would alone
            let q_in = quant_act_rows(&xn, plan.site(li, 1).act);
            let q = pl.wq_t.matmul_bt_rowwise(&q_in).add_bias(&l.bq);
            let k_in = quant_act_rows(&xn, plan.site(li, 2).act);
            let k = pl.wk_t.matmul_bt_rowwise(&k_in).add_bias(&l.bk);
            let v_in = quant_act_rows(&xn, plan.site(li, 3).act);
            let v = pl.wv_t.matmul_bt_rowwise(&v_in).add_bias(&l.bv);
            let (q, k) = if cfg.pos == PosEncoding::Rope {
                (self.rope_rows(&q, batch, h), self.rope_rows(&k, batch, h))
            } else {
                (q, k)
            };
            let scale = 1.0 / (hd as f32).sqrt();
            let mut ctx = Tensor::zeros(&[b, d]);
            let q45 = (plan.site(li, 4), plan.site(li, 5));
            // ④⑤ per slot: attention state is inherently per-sequence
            for (bi, &(slot, _)) in batch.iter().enumerate() {
                let cache = &mut self.caches[slot][li];
                cache.k.extend_from_slice(k.row(bi));
                cache.v.extend_from_slice(v.row(bi));
                let t = self.pos[slot] + 1; // keys available in this slot
                for hi in 0..h {
                    let qh = Tensor::new(&[1, hd], head_slice(q.row(bi), hi, hd).to_vec());
                    let mut kh = Tensor::zeros(&[t, hd]);
                    let mut vh = Tensor::zeros(&[t, hd]);
                    for ti in 0..t {
                        kh.row_mut(ti)
                            .copy_from_slice(&cache.k[ti * d + hi * hd..ti * d + (hi + 1) * hd]);
                        vh.row_mut(ti)
                            .copy_from_slice(&cache.v[ti * d + hi * hd..ti * d + (hi + 1) * hd]);
                    }
                    let mut qh_q = quant_act(&qh, q45.0.act);
                    let kh_q = quant_act(&kh, q45.0.weight);
                    for r in qh_q.data.iter_mut() {
                        *r *= scale;
                    }
                    let mut scores = matmul_bt(&qh_q, &kh_q); // [1, t]
                    scores.softmax_rows();
                    let a_q = quant_act(&scores, q45.1.act);
                    let vht_q = quant_act(&vh.t(), q45.1.weight);
                    let ctx_h = matmul_bt(&a_q, &vht_q); // [1, hd]
                    ctx.row_mut(bi)[hi * hd..(hi + 1) * hd].copy_from_slice(ctx_h.row(0));
                }
            }
            // ⑥⑦⑧: fused batched GEMMs again
            let ctx_q = quant_act_rows(&ctx, plan.site(li, 6).act);
            let att_out = pl.wo_t.matmul_bt_rowwise(&ctx_q).add_bias(&l.bo);
            let x1 = x.add(&att_out);
            let xn2 = x1.layer_norm(&l.ln2_g, &l.ln2_b, cfg.ln_eps);
            let h_in = quant_act_rows(&xn2, plan.site(li, 7).act);
            let hpre = pl.w1_t.matmul_bt_rowwise(&h_in).add_bias(&l.b1);
            let hact = hpre.gelu();
            let h_q = quant_act_rows(&hact, plan.site(li, 8).act);
            let mlp_out = pl.w2_t.matmul_bt_rowwise(&h_q).add_bias(&l.b2);
            x = x1.add(&mlp_out);
        }
        for &(slot, _) in batch {
            self.pos[slot] += 1;
        }
        // tied-embedding LM head, row-order-preserving like everything else
        match needs_logits {
            None => {
                let xn = x.layer_norm(&m.params.lnf_g, &m.params.lnf_b, cfg.ln_eps);
                let logits = matmul_bt_rowwise(&xn, &m.params.tok_emb);
                (0..b).map(|bi| logits.row(bi).to_vec()).collect()
            }
            Some(mask) => {
                assert_eq!(mask.len(), b, "logit mask length");
                // gather the rows that want logits and run ONE batched head
                // GEMM over them — bit-identical per row to the full path
                let wanted: Vec<usize> = (0..b).filter(|&bi| mask[bi]).collect();
                let mut out = vec![Vec::new(); b];
                if !wanted.is_empty() {
                    let mut xs = Tensor::zeros(&[wanted.len(), d]);
                    for (ri, &bi) in wanted.iter().enumerate() {
                        xs.row_mut(ri).copy_from_slice(x.row(bi));
                    }
                    let xn = xs.layer_norm(&m.params.lnf_g, &m.params.lnf_b, cfg.ln_eps);
                    let logits = matmul_bt_rowwise(&xn, &m.params.tok_emb);
                    for (ri, &bi) in wanted.iter().enumerate() {
                        out[bi] = logits.row(ri).to_vec();
                    }
                }
                out
            }
        }
    }

    /// Apply RoPE row by row with each slot's own absolute position.
    fn rope_rows(&self, t: &Tensor, batch: &[(usize, usize)], n_heads: usize) -> Tensor {
        let (_, d) = t.dims2();
        let mut out = t.clone();
        for (bi, &(slot, _)) in batch.iter().enumerate() {
            let row = Tensor::new(&[1, d], t.row(bi).to_vec());
            let rotated = apply_rope(&row, n_heads, self.pos[slot]);
            out.row_mut(bi).copy_from_slice(&rotated.data);
        }
        out
    }
}

#[inline]
fn head_slice(row: &[f32], hi: usize, hd: usize) -> &[f32] {
    &row[hi * hd..(hi + 1) * hd]
}

/// Greedy / temperature sampling helper.
pub fn sample_logits(logits: &[f32], temperature: f32, rng: &mut crate::util::rng::Pcg32) -> usize {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
    }
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - m) / temperature) as f64).exp())
        .collect();
    rng.weighted(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn model(preset: &str, plan: QuantPlan) -> Model {
        let cfg = ModelConfig::preset(preset);
        Model::new(Params::init(&cfg, 42), plan)
    }

    #[test]
    fn decode_matches_full_forward_fp32() {
        let m = model("nano", QuantPlan::fp32());
        let toks = [3usize, 9, 100, 42, 7];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m);
        for (i, &t) in toks.iter().enumerate() {
            let logits = sess.step(t);
            for j in (0..512).step_by(37) {
                assert!(
                    (logits[j] - full.row(i)[j]).abs() < 2e-4,
                    "pos {i} logit {j}: {} vs {}",
                    logits[j],
                    full.row(i)[j]
                );
            }
        }
    }

    #[test]
    fn decode_matches_full_forward_quantised() {
        // GEMM ⑤ blocks run along the key dimension, so in the full-context
        // path a block's shared exponent can see *future* keys that the
        // incremental path has not produced yet. The two paths therefore
        // agree only up to quantisation noise at intermediate positions —
        // a property of block formats worth documenting, hence the looser
        // tolerance here (FP32 decode matches to 2e-4 above).
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let toks = [1usize, 2, 3, 4];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m);
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t);
        }
        for j in (0..512).step_by(23) {
            assert!(
                (last[j] - full.row(3)[j]).abs() < 3e-2,
                "logit {j}: {} vs {}",
                last[j],
                full.row(3)[j]
            );
        }
    }

    #[test]
    fn rope_decode_matches_full() {
        let m = model("rope-tiny", QuantPlan::fp32());
        let toks = [5usize, 6, 7];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m);
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t);
        }
        for j in (0..512).step_by(31) {
            assert!((last[j] - full.row(2)[j]).abs() < 2e-4);
        }
    }

    #[test]
    fn batched_step_bit_identical_to_sequential() {
        // the tentpole guarantee: a batch-of-N step returns, per row, the
        // exact bits the sequential session produces
        for plan in [
            QuantPlan::fp32(),
            QuantPlan::uniform(presets::bfp_w(6)),
            QuantPlan::uniform(presets::fixed8()),
        ] {
            let m = model("nano", plan);
            let streams: [&[usize]; 3] = [&[3, 9, 100, 42], &[7, 7, 7, 7], &[250, 1, 30, 8]];
            let mut batched = BatchedDecodeSession::new(&m, 3);
            let mut seq: Vec<DecodeSession> = (0..3).map(|_| DecodeSession::new(&m)).collect();
            for step in 0..4 {
                let batch: Vec<(usize, usize)> =
                    (0..3).map(|s| (s, streams[s][step])).collect();
                let got = batched.step(&batch);
                for s in 0..3 {
                    let want = seq[s].step(streams[s][step]);
                    assert_eq!(got[s], want, "slot {s} step {step}");
                }
            }
        }
    }

    #[test]
    fn batched_rope_per_slot_positions() {
        // slots at different positions must each get their own rotation
        let m = model("rope-tiny", QuantPlan::fp32());
        let mut batched = BatchedDecodeSession::new(&m, 2);
        let mut s0 = DecodeSession::new(&m);
        let mut s1 = DecodeSession::new(&m);
        // advance slot 0 by two tokens first, so positions diverge
        batched.step(&[(0, 5)]);
        s0.step(5);
        batched.step(&[(0, 6)]);
        s0.step(6);
        let got = batched.step(&[(0, 7), (1, 9)]);
        let w0 = s0.step(7);
        let w1 = s1.step(9);
        assert_eq!(got[0], w0);
        assert_eq!(got[1], w1);
        assert_eq!(batched.pos(0), 3);
        assert_eq!(batched.pos(1), 1);
    }

    #[test]
    fn logit_mask_skips_rows_exactly() {
        // masked rows return empty logits; unmasked rows are bit-identical
        // to the unmasked step
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let mut a = BatchedDecodeSession::new(&m, 3);
        let mut b = BatchedDecodeSession::new(&m, 3);
        let batch = [(0usize, 3usize), (1, 9), (2, 100)];
        let full = a.step(&batch);
        let masked = b.step_with_logit_mask(&batch, Some(&[true, false, true]));
        assert_eq!(masked[0], full[0]);
        assert!(masked[1].is_empty());
        assert_eq!(masked[2], full[2]);
        // positions advance for masked rows too
        assert_eq!(b.pos(1), 1);
    }

    #[test]
    fn reset_slot_reuses_cleanly() {
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let mut batched = BatchedDecodeSession::new(&m, 2);
        batched.step(&[(0, 3), (1, 9)]);
        batched.step(&[(0, 4), (1, 10)]);
        // recycle slot 1 for a fresh sequence; slot 0 keeps its history
        batched.reset_slot(1);
        assert_eq!(batched.pos(1), 0);
        let mut fresh = DecodeSession::new(&m);
        let mut old = DecodeSession::new(&m);
        old.step(3);
        old.step(4);
        let got = batched.step(&[(0, 5), (1, 42)]);
        assert_eq!(got[0], old.step(5));
        assert_eq!(got[1], fresh.step(42));
    }

    #[test]
    fn sampling_greedy_vs_temp() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let logits = vec![0.0, 5.0, 1.0];
        assert_eq!(sample_logits(&logits, 0.0, &mut rng), 1);
        let mut counts = [0; 3];
        for _ in 0..200 {
            counts[sample_logits(&logits, 1.0, &mut rng)] += 1;
        }
        assert!(counts[1] > 150);
    }
}
