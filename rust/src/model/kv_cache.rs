//! Incremental decoding with a per-layer KV cache — the serving hot path
//! used by the coordinator. Numerically identical to the full-context
//! forward (tested), but O(s) per new token instead of O(s²).

use super::config::PosEncoding;
use super::rope::apply_rope;
use super::transformer::Model;
use crate::quant::fake_quant;
use crate::quant::config::QFormat;
use crate::tensor::matmul::matmul_bt;
use crate::tensor::Tensor;

/// Cached keys/values for one layer: rows are positions, [t, d_model].
#[derive(Clone, Debug, Default)]
struct LayerCache {
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct DecodeSession<'m> {
    model: &'m Model,
    caches: Vec<LayerCache>,
    pub pos: usize,
}

impl<'m> DecodeSession<'m> {
    pub fn new(model: &'m Model) -> Self {
        DecodeSession {
            caches: vec![LayerCache::default(); model.cfg().n_layers],
            model,
            pos: 0,
        }
    }

    /// Feed one token, return logits [vocab].
    pub fn step(&mut self, token: usize) -> Vec<f32> {
        let m = self.model;
        let cfg = m.cfg();
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let hd = cfg.head_dim();
        assert!(self.pos < cfg.max_seq, "context overflow");
        let q_act = |fmt: QFormat, t: &Tensor| -> Tensor {
            if fmt == QFormat::Fp32 {
                t.clone()
            } else {
                fake_quant(t, fmt)
            }
        };
        // embedding
        let mut x = Tensor::new(&[1, d], m.params.tok_emb.row(token).to_vec());
        if cfg.pos == PosEncoding::Learned {
            let p = m.params.pos_emb.row(self.pos);
            for (a, &b) in x.data.iter_mut().zip(p) {
                *a += b;
            }
        }
        for li in 0..cfg.n_layers {
            let l = &m.params.layers[li];
            let pl = m.prepared(li);
            let plan = &m.plan;
            let xn = x.layer_norm(&l.ln1_g, &l.ln1_b, cfg.ln_eps);
            // ①②③ decode straight from the packed weight cache: for block
            // formats the [1, d] activation streams against bit-packed
            // rows, so the bytes touched per token are the packed payload
            let q = pl.wq_t.matmul_bt(&q_act(plan.site(li, 1).act, &xn)).add_bias(&l.bq);
            let k = pl.wk_t.matmul_bt(&q_act(plan.site(li, 2).act, &xn)).add_bias(&l.bk);
            let v = pl.wv_t.matmul_bt(&q_act(plan.site(li, 3).act, &xn)).add_bias(&l.bv);
            let (q, k) = if cfg.pos == PosEncoding::Rope {
                (apply_rope(&q, h, self.pos), apply_rope(&k, h, self.pos))
            } else {
                (q, k)
            };
            let cache = &mut self.caches[li];
            cache.k.extend_from_slice(&k.data);
            cache.v.extend_from_slice(&v.data);
            let t = self.pos + 1; // keys available
            let scale = 1.0 / (hd as f32).sqrt();
            let mut ctx = Tensor::zeros(&[1, d]);
            let q45 = (plan.site(li, 4), plan.site(li, 5));
            for hi in 0..h {
                // gather head slices
                let qh = Tensor::new(&[1, hd], q.data[hi * hd..(hi + 1) * hd].to_vec());
                let mut kh = Tensor::zeros(&[t, hd]);
                let mut vh = Tensor::zeros(&[t, hd]);
                for ti in 0..t {
                    kh.row_mut(ti)
                        .copy_from_slice(&cache.k[ti * d + hi * hd..ti * d + (hi + 1) * hd]);
                    vh.row_mut(ti)
                        .copy_from_slice(&cache.v[ti * d + hi * hd..ti * d + (hi + 1) * hd]);
                }
                let mut qh_q = q_act(q45.0.act, &qh);
                let kh_q = q_act(q45.0.weight, &kh);
                for r in qh_q.data.iter_mut() {
                    *r *= scale;
                }
                let mut scores = matmul_bt(&qh_q, &kh_q); // [1, t]
                scores.softmax_rows();
                let a_q = q_act(q45.1.act, &scores);
                let vht_q = q_act(q45.1.weight, &vh.t());
                let ctx_h = matmul_bt(&a_q, &vht_q); // [1, hd]
                ctx.row_mut(0)[hi * hd..(hi + 1) * hd].copy_from_slice(ctx_h.row(0));
            }
            let ctx_q = q_act(plan.site(li, 6).act, &ctx);
            let att_out = pl.wo_t.matmul_bt(&ctx_q).add_bias(&l.bo);
            let x1 = x.add(&att_out);
            let xn2 = x1.layer_norm(&l.ln2_g, &l.ln2_b, cfg.ln_eps);
            let hpre = pl.w1_t.matmul_bt(&q_act(plan.site(li, 7).act, &xn2)).add_bias(&l.b1);
            let hact = hpre.gelu();
            let h_q = q_act(plan.site(li, 8).act, &hact);
            let mlp_out = pl.w2_t.matmul_bt(&h_q).add_bias(&l.b2);
            x = x1.add(&mlp_out);
        }
        self.pos += 1;
        let xn = x.layer_norm(&m.params.lnf_g, &m.params.lnf_b, cfg.ln_eps);
        matmul_bt(&xn, &m.params.tok_emb).data
    }
}

/// Greedy / temperature sampling helper.
pub fn sample_logits(logits: &[f32], temperature: f32, rng: &mut crate::util::rng::Pcg32) -> usize {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
    }
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let weights: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - m) / temperature) as f64).exp())
        .collect();
    rng.weighted(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn model(preset: &str, plan: QuantPlan) -> Model {
        let cfg = ModelConfig::preset(preset);
        Model::new(Params::init(&cfg, 42), plan)
    }

    #[test]
    fn decode_matches_full_forward_fp32() {
        let m = model("nano", QuantPlan::fp32());
        let toks = [3usize, 9, 100, 42, 7];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m);
        for (i, &t) in toks.iter().enumerate() {
            let logits = sess.step(t);
            for j in (0..512).step_by(37) {
                assert!(
                    (logits[j] - full.row(i)[j]).abs() < 2e-4,
                    "pos {i} logit {j}: {} vs {}",
                    logits[j],
                    full.row(i)[j]
                );
            }
        }
    }

    #[test]
    fn decode_matches_full_forward_quantised() {
        // GEMM ⑤ blocks run along the key dimension, so in the full-context
        // path a block's shared exponent can see *future* keys that the
        // incremental path has not produced yet. The two paths therefore
        // agree only up to quantisation noise at intermediate positions —
        // a property of block formats worth documenting, hence the looser
        // tolerance here (FP32 decode matches to 2e-4 above).
        let m = model("nano", QuantPlan::uniform(presets::bfp_w(6)));
        let toks = [1usize, 2, 3, 4];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m);
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t);
        }
        for j in (0..512).step_by(23) {
            assert!(
                (last[j] - full.row(3)[j]).abs() < 3e-2,
                "logit {j}: {} vs {}",
                last[j],
                full.row(3)[j]
            );
        }
    }

    #[test]
    fn rope_decode_matches_full() {
        let m = model("rope-tiny", QuantPlan::fp32());
        let toks = [5usize, 6, 7];
        let full = m.forward(&toks, None);
        let mut sess = DecodeSession::new(&m);
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t);
        }
        for j in (0..512).step_by(31) {
            assert!((last[j] - full.row(2)[j]).abs() < 2e-4);
        }
    }

    #[test]
    fn sampling_greedy_vs_temp() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let logits = vec![0.0, 5.0, 1.0];
        assert_eq!(sample_logits(&logits, 0.0, &mut rng), 1);
        let mut counts = [0; 3];
        for _ in 0..200 {
            counts[sample_logits(&logits, 1.0, &mut rng)] += 1;
        }
        assert!(counts[1] > 150);
    }
}
