//! Quantisation plans: which format each GEMM operand uses.
//!
//! A plan maps every GEMM site (layer × ①..⑧ × {weight, activation}) to a
//! format. Uniform plans (Table 3/5) use one format everywhere; mixed-
//! precision plans (§4.4, Fig. 3) assign per-tensor formats found by the
//! TPE search.

use super::config::ModelConfig;
use crate::quant::config::{GemmQuant, QFormat};
use std::collections::HashMap;
use std::fmt;

/// How GEMMs execute. `FakeQuant` is the paper's evaluation semantics;
/// `LlmInt8` routes the six weight GEMMs through the runtime outlier
/// decomposition of Dettmers et al. (④⑤ stay FP16/FP32, as released).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GemmMode {
    FakeQuant,
    LlmInt8 { threshold: f32, bits: u32 },
}

/// How prepared weights are *stored* by the model's weight cache
/// ([`crate::model::params::PackedLayerParams`]). Orthogonal to the GEMM
/// mode: it changes resident bytes, never results — the packed path is
/// bit-exact with the dense fake-quant path (tested).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WeightStore {
    /// Serve quantised weights from their bit-packed payload (BFP/BM/BL/…
    /// block layouts along k), dequantising block-wise inside the GEMM.
    /// This is the deployment story of the paper's §3.2 memory-density
    /// numbers: resident weight bytes shrink ~5× under BFP6.
    #[default]
    PackedAuto,
    /// Keep dequantised f32 copies of every prepared weight (the legacy
    /// behaviour; useful for debugging and as the fake-quant reference).
    DenseF32,
}

/// A GEMM site: (layer index, GEMM index ①..⑧).
pub type SiteId = (usize, u8);

pub const GEMM_NAMES: [&str; 8] = [
    "q_proj", "k_proj", "v_proj", "qk_t", "att_v", "o_proj", "fc1", "fc2",
];

/// Why a [`QuantPlan`] is unusable against a concrete [`ModelConfig`] —
/// the typed rejection surface of [`QuantPlan::validate`], checked when a
/// plan file is loaded or served (mirroring how
/// [`super::paged::KvConfig::validate`] guards KV formats).
#[derive(Clone, Debug, PartialEq)]
pub enum PlanError {
    /// A per-site entry names a layer the model does not have.
    LayerOutOfRange {
        /// Offending layer index.
        layer: usize,
        /// Layers the model actually has.
        n_layers: usize,
    },
    /// A per-site entry's GEMM index is outside ①..⑧.
    BadGemmIndex {
        /// Offending GEMM index.
        gemm: u8,
    },
    /// A per-site plan leaves a whole layer uncovered — the signature of a
    /// plan searched against a model with fewer layers.
    MissingLayer {
        /// First layer with no per-site entry.
        layer: usize,
    },
    /// A per-tensor scaled format (fixed / fixedrow / minifloat / dmf) at
    /// a KV-relevant site (④ QKᵀ or ⑤ A·V): those operands are the K/V
    /// rows the paged KV cache stores, which admits only `fp32` and the
    /// block formats (`bfp`/`bm`/`bl`) — the same set
    /// [`super::paged::KvConfig::validate`] accepts.
    KvIncompatibleFormat {
        /// Layer of the offending site.
        layer: usize,
        /// GEMM index of the offending site (4 or 5).
        gemm: u8,
        /// The rejected format.
        fmt: QFormat,
    },
    /// Outlier fraction outside `[0, 0.01)` — the overlay is defined as a
    /// "< 1% of weights" side table; anything larger is a different
    /// (dense) decomposition.
    BadOutlierFraction {
        /// The rejected fraction.
        frac: f32,
    },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::LayerOutOfRange { layer, n_layers } => {
                write!(f, "plan site names layer {layer}, model has {n_layers}")
            }
            PlanError::BadGemmIndex { gemm } => {
                write!(f, "plan site names GEMM {gemm}, valid indices are 1..=8")
            }
            PlanError::MissingLayer { layer } => {
                write!(f, "per-site plan covers no site of layer {layer}")
            }
            PlanError::KvIncompatibleFormat { layer, gemm, fmt } => write!(
                f,
                "per-tensor scaled format {} at KV-relevant site L{layer} gemm {gemm} \
                 (paged KV admits only fp32 and block formats bfp/bm/bl)",
                fmt.name()
            ),
            PlanError::BadOutlierFraction { frac } => {
                write!(f, "outlier fraction {frac} outside [0, 0.01)")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// True for formats the paged KV cache can store (and so the ④⑤
/// activation-activation operands may use): fp32 and the block formats.
fn kv_compatible(fmt: QFormat) -> bool {
    matches!(
        fmt,
        QFormat::Fp32 | QFormat::Bfp { .. } | QFormat::Bm { .. } | QFormat::Bl { .. }
    )
}

#[derive(Clone, Debug, PartialEq)]
pub struct QuantPlan {
    pub default: GemmQuant,
    pub per_site: HashMap<SiteId, GemmQuant>,
    pub mode: GemmMode,
    /// Storage policy for the prepared weight cache.
    pub store: WeightStore,
    /// Dense-and-sparse outlier overlay: the fraction (< 0.01) of
    /// largest-|w| weights per site kept exactly in an f32 side table
    /// ([`crate::quant::outlier`]) instead of the packed payload. 0 (the
    /// default) disables the overlay. Ignored by non-FakeQuant modes.
    pub outliers: f32,
}

impl QuantPlan {
    pub fn fp32() -> Self {
        QuantPlan {
            default: GemmQuant::fp32(),
            per_site: HashMap::new(),
            mode: GemmMode::FakeQuant,
            store: WeightStore::default(),
            outliers: 0.0,
        }
    }

    /// LLM.int8()/int4() plan: fake-quant disabled, runtime outlier
    /// decomposition on the six weight GEMMs.
    pub fn llm_int8(bits: u32) -> Self {
        QuantPlan {
            default: GemmQuant::fp32(),
            per_site: HashMap::new(),
            mode: GemmMode::LlmInt8 {
                threshold: crate::baselines::llm_int8::DEFAULT_THRESHOLD,
                bits,
            },
            store: WeightStore::default(),
            outliers: 0.0,
        }
    }

    /// Uniform WxAx plan (all eight GEMMs — "8/8" in Table 1).
    pub fn uniform(fmt: QFormat) -> Self {
        QuantPlan {
            default: GemmQuant::uniform(fmt),
            per_site: HashMap::new(),
            mode: GemmMode::FakeQuant,
            store: WeightStore::default(),
            outliers: 0.0,
        }
    }

    /// Uniform with distinct weight/activation formats (e.g. W4A8).
    pub fn wa(weight: QFormat, act: QFormat) -> Self {
        QuantPlan {
            default: GemmQuant { weight, act },
            per_site: HashMap::new(),
            mode: GemmMode::FakeQuant,
            store: WeightStore::default(),
            outliers: 0.0,
        }
    }

    /// Override the weight-cache storage policy (builder style).
    pub fn with_store(mut self, store: WeightStore) -> Self {
        self.store = store;
        self
    }

    /// Enable the dense-and-sparse outlier overlay: keep the `frac`
    /// (< 0.01) largest-|w| weights of every quantised site exactly, in an
    /// f32 side table applied after the packed GEMM (builder style).
    pub fn with_outliers(mut self, frac: f32) -> Self {
        self.outliers = frac;
        self
    }

    /// Check this plan against a concrete model shape — the guard the
    /// plan-file loader and `serve --plan` run before building a weight
    /// cache from foreign input. Deliberately *not* called by
    /// `Model::new`: in-memory experiment plans (e.g. uniform `fixed8`
    /// for Table 3's fake-quant rows) legitimately use formats a paged-KV
    /// serving deployment must reject.
    pub fn validate(&self, cfg: &ModelConfig) -> Result<(), PlanError> {
        if !(0.0..0.01).contains(&self.outliers) {
            return Err(PlanError::BadOutlierFraction {
                frac: self.outliers,
            });
        }
        // deterministic error choice: scan sites in (layer, gemm) order
        let mut sites: Vec<SiteId> = self.per_site.keys().copied().collect();
        sites.sort_unstable();
        for &(layer, gemm) in &sites {
            if gemm < 1 || gemm > 8 {
                return Err(PlanError::BadGemmIndex { gemm });
            }
            if layer >= cfg.n_layers {
                return Err(PlanError::LayerOutOfRange {
                    layer,
                    n_layers: cfg.n_layers,
                });
            }
        }
        // a per-site plan must cover every layer of the model it claims to
        // describe (a uniform default-only plan trivially covers all)
        if !self.per_site.is_empty() {
            for layer in 0..cfg.n_layers {
                if !(1..=8).any(|g| self.per_site.contains_key(&(layer, g))) {
                    return Err(PlanError::MissingLayer { layer });
                }
            }
        }
        // ④⑤ operands are the K/V rows the paged KV cache stores
        for layer in 0..cfg.n_layers {
            for gemm in [4u8, 5u8] {
                let q = self.site(layer, gemm);
                for fmt in [q.weight, q.act] {
                    if !kv_compatible(fmt) {
                        return Err(PlanError::KvIncompatibleFormat { layer, gemm, fmt });
                    }
                }
            }
        }
        Ok(())
    }

    /// Leave ④⑤ (the activation-activation GEMMs) in FP32 — the "6/8"
    /// behaviour of LLM.int8()/GPTQ/SmoothQuant in Table 1.
    pub fn six_of_eight(fmt: QFormat, n_layers: usize) -> Self {
        let mut plan = QuantPlan::uniform(fmt);
        for layer in 0..n_layers {
            plan.per_site.insert((layer, 4), GemmQuant::fp32());
            plan.per_site.insert((layer, 5), GemmQuant::fp32());
        }
        plan
    }

    #[inline]
    pub fn site(&self, layer: usize, gemm: u8) -> GemmQuant {
        *self.per_site.get(&(layer, gemm)).unwrap_or(&self.default)
    }

    pub fn set(&mut self, layer: usize, gemm: u8, q: GemmQuant) {
        self.per_site.insert((layer, gemm), q);
    }

    /// Count of quantised GEMMs out of 8 per layer (Table 1 column).
    pub fn quantised_gemms(&self, n_layers: usize) -> (usize, usize) {
        let mut q = 0;
        let total = 8;
        for g in 1..=8u8 {
            let all_q = (0..n_layers).all(|l| {
                let s = self.site(l, g);
                s.weight != QFormat::Fp32 || s.act != QFormat::Fp32
            });
            if all_q {
                q += 1;
            }
        }
        (q, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;

    #[test]
    fn uniform_covers_all_sites() {
        let p = QuantPlan::uniform(presets::bfp_w(6));
        assert_eq!(p.site(3, 7).act, presets::bfp_w(6));
        assert_eq!(p.quantised_gemms(4), (8, 8));
    }

    #[test]
    fn six_of_eight_leaves_attention_fp32() {
        let p = QuantPlan::six_of_eight(presets::fixed8(), 4);
        assert_eq!(p.site(2, 4), GemmQuant::fp32());
        assert_eq!(p.site(2, 5), GemmQuant::fp32());
        assert_ne!(p.site(2, 1), GemmQuant::fp32());
        assert_eq!(p.quantised_gemms(4), (6, 8));
    }

    #[test]
    fn store_defaults_to_packed_and_overrides() {
        let p = QuantPlan::uniform(presets::bfp_w(6));
        assert_eq!(p.store, WeightStore::PackedAuto);
        let p = p.with_store(WeightStore::DenseF32);
        assert_eq!(p.store, WeightStore::DenseF32);
    }

    #[test]
    fn per_site_override() {
        let mut p = QuantPlan::uniform(presets::bfp_w(4));
        p.set(1, 2, GemmQuant::uniform(presets::bfp_w(8)));
        assert_eq!(p.site(1, 2).act, presets::bfp_w(8));
        assert_eq!(p.site(0, 2).act, presets::bfp_w(4));
    }

    #[test]
    fn validate_accepts_serveable_plans() {
        let cfg = ModelConfig::preset("nano");
        assert_eq!(QuantPlan::fp32().validate(&cfg), Ok(()));
        assert_eq!(QuantPlan::uniform(presets::bfp_w(4)).validate(&cfg), Ok(()));
        assert_eq!(
            QuantPlan::uniform(presets::bfp_w(4))
                .with_outliers(0.005)
                .validate(&cfg),
            Ok(())
        );
        // six-of-eight leaves ④⑤ fp32 → KV-compatible even under fixed8
        assert_eq!(
            QuantPlan::six_of_eight(presets::fixed8(), cfg.n_layers).validate(&cfg),
            Ok(())
        );
    }

    #[test]
    fn validate_rejects_layer_out_of_range() {
        let cfg = ModelConfig::preset("nano"); // 2 layers
        let mut p = QuantPlan::uniform(presets::bfp_w(6));
        for l in 0..4 {
            p.set(l, 1, GemmQuant::uniform(presets::bfp_w(8)));
        }
        assert_eq!(
            p.validate(&cfg),
            Err(PlanError::LayerOutOfRange {
                layer: 2,
                n_layers: 2
            })
        );
    }

    #[test]
    fn validate_rejects_uncovered_layers() {
        // a per-site plan searched on a 1-layer model must not silently
        // serve a 2-layer one with default-format tail layers
        let cfg = ModelConfig::preset("nano"); // 2 layers
        let mut p = QuantPlan::uniform(presets::bfp_w(6));
        p.set(0, 1, GemmQuant::uniform(presets::bfp_w(8)));
        assert_eq!(p.validate(&cfg), Err(PlanError::MissingLayer { layer: 1 }));
    }

    #[test]
    fn validate_rejects_bad_gemm_index() {
        let cfg = ModelConfig::preset("nano");
        let mut p = QuantPlan::uniform(presets::bfp_w(6));
        for l in 0..cfg.n_layers {
            p.set(l, 9, GemmQuant::uniform(presets::bfp_w(8)));
        }
        assert_eq!(p.validate(&cfg), Err(PlanError::BadGemmIndex { gemm: 9 }));
    }

    #[test]
    fn validate_rejects_per_tensor_formats_at_kv_sites() {
        let cfg = ModelConfig::preset("nano");
        // uniform fixed8 puts a per-tensor scale on ④⑤'s K/V operands —
        // fine for fake-quant experiments, unserveable through paged KV
        let p = QuantPlan::uniform(presets::fixed8());
        assert_eq!(
            p.validate(&cfg),
            Err(PlanError::KvIncompatibleFormat {
                layer: 0,
                gemm: 4,
                fmt: presets::fixed8()
            })
        );
        // a block-format default with one minifloat override at ⑤
        let mut p = QuantPlan::uniform(presets::bfp_w(6));
        for l in 0..cfg.n_layers {
            p.set(l, 1, GemmQuant::uniform(presets::bfp_w(6)));
        }
        p.set(1, 5, GemmQuant::uniform(presets::minifloat8()));
        assert_eq!(
            p.validate(&cfg),
            Err(PlanError::KvIncompatibleFormat {
                layer: 1,
                gemm: 5,
                fmt: presets::minifloat8()
            })
        );
    }

    #[test]
    fn validate_rejects_outlier_fraction_out_of_bounds() {
        let cfg = ModelConfig::preset("nano");
        for bad in [-0.1f32, 0.01, 0.5] {
            let p = QuantPlan::uniform(presets::bfp_w(4)).with_outliers(bad);
            assert_eq!(
                p.validate(&cfg),
                Err(PlanError::BadOutlierFraction { frac: bad })
            );
        }
    }

    #[test]
    #[should_panic(expected = "outside [0, 0.01)")]
    fn validate_panics_when_unwrapped() {
        let cfg = ModelConfig::preset("nano");
        QuantPlan::uniform(presets::bfp_w(4))
            .with_outliers(0.5)
            .validate(&cfg)
            .map_err(|e| e.to_string())
            .unwrap();
    }
}
