//! Quantisation plans: which format each GEMM operand uses.
//!
//! A plan maps every GEMM site (layer × ①..⑧ × {weight, activation}) to a
//! format. Uniform plans (Table 3/5) use one format everywhere; mixed-
//! precision plans (§4.4, Fig. 3) assign per-tensor formats found by the
//! TPE search.

use crate::quant::config::{GemmQuant, QFormat};
use std::collections::HashMap;

/// How GEMMs execute. `FakeQuant` is the paper's evaluation semantics;
/// `LlmInt8` routes the six weight GEMMs through the runtime outlier
/// decomposition of Dettmers et al. (④⑤ stay FP16/FP32, as released).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GemmMode {
    FakeQuant,
    LlmInt8 { threshold: f32, bits: u32 },
}

/// How prepared weights are *stored* by the model's weight cache
/// ([`crate::model::params::PackedLayerParams`]). Orthogonal to the GEMM
/// mode: it changes resident bytes, never results — the packed path is
/// bit-exact with the dense fake-quant path (tested).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WeightStore {
    /// Serve quantised weights from their bit-packed payload (BFP/BM/BL/…
    /// block layouts along k), dequantising block-wise inside the GEMM.
    /// This is the deployment story of the paper's §3.2 memory-density
    /// numbers: resident weight bytes shrink ~5× under BFP6.
    #[default]
    PackedAuto,
    /// Keep dequantised f32 copies of every prepared weight (the legacy
    /// behaviour; useful for debugging and as the fake-quant reference).
    DenseF32,
}

/// A GEMM site: (layer index, GEMM index ①..⑧).
pub type SiteId = (usize, u8);

pub const GEMM_NAMES: [&str; 8] = [
    "q_proj", "k_proj", "v_proj", "qk_t", "att_v", "o_proj", "fc1", "fc2",
];

#[derive(Clone, Debug)]
pub struct QuantPlan {
    pub default: GemmQuant,
    pub per_site: HashMap<SiteId, GemmQuant>,
    pub mode: GemmMode,
    /// Storage policy for the prepared weight cache.
    pub store: WeightStore,
}

impl QuantPlan {
    pub fn fp32() -> Self {
        QuantPlan {
            default: GemmQuant::fp32(),
            per_site: HashMap::new(),
            mode: GemmMode::FakeQuant,
            store: WeightStore::default(),
        }
    }

    /// LLM.int8()/int4() plan: fake-quant disabled, runtime outlier
    /// decomposition on the six weight GEMMs.
    pub fn llm_int8(bits: u32) -> Self {
        QuantPlan {
            default: GemmQuant::fp32(),
            per_site: HashMap::new(),
            mode: GemmMode::LlmInt8 {
                threshold: crate::baselines::llm_int8::DEFAULT_THRESHOLD,
                bits,
            },
            store: WeightStore::default(),
        }
    }

    /// Uniform WxAx plan (all eight GEMMs — "8/8" in Table 1).
    pub fn uniform(fmt: QFormat) -> Self {
        QuantPlan {
            default: GemmQuant::uniform(fmt),
            per_site: HashMap::new(),
            mode: GemmMode::FakeQuant,
            store: WeightStore::default(),
        }
    }

    /// Uniform with distinct weight/activation formats (e.g. W4A8).
    pub fn wa(weight: QFormat, act: QFormat) -> Self {
        QuantPlan {
            default: GemmQuant { weight, act },
            per_site: HashMap::new(),
            mode: GemmMode::FakeQuant,
            store: WeightStore::default(),
        }
    }

    /// Override the weight-cache storage policy (builder style).
    pub fn with_store(mut self, store: WeightStore) -> Self {
        self.store = store;
        self
    }

    /// Leave ④⑤ (the activation-activation GEMMs) in FP32 — the "6/8"
    /// behaviour of LLM.int8()/GPTQ/SmoothQuant in Table 1.
    pub fn six_of_eight(fmt: QFormat, n_layers: usize) -> Self {
        let mut plan = QuantPlan::uniform(fmt);
        for layer in 0..n_layers {
            plan.per_site.insert((layer, 4), GemmQuant::fp32());
            plan.per_site.insert((layer, 5), GemmQuant::fp32());
        }
        plan
    }

    #[inline]
    pub fn site(&self, layer: usize, gemm: u8) -> GemmQuant {
        *self.per_site.get(&(layer, gemm)).unwrap_or(&self.default)
    }

    pub fn set(&mut self, layer: usize, gemm: u8, q: GemmQuant) {
        self.per_site.insert((layer, gemm), q);
    }

    /// Count of quantised GEMMs out of 8 per layer (Table 1 column).
    pub fn quantised_gemms(&self, n_layers: usize) -> (usize, usize) {
        let mut q = 0;
        let total = 8;
        for g in 1..=8u8 {
            let all_q = (0..n_layers).all(|l| {
                let s = self.site(l, g);
                s.weight != QFormat::Fp32 || s.act != QFormat::Fp32
            });
            if all_q {
                q += 1;
            }
        }
        (q, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;

    #[test]
    fn uniform_covers_all_sites() {
        let p = QuantPlan::uniform(presets::bfp_w(6));
        assert_eq!(p.site(3, 7).act, presets::bfp_w(6));
        assert_eq!(p.quantised_gemms(4), (8, 8));
    }

    #[test]
    fn six_of_eight_leaves_attention_fp32() {
        let p = QuantPlan::six_of_eight(presets::fixed8(), 4);
        assert_eq!(p.site(2, 4), GemmQuant::fp32());
        assert_eq!(p.site(2, 5), GemmQuant::fp32());
        assert_ne!(p.site(2, 1), GemmQuant::fp32());
        assert_eq!(p.quantised_gemms(4), (6, 8));
    }

    #[test]
    fn store_defaults_to_packed_and_overrides() {
        let p = QuantPlan::uniform(presets::bfp_w(6));
        assert_eq!(p.store, WeightStore::PackedAuto);
        let p = p.with_store(WeightStore::DenseF32);
        assert_eq!(p.store, WeightStore::DenseF32);
    }

    #[test]
    fn per_site_override() {
        let mut p = QuantPlan::uniform(presets::bfp_w(4));
        p.set(1, 2, GemmQuant::uniform(presets::bfp_w(8)));
        assert_eq!(p.site(1, 2).act, presets::bfp_w(8));
        assert_eq!(p.site(0, 2).act, presets::bfp_w(4));
    }
}
