//! Parameter store: initialisation, flat named access (for the optimizer
//! and the PJRT train-step bridge), a simple binary checkpoint format, and
//! the packed-weight serving cache ([`PackedLayerParams`]).

use super::config::{ModelConfig, PosEncoding};
use crate::quant::outlier::OutlierTable;
use crate::quant::qmatmul::{matmul_packed_bt, matmul_packed_bt_rowwise};
use crate::quant::qtensor::QTensor;
use crate::tensor::matmul::{matmul_bt, matmul_bt_rowwise};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use std::io::{Read, Write};
use std::path::Path;

/// Base storage of one prepared weight: a dequantised f32 copy or the
/// bit-packed payload itself.
#[derive(Clone, Debug)]
enum WeightStorage {
    /// Dense f32 (fp32 weights, non-FakeQuant modes, or `WeightStore::DenseF32`).
    Dense(Tensor),
    /// Bit-packed block layout, blocks along the contraction dim.
    Packed(QTensor),
}

/// One prepared (transposed, [out, in]) weight of the serving cache —
/// either a dequantised f32 copy or the bit-packed payload itself, plus an
/// optional dense-and-sparse outlier side table
/// ([`crate::quant::outlier`]) applied as an exact f32 correction after
/// the base GEMM. The two storages produce bit-identical GEMM results
/// (tested); they only differ in resident bytes.
#[derive(Clone, Debug)]
pub struct PackedWeight {
    store: WeightStorage,
    outliers: Option<OutlierTable>,
}

impl PackedWeight {
    /// Wrap a dense f32 prepared weight (no outlier overlay).
    pub fn new_dense(t: Tensor) -> PackedWeight {
        PackedWeight {
            store: WeightStorage::Dense(t),
            outliers: None,
        }
    }

    /// Wrap a bit-packed prepared weight (no outlier overlay).
    pub fn new_packed(q: QTensor) -> PackedWeight {
        PackedWeight {
            store: WeightStorage::Packed(q),
            outliers: None,
        }
    }

    /// Attach an outlier side table (builder style). An empty table is
    /// dropped, so a 0% extraction is literally "no overlay".
    pub fn with_outliers(mut self, t: OutlierTable) -> PackedWeight {
        self.outliers = if t.nnz() == 0 { None } else { Some(t) };
        self
    }

    /// The attached outlier side table, if any.
    pub fn outliers(&self) -> Option<&OutlierTable> {
        self.outliers.as_ref()
    }

    /// Bytes held by the outlier side table (0 without one).
    pub fn outlier_bytes(&self) -> usize {
        self.outliers.as_ref().map(|t| t.bytes()).unwrap_or(0)
    }

    /// `act_q [m,k] @ selfᵀ` — `act_q` is already activation-quantised.
    ///
    /// Shape regime: splits on m like the underlying dispatch — m ≥ 4 takes
    /// the column-panel prefill kernel, m < 4 (m == 1 decode) the dot
    /// kernel. Use [`Self::matmul_bt_rowwise`] when per-row bit-identity
    /// across batch sizes is required instead. Either way the outlier
    /// correction (if any) is added after the base GEMM, in a fixed serial
    /// order independent of the shape split.
    pub fn matmul_bt(&self, act_q: &Tensor) -> Tensor {
        let mut out = match &self.store {
            WeightStorage::Dense(t) => matmul_bt(act_q, t),
            WeightStorage::Packed(q) => matmul_packed_bt(act_q, q),
        };
        if let Some(t) = &self.outliers {
            t.apply(act_q, &mut out);
        }
        out
    }

    /// Batched-decode variant of [`Self::matmul_bt`]: one fused GEMM for
    /// the whole [m, k] activation batch, with the weight decoded exactly
    /// once per call and every output row accumulating in the order the
    /// m == 1 decode path uses — so a batch-of-N step is bit-identical to N
    /// sequential single-row steps. The outlier correction is per-row
    /// independent, so it preserves that property.
    ///
    /// Shape regime: row-wise batched decode, any m.
    pub fn matmul_bt_rowwise(&self, act_q: &Tensor) -> Tensor {
        let mut out = match &self.store {
            WeightStorage::Dense(t) => matmul_bt_rowwise(act_q, t),
            WeightStorage::Packed(q) => matmul_packed_bt_rowwise(act_q, q),
        };
        if let Some(t) = &self.outliers {
            t.apply(act_q, &mut out);
        }
        out
    }

    /// Dense view — only valid for weights prepared densely (e.g. the
    /// LLM.int8() mode, which never packs or extracts outliers). Panics on
    /// packed storage.
    pub fn dense(&self) -> &Tensor {
        match &self.store {
            WeightStorage::Dense(t) => t,
            WeightStorage::Packed(q) => panic!(
                "dense view requested for packed weight {:?} — this GEMM mode must \
                 prepare weights with WeightStore::DenseF32",
                q.shape
            ),
        }
    }

    /// Elements of the prepared weight (outliers included — they are part
    /// of the same logical tensor).
    pub fn numel(&self) -> usize {
        match &self.store {
            WeightStorage::Dense(t) => t.numel(),
            WeightStorage::Packed(q) => q.numel(),
        }
    }

    /// Bytes actually resident for this weight (payload for packed, 4·numel
    /// for dense, plus the outlier side table — the unit the server's
    /// memory metrics report).
    pub fn resident_bytes(&self) -> usize {
        let base = match &self.store {
            WeightStorage::Dense(t) => t.numel() * 4,
            WeightStorage::Packed(q) => q.packed_bytes(),
        };
        base + self.outlier_bytes()
    }

    /// Storage-format label for per-format memory breakdowns: the packed
    /// format's name, or `"f32"` for dense copies (fake-quantised or not —
    /// what is *resident* is f32 either way).
    pub fn store_format_name(&self) -> String {
        match &self.store {
            WeightStorage::Dense(_) => "f32".to_string(),
            WeightStorage::Packed(q) => q.fmt.name(),
        }
    }

    pub fn is_packed(&self) -> bool {
        matches!(self.store, WeightStorage::Packed(_))
    }
}

/// Per-layer weight cache for serving: the six weight-GEMM operands of
/// Algorithm 2, transposed to [out, in] so blocks run along the
/// contraction dim, quantised once per plan, and stored per
/// [`super::plan::WeightStore`].
pub struct PackedLayerParams {
    pub wq_t: PackedWeight,
    pub wk_t: PackedWeight,
    pub wv_t: PackedWeight,
    pub wo_t: PackedWeight,
    pub w1_t: PackedWeight,
    pub w2_t: PackedWeight,
}

impl PackedLayerParams {
    pub fn weights(&self) -> [&PackedWeight; 6] {
        [
            &self.wq_t, &self.wk_t, &self.wv_t, &self.wo_t, &self.w1_t, &self.w2_t,
        ]
    }
}

/// Resident vs dense-f32 accounting for a prepared weight cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WeightMemory {
    /// What the same cache would occupy fully dequantised (4 bytes/weight).
    pub dense_f32_bytes: usize,
    /// What is actually resident (packed payloads + dense copies).
    pub resident_bytes: usize,
}

impl WeightMemory {
    /// Memory-density factor (≥ 1 when packing helps; Table 3's Mem column,
    /// measured on live serving state rather than computed from formulas).
    pub fn ratio(&self) -> f64 {
        if self.resident_bytes == 0 {
            1.0
        } else {
            self.dense_f32_bytes as f64 / self.resident_bytes as f64
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerParams {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub bq: Vec<f32>,
    pub bk: Vec<f32>,
    pub bv: Vec<f32>,
    pub bo: Vec<f32>,
    pub w1: Tensor,
    pub w2: Tensor,
    pub b1: Vec<f32>,
    pub b2: Vec<f32>,
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct Params {
    pub cfg: ModelConfig,
    pub tok_emb: Tensor,
    /// empty for RoPE models
    pub pos_emb: Tensor,
    pub layers: Vec<LayerParams>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

impl Params {
    /// GPT-2-style init: N(0, 0.02), residual projections scaled by depth.
    pub fn init(cfg: &ModelConfig, seed: u64) -> Params {
        let rng = Pcg32::new(seed);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let sigma = 0.02f32;
        let resid_sigma = sigma / (2.0 * cfg.n_layers as f32).sqrt();
        let layers = (0..cfg.n_layers)
            .map(|li| {
                let mut r = rng.split(1000 + li as u64);
                LayerParams {
                    wq: Tensor::randn(&[d, d], sigma, &mut r),
                    wk: Tensor::randn(&[d, d], sigma, &mut r),
                    wv: Tensor::randn(&[d, d], sigma, &mut r),
                    wo: Tensor::randn(&[d, d], resid_sigma, &mut r),
                    bq: vec![0.0; d],
                    bk: vec![0.0; d],
                    bv: vec![0.0; d],
                    bo: vec![0.0; d],
                    w1: Tensor::randn(&[d, f], sigma, &mut r),
                    w2: Tensor::randn(&[f, d], resid_sigma, &mut r),
                    b1: vec![0.0; f],
                    b2: vec![0.0; d],
                    ln1_g: vec![1.0; d],
                    ln1_b: vec![0.0; d],
                    ln2_g: vec![1.0; d],
                    ln2_b: vec![0.0; d],
                }
            })
            .collect();
        Params {
            cfg: cfg.clone(),
            tok_emb: Tensor::randn(&[cfg.vocab_size, d], sigma, &mut rng.split(1)),
            pos_emb: if cfg.pos == PosEncoding::Learned {
                Tensor::randn(&[cfg.max_seq, d], sigma, &mut rng.split(2))
            } else {
                Tensor::zeros(&[0, d])
            },
            layers,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
        }
    }

    pub fn param_count(&self) -> usize {
        self.flat_views().iter().map(|(_, v)| v.len()).sum()
    }

    /// Named views over every parameter buffer, in a fixed order shared
    /// with the python model (python/compile/model.py PARAM_ORDER).
    pub fn flat_views(&self) -> Vec<(String, &[f32])> {
        let mut out: Vec<(String, &[f32])> = vec![
            ("tok_emb".into(), &self.tok_emb.data[..]),
            ("pos_emb".into(), &self.pos_emb.data[..]),
        ];
        for (i, l) in self.layers.iter().enumerate() {
            let p = |n: &str| format!("layer{i}.{n}");
            out.push((p("ln1_g"), &l.ln1_g));
            out.push((p("ln1_b"), &l.ln1_b));
            out.push((p("wq"), &l.wq.data));
            out.push((p("bq"), &l.bq));
            out.push((p("wk"), &l.wk.data));
            out.push((p("bk"), &l.bk));
            out.push((p("wv"), &l.wv.data));
            out.push((p("bv"), &l.bv));
            out.push((p("wo"), &l.wo.data));
            out.push((p("bo"), &l.bo));
            out.push((p("ln2_g"), &l.ln2_g));
            out.push((p("ln2_b"), &l.ln2_b));
            out.push((p("w1"), &l.w1.data));
            out.push((p("b1"), &l.b1));
            out.push((p("w2"), &l.w2.data));
            out.push((p("b2"), &l.b2));
        }
        out.push(("lnf_g".into(), &self.lnf_g));
        out.push(("lnf_b".into(), &self.lnf_b));
        out
    }

    /// Mutable counterpart of [`Params::flat_views`] (same order).
    pub fn flat_views_mut(&mut self) -> Vec<(String, &mut [f32])> {
        let mut out: Vec<(String, &mut [f32])> = Vec::new();
        out.push(("tok_emb".into(), &mut self.tok_emb.data[..]));
        out.push(("pos_emb".into(), &mut self.pos_emb.data[..]));
        for (i, l) in self.layers.iter_mut().enumerate() {
            let p = |n: &str| format!("layer{i}.{n}");
            out.push((p("ln1_g"), &mut l.ln1_g[..]));
            out.push((p("ln1_b"), &mut l.ln1_b[..]));
            out.push((p("wq"), &mut l.wq.data[..]));
            out.push((p("bq"), &mut l.bq[..]));
            out.push((p("wk"), &mut l.wk.data[..]));
            out.push((p("bk"), &mut l.bk[..]));
            out.push((p("wv"), &mut l.wv.data[..]));
            out.push((p("bv"), &mut l.bv[..]));
            out.push((p("wo"), &mut l.wo.data[..]));
            out.push((p("bo"), &mut l.bo[..]));
            out.push((p("ln2_g"), &mut l.ln2_g[..]));
            out.push((p("ln2_b"), &mut l.ln2_b[..]));
            out.push((p("w1"), &mut l.w1.data[..]));
            out.push((p("b1"), &mut l.b1[..]));
            out.push((p("w2"), &mut l.w2.data[..]));
            out.push((p("b2"), &mut l.b2[..]));
        }
        out.push(("lnf_g".into(), &mut self.lnf_g[..]));
        out.push(("lnf_b".into(), &mut self.lnf_b[..]));
        out
    }

    /// Save as a simple binary checkpoint: magic, config-json, then each
    /// buffer as little-endian f32 in flat order.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"BBQW0001")?;
        let cfg = self.cfg.to_json().to_string();
        f.write_all(&(cfg.len() as u64).to_le_bytes())?;
        f.write_all(cfg.as_bytes())?;
        for (_, v) in self.flat_views() {
            f.write_all(&(v.len() as u64).to_le_bytes())?;
            for &x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Params> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"BBQW0001" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad checkpoint magic",
            ));
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let cfg_len = u64::from_le_bytes(len8) as usize;
        let mut cfg_buf = vec![0u8; cfg_len];
        f.read_exact(&mut cfg_buf)?;
        let cfg_json = crate::util::json::Json::parse(
            std::str::from_utf8(&cfg_buf)
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?,
        )
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        let cfg = ModelConfig::from_json(&cfg_json).ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "bad config json")
        })?;
        let mut params = Params::init(&cfg, 0);
        for (name, v) in params.flat_views_mut() {
            f.read_exact(&mut len8)?;
            let n = u64::from_le_bytes(len8) as usize;
            if n != v.len() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("buffer '{name}' length {n} != expected {}", v.len()),
                ));
            }
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            for (i, x) in v.iter_mut().enumerate() {
                *x = f32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
            }
        }
        Ok(params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matches_config_count() {
        let cfg = ModelConfig::preset("micro");
        let p = Params::init(&cfg, 1);
        assert_eq!(p.param_count(), cfg.param_count());
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 7);
        let dir = std::env::temp_dir().join("bbq_test_ckpt");
        let path = dir.join("nano.bbqw");
        p.save(&path).unwrap();
        let q = Params::load(&path).unwrap();
        assert_eq!(p.tok_emb.data, q.tok_emb.data);
        assert_eq!(p.layers[1].w2.data, q.layers[1].w2.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("bbq_test_badckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bbqw");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Params::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deterministic_init() {
        let cfg = ModelConfig::preset("nano");
        let a = Params::init(&cfg, 3);
        let b = Params::init(&cfg, 3);
        assert_eq!(a.layers[0].wq.data, b.layers[0].wq.data);
    }
}
