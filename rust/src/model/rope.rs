//! Rotary position embeddings (Su et al. 2021) — the LLaMA-family variant
//! used for Table 4 / Figure 4. The paper observes RoPE keeps Q'/K'
//! variance high from the very first layer, which our profiler reproduces.

use crate::tensor::Tensor;

/// Apply RoPE to a [s, d] tensor of h heads (rotates pairs within each
/// head's dimensions). `pos0` is the absolute position of row 0.
pub fn apply_rope(x: &Tensor, n_heads: usize, pos0: usize) -> Tensor {
    let (s, d) = x.dims2();
    let hd = d / n_heads;
    assert_eq!(hd % 2, 0, "head_dim must be even for RoPE");
    let mut out = x.clone();
    let half = hd / 2;
    for i in 0..s {
        let pos = (pos0 + i) as f32;
        let row = out.row_mut(i);
        for h in 0..n_heads {
            let base = h * hd;
            for j in 0..half {
                let theta = pos * (10000f32).powf(-2.0 * j as f32 / hd as f32);
                let (sin, cos) = theta.sin_cos();
                let a = row[base + j];
                let b = row[base + half + j];
                row[base + j] = a * cos - b * sin;
                row[base + half + j] = a * sin + b * cos;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn position_zero_is_identity() {
        let mut rng = Pcg32::new(1);
        let x = Tensor::randn(&[1, 16], 1.0, &mut rng);
        let y = apply_rope(&x, 2, 0);
        assert_eq!(x.data, y.data);
    }

    #[test]
    fn norm_preserved() {
        // rotation preserves the L2 norm of each pair
        let mut rng = Pcg32::new(2);
        let x = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let y = apply_rope(&x, 4, 3);
        for i in 0..4 {
            let nx: f32 = x.row(i).iter().map(|v| v * v).sum();
            let ny: f32 = y.row(i).iter().map(|v| v * v).sum();
            assert!((nx - ny).abs() / nx < 1e-5);
        }
    }

    #[test]
    fn relative_property() {
        // dot(rope(q, m), rope(k, n)) depends only on m - n: shifting both
        // positions by the same offset keeps the dot product.
        let mut rng = Pcg32::new(3);
        let q = Tensor::randn(&[1, 8], 1.0, &mut rng);
        let k = Tensor::randn(&[1, 8], 1.0, &mut rng);
        let dot = |a: &Tensor, b: &Tensor| -> f32 {
            a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum()
        };
        let d1 = dot(&apply_rope(&q, 1, 5), &apply_rope(&k, 1, 2));
        let d2 = dot(&apply_rope(&q, 1, 15), &apply_rope(&k, 1, 12));
        assert!((d1 - d2).abs() < 1e-3, "{d1} vs {d2}");
    }
}
