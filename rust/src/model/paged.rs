//! Paged KV store with copy-on-write prefix sharing and block-quantised
//! pages (ROADMAP: "Paged KV cache with prefix sharing + block-quantised
//! KV").
//!
//! KV memory is carved into fixed-size pages of `page_size` token rows;
//! one [`KvPage`] spans *all* layers (layer `l` of page `p` holds rows
//! `p*page_size..(p+1)*page_size` of layer `l`'s K and V). Slots address
//! their context through a page table, so requests with a common prompt
//! prefix can map the same prefill pages: sealed pages are refcounted and
//! registered in a chain-hash prefix cache, and a write into a shared or
//! sealed page copy-on-write-forks it first.
//!
//! Pages carry a storage format ([`KvConfig::format`]):
//!
//! * `Fp32` — rows stay raw f32. This is the bit-exactness lane: gathering
//!   pages back into a contiguous context buffer reproduces the dense
//!   layout byte for byte, so paged attention is asserted logits-bit-
//!   identical to the dense reference path.
//! * a block format (BFP/BM/BL) — every K/V row is fake-quantised to the
//!   format *at append time* (so stored values are independent of page
//!   geometry, sharing, and sealing order), and a page is bit-packed via
//!   [`qtensor::encode`] once it seals full. Packing already-quantised
//!   rows is lossless because the block formats are exactly idempotent
//!   (their `idempotent` unit tests assert tolerance 0.0) — which is also
//!   why per-tensor fixed point, whose scale crosses rows, is rejected as
//!   a KV format.

use std::collections::HashMap;

use crate::quant::qtensor::{self, QTensor};
use crate::quant::{fake_quant_buffer, QFormat};
use crate::tensor::Tensor;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a page's token ids, seeded with the parent chain's hash so
/// equal hashes imply (modulo collisions, which [`PagedKv`] re-verifies by
/// exact token comparison) equal full prefixes, not just equal pages.
fn chain_hash(parent: u64, toks: &[usize]) -> u64 {
    let mut h = parent;
    for &t in toks {
        h ^= t as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// KV storage configuration: page geometry, page format, and prefix-cache
/// capacity. Shared by [`SessionConfig`] and the serving stack's
/// `ServerConfig`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvConfig {
    /// Token rows per page (every layer of a page covers the same rows).
    pub page_size: usize,
    /// Storage format for KV rows: `Fp32` keeps raw rows (bit-exactness
    /// lane); a block format (BFP/BM/BL) fake-quantises rows on write and
    /// bit-packs each page when it seals full.
    pub format: QFormat,
    /// Max sealed pages pinned by the prefix cache (0 disables sharing).
    pub prefix_cache_pages: usize,
}

impl Default for KvConfig {
    fn default() -> Self {
        KvConfig {
            page_size: 16,
            format: QFormat::Fp32,
            prefix_cache_pages: 256,
        }
    }
}

impl KvConfig {
    /// Panics on an invalid configuration (mirrors `ServerConfig::validate`).
    pub fn validate(&self) {
        assert!(self.page_size >= 1, "KvConfig: page_size must be >= 1");
        assert!(
            matches!(
                self.format,
                QFormat::Fp32 | QFormat::Bfp { .. } | QFormat::Bm { .. } | QFormat::Bl { .. }
            ),
            "KvConfig: kv format must be fp32 or a block format (bfp/bm/bl)"
        );
    }
}

/// Validated construction parameters for `DecodeSession` /
/// `BatchedDecodeSession` — the one config type shared by the engine,
/// `run_batched`, the bench, and tests.
///
/// ```ignore
/// let cfg = SessionConfig::new(8)          // 8 decode slots
///     .page_size(32)                       // 32 token rows per KV page
///     .kv_format(presets::bfp_w(6));       // block-quantised KV pages
/// let session = BatchedDecodeSession::new(&model, &cfg);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SessionConfig {
    /// Number of concurrent decode slots (batch lanes); must be >= 1.
    pub slots: usize,
    /// KV page/store configuration.
    pub kv: KvConfig,
    /// Context cap in tokens; 0 means "use the model's `max_seq`". Values
    /// above `max_seq` are clamped to it at session construction.
    pub max_context: usize,
}

impl SessionConfig {
    pub fn new(slots: usize) -> Self {
        let cfg = SessionConfig {
            slots,
            kv: KvConfig::default(),
            max_context: 0,
        };
        cfg.validate();
        cfg
    }

    pub fn page_size(mut self, n: usize) -> Self {
        self.kv.page_size = n;
        self.validate();
        self
    }

    pub fn kv_format(mut self, fmt: QFormat) -> Self {
        self.kv.format = fmt;
        self.validate();
        self
    }

    pub fn prefix_cache_pages(mut self, n: usize) -> Self {
        self.kv.prefix_cache_pages = n;
        self
    }

    pub fn max_context(mut self, n: usize) -> Self {
        self.max_context = n;
        self
    }

    pub fn kv(mut self, kv: KvConfig) -> Self {
        self.kv = kv;
        self.validate();
        self
    }

    pub fn validate(&self) {
        assert!(self.slots >= 1, "SessionConfig: slots must be >= 1");
        self.kv.validate();
    }
}

/// Point-in-time KV accounting. Shared pages are counted once; packed
/// pages at packed size.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KvStats {
    /// Bytes held in raw-f32 page rows (committed rows only).
    pub bytes_f32: usize,
    /// Bytes held in bit-packed (sealed, block-format) pages.
    pub bytes_packed: usize,
    /// Bytes reachable from the prefix cache (the part of `bytes()` that
    /// is pinned by caching rather than by live slots).
    pub cache_bytes: usize,
    /// Live pages.
    pub pages: usize,
    /// Pages mapped into two or more slot tables (true prefix sharing).
    pub pages_shared: usize,
    pub prefix_lookups: usize,
    pub prefix_hits: usize,
    /// Prompt rows skipped thanks to attached prefixes.
    pub prefix_hit_rows: usize,
}

impl KvStats {
    pub fn bytes(&self) -> usize {
        self.bytes_f32 + self.bytes_packed
    }

    pub fn hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }
}

/// Per-layer storage of one page.
enum LayerPage {
    /// Raw rows; buffers are allocated at full page capacity up front so
    /// `append_rows` can write by position without reallocation.
    F32 { k: Vec<f32>, v: Vec<f32> },
    /// Sealed, bit-packed `[page_size, d]` tensors (block formats only).
    Packed { k: QTensor, v: QTensor },
}

struct KvPage {
    /// Outstanding references: one per slot table containing the page, one
    /// per child page (chain link), one if held by the prefix cache.
    refs: usize,
    /// Committed token rows (== `page_size` once sealed).
    len: usize,
    /// Token ids covered by this page; drives prefix hashing/verification.
    tokens: Vec<usize>,
    /// Previous page of the chain; holds one ref on it so cached tails pin
    /// their whole prefix.
    parent: Option<usize>,
    /// Chain hash (parent chain + this page's tokens); valid once sealed.
    hash: u64,
    sealed: bool,
    cached: bool,
    last_used: u64,
    /// One entry per model layer.
    layers: Vec<LayerPage>,
}

/// The paged KV store. Owns every page, the per-slot page tables, and the
/// prefix cache; `BatchedDecodeSession` drives it with the
/// `prepare_append` → per-layer `append_rows` → `commit_append` protocol
/// and reads through `slot_slices` / `gather_into`.
pub struct PagedKv {
    page_size: usize,
    fmt: QFormat,
    n_layers: usize,
    d: usize,
    pages: Vec<KvPage>,
    /// Indices of freed `pages` entries, available for reuse.
    free: Vec<usize>,
    tables: Vec<Vec<usize>>,
    pos: Vec<usize>,
    /// chain hash → sealed page indices (collision list).
    cache: HashMap<u64, Vec<usize>>,
    cache_cap: usize,
    cache_len: usize,
    /// Monotonic clock for LRU eviction.
    tick: u64,
    prefix_lookups: usize,
    prefix_hits: usize,
    prefix_hit_rows: usize,
}

impl PagedKv {
    pub fn new(n_slots: usize, n_layers: usize, d: usize, kv: &KvConfig) -> Self {
        kv.validate();
        assert!(n_slots >= 1, "PagedKv: need at least one slot");
        PagedKv {
            page_size: kv.page_size,
            fmt: kv.format,
            n_layers,
            d,
            pages: Vec::new(),
            free: Vec::new(),
            tables: vec![Vec::new(); n_slots],
            pos: vec![0; n_slots],
            cache: HashMap::new(),
            cache_cap: kv.prefix_cache_pages,
            cache_len: 0,
            tick: 0,
            prefix_lookups: 0,
            prefix_hits: 0,
            prefix_hit_rows: 0,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.tables.len()
    }

    pub fn pos(&self, slot: usize) -> usize {
        self.pos[slot]
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn format(&self) -> QFormat {
        self.fmt
    }

    fn alloc_page(&mut self, parent: Option<usize>) -> usize {
        if let Some(pi) = parent {
            self.pages[pi].refs += 1;
        }
        self.tick += 1;
        let layers = (0..self.n_layers)
            .map(|_| LayerPage::F32 {
                k: vec![0.0; self.page_size * self.d],
                v: vec![0.0; self.page_size * self.d],
            })
            .collect();
        let page = KvPage {
            refs: 1,
            len: 0,
            tokens: Vec::with_capacity(self.page_size),
            parent,
            hash: 0,
            sealed: false,
            cached: false,
            last_used: self.tick,
            layers,
        };
        match self.free.pop() {
            Some(idx) => {
                self.pages[idx] = page;
                idx
            }
            None => {
                self.pages.push(page);
                self.pages.len() - 1
            }
        }
    }

    /// Drop one reference; frees the page at zero and cascades up the
    /// parent chain (a freed child releases its chain link).
    fn decref(&mut self, idx: usize) {
        let mut cur = Some(idx);
        while let Some(i) = cur {
            let p = &mut self.pages[i];
            debug_assert!(p.refs > 0, "double release of page {i}");
            p.refs -= 1;
            if p.refs > 0 {
                return;
            }
            debug_assert!(!p.cached, "cached page freed while still indexed");
            cur = p.parent.take();
            p.layers = Vec::new();
            p.tokens = Vec::new();
            p.len = 0;
            p.sealed = false;
            p.hash = 0;
            self.free.push(i);
        }
    }

    /// Release every page mapped by `slot` and rewind it to position 0.
    /// Pages survive if shared with other slots or pinned by the cache.
    pub fn reset_slot(&mut self, slot: usize) {
        let table = std::mem::take(&mut self.tables[slot]);
        for idx in table {
            self.decref(idx);
        }
        self.pos[slot] = 0;
    }

    /// Make pages writable for the next `toks.len()` rows of `slot` and
    /// record the token ids. Call once per step before the layer loop,
    /// then `append_rows` for every layer, then one `commit_append`.
    /// Copy-on-write happens here: a sealed or shared tail page is forked
    /// before any row lands in it.
    pub fn prepare_append(&mut self, slot: usize, toks: &[usize]) {
        let p_sz = self.page_size;
        let mut pos = self.pos[slot];
        for &tok in toks {
            let ti = pos / p_sz;
            let row = pos % p_sz;
            if ti == self.tables[slot].len() {
                let parent = self.tables[slot].last().copied();
                let fresh = self.alloc_page(parent);
                self.tables[slot].push(fresh);
            } else {
                let idx = self.tables[slot][ti];
                let pg = &self.pages[idx];
                // `tokens.len()` (not `len`) tracks rows written so far in
                // this chunk; `len` only catches up at commit.
                if pg.sealed || pg.refs > 1 || pg.tokens.len() != row {
                    self.fork_tail(slot, ti, row);
                }
            }
            let idx = self.tables[slot][ti];
            let pg = &mut self.pages[idx];
            debug_assert_eq!(pg.tokens.len(), row);
            pg.tokens.push(tok);
            pos += 1;
        }
    }

    /// Replace the tail page `tables[slot][ti]` with a private copy of its
    /// first `keep` rows (the copy-on-write fork).
    fn fork_tail(&mut self, slot: usize, ti: usize, keep: usize) {
        let orig = self.tables[slot][ti];
        let parent = self.pages[orig].parent;
        let fresh = self.alloc_page(parent);
        let d = self.d;
        let mut kbuf = vec![0.0f32; keep * d];
        let mut vbuf = vec![0.0f32; keep * d];
        for li in 0..self.n_layers {
            if keep > 0 {
                self.read_rows(orig, li, keep, &mut kbuf, &mut vbuf);
            }
            if let LayerPage::F32 { k, v } = &mut self.pages[fresh].layers[li] {
                k[..keep * d].copy_from_slice(&kbuf);
                v[..keep * d].copy_from_slice(&vbuf);
            }
        }
        let toks = self.pages[orig].tokens[..keep].to_vec();
        let pg = &mut self.pages[fresh];
        pg.len = keep;
        pg.tokens = toks;
        self.tables[slot][ti] = fresh;
        self.decref(orig);
    }

    /// Decode the first `rows` rows of one layer of a page into `k_out` /
    /// `v_out` (raw copy for f32 pages, lossless block decode for packed).
    fn read_rows(
        &self,
        idx: usize,
        layer: usize,
        rows: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let d = self.d;
        match &self.pages[idx].layers[layer] {
            LayerPage::F32 { k, v } => {
                k_out[..rows * d].copy_from_slice(&k[..rows * d]);
                v_out[..rows * d].copy_from_slice(&v[..rows * d]);
            }
            LayerPage::Packed { k, v } => {
                for r in 0..rows {
                    k.decode_row_into(r, &mut k_out[r * d..(r + 1) * d]);
                    v.decode_row_into(r, &mut v_out[r * d..(r + 1) * d]);
                }
            }
        }
    }

    /// Write `m = k_rows.len()/d` K/V rows (post-RoPE) for one layer at the
    /// slot's current position. Rows are fake-quantised to the page format
    /// on write, so stored values are independent of page geometry,
    /// sharing, and sealing time.
    pub fn append_rows(&mut self, slot: usize, layer: usize, k_rows: &[f32], v_rows: &[f32]) {
        let d = self.d;
        let fmt = self.fmt;
        debug_assert_eq!(k_rows.len(), v_rows.len());
        debug_assert_eq!(k_rows.len() % d, 0);
        let m = k_rows.len() / d;
        let p_sz = self.page_size;
        let base = self.pos[slot];
        for r in 0..m {
            let pos = base + r;
            let idx = self.tables[slot][pos / p_sz];
            let row = pos % p_sz;
            match &mut self.pages[idx].layers[layer] {
                LayerPage::F32 { k, v } => {
                    let kd = &mut k[row * d..(row + 1) * d];
                    let vd = &mut v[row * d..(row + 1) * d];
                    kd.copy_from_slice(&k_rows[r * d..(r + 1) * d]);
                    vd.copy_from_slice(&v_rows[r * d..(r + 1) * d]);
                    if fmt != QFormat::Fp32 {
                        fake_quant_buffer(kd, d, fmt);
                        fake_quant_buffer(vd, d, fmt);
                    }
                }
                LayerPage::Packed { .. } => unreachable!("append into sealed page"),
            }
        }
    }

    /// Commit `m` rows appended on every layer: bump page lens and the slot
    /// position, then seal (hash, optionally bit-pack, and prefix-cache)
    /// any page that became full.
    pub fn commit_append(&mut self, slot: usize, m: usize) {
        if m == 0 {
            return;
        }
        let p_sz = self.page_size;
        let start = self.pos[slot];
        self.pos[slot] += m;
        let end = self.pos[slot];
        self.tick += 1;
        let tick = self.tick;
        for ti in start / p_sz..end.div_ceil(p_sz) {
            let idx = self.tables[slot][ti];
            let len = (end - ti * p_sz).min(p_sz);
            {
                let pg = &mut self.pages[idx];
                pg.len = len;
                pg.last_used = tick;
                debug_assert_eq!(pg.len, pg.tokens.len());
            }
            if len == p_sz && !self.pages[idx].sealed {
                self.seal(idx);
            }
        }
    }

    /// Roll `slot` back to `new_pos` *committed* rows — the speculative
    /// rejection path for a draft session, whose proposals are committed
    /// like real decode steps. Whole rejected pages are released from the
    /// page table (sealed ones survive while pinned by the prefix cache or
    /// shared with another slot); a partially-rejected tail page is trimmed
    /// in place when private and unsealed, else copy-on-write-forked down
    /// to the surviving rows — a sealed or shared page is never mutated.
    pub fn truncate(&mut self, slot: usize, new_pos: usize) {
        let pos = self.pos[slot];
        assert!(
            new_pos <= pos,
            "truncate(slot {slot}): new_pos {new_pos} beyond committed {pos}"
        );
        if new_pos == pos {
            return;
        }
        let p_sz = self.page_size;
        let keep_pages = new_pos.div_ceil(p_sz);
        while self.tables[slot].len() > keep_pages {
            let idx = self.tables[slot].pop().unwrap();
            self.decref(idx);
        }
        let rem = new_pos % p_sz;
        if rem != 0 {
            let ti = keep_pages - 1;
            let idx = self.tables[slot][ti];
            let pg = &self.pages[idx];
            if pg.len > rem || pg.tokens.len() > rem {
                if pg.sealed || pg.refs > 1 {
                    self.fork_tail(slot, ti, rem);
                } else {
                    let pg = &mut self.pages[idx];
                    pg.len = rem;
                    pg.tokens.truncate(rem);
                }
            }
        }
        self.pos[slot] = new_pos;
    }

    /// Discard rows written through `prepare_append`/`append_rows` but
    /// never committed, keeping only the first `keep` of them — the
    /// speculative verify path: the target feeds all proposed rows through
    /// one chunked step, then commits just the accepted prefix and drops
    /// the rest here *before* [`PagedKv::commit_append`]. Uncommitted rows
    /// can never have sealed a page (sealing requires a full page of
    /// committed rows), so the pages dropped or trimmed here are private
    /// scratch — shared prefixes and the prefix cache cannot observe a
    /// speculated token, which is what makes post-rejection state
    /// indistinguishable from a session that never speculated.
    pub fn rollback_prepared(&mut self, slot: usize, keep: usize) {
        let p_sz = self.page_size;
        let end = self.pos[slot] + keep;
        let keep_pages = end.div_ceil(p_sz);
        while self.tables[slot].len() > keep_pages {
            let idx = self.tables[slot].pop().unwrap();
            debug_assert!(
                !self.pages[idx].sealed && self.pages[idx].refs == 1,
                "uncommitted page {idx} sealed or shared"
            );
            self.decref(idx);
        }
        if let Some(&idx) = self.tables[slot].last() {
            let last_rows = end - (self.tables[slot].len() - 1) * p_sz;
            let pg = &mut self.pages[idx];
            if pg.tokens.len() > last_rows {
                debug_assert!(
                    !pg.sealed && pg.refs == 1,
                    "uncommitted tail page {idx} sealed or shared"
                );
                pg.tokens.truncate(last_rows);
            }
        }
    }

    /// Seal a full page: compute its chain hash, bit-pack it under block
    /// formats (lossless — rows were already fake-quantised at append and
    /// the block formats are exactly idempotent), and register it in the
    /// prefix cache.
    fn seal(&mut self, idx: usize) {
        let parent_hash = match self.pages[idx].parent {
            Some(pi) => {
                debug_assert!(self.pages[pi].sealed, "parent must seal before child");
                self.pages[pi].hash
            }
            None => FNV_OFFSET,
        };
        let hash = chain_hash(parent_hash, &self.pages[idx].tokens);
        let fmt = self.fmt;
        let (p_sz, d) = (self.page_size, self.d);
        let pg = &mut self.pages[idx];
        pg.hash = hash;
        pg.sealed = true;
        if fmt != QFormat::Fp32 {
            for li in 0..pg.layers.len() {
                let old = std::mem::replace(
                    &mut pg.layers[li],
                    LayerPage::F32 {
                        k: Vec::new(),
                        v: Vec::new(),
                    },
                );
                if let LayerPage::F32 { k, v } = old {
                    pg.layers[li] = LayerPage::Packed {
                        k: qtensor::encode(&Tensor::new(&[p_sz, d], k), fmt),
                        v: qtensor::encode(&Tensor::new(&[p_sz, d], v), fmt),
                    };
                }
            }
        }
        self.cache_insert(idx);
    }

    fn cache_insert(&mut self, idx: usize) {
        if self.cache_cap == 0 {
            return;
        }
        let hash = self.pages[idx].hash;
        if let Some(cands) = self.cache.get(&hash) {
            let cands = cands.clone();
            for &c in &cands {
                if self.chains_equal(c, idx) {
                    return; // an identical chain is already cached
                }
            }
        }
        self.cache.entry(hash).or_default().push(idx);
        self.pages[idx].cached = true;
        self.pages[idx].refs += 1;
        self.cache_len += 1;
        while self.cache_len > self.cache_cap {
            self.evict_lru();
        }
    }

    /// Token-exact chain comparison (hash collisions must not alias).
    fn chains_equal(&self, mut a: usize, mut b: usize) -> bool {
        loop {
            if a == b {
                return true; // chains converge on a shared ancestor
            }
            if self.pages[a].tokens[..] != self.pages[b].tokens[..] {
                return false;
            }
            match (self.pages[a].parent, self.pages[b].parent) {
                (None, None) => return true,
                (Some(pa), Some(pb)) => {
                    a = pa;
                    b = pb;
                }
                _ => return false,
            }
        }
    }

    fn evict_lru(&mut self) {
        let mut best_idx = usize::MAX;
        let mut best_tick = u64::MAX;
        for (i, p) in self.pages.iter().enumerate() {
            if p.cached && p.last_used < best_tick {
                best_tick = p.last_used;
                best_idx = i;
            }
        }
        if best_idx == usize::MAX {
            return;
        }
        let hash = self.pages[best_idx].hash;
        if let Some(v) = self.cache.get_mut(&hash) {
            v.retain(|&p| p != best_idx);
            if v.is_empty() {
                self.cache.remove(&hash);
            }
        }
        self.pages[best_idx].cached = false;
        self.cache_len -= 1;
        self.decref(best_idx);
    }

    /// Attach shared prefill pages for `prompt` to an empty slot; returns
    /// the number of prompt rows covered (the caller skips recomputing
    /// them). At most `prompt.len() - 1` rows are covered so the final
    /// prompt row is always recomputed (its logits drive the first sampled
    /// token) — when the whole prompt is cached, that recompute
    /// copy-on-write-forks the last shared page.
    pub fn attach_prefix(&mut self, slot: usize, prompt: &[usize]) -> usize {
        debug_assert!(self.tables[slot].is_empty() && self.pos[slot] == 0);
        if self.cache_cap == 0 || prompt.len() < 2 {
            return 0;
        }
        let p_sz = self.page_size;
        let n_max = prompt.len() / p_sz;
        if n_max == 0 {
            return 0;
        }
        self.prefix_lookups += 1;
        let mut hashes = Vec::with_capacity(n_max);
        let mut h = FNV_OFFSET;
        for n in 0..n_max {
            h = chain_hash(h, &prompt[n * p_sz..(n + 1) * p_sz]);
            hashes.push(h);
        }
        for n in (1..=n_max).rev() {
            let Some(cands) = self.cache.get(&hashes[n - 1]) else {
                continue;
            };
            let cands = cands.clone();
            for &tail in &cands {
                let Some(chain) = self.chain_matching(tail, &prompt[..n * p_sz]) else {
                    continue;
                };
                self.tick += 1;
                for &pg in &chain {
                    self.pages[pg].refs += 1;
                    self.pages[pg].last_used = self.tick;
                }
                self.tables[slot] = chain;
                let rows = (n * p_sz).min(prompt.len() - 1);
                self.pos[slot] = rows;
                self.prefix_hits += 1;
                self.prefix_hit_rows += rows;
                return rows;
            }
        }
        0
    }

    /// Walk `tail`'s parent chain; return the page indices in table order
    /// iff the chain covers exactly `toks`.
    fn chain_matching(&self, tail: usize, toks: &[usize]) -> Option<Vec<usize>> {
        let p_sz = self.page_size;
        debug_assert_eq!(toks.len() % p_sz, 0);
        let n = toks.len() / p_sz;
        let mut chain = vec![0usize; n];
        let mut cur = Some(tail);
        for i in (0..n).rev() {
            let idx = cur?;
            if self.pages[idx].tokens[..] != toks[i * p_sz..(i + 1) * p_sz] {
                return None;
            }
            chain[i] = idx;
            cur = self.pages[idx].parent;
        }
        if cur.is_some() {
            return None; // candidate's prefix is longer than the prompt's
        }
        Some(chain)
    }

    /// Fast path: a slot whose context lives in a single resident f32 page
    /// reads K/V in place with no gather copy (`page_size >= max context`
    /// and no packing turns the store back into the dense layout).
    pub fn slot_slices(&self, slot: usize, layer: usize, upto: usize) -> Option<(&[f32], &[f32])> {
        let table = &self.tables[slot];
        if table.len() != 1 {
            return None;
        }
        match &self.pages[table[0]].layers[layer] {
            LayerPage::F32 { k, v } => Some((&k[..upto * self.d], &v[..upto * self.d])),
            LayerPage::Packed { .. } => None,
        }
    }

    /// Gather the first `upto` rows of `slot` for `layer` into contiguous
    /// `[upto, d]` buffers, decoding packed pages losslessly. `upto` may
    /// run ahead of the committed position mid-step (rows written by
    /// `append_rows` but not yet committed are readable).
    pub fn gather_into(
        &self,
        slot: usize,
        layer: usize,
        upto: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        let d = self.d;
        k_out.resize(upto * d, 0.0);
        v_out.resize(upto * d, 0.0);
        let mut done = 0;
        for &idx in &self.tables[slot] {
            if done >= upto {
                break;
            }
            let take = (upto - done).min(self.page_size);
            match &self.pages[idx].layers[layer] {
                LayerPage::F32 { k, v } => {
                    k_out[done * d..(done + take) * d].copy_from_slice(&k[..take * d]);
                    v_out[done * d..(done + take) * d].copy_from_slice(&v[..take * d]);
                }
                LayerPage::Packed { k, v } => {
                    for r in 0..take {
                        k.decode_row_into(r, &mut k_out[(done + r) * d..(done + r + 1) * d]);
                        v.decode_row_into(r, &mut v_out[(done + r) * d..(done + r + 1) * d]);
                    }
                }
            }
            done += take;
        }
        debug_assert_eq!(done, upto);
    }

    /// Point-in-time accounting; shared pages counted once.
    pub fn stats(&self) -> KvStats {
        let mut s = KvStats {
            prefix_lookups: self.prefix_lookups,
            prefix_hits: self.prefix_hits,
            prefix_hit_rows: self.prefix_hit_rows,
            ..KvStats::default()
        };
        let mut table_refs = vec![0usize; self.pages.len()];
        for t in &self.tables {
            for &i in t {
                table_refs[i] += 1;
            }
        }
        for (i, p) in self.pages.iter().enumerate() {
            if p.refs == 0 {
                continue;
            }
            s.pages += 1;
            if table_refs[i] >= 2 {
                s.pages_shared += 1;
            }
            for l in &p.layers {
                match l {
                    LayerPage::F32 { .. } => s.bytes_f32 += p.len * self.d * 4 * 2,
                    LayerPage::Packed { k, v } => {
                        s.bytes_packed += k.packed_bytes() + v.packed_bytes()
                    }
                }
            }
        }
        // Mark everything reachable from the cache through parent links.
        let mut mark = vec![false; self.pages.len()];
        for (i, p) in self.pages.iter().enumerate() {
            if !p.cached {
                continue;
            }
            let mut cur = Some(i);
            while let Some(c) = cur {
                if mark[c] {
                    break;
                }
                mark[c] = true;
                cur = self.pages[c].parent;
            }
        }
        for (i, p) in self.pages.iter().enumerate() {
            if !mark[i] {
                continue;
            }
            for l in &p.layers {
                match l {
                    LayerPage::F32 { .. } => s.cache_bytes += p.len * self.d * 4 * 2,
                    LayerPage::Packed { k, v } => {
                        s.cache_bytes += k.packed_bytes() + v.packed_bytes()
                    }
                }
            }
        }
        s
    }

    /// Total resident KV bytes (shared pages once, packed pages at packed
    /// size).
    pub fn kv_bytes(&self) -> usize {
        self.stats().bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;

    #[test]
    #[should_panic(expected = "slots must be >= 1")]
    fn config_rejects_zero_slots() {
        SessionConfig::new(0);
    }

    #[test]
    #[should_panic(expected = "page_size must be >= 1")]
    fn config_rejects_zero_page() {
        let _ = SessionConfig::new(1).page_size(0);
    }

    #[test]
    #[should_panic(expected = "block format")]
    fn config_rejects_per_tensor_fixed_kv() {
        // per-tensor fixed point is not exactly idempotent across rows, so
        // pack-on-seal would be lossy — rejected at validation
        let _ = SessionConfig::new(1).kv_format(presets::fixed8());
    }

    /// 1-layer store with d=2 and distinguishable row values.
    fn tiny(kv: &KvConfig) -> PagedKv {
        PagedKv::new(2, 1, 2, kv)
    }

    /// Append `toks` one step, writing rows whose value encodes (slot, pos).
    fn push(kv: &mut PagedKv, slot: usize, toks: &[usize]) {
        kv.prepare_append(slot, toks);
        let base = kv.pos(slot);
        let m = toks.len();
        let mut k_rows = Vec::new();
        let mut v_rows = Vec::new();
        for r in 0..m {
            let val = (slot * 1000 + base + r) as f32;
            k_rows.extend_from_slice(&[val, val + 0.5]);
            v_rows.extend_from_slice(&[-val, -val - 0.5]);
        }
        kv.append_rows(slot, 0, &k_rows, &v_rows);
        kv.commit_append(slot, m);
    }

    fn rows_of(kv: &PagedKv, slot: usize, upto: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = Vec::new();
        let mut v = Vec::new();
        kv.gather_into(slot, 0, upto, &mut k, &mut v);
        (k, v)
    }

    #[test]
    fn prefix_attach_shares_pages_and_counts_bytes_once() {
        let cfg = KvConfig {
            page_size: 2,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12, 13]); // two sealed pages
        let solo = kv.kv_bytes();
        assert_eq!(solo, 4 * 2 * 4 * 2); // 4 rows x d=2 x 4B x (k+v)

        let got = kv.attach_prefix(1, &[10, 11, 12, 13]);
        assert_eq!(got, 3, "full-prompt hit leaves the last row to recompute");
        assert_eq!(kv.pos(1), 3);
        // shared pages add no bytes
        assert_eq!(kv.kv_bytes(), solo);
        let s = kv.stats();
        assert_eq!(s.pages_shared, 2);
        assert_eq!(s.prefix_hits, 1);
        assert_eq!(s.prefix_hit_rows, 3);

        // mismatched prompt: no attach
        kv.reset_slot(1);
        assert_eq!(kv.attach_prefix(1, &[10, 11, 12, 99]), 2, "partial prefix");
        kv.reset_slot(1);
        assert_eq!(kv.attach_prefix(1, &[99, 11, 12, 13]), 0);
    }

    #[test]
    fn cow_fork_leaves_sharer_untouched() {
        let cfg = KvConfig {
            page_size: 2,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12, 13]);
        let (k0, v0) = rows_of(&kv, 0, 4);
        assert_eq!(kv.attach_prefix(1, &[10, 11, 12, 13]), 3);
        // recompute the final prompt row: forks the sealed tail page
        push(&mut kv, 1, &[13]);
        // divergence: slot 1 decodes different tokens
        push(&mut kv, 1, &[40]);
        let (k1, _v1) = rows_of(&kv, 1, 5);
        // shared prefix rows (written by slot 0) are identical
        assert_eq!(&k1[..3 * 2], &k0[..3 * 2]);
        // row 3 was rewritten by slot 1 (value encodes slot 1000+3)
        assert_eq!(k1[3 * 2], 1003.0);
        // slot 0 is untouched by the fork
        let (k0b, v0b) = rows_of(&kv, 0, 4);
        assert_eq!(k0, k0b);
        assert_eq!(v0, v0b);
    }

    #[test]
    fn reset_releases_pages_down_to_cache_pins() {
        let cfg = KvConfig {
            page_size: 2,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12, 13]);
        push(&mut kv, 1, &[20, 21, 22]); // second page unsealed
        assert!(kv.kv_bytes() > 0);
        kv.reset_slot(0);
        kv.reset_slot(1);
        let s = kv.stats();
        // everything still resident is pinned by the prefix cache
        assert_eq!(s.bytes(), s.cache_bytes);
        // slot 0's two sealed pages + slot 1's first sealed page survive;
        // slot 1's unsealed tail was freed
        assert_eq!(s.pages, 3);
        assert_eq!(kv.pos(0), 0);

        // a fresh identical prompt re-attaches from the cache alone
        assert_eq!(kv.attach_prefix(0, &[10, 11, 12, 13]), 3);
    }

    #[test]
    fn cache_capacity_evicts_lru_without_freeing_shared_chains() {
        let cfg = KvConfig {
            page_size: 2,
            prefix_cache_pages: 1,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12, 13]); // seals two pages, cache keeps 1
        // the older page was evicted from the cache but survives as the
        // cached tail's parent
        let s = kv.stats();
        assert_eq!(s.pages, 2);
        kv.reset_slot(0);
        // tail + its pinned parent both survive the reset
        assert_eq!(kv.stats().pages, 2);
        // and the full prefix still attaches via the cached tail
        assert_eq!(kv.attach_prefix(0, &[10, 11, 12, 13]), 3);
    }

    #[test]
    fn disabled_cache_frees_everything_on_reset() {
        let cfg = KvConfig {
            page_size: 2,
            prefix_cache_pages: 0,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12, 13]);
        assert_eq!(kv.attach_prefix(1, &[10, 11, 12, 13]), 0);
        kv.reset_slot(0);
        let s = kv.stats();
        assert_eq!(s.pages, 0);
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn truncate_rolls_back_committed_rows_without_touching_sealed_pages() {
        let cfg = KvConfig {
            page_size: 2,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12]);
        let (k3, v3) = rows_of(&kv, 0, 3);
        // speculate two committed rows: seals [12, 13], opens a tail page
        push(&mut kv, 0, &[13, 14]);
        kv.truncate(0, 3);
        assert_eq!(kv.pos(0), 3);
        let (k, v) = rows_of(&kv, 0, 3);
        assert_eq!(k, k3);
        assert_eq!(v, v3);
        // the sealed page survives in the cache (it was never mutated) and
        // decode continues cleanly past the rollback point
        push(&mut kv, 0, &[99]);
        assert_eq!(kv.pos(0), 4);
        let (k4, _) = rows_of(&kv, 0, 4);
        assert_eq!(&k4[..6], &k3[..]);
        assert_eq!(k4[6], 3.0, "row 3 rewritten after rollback");
    }

    #[test]
    fn truncate_to_zero_equals_reset() {
        let cfg = KvConfig {
            page_size: 2,
            prefix_cache_pages: 0,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12]);
        kv.truncate(0, 0);
        assert_eq!(kv.pos(0), 0);
        assert_eq!(kv.stats().pages, 0);
        assert_eq!(kv.kv_bytes(), 0);
    }

    #[test]
    fn rollback_prepared_matches_never_speculated_twin() {
        let cfg = KvConfig {
            page_size: 2,
            ..KvConfig::default()
        };
        let mut kv = tiny(&cfg);
        let mut twin = tiny(&cfg);
        push(&mut kv, 0, &[10, 11, 12]);
        push(&mut twin, 0, &[10, 11, 12]);
        // speculative verify on kv: 3 rows prepared + written, 1 accepted
        kv.prepare_append(0, &[13, 14, 15]);
        kv.append_rows(0, 0, &[1.0; 6], &[2.0; 6]);
        kv.rollback_prepared(0, 1);
        kv.commit_append(0, 1);
        // twin only ever sees the accepted row
        twin.prepare_append(0, &[13]);
        twin.append_rows(0, 0, &[1.0, 1.0], &[2.0, 2.0]);
        twin.commit_append(0, 1);
        assert_eq!(kv.pos(0), twin.pos(0));
        assert_eq!(kv.stats(), twin.stats());
        let (k_a, v_a) = rows_of(&kv, 0, 4);
        let (k_b, v_b) = rows_of(&twin, 0, 4);
        assert_eq!(k_a, k_b);
        assert_eq!(v_a, v_b);
        // continued decode stays in lockstep (page tables, cache, bytes)
        push(&mut kv, 0, &[16, 17]);
        push(&mut twin, 0, &[16, 17]);
        assert_eq!(kv.stats(), twin.stats());
        let (k_a, _) = rows_of(&kv, 0, 6);
        let (k_b, _) = rows_of(&twin, 0, 6);
        assert_eq!(k_a, k_b);
    }

    #[test]
    fn packed_pages_roundtrip_losslessly_and_shrink_bytes() {
        let fmt = presets::bfp_w(6);
        let cfg = KvConfig {
            page_size: 4,
            format: fmt,
            ..KvConfig::default()
        };
        // d=32 so BFP blocks of 16 tile the rows
        let mut kv = PagedKv::new(1, 1, 32, &cfg);
        let mut rng = crate::util::rng::Pcg32::new(9);
        let mut write = |kv: &mut PagedKv, toks: &[usize]| {
            kv.prepare_append(0, toks);
            let m = toks.len();
            let mut k_rows = Vec::with_capacity(m * 32);
            for _ in 0..m * 32 {
                k_rows.push(rng.normal());
            }
            let v_rows = k_rows.clone();
            kv.append_rows(0, 0, &k_rows, &v_rows);
            kv.commit_append(0, m);
        };
        write(&mut kv, &[1, 2, 3]);
        let (k_before, v_before) = {
            let mut k = Vec::new();
            let mut v = Vec::new();
            kv.gather_into(0, 0, 3, &mut k, &mut v);
            (k, v)
        };
        // fourth row seals + packs the page
        write(&mut kv, &[4]);
        let mut k_after = Vec::new();
        let mut v_after = Vec::new();
        kv.gather_into(0, 0, 3, &mut k_after, &mut v_after);
        // packing already-quantised rows is bit-lossless
        assert_eq!(k_before, k_after);
        assert_eq!(v_before, v_after);
        let s = kv.stats();
        assert!(s.bytes_packed > 0);
        // sealed page packs below its f32 footprint
        assert!(
            s.bytes_packed < 4 * 32 * 4 * 2,
            "packed {} vs f32 {}",
            s.bytes_packed,
            4 * 32 * 4 * 2
        );
    }
}
