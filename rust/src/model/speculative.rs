//! Self-drafting speculative decoding on the shared paged runtime: a
//! low-bit draft model (same family — typically the same weights
//! re-quantised to BFP4) autoregressively proposes up to `k` tokens per
//! round from its own paged KV, and the serving (target) model verifies
//! all `k + 1` rows in **one** chunked multi-row step — the same row-block
//! machinery chunked prefill uses, so the whole verify pays a single
//! weight-dequant pass per layer. That is exactly where the win lives in
//! this codebase: per-step cost is dominated by packed-weight decode,
//! which is amortised across every row a step carries.
//!
//! Greedy acceptance keeps the emitted stream **bit-identical to
//! target-only greedy decode**: row `j` of the verify step carries the
//! logits the target would produce sequentially after consuming the same
//! prefix (the chunked-step bit-identity contract of
//! [`BatchedDecodeSession::step_chunked`]), and acceptance reuses the
//! engine's own argmax ([`sample_logits`] at temperature 0, last maximal
//! index on ties). A proposal is accepted only when it *equals* that
//! argmax, so by induction every emitted token is the token target-only
//! decode would have emitted (tested in tests/speculative.rs per preset
//! format, thread count and `BBQ_ISA`). Temperature > 0 requests are out
//! of scope — the engine routes them through the plain path.
//!
//! Rollback never touches sealed or shared pages:
//!
//! * the target appends all `k + 1` verify rows *uncommitted*
//!   ([`BatchedDecodeSession::defer_commit`]) and then commits only the
//!   accepted prefix ([`BatchedDecodeSession::commit_partial`]) — a
//!   rejected row never advances the position, never seals a page and
//!   never enters the prefix cache, so the post-round store is
//!   bit-identical to a never-speculated session's;
//! * the draft commits its proposals as real decode steps and rolls back
//!   a rejected tail with [`BatchedDecodeSession::truncate`], which pops
//!   whole tail pages by refcount and copy-on-write-forks a partial tail
//!   only when it is sealed or shared.

use super::kv_cache::{sample_logits, BatchedDecodeSession};
use super::paged::{KvStats, SessionConfig};
use super::transformer::Model;

/// Speculative-decoding counters, aggregated across slots and rounds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Verify rounds executed (one chunked multi-row target step each).
    pub rounds: u64,
    /// Draft tokens proposed across all rounds.
    pub proposed: u64,
    /// Proposals accepted (target argmax agreed).
    pub accepted: u64,
    /// Proposals rejected (target argmax disagreed; the round emitted the
    /// target's correction instead).
    pub rejected: u64,
    /// Budget- or context-starved rounds that fell back to a plain
    /// single-row target step (no proposals, not counted in
    /// [`Self::rounds`]).
    pub fallback_steps: u64,
}

impl SpecStats {
    /// Fraction of proposals the target accepted (0 before any round).
    pub fn acceptance_rate(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }

    /// Tokens emitted per verify step: every round emits its accepted
    /// prefix plus one target token (correction or bonus), so this is
    /// `(accepted + rounds) / rounds` — the speed-up lever speculative
    /// decoding exists for (1.0 means no proposal ever survived; plain
    /// fallback steps are excluded).
    pub fn tokens_per_target_step(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            (self.accepted + self.rounds) as f64 / self.rounds as f64
        }
    }
}

/// The exact argmax the serving sampler uses at temperature 0 (last
/// maximal index on ties, token 0 on empty logits) — acceptance must
/// match it decision for decision or the bit-identity contract breaks.
fn greedy(logits: &[f32]) -> usize {
    sample_logits(logits, 0.0, &mut crate::util::rng::Pcg32::new(0))
}

/// A draft + target session pair sharing slot numbering: the engine's
/// speculative execution backend. Prompt rows flow into the target
/// normally (recorded per slot so the draft can catch up lazily); decode
/// happens in [`Self::round`]s.
pub struct SpeculativeSession<'m> {
    target: BatchedDecodeSession<'m>,
    draft: BatchedDecodeSession<'m>,
    /// Max proposals per round (`--spec-k`).
    k: usize,
    /// Per-slot tokens already fed to the target but not yet to the draft:
    /// prompt chunks, plain-path decode rows, and on a fully accepted
    /// round the last proposal (the draft never consumed it). The draft
    /// absorbs the backlog as the first rows of its next propose chunk.
    pending: Vec<Vec<usize>>,
    stats: SpecStats,
    max_context: usize,
}

impl<'m> SpeculativeSession<'m> {
    /// Build the pair over one [`SessionConfig`] (both stores get the same
    /// slot count, page geometry and KV format; the draft keeps its own
    /// pages — target KV is computed with target weights and would be
    /// wrong for the draft, so nothing is shared between the two).
    pub fn new(target: &'m Model, draft: &'m Model, cfg: &SessionConfig, k: usize) -> Self {
        assert!(k >= 1, "speculative k must be >= 1");
        assert_eq!(
            target.cfg().vocab_size,
            draft.cfg().vocab_size,
            "draft/target vocabulary mismatch"
        );
        let target = BatchedDecodeSession::new(target, cfg);
        let draft = BatchedDecodeSession::new(draft, cfg);
        let max_context = target.max_context().min(draft.max_context());
        let pending = vec![Vec::new(); target.n_slots()];
        SpeculativeSession {
            target,
            draft,
            k,
            pending,
            stats: SpecStats::default(),
            max_context,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.target.n_slots()
    }

    /// Tokens consumed so far by one slot (target side — the serving
    /// position; the draft trails by the slot's pending backlog).
    pub fn pos(&self, slot: usize) -> usize {
        self.target.pos(slot)
    }

    /// Context cap: the tighter of the two sessions' caps, so a round can
    /// always feed the draft as far as the target.
    pub fn max_context(&self) -> usize {
        self.max_context
    }

    pub fn reset_slot(&mut self, slot: usize) {
        self.target.reset_slot(slot);
        self.draft.reset_slot(slot);
        self.pending[slot].clear();
    }

    /// Prefix-cache lookup on the *target* store (the serving KV). The
    /// covered rows still enter the draft's backlog — the draft has no use
    /// for target pages and recomputes them with its own weights.
    pub fn attach_prefix(&mut self, slot: usize, prompt: &[usize]) -> usize {
        let covered = self.target.attach_prefix(slot, prompt);
        self.pending[slot].extend_from_slice(&prompt[..covered]);
        covered
    }

    /// Serving-side (target) resident KV bytes.
    pub fn kv_bytes(&self) -> usize {
        self.target.kv_bytes()
    }

    /// Draft-side resident KV bytes (reported separately in metrics — the
    /// draft store is speculation overhead, not serving state).
    pub fn draft_kv_bytes(&self) -> usize {
        self.draft.kv_bytes()
    }

    /// Serving-side (target) paged-KV accounting.
    pub fn kv_stats(&self) -> KvStats {
        self.target.kv_stats()
    }

    pub fn spec_stats(&self) -> SpecStats {
        self.stats
    }

    /// Feed row-blocks through the *target* (prefill chunks and
    /// temperature-sampled decode rows — everything that does not
    /// speculate). Same contract as [`BatchedDecodeSession::step_chunked`];
    /// the tokens join each slot's draft backlog.
    pub fn step_chunked(
        &mut self,
        batch: &[(usize, &[usize])],
        needs_logits: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        for &(slot, toks) in batch {
            self.pending[slot].extend_from_slice(toks);
        }
        self.target.step_chunked(batch, needs_logits)
    }

    /// One speculative round for a greedy decode-phase slot: draft
    /// proposes, target verifies in one chunked step, the accepted prefix
    /// commits. `next` is the slot's pending input token (the last emitted
    /// token); `budget` bounds how many tokens may still be emitted
    /// (`max_new_tokens` remainder). Returns the emitted tokens — at least
    /// one, at most `min(k, budget - 1) + 1` — which are exactly the next
    /// tokens target-only greedy decode would emit from the same state.
    pub fn round(&mut self, slot: usize, next: usize, budget: usize) -> Vec<usize> {
        assert!(budget >= 1, "round called with no token budget");
        let t_pos = self.target.pos(slot);
        assert!(t_pos < self.max_context, "context overflow in speculative round");
        // room - 1: the verify step feeds `next` plus k_r proposals, and
        // the draft runs one position ahead of its last proposal
        let k_r = self.k.min(budget - 1).min(self.max_context - t_pos - 1);
        if k_r == 0 {
            // no room to speculate (last budgeted token, or the context is
            // nearly full): plain greedy target step, draft catches up on
            // a later round
            let logits = self.target.step(&[(slot, next)]);
            self.pending[slot].push(next);
            self.stats.fallback_steps += 1;
            return vec![greedy(&logits[0])];
        }
        // ── phase 1: draft catch-up + autoregressive proposals ──────────
        // The backlog and `next` go in as one chunk (logits wanted on the
        // last row only), then each proposal feeds back one row at a time.
        let mut catchup = std::mem::take(&mut self.pending[slot]);
        catchup.push(next);
        let mut mask = vec![false; catchup.len()];
        *mask.last_mut().expect("catchup holds at least `next`") = true;
        let d_logits = self.draft.step_chunked(&[(slot, &catchup)], Some(&mask));
        let mut proposals = Vec::with_capacity(k_r);
        proposals.push(greedy(d_logits.last().expect("one row per catchup token")));
        for i in 1..k_r {
            let d_logits = self.draft.step(&[(slot, proposals[i - 1])]);
            proposals.push(greedy(&d_logits[0]));
        }
        // ── phase 2: one chunked verify step over [next, proposals…] ────
        // Deferred commit: the rows stay uncommitted until acceptance is
        // known, so a rejected row can never seal a page or advance pos.
        let mut rows = Vec::with_capacity(k_r + 1);
        rows.push(next);
        rows.extend_from_slice(&proposals);
        self.target.defer_commit(slot);
        let t_logits = self.target.step_chunked(&[(slot, &rows)], None);
        // ── phase 3: greedy acceptance ──────────────────────────────────
        // Row j's logits are the target's next-token distribution after
        // [.., next, proposals[..j]]; its argmax is the true next token
        // whenever every earlier proposal matched. First mismatch emits
        // the target's correction; a clean sweep emits the bonus token
        // from the last verify row.
        let mut emitted = Vec::with_capacity(k_r + 1);
        let mut accepted = 0usize;
        for j in 0..k_r {
            let g = greedy(&t_logits[j]);
            emitted.push(g);
            if g != proposals[j] {
                break;
            }
            accepted += 1;
        }
        if accepted == k_r {
            emitted.push(greedy(&t_logits[k_r]));
        }
        // ── phase 4: commit the accepted prefix, roll back the rest ─────
        self.target.commit_partial(slot, 1 + accepted);
        if accepted == k_r {
            // every draft row was a true token; the last proposal was
            // never fed to the draft, so it becomes backlog
            self.pending[slot].push(proposals[k_r - 1]);
        } else {
            // the draft consumed proposals[..k_r - 1]; of those, only the
            // first `accepted` are true tokens — drop the wrong tail
            let keep = self.draft.pos(slot) - (k_r - 1 - accepted);
            self.draft.truncate(slot, keep);
        }
        self.stats.rounds += 1;
        self.stats.proposed += k_r as u64;
        self.stats.accepted += accepted as u64;
        self.stats.rejected += (k_r - accepted) as u64;
        emitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::params::Params;
    use crate::model::plan::QuantPlan;
    use crate::quant::config::presets;

    fn pair() -> (Model, Model) {
        let cfg = ModelConfig::preset("nano");
        let params = Params::init(&cfg, 42);
        let target = Model::new(params.clone(), QuantPlan::uniform(presets::bfp_w(6)));
        let draft = Model::new(params, QuantPlan::uniform(presets::bfp_w(4)));
        (target, draft)
    }

    /// Target-only greedy decode through a plain batched session — the
    /// stream the speculative path must reproduce bit for bit.
    fn reference_stream(target: &Model, prompt: &[usize], n: usize) -> Vec<usize> {
        let mut sess = BatchedDecodeSession::new(target, &SessionConfig::new(1));
        let mut logits = sess.step_chunked(&[(0, prompt)], None);
        let mut out = Vec::with_capacity(n);
        let mut next = greedy(logits.last().unwrap());
        out.push(next);
        while out.len() < n {
            logits = sess.step(&[(0, next)]);
            next = greedy(&logits[0]);
            out.push(next);
        }
        out
    }

    #[test]
    fn speculative_stream_matches_target_only_greedy() {
        let (target, draft) = pair();
        let prompt = [3usize, 9, 100, 42, 7];
        let n = 24;
        let want = reference_stream(&target, &prompt, n);
        for k in [1usize, 2, 4, 7] {
            let mut spec = SpeculativeSession::new(&target, &draft, &SessionConfig::new(1), k);
            let mut mask = vec![false; prompt.len()];
            *mask.last_mut().unwrap() = true;
            let logits = spec.step_chunked(&[(0, &prompt)], Some(&mask));
            let mut out = vec![greedy(logits.last().unwrap())];
            while out.len() < n {
                let next = *out.last().unwrap();
                let emitted = spec.round(0, next, n - out.len());
                assert!(!emitted.is_empty());
                out.extend_from_slice(&emitted);
            }
            assert_eq!(out, want, "k={k}");
            assert_eq!(out.len(), n, "k={k}: budget respected exactly");
            let st = spec.spec_stats();
            assert!(st.rounds > 0, "k={k}");
            assert_eq!(st.proposed, st.accepted + st.rejected, "k={k}");
            // self-drafting from the same weights: proposals mostly land
            assert!(
                st.tokens_per_target_step() >= 1.0,
                "k={k}: {:?}",
                st
            );
        }
    }

    #[test]
    fn fallback_step_on_exhausted_budget() {
        let (target, draft) = pair();
        let mut spec = SpeculativeSession::new(&target, &draft, &SessionConfig::new(1), 4);
        let logits = spec.step_chunked(&[(0, &[3, 9])], None);
        let next = greedy(&logits[1]);
        // budget 1 → no room for proposals: exactly one token, no round
        let emitted = spec.round(0, next, 1);
        assert_eq!(emitted.len(), 1);
        let st = spec.spec_stats();
        assert_eq!(st.rounds, 0);
        assert_eq!(st.fallback_steps, 1);
        assert_eq!(emitted[0], reference_stream(&target, &[3, 9], 2)[1]);
    }

    #[test]
    fn round_respects_context_cap() {
        let (target, draft) = pair();
        let cfg = SessionConfig::new(1).max_context(8);
        let mut spec = SpeculativeSession::new(&target, &draft, &cfg, 4);
        assert_eq!(spec.max_context(), 8);
        let prompt = [3usize, 9, 100, 42, 7];
        let logits = spec.step_chunked(&[(0, &prompt)], None);
        let mut next = greedy(logits.last().unwrap());
        let mut out = vec![next];
        // 3 rows of room: rounds clamp k_r so target pos never passes 8
        while spec.pos(0) < spec.max_context() {
            let toks = spec.round(0, next, 64);
            out.extend_from_slice(&toks);
            next = *toks.last().unwrap();
        }
        assert_eq!(spec.pos(0), 8);
        // emitted tokens still match target-only greedy at the cap edge
        // (the reference session has no cap, so it can verify past it)
        assert_eq!(out, reference_stream(&target, &prompt, out.len()));
    }

    #[test]
    fn rejected_rounds_leave_target_store_pristine() {
        // a draft from *different* weights rejects often; after every
        // round the target store must equal a never-speculated twin's
        let cfg = ModelConfig::preset("nano");
        let target = Model::new(Params::init(&cfg, 42), QuantPlan::uniform(presets::bfp_w(6)));
        let draft = Model::new(Params::init(&cfg, 7), QuantPlan::uniform(presets::bfp_w(4)));
        let scfg = SessionConfig::new(1).page_size(4);
        let mut spec = SpeculativeSession::new(&target, &draft, &scfg, 3);
        let mut twin = BatchedDecodeSession::new(&target, &scfg);
        let prompt = [3usize, 9, 100];
        let logits = spec.step_chunked(&[(0, &prompt)], None);
        twin.step_chunked(&[(0, &prompt)], None);
        let mut next = greedy(logits.last().unwrap());
        for _ in 0..6 {
            let emitted = spec.round(0, next, 8);
            for &t in &emitted {
                twin.step(&[(0, next)]);
                next = t;
            }
            assert_eq!(spec.pos(0), twin.pos(0));
            assert_eq!(spec.kv_stats(), twin.kv_stats(), "target store diverged");
        }
        let st = spec.spec_stats();
        assert!(st.rejected > 0, "divergent draft should reject: {st:?}");
    }
}
