//! Versioned, human-diffable on-disk format for [`QuantPlan`] artifacts.
//!
//! A plan file is the deployable output of the mixed-precision TPE search
//! (`bbq search-plan`): line-based text, one directive per line, `#`
//! comments for provenance — so two plans diff cleanly in review and a
//! corrupted or truncated file is rejected, not half-loaded.
//!
//! ```text
//! bbqplan v1
//! # emitted by `bbq search-plan` (model micro, task lambada, 40 trials)
//! model name=micro layers=2 d_model=64 n_heads=2 d_ff=256 vocab=512 max_seq=256 pos=learned
//! fingerprint 90b4b7a7e8f1c3d2
//! mode fake_quant
//! store packed
//! outliers 0.005
//! default w=bfp_e8m5n16 a=bfp_e8m5n16
//! site L0.q_proj w=bfp_e8m3n16 a=bfp_e8m7n16
//! ...
//! end sites=32
//! ```
//!
//! [`load`] re-parses the text, checks every shape field and the FNV-1a
//! shape fingerprint against the [`ModelConfig`] it is being deployed
//! onto, runs [`QuantPlan::validate`] (layer coverage, KV-compatible
//! formats at ④⑤, outlier bound), and requires the `end sites=N` trailer
//! to match the site count — so truncation anywhere is detected. Formats
//! round-trip through [`QFormat::name`]/[`QFormat::parse`] and floats
//! through Rust's shortest-round-trip `Display`, making save → load
//! bit-exact (tested).

use super::config::{ModelConfig, PosEncoding};
use super::plan::{GemmMode, PlanError, QuantPlan, SiteId, WeightStore, GEMM_NAMES};
use crate::quant::config::{GemmQuant, QFormat};
use std::collections::HashMap;
use std::fmt;
use std::path::Path;

/// First line of every plan file: magic + format version.
pub const PLAN_HEADER: &str = "bbqplan v1";

/// Why a plan file could not be loaded (or an invalid plan saved).
#[derive(Debug)]
pub enum PlanFileError {
    /// Underlying filesystem error.
    Io(std::io::Error),
    /// The first line is not a `bbqplan` header at all.
    BadMagic(String),
    /// A `bbqplan` header with a version this build does not read.
    UnsupportedVersion(u32),
    /// The `end sites=N` trailer is missing or disagrees with the site
    /// count — the file was cut short or lines were lost.
    Truncated,
    /// A directive line failed to parse (1-based line number + reason).
    Parse {
        /// 1-based line number of the offending line.
        line: usize,
        /// What went wrong.
        msg: String,
    },
    /// A required directive never appeared.
    Missing(&'static str),
    /// A model-shape field in the file disagrees with the target config.
    ShapeMismatch {
        /// Which shape field disagrees.
        field: &'static str,
        /// The value recorded in the plan file.
        plan: String,
        /// The value of the config being deployed onto.
        model: String,
    },
    /// Shape fields match but the recorded fingerprint does not — the
    /// header was hand-edited or the file corrupted.
    FingerprintMismatch {
        /// Fingerprint recorded in the file.
        plan: u64,
        /// Fingerprint of the target config.
        model: u64,
    },
    /// The plan parsed but fails [`QuantPlan::validate`] against the
    /// target config.
    Invalid(PlanError),
}

impl fmt::Display for PlanFileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanFileError::Io(e) => write!(f, "plan file io: {e}"),
            PlanFileError::BadMagic(got) => {
                write!(f, "not a plan file (first line {got:?}, want {PLAN_HEADER:?})")
            }
            PlanFileError::UnsupportedVersion(v) => {
                write!(f, "plan file version v{v} unsupported (this build reads v1)")
            }
            PlanFileError::Truncated => {
                write!(f, "plan file truncated (missing or mismatched 'end sites=N' trailer)")
            }
            PlanFileError::Parse { line, msg } => write!(f, "plan file line {line}: {msg}"),
            PlanFileError::Missing(what) => write!(f, "plan file missing '{what}' directive"),
            PlanFileError::ShapeMismatch { field, plan, model } => write!(
                f,
                "plan was made for a different model shape: {field}={plan} in file, \
                 {field}={model} in target config"
            ),
            PlanFileError::FingerprintMismatch { plan, model } => write!(
                f,
                "plan shape fingerprint {plan:016x} != target config {model:016x}"
            ),
            PlanFileError::Invalid(e) => write!(f, "plan invalid for target config: {e}"),
        }
    }
}

impl std::error::Error for PlanFileError {}

impl From<std::io::Error> for PlanFileError {
    fn from(e: std::io::Error) -> Self {
        PlanFileError::Io(e)
    }
}

impl From<PlanError> for PlanFileError {
    fn from(e: PlanError) -> Self {
        PlanFileError::Invalid(e)
    }
}

/// FNV-1a fingerprint of a model's *shape* (everything that determines
/// which sites exist and how big their tensors are — the name is
/// deliberately excluded so a plan searched on "micro" deploys onto any
/// identically-shaped config).
pub fn shape_fingerprint(cfg: &ModelConfig) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let s = canonical_shape(cfg);
    let mut h = FNV_OFFSET;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

fn pos_name(pos: PosEncoding) -> &'static str {
    match pos {
        PosEncoding::Learned => "learned",
        PosEncoding::Rope => "rope",
    }
}

fn canonical_shape(cfg: &ModelConfig) -> String {
    format!(
        "layers={} d_model={} n_heads={} d_ff={} vocab={} max_seq={} pos={}",
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.d_ff,
        cfg.vocab_size,
        cfg.max_seq,
        pos_name(cfg.pos)
    )
}

fn gemm_name(gemm: u8) -> &'static str {
    GEMM_NAMES[(gemm - 1) as usize]
}

fn fmt_pair(q: GemmQuant) -> String {
    format!("w={} a={}", q.weight.name(), q.act.name())
}

/// Render a validated plan as plan-file text (the body [`save`] writes).
/// `provenance` lines become `#` comments under the header.
pub fn to_text(plan: &QuantPlan, cfg: &ModelConfig, provenance: &[String]) -> String {
    let mut out = String::new();
    out.push_str(PLAN_HEADER);
    out.push('\n');
    for p in provenance {
        for line in p.lines() {
            out.push_str("# ");
            out.push_str(line);
            out.push('\n');
        }
    }
    out.push_str(&format!("model name={} {}\n", cfg.name, canonical_shape(cfg)));
    out.push_str(&format!("fingerprint {:016x}\n", shape_fingerprint(cfg)));
    match plan.mode {
        GemmMode::FakeQuant => out.push_str("mode fake_quant\n"),
        GemmMode::LlmInt8 { threshold, bits } => {
            out.push_str(&format!("mode llm_int8 threshold={threshold} bits={bits}\n"))
        }
    }
    match plan.store {
        WeightStore::PackedAuto => out.push_str("store packed\n"),
        WeightStore::DenseF32 => out.push_str("store dense_f32\n"),
    }
    out.push_str(&format!("outliers {}\n", plan.outliers));
    out.push_str(&format!("default {}\n", fmt_pair(plan.default)));
    let mut sites: Vec<(&SiteId, &GemmQuant)> = plan.per_site.iter().collect();
    sites.sort_by_key(|(site, _)| **site);
    for (&(layer, gemm), &q) in &sites {
        out.push_str(&format!("site L{layer}.{} {}\n", gemm_name(gemm), fmt_pair(q)));
    }
    out.push_str(&format!("end sites={}\n", sites.len()));
    out
}

/// Parse plan-file text and validate it against `cfg` (shape fields,
/// fingerprint, then [`QuantPlan::validate`]).
pub fn from_text(text: &str, cfg: &ModelConfig) -> Result<QuantPlan, PlanFileError> {
    let parse = |line: usize, msg: String| PlanFileError::Parse { line, msg };
    let mut lines = text.lines().enumerate();
    let (_, first) = lines.next().unwrap_or((0, ""));
    if first.trim() != PLAN_HEADER {
        return match first.trim().strip_prefix("bbqplan v") {
            Some(v) => match v.trim().parse::<u32>() {
                Ok(n) => Err(PlanFileError::UnsupportedVersion(n)),
                Err(_) => Err(PlanFileError::BadMagic(first.trim().to_string())),
            },
            None => Err(PlanFileError::BadMagic(first.trim().to_string())),
        };
    }
    let mut model_line: Option<(usize, String)> = None;
    let mut fingerprint: Option<u64> = None;
    let mut mode: Option<GemmMode> = None;
    let mut store: Option<WeightStore> = None;
    let mut outliers: Option<f32> = None;
    let mut default: Option<GemmQuant> = None;
    let mut per_site: HashMap<SiteId, GemmQuant> = HashMap::new();
    let mut end_sites: Option<usize> = None;
    for (i, raw) in lines {
        let ln = i + 1; // 1-based
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if end_sites.is_some() {
            return Err(parse(ln, "content after 'end' trailer".to_string()));
        }
        let (word, rest) = line.split_once(char::is_whitespace).unwrap_or((line, ""));
        let rest = rest.trim();
        match word {
            "model" => {
                if model_line.is_some() {
                    return Err(parse(ln, "duplicate 'model' directive".to_string()));
                }
                model_line = Some((ln, rest.to_string()));
            }
            "fingerprint" => {
                let v = u64::from_str_radix(rest, 16)
                    .map_err(|e| parse(ln, format!("bad fingerprint {rest:?}: {e}")))?;
                fingerprint = Some(v);
            }
            "mode" => {
                let (m, margs) = rest.split_once(char::is_whitespace).unwrap_or((rest, ""));
                mode = Some(match m {
                    "fake_quant" => GemmMode::FakeQuant,
                    "llm_int8" => {
                        let kv = parse_kv(margs);
                        let threshold = kv
                            .get("threshold")
                            .and_then(|v| v.parse::<f32>().ok())
                            .ok_or_else(|| parse(ln, "llm_int8 needs threshold=".to_string()))?;
                        let bits = kv
                            .get("bits")
                            .and_then(|v| v.parse::<u32>().ok())
                            .ok_or_else(|| parse(ln, "llm_int8 needs bits=".to_string()))?;
                        GemmMode::LlmInt8 { threshold, bits }
                    }
                    other => return Err(parse(ln, format!("unknown mode {other:?}"))),
                });
            }
            "store" => {
                store = Some(match rest {
                    "packed" => WeightStore::PackedAuto,
                    "dense_f32" => WeightStore::DenseF32,
                    other => return Err(parse(ln, format!("unknown store {other:?}"))),
                });
            }
            "outliers" => {
                outliers = Some(
                    rest.parse::<f32>()
                        .map_err(|e| parse(ln, format!("bad outliers {rest:?}: {e}")))?,
                );
            }
            "default" => {
                default = Some(parse_formats(rest).map_err(|m| parse(ln, m))?);
            }
            "site" => {
                let (name, fmts) = rest
                    .split_once(char::is_whitespace)
                    .ok_or_else(|| parse(ln, format!("bad site line {rest:?}")))?;
                let site = parse_site(name).map_err(|m| parse(ln, m))?;
                let q = parse_formats(fmts.trim()).map_err(|m| parse(ln, m))?;
                if per_site.insert(site, q).is_some() {
                    return Err(parse(ln, format!("duplicate site {name:?}")));
                }
            }
            "end" => {
                let kv = parse_kv(rest);
                let n = kv
                    .get("sites")
                    .and_then(|v| v.parse::<usize>().ok())
                    .ok_or(PlanFileError::Truncated)?;
                end_sites = Some(n);
            }
            other => return Err(parse(ln, format!("unknown directive {other:?}"))),
        }
    }
    // truncation: no trailer, or the trailer disagrees with what arrived
    match end_sites {
        Some(n) if n == per_site.len() => {}
        _ => return Err(PlanFileError::Truncated),
    }
    let (model_ln, model_rest) = model_line.ok_or(PlanFileError::Missing("model"))?;
    check_shape(model_ln, &model_rest, cfg)?;
    let fp = fingerprint.ok_or(PlanFileError::Missing("fingerprint"))?;
    let want = shape_fingerprint(cfg);
    if fp != want {
        return Err(PlanFileError::FingerprintMismatch {
            plan: fp,
            model: want,
        });
    }
    let plan = QuantPlan {
        default: default.ok_or(PlanFileError::Missing("default"))?,
        per_site,
        mode: mode.ok_or(PlanFileError::Missing("mode"))?,
        store: store.ok_or(PlanFileError::Missing("store"))?,
        outliers: outliers.ok_or(PlanFileError::Missing("outliers"))?,
    };
    plan.validate(cfg)?;
    Ok(plan)
}

/// Save a plan as a deployable artifact, validating it against `cfg`
/// first so an unserveable plan is never written. `provenance` lines are
/// embedded as `#` comments.
pub fn save(
    plan: &QuantPlan,
    cfg: &ModelConfig,
    path: &Path,
    provenance: &[String],
) -> Result<(), PlanFileError> {
    plan.validate(cfg)?;
    if let Some(p) = path.parent() {
        if !p.as_os_str().is_empty() {
            std::fs::create_dir_all(p)?;
        }
    }
    std::fs::write(path, to_text(plan, cfg, provenance))?;
    Ok(())
}

/// Load a plan artifact and validate it against the config it is being
/// deployed onto. See the module docs for everything this checks.
pub fn load(path: &Path, cfg: &ModelConfig) -> Result<QuantPlan, PlanFileError> {
    from_text(&std::fs::read_to_string(path)?, cfg)
}

/// `k=v` pairs of a directive tail (whitespace-separated).
fn parse_kv(s: &str) -> HashMap<&str, &str> {
    s.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .collect()
}

/// `w=<fmt> a=<fmt>` → [`GemmQuant`].
fn parse_formats(s: &str) -> Result<GemmQuant, String> {
    let kv = parse_kv(s);
    let get = |key: &str| -> Result<QFormat, String> {
        let name = kv
            .get(key)
            .ok_or_else(|| format!("missing {key}= in {s:?}"))?;
        QFormat::parse(name).ok_or_else(|| format!("unknown format {name:?}"))
    };
    Ok(GemmQuant {
        weight: get("w")?,
        act: get("a")?,
    })
}

/// `L<layer>.<gemm_name>` → [`SiteId`].
fn parse_site(name: &str) -> Result<SiteId, String> {
    let body = name
        .strip_prefix('L')
        .ok_or_else(|| format!("site {name:?} must start with 'L'"))?;
    let (layer, gname) = body
        .split_once('.')
        .ok_or_else(|| format!("site {name:?} must be L<layer>.<gemm>"))?;
    let layer: usize = layer
        .parse()
        .map_err(|_| format!("bad layer in site {name:?}"))?;
    let gemm = GEMM_NAMES
        .iter()
        .position(|&g| g == gname)
        .ok_or_else(|| format!("unknown gemm {gname:?} in site {name:?}"))?;
    Ok((layer, (gemm + 1) as u8))
}

/// Compare every shape field on the `model` line against the target
/// config (name is informational only).
fn check_shape(ln: usize, rest: &str, cfg: &ModelConfig) -> Result<(), PlanFileError> {
    let kv = parse_kv(rest);
    let want: [(&'static str, String); 7] = [
        ("layers", cfg.n_layers.to_string()),
        ("d_model", cfg.d_model.to_string()),
        ("n_heads", cfg.n_heads.to_string()),
        ("d_ff", cfg.d_ff.to_string()),
        ("vocab", cfg.vocab_size.to_string()),
        ("max_seq", cfg.max_seq.to_string()),
        ("pos", pos_name(cfg.pos).to_string()),
    ];
    for (field, model_val) in want {
        let plan_val = kv.get(field).ok_or(PlanFileError::Parse {
            line: ln,
            msg: format!("model line missing {field}="),
        })?;
        if *plan_val != model_val {
            return Err(PlanFileError::ShapeMismatch {
                field,
                plan: plan_val.to_string(),
                model: model_val,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;

    fn mixed_plan(cfg: &ModelConfig) -> QuantPlan {
        let mut plan = QuantPlan::uniform(presets::bfp_w(6)).with_outliers(0.005);
        for l in 0..cfg.n_layers {
            for g in 1..=8u8 {
                let fmt = presets::bfp_w([4u32, 6, 8][(l + g as usize) % 3]);
                plan.set(l, g, GemmQuant::uniform(fmt));
            }
        }
        plan
    }

    #[test]
    fn text_roundtrip_is_bit_exact() {
        let cfg = ModelConfig::preset("nano");
        let plan = mixed_plan(&cfg);
        let text = to_text(&plan, &cfg, &["searched somewhere".to_string()]);
        let back = from_text(&text, &cfg).unwrap();
        assert_eq!(back, plan);
        // and the rendering itself is stable (sorted sites)
        assert_eq!(to_text(&back, &cfg, &["searched somewhere".to_string()]), text);
    }

    #[test]
    fn llm_int8_mode_roundtrips() {
        let cfg = ModelConfig::preset("nano");
        let plan = QuantPlan::llm_int8(8).with_store(WeightStore::DenseF32);
        let back = from_text(&to_text(&plan, &cfg, &[]), &cfg).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn fingerprint_tracks_shape_not_name() {
        let mut a = ModelConfig::preset("nano");
        let mut b = ModelConfig::preset("nano");
        b.name = "renamed".to_string();
        assert_eq!(shape_fingerprint(&a), shape_fingerprint(&b));
        a.d_ff += 1;
        assert_ne!(shape_fingerprint(&a), shape_fingerprint(&b));
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let cfg = ModelConfig::preset("nano");
        assert!(matches!(
            from_text("not a plan\n", &cfg),
            Err(PlanFileError::BadMagic(_))
        ));
        assert!(matches!(
            from_text("bbqplan v9\nend sites=0\n", &cfg),
            Err(PlanFileError::UnsupportedVersion(9))
        ));
    }

    #[test]
    fn rejects_truncation() {
        let cfg = ModelConfig::preset("nano");
        let text = to_text(&mixed_plan(&cfg), &cfg, &[]);
        // drop the trailer line
        let cut = text.rsplit_once("end ").unwrap().0;
        assert!(matches!(
            from_text(cut, &cfg),
            Err(PlanFileError::Truncated)
        ));
        // drop a site line but keep the trailer: count disagrees
        let missing: String = text
            .lines()
            .enumerate()
            .filter(|(i, _)| *i != 8)
            .map(|(_, l)| format!("{l}\n"))
            .collect();
        assert!(matches!(
            from_text(&missing, &cfg),
            Err(PlanFileError::Truncated)
        ));
    }

    #[test]
    fn rejects_garbage_lines_and_formats() {
        let cfg = ModelConfig::preset("nano");
        let text = to_text(&mixed_plan(&cfg), &cfg, &[]);
        let garbled = text.replace("site L0.q_proj", "site L0.zz_proj");
        assert!(matches!(
            from_text(&garbled, &cfg),
            Err(PlanFileError::Parse { .. })
        ));
        let garbled = text.replace("bfp_e8m5n16", "bfp_eXmYnZ");
        assert!(matches!(
            from_text(&garbled, &cfg),
            Err(PlanFileError::Parse { .. })
        ));
    }

    #[test]
    fn rejects_wrong_shape_and_tampered_fingerprint() {
        let nano = ModelConfig::preset("nano");
        let micro = ModelConfig::preset("micro");
        let text = to_text(&mixed_plan(&nano), &nano, &[]);
        assert!(matches!(
            from_text(&text, &micro),
            Err(PlanFileError::ShapeMismatch { field: "d_model", .. })
        ));
        // same shape, hand-edited fingerprint line
        let tampered = text.replace(
            &format!("fingerprint {:016x}", shape_fingerprint(&nano)),
            "fingerprint 00000000deadbeef",
        );
        assert!(matches!(
            from_text(&tampered, &nano),
            Err(PlanFileError::FingerprintMismatch { .. })
        ));
    }

    #[test]
    fn rejects_invalid_plans_on_save_and_load() {
        let cfg = ModelConfig::preset("nano");
        // per-tensor fixed8 at ④⑤ — validate refuses, so save refuses
        let plan = QuantPlan::uniform(presets::fixed8());
        let dir = std::env::temp_dir().join("bbq_test_planfile");
        let path = dir.join("bad.bbqp");
        assert!(matches!(
            save(&plan, &cfg, &path, &[]),
            Err(PlanFileError::Invalid(PlanError::KvIncompatibleFormat { .. }))
        ));
        // a file claiming a site beyond the model's layers fails load
        let mut plan = mixed_plan(&cfg);
        plan.set(7, 1, GemmQuant::uniform(presets::bfp_w(8)));
        let text = to_text(&plan, &cfg, &[]);
        assert!(matches!(
            from_text(&text, &cfg),
            Err(PlanFileError::Invalid(PlanError::LayerOutOfRange { .. }))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_load_file_roundtrip() {
        let cfg = ModelConfig::preset("nano");
        let plan = mixed_plan(&cfg);
        let dir = std::env::temp_dir().join("bbq_test_planfile_rt");
        let path = dir.join("plan.bbqp");
        save(&plan, &cfg, &path, &["prov line".to_string()]).unwrap();
        let back = load(&path, &cfg).unwrap();
        assert_eq!(back, plan);
        assert!(matches!(
            load(&dir.join("absent.bbqp"), &cfg),
            Err(PlanFileError::Io(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }
}
