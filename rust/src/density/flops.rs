//! FLOP / GEMM-operand profiler (paper Appendix B.4).
//!
//! The mixed-precision search needs, per quantisable tensor, its element
//! count — to turn a per-tensor format assignment into a model-level
//! memory density. This module enumerates the eight GEMMs of Algorithm 2
//! for a model configuration and reports operand sizes and MAC counts,
//! including the share of FLOPs in the two activation-activation GEMMs
//! (④⑤) that prior work leaves unquantised (~20% of self-attention in the
//! paper's accounting).

use crate::model::config::ModelConfig;

/// One GEMM site: `act [m,k] @ weight-ish [k,n]`, `per_layer` times.
#[derive(Clone, Debug)]
pub struct GemmSite {
    /// ①..⑧ in Algorithm 2
    pub index: usize,
    pub name: &'static str,
    /// contraction dim
    pub k: usize,
    /// act rows per token-sequence of length s (expressed at s=1; scale by seq)
    pub act_numel_per_tok: usize,
    pub weight_numel: usize,
    /// MACs per token
    pub macs_per_tok: usize,
    /// true for ④⑤ (both operands are activations)
    pub act_act: bool,
}

/// Row constructor keeping [`layer_gemms`]'s table readable: (index, name,
/// k, act numel per token, weight numel, MACs per token, act-act?).
fn site(
    index: usize,
    name: &'static str,
    k: usize,
    act: usize,
    weight: usize,
    macs: usize,
    act_act: bool,
) -> GemmSite {
    GemmSite {
        index,
        name,
        k,
        act_numel_per_tok: act,
        weight_numel: weight,
        macs_per_tok: macs,
        act_act,
    }
}

/// Enumerate the 8 GEMMs of one transformer layer.
pub fn layer_gemms(cfg: &ModelConfig, seq: usize) -> Vec<GemmSite> {
    let d = cfg.d_model;
    let f = cfg.d_ff;
    let s = seq;
    vec![
        site(1, "q_proj", d, d, d * d, d * d, false),
        site(2, "k_proj", d, d, d * d, d * d, false),
        site(3, "v_proj", d, d, d * d, d * d, false),
        // ④ S = Q K^T: per token, dot over head_dim with s keys × heads
        site(4, "qk_t", d / cfg.n_heads, d, 0, s * d, true),
        // ⑤ C = A V
        site(5, "att_v", s, cfg.n_heads * s, 0, s * d, true),
        site(6, "o_proj", d, d, d * d, d * d, false),
        site(7, "fc1", d, d, d * f, d * f, false),
        site(8, "fc2", f, f, d * f, d * f, false),
    ]
}

/// Whole-model profile at a given sequence length.
#[derive(Clone, Debug)]
pub struct FlopProfile {
    pub total_macs_per_tok: f64,
    pub attn_macs_per_tok: f64,
    pub act_act_macs_per_tok: f64,
    /// fraction of *self-attention* MACs in ④⑤ (paper: ~20.6% for OPT-6.7B)
    pub act_act_share_of_attn: f64,
    pub weight_numel: usize,
}

pub fn profile(cfg: &ModelConfig, seq: usize) -> FlopProfile {
    let mut total = 0.0;
    let mut attn = 0.0;
    let mut aa = 0.0;
    let mut w = cfg.vocab_size * cfg.d_model; // embedding
    for _ in 0..cfg.n_layers {
        for g in layer_gemms(cfg, seq) {
            total += g.macs_per_tok as f64;
            if g.index <= 6 {
                attn += g.macs_per_tok as f64;
            }
            if g.act_act {
                aa += g.macs_per_tok as f64;
            }
            w += g.weight_numel;
        }
        w += 4 * cfg.d_model + 2 * cfg.d_ff; // LN gains/biases + fc biases (approx)
    }
    // final LM head (tied embedding — no extra weights) still costs MACs
    total += (cfg.vocab_size * cfg.d_model) as f64;
    FlopProfile {
        total_macs_per_tok: total,
        attn_macs_per_tok: attn,
        act_act_macs_per_tok: aa,
        act_act_share_of_attn: if attn > 0.0 { aa / attn } else { 0.0 },
        weight_numel: w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn eight_gemms() {
        let cfg = ModelConfig::preset("tiny");
        let g = layer_gemms(&cfg, 64);
        assert_eq!(g.len(), 8);
        assert_eq!(g.iter().filter(|s| s.act_act).count(), 2);
    }

    #[test]
    fn act_act_share_grows_with_seq() {
        // at long sequence lengths ④⑤ dominate — the reason the paper
        // insists on quantising 8/8 GEMMs
        let cfg = ModelConfig::preset("tiny");
        let short = profile(&cfg, 32).act_act_share_of_attn;
        let long = profile(&cfg, 2048).act_act_share_of_attn;
        assert!(long > short);
        assert!(long > 0.15, "long-seq share {long}");
    }

    #[test]
    fn weight_count_scales_with_layers() {
        let a = profile(&ModelConfig::preset("micro"), 64).weight_numel;
        let b = profile(&ModelConfig::preset("small"), 64).weight_numel;
        assert!(b > a);
    }
}
