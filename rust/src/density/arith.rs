//! Arithmetic density (paper §3.2, Appendix D, Table 6).
//!
//! The paper synthesises one multiply-accumulate (MAC) unit per format with
//! Vivado and reports LUT-equivalent area (1 DSP = 100 LUTs). We do not
//! have Vivado, so we substitute a **structural gate-level cost model**:
//! each MAC is decomposed into a mantissa multiplier array, an alignment /
//! normalisation shifter, accumulator adders and exponent/bias logic, each
//! with a LUT cost linear in its bit counts; block-shared logic is
//! amortised over the block size. Three coefficients are calibrated by
//! least squares on five of the paper's published rows (FP32, Int8,
//! MiniFloat, BM, BL) and the three BFP rows are *held out* as validation
//! (see EXPERIMENTS.md — the model predicts them within ~20%).

use crate::quant::config::QFormat;

/// Structural feature counts for one MAC unit of a format.
#[derive(Clone, Copy, Debug, Default)]
pub struct MacStructure {
    /// partial-product bits of the mantissa multiplier (w1*w2)
    pub mult_bits: f64,
    /// accumulator + normalisation datapath bits (adds, LZC, rounding)
    pub acc_bits: f64,
    /// barrel-shifter work: width × stages
    pub shift_bits: f64,
    /// exponent / shared-bias adders (amortised over block if shared)
    pub exp_bits: f64,
}

/// Decompose a format's MAC into structural counts. `other` is the second
/// operand's format (a MAC multiplies act × weight — Table 6 uses the same
/// format on both sides, as do we).
pub fn mac_structure(fmt: QFormat) -> MacStructure {
    match fmt {
        QFormat::Fp32 => MacStructure {
            // 24×24 mantissa array, 48-bit product datapath with full
            // align/normalise on every accumulate
            mult_bits: 24.0 * 24.0,
            acc_bits: 48.0 + 32.0, // product normalise + accumulator round
            shift_bits: 48.0 * 6.0,
            exp_bits: 8.0 + 8.0,
        },
        QFormat::Fixed { w } | QFormat::FixedRow { w } => MacStructure {
            // pure integer MAC: multiplier + wide accumulator, no shifters
            mult_bits: (w as f64) * (w as f64),
            acc_bits: 2.0 * w as f64 + 4.0,
            shift_bits: 0.0,
            exp_bits: 0.0,
        },
        QFormat::MiniFloat { e, m } | QFormat::Dmf { e, m } => {
            let mant = m as f64 + 1.0; // implicit bit
            let acc = 2.0 * mant + 4.0;
            MacStructure {
                mult_bits: mant * mant,
                acc_bits: acc,
                // align into a fixed-point accumulator across 2^E binades:
                // shifter width × log2(range) stages
                shift_bits: acc * e as f64 / 2.0,
                exp_bits: 2.0 * e as f64,
            }
        }
        QFormat::Bfp { e, m, n } => {
            let mant = m as f64; // sign-magnitude, no implicit bit
            MacStructure {
                // integer mantissa MAC inside the block — Eq. 4's cheap loop
                mult_bits: mant * mant,
                acc_bits: 2.0 * mant + (n as f64).log2() + 1.0,
                // single post-block scaling shift, amortised over N
                shift_bits: (2.0 * mant + 8.0) * 4.0 / n as f64,
                // one shared-exponent adder per block pair, amortised
                exp_bits: 2.0 * e as f64 / n as f64,
            }
        }
        QFormat::Bm { e, m, b, n } => {
            let mant = m as f64 + 1.0;
            let acc = 2.0 * mant + 4.0;
            MacStructure {
                mult_bits: mant * mant,
                acc_bits: acc,
                shift_bits: acc * e as f64 / 2.0,
                // per-element exponent add + amortised shared-bias add
                exp_bits: 2.0 * e as f64 + 2.0 * b as f64 / n as f64,
            }
        }
        QFormat::Bl { e, b, n } => MacStructure {
            // no multiplier at all: product = exponent add
            mult_bits: 0.0,
            acc_bits: 2.0f64.powi(2) + 8.0, // small decode+accumulate
            // shift by exponent to accumulate in fixed point
            shift_bits: 16.0 * e as f64 / 2.0,
            exp_bits: 2.0 * e as f64 + 2.0 * b as f64 / n as f64,
        },
    }
}

/// Calibrated model coefficients (LUTs per structural bit).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub c_mult: f64,
    pub c_acc: f64,
    pub c_shift: f64,
    pub c_exp: f64,
}

impl CostModel {
    /// LUT-equivalent area of one MAC.
    pub fn area(&self, fmt: QFormat) -> f64 {
        let s = mac_structure(fmt);
        self.c_mult * s.mult_bits
            + self.c_acc * s.acc_bits
            + self.c_shift * s.shift_bits
            + self.c_exp * s.exp_bits
    }

    /// Arithmetic density relative to FP32 (Table 6 last column).
    pub fn arithmetic_density(&self, fmt: QFormat) -> f64 {
        self.area(QFormat::Fp32) / self.area(fmt)
    }
}

/// The paper's published (format, LUT-equivalent area factor) anchor rows
/// from Table 6. BFP rows are held out for validation.
pub fn paper_anchor_rows() -> Vec<(QFormat, f64)> {
    use crate::quant::config::presets::*;
    vec![
        (QFormat::Fp32, 835.0),
        (fixed8(), 109.0),
        (minifloat8(), 48.0),
        (bm8(), 51.0),
        (bl8(), 52.0),
    ]
}

/// Held-out validation rows (BFP family, Table 6).
pub fn paper_validation_rows() -> Vec<(QFormat, f64)> {
    use crate::quant::config::presets::*;
    vec![(bfp_w(8), 58.0), (bfp_w(6), 43.6), (bfp_w(4), 22.4)]
}

/// Non-negative least-squares calibration of the four coefficients on the
/// anchor rows (active-set: solve, drop the most negative coefficient,
/// repeat — coefficients are LUTs/bit, so they must be ≥ 0).
pub fn calibrate() -> CostModel {
    let rows = paper_anchor_rows();
    let feats: Vec<[f64; 4]> = rows
        .iter()
        .map(|(f, _)| {
            let s = mac_structure(*f);
            [s.mult_bits, s.acc_bits, s.shift_bits, s.exp_bits]
        })
        .collect();
    let ys: Vec<f64> = rows.iter().map(|(_, a)| *a).collect();
    let mut active = [true; 4];
    loop {
        // normal equations over active features
        let mut ata = [[0.0f64; 4]; 4];
        let mut aty = [0.0f64; 4];
        for (f, y) in feats.iter().zip(&ys) {
            for i in 0..4 {
                if !active[i] {
                    continue;
                }
                aty[i] += f[i] * y;
                for j in 0..4 {
                    if active[j] {
                        ata[i][j] += f[i] * f[j];
                    }
                }
            }
        }
        for i in 0..4 {
            if active[i] {
                ata[i][i] += 1e-9;
            } else {
                ata[i][i] = 1.0; // pin inactive coefficient to 0
            }
        }
        let x = solve4(ata, aty);
        // find the most negative active coefficient
        let mut worst = None;
        for i in 0..4 {
            if active[i] && x[i] < -1e-12 {
                if worst.map(|(_, v)| x[i] < v).unwrap_or(true) {
                    worst = Some((i, x[i]));
                }
            }
        }
        match worst {
            Some((i, _)) => active[i] = false,
            None => {
                return CostModel {
                    c_mult: x[0].max(0.0),
                    c_acc: x[1].max(0.0),
                    c_shift: x[2].max(0.0),
                    c_exp: x[3].max(0.0),
                }
            }
        }
    }
}

fn solve4(mut a: [[f64; 4]; 4], mut b: [f64; 4]) -> [f64; 4] {
    for col in 0..4 {
        // partial pivot
        let mut piv = col;
        for r in col + 1..4 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() < 1e-12 {
            continue;
        }
        for r in 0..4 {
            if r == col {
                continue;
            }
            let f = a[r][col] / d;
            for c in 0..4 {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = [0.0; 4];
    for i in 0..4 {
        x[i] = if a[i][i].abs() < 1e-12 {
            0.0
        } else {
            b[i] / a[i][i]
        };
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets::*;

    #[test]
    fn calibration_fits_anchors() {
        let m = calibrate();
        for (fmt, paper) in paper_anchor_rows() {
            let got = m.area(fmt);
            let rel = (got - paper).abs() / paper;
            assert!(rel < 0.6, "{}: model {got:.1} vs paper {paper} (rel {rel:.2})", fmt.name());
        }
    }

    #[test]
    fn bfp_validation_within_factor() {
        // held-out rows: require correct order of magnitude + ranking
        let m = calibrate();
        for (fmt, paper) in paper_validation_rows() {
            let got = m.area(fmt);
            let ratio = got / paper;
            assert!(
                ratio > 0.35 && ratio < 2.8,
                "{}: model {got:.1} vs paper {paper}",
                fmt.name()
            );
        }
        // ranking: BFP4 < BFP6 < BFP8 area
        assert!(m.area(bfp_w(4)) < m.area(bfp_w(6)));
        assert!(m.area(bfp_w(6)) < m.area(bfp_w(8)));
    }

    #[test]
    fn density_ranking_matches_table6() {
        // the paper's qualitative ordering of arithmetic density:
        // BFP4 > BFP6 > MiniFloat ≈ BL ≈ BM ≈ BFP8 > Int8 > FP32
        let m = calibrate();
        let d = |f| m.arithmetic_density(f);
        assert!(d(bfp_w(4)) > d(bfp_w(6)));
        assert!(d(bfp_w(6)) > d(bfp_w(8)) * 0.9);
        assert!(d(minifloat8()) > d(fixed8()));
        assert!(d(fixed8()) > d(QFormat::Fp32));
        assert!((d(QFormat::Fp32) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bl_has_no_multiplier() {
        assert_eq!(mac_structure(bl8()).mult_bits, 0.0);
    }
}
