//! Hardware-efficiency metrics (paper §3.2): memory density, arithmetic
//! density (LUT-area model substituting Vivado synthesis — DESIGN.md §3),
//! and the FLOP/operand profiler feeding the mixed-precision search.

pub mod arith;
pub mod flops;
pub mod memory;

pub use arith::{calibrate, CostModel};
pub use memory::{average_bits, format_density, model_memory_density};
