//! Memory density (paper §3.2): reciprocal of the size of activation +
//! weight data relative to float32. Computed from format bit widths
//! (matches Table 3's Mem column) and, for whole models, from the actual
//! GEMM operand inventory collected by [`crate::density::flops`].

use crate::quant::config::QFormat;

/// Memory density of a single format (Table 3 column).
pub fn format_density(fmt: QFormat) -> f64 {
    fmt.memory_density()
}

/// Weighted memory density over a set of (numel, format) tensors — the
/// quantity the search objective `O_f = acc + α·mem` uses.
pub fn model_memory_density(tensors: &[(usize, QFormat)]) -> f64 {
    let fp32_bits: f64 = tensors.iter().map(|(n, _)| *n as f64 * 32.0).sum();
    let q_bits: f64 = tensors
        .iter()
        .map(|(n, f)| *n as f64 * f.bits_per_element())
        .sum();
    if q_bits == 0.0 {
        return 1.0;
    }
    fp32_bits / q_bits
}

/// Average effective bit width (the "4.3-bit OPT-2.7B" accounting in §4.4).
pub fn average_bits(tensors: &[(usize, QFormat)]) -> f64 {
    let numel: f64 = tensors.iter().map(|(n, _)| *n as f64).sum();
    let q_bits: f64 = tensors
        .iter()
        .map(|(n, f)| *n as f64 * f.bits_per_element())
        .sum();
    if numel == 0.0 {
        0.0
    } else {
        q_bits / numel
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::config::presets;

    #[test]
    fn uniform_model_density_equals_format_density() {
        let fmt = presets::bfp_w(6);
        let ts = vec![(1000, fmt), (2048, fmt)];
        assert!((model_memory_density(&ts) - fmt.memory_density()).abs() < 1e-9);
    }

    #[test]
    fn mixed_density_between_parts() {
        let ts = vec![(1000, presets::bfp_w(4)), (1000, presets::bfp_w(8))];
        let d = model_memory_density(&ts);
        assert!(d < presets::bfp_w(4).memory_density());
        assert!(d > presets::bfp_w(8).memory_density());
    }

    #[test]
    fn average_bits_uniform() {
        let ts = vec![(64, presets::bfp_w(4))];
        assert!((average_bits(&ts) - 4.5).abs() < 1e-9); // 1+3+8/16
    }
}
