//! SmoothQuant baseline (Xiao et al. 2022): migrate activation outliers
//! into the weights before 8-bit fixed-point quantisation.
//!
//! Per input channel j: `s_j = max|X_j|^α / max|W_j|^(1-α)`; activations
//! are divided by s_j (folded into the preceding LayerNorm gain/bias) and
//! the corresponding weight rows multiplied by s_j. Applied to the four
//! LN-preceded GEMMs (①②③⑦ — exactly where the original implementation
//! can fold the scales). "SmoothQuant" then quantises 6/8 GEMMs (④⑤ left
//! in fp16, as the released code does); our amended **SmoothQuant-c**
//! quantises all 8 (the paper's Appendix B.2 correction).

use crate::model::params::Params;
use crate::model::plan::QuantPlan;
use crate::model::transformer::{ActStats, Model};
use crate::quant::config::presets;
use crate::util::stats::Welford;

/// Per-channel absmax calibration collector.
#[derive(Clone, Debug, Default)]
pub struct Calibration {
    /// (layer, "xn1"/"xn2") → per-channel absmax
    pub absmax: std::collections::BTreeMap<(usize, &'static str), Vec<f32>>,
}

/// Run calibration batches through an FP32 model, recording per-channel
/// absmax of the LN outputs feeding ①②③ and ⑦ (via the model's stats hook).
pub fn calibrate(params: &Params, samples: &[Vec<usize>]) -> Calibration {
    let model = Model::new(params.clone(), QuantPlan::fp32());
    let cfg = &params.cfg;
    let d = cfg.d_model;
    let mut stats = ActStats::default();
    for s in samples {
        model.forward(s, Some(&mut stats));
    }
    let mut cal = Calibration::default();
    for li in 0..cfg.n_layers {
        let x1 = stats
            .chan_absmax
            .get(&("X1".to_string(), li))
            .cloned()
            .unwrap_or_else(|| vec![1.0; d]);
        let x2 = stats
            .chan_absmax
            .get(&("X2".to_string(), li))
            .cloned()
            .unwrap_or_else(|| vec![1.0; d]);
        cal.absmax.insert((li, "xn1"), x1);
        cal.absmax.insert((li, "xn2"), x2);
    }
    cal
}

/// Produce SmoothQuant-transformed parameters: LN gains/biases divided by
/// s, weight rows multiplied by s.
pub fn smooth_params(params: &Params, cal: &Calibration, alpha: f32) -> Params {
    let mut p = params.clone();
    let d = p.cfg.d_model;
    for (li, l) in p.layers.iter_mut().enumerate() {
        // --- attention input (xn1 feeds wq, wk, wv) ---
        let ax = &cal.absmax[&(li, "xn1")];
        let mut wmax = vec![0.0f32; d];
        for w in [&l.wq, &l.wk, &l.wv] {
            for j in 0..d {
                for c in 0..d {
                    wmax[j] = wmax[j].max(w.data[j * d + c].abs());
                }
            }
        }
        let s = scales(ax, &wmax, alpha);
        for j in 0..d {
            l.ln1_g[j] /= s[j];
            l.ln1_b[j] /= s[j];
        }
        for w in [&mut l.wq, &mut l.wk, &mut l.wv] {
            for j in 0..d {
                for c in 0..d {
                    w.data[j * d + c] *= s[j];
                }
            }
        }
        // --- MLP input (xn2 feeds w1) ---
        let ax2 = &cal.absmax[&(li, "xn2")];
        let f = p.cfg.d_ff;
        let mut wmax2 = vec![0.0f32; d];
        for j in 0..d {
            for c in 0..f {
                wmax2[j] = wmax2[j].max(l.w1.data[j * f + c].abs());
            }
        }
        let s2 = scales(ax2, &wmax2, alpha);
        for j in 0..d {
            l.ln2_g[j] /= s2[j];
            l.ln2_b[j] /= s2[j];
        }
        for j in 0..d {
            for c in 0..f {
                l.w1.data[j * f + c] *= s2[j];
            }
        }
    }
    p
}

fn scales(act_max: &[f32], w_max: &[f32], alpha: f32) -> Vec<f32> {
    act_max
        .iter()
        .zip(w_max)
        .map(|(&a, &w)| {
            let s = a.max(1e-5).powf(alpha) / w.max(1e-5).powf(1.0 - alpha);
            s.clamp(1e-3, 1e3)
        })
        .collect()
}

/// Build the two SmoothQuant model variants from FP32 params.
/// Returns (smoothquant 6/8, smoothquant-c 8/8) models at W8A8 fixed-point.
pub fn build(params: &Params, samples: &[Vec<usize>], alpha: f32) -> (Model, Model) {
    let cal = calibrate(params, samples);
    let smoothed = smooth_params(params, &cal, alpha);
    let n_layers = params.cfg.n_layers;
    let plan68 = QuantPlan::six_of_eight(presets::fixed8(), n_layers);
    let plan88 = QuantPlan::uniform(presets::fixed8());
    (
        Model::new(smoothed.clone(), plan68),
        Model::new(smoothed, plan88),
    )
}

/// Variance helper used in tests.
pub fn channel_spread(xs: &[f32]) -> f64 {
    let mut w = Welford::new();
    w.push_slice(xs);
    w.variance()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::train_stream;
    use crate::data::lm_eval::perplexity;
    use crate::data::vocab::Vocab;
    use crate::model::config::ModelConfig;

    fn samples() -> Vec<Vec<usize>> {
        let v = Vocab::build();
        let s = train_stream(&v, 400);
        s.chunks(48).take(4).map(|c| c.to_vec()).collect()
    }

    #[test]
    fn scales_balance_act_and_weight() {
        let s = scales(&[8.0, 0.5], &[0.5, 0.5], 0.5);
        assert!(s[0] > s[1]); // big activation channel gets scaled down harder
    }

    #[test]
    fn smoothing_preserves_fp32_function() {
        // dividing LN gain by s and multiplying W rows by s is an exact
        // identity in fp32 (up to rounding)
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 11);
        let cal = calibrate(&p, &samples());
        let sp = smooth_params(&p, &cal, 0.5);
        let m0 = Model::new(p, QuantPlan::fp32());
        let m1 = Model::new(sp, QuantPlan::fp32());
        let toks = [1usize, 5, 9, 42];
        let a = m0.forward(&toks, None);
        let b = m1.forward(&toks, None);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 3e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn smoothing_reduces_activation_channel_spread() {
        let cfg = ModelConfig::preset("nano");
        let p = Params::init(&cfg, 13);
        let cal = calibrate(&p, &samples());
        let sp = smooth_params(&p, &cal, 0.5);
        let cal2 = calibrate(&sp, &samples());
        // per-channel absmax spread should shrink after smoothing
        let spread = |c: &Calibration| {
            c.absmax
                .values()
                .map(|v| channel_spread(v))
                .sum::<f64>()
        };
        assert!(spread(&cal2) < spread(&cal) * 1.05);
    }

    #[test]
    fn smoothquant_beats_plain_fixed8_after_training() {
        // train a tiny model briefly so real activation structure exists,
        // then compare W8A8 fixed-point with and without smoothing
        let v = Vocab::build();
        let stream = train_stream(&v, 3000);
        let cfg = ModelConfig::preset("nano");
        let mut p = Params::init(&cfg, 3);
        crate::train::train_lm(
            &mut p,
            &QuantPlan::fp32(),
            &stream,
            &crate::train::TrainConfig {
                steps: 40,
                seq_len: 32,
                lr: 3e-3,
                seed: 1,
                log_every: 0,
            },
            |_, _| {},
        );
        let test = crate::data::corpus::test_stream(&v, 400);
        let (sq68, _sqc) = build(&p, &samples(), 0.5);
        let plain = Model::new(p, QuantPlan::uniform(presets::fixed8()));
        let ppl_plain = perplexity(&plain, &test, 48, 4).perplexity;
        let ppl_sq = perplexity(&sq68, &test, 48, 4).perplexity;
        assert!(
            ppl_sq < ppl_plain * 1.5,
            "smoothquant {ppl_sq} vs plain fixed8 {ppl_plain}"
        );
    }
}
